//! `cargo bench --bench table3` regenerates Table 3 (VGG16 / CIFAR10
//! stand-in). See table2.rs.

fn main() {
    let steps: u64 =
        std::env::var("QADAM_BENCH_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(96);
    qadam::coordinator::tables::run_table("table3", steps, 4, "results").unwrap();
}
