//! Shard-scaling sweep: rounds/sec and uplink+downlink bytes as the
//! parameter server splits into more shards, everything else fixed
//! (threaded engine, delta downlink, kg=2).
//!
//! The interesting outputs: how round throughput moves with the shard
//! count on one machine (in-process, the shards only change codec
//! scale granularity and frame count — the real win is that each shard
//! can leave the process), and what sharding does to the byte
//! accounting (per-shard frame headers and per-shard codec scales are
//! real traffic).
//!
//!   cargo bench --bench shard_scaling
//!   cargo bench --bench shard_scaling -- --rounds 1 --dim 4096 --shards 1,2   # CI smoke
//!
//! Flags: --rounds N (default 60), --dim D (default 32768),
//! --workers W (default 8), --shards CSV (default 1,2,4,8),
//! --json PATH (default BENCH_shard_scaling.json).
//!
//! Emits a machine-readable `BENCH_shard_scaling.json` next to the
//! working directory so the perf trajectory can be tracked run over
//! run.

use qadam::optim::{LrSchedule, QAdamEf};
use qadam::ps::transport::Transport;
use qadam::ps::worker::{SimGradSource, Worker};
use qadam::ps::{ShardPlan, ShardedServer, ThreadedBus};
use qadam::sim::StochasticProblem;
use qadam::util::Args;
use std::time::Instant;

fn mk_workers(n: usize, dim: usize, plan: &ShardPlan) -> Vec<Worker> {
    (0..n as u32)
        .map(|i| {
            let src = SimGradSource { problem: StochasticProblem::new(dim, 0.05, 3) };
            let opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 1e-3 });
            let mut w = Worker::new(i, Box::new(opt), Box::new(src), 7);
            w.set_shards(plan.clone());
            w
        })
        .collect()
}

struct ShardResult {
    shards: usize,
    secs: f64,
    rounds_per_sec: f64,
    up_bytes: u64,
    down_bytes: u64,
}

fn run_one(dim: usize, nworkers: usize, shards: usize, rounds: u64) -> ShardResult {
    let plan = ShardPlan::uniform(dim, shards);
    let x0: Vec<f32> = (0..dim).map(|i| 0.1 * (i as f32 * 0.013).sin()).collect();
    let mut srv = ShardedServer::new(x0, None, plan.clone(), 1 << 16, 1);
    srv.enable_delta_downlink(Some(2), 16);
    let mut workers = mk_workers(nworkers, dim, &plan);
    let mut bus = ThreadedBus::new();
    let start = Instant::now();
    for _ in 0..rounds {
        let frames = srv.broadcast(nworkers);
        let lanes = bus.round_sharded(&frames, &mut workers).unwrap();
        srv.apply(&lanes).unwrap();
    }
    let secs = start.elapsed().as_secs_f64();
    let stats = srv.stats();
    ShardResult {
        shards,
        secs,
        rounds_per_sec: rounds as f64 / secs.max(1e-9),
        up_bytes: stats.up_bytes,
        down_bytes: stats.down_bytes,
    }
}

fn main() {
    let a = Args::parse_env().unwrap();
    let rounds: u64 = a.get("rounds", 60).unwrap();
    let dim: usize = a.get("dim", 32768).unwrap();
    let nworkers: usize = a.get("workers", 8).unwrap();
    let shard_list = a.get_str("shards", "1,2,4,8");
    let json_path = a.get_str("json", "BENCH_shard_scaling.json");
    a.reject_unknown().unwrap();
    let shard_counts: Vec<usize> = shard_list
        .split(',')
        .map(|s| s.trim().parse().expect("--shards takes a comma list of counts"))
        .collect();

    println!("== shard_scaling: dim={dim} workers={nworkers} rounds={rounds} ==");
    let mut results = Vec::with_capacity(shard_counts.len());
    for &s in &shard_counts {
        let r = run_one(dim, nworkers, s, rounds);
        println!(
            "shards={:<2} {:>9.1} rounds/s  up={:>10} B  down={:>10} B  ({:.3}s)",
            r.shards, r.rounds_per_sec, r.up_bytes, r.down_bytes, r.secs
        );
        results.push(r);
    }

    // Machine-readable trajectory point.
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"shard_scaling\",\n");
    json.push_str(&format!(
        "  \"dim\": {dim},\n  \"workers\": {nworkers},\n  \"rounds\": {rounds},\n"
    ));
    json.push_str("  \"results\": [\n");
    for (i, r) in results.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"shards\": {}, \"rounds_per_sec\": {:.3}, \"up_bytes\": {}, \"down_bytes\": {}, \"secs\": {:.6}}}{}\n",
            r.shards,
            r.rounds_per_sec,
            r.up_bytes,
            r.down_bytes,
            r.secs,
            if i + 1 == results.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write(&json_path, json).expect("writing the bench JSON");
    println!("wrote {json_path}");
}
