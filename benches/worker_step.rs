//! Worker-step benchmarks: the per-iteration cost of Alg. 3 on each
//! engine, the sequential-vs-threaded scaling of a full synchronous
//! round, plus the PJRT model gradient (the other per-round cost).
//!
//!   cargo bench --bench worker_step
//!   cargo bench --bench worker_step -- --dim 4096 --workers 1,2 \
//!       --step-dims 4096 --target-ms 20 --downlink-rounds 4 \
//!       --skip-pjrt --json /tmp/w.json                     # CI smoke
//!
//! Flags: --dim D for the round benches (default 262144),
//! --workers CSV (default 1,2,4,8,16), --step-dims CSV for the bare
//! optimizer step (default 65536,1048576,3257856), --target-ms N per
//! measurement (default 300), --downlink-rounds N (default 64),
//! --skip-pjrt, --json PATH (default BENCH_worker_step.json).
//!
//! The JSON is the bench trajectory: `scripts/bench_diff.sh` compares a
//! fresh run against the committed `BENCH_worker_step.json` and fails
//! on regression. Refresh the baseline with
//! `scripts/bench_diff.sh --refresh`.

use qadam::data::{Dataset, SyntheticVector, SyntheticVision};
use qadam::models::{artifacts_dir, Manifest};
use qadam::optim::{LrSchedule, QAdamEf, WorkerOpt};
use qadam::ps::transport::{LocalBus, ThreadedBus};
use qadam::ps::worker::{SimGradSource, Worker};
use qadam::ps::ParameterServer;
use qadam::quant::seeded_rng;
use qadam::runtime::kernel::PjrtQAdam;
use qadam::runtime::{KernelQAdam, ModelRuntime, Runtime};
use qadam::sim::StochasticProblem;
use qadam::util::bench::{bench, BenchResult};
use qadam::util::{Args, DetRng};
use std::sync::Arc;

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut r = DetRng::seed_stream(seed, 0);
    (0..n).map(|_| r.gen_normal() * 0.01).collect()
}

struct Session {
    target_ms: u64,
    entries: Vec<BenchResult>,
}

impl Session {
    fn run(&mut self, name: &str, bytes: Option<usize>, f: impl FnMut()) -> f64 {
        let res = bench(name, self.target_ms, f);
        res.print(bytes);
        let ns = res.median_ns;
        self.entries.push(res);
        ns
    }
}

fn mk_workers(n: usize, dim: usize) -> Vec<Worker> {
    (0..n)
        .map(|i| {
            let src = SimGradSource { problem: StochasticProblem::new(dim, 0.05, 3) };
            let opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 1e-3 });
            Worker::new(i as u32, Box::new(opt), Box::new(src), 7)
        })
        .collect()
}

/// Full synchronous rounds (broadcast → worker steps → decode/apply) on
/// the sequential vs the threaded engine, across worker counts. Both
/// engines compute bit-identical trajectories (asserted in
/// `ps::transport` tests); this measures the wall-clock gap.
fn round_scaling_bench(sess: &mut Session, dim: usize, worker_counts: &[usize]) {
    let threads = qadam::util::par::available_threads();
    println!("-- synchronous round, dim={dim}, kg=2, kx=6 ({threads} hw threads) --");
    let x0: Vec<f32> = (0..dim).map(|i| 0.1 * (i as f32 * 0.013).sin()).collect();
    for &nw in worker_counts {
        let seq = {
            let mut workers = mk_workers(nw, dim);
            let mut ps = ParameterServer::new(x0.clone(), Some(6));
            let bus = LocalBus::default();
            sess.run(&format!("round sequential dim={dim} workers={nw}"), None, || {
                let replies = {
                    let (b, _) = ps.broadcast(nw);
                    bus.round(&b, &mut workers).unwrap()
                };
                ps.apply(&replies).unwrap();
            })
        };
        let thr = {
            let mut workers = mk_workers(nw, dim);
            let mut ps = ParameterServer::with_shards(
                x0.clone(),
                Some(6),
                qadam::ps::server::DEFAULT_BLOCK,
                threads,
            );
            let bus = ThreadedBus::new();
            sess.run(&format!("round threaded dim={dim} workers={nw}"), None, || {
                let replies = {
                    let (b, _) = ps.broadcast(nw);
                    bus.round(&b, &mut workers).unwrap()
                };
                ps.apply(&replies).unwrap();
            })
        };
        println!("   -> threaded speedup at {nw:>2} workers: {:.2}x", seq / thr);
    }
}

/// Downlink accounting on the synchronous round: full fp32 broadcasts
/// vs compressed weight deltas (kg=2, resync every 50). The acceptance
/// target is a ≥4x reduction in `stats.down_bytes`.
fn downlink_bench(dim: usize, rounds: u64) -> (u64, u64) {
    let nw = 8usize;
    println!("-- downlink accounting, dim={dim}, {nw} workers, {rounds} rounds --");
    let x0: Vec<f32> = (0..dim).map(|i| 0.1 * (i as f32 * 0.013).sin()).collect();
    let run_mode = |delta: bool| -> (u64, f64) {
        let mut workers = mk_workers(nw, dim);
        let mut ps = ParameterServer::new(x0.clone(), None);
        if delta {
            ps.enable_delta_downlink(Box::new(qadam::quant::LogQuant::new(2)), 50);
        }
        let bus = LocalBus::default();
        let t0 = std::time::Instant::now();
        for _ in 0..rounds {
            let replies = {
                let (b, _) = ps.broadcast(nw);
                bus.round(&b, &mut workers).unwrap()
            };
            ps.apply(&replies).unwrap();
        }
        (ps.stats.down_bytes, t0.elapsed().as_secs_f64())
    };
    let (full_bytes, full_s) = run_mode(false);
    let (delta_bytes, delta_s) = run_mode(true);
    let per_round = |b: u64| b as f64 / rounds as f64 / nw as f64 / 1e6;
    println!(
        "   downlink full : {:8.3} MB/round/worker  ({full_s:6.2}s)",
        per_round(full_bytes)
    );
    println!(
        "   downlink delta: {:8.3} MB/round/worker  ({delta_s:6.2}s)",
        per_round(delta_bytes)
    );
    println!(
        "   -> down-bytes reduction: {:.2}x (target >= 4x)",
        full_bytes as f64 / delta_bytes as f64
    );
    (full_bytes, delta_bytes)
}

fn pjrt_benches(sess: &mut Session) {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("(skipping PJRT benches: run `make artifacts`)");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();

    // Pallas kernel step via PJRT.
    let kernel = Arc::new(KernelQAdam::load(&rt, &dir, &manifest).unwrap());
    for &n in &[1usize << 16, 1 << 20] {
        let g = randv(n, 3);
        let mut opt = PjrtQAdam::new(kernel.clone(), n, 2, LrSchedule::Const { alpha: 1e-3 });
        let mut rng = seeded_rng(0, 0);
        let mut t = 0u64;
        sess.run(&format!("pjrt qadam step dim={n}"), Some(n * 4), || {
            t += 1;
            std::hint::black_box(opt.step(&g, t, 0, &mut rng).wire_bytes());
        });
    }

    // Model gradient graphs (per-round worker compute).
    {
        let model = ModelRuntime::load(&rt, &dir, &manifest, "mlp").unwrap();
        let data = SyntheticVector::new(64, 10, 0);
        let flat = model.init_flat(0);
        let batch = data.train_batch(0, 0, model.meta.train_x.shape[0]);
        sess.run("pjrt grad mlp (batch 16)", None, || {
            std::hint::black_box(model.loss_grad(&flat, &batch).unwrap().0);
        });
    }
    {
        let model = ModelRuntime::load(&rt, &dir, &manifest, "vgg_sim").unwrap();
        let data = SyntheticVision::cifar10_sim(0);
        let flat = model.init_flat(0);
        let batch = data.train_batch(0, 0, model.meta.train_x.shape[0]);
        sess.run("pjrt grad vgg_sim (batch 16)", None, || {
            std::hint::black_box(model.loss_grad(&flat, &batch).unwrap().0);
        });
    }
}

fn parse_csv(s: &str, what: &str) -> Vec<usize> {
    s.split(',')
        .map(|x| x.trim().parse().unwrap_or_else(|_| panic!("{what} takes a comma list")))
        .collect()
}

fn main() {
    let a = Args::parse_env().unwrap();
    let dim: usize = a.get("dim", 1 << 18).unwrap();
    let worker_counts = parse_csv(&a.get_str("workers", "1,2,4,8,16"), "--workers");
    let step_dims = parse_csv(&a.get_str("step_dims", "65536,1048576,3257856"), "--step-dims");
    let target_ms: u64 = a.get("target_ms", 300).unwrap();
    let downlink_rounds: u64 = a.get("downlink_rounds", 64).unwrap();
    let skip_pjrt = a.flag("skip_pjrt");
    let json_path = a.get_str("json", "BENCH_worker_step.json");
    a.reject_unknown().unwrap();

    println!("== worker_step (dim={dim}, {target_ms} ms/measurement) ==");
    let mut sess = Session { target_ms, entries: Vec::new() };
    round_scaling_bench(&mut sess, dim, &worker_counts);
    let (full_bytes, delta_bytes) = downlink_bench(dim, downlink_rounds);
    // Native fused QAdam step at model-scale dims.
    for &n in &step_dims {
        let g = randv(n, 3);
        let mut opt = QAdamEf::paper_default(n, 2, LrSchedule::Const { alpha: 1e-3 });
        let mut rng = seeded_rng(0, 0);
        let mut t = 0u64;
        sess.run(&format!("native qadam step dim={n}"), Some(n * 4), || {
            t += 1;
            std::hint::black_box(opt.step(&g, t, 0, &mut rng).wire_bytes());
        });
    }
    if !skip_pjrt {
        pjrt_benches(&mut sess);
    }

    // Machine-readable trajectory point.
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"worker_step\",\n");
    json.push_str(&format!("  \"dim\": {dim},\n  \"target_ms\": {target_ms},\n"));
    json.push_str("  \"results\": [\n");
    for (i, e) in sess.entries.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"median_ns\": {:.1}, \"p10_ns\": {:.1}, \"p90_ns\": {:.1}, \"iters\": {}}}{}\n",
            e.name,
            e.median_ns,
            e.p10_ns,
            e.p90_ns,
            e.iters,
            if i + 1 == sess.entries.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!(
        "  \"downlink\": {{\"rounds\": {downlink_rounds}, \"full_bytes\": {full_bytes}, \"delta_bytes\": {delta_bytes}, \"reduction\": {:.3}}}\n",
        full_bytes as f64 / (delta_bytes.max(1)) as f64
    ));
    json.push_str("}\n");
    std::fs::write(&json_path, json).expect("writing the bench JSON");
    println!("wrote {json_path}");
}
