//! Worker-step benchmarks: the per-iteration cost of Alg. 3 on each
//! engine, the sequential-vs-threaded scaling of a full synchronous
//! round, plus the PJRT model gradient (the other per-round cost).
//!
//!   cargo bench --bench worker_step

use qadam::data::{Dataset, SyntheticVector, SyntheticVision};
use qadam::models::{artifacts_dir, Manifest};
use qadam::optim::{LrSchedule, QAdamEf, WorkerOpt};
use qadam::ps::transport::{LocalBus, ThreadedBus};
use qadam::ps::worker::{SimGradSource, Worker};
use qadam::ps::ParameterServer;
use qadam::quant::seeded_rng;
use qadam::runtime::kernel::PjrtQAdam;
use qadam::runtime::{KernelQAdam, ModelRuntime, Runtime};
use qadam::sim::StochasticProblem;
use qadam::util::bench::run;
use qadam::util::DetRng;
use std::sync::Arc;

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut r = DetRng::seed_stream(seed, 0);
    (0..n).map(|_| r.gen_normal() * 0.01).collect()
}

/// Full synchronous rounds (broadcast → worker steps → decode/apply) on
/// the sequential vs the threaded engine, across worker counts. Both
/// engines compute bit-identical trajectories (asserted in
/// `ps::transport` tests); this measures the wall-clock gap.
fn round_scaling_bench() {
    let dim = 1usize << 18;
    let threads = qadam::util::par::available_threads();
    println!(
        "-- synchronous round, dim={dim}, kg=2, kx=6 ({threads} hw threads) --"
    );
    let x0: Vec<f32> = (0..dim).map(|i| 0.1 * (i as f32 * 0.013).sin()).collect();
    let mk_workers = |n: usize| -> Vec<Worker> {
        (0..n)
            .map(|i| {
                let src = SimGradSource { problem: StochasticProblem::new(dim, 0.05, 3) };
                let opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 1e-3 });
                Worker::new(i as u32, Box::new(opt), Box::new(src), 7)
            })
            .collect()
    };
    for &nw in &[1usize, 2, 4, 8, 16] {
        let seq = {
            let mut workers = mk_workers(nw);
            let mut ps = ParameterServer::new(x0.clone(), Some(6));
            let bus = LocalBus::default();
            run(&format!("round sequential workers={nw:>2}"), None, || {
                let replies = {
                    let (b, _) = ps.broadcast(nw);
                    bus.round(&b, &mut workers).unwrap()
                };
                ps.apply(&replies).unwrap();
            })
        };
        let thr = {
            let mut workers = mk_workers(nw);
            let mut ps = ParameterServer::with_shards(
                x0.clone(),
                Some(6),
                qadam::ps::server::DEFAULT_BLOCK,
                threads,
            );
            let bus = ThreadedBus::new();
            run(&format!("round threaded   workers={nw:>2}"), None, || {
                let replies = {
                    let (b, _) = ps.broadcast(nw);
                    bus.round(&b, &mut workers).unwrap()
                };
                ps.apply(&replies).unwrap();
            })
        };
        println!(
            "   -> threaded speedup at {nw:>2} workers: {:.2}x",
            seq.median_ns / thr.median_ns
        );
    }
}

/// Downlink accounting on the 8-worker synchronous round: full fp32
/// broadcasts vs compressed weight deltas (kg=2, resync every 50).
/// The acceptance target is a ≥4x reduction in `stats.down_bytes`.
fn downlink_bench() {
    let dim = 1usize << 18;
    let nw = 8usize;
    let rounds = 64u64;
    println!("-- downlink accounting, dim={dim}, {nw} workers, {rounds} rounds --");
    let x0: Vec<f32> = (0..dim).map(|i| 0.1 * (i as f32 * 0.013).sin()).collect();
    let mk_workers = || -> Vec<Worker> {
        (0..nw)
            .map(|i| {
                let src = SimGradSource { problem: StochasticProblem::new(dim, 0.05, 3) };
                let opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 1e-3 });
                Worker::new(i as u32, Box::new(opt), Box::new(src), 7)
            })
            .collect()
    };
    let run_mode = |delta: bool| -> (u64, f64) {
        let mut workers = mk_workers();
        let mut ps = ParameterServer::new(x0.clone(), None);
        if delta {
            ps.enable_delta_downlink(Box::new(qadam::quant::LogQuant::new(2)), 50);
        }
        let bus = LocalBus::default();
        let t0 = std::time::Instant::now();
        for _ in 0..rounds {
            let replies = {
                let (b, _) = ps.broadcast(nw);
                bus.round(&b, &mut workers).unwrap()
            };
            ps.apply(&replies).unwrap();
        }
        (ps.stats.down_bytes, t0.elapsed().as_secs_f64())
    };
    let (full_bytes, full_s) = run_mode(false);
    let (delta_bytes, delta_s) = run_mode(true);
    let per_round = |b: u64| b as f64 / rounds as f64 / nw as f64 / 1e6;
    println!(
        "   downlink full : {:8.3} MB/round/worker  ({full_s:6.2}s)",
        per_round(full_bytes)
    );
    println!(
        "   downlink delta: {:8.3} MB/round/worker  ({delta_s:6.2}s)",
        per_round(delta_bytes)
    );
    println!(
        "   -> down-bytes reduction: {:.2}x (target >= 4x)",
        full_bytes as f64 / delta_bytes as f64
    );
}

fn main() {
    println!("== worker_step ==");
    round_scaling_bench();
    downlink_bench();
    // Native fused QAdam step at model-scale dims.
    for &n in &[1usize << 16, 1 << 20, 3_257_856] {
        let g = randv(n, 3);
        let mut opt = QAdamEf::paper_default(n, 2, LrSchedule::Const { alpha: 1e-3 });
        let mut rng = seeded_rng(0, 0);
        let mut t = 0u64;
        run(&format!("native qadam step dim={n}"), Some(n * 4), || {
            t += 1;
            std::hint::black_box(opt.step(&g, t, 0, &mut rng).wire_bytes());
        });
    }

    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("(skipping PJRT benches: run `make artifacts`)");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();

    // Pallas kernel step via PJRT.
    let kernel = Arc::new(KernelQAdam::load(&rt, &dir, &manifest).unwrap());
    for &n in &[1usize << 16, 1 << 20] {
        let g = randv(n, 3);
        let mut opt = PjrtQAdam::new(kernel.clone(), n, 2, LrSchedule::Const { alpha: 1e-3 });
        let mut rng = seeded_rng(0, 0);
        let mut t = 0u64;
        run(&format!("pjrt qadam step dim={n}"), Some(n * 4), || {
            t += 1;
            std::hint::black_box(opt.step(&g, t, 0, &mut rng).wire_bytes());
        });
    }

    // Model gradient graphs (per-round worker compute).
    {
        let model = ModelRuntime::load(&rt, &dir, &manifest, "mlp").unwrap();
        let data = SyntheticVector::new(64, 10, 0);
        let flat = model.init_flat(0);
        let batch = data.train_batch(0, 0, model.meta.train_x.shape[0]);
        run("pjrt grad mlp (batch 16)", None, || {
            std::hint::black_box(model.loss_grad(&flat, &batch).unwrap().0);
        });
    }
    {
        let model = ModelRuntime::load(&rt, &dir, &manifest, "vgg_sim").unwrap();
        let data = SyntheticVision::cifar10_sim(0);
        let flat = model.init_flat(0);
        let batch = data.train_batch(0, 0, model.meta.train_x.shape[0]);
        run("pjrt grad vgg_sim (batch 16)", None, || {
            std::hint::black_box(model.loss_grad(&flat, &batch).unwrap().0);
        });
    }
}
