//! `cargo bench --bench table2` regenerates Table 2 (ResNet-101 /
//! CIFAR100 stand-in). Budget via QADAM_BENCH_STEPS (default 96 —
//! orderings stable; EXPERIMENTS.md records the longer runs from
//! examples/table_sweep.rs).

fn main() {
    let steps: u64 =
        std::env::var("QADAM_BENCH_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(96);
    qadam::coordinator::tables::run_table("table2", steps, 4, "results").unwrap();
}
