//! Elastic-round benchmarks: what the chaos layer costs when idle
//! (an empty plan must be ~free — it is the wrapper the trainer
//! installs whenever `--straggler drop` is set), and what a lossy
//! round looks like next to a clean one.
//!
//!   cargo bench --bench elastic_round

use qadam::elastic::{ChaosPlan, ChaosTransport, StragglerPolicy};
use qadam::optim::{LrSchedule, QAdamEf};
use qadam::ps::transport::{LocalBus, ThreadedBus, Transport};
use qadam::ps::worker::{SimGradSource, Worker};
use qadam::ps::ParameterServer;
use qadam::sim::StochasticProblem;
use qadam::util::bench::run;

fn mk_workers(n: usize, dim: usize) -> Vec<Worker> {
    (0..n)
        .map(|i| {
            let src = SimGradSource { problem: StochasticProblem::new(dim, 0.05, 3) };
            let opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 1e-3 });
            Worker::new(i as u32, Box::new(opt), Box::new(src), 7)
        })
        .collect()
}

fn main() {
    println!("== elastic_round ==");
    let dim = 1usize << 16;
    let nw = 8usize;
    let x0: Vec<f32> = (0..dim).map(|i| 0.1 * (i as f32 * 0.013).sin()).collect();

    // Bare sequential bus: the reference round cost.
    let bare = {
        let mut workers = mk_workers(nw, dim);
        let mut ps = ParameterServer::new(x0.clone(), Some(6));
        let bus = LocalBus::default();
        run("round bare LocalBus", None, || {
            let replies = {
                let (b, _) = ps.broadcast(nw);
                bus.round(&b, &mut workers).unwrap()
            };
            ps.apply(&replies).unwrap();
        })
    };

    // Empty chaos plan: the wrapper the trainer installs for quorum
    // enforcement; must cost nothing measurable.
    let idle = {
        let mut workers = mk_workers(nw, dim);
        let mut ps = ParameterServer::new(x0.clone(), Some(6));
        let mut bus = ChaosTransport::new(Box::new(LocalBus::default()), ChaosPlan::default())
            .with_policy(StragglerPolicy::Drop, 1);
        run("round chaos-idle wrap", None, || {
            let replies = {
                let (b, _) = ps.broadcast(nw);
                bus.round(&b, &mut workers).unwrap()
            };
            ps.apply(&replies).unwrap();
        })
    };
    println!("   -> idle-wrapper overhead: {:.2}x", idle.median_ns / bare.median_ns);

    // Lossy plan over the threaded engine: drops shrink the gather (and
    // the apply), crash windows shrink the worker fan-out.
    {
        let plan = ChaosPlan::parse("seed=9,drop=0.15,delay=0.1,crash=5@1..1000000").unwrap();
        let mut workers = mk_workers(nw, dim);
        let mut ps = ParameterServer::new(x0, Some(6));
        let mut bus = ChaosTransport::new(Box::new(ThreadedBus::new()), plan)
            .with_policy(StragglerPolicy::Drop, 1);
        let mut skipped = 0u64;
        run("round chaos-lossy threaded", None, || {
            let t = ps.step() + 1;
            let m = bus.membership(t, nw);
            let round = {
                let (b, _) = ps.broadcast(m.present);
                bus.round(&b, &mut workers)
            };
            match round {
                Ok(replies) => {
                    ps.apply(&replies).unwrap();
                }
                Err(_) => skipped += 1, // below quorum: skipped round
            }
        });
        println!(
            "   faults: {} dropped, {} delayed, {} worker-rounds crashed, {skipped} rounds skipped",
            bus.stats.dropped, bus.stats.delayed, bus.stats.crashed
        );
    }
}
