//! Sparse-vs-dense codec sweep at **equal total (uplink + downlink)
//! byte budgets** on the MoE workload — the gradient-sparsity regime
//! the sparse codecs are built for.
//!
//! The workload routes each (worker, t) microbatch top-1 to one expert,
//! so a worker's gradient is dense on the small router block and on
//! exactly one expert slice, and exactly zero elsewhere
//! ([`qadam::models::moe`]). A dense codec spends its bits on every
//! coordinate of that mostly-zero vector; a sparse codec ships the few
//! live coordinates at full precision and lets error feedback carry the
//! rest. The reference run (dense `kg=2`, the paper's 3-bit row) fixes
//! the byte budget; every other row spends the same up+down total and
//! the table reports where each trajectory got.
//!
//!   cargo bench --bench sparse_sweep
//!   cargo bench --bench sparse_sweep -- --rounds 2 --experts 4 \
//!       --expert-dim 64 --json /tmp/s.json               # CI smoke
//!
//! Flags: --rounds N (reference-run rounds; default 150), --experts E
//! (default 16), --expert-dim D (default 512), --router-dim R (default
//! 64), --workers W (default 8), --density F (top-k kept fraction for
//! the per-layer row; default 0.05), --json PATH (default
//! BENCH_sparse_sweep.json; machine-readable trajectory, compared with
//! `qadam bench-diff`).

use qadam::models::moe::{MoeGradSource, MoeProblem};
use qadam::optim::{LrSchedule, QAdamEf};
use qadam::ps::transport::LocalBus;
use qadam::ps::worker::Worker;
use qadam::ps::ParameterServer;
use qadam::quant::{CodecPolicy, LogQuant, PolicySpec};
use qadam::util::Args;
use std::time::Instant;

struct Cfg {
    experts: usize,
    expert_dim: usize,
    router_dim: usize,
    workers: usize,
}

fn mk_workers(cfg: &Cfg, spec: Option<&PolicySpec>, kg: u32) -> Vec<Worker> {
    (0..cfg.workers as u32)
        .map(|i| {
            let problem =
                MoeProblem::new(cfg.experts, cfg.expert_dim, cfg.router_dim, 0.05, 3);
            let layout = problem.layout();
            let dim = problem.dim();
            let src = MoeGradSource { problem };
            let mut opt = QAdamEf::paper_default(dim, kg, LrSchedule::InvSqrt { alpha: 0.05 });
            if let Some(s) = spec {
                opt = opt.with_policy(CodecPolicy::new(s.clone(), layout, kg).unwrap());
            }
            Worker::new(i, Box::new(opt), Box::new(src), 7)
        })
        .collect()
}

struct SweepResult {
    label: String,
    rounds: u64,
    total_bytes: u64,
    loss: f32,
    grad_norm_sq: f32,
    mean_bits: f64,
    secs: f64,
}

/// Run until `budget` total (up + down) bytes are spent (or
/// `max_rounds`), then report where the trajectory got. Every row uses
/// a compressed delta downlink (`kg=2`, resync only at round 1); rows
/// with a policy install it on **both** directions — worker uplinks and
/// the server's delta downlink — so the byte comparison is the whole
/// round trip.
fn run_budget(
    label: &str,
    cfg: &Cfg,
    spec: Option<&PolicySpec>,
    kg: u32,
    budget: Option<u64>,
    max_rounds: u64,
) -> SweepResult {
    let problem = MoeProblem::new(cfg.experts, cfg.expert_dim, cfg.router_dim, 0.05, 3);
    let mut ps = ParameterServer::new(problem.x0(), None);
    ps.enable_delta_downlink(Box::new(LogQuant::new(2)), 0);
    if let Some(s) = spec {
        let policy = CodecPolicy::new(s.clone(), problem.layout(), 2).unwrap();
        ps.set_downlink_policy(policy);
    }
    let mut workers = mk_workers(cfg, spec, kg);
    let bus = LocalBus::default();
    let start = Instant::now();
    let mut rounds = 0u64;
    let spent = |ps: &ParameterServer| ps.stats.up_bytes + ps.stats.down_bytes;
    while rounds < max_rounds && budget.map(|b| spent(&ps) < b).unwrap_or(true) {
        let replies = {
            let (b, _) = ps.broadcast(cfg.workers);
            bus.round(&b, &mut workers).unwrap()
        };
        ps.apply(&replies).unwrap();
        rounds += 1;
    }
    let mean_bits = workers[0].policy_bits().unwrap_or_else(|| workers[0].bits_per_element());
    SweepResult {
        label: label.into(),
        rounds,
        total_bytes: spent(&ps),
        loss: problem.mean_loss(ps.master()),
        grad_norm_sq: problem.full_grad_norm_sq(ps.master()),
        mean_bits,
        secs: start.elapsed().as_secs_f64(),
    }
}

fn main() {
    let a = Args::parse_env().expect("args");
    let rounds = a.get("rounds", 150u64).expect("--rounds");
    let experts = a.get("experts", 16usize).expect("--experts");
    let expert_dim = a.get("expert_dim", 512usize).expect("--expert-dim");
    let router_dim = a.get("router_dim", 64usize).expect("--router-dim");
    let workers = a.get("workers", 8usize).expect("--workers");
    let density: f64 = a.get("density", 0.05f64).expect("--density");
    let json_path = a.get_str("json", "BENCH_sparse_sweep.json");
    a.reject_unknown().expect("flags");
    let cfg = Cfg { experts, expert_dim, router_dim, workers };
    let dim = router_dim + experts * expert_dim;
    let live = (router_dim + expert_dim) as f64 / dim as f64;
    println!(
        "== sparse_sweep == dim={dim} ({experts} experts x {expert_dim} + router {router_dim}) \
         workers={workers} live-density={live:.3} reference-rounds={rounds}"
    );

    // Reference spend: dense kg=2 for --rounds fixes the up+down budget.
    let static2 = run_budget("dense kg=2", &cfg, None, 2, None, rounds);
    let budget = static2.total_bytes;

    let static0 = run_budget("dense kg=0", &cfg, None, 0, Some(budget), rounds * 4);
    let topk_spec = PolicySpec::parse(&format!(
        "per-layer:expert*=topk@{density},router=2"
    ))
    .expect("per-layer topk spec");
    let topk = run_budget(
        "per-layer topk",
        &cfg,
        Some(&topk_spec),
        2,
        Some(budget),
        rounds * 4,
    );
    let adaptive_spec =
        PolicySpec::parse("adaptive-topk:0.01..0.25").expect("adaptive-topk spec");
    let adaptive = run_budget(
        "adaptive-topk",
        &cfg,
        Some(&adaptive_spec),
        2,
        Some(budget),
        rounds * 4,
    );

    println!(
        "{:<16} {:>7} {:>12} {:>11} {:>12} {:>10} {:>8}",
        "codec", "rounds", "up+down MB", "loss", "|grad|^2", "bits/elem", "secs"
    );
    let rows = [static2, static0, topk, adaptive];
    for r in &rows {
        println!(
            "{:<16} {:>7} {:>12.3} {:>11.5} {:>12.6} {:>10.2} {:>8.2}",
            r.label,
            r.rounds,
            r.total_bytes as f64 / 1e6,
            r.loss,
            r.grad_norm_sq,
            r.mean_bits,
            r.secs
        );
    }
    let best_dense = rows[0].loss.min(rows[1].loss);
    let best_sparse = rows[2].loss.min(rows[3].loss);
    println!(
        "(equal-budget comparison: every row spends ~the dense kg=2 up+down bytes; \
         best sparse loss {best_sparse:.5} vs best dense {best_dense:.5} -> {})",
        if best_sparse < best_dense { "sparse wins" } else { "dense wins" }
    );

    // Machine-readable trajectory point (same shape the other benches
    // emit; `qadam bench-diff` compares the median_ns entries and CI
    // self-compares a smoke run at 0% diff).
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"sparse_sweep\",\n");
    json.push_str(&format!(
        "  \"dim\": {dim},\n  \"workers\": {workers},\n  \"budget_bytes\": {budget},\n"
    ));
    json.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{} dim={dim}\", \"median_ns\": {:.1}, \"rounds\": {}, \
             \"total_bytes\": {}, \"loss\": {:.6}, \"grad_norm_sq\": {:.8}, \
             \"bits_per_elem\": {:.3}}}{}\n",
            r.label,
            r.secs * 1e9 / r.rounds.max(1) as f64,
            r.rounds,
            r.total_bytes,
            r.loss,
            r.grad_norm_sq,
            r.mean_bits,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write(&json_path, json).expect("writing the bench JSON");
    println!("wrote {json_path}");
}
