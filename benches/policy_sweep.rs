//! Codec-policy sweep: static `k_g` vs the adaptive per-tensor policy
//! at **equal uplink byte budgets** on the sim problem.
//!
//! The static runs fix one global level for the whole run; the adaptive
//! run spends the same number of uplink bytes, letting the controller
//! move bits between tensors and rounds (growing where the EF residual
//! says the codec under-serves, shrinking where it over-serves). The
//! interesting outputs are loss / ‖∇f‖² *at the same spend*, plus how
//! many rounds the adaptive budget stretched to.
//!
//!   cargo bench --bench policy_sweep
//!   cargo bench --bench policy_sweep -- --rounds 1 --dim 4096   # CI smoke
//!
//! Flags: --rounds N (static-run rounds; default 150), --dim D
//! (default 32768), --workers W (default 8).

use qadam::optim::{LrSchedule, QAdamEf};
use qadam::ps::transport::LocalBus;
use qadam::ps::worker::{SimGradSource, Worker};
use qadam::ps::ParameterServer;
use qadam::quant::{CodecPolicy, PolicySpec, TensorLayout};
use qadam::sim::StochasticProblem;
use qadam::util::Args;
use std::time::Instant;

const POLICY_TENSORS: usize = 8;

fn mk_workers(n: usize, dim: usize, spec: Option<PolicySpec>, kg: u32) -> Vec<Worker> {
    (0..n as u32)
        .map(|i| {
            let src = SimGradSource { problem: StochasticProblem::new(dim, 0.05, 3) };
            let mut opt = QAdamEf::paper_default(dim, kg, LrSchedule::InvSqrt { alpha: 0.05 });
            if let Some(s) = &spec {
                let layout = TensorLayout::uniform(dim, POLICY_TENSORS);
                opt = opt.with_policy(CodecPolicy::new(s.clone(), layout, kg).unwrap());
            }
            Worker::new(i, Box::new(opt), Box::new(src), 7)
        })
        .collect()
}

struct SweepResult {
    label: String,
    rounds: u64,
    up_bytes: u64,
    loss: f32,
    grad_norm_sq: f32,
    mean_bits: f64,
    secs: f64,
}

/// Run until `budget` uplink bytes are spent (or `max_rounds`), then
/// report where the trajectory got.
fn run_budget(
    label: &str,
    dim: usize,
    nworkers: usize,
    spec: Option<PolicySpec>,
    kg: u32,
    budget: Option<u64>,
    max_rounds: u64,
) -> SweepResult {
    let problem = StochasticProblem::new(dim, 0.05, 3);
    let mut ps = ParameterServer::new(problem.x0(), None);
    let mut workers = mk_workers(nworkers, dim, spec, kg);
    let bus = LocalBus::default();
    let start = Instant::now();
    let mut rounds = 0u64;
    while rounds < max_rounds && budget.map(|b| ps.stats.up_bytes < b).unwrap_or(true) {
        let replies = {
            let (b, _) = ps.broadcast(nworkers);
            bus.round(&b, &mut workers).unwrap()
        };
        ps.apply(&replies).unwrap();
        rounds += 1;
    }
    let mean_bits =
        workers[0].policy_bits().unwrap_or_else(|| workers[0].bits_per_element());
    SweepResult {
        label: label.into(),
        rounds,
        up_bytes: ps.stats.up_bytes,
        loss: problem.loss(ps.master()),
        grad_norm_sq: problem.grad_norm_sq(ps.master()),
        mean_bits,
        secs: start.elapsed().as_secs_f64(),
    }
}

fn main() {
    let a = Args::parse_env().expect("args");
    let rounds = a.get("rounds", 150u64).expect("--rounds");
    let dim = a.get("dim", 1usize << 15).expect("--dim");
    let nworkers = a.get("workers", 8usize).expect("--workers");
    a.reject_unknown().expect("flags");
    println!("== policy_sweep == dim={dim} workers={nworkers} static-rounds={rounds}");

    // Reference spend: static kg=2 (the paper's 3-bit row) for --rounds.
    let static2 = run_budget("static kg=2", dim, nworkers, None, 2, None, rounds);
    let budget = static2.up_bytes;

    // Same byte budget, different policies.
    let static0 =
        run_budget("static kg=0", dim, nworkers, None, 0, Some(budget), rounds * 4);
    let adaptive = run_budget(
        "adaptive:0..4",
        dim,
        nworkers,
        Some(PolicySpec::Adaptive { lo: 0, hi: 4 }),
        2,
        Some(budget),
        rounds * 4,
    );

    println!(
        "{:<16} {:>7} {:>12} {:>11} {:>12} {:>10} {:>8}",
        "policy", "rounds", "up MB", "loss", "|grad|^2", "bits/elem", "secs"
    );
    for r in [static2, static0, adaptive] {
        println!(
            "{:<16} {:>7} {:>12.3} {:>11.5} {:>12.6} {:>10.2} {:>8.2}",
            r.label,
            r.rounds,
            r.up_bytes as f64 / 1e6,
            r.loss,
            r.grad_norm_sq,
            r.mean_bits,
            r.secs
        );
    }
    println!("(equal-budget comparison: every row spends ~the static kg=2 uplink bytes)");
}
