//! Ablations over the design choices DESIGN.md calls out, on the
//! Assumption-1 synthetic problem (fast, PJRT-free):
//!
//!   1. deterministic-nearest log quant + EF (the paper)  vs
//!      unbiased stochastic log quant, no EF               vs
//!      QSGD uniform levels, no EF          — same bit-width each;
//!   2. error feedback on/off for the biased quantizer;
//!   3. quantization codebook: log (power-of-two) vs uniform levels.
//!
//!   cargo bench --bench ablations

use qadam::optim::{LrSchedule, QAdamEf, ThetaSchedule, WorkerOpt};
use qadam::ps::transport::LocalBus;
use qadam::ps::worker::{SimGradSource, Worker};
use qadam::ps::ParameterServer;
use qadam::quant::{Compressor, LogQuant, Qsgd, StochasticLogQuant};
use qadam::sim::StochasticProblem;

const DIM: usize = 256;
const STEPS: u64 = 800;

fn run(label: &str, comp: Box<dyn Compressor>, ef: bool) -> (f32, f64) {
    let problem = StochasticProblem::with_offgrid_minimum(DIM, 0.3, 7);
    let bits = comp.bits_per_element();
    let mut ps = ParameterServer::new(problem.x0(), None);
    let mut ws: Vec<Worker> = (0..4)
        .map(|i| {
            let opt = QAdamEf::new(
                DIM,
                match comp.codec() {
                    qadam::quant::CodecId::Qsgd => Box::new(Qsgd::new(3)) as Box<dyn Compressor>,
                    _ if comp.name().contains("stochastic") => Box::new(StochasticLogQuant::new(2)),
                    _ => Box::new(LogQuant::new(2)),
                },
                ef,
                LrSchedule::InvSqrt { alpha: 0.5 },
                ThetaSchedule::Anneal { theta: 0.9 },
                0.9,
                1e-8,
            );
            Worker::new(i, Box::new(opt), Box::new(SimGradSource { problem: problem.clone() }), 11)
        })
        .collect();
    let bus = LocalBus::default();
    let mut tail = 0.0f64;
    let mut cnt = 0;
    for t in 1..=STEPS {
        let replies = {
            let (b, _) = ps.broadcast(4);
            bus.round(&b, &mut ws).unwrap()
        };
        ps.apply(&replies).unwrap();
        if t >= STEPS / 2 {
            tail += problem.grad_norm_sq(ps.master()) as f64;
            cnt += 1;
        }
    }
    let g = (tail / cnt as f64) as f32;
    println!("{label:<44} tail E||∇f||² = {g:.3e}   ({bits:.0} bits/elem)");
    (g, bits)
}

fn main() {
    println!("== ablations (dim {DIM}, 4 workers, {STEPS} steps) ==");
    println!("-- biased-vs-unbiased at equal bits (3b) --");
    let (det_ef, _) = run("log levels, deterministic nearest + EF (paper)", Box::new(LogQuant::new(2)), true);
    let (stoch, _) = run("log levels, stochastic rounding, no EF", Box::new(StochasticLogQuant::new(2)), false);
    let (qsgd, _) = run("uniform levels (QSGD-3), stochastic, no EF", Box::new(Qsgd::new(3)), false);
    println!("-- error-feedback ablation (biased quantizer) --");
    let (noef, _) = run("log levels, deterministic nearest, NO EF", Box::new(LogQuant::new(2)), false);
    println!();
    println!("paper choice vs unbiased-stochastic: {det_ef:.3e} vs {stoch:.3e} (lower is better)");
    println!("paper choice vs QSGD uniform:        {det_ef:.3e} vs {qsgd:.3e}");
    println!("EF on vs off:                        {det_ef:.3e} vs {noef:.3e}");
}
