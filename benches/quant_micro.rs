//! Micro-benchmarks for the communication hot path: quantize, pack,
//! decode for every codec, plus wire serialization.
//!
//!   cargo bench --bench quant_micro

use qadam::quant::{seeded_rng, Blockwise, Compressor, Identity, LogQuant, TernGrad, WQuant};
use qadam::util::bench::run;
use qadam::util::DetRng;

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut r = DetRng::seed_stream(seed, 0);
    (0..n).map(|_| r.gen_normal() * 0.01).collect()
}

fn main() {
    println!("== quant_micro (sizes: 64Ki and 1Mi f32) ==");
    for &n in &[1usize << 16, 1 << 20] {
        let u = randv(n, 1);
        let bytes = n * 4;
        let mut q = vec![0.0f32; n];

        for (name, comp) in [
            ("logquant kg=2", Box::new(LogQuant::new(2)) as Box<dyn Compressor>),
            ("logquant kg=8", Box::new(LogQuant::new(8))),
            ("terngrad", Box::new(TernGrad)),
            ("blockwise 4096", Box::new(Blockwise::new(4096))),
            ("wquant kx=6", Box::new(WQuant::new(6))),
            ("identity", Box::new(Identity)),
        ] {
            let mut rng = seeded_rng(0, 0);
            let label = format!("{name} compress n={n}");
            run(&label, Some(bytes), || {
                let msg = comp.compress_into(&u, &mut q, &mut rng);
                std::hint::black_box(msg.wire_bytes());
            });
            let mut rng = seeded_rng(0, 0);
            let msg = comp.compress_into(&u, &mut q, &mut rng);
            let mut out = vec![0.0f32; n];
            let label = format!("{name} decompress n={n}");
            run(&label, Some(bytes), || {
                comp.decompress(&msg, &mut out);
                std::hint::black_box(out[0]);
            });
        }

        // wire serialization roundtrip
        let lq = LogQuant::new(2);
        let mut rng = seeded_rng(0, 0);
        let msg = lq.compress_into(&u, &mut q, &mut rng);
        run(&format!("wire to_bytes n={n}"), Some(msg.wire_bytes()), || {
            std::hint::black_box(msg.to_bytes().len());
        });
        let b = msg.to_bytes();
        run(&format!("wire from_bytes n={n}"), Some(b.len()), || {
            std::hint::black_box(qadam::quant::WireMsg::from_bytes(&b).unwrap().n);
        });
        println!();
    }
}
