//! Micro-benchmarks for the communication hot path: quantize, pack,
//! decode for every codec, plus wire serialization — and, for each
//! rewritten kernel, the retained scalar reference implementation
//! (`qadam::quant::reference`) timed side by side so the speedup the
//! SIMD/fused rewrite buys is a *measured, tracked* number, not a
//! claim.
//!
//!   cargo bench --bench quant_micro
//!   cargo bench --bench quant_micro -- --sizes 4096 --target-ms 20 \
//!       --json /tmp/q.json                                  # CI smoke
//!
//! Flags: --sizes CSV of element counts (default 65536,1048576),
//! --target-ms N per measurement (default 200),
//! --json PATH (default BENCH_quant_micro.json).
//!
//! The JSON is the bench trajectory: `scripts/bench_diff.sh` compares a
//! fresh run against the committed `BENCH_quant_micro.json` and fails
//! on regression. Refresh the baseline with
//! `scripts/bench_diff.sh --refresh`.

use qadam::quant::reference as r;
use qadam::quant::{
    decode_msg_range_add, seeded_rng, Blockwise, Compressor, Identity, LogQuant, Qsgd,
    StochasticLogQuant, TernGrad, WQuant, WireMsg,
};
use qadam::util::bench::{bench, BenchResult};
use qadam::util::{Args, DetRng};

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut r = DetRng::seed_stream(seed, 0);
    (0..n).map(|_| r.gen_normal() * 0.01).collect()
}

struct Entry {
    name: String,
    n: usize,
    res: BenchResult,
}

struct Speedup {
    kernel: String,
    n: usize,
    ref_ns: f64,
    fused_ns: f64,
}

struct Session {
    target_ms: u64,
    entries: Vec<Entry>,
    speedups: Vec<Speedup>,
}

impl Session {
    /// Bench `f`, print with throughput, record for the JSON.
    fn run(&mut self, name: &str, n: usize, bytes: usize, f: impl FnMut()) -> f64 {
        let res = bench(&format!("{name} n={n}"), self.target_ms, f);
        res.print(Some(bytes));
        let ns = res.median_ns;
        self.entries.push(Entry { name: name.to_string(), n, res });
        ns
    }

    /// Bench the fused kernel against its scalar reference and record
    /// the speedup.
    fn versus(
        &mut self,
        kernel: &str,
        n: usize,
        bytes: usize,
        fused: impl FnMut(),
        reference: impl FnMut(),
    ) {
        let fused_ns = self.run(kernel, n, bytes, fused);
        let ref_ns = self.run(&format!("{kernel} [scalar ref]"), n, bytes, reference);
        println!("   -> {kernel}: {:.2}x vs scalar reference", ref_ns / fused_ns);
        self.speedups.push(Speedup { kernel: kernel.to_string(), n, ref_ns, fused_ns });
    }
}

/// A codec paired with reference compress/decompress closures.
type RefCompress = Box<dyn Fn(&[f32], &mut [f32], &mut DetRng) -> WireMsg>;
type RefDecompress = Box<dyn Fn(&WireMsg, usize, &mut [f32])>;

fn codec_cases() -> Vec<(&'static str, Box<dyn Compressor>, RefCompress, RefDecompress)> {
    vec![
        (
            "logquant kg=2",
            Box::new(LogQuant::new(2)),
            Box::new(|u, q, _rng: &mut DetRng| r::logquant_compress_ref(2, u, q)),
            Box::new(|m: &WireMsg, s, o: &mut [f32]| r::logquant_decompress_range_ref(m, s, o)),
        ),
        (
            "logquant kg=8",
            Box::new(LogQuant::new(8)),
            Box::new(|u, q, _rng: &mut DetRng| r::logquant_compress_ref(8, u, q)),
            Box::new(|m: &WireMsg, s, o: &mut [f32]| r::logquant_decompress_range_ref(m, s, o)),
        ),
        (
            "stoch-log kg=2",
            Box::new(StochasticLogQuant::new(2)),
            Box::new(|u, q, rng: &mut DetRng| r::stochastic_log_compress_ref(2, u, q, rng)),
            Box::new(|m: &WireMsg, s, o: &mut [f32]| r::logquant_decompress_range_ref(m, s, o)),
        ),
        (
            "terngrad",
            Box::new(TernGrad),
            Box::new(|u, q, rng: &mut DetRng| r::terngrad_compress_ref(u, q, rng)),
            Box::new(|m: &WireMsg, s, o: &mut [f32]| r::terngrad_decompress_range_ref(m, s, o)),
        ),
        (
            "qsgd L=4",
            Box::new(Qsgd::new(4)),
            Box::new(|u, q, rng: &mut DetRng| r::qsgd_compress_ref(4, u, q, rng)),
            Box::new(|m: &WireMsg, s, o: &mut [f32]| r::qsgd_decompress_range_ref(m, s, o)),
        ),
        (
            "blockwise 4096",
            Box::new(Blockwise::new(4096)),
            Box::new(|u, q, _rng: &mut DetRng| r::blockwise_compress_ref(4096, u, q)),
            Box::new(|m: &WireMsg, s, o: &mut [f32]| {
                r::blockwise_decompress_range_ref(4096, m, s, o)
            }),
        ),
        (
            "wquant kx=6",
            Box::new(WQuant::new(6)),
            Box::new(|u, q, _rng: &mut DetRng| r::wquant_compress_ref(6, u, q)),
            Box::new(|m: &WireMsg, s, o: &mut [f32]| r::wquant_decompress_range_ref(6, m, s, o)),
        ),
    ]
}

fn main() {
    let a = Args::parse_env().unwrap();
    let sizes_csv = a.get_str("sizes", "65536,1048576");
    let target_ms: u64 = a.get("target_ms", 200).unwrap();
    let json_path = a.get_str("json", "BENCH_quant_micro.json");
    a.reject_unknown().unwrap();
    let sizes: Vec<usize> = sizes_csv
        .split(',')
        .map(|s| s.trim().parse().expect("--sizes takes a comma list of element counts"))
        .collect();

    let mut sess = Session { target_ms, entries: Vec::new(), speedups: Vec::new() };
    println!("== quant_micro (sizes: {sizes:?}, {target_ms} ms/measurement) ==");
    for &n in &sizes {
        let u = randv(n, 1);
        let bytes = n * 4;
        let mut q = vec![0.0f32; n];
        let mut q_ref = vec![0.0f32; n];
        let mut out = vec![0.0f32; n];
        let mut out_ref = vec![0.0f32; n];

        for (name, comp, ref_c, ref_d) in codec_cases() {
            // fused-vs-reference compress (quantize + bit-pack)
            let mut rng = seeded_rng(0, 0);
            let mut rng_ref = seeded_rng(0, 0);
            sess.versus(
                &format!("{name} compress"),
                n,
                bytes,
                || {
                    std::hint::black_box(comp.compress_into(&u, &mut q, &mut rng).wire_bytes());
                },
                || {
                    std::hint::black_box(ref_c(&u, &mut q_ref, &mut rng_ref).wire_bytes());
                },
            );
            // fused-vs-reference decode
            let mut rng = seeded_rng(0, 0);
            let msg = comp.compress_into(&u, &mut q, &mut rng);
            sess.versus(
                &format!("{name} decompress"),
                n,
                bytes,
                || {
                    comp.decompress(&msg, &mut out);
                    std::hint::black_box(out[0]);
                },
                || {
                    ref_d(&msg, 0, &mut out_ref);
                    std::hint::black_box(out_ref[0]);
                },
            );
            // fused decode-accumulate (the server apply inner loop) vs
            // the pre-fusion shape: decode to scratch, then add.
            let mut scratch = vec![0.0f32; n];
            sess.versus(
                &format!("{name} decode_add"),
                n,
                bytes,
                || {
                    decode_msg_range_add(&msg, 0, &mut out);
                    std::hint::black_box(out[0]);
                },
                || {
                    ref_d(&msg, 0, &mut scratch);
                    for (o, &s) in out_ref.iter_mut().zip(scratch.iter()) {
                        *o += s;
                    }
                    std::hint::black_box(out_ref[0]);
                },
            );
        }

        // identity + wire serialization (no scalar reference — these
        // were not rewritten, they just anchor the trajectory)
        let mut rng = seeded_rng(0, 0);
        sess.run("identity compress", n, bytes, || {
            std::hint::black_box(Identity.compress_into(&u, &mut q, &mut rng).wire_bytes());
        });
        let lq = LogQuant::new(2);
        let msg = lq.compress_into(&u, &mut q, &mut seeded_rng(0, 0));
        sess.run("wire to_bytes", n, msg.wire_bytes(), || {
            std::hint::black_box(msg.to_bytes().len());
        });
        let b = msg.to_bytes();
        sess.run("wire from_bytes", n, b.len(), || {
            std::hint::black_box(WireMsg::from_bytes(&b).unwrap().n);
        });
        println!();
    }

    // Machine-readable trajectory point.
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"quant_micro\",\n");
    json.push_str(&format!(
        "  \"sizes\": [{}],\n  \"target_ms\": {target_ms},\n",
        sizes.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(", ")
    ));
    json.push_str("  \"results\": [\n");
    for (i, e) in sess.entries.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{} n={}\", \"median_ns\": {:.1}, \"p10_ns\": {:.1}, \"p90_ns\": {:.1}, \"iters\": {}}}{}\n",
            e.name,
            e.n,
            e.res.median_ns,
            e.res.p10_ns,
            e.res.p90_ns,
            e.res.iters,
            if i + 1 == sess.entries.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n  \"speedups\": [\n");
    for (i, s) in sess.speedups.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"kernel\": \"{} n={}\", \"ref_ns\": {:.1}, \"fused_ns\": {:.1}, \"speedup\": {:.3}}}{}\n",
            s.kernel,
            s.n,
            s.ref_ns,
            s.fused_ns,
            s.ref_ns / s.fused_ns,
            if i + 1 == sess.speedups.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write(&json_path, json).expect("writing the bench JSON");
    println!("wrote {json_path}");
}
