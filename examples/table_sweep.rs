//! Regenerate the paper's evaluation artifacts:
//!
//!   table2 — Test accuracy vs Comm vs Size on the CIFAR100 stand-in
//!            (resnet_sim), QADAM vs TernGrad vs Zheng[44] vs WQuan.
//!   table3 — the same grid on the CIFAR10 stand-in (vgg_sim).
//!   fig3 / fig4 — the corresponding training curves (CSV per run).
//!
//!   cargo run --release --example table_sweep -- table3 \
//!       [--steps N] [--workers N] [--outdir results/]
//!
//! `--steps` defaults to a CPU-budget 192; pass more for tighter
//! accuracy estimates (the orderings are stable from ~150 steps).

use anyhow::Result;
use qadam::coordinator::tables::run_table;
use qadam::util::Args;

fn main() -> Result<()> {
    let a = Args::parse_env()?;
    let which = a.subcommand.clone().unwrap_or_else(|| "table3".into());
    let steps = a.get("steps", 192u64)?;
    let workers = a.get("workers", 4usize)?;
    let outdir = a.get_str("outdir", "results");
    a.reject_unknown()?;
    run_table(&which, steps, workers, &outdir)?;
    Ok(())
}
