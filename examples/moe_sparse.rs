//! The MoE sparse-codec walkthrough (README §Sparse codecs): top-1
//! routed mixture-of-experts gradients shipped with `topk@d` /
//! `adaptive-topk` policies on **both** directions — worker uplinks
//! through the per-tensor codec policy, the weight-delta downlink
//! through the server's own policy controller — against the same run
//! with the dense `kg=2` codec.
//!
//! What it demonstrates (and asserts, so CI catches rot):
//!
//! * a sparse policy composes with error feedback end to end: the runs
//!   train (loss drops), nothing is silently lost;
//! * at the same round count the fixed-density `topk@0.02` run ships
//!   **fewer bytes than dense `kg=2` in both directions**;
//! * the adaptive-topk controller actually moves kept densities per
//!   tensor in response to the EF residual (the per-tensor choices are
//!   printed — expert slices are live only ~1/E of the rounds, the
//!   router every round).
//!
//!   cargo run --release --example moe_sparse -- [--experts E]
//!       [--expert-dim D] [--rounds N] [--workers W]

use anyhow::Result;
use qadam::models::moe::{MoeGradSource, MoeProblem};
use qadam::optim::{LrSchedule, QAdamEf};
use qadam::ps::transport::LocalBus;
use qadam::ps::worker::Worker;
use qadam::ps::ParameterServer;
use qadam::quant::{CodecPolicy, LogQuant, PolicySpec};

const ROUTER_DIM: usize = 32;

struct RunResult {
    label: String,
    loss0: f32,
    loss: f32,
    up_bytes: u64,
    down_bytes: u64,
    /// final per-tensor uplink levels (kept densities in 1/10000ths on
    /// sparse tensors, `k_g` on dense ones); None for the static run
    chosen: Option<Vec<u32>>,
}

fn run(
    label: &str,
    experts: usize,
    expert_dim: usize,
    workers: usize,
    rounds: u64,
    policy: Option<&str>,
) -> Result<RunResult> {
    let problem = MoeProblem::new(experts, expert_dim, ROUTER_DIM, 0.05, 3);
    let dim = problem.dim();
    let loss0 = problem.mean_loss(&problem.x0());
    let mut ps = ParameterServer::new(problem.x0(), None);
    ps.enable_delta_downlink(Box::new(LogQuant::new(2)), 0);
    if let Some(s) = policy {
        let spec = PolicySpec::parse(s)?;
        ps.set_downlink_policy(CodecPolicy::new(spec, problem.layout(), 2)?);
    }
    let mut fleet: Vec<Worker> = (0..workers as u32)
        .map(|i| {
            let p = MoeProblem::new(experts, expert_dim, ROUTER_DIM, 0.05, 3);
            let layout = p.layout();
            let src = MoeGradSource { problem: p };
            let mut opt = QAdamEf::paper_default(dim, 2, LrSchedule::InvSqrt { alpha: 0.05 });
            if let Some(s) = policy {
                let spec = PolicySpec::parse(s).expect("uplink policy spec");
                opt = opt.with_policy(CodecPolicy::new(spec, layout, 2).unwrap());
            }
            Worker::new(i, Box::new(opt), Box::new(src), 7)
        })
        .collect();
    let bus = LocalBus::default();
    for _ in 0..rounds {
        let replies = {
            let (b, _) = ps.broadcast(workers);
            bus.round(&b, &mut fleet)?
        };
        ps.apply(&replies)?;
    }
    Ok(RunResult {
        label: label.into(),
        loss0,
        loss: problem.mean_loss(ps.master()),
        up_bytes: ps.stats.up_bytes,
        down_bytes: ps.stats.down_bytes,
        chosen: fleet[0].chosen_bits().map(|b| b.to_vec()),
    })
}

fn main() -> Result<()> {
    let a = qadam::util::Args::parse_env()?;
    let experts = a.get("experts", 8usize)?;
    let expert_dim = a.get("expert_dim", 256usize)?;
    let rounds = a.get("rounds", 60u64)?;
    let workers = a.get("workers", 4usize)?;
    a.reject_unknown()?;
    let dim = ROUTER_DIM + experts * expert_dim;
    println!(
        "MoE: {experts} experts x {expert_dim} + router {ROUTER_DIM} = dim {dim}, \
         {workers} workers, {rounds} rounds\n"
    );

    let dense = run("dense kg=2", experts, expert_dim, workers, rounds, None)?;
    let topk = run(
        "topk@0.02",
        experts,
        expert_dim,
        workers,
        rounds,
        Some("per-layer:expert*=topk@0.02,router=2"),
    )?;
    let adaptive = run(
        "adaptive-topk",
        experts,
        expert_dim,
        workers,
        rounds,
        Some("adaptive-topk:0.01..0.25"),
    )?;

    println!(
        "{:<14} {:>10} {:>12} {:>12}",
        "codec", "loss", "up bytes", "down bytes"
    );
    for r in [&dense, &topk, &adaptive] {
        println!(
            "{:<14} {:>10.5} {:>12} {:>12}",
            r.label, r.loss, r.up_bytes, r.down_bytes
        );
    }

    // 1. sparse + EF trains: both sparse trajectories moved downhill
    // and did not blow up.
    for r in [&topk, &adaptive] {
        if !(r.loss.is_finite() && r.loss < r.loss0) {
            anyhow::bail!(
                "{} run did not train: loss {} (started at {})",
                r.label,
                r.loss,
                r.loss0
            );
        }
    }
    // 2. equal rounds, fewer bytes — in both directions — for the
    // fixed-density run (the adaptive band deliberately starts at its
    // dense edge, so its early rounds spend more; it is reported, not
    // byte-gated).
    if topk.up_bytes >= dense.up_bytes || topk.down_bytes >= dense.down_bytes {
        anyhow::bail!(
            "topk@0.02 should undercut dense bytes at equal rounds: up {} vs {}, down {} vs {}",
            topk.up_bytes,
            dense.up_bytes,
            topk.down_bytes,
            dense.down_bytes
        );
    }
    // 3. the adaptive controller reports a per-tensor density for every
    // tensor and never leaves its band. (Whether it moves here depends
    // on the residual-ratio trajectory; the movement rules themselves
    // are property-tested in quant::policy with controlled inputs.)
    let chosen = adaptive.chosen.as_ref().expect("adaptive run reports chosen densities");
    println!(
        "\nadaptive kept densities (1/10000ths): router {}, experts {:?}",
        chosen[0],
        &chosen[1..]
    );
    if chosen.len() != 1 + experts || chosen.iter().any(|&d| !(100..=2500).contains(&d)) {
        anyhow::bail!("adaptive-topk densities left the 0.01..0.25 band: {chosen:?}");
    }
    println!(
        "\nOK: sparse codecs + EF train end to end; topk@0.02 ships {}% of the dense \
         uplink bytes and {}% of the dense downlink bytes at equal rounds",
        100 * topk.up_bytes / dense.up_bytes.max(1),
        100 * topk.down_bytes / dense.down_bytes.max(1)
    );
    Ok(())
}
