//! Convergence-theory curves (Theorems 3.1–3.3) on the synthetic
//! stochastic nonconvex problem: writes per-step ||∇f||² (and the
//! quantized-weight gradient) so the C/√T decay and the δ_x floor can
//! be plotted.
//!
//!   cargo run --release --example convergence_check -- [--steps N]

use anyhow::Result;
use qadam::optim::{LrSchedule, QAdamEf, ThetaSchedule, WorkerOpt};
use qadam::ps::transport::LocalBus;
use qadam::ps::worker::{SimGradSource, Worker};
use qadam::ps::ParameterServer;
use qadam::quant::LogQuant;
use qadam::sim::StochasticProblem;
use qadam::util::Args;
use std::io::Write;

const DIM: usize = 64;

struct Curve {
    label: String,
    grad_sq: Vec<f32>,
}

fn run(label: &str, workers: usize, kg: Option<u32>, ef: bool, kx: Option<u32>, steps: u64) -> Curve {
    let problem = StochasticProblem::with_offgrid_minimum(DIM, 0.3, 7);
    let mut ps = ParameterServer::new(problem.x0(), kx);
    let mut ws: Vec<Worker> = (0..workers)
        .map(|i| {
            let src = SimGradSource { problem: problem.clone() };
            let opt: Box<dyn WorkerOpt> = match kg {
                Some(k) => Box::new(QAdamEf::new(
                    DIM,
                    Box::new(LogQuant::new(k)),
                    ef,
                    LrSchedule::InvSqrt { alpha: 0.5 },
                    ThetaSchedule::Anneal { theta: 0.9 },
                    0.9,
                    1e-8,
                )),
                None => Box::new(QAdamEf::full_precision(DIM, LrSchedule::InvSqrt { alpha: 0.5 })),
            };
            Worker::new(i as u32, opt, Box::new(src), 11)
        })
        .collect();
    let bus = LocalBus::default();
    let mut grad_sq = Vec::with_capacity(steps as usize);
    for _t in 1..=steps {
        let replies = {
            let (b, _) = ps.broadcast(workers);
            bus.round(&b, &mut ws).unwrap()
        };
        ps.apply(&replies).unwrap();
        grad_sq.push(problem.grad_norm_sq(ps.output_weights()));
    }
    Curve { label: label.into(), grad_sq }
}

fn tail_mean(c: &Curve) -> f32 {
    let n = c.grad_sq.len();
    c.grad_sq[n / 2..].iter().sum::<f32>() / (n - n / 2) as f32
}

fn main() -> Result<()> {
    let a = Args::parse_env()?;
    let steps = a.get("steps", 1000u64)?;
    let outdir = a.get_str("outdir", "results");
    a.reject_unknown()?;
    std::fs::create_dir_all(&outdir)?;

    let curves = vec![
        // Thm 3.1: gradient quantization + EF -> stationary point
        run("fp32", 1, None, false, None, steps),
        run("qg_kg2_ef", 1, Some(2), true, None, steps),
        run("qg_kg0_ef", 1, Some(0), true, None, steps),
        run("qg_kg2_noef", 1, Some(2), false, None, steps),
        // Thm 3.2: weight quantization -> floor proportional to delta_x
        run("qx_kx1", 1, None, false, Some(1), steps),
        run("qx_kx4", 1, None, false, Some(4), steps),
        run("qx_kx8", 1, None, false, Some(8), steps),
        // Thm 3.3: multi-worker, both quantizers
        run("both_8workers", 8, Some(2), true, Some(8), steps),
    ];

    println!("{:<16} {:>14} {:>14}", "run", "tail E||∇f||²", "min ||∇f||²");
    for c in &curves {
        let minv = c.grad_sq.iter().cloned().fold(f32::INFINITY, f32::min);
        println!("{:<16} {:>14.3e} {:>14.3e}", c.label, tail_mean(c), minv);
    }

    // Thm 3.1 rate check: tail(2T) should be ≲ tail(T)/sqrt(2)·(1+log-slack)
    let half = run("qg_kg2_ef_half", 1, Some(2), true, None, steps / 2);
    println!(
        "\nThm 3.1 horizon scaling: tail(T/2)={:.3e} vs tail(T)={:.3e} (expect decreasing)",
        tail_mean(&half),
        tail_mean(&curves[1])
    );
    println!("Thm 3.2 floor ordering (coarse > fine): kx1={:.3e} kx4={:.3e} kx8={:.3e}",
        tail_mean(&curves[4]), tail_mean(&curves[5]), tail_mean(&curves[6]));

    let path = format!("{outdir}/convergence_curves.csv");
    let mut f = std::fs::File::create(&path)?;
    write!(f, "t")?;
    for c in &curves {
        write!(f, ",{}", c.label)?;
    }
    writeln!(f)?;
    for t in 0..steps as usize {
        write!(f, "{}", t + 1)?;
        for c in &curves {
            write!(f, ",{:e}", c.grad_sq[t])?;
        }
        writeln!(f)?;
    }
    println!("\ncurves written to {path}");
    Ok(())
}
