//! The paper's motivating federated/edge scenario (§1): N resource-
//! constrained devices train over a *real TCP network* against the
//! parameter server, with BOTH quantizations on — weights broadcast at
//! k_x bits (storage-constrained devices), update vectors uploaded at
//! k_g-derived bits (bandwidth-constrained uplink) — and, because edge
//! links are lossy, a deterministic [`ChaosPlan`] chews on the uplink:
//! replies get dropped and delayed, the round proceeds at quorum under
//! the `drop` straggler policy, and error feedback absorbs the missed
//! contributions (the residual carries them into the next round).
//!
//! Everything runs in this one process (server thread + one thread per
//! device) but every byte crosses a real socket through the same
//! length-prefixed protocol a multi-host deployment uses
//! (`qadam serve` / `qadam worker`).
//!
//!   cargo run --release --example fedlearn_edge -- [--devices N] [--steps N]
//!       [--chaos "seed=9,drop=0.06,delay=0.04"]   ("" disables chaos)

use anyhow::Result;
use qadam::elastic::{ChaosPlan, ChaosTransport, StragglerPolicy};
use qadam::optim::{LrSchedule, QAdamEf};
use qadam::ps::transport::{tcp_worker_loop, TcpServer, Transport};
use qadam::ps::worker::{SimGradSource, Worker};
use qadam::ps::ParameterServer;
use qadam::quant::LogQuant;
use qadam::sim::StochasticProblem;
use qadam::util::Args;

fn main() -> Result<()> {
    let a = Args::parse_env()?;
    let devices = a.get("devices", 4usize)?;
    let steps = a.get("steps", 300u64)?;
    let dim = a.get("dim", 4096usize)?;
    let kg = a.get("kg", 2u32)?;
    let kx = a.get("kx", 6u32)?;
    let chaos_spec = a.get_str("chaos", "seed=9,drop=0.06,delay=0.04");
    a.reject_unknown()?;
    let plan = ChaosPlan::parse(&chaos_spec)?;

    // pick a free port
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    drop(listener);

    println!("edge scenario: {devices} devices, dim={dim}, k_g={kg} uplink, k_x={kx} broadcast");
    let chaos_label = if plan.is_empty() { "off" } else { chaos_spec.as_str() };
    println!("server at {addr}, chaos: {chaos_label}");

    let mut handles = Vec::new();
    for id in 0..devices as u32 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || -> Result<u64> {
            let problem = StochasticProblem::with_offgrid_minimum(dim, 0.1, 3);
            let opt = QAdamEf::new(
                dim,
                Box::new(LogQuant::new(kg)),
                true,
                LrSchedule::InvSqrt { alpha: 0.5 },
                qadam::optim::ThetaSchedule::Anneal { theta: 0.9 },
                0.9,
                1e-8,
            );
            let mut w = Worker::new(id, Box::new(opt), Box::new(SimGradSource { problem }), 5);
            // retry until the server socket is up
            for _ in 0..200 {
                match tcp_worker_loop(&addr, &mut w) {
                    Ok(r) => return Ok(r),
                    Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
                }
            }
            anyhow::bail!("device {id} could not connect")
        }));
    }

    let srv = TcpServer::bind_and_accept(&addr, devices)?;
    // The chaos wrapper emulates the lossy edge uplink on top of the
    // healthy loopback sockets; `drop` + quorum 1 keeps rounds moving.
    let mut net = ChaosTransport::new(Box::new(srv), plan)
        .with_policy(StragglerPolicy::Drop, 1);
    let problem = StochasticProblem::with_offgrid_minimum(dim, 0.1, 3);
    let mut ps = ParameterServer::new(problem.x0(), Some(kx));
    let t0 = std::time::Instant::now();
    let mut partial_rounds = 0u64;
    let mut skipped_rounds = 0u64;
    // Delivered message slots, so the fp32 baselines below compare
    // like-for-like: chaos-dropped replies and skipped rounds must not
    // be credited to quantization.
    let mut down_slots = 0u64;
    let mut up_slots = 0u64;
    for t in 1..=steps {
        let m = net.membership(t, devices);
        if m.rejoined {
            ps.force_resync();
        }
        let round = {
            let (b, _) = ps.broadcast(m.present);
            down_slots += m.present as u64;
            net.round(&b, &mut [])
        };
        match round {
            Ok(replies) => {
                let part = ps.apply(&replies)?;
                up_slots += part.count() as u64;
                if part.count() < devices {
                    partial_rounds += 1;
                }
                if t % (steps / 6).max(1) == 0 {
                    println!(
                        "  t={t:>4} loss={:.5} members={}/{devices} ||∇f(Qx(x))||²={:.3e}",
                        part.mean_loss,
                        part.count(),
                        problem.grad_norm_sq(ps.output_weights())
                    );
                }
            }
            Err(e) => {
                // every reply of the round lost: below quorum — skip
                // the update and move on, like a production loop would
                skipped_rounds += 1;
                eprintln!("  t={t:>4} round skipped: {e}");
            }
        }
    }
    net.shutdown()?;
    for h in handles {
        h.join().unwrap()?;
    }
    let secs = t0.elapsed().as_secs_f64();

    let s = &ps.stats;
    // fp32 baselines over the *delivered* message slots, so the saving
    // factors measure quantization, not chaos losses.
    let fp32_up = dim as f64 * 4.0 * up_slots as f64;
    let fp32_down = dim as f64 * 4.0 * down_slots as f64;
    println!("\n=== traffic over {} applied rounds, {:.1}s ===", s.rounds, secs);
    println!(
        "uplink   {:>10.3} MB (fp32 would be {:>10.3} MB) -> {:.1}x saved",
        s.up_bytes as f64 / 1e6,
        fp32_up / 1e6,
        fp32_up / s.up_bytes as f64
    );
    println!(
        "downlink {:>10.3} MB (fp32 would be {:>10.3} MB) -> {:.1}x saved",
        s.down_bytes as f64 / 1e6,
        fp32_down / 1e6,
        fp32_down / s.down_bytes as f64
    );
    println!(
        "device model storage: {:.3} MB at {}-bit weights (fp32 {:.3} MB)",
        dim as f64 * qadam::quant::WQuant::new(kx).code_bits() as f64 / 8.0 / 1e6,
        qadam::quant::WQuant::new(kx).code_bits(),
        dim as f64 * 4.0 / 1e6
    );
    println!(
        "chaos: {} replies dropped, {} delayed past deadline; {partial_rounds} partial + \
         {skipped_rounds} skipped of {steps} rounds — EF absorbed the losses",
        net.stats.dropped, net.stats.delayed
    );
    Ok(())
}
