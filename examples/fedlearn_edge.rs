//! The paper's motivating federated/edge scenario (§1): N resource-
//! constrained devices train over a *real TCP network* against the
//! parameter server, with BOTH quantizations on — weights broadcast at
//! k_x bits (storage-constrained devices), update vectors uploaded at
//! k_g-derived bits (bandwidth-constrained uplink).
//!
//! Everything runs in this one process (server thread + one thread per
//! device) but every byte crosses a real socket through the same
//! length-prefixed protocol a multi-host deployment uses
//! (`qadam serve` / `qadam worker`).
//!
//!   cargo run --release --example fedlearn_edge -- [--devices N] [--steps N]

use anyhow::Result;
use qadam::optim::{LrSchedule, QAdamEf};
use qadam::ps::transport::{tcp_worker_loop, TcpServer};
use qadam::ps::worker::{SimGradSource, Worker};
use qadam::ps::ParameterServer;
use qadam::quant::LogQuant;
use qadam::sim::StochasticProblem;
use qadam::util::Args;

fn main() -> Result<()> {
    let a = Args::parse_env()?;
    let devices = a.get("devices", 4usize)?;
    let steps = a.get("steps", 300u64)?;
    let dim = a.get("dim", 4096usize)?;
    let kg = a.get("kg", 2u32)?;
    let kx = a.get("kx", 6u32)?;
    a.reject_unknown()?;

    // pick a free port
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    drop(listener);

    println!("edge scenario: {devices} devices, dim={dim}, k_g={kg} uplink, k_x={kx} broadcast");
    println!("server at {addr}");

    let mut handles = Vec::new();
    for id in 0..devices as u32 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || -> Result<u64> {
            let problem = StochasticProblem::with_offgrid_minimum(dim, 0.1, 3);
            let opt = QAdamEf::new(
                dim,
                Box::new(LogQuant::new(kg)),
                true,
                LrSchedule::InvSqrt { alpha: 0.5 },
                qadam::optim::ThetaSchedule::Anneal { theta: 0.9 },
                0.9,
                1e-8,
            );
            let mut w = Worker::new(id, Box::new(opt), Box::new(SimGradSource { problem }), 5);
            // retry until the server socket is up
            for _ in 0..200 {
                match tcp_worker_loop(&addr, &mut w) {
                    Ok(r) => return Ok(r),
                    Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
                }
            }
            anyhow::bail!("device {id} could not connect")
        }));
    }

    let mut srv = TcpServer::bind_and_accept(&addr, devices)?;
    let problem = StochasticProblem::with_offgrid_minimum(dim, 0.1, 3);
    let mut ps = ParameterServer::new(problem.x0(), Some(kx));
    let t0 = std::time::Instant::now();
    for t in 1..=steps {
        let replies = {
            let (b, _) = ps.broadcast(devices);
            srv.round(&b)?
        };
        let loss = ps.apply(&replies)?;
        if t % (steps / 6).max(1) == 0 {
            println!(
                "  t={t:>4} loss={loss:.5} ||∇f(Qx(x))||²={:.3e}",
                problem.grad_norm_sq(ps.output_weights())
            );
        }
    }
    srv.shutdown()?;
    for h in handles {
        h.join().unwrap()?;
    }
    let secs = t0.elapsed().as_secs_f64();

    let s = &ps.stats;
    let fp32_up = dim as f64 * 4.0 * devices as f64 * steps as f64;
    let fp32_down = fp32_up;
    println!("\n=== traffic over {} rounds, {:.1}s ===", s.rounds, secs);
    println!(
        "uplink   {:>10.3} MB (fp32 would be {:>10.3} MB) -> {:.1}x saved",
        s.up_bytes as f64 / 1e6,
        fp32_up / 1e6,
        fp32_up / s.up_bytes as f64
    );
    println!(
        "downlink {:>10.3} MB (fp32 would be {:>10.3} MB) -> {:.1}x saved",
        s.down_bytes as f64 / 1e6,
        fp32_down / 1e6,
        fp32_down / s.down_bytes as f64
    );
    println!(
        "device model storage: {:.3} MB at {}-bit weights (fp32 {:.3} MB)",
        dim as f64 * qadam::quant::WQuant::new(kx).code_bits() as f64 / 8.0 / 1e6,
        qadam::quant::WQuant::new(kx).code_bits(),
        dim as f64 * 4.0 / 1e6
    );
    Ok(())
}
