//! End-to-end driver (the mandated validation run): train a transformer
//! LM on a synthetic Markov corpus with the full three-layer system —
//! Rust parameter server + workers, PJRT-executed JAX fwd/bwd graphs,
//! log-quantized Adam updates with error feedback — and log the loss
//! curve.
//!
//!   cargo run --release --example train_transformer -- \
//!       [--model transformer_small|transformer] [--steps N] [--workers N]
//!       [--kg K] [--kx K] [--alpha A] [--engine native|pjrt]
//!       [--bus sequential|threaded] [--downlink full|delta] [--csv PATH]
//!
//! Defaults are sized so the run finishes in a few minutes on a laptop
//! CPU while showing an unambiguous loss drop; `--model transformer`
//! runs the 3.3M-parameter config.

use qadam::coordinator::config::{BusKind, Downlink, Engine, ExperimentConfig, Method};
use qadam::coordinator::Trainer;
use qadam::optim::LrSchedule;
use qadam::util::Args;

fn main() -> anyhow::Result<()> {
    let a = Args::parse_env()?;
    let model = a.get_str("model", "transformer_small");
    let steps = a.get("steps", 1500u64)?;
    let workers = a.get("workers", 4usize)?;
    let kg: Option<u32> = Some(a.get("kg", 2u32)?);
    let kx: Option<u32> = a.opt("kx")?;
    let alpha = a.get("alpha", 3e-3f32)?;
    let engine = match a.get_str("engine", "native").as_str() {
        "pjrt" | "pjrt_kernel" => Engine::PjrtKernel,
        _ => Engine::Native,
    };
    let bus_str = a.get_str("bus", "sequential");
    let bus = BusKind::parse(&bus_str)
        .ok_or_else(|| anyhow::anyhow!("unknown bus '{bus_str}' (sequential | threaded)"))?;
    let down_str = a.get_str("downlink", "full");
    let downlink = Downlink::parse(&down_str)
        .ok_or_else(|| anyhow::anyhow!("unknown downlink '{down_str}' (full | delta)"))?;
    let resync_every = a.get("resync_every", 64u64)?;
    let csv = a.get_str("csv", "results/train_transformer.csv");
    a.reject_unknown()?;

    let cfg = ExperimentConfig {
        model: model.clone(),
        dataset: "text".into(),
        method: Method::QAdam { kg, error_feedback: true },
        kx,
        workers,
        batch: 8,
        steps,
        steps_per_epoch: 200,
        lr: LrSchedule::ExpDecay { alpha, half_every: 4 },
        engine,
        bus,
        downlink,
        resync_every,
        chaos: None,
        codec_policy: qadam::quant::PolicySpec::Static,
        shards: 1,
        straggler: qadam::elastic::StragglerPolicy::Wait,
        min_participation: 1,
        async_rounds: false,
        staleness: 0,
        staleness_down_weight: false,
        cohort: None,
        registry: 100_000,
        seed: 0,
        eval_every: (steps / 12).max(25),
        eval_batches: 2,
    };
    let t0 = std::time::Instant::now();
    let mut tr = Trainer::new(cfg)?;
    let summary = tr.run()?;
    let secs = t0.elapsed().as_secs_f64();

    println!("\n=== loss curve (t, train_loss, next-token acc) ===");
    for r in &tr.log.rows {
        println!("  t={:>5}  loss={:.4}  acc={:.2}%", r.t, r.train_loss, 100.0 * r.test_acc);
    }
    let first = tr.log.rows.first().map(|r| r.train_loss).unwrap_or(f32::NAN);
    println!("\n{}", summary.table_row());
    println!(
        "loss {:.3} -> {:.3} over {} steps ({} workers, {:.0}s, {:.2} steps/s)",
        first,
        summary.final_loss,
        steps,
        workers,
        secs,
        steps as f64 / secs
    );
    let p = std::path::PathBuf::from(csv);
    tr.log.write_csv(&p)?;
    println!("curve written to {}", p.display());
    Ok(())
}
