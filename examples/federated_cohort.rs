//! The cross-device federated walkthrough: a registry of 100k+
//! *logical* workers, of which only a small sampled cohort trains each
//! round (`--cohort`, README §Async rounds & client sampling).
//!
//! The point this example measures: per-round cost is a function of
//! the **cohort size K**, not the registry size. The registry is
//! purely virtual (`O(1)` memory), the cohort draw is Floyd's
//! sampling — exactly K rng variates — and the process holds K worker
//! slots that impersonate that round's sampled ids. The same run is
//! repeated over registries of 10k, 100k and 1M logical workers; the
//! per-round wall-clock must stay flat while the sampled id space
//! grows 100×.
//!
//! Deltas are applied through the async bounded-staleness engine with
//! τ = 0 (in-process replies are always fresh, so nothing is ever
//! rejected) — the same `apply_async` path `qadam train
//! --async-rounds --cohort K` drives.
//!
//!   cargo run --release --example federated_cohort -- [--cohort K]
//!       [--steps N] [--dim D]

use anyhow::Result;
use qadam::elastic::{StalenessPolicy, WorkerRegistry};
use qadam::optim::{LrSchedule, QAdamEf};
use qadam::ps::worker::{SimGradSource, Worker};
use qadam::ps::{LocalBus, ShardPlan, ShardedServer, Transport};
use qadam::quant::{PolicySpec, TensorLayout};
use std::time::Instant;

/// One sampled-cohort training run; returns (mean round µs, final
/// mean loss, distinct logical ids that actually trained).
fn run(
    registry_size: u64,
    k: usize,
    steps: u64,
    dim: usize,
) -> Result<(f64, f32, usize)> {
    let registry = WorkerRegistry::new(registry_size, 7);
    let plan = ShardPlan::build(dim, 1, &PolicySpec::Static, &TensorLayout::uniform(dim, 4))?;
    let x0: Vec<f32> = (0..dim).map(|i| 0.3 + 0.01 * (i as f32).sin()).collect();
    let mut srv = ShardedServer::new(x0, Some(6), plan.clone(), 1 << 16, 1);
    // K worker *slots*: each round they impersonate the sampled ids
    // (the id drives the data draw and the wire identity).
    let mut workers: Vec<Worker> = (0..k as u32)
        .map(|i| {
            let src =
                SimGradSource { problem: qadam::sim::StochasticProblem::new(dim, 0.05, 9) };
            let opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.02 });
            let mut w = Worker::new(i, Box::new(opt), Box::new(src), 1);
            w.set_shards(plan.clone());
            w
        })
        .collect();
    let mut bus: Box<dyn Transport> = Box::new(LocalBus::default());
    let policy = StalenessPolicy::new(0, false);
    let mut seen: Vec<u32> = Vec::new();
    let mut last_loss = 0.0f32;
    let start = Instant::now();
    for t in 1..=steps {
        for (slot, lid) in registry.cohort(t, k).into_iter().enumerate() {
            workers[slot].id = lid;
            if let Err(pos) = seen.binary_search(&lid) {
                seen.insert(pos, lid);
            }
        }
        let frames = srv.broadcast(k);
        let lanes = bus.round_sharded(&frames, &mut workers)?;
        let ar = srv.apply_async(&lanes, &policy)?;
        assert!(ar.rejected.is_empty(), "in-process replies are always fresh");
        last_loss = ar.part.mean_loss;
    }
    let us_per_round = start.elapsed().as_micros() as f64 / steps as f64;
    Ok((us_per_round, last_loss, seen.len()))
}

fn main() -> Result<()> {
    let a = qadam::util::Args::parse_env()?;
    let k = a.get("cohort", 32usize)?;
    let steps = a.get("steps", 20u64)?;
    let dim = a.get("dim", 4096usize)?;
    a.reject_unknown()?;
    println!("cohort K={k}, dim={dim}, {steps} rounds per registry size\n");
    println!(
        "{:>12}  {:>14}  {:>10}  {:>12}",
        "registry", "us/round", "loss", "ids trained"
    );
    // Warmup run (untimed ranking-wise): page in the binary and the
    // allocator so cold-start cost doesn't skew the first measured size.
    run(10_000, k, 2.min(steps), dim)?;
    let mut flat: Vec<f64> = Vec::new();
    for size in [10_000u64, 100_000, 1_000_000] {
        let (us, loss, distinct) = run(size, k, steps, dim)?;
        println!("{size:>12}  {us:>14.1}  {loss:>10.4}  {distinct:>12}");
        flat.push(us);
    }
    // The acceptance claim: 100× more logical workers, same per-round
    // cost. Generous 3× bound — this is a smoke gate, not a benchmark.
    let (lo, hi) =
        flat.iter().fold((f64::MAX, 0.0f64), |(l, h), &v| (l.min(v), h.max(v)));
    println!("\nspread: min {lo:.1} us, max {hi:.1} us ({:.2}x)", hi / lo);
    if hi / lo > 3.0 {
        anyhow::bail!("per-round cost should be independent of registry size");
    }
    println!("OK: per-round cost is flat across registry sizes (cohort sampling is O(K))");
    Ok(())
}
