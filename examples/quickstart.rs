//! Quickstart: train a small MLP with the paper's full stack —
//! 8 workers on the threaded round engine, parameter server, log-level
//! gradient quantization (k_g=2, 3 bits/coordinate), error feedback —
//! and compare against full precision.
//!
//!   make artifacts && cargo run --release --example quickstart

use qadam::coordinator::config::{BusKind, Downlink, Engine, ExperimentConfig, Method};
use qadam::coordinator::Trainer;
use qadam::optim::LrSchedule;

fn main() -> anyhow::Result<()> {
    let base = ExperimentConfig {
        model: "mlp".into(),
        dataset: "vector".into(),
        method: Method::QAdam { kg: Some(2), error_feedback: true },
        kx: None,
        workers: 8,
        batch: 16,
        steps: 80,
        steps_per_epoch: 40,
        lr: LrSchedule::ExpDecay { alpha: 2e-3, half_every: 50 },
        engine: Engine::Native,
        bus: BusKind::Threaded,
        downlink: Downlink::Full,
        resync_every: 64,
        chaos: None,
        codec_policy: qadam::quant::PolicySpec::Static,
        shards: 1,
        straggler: qadam::elastic::StragglerPolicy::Wait,
        min_participation: 1,
        async_rounds: false,
        staleness: 0,
        staleness_down_weight: false,
        cohort: None,
        registry: 100_000,
        seed: 0,
        eval_every: 20,
        eval_batches: 4,
    };

    println!("== QAdam-EF (k_g = 2, 3-bit gradients) ==");
    let mut tr = Trainer::new(base.clone())?;
    let q = tr.run()?;

    println!("\n== full-precision distributed Adam ==");
    let mut cfg = base;
    cfg.method = Method::QAdam { kg: None, error_feedback: false };
    let mut tr = Trainer::new(cfg)?;
    let fp = tr.run()?;

    println!("\n{}", q.table_row());
    println!("{}", fp.table_row());
    println!(
        "\ncommunication reduced {:.1}x, accuracy {:+.2} pts",
        fp.comm_mb_per_iter / q.comm_mb_per_iter,
        100.0 * (q.final_acc - fp.final_acc)
    );
    Ok(())
}
