//! Async bounded-staleness acceptance suite (no artifacts needed —
//! sim workers over the real engines):
//!
//! * Sync mode stays pinned: with the async machinery compiled in but
//!   a τ = 0 policy and no faults, `apply_async` walks the exact same
//!   trajectory as the sync `apply` engine — frames, participation and
//!   masters byte-identical round by round.
//! * The chaos property: under seeded drop/delay faults in async mode,
//!   every delta that survives the wire is either **admitted** within
//!   the staleness bound or **rejected and refunded** into its
//!   sender's EF residual — no gradient mass is silently lost — and
//!   the whole run is bit-reproducible across the sequential and
//!   threaded engines.

use qadam::elastic::{ChaosPlan, ChaosTransport, StalenessPolicy};
use qadam::optim::{LrSchedule, QAdamEf};
use qadam::ps::worker::{SimGradSource, Worker};
use qadam::ps::{LocalBus, ShardPlan, ShardedServer, ThreadedBus, Transport};
use qadam::quant::{PolicySpec, TensorLayout};

const BLOCK: usize = 1 << 16;

fn x0(dim: usize) -> Vec<f32> {
    (0..dim).map(|i| 0.3 + 0.01 * (i as f32).sin()).collect()
}

fn mk_worker(id: u32, dim: usize, plan: &ShardPlan) -> Worker {
    let src = SimGradSource { problem: qadam::sim::StochasticProblem::new(dim, 0.05, 9) };
    let opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.02 });
    let mut w = Worker::new(id, Box::new(opt), Box::new(src), 1);
    w.set_shards(plan.clone());
    w
}

fn mk_plan(dim: usize, shards: usize) -> ShardPlan {
    ShardPlan::build(dim, shards, &PolicySpec::Static, &TensorLayout::uniform(dim, 4)).unwrap()
}

/// Acceptance (the sync-parity pin): with every delta fresh, the async
/// apply is the sync engine bit for bit — same broadcasts, same
/// participation, same masters, nothing rejected. This is what keeps
/// `--async-rounds` off the hook for the seed trajectory: the sync
/// path is untouched, and the async path degenerates to it at age 0.
#[test]
fn async_apply_at_age_zero_matches_the_sync_engine_bitwise() {
    let dim = 64;
    let nw = 3usize;
    let plan = mk_plan(dim, 2);
    let mut sync_srv = ShardedServer::new(x0(dim), Some(4), plan.clone(), BLOCK, 1);
    let mut async_srv = ShardedServer::new(x0(dim), Some(4), plan.clone(), BLOCK, 1);
    let mut ws_sync: Vec<Worker> = (0..nw as u32).map(|i| mk_worker(i, dim, &plan)).collect();
    let mut ws_async: Vec<Worker> = (0..nw as u32).map(|i| mk_worker(i, dim, &plan)).collect();
    let mut bus_sync: Box<dyn Transport> = Box::new(LocalBus::default());
    let mut bus_async: Box<dyn Transport> = Box::new(LocalBus::default());
    let policy = StalenessPolicy::new(0, false);
    for t in 1u64..=12 {
        let fa = sync_srv.broadcast(nw);
        let fb = async_srv.broadcast(nw);
        for (a, b) in fa.iter().zip(&fb) {
            assert_eq!(a.to_bytes(), b.to_bytes(), "t={t}: broadcast frame diverged");
        }
        let ra = bus_sync.round_sharded(&fa, &mut ws_sync).unwrap();
        let rb = bus_async.round_sharded(&fb, &mut ws_async).unwrap();
        let pa = sync_srv.apply(&ra).unwrap();
        let ar = async_srv.apply_async(&rb, &policy).unwrap();
        assert!(ar.rejected.is_empty(), "t={t}: fresh deltas must all be admitted");
        assert!(ar.ages.iter().flatten().all(|&a| a == 0), "t={t}: all ages fresh");
        assert_eq!(ar.part, pa, "t={t}: participation diverged");
        assert_eq!(async_srv.master(), sync_srv.master(), "t={t}: masters diverged");
    }
}

/// Acceptance (the zero-reporters guard): a drop-everything chaos
/// plan in async mode yields quiet rounds — no reporters, weights
/// pinned — and `mean_loss` is exactly 0.0, never the 0/0 NaN that
/// would otherwise poison the CSV rows and the obs loss gauge
/// downstream. (The sync path can't reach this state: `apply` rejects
/// an empty round and the quorum check fires first.)
#[test]
fn drop_all_chaos_rounds_report_finite_zero_loss() {
    let dim = 32;
    let nw = 2u32;
    let plan = mk_plan(dim, 2);
    let mut srv = ShardedServer::new(x0(dim), None, plan.clone(), BLOCK, 1);
    let mut workers: Vec<Worker> = (0..nw).map(|i| mk_worker(i, dim, &plan)).collect();
    let chaos = ChaosPlan::parse("seed=5,drop=1.0").unwrap();
    let inner: Box<dyn Transport> = Box::new(LocalBus::default());
    let mut bus = ChaosTransport::new(inner, chaos).with_async(true);
    let policy = StalenessPolicy::new(0, false);
    let before = srv.master();
    for t in 1u64..=4 {
        let frames = srv.broadcast(nw as usize);
        let lanes = bus.round_sharded(&frames, &mut workers).unwrap();
        assert!(lanes.iter().all(|l| l.is_empty()), "t={t}: drop=1.0 must drop every reply");
        let ar = srv.apply_async(&lanes, &policy).unwrap();
        assert!(ar.part.reporters.is_empty(), "t={t}: a quiet round has no reporters");
        assert!(ar.part.mean_loss.is_finite(), "t={t}: quiet round must not produce NaN");
        assert_eq!(ar.part.mean_loss, 0.0);
    }
    assert_eq!(srv.master(), before, "no admitted mass may move the weights");
}

/// One full chaos-async run; returns (per-round masters, final worker
/// residual norms, surfaced replies, rejected replies, refunds).
fn chaos_async_run(threaded: bool, rounds: u64) -> (Vec<Vec<f32>>, Vec<f32>, u64, u64, u64) {
    let dim = 48;
    let nw = 3u32;
    let shards = 2usize;
    let tau = 1u64;
    let plan = mk_plan(dim, shards);
    let mut srv = ShardedServer::new(x0(dim), None, plan.clone(), BLOCK, 1);
    let mut workers: Vec<Worker> = (0..nw).map(|i| mk_worker(i, dim, &plan)).collect();
    let inner: Box<dyn Transport> =
        if threaded { Box::new(ThreadedBus::new()) } else { Box::new(LocalBus::default()) };
    // lag=1 makes every delayed reply resurface at age 2 — strictly
    // past τ=1 — so each one must take the reject+refund path.
    let chaos = ChaosPlan::parse("seed=11,drop=0.15,delay=0.35,lag=1").unwrap();
    let mut bus = ChaosTransport::new(inner, chaos).with_async(true);
    let policy = StalenessPolicy::new(tau, false);
    let mut masters = Vec::new();
    let (mut surfaced, mut rejected_total, mut refunds) = (0u64, 0u64, 0u64);
    for t in 1u64..=rounds {
        let frames = srv.broadcast(nw as usize);
        let lanes = bus.round_sharded(&frames, &mut workers).unwrap();
        surfaced += lanes.iter().map(|l| l.len() as u64).sum::<u64>();
        let ar = srv.apply_async(&lanes, &policy).unwrap();
        for (lane, lane_ages) in ar.ages.iter().enumerate() {
            for (i, &age) in lane_ages.iter().enumerate() {
                if ar.rejected.binary_search(&(lane, i)).is_ok() {
                    // the no-lost-mass half of the property: every
                    // rejected delta folds into its sender's residual
                    let wid = lanes[lane][i].worker() as usize;
                    workers[wid].absorb_rejected(lane, &lanes[lane][i], 1.0).unwrap();
                    refunds += 1;
                    assert!(age > tau, "t={t}: rejected a delta inside the bound (age {age})");
                } else {
                    assert!(age <= tau, "t={t}: admitted a delta beyond the bound (age {age})");
                }
            }
        }
        rejected_total += ar.rejected.len() as u64;
        masters.push(srv.master());
    }
    // Wire accounting: every reply a worker sent either surfaced in
    // some round's gather, was dropped by the chaos plan, or is still
    // held past the horizon — nothing vanishes without a ledger entry.
    let stats = bus.fault_stats().unwrap();
    let held_at_end = bus.held_replies().len() as u64;
    let sent = rounds * nw as u64 * shards as u64;
    assert_eq!(
        surfaced + stats.dropped + held_at_end,
        sent,
        "reply ledger does not balance: {surfaced} surfaced + {} dropped + {held_at_end} held != {sent} sent",
        stats.dropped
    );
    assert!(stats.delayed > 0, "the plan should actually delay something");
    assert!(rejected_total > 0, "the lagged delays should actually get rejected");
    let residuals = workers.iter().map(|w| w.residual_norm()).collect();
    (masters, residuals, surfaced, rejected_total, refunds)
}

/// Acceptance (the chaos property): under seeded drop/delay faults,
/// every surfaced delta is admitted within τ or refunded into its
/// sender's EF residual, the reply ledger balances exactly, and the
/// whole trajectory — masters per round *and* worker residuals — is
/// bit-reproducible across the sequential and threaded engines.
#[test]
fn chaos_async_rounds_conserve_delta_mass_and_reproduce_bitwise() {
    let rounds = 10u64;
    let (m_seq, r_seq, surfaced_seq, rej_seq, refunds_seq) = chaos_async_run(false, rounds);
    let (m_thr, r_thr, surfaced_thr, rej_thr, refunds_thr) = chaos_async_run(true, rounds);
    assert_eq!(rej_seq, refunds_seq, "every rejected delta must be refunded exactly once");
    assert_eq!(surfaced_seq, surfaced_thr, "engines gathered different reply streams");
    assert_eq!(rej_seq, rej_thr);
    assert_eq!(refunds_seq, refunds_thr);
    for (t, (a, b)) in m_seq.iter().zip(&m_thr).enumerate() {
        assert_eq!(a, b, "t={}: masters diverged across engines", t + 1);
    }
    assert_eq!(r_seq, r_thr, "worker EF residuals diverged across engines");
}
