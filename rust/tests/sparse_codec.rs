//! Conservation properties of the sparse codecs composed with error
//! feedback (the tier-1 sparse wall, DESIGN.md §Sparse codecs & EF
//! composition).
//!
//! The load-bearing claim: sparsification drops coordinates, and every
//! dropped coordinate's mass lands **bit-exactly** in the EF residual —
//! `decoded + residual == input`, per coordinate, as f32 bit patterns.
//! For [`TopK`] the kept coordinates ship verbatim, so the identity is
//! exact everywhere; for [`SparseBlock`] the kept coordinates are
//! sign·scale approximations (checked within the f32-subtraction
//! tolerance) while the dropped ones stay bit-exact.

use qadam::quant::{
    decode_msg_range_add, pack, seeded_rng, Compressor, ErrorFeedback, SparseBlock, TopK,
    WireMsg,
};

/// Deterministic ragged-value vector mixing signs, magnitudes spanning
/// many decades, exact zeros, subnormals and f32 extremes.
fn hostile_values(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = seeded_rng(seed, 42);
    (0..n)
        .map(|i| match i % 7 {
            0 => 0.0,
            1 => -0.0,
            2 => f32::MIN_POSITIVE / 2.0, // subnormal
            3 => f32::MAX * (rng.gen_f32() - 0.5) * 1e-3,
            4 => -(rng.gen_f32() + 0.5) * 1e-30,
            _ => (rng.gen_f32() * 2.0 - 1.0) * 10f32.powi((i % 9) as i32 - 4),
        })
        .collect()
}

const RAGGED_LENGTHS: &[usize] = &[1, 2, 3, 7, 31, 64, 65, 129, 257, 1000];
const DENSITIES_BP: &[u32] = &[1, 100, 1250, 2500, 5000, 9999, 10000];

#[test]
fn topk_conservation_is_bit_exact_per_coordinate() {
    for &n in RAGGED_LENGTHS {
        for &bp in DENSITIES_BP {
            let u = hostile_values(n, n as u64 ^ u64::from(bp));
            let comp = TopK::new(bp);
            let mut q = vec![0.0f32; n];
            let msg = comp.compress_into(&u, &mut q, &mut seeded_rng(1, 1));
            for (i, (&ui, &qi)) in u.iter().zip(&q).enumerate() {
                // Every coordinate is either kept — shipped verbatim,
                // residual exactly +0.0 — or dropped to 0.0 with the
                // residual reproducing the input bit for bit. Both
                // cases make `decoded + residual == input` exact.
                let kept_exact = qi.to_bits() == ui.to_bits();
                let dropped_exact = qi == 0.0 && (ui - qi).to_bits() == ui.to_bits();
                assert!(
                    kept_exact || dropped_exact,
                    "n={n} bp={bp} i={i}: u={ui:?} decoded to q={qi:?} — conservation broken"
                );
            }
            // no more nonzero decoded coords than the header claims
            assert!(
                q.iter().filter(|&&v| v != 0.0).count() <= msg.param as usize,
                "n={n} bp={bp}: more shipped coords than k"
            );
            // the decoded message reproduces q bit-for-bit
            let mut out = vec![0.0f32; n];
            comp.decompress(&msg, &mut out);
            for (i, (&qi, &oi)) in q.iter().zip(&out).enumerate() {
                assert_eq!(qi.to_bits(), oi.to_bits(), "n={n} bp={bp} i={i}: decode mismatch");
            }
        }
    }
}

#[test]
fn topk_indices_are_sorted_and_duplicate_free() {
    for &n in RAGGED_LENGTHS {
        for &bp in &[1u32, 400, 2500, 9999] {
            let u = hostile_values(n, 7 ^ n as u64);
            let comp = TopK::new(bp);
            let mut q = vec![0.0f32; n];
            let msg = comp.compress_into(&u, &mut q, &mut seeded_rng(2, 2));
            let k = msg.param as usize;
            let Some(p) = msg.codes.as_ref() else {
                assert_eq!(k, 0);
                continue;
            };
            let codes = pack::unpack(p);
            if p.bits == 1 {
                // bitmap: n lanes, popcount == k
                assert_eq!(codes.len(), n, "bitmap must cover every coordinate");
                assert_eq!(
                    codes.iter().filter(|&&c| c == 1).count(),
                    k,
                    "n={n} bp={bp}: bitmap popcount != k"
                );
            } else {
                // index list: k entries, strictly increasing => sorted
                // AND duplicate-free in one check
                assert_eq!(codes.len(), k);
                for w in codes.windows(2) {
                    assert!(w[0] < w[1], "n={n} bp={bp}: indices not strictly increasing");
                }
                assert!(codes.iter().all(|&c| (c as usize) < n));
            }
        }
    }
}

#[test]
fn topk_degenerate_keep_counts_are_legal() {
    // k == len: density 1.0 keeps everything — the identity codec with
    // a bitmap, bit-exact round trip.
    let u = hostile_values(65, 9);
    let comp = TopK::new(10_000);
    let mut q = vec![0.0f32; 65];
    let msg = comp.compress_into(&u, &mut q, &mut seeded_rng(3, 3));
    assert_eq!(msg.param as usize, 65);
    for (&ui, &qi) in u.iter().zip(&q) {
        assert_eq!(ui.to_bits(), qi.to_bits());
    }
    let bytes = msg.to_bytes();
    let rt = WireMsg::from_bytes(&bytes).expect("k = n frame round-trips");
    assert_eq!(rt.to_bytes(), bytes);

    // k == 0: never emitted by the encoder (density is floored at
    // 1/10000 and k = ceil) but legal on the wire; decodes to zeros.
    let mut zero = msg.clone();
    zero.param = 0;
    zero.raw.clear();
    zero.codes = None;
    let bytes = zero.to_bytes();
    let rt = WireMsg::from_bytes(&bytes).expect("k = 0 frame is legal");
    let mut out = vec![1.0f32; 65];
    TopK::decoder().decompress(&rt, &mut out);
    assert!(out.iter().all(|&v| v == 0.0), "k = 0 decodes to all-zero");

    // k = 1 on n = 1 (the smallest ragged edge)
    let comp = TopK::new(1);
    let mut q1 = [0.0f32];
    let msg = comp.compress_into(&[-3.5], &mut q1, &mut seeded_rng(4, 4));
    assert_eq!(q1[0], -3.5);
    assert_eq!(msg.param, 1);
}

#[test]
fn sparse_block_dropped_coordinates_conserve_bit_exactly() {
    for &(block, kb) in &[(2usize, 1usize), (7, 2), (32, 4), (64, 64)] {
        for &n in RAGGED_LENGTHS {
            let u = hostile_values(n, (block * 1000 + kb) as u64 ^ n as u64);
            let comp = SparseBlock::new(block, kb);
            let mut q = vec![0.0f32; n];
            let msg = comp.compress_into(&u, &mut q, &mut seeded_rng(5, 5));
            for (i, (&ui, &qi)) in u.iter().zip(&q).enumerate() {
                if qi == 0.0 && qi.to_bits() != ui.to_bits() {
                    assert_eq!(
                        (ui - qi).to_bits(),
                        ui.to_bits(),
                        "block={block} kb={kb} n={n} i={i}: dropped coord must conserve"
                    );
                } else if qi != 0.0 {
                    // kept: sign·scale, conservation up to the two f32
                    // roundings of `e = u - q` and `q + e` (each within
                    // an ulp of a value no larger than |u| + |q|)
                    let e = ui - qi;
                    let back = qi + e;
                    assert!(
                        (back - ui).abs() <= (ui.abs() + qi.abs()) * f32::EPSILON * 2.0,
                        "block={block} kb={kb} n={n} i={i}: kept coord residual off"
                    );
                }
            }
            // full decode == q bit-for-bit, and range decode agrees
            let mut out = vec![0.0f32; n];
            comp.decompress(&msg, &mut out);
            for (&qi, &oi) in q.iter().zip(&out) {
                assert_eq!(qi.to_bits(), oi.to_bits());
            }
            if n > 2 {
                let mut acc = vec![1.0f32; n - 2];
                decode_msg_range_add(&msg, 1, &mut acc);
                for (j, &a) in acc.iter().enumerate() {
                    assert_eq!(a, 1.0 + q[j + 1], "range-add decode must match q");
                }
            }
        }
    }
}

#[test]
fn sparse_block_positions_sorted_within_every_block() {
    for &(block, kb) in &[(7usize, 3usize), (16, 2), (32, 8)] {
        let n = 129;
        let u = hostile_values(n, 77);
        let comp = SparseBlock::new(block, kb);
        let mut q = vec![0.0f32; n];
        let msg = comp.compress_into(&u, &mut q, &mut seeded_rng(6, 6));
        let p = msg.codes.as_ref().expect("sparse-block frames carry codes");
        let codes = pack::unpack(p);
        let nblocks = n.div_ceil(block);
        assert_eq!(msg.scales.len(), nblocks);
        let mut it = codes.iter();
        for bi in 0..nblocks {
            let len_b = block.min(n - bi * block);
            let kk = kb.min(len_b);
            let mut prev: i64 = -1;
            for _ in 0..kk {
                let c = *it.next().expect("code count == sum of per-block keeps");
                let pos = (c >> 1) as i64;
                assert!(pos > prev, "block {bi}: positions must be strictly increasing");
                assert!((pos as usize) < len_b, "block {bi}: position out of block");
                prev = pos;
            }
        }
        assert!(it.next().is_none(), "no trailing codes");
    }
}

/// Error feedback composed with a sparse codec stays bounded: the
/// dropped mass is re-offered every round, and because top-k ships the
/// largest magnitudes first the residual contracts by at least the
/// kept-density factor — it cannot grow without bound (the Assumption 2
/// δ-contraction argument, measured).
#[test]
fn ef_residual_stays_bounded_under_repeated_sparse_compression() {
    let n = 256;
    let dir: Vec<f32> = (0..n).map(|i| ((i as f32 * 0.7).sin()) / (n as f32).sqrt()).collect();
    let g_norm = dir.iter().map(|v| v * v).sum::<f32>().sqrt();

    // TopK at 5% kept: steady-state ||e|| <= sqrt(1-d)/(1-sqrt(1-d)) ||g||
    // ~ 38.5 ||g|| for d = 0.05; assert a ceiling above it.
    let comp = TopK::new(500);
    let mut ef = ErrorFeedback::new(n, true);
    let mut rng = seeded_rng(11, 0);
    let mut peak = 0.0f32;
    for _ in 0..500 {
        let _ = ef.compress(&dir, &comp, &mut rng);
        peak = peak.max(ef.residual_norm());
    }
    let bound = 3.0 / 0.05 * g_norm;
    assert!(
        peak <= bound,
        "topk EF residual grew past the contraction bound: peak {peak} > {bound}"
    );

    // SparseBlock 4-of-32: weaker per-round contraction (kept values
    // are sign*scale, not verbatim) but still a contraction.
    let comp = SparseBlock::new(32, 4);
    let mut ef = ErrorFeedback::new(n, true);
    let mut peak = 0.0f32;
    for _ in 0..500 {
        let _ = ef.compress(&dir, &comp, &mut rng);
        peak = peak.max(ef.residual_norm());
    }
    assert!(
        peak <= 100.0 * g_norm,
        "sparse-block EF residual grew without bound: peak {peak}"
    );

    // And on the *sparse* gradient shape the codecs are for: a vector
    // that is zero outside one live slice. The residual can never
    // exceed the un-shipped fraction of what was ever offered.
    let mut sparse_dir = vec![0.0f32; n];
    for (i, v) in sparse_dir.iter_mut().enumerate().take(32) {
        *v = ((i as f32) * 0.3).cos() * 0.1;
    }
    let comp = TopK::new(1250); // 12.5% of n = 32 coords = the live slice
    let mut ef = ErrorFeedback::new(n, true);
    for _ in 0..50 {
        let (_, q) = ef.compress_q(&sparse_dir, &comp, &mut rng);
        // everything shipped lands inside the live slice
        assert!(q[32..].iter().all(|&v| v == 0.0), "shipped mass leaked outside the live slice");
    }
    // k (= 32) covers the live slice, so the residual drains to ~0
    assert!(
        ef.residual_norm() <= 1e-6,
        "top-k covering the live slice must drain the residual, got {}",
        ef.residual_norm()
    );
}
