//! Kernel-equivalence suite: every rewritten hot-path kernel
//! (streaming bit-pack, fused compress, table/fused range decode) is
//! **bit-identical** to the retained scalar reference implementations
//! in `qadam::quant::reference` — the literal pre-rewrite code.
//!
//! Coverage axes:
//! * randomized lengths, including non-lane-multiple tails (the
//!   `for_each_chunk` chunk width is 128; lengths straddle 63/64/65,
//!   127/128/129 and a large non-multiple);
//! * extreme values: ±0.0, subnormals, the smallest normal, and
//!   magnitudes near `f32::MAX`;
//! * every supported bit level per codec;
//! * stochastic codecs additionally prove they consume the *same rng
//!   sequence* (the wire golden fixtures depend on exact draw counts).
//!
//! Equality is always on bit patterns: wire bytes via
//! [`WireMsg::to_bytes`], floats via `f32::to_bits`.

use qadam::quant::pack::{pack, unpack_range_into};
use qadam::quant::reference as r;
use qadam::quant::{
    decode_msg_range_add, seeded_rng, Blockwise, CodecId, Compressor, Identity, LogQuant, Qsgd,
    StochasticLogQuant, TernGrad, WQuant, WireMsg,
};

/// Lengths exercising empty, single-lane, tail-straddling and large
/// non-multiple cases for every chunked kernel.
const LENGTHS: &[usize] = &[0, 1, 3, 31, 32, 33, 63, 64, 65, 127, 128, 129, 1000, 4097];

/// Deterministic values with extremes spliced at the head and tail, so
/// both the vector head and the ragged last chunk see them.
fn vals(seed: u64, n: usize, scale: f32) -> Vec<f32> {
    let mut rng = seeded_rng(seed, 0x7e57);
    let mut v: Vec<f32> = (0..n).map(|_| scale * (rng.gen_f32() - 0.5)).collect();
    let extremes = [
        0.0f32,
        -0.0,
        f32::from_bits(1),        // smallest positive subnormal
        -f32::from_bits(1),
        f32::MIN_POSITIVE,        // smallest normal
        -f32::MIN_POSITIVE,
        1.0e38,
        -1.0e38,
    ];
    for (slot, &e) in v.iter_mut().zip(&extremes) {
        *slot = e;
    }
    let m = v.len();
    for (k, &e) in extremes.iter().enumerate().take(m.saturating_sub(extremes.len())) {
        v[m - 1 - k] = e;
    }
    v
}

/// Range windows covering full, prefix, suffix, middle and off-by-one
/// starts of an `n`-element payload.
fn windows(n: usize) -> Vec<(usize, usize)> {
    let mut w = vec![(0usize, n)];
    if n > 0 {
        w.push((0, 1));
        w.push((n - 1, 1));
        w.push((n / 3, n - n / 3 - (n / 4)));
    }
    if n > 2 {
        w.push((1, n - 2));
    }
    w
}

#[track_caller]
fn assert_bits_eq(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: element {i}: {x:?} vs {y:?}");
    }
}

/// Compare the fused range decode (and its `_add` variant) against the
/// reference range decode over every window of the payload.
fn check_ranges(
    msg: &WireMsg,
    n: usize,
    dec_new: &dyn Fn(&WireMsg, usize, &mut [f32]),
    dec_ref: &dyn Fn(&WireMsg, usize, &mut [f32]),
    ctx: &str,
) {
    for (start, len) in windows(n) {
        let mut a = vec![0.0f32; len];
        let mut b = vec![0.0f32; len];
        dec_new(msg, start, &mut a);
        dec_ref(msg, start, &mut b);
        assert_bits_eq(&a, &b, &format!("{ctx} decode range {start}+{len}"));
        // fused add == reference decode into scratch, then add
        let mut acc_fused: Vec<f32> = (0..len).map(|i| 0.25 * (i as f32 + 1.0)).collect();
        let mut acc_ref = acc_fused.clone();
        decode_msg_range_add(msg, start, &mut acc_fused);
        for (dst, &s) in acc_ref.iter_mut().zip(&b) {
            *dst += s;
        }
        assert_bits_eq(&acc_fused, &acc_ref, &format!("{ctx} add range {start}+{len}"));
    }
}

#[test]
fn pack_streaming_matches_reference_all_widths() {
    for bits in 1u8..=32 {
        let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
        for &n in LENGTHS {
            let mut rng = seeded_rng(bits as u64, n as u64);
            let codes: Vec<u32> = (0..n).map(|_| rng.gen_u32() & mask).collect();
            let new = pack(&codes, bits);
            let reference = r::pack_ref(&codes, bits);
            assert_eq!(new.words, reference.words, "bits={bits} n={n}");
            assert_eq!((new.bits, new.n), (reference.bits, reference.n));
            for (start, len) in windows(n) {
                let mut a = vec![0u32; len];
                let mut b = vec![0u32; len];
                unpack_range_into(&new, start, &mut a);
                r::unpack_range_ref(&new, start, &mut b);
                assert_eq!(a, b, "bits={bits} n={n} range {start}+{len}");
            }
        }
    }
}

#[test]
fn logquant_kernels_match_reference() {
    for &kg in &[0u32, 1, 2, 8, 20] {
        let lq = LogQuant::new(kg);
        for &n in LENGTHS {
            for seed in 0..2u64 {
                let u = vals(seed, n, 0.2);
                let mut q_new = vec![0.0f32; n];
                let mut q_ref = vec![0.0f32; n];
                let mut rng = seeded_rng(0, 0); // unused: deterministic codec
                let m_new = lq.compress_into(&u, &mut q_new, &mut rng);
                let m_ref = r::logquant_compress_ref(kg, &u, &mut q_ref);
                let ctx = format!("logquant kg={kg} n={n} seed={seed}");
                assert_eq!(m_new.to_bytes(), m_ref.to_bytes(), "{ctx}: wire bytes");
                assert_bits_eq(&q_new, &q_ref, &format!("{ctx}: q"));
                check_ranges(
                    &m_new,
                    n,
                    &|m, s, o| lq.decompress_range(m, s, o),
                    &r::logquant_decompress_range_ref,
                    &ctx,
                );
            }
        }
    }
}

/// Multi-scale (per-chunk scale) LogQuant frames — the PJRT kernel
/// layout — decode through the signed-level table bit-identically to
/// the reference, including the zero symbol staying exactly +0.0.
#[test]
fn logquant_multiscale_decode_matches_reference() {
    for &kg in &[0u32, 2, 8] {
        let lq = LogQuant::new(kg);
        for &block_log2 in &[2u32, 6] {
            let block = 1usize << block_log2;
            for &n in &[1usize, 5, 64, 65, 257, 1000] {
                let u = vals(kg as u64 + block_log2 as u64, n, 0.5);
                let mut q = vec![0.0f32; n];
                let mut scales = Vec::new();
                let mut all_codes: Vec<u32> = Vec::new();
                for (bi, chunk) in u.chunks(block).enumerate() {
                    let lo = bi * block;
                    let mut codes = Vec::new();
                    let s = lq.quantize(chunk, &mut q[lo..lo + chunk.len()], &mut codes);
                    scales.push(s);
                    all_codes.extend_from_slice(&codes);
                }
                let msg = WireMsg {
                    codec: CodecId::LogQuant,
                    param: lq.pjrt_param(block),
                    n,
                    scales,
                    codes: Some(pack(&all_codes, lq.code_bits())),
                    raw: vec![],
                };
                let ctx = format!("logquant-ms kg={kg} block={block} n={n}");
                check_ranges(
                    &msg,
                    n,
                    &|m, s, o| lq.decompress_range(m, s, o),
                    &r::logquant_decompress_range_ref,
                    &ctx,
                );
                // the decoded payload equals the quantizer's q (decode
                // identity across the multi-scale wire layout)
                let mut out = vec![0.0f32; n];
                lq.decompress_range(&msg, 0, &mut out);
                assert_bits_eq(&out, &q, &ctx);
            }
        }
    }
}

#[test]
fn stochastic_logquant_matches_reference_and_rng_sequence() {
    for &kg in &[0u32, 3] {
        let slq = StochasticLogQuant::new(kg);
        for &n in LENGTHS {
            let u = vals(kg as u64, n, 0.1);
            let mut q_new = vec![0.0f32; n];
            let mut q_ref = vec![0.0f32; n];
            let mut rng_new = seeded_rng(42, n as u64);
            let mut rng_ref = seeded_rng(42, n as u64);
            let m_new = slq.compress_into(&u, &mut q_new, &mut rng_new);
            let m_ref = r::stochastic_log_compress_ref(kg, &u, &mut q_ref, &mut rng_ref);
            let ctx = format!("slq kg={kg} n={n}");
            assert_eq!(m_new.to_bytes(), m_ref.to_bytes(), "{ctx}: wire bytes");
            assert_bits_eq(&q_new, &q_ref, &format!("{ctx}: q"));
            // identical post-compress draws == identical consumption
            for _ in 0..4 {
                assert_eq!(rng_new.gen_u32(), rng_ref.gen_u32(), "{ctx}: rng sequence");
            }
            check_ranges(
                &m_new,
                n,
                &|m, s, o| slq.decompress_range(m, s, o),
                &r::logquant_decompress_range_ref,
                &ctx,
            );
        }
    }
}

#[test]
fn qsgd_matches_reference_and_rng_sequence() {
    for &levels in &[1u32, 4, 255, 1000] {
        let qs = Qsgd::new(levels);
        for &n in LENGTHS {
            let u = vals(levels as u64, n, 0.3);
            let mut q_new = vec![0.0f32; n];
            let mut q_ref = vec![0.0f32; n];
            let mut rng_new = seeded_rng(7, n as u64);
            let mut rng_ref = seeded_rng(7, n as u64);
            let m_new = qs.compress_into(&u, &mut q_new, &mut rng_new);
            let m_ref = r::qsgd_compress_ref(levels, &u, &mut q_ref, &mut rng_ref);
            let ctx = format!("qsgd levels={levels} n={n}");
            assert_eq!(m_new.to_bytes(), m_ref.to_bytes(), "{ctx}: wire bytes");
            assert_bits_eq(&q_new, &q_ref, &format!("{ctx}: q"));
            for _ in 0..4 {
                assert_eq!(rng_new.gen_u32(), rng_ref.gen_u32(), "{ctx}: rng sequence");
            }
            check_ranges(
                &m_new,
                n,
                &|m, s, o| qs.decompress_range(m, s, o),
                &r::qsgd_decompress_range_ref,
                &ctx,
            );
        }
    }
}

#[test]
fn terngrad_matches_reference_and_rng_sequence() {
    for &n in LENGTHS {
        for seed in 0..3u64 {
            let u = vals(seed, n, 0.4);
            let mut q_new = vec![0.0f32; n];
            let mut q_ref = vec![0.0f32; n];
            let mut rng_new = seeded_rng(9, seed * 1000 + n as u64);
            let mut rng_ref = seeded_rng(9, seed * 1000 + n as u64);
            let m_new = TernGrad.compress_into(&u, &mut q_new, &mut rng_new);
            let m_ref = r::terngrad_compress_ref(&u, &mut q_ref, &mut rng_ref);
            let ctx = format!("terngrad n={n} seed={seed}");
            assert_eq!(m_new.to_bytes(), m_ref.to_bytes(), "{ctx}: wire bytes");
            assert_bits_eq(&q_new, &q_ref, &format!("{ctx}: q"));
            for _ in 0..4 {
                assert_eq!(rng_new.gen_u32(), rng_ref.gen_u32(), "{ctx}: rng sequence");
            }
            check_ranges(
                &m_new,
                n,
                &|m, s, o| TernGrad.decompress_range(m, s, o),
                &r::terngrad_decompress_range_ref,
                &ctx,
            );
        }
    }
}

#[test]
fn wquant_matches_reference() {
    for &kx in &[0u32, 1, 6, 14, 22] {
        let wq = WQuant::new(kx);
        for &n in LENGTHS {
            let u = vals(kx as u64, n, 1.2); // wide enough to hit the clamp
            let mut q_new = vec![0.0f32; n];
            let mut q_ref = vec![0.0f32; n];
            let mut rng = seeded_rng(0, 0); // unused: deterministic codec
            let m_new = wq.compress_into(&u, &mut q_new, &mut rng);
            let m_ref = r::wquant_compress_ref(kx, &u, &mut q_ref);
            let ctx = format!("wquant kx={kx} n={n}");
            assert_eq!(m_new.to_bytes(), m_ref.to_bytes(), "{ctx}: wire bytes");
            assert_bits_eq(&q_new, &q_ref, &format!("{ctx}: q"));
            check_ranges(
                &m_new,
                n,
                &|m, s, o| wq.decompress_range(m, s, o),
                &|m, s, o| r::wquant_decompress_range_ref(kx, m, s, o),
                &ctx,
            );
        }
    }
}

#[test]
fn blockwise_matches_reference() {
    for &block in &[1usize, 3, 7, 4096] {
        let bw = Blockwise::new(block);
        for &n in LENGTHS {
            let u = vals(block as u64, n, 0.6);
            let mut q_new = vec![0.0f32; n];
            let mut q_ref = vec![0.0f32; n];
            let mut rng = seeded_rng(0, 0); // unused: deterministic codec
            let m_new = bw.compress_into(&u, &mut q_new, &mut rng);
            let m_ref = r::blockwise_compress_ref(block, &u, &mut q_ref);
            let ctx = format!("blockwise block={block} n={n}");
            assert_eq!(m_new.to_bytes(), m_ref.to_bytes(), "{ctx}: wire bytes");
            assert_bits_eq(&q_new, &q_ref, &format!("{ctx}: q"));
            check_ranges(
                &m_new,
                n,
                &|m, s, o| bw.decompress_range(m, s, o),
                &|m, s, o| r::blockwise_decompress_range_ref(block, m, s, o),
                &ctx,
            );
        }
    }
}

/// Identity has no rewritten kernel, but its fused-add path feeds the
/// same server loop — pin it against scratch-then-add too.
#[test]
fn identity_add_matches_scratch_then_add() {
    for &n in LENGTHS {
        let u = vals(1, n, 2.0);
        let mut q = vec![0.0f32; n];
        let msg = Identity.compress_into(&u, &mut q, &mut seeded_rng(0, 0));
        check_ranges(
            &msg,
            n,
            &|m, s, o| Identity.decompress_range(m, s, o),
            &|m, s, o| Identity.decompress_range(m, s, o),
            &format!("identity n={n}"),
        );
    }
}
