//! Integration: the PJRT runtime against the real AOT artifacts.
//!
//! Requires `make artifacts` to have run (skips with a message if not —
//! CI always builds artifacts first via the Makefile).

use qadam::data::{Dataset, SyntheticVector};
use qadam::models::{artifacts_dir, Manifest};
use qadam::optim::{LrSchedule, QAdamEf, ThetaSchedule, WorkerOpt};
use qadam::quant::seeded_rng;
use qadam::runtime::kernel::{PjrtQAdam, StepScalars};
use qadam::runtime::{KernelQAdam, ModelRuntime, Runtime};
use std::sync::Arc;

fn setup() -> Option<(Arc<Runtime>, Manifest, std::path::PathBuf)> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    Some((rt, manifest, dir))
}

fn rand_vec(seed: u64, n: usize, scale: f32) -> Vec<f32> {
    let mut rng = qadam::util::DetRng::seed_stream(seed, 0);
    (0..n).map(|_| scale * rng.gen_normal()).collect()
}

#[test]
fn grad_graph_runs_and_is_finite() {
    let Some((rt, manifest, dir)) = setup() else { return };
    let model = ModelRuntime::load(&rt, &dir, &manifest, "mlp").unwrap();
    let data = SyntheticVector::new(64, 10, 0);
    let flat = model.init_flat(0);
    let batch = data.train_batch(0, 0, model.meta.train_x.shape[0]);
    let (loss, grad) = model.loss_grad(&flat, &batch).unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
    assert_eq!(grad.len(), model.dim());
    assert!(grad.iter().all(|g| g.is_finite()));
    let gnorm: f32 = grad.iter().map(|g| g * g).sum::<f32>().sqrt();
    assert!(gnorm > 1e-6, "gradient should be nonzero");
}

#[test]
fn grad_matches_finite_difference_on_loss() {
    // Directional finite difference of the AOT loss should match <g, d>.
    let Some((rt, manifest, dir)) = setup() else { return };
    let model = ModelRuntime::load(&rt, &dir, &manifest, "mlp").unwrap();
    let data = SyntheticVector::new(64, 10, 0);
    let flat = model.init_flat(3);
    let batch = data.train_batch(0, 0, model.meta.train_x.shape[0]);
    let (_, grad) = model.loss_grad(&flat, &batch).unwrap();
    let dir_vec = rand_vec(5, model.dim(), 1.0);
    let h = 1e-3f32;
    let norm: f32 = dir_vec.iter().map(|d| d * d).sum::<f32>().sqrt();
    let dir_vec: Vec<f32> = dir_vec.iter().map(|d| d / norm).collect();
    let xp: Vec<f32> = flat.iter().zip(&dir_vec).map(|(x, d)| x + h * d).collect();
    let xm: Vec<f32> = flat.iter().zip(&dir_vec).map(|(x, d)| x - h * d).collect();
    let (lp, _) = model.loss_grad(&xp, &batch).unwrap();
    let (lm, _) = model.loss_grad(&xm, &batch).unwrap();
    let fd = (lp - lm) / (2.0 * h);
    let analytic: f32 = grad.iter().zip(&dir_vec).map(|(g, d)| g * d).sum();
    assert!(
        (fd - analytic).abs() < 2e-2 * analytic.abs().max(0.1),
        "fd={fd} analytic={analytic}"
    );
}

#[test]
fn pallas_kernel_matches_native_qadam() {
    // The flagship cross-layer check: the AOT Pallas kernel (L1, via
    // PJRT) and the pure-Rust fused loop produce the same moments,
    // quantized delta and residual.
    let Some((rt, manifest, dir)) = setup() else { return };
    let kernel = Arc::new(KernelQAdam::load(&rt, &dir, &manifest).unwrap());
    // cover: exact multiple of chunk and a ragged tail
    for &n in &[kernel.chunk, kernel.chunk / 2 + 1234] {
        let mut m = rand_vec(1, n, 0.01);
        let mut v: Vec<f32> = rand_vec(2, n, 0.001).iter().map(|x| x.abs()).collect();
        let g = rand_vec(3, n, 0.5);
        let mut e = rand_vec(4, n, 0.001);
        let (m0, v0, e0) = (m.clone(), v.clone(), e.clone());
        let s = StepScalars { alpha: 1e-3, beta: 0.99, theta: 0.999, eps: 1e-5, qlo: 0.25 };
        let mut qdelta = vec![0.0; n];
        kernel.step(&mut m, &mut v, &g, &mut e, s, &mut qdelta).unwrap();

        // native reference on the same chunking
        let lq = qadam::quant::LogQuant::new(2);
        let mut off = 0;
        let mut mism = 0usize;
        while off < n {
            let len = (n - off).min(kernel.chunk);
            let (beta, theta) = (0.99f32, 0.999f32);
            for i in off..off + len {
                // NB: compute (1-beta)/(1-theta) exactly as the kernel
                // does (from the f32 scalars), not as decimal literals.
                let mm = beta * m0[i] + (1.0 - beta) * g[i];
                let vv = theta * v0[i] + (1.0 - theta) * g[i] * g[i];
                assert!((m[i] - mm).abs() <= 1e-5 * mm.abs().max(1e-3), "m mismatch at {i}");
                assert!((v[i] - vv).abs() <= 1e-5 * vv.abs().max(1e-5), "v mismatch at {i}");
            }
            // quantized delta: recompute u and quantize natively
            let u: Vec<f32> = (off..off + len)
                .map(|i| 1e-3 * m[i] / (v[i] + 1e-5).sqrt() + e0[i])
                .collect();
            let mut qn = vec![0.0; len];
            let mut codes = Vec::new();
            lq.quantize(&u, &mut qn, &mut codes);
            for i in 0..len {
                // identical up to a possible 1-ulp log2 boundary flip
                if (qdelta[off + i] - qn[i]).abs() > 1e-6 * qn[i].abs().max(1e-7) {
                    mism += 1;
                }
                // EF identity must hold exactly as computed by the kernel
                let r = qdelta[off + i] + e[off + i];
                assert!((r - u[i]).abs() <= 1e-5 * u[i].abs().max(1e-4), "EF identity at {i}");
            }
            off += len;
        }
        let rate = mism as f64 / n as f64;
        assert!(rate < 1e-3, "quantized-delta mismatch rate {rate} (n={n})");
    }
}

#[test]
fn pjrt_worker_opt_decodes_identically() {
    // PjrtQAdam's wire message must decode to exactly its local qdelta.
    let Some((rt, manifest, dir)) = setup() else { return };
    let kernel = Arc::new(KernelQAdam::load(&rt, &dir, &manifest).unwrap());
    let n = kernel.chunk + 777; // multi-chunk with ragged tail
    let mut opt = PjrtQAdam::new(kernel, n, 2, LrSchedule::Const { alpha: 1e-2 });
    let mut rng = seeded_rng(0, 0);
    for t in 1..=3 {
        let g = rand_vec(10 + t, n, 0.3);
        let msg = opt.step(&g, t, 0, &mut rng);
        let mut dec = vec![0.0; n];
        msg.decode(&mut dec);
        // Residual identity: decoded delta + e' == u; we can't see u here,
        // but decoded delta must be a valid LogQuant codebook vector and
        // finite.
        assert!(dec.iter().all(|x| x.is_finite()));
        let nz = dec.iter().filter(|&&x| x != 0.0).count();
        assert!(nz > 0, "t={t}: all-zero delta");
    }
}

#[test]
fn native_and_pjrt_training_converge_similarly() {
    // Same seed, same data: after 15 steps both engines reach a loss in
    // the same ballpark (they are the same algorithm; tiny divergence
    // from per-chunk scale & f32 is amplified by training, so compare
    // coarse outcomes, not trajectories).
    let Some((rt, manifest, dir)) = setup() else { return };
    let model = Arc::new(ModelRuntime::load(&rt, &dir, &manifest, "mlp").unwrap());
    let data = SyntheticVector::new(64, 10, 0);
    let run = |use_pjrt: bool| -> f32 {
        let dim = model.dim();
        let mut opt: Box<dyn WorkerOpt> = if use_pjrt {
            let kernel = Arc::new(KernelQAdam::load(&rt, &dir, &manifest).unwrap());
            Box::new(PjrtQAdam::new(kernel, dim, 2, LrSchedule::Const { alpha: 5e-3 }))
        } else {
            Box::new(QAdamEf::new(
                dim,
                Box::new(qadam::quant::LogQuant::new(2)),
                true,
                LrSchedule::Const { alpha: 5e-3 },
                ThetaSchedule::Const { theta: 0.999 },
                0.99,
                1e-5,
            ))
        };
        let mut x = model.init_flat(0);
        let mut rng = seeded_rng(0, 0);
        let mut last = f32::NAN;
        for t in 1..=15 {
            let batch = data.train_batch(0, t, model.meta.train_x.shape[0]);
            let (loss, grad) = model.loss_grad(&x, &batch).unwrap();
            last = loss;
            let msg = opt.step(&grad, t, 0, &mut rng);
            let mut delta = vec![0.0; dim];
            msg.decode(&mut delta);
            for (xi, d) in x.iter_mut().zip(&delta) {
                *xi -= d;
            }
        }
        last
    };
    let l_native = run(false);
    let l_pjrt = run(true);
    assert!(l_native.is_finite() && l_pjrt.is_finite());
    assert!(
        (l_native - l_pjrt).abs() < 0.25 * l_native.max(0.2),
        "native={l_native} pjrt={l_pjrt}"
    );
}

#[test]
fn eval_graph_accuracy_improves_with_training() {
    let Some((rt, manifest, dir)) = setup() else { return };
    let model = Arc::new(ModelRuntime::load(&rt, &dir, &manifest, "mlp").unwrap());
    let data = SyntheticVector::new(64, 10, 0);
    let mut x = model.init_flat(0);
    let acc0 = model.accuracy(&x, &data, 2).unwrap();
    let mut opt =
        QAdamEf::paper_default(model.dim(), 2, LrSchedule::Const { alpha: 5e-3 });
    let mut rng = seeded_rng(0, 0);
    for t in 1..=40 {
        let batch = data.train_batch(0, t, model.meta.train_x.shape[0]);
        let (_, grad) = model.loss_grad(&x, &batch).unwrap();
        let msg = opt.step(&grad, t, 0, &mut rng);
        let mut delta = vec![0.0; model.dim()];
        msg.decode(&mut delta);
        for (xi, d) in x.iter_mut().zip(&delta) {
            *xi -= d;
        }
    }
    let acc1 = model.accuracy(&x, &data, 2).unwrap();
    assert!(acc1 > acc0 + 0.3, "acc {acc0} -> {acc1}");
}

#[test]
fn pjrt_engine_with_delta_downlink_trains_and_cuts_down_bytes() {
    // The compressed downlink composed with the Pallas-kernel worker
    // engine: still trains, downlink ≥4x smaller than full fp32
    // broadcasts, uplink accounting untouched.
    if setup().is_none() {
        return;
    }
    use qadam::coordinator::config::{BusKind, Downlink, Engine, ExperimentConfig, Method};
    use qadam::coordinator::Trainer;
    let cfg = ExperimentConfig {
        model: "mlp".into(),
        dataset: "vector".into(),
        method: Method::QAdam { kg: Some(2), error_feedback: true },
        kx: None,
        workers: 2,
        batch: 16,
        steps: 20,
        steps_per_epoch: 20,
        lr: LrSchedule::Const { alpha: 2e-3 },
        engine: Engine::PjrtKernel,
        bus: BusKind::Sequential,
        downlink: Downlink::Delta,
        resync_every: 8,
        chaos: None,
        codec_policy: qadam::quant::PolicySpec::Static,
        shards: 1,
        straggler: qadam::elastic::StragglerPolicy::Wait,
        min_participation: 1,
        async_rounds: false,
        staleness: 0,
        staleness_down_weight: false,
        cohort: None,
        registry: 100_000,
        seed: 0,
        eval_every: 0,
        eval_batches: 2,
    };
    let mut full_cfg = cfg.clone();
    full_cfg.downlink = Downlink::Full;
    let delta = Trainer::new(cfg).unwrap().run().unwrap();
    let full = Trainer::new(full_cfg).unwrap().run().unwrap();
    assert!(delta.final_loss.is_finite(), "loss={}", delta.final_loss);
    assert!(delta.final_acc > 0.3, "acc={}", delta.final_acc);
    let ratio = full.down_mb_per_iter / delta.down_mb_per_iter;
    assert!(ratio >= 4.0, "down-bytes reduction only {ratio:.2}x");
    assert_eq!(full.comm_mb_per_iter, delta.comm_mb_per_iter);
}

#[test]
fn wquant_artifact_matches_rust_wquant() {
    // The AOT wquant graph and the Rust WQuant must agree elementwise.
    let Some((rt, manifest, dir)) = setup() else { return };
    let graph = rt.load(&dir.join(&manifest.optimizer.wquant_artifact)).unwrap();
    let chunk = manifest.optimizer.chunk;
    let x = rand_vec(9, chunk, 0.3);
    let inputs = vec![
        qadam::runtime::literal_f32(&x, &[chunk]).unwrap(),
        qadam::runtime::literal_scalar(16.0), // kx = 4 -> 2^4 levels
    ];
    let outs = graph.run(&inputs).unwrap();
    let got = outs[0].to_vec::<f32>().unwrap();
    let wq = qadam::quant::WQuant::new(4);
    let mut want = vec![0.0; chunk];
    wq.quantize_into(&x, &mut want);
    let mism = got.iter().zip(&want).filter(|(a, b)| a != b).count();
    // round-half cases could differ at exact .5 boundaries (measure-zero
    // for random normals) — require exact match here.
    assert_eq!(mism, 0, "wquant mismatch count {mism}");
}
