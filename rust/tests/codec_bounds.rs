//! Property tests for the codecs' theoretical error bounds, and for the
//! adaptive controller's band/purity contract, on randomized inputs.
//!
//! Each codec documents (or implies) a per-coordinate worst case; these
//! tests pin them so a quantizer change that silently loosens a bound
//! fails here, not three layers up in a convergence plateau:
//!
//! * `Q_g` (LogQuant, nearest power of two):
//!   `|u − Q(u)|_i ≤ max(s·2^-(kg+1), |u_i|/2)` — the zero region is
//!   below `s·2^-(kg+1)`, and inside a bracket `[2^m, 2^(m+1})` the
//!   nearest endpoint is at most half the gap (`2^(m-1) ≤ |y|/2`) away.
//! * stochastic log: rounding to *either* bracket endpoint —
//!   `≤ max(s·2^-kg, |u_i|)` (full gap, or the smallest level).
//! * `Q_x` (WQuant): `≤ 2^-(kx+2)` inside the representable
//!   `|x| ≤ 0.5` (Assumption 3).
//! * TernGrad: values are `{0, ±s}` with matching sign —
//!   `≤ s = ‖u‖_∞`.
//! * Blockwise sign·mean: `|u_i − sign(u_i)·s_b| ≤ max(|u_i|, s_b) ≤ s`.
//! * QSGD(L): stochastic rounding between adjacent uniform levels —
//!   `≤ s/L`.

use qadam::optim::{LrSchedule, QAdamEf, WorkerOpt};
use qadam::quant::{
    seeded_rng, Blockwise, CodecPolicy, Compressor, DeltaMsg, Identity, LogQuant, PolicySpec,
    Qsgd, StochasticLogQuant, TensorLayout, TernGrad, WQuant,
};

fn rand_vec(seed: u64, n: usize, scale: f32) -> Vec<f32> {
    let mut rng = seeded_rng(seed, 0xb0);
    (0..n).map(|_| rng.gen_range_f32(-scale, scale)).collect()
}

/// Run `comp` over randomized inputs and check the per-coordinate bound
/// `|u_i − q_i| ≤ bound(s, |u_i|) + tol`.
fn check_bound(
    name: &str,
    comp: &dyn Compressor,
    scale: f32,
    bound: impl Fn(f32, f32) -> f32,
) {
    for seed in 0..6u64 {
        let u = rand_vec(seed * 31 + 1, 257, scale);
        let s = u.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let mut q = vec![0.0; u.len()];
        let mut rng = seeded_rng(seed, 9);
        let msg = comp.compress_into(&u, &mut q, &mut rng);
        assert_eq!(msg.n, u.len());
        let tol = 1e-5 * s.max(1e-30);
        for (i, (&ui, &qi)) in u.iter().zip(&q).enumerate() {
            let err = (ui - qi).abs();
            let b = bound(s, ui.abs());
            assert!(
                err <= b + tol,
                "{name} seed={seed} i={i}: |{ui} - {qi}| = {err} > bound {b}"
            );
        }
    }
}

#[test]
fn identity_is_exact() {
    check_bound("identity", &Identity, 3.0, |_, _| 0.0);
}

#[test]
fn logquant_inf_bound_across_levels() {
    for kg in [0u32, 1, 2, 4, 8] {
        let comp = LogQuant::new(kg);
        let zero_region = f32::exp2(-((kg + 1) as f32));
        for scale in [1e-3f32, 1.0, 1e3] {
            check_bound(&format!("logquant kg={kg}"), &comp, scale, |s, ui| {
                (s * zero_region).max(ui / 2.0)
            });
        }
    }
}

#[test]
fn stochastic_logquant_inf_bound() {
    for kg in [0u32, 2, 4] {
        let comp = StochasticLogQuant::new(kg);
        let lo = f32::exp2(-(kg as f32));
        check_bound(&format!("stoch-log kg={kg}"), &comp, 1.0, |s, ui| (s * lo).max(ui));
    }
}

#[test]
fn wquant_assumption3_bound_inside_range() {
    for kx in [1u32, 2, 6, 10] {
        let comp = WQuant::new(kx);
        let delta = comp.delta_x_per_coord();
        // restrict to the representable range |x| <= 0.5
        check_bound(&format!("wquant kx={kx}"), &comp, 0.5, |_, _| delta);
    }
}

#[test]
fn terngrad_inf_bound() {
    check_bound("terngrad", &TernGrad, 2.0, |s, _| s);
}

#[test]
fn blockwise_inf_bound() {
    for block in [3usize, 64, 4096] {
        check_bound(&format!("blockwise b={block}"), &Blockwise::new(block), 2.0, |s, _| s);
    }
}

#[test]
fn qsgd_inf_bound() {
    for levels in [1u32, 4, 16] {
        let comp = Qsgd::new(levels);
        check_bound(&format!("qsgd L={levels}"), &comp, 5.0, |s, _| s / levels as f32);
    }
}

// ---------------------------------------------------------------------------
// adaptive-controller properties, end to end through the optimizer
// ---------------------------------------------------------------------------

/// Drive a full adaptive QAdam-EF optimizer on random gradients: the
/// chosen levels never leave the configured band, every part's wire
/// header carries exactly the chosen level, and two identical runs
/// produce byte-identical uplinks — the decision layer is a pure
/// function of `(seed, t, tensor)`, nothing else.
#[test]
fn adaptive_controller_stays_in_band_and_is_pure() {
    let dim = 48;
    let (lo, hi) = (1u32, 4u32);
    let run = |seed: u64| -> Vec<(Vec<u32>, Vec<Vec<u8>>)> {
        let layout = TensorLayout::uniform(dim, 3);
        let policy =
            CodecPolicy::new(PolicySpec::Adaptive { lo, hi }, layout, 2).unwrap();
        let mut opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.05 })
            .with_policy(policy);
        let mut rng = seeded_rng(seed, 1);
        let mut grad_rng = seeded_rng(seed, 2);
        let mut trace = Vec::new();
        for t in 1u64..=60 {
            // gradients with a tensor-dependent magnitude profile so the
            // controller has something to react to
            let g: Vec<f32> = (0..dim)
                .map(|i| grad_rng.gen_normal() * (0.01 + 0.1 * (i / 16) as f32))
                .collect();
            let msg = opt.step(&g, t, 0, &mut rng);
            let bits = opt.chosen_bits().expect("adaptive policy reports levels").to_vec();
            assert!(
                bits.iter().all(|&b| (lo..=hi).contains(&b)),
                "t={t}: levels {bits:?} left the band {lo}..{hi}"
            );
            match &msg {
                DeltaMsg::Parts(parts) => {
                    assert_eq!(parts.len(), 3);
                    for (p, &b) in parts.iter().zip(&bits) {
                        assert_eq!(p.param, b, "t={t}: header level != chosen level");
                    }
                    trace.push((bits, parts.iter().map(|p| p.to_bytes()).collect()));
                }
                other => panic!("adaptive policy must emit parts, got {other:?}"),
            }
        }
        trace
    };
    let a = run(11);
    assert_eq!(a, run(11), "fixed seed must reproduce decisions and bytes exactly");
    // (That the controller *moves* under debt/idle pressure is pinned by
    // the unit tests in `quant::policy`; here the property under test is
    // band confinement + reproducibility on a live optimizer.)
}
