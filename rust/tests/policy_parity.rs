//! Cross-engine differential tests for the codec-policy layer.
//!
//! The acceptance contract of the adaptive per-tensor bit-width change:
//!
//! * a fixed-seed `adaptive` run is **bit-identical** across the
//!   sequential, threaded and TCP engines — masters, replicas,
//!   per-round chosen bits, reply bytes and `CommStats`;
//! * it survives a chaos crash/rejoin cycle with replica parity
//!   (forced full-weights resync re-anchors the returning worker);
//! * `--codec-policy static` (the default) leaves every existing path
//!   bit-identical to the pre-policy build: same single-message frames,
//!   byte for byte.

use qadam::elastic::{ChaosPlan, ChaosTransport, StragglerPolicy};
use qadam::optim::{LrSchedule, QAdamEf};
use qadam::ps::transport::{tcp_worker_loop, LocalBus, TcpServer, ThreadedBus, Transport};
use qadam::ps::worker::{SimGradSource, Worker};
use qadam::ps::{ParameterServer, ToServer, ToWorker};
use qadam::quant::{CodecPolicy, LogQuant, PolicySpec, TensorLayout};
use qadam::sim::StochasticProblem;

const DIM: usize = 96;
const TENSORS: usize = 3;

fn adaptive_spec() -> PolicySpec {
    PolicySpec::Adaptive { lo: 0, hi: 4 }
}

/// Mixed sparse per-layer spec over the uniform `b0,b1,b2` layout: a
/// global top-k tensor, a blockwise top-k tensor and a dense LogQuant
/// tensor in one frame stream.
fn mixed_sparse_spec() -> PolicySpec {
    PolicySpec::parse("per-layer:b0=topk@0.05,b1=sblock@16x2,b2=2").unwrap()
}

fn adaptive_topk_spec() -> PolicySpec {
    PolicySpec::parse("adaptive-topk:0.01..0.25").unwrap()
}

fn mk_policy(spec: PolicySpec) -> CodecPolicy {
    CodecPolicy::new(spec, TensorLayout::uniform(DIM, TENSORS), 2).unwrap()
}

/// Worker construction shared by every engine (and both ends of the
/// TCP leg): identical state ⇒ any divergence is the engine's fault.
fn mk_worker(id: u32, spec: Option<PolicySpec>) -> Worker {
    let src = SimGradSource { problem: StochasticProblem::new(DIM, 0.05, 9) };
    let mut opt = QAdamEf::paper_default(DIM, 2, LrSchedule::Const { alpha: 0.02 });
    if let Some(s) = spec {
        opt = opt.with_policy(mk_policy(s));
    }
    Worker::new(id, Box::new(opt), Box::new(src), 1)
}

fn mk_ps_with(spec: PolicySpec) -> ParameterServer {
    let x0: Vec<f32> = (0..DIM).map(|i| 0.3 + 0.01 * (i as f32).sin()).collect();
    let mut ps = ParameterServer::new(x0, Some(4));
    ps.enable_delta_downlink(Box::new(LogQuant::new(2)), 5);
    ps.set_downlink_policy(mk_policy(spec));
    ps
}

fn mk_ps_with_policy() -> ParameterServer {
    mk_ps_with(adaptive_spec())
}

fn reply_bytes(replies: &[ToServer]) -> Vec<Vec<u8>> {
    replies.iter().map(|r| r.to_bytes()).collect()
}

/// Sequential vs threaded, both with the adaptive uplink policy and the
/// adaptive delta-downlink policy: every broadcast frame, every reply
/// frame, every chosen level, the masters, the replicas and the byte
/// accounting agree round by round.
#[test]
fn adaptive_run_bit_identical_sequential_vs_threaded() {
    let nw = 4usize;
    let mut ps_seq = mk_ps_with_policy();
    let mut ws_seq: Vec<Worker> = (0..nw as u32).map(|i| mk_worker(i, Some(adaptive_spec()))).collect();
    let seq = LocalBus::default();
    let mut ps_thr = mk_ps_with_policy();
    let mut ws_thr: Vec<Worker> = (0..nw as u32).map(|i| mk_worker(i, Some(adaptive_spec()))).collect();
    let thr = ThreadedBus::new();
    let mut saw_parts_uplink = false;
    let mut saw_parts_downlink = false;
    for t in 1u64..=20 {
        let (b_seq, _) = ps_seq.broadcast(nw);
        let (b_thr, _) = ps_thr.broadcast(nw);
        assert_eq!(b_seq.to_bytes(), b_thr.to_bytes(), "broadcast diverged at round {t}");
        saw_parts_downlink |= matches!(b_seq, ToWorker::WeightsDeltaParts { .. });
        let r_seq = seq.round(&b_seq, &mut ws_seq).unwrap();
        let r_thr = thr.round(&b_thr, &mut ws_thr).unwrap();
        assert_eq!(
            reply_bytes(&r_seq),
            reply_bytes(&r_thr),
            "uplink frames diverged at round {t}"
        );
        saw_parts_uplink |= r_seq.iter().all(|r| matches!(r, ToServer::DeltaParts { .. }));
        ps_seq.apply(&r_seq).unwrap();
        ps_thr.apply(&r_thr).unwrap();
        assert_eq!(ps_seq.master(), ps_thr.master(), "masters diverged at round {t}");
        assert_eq!(
            ps_seq.downlink_state().unwrap().0,
            ps_thr.downlink_state().unwrap().0,
            "replicas diverged at round {t}"
        );
        // per-round chosen bits: every worker, plus the server downlink
        for (a, b) in ws_seq.iter().zip(&ws_thr) {
            assert_eq!(
                a.chosen_bits().expect("adaptive worker reports levels"),
                b.chosen_bits().unwrap(),
                "worker {} levels diverged at round {t}",
                a.id
            );
        }
        assert_eq!(
            ps_seq.downlink_chosen_bits().unwrap(),
            ps_thr.downlink_chosen_bits().unwrap(),
            "downlink levels diverged at round {t}"
        );
    }
    assert_eq!(ps_seq.stats, ps_thr.stats, "CommStats diverged");
    assert!(saw_parts_uplink, "the adaptive uplink never produced parts frames");
    assert!(saw_parts_downlink, "the adaptive downlink never produced parts frames");
}

/// The TCP engine replays the same adaptive trajectory bit-for-bit:
/// reply frames off the socket equal the in-process reference, masters
/// and replicas track, and the byte accounting agrees.
#[test]
fn adaptive_run_bit_identical_over_tcp() {
    let rounds = 12u64;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener);

    let spawn_worker = |addr: String, id: u32| {
        std::thread::spawn(move || {
            let mut w = mk_worker(id, Some(adaptive_spec()));
            for _ in 0..100 {
                match tcp_worker_loop(&addr, &mut w) {
                    Ok(r) => return r,
                    Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
                }
            }
            panic!("worker {id} never connected");
        })
    };
    let h0 = spawn_worker(addr.clone(), 0);
    let h1 = spawn_worker(addr.clone(), 1);

    let mut srv = TcpServer::bind_and_accept(&addr, 2).unwrap();
    let mut ps_tcp = mk_ps_with_policy();
    let mut ps_ref = mk_ps_with_policy();
    let mut ws_ref: Vec<Worker> = (0..2).map(|i| mk_worker(i, Some(adaptive_spec()))).collect();
    let bus = LocalBus::default();
    for t in 1..=rounds {
        let replies = {
            let (b, _) = ps_tcp.broadcast(2);
            srv.round(&b).unwrap()
        };
        let r_ref = {
            let (b, _) = ps_ref.broadcast(2);
            bus.round(&b, &mut ws_ref).unwrap()
        };
        assert_eq!(
            reply_bytes(&replies),
            reply_bytes(&r_ref),
            "tcp uplink frames diverged at round {t}"
        );
        ps_tcp.apply(&replies).unwrap();
        ps_ref.apply(&r_ref).unwrap();
        assert_eq!(ps_tcp.master(), ps_ref.master(), "tcp master diverged at round {t}");
        assert_eq!(
            ps_tcp.downlink_state().unwrap().0,
            ps_ref.downlink_state().unwrap().0,
            "tcp replica diverged at round {t}"
        );
        assert_eq!(
            ps_tcp.downlink_chosen_bits().unwrap(),
            ps_ref.downlink_chosen_bits().unwrap(),
            "tcp downlink levels diverged at round {t}"
        );
    }
    assert_eq!(ps_tcp.stats, ps_ref.stats, "CommStats diverged over TCP");
    srv.shutdown().unwrap();
    assert_eq!(h0.join().unwrap(), rounds);
    assert_eq!(h1.join().unwrap(), rounds);
}

/// Sparse specs get the same cross-engine guarantee as the dense
/// adaptive policy: a fixed-seed run with sparse codecs on **both**
/// directions — mixed `topk`/`sblock`/dense per-layer rules, and the
/// adaptive-topk density controller — is bit-identical between the
/// sequential and threaded engines, down to the frames, the chosen
/// densities and the byte accounting.
#[test]
fn sparse_policy_run_bit_identical_sequential_vs_threaded() {
    let nw = 4usize;
    for spec in [mixed_sparse_spec(), adaptive_topk_spec()] {
        let mut ps_seq = mk_ps_with(spec.clone());
        let mut ws_seq: Vec<Worker> =
            (0..nw as u32).map(|i| mk_worker(i, Some(spec.clone()))).collect();
        let seq = LocalBus::default();
        let mut ps_thr = mk_ps_with(spec.clone());
        let mut ws_thr: Vec<Worker> =
            (0..nw as u32).map(|i| mk_worker(i, Some(spec.clone()))).collect();
        let thr = ThreadedBus::new();
        let label = spec.label();
        for t in 1u64..=16 {
            let (b_seq, _) = ps_seq.broadcast(nw);
            let (b_thr, _) = ps_thr.broadcast(nw);
            assert_eq!(
                b_seq.to_bytes(),
                b_thr.to_bytes(),
                "{label}: broadcast diverged at round {t}"
            );
            let r_seq = seq.round(&b_seq, &mut ws_seq).unwrap();
            let r_thr = thr.round(&b_thr, &mut ws_thr).unwrap();
            assert_eq!(
                reply_bytes(&r_seq),
                reply_bytes(&r_thr),
                "{label}: uplink frames diverged at round {t}"
            );
            ps_seq.apply(&r_seq).unwrap();
            ps_thr.apply(&r_thr).unwrap();
            assert_eq!(ps_seq.master(), ps_thr.master(), "{label}: masters diverged at round {t}");
            assert_eq!(
                ps_seq.downlink_state().unwrap().0,
                ps_thr.downlink_state().unwrap().0,
                "{label}: replicas diverged at round {t}"
            );
            for (a, b) in ws_seq.iter().zip(&ws_thr) {
                assert_eq!(
                    a.chosen_bits().expect("sparse policy reports levels"),
                    b.chosen_bits().unwrap(),
                    "{label}: worker {} levels diverged at round {t}",
                    a.id
                );
            }
            assert_eq!(
                ps_seq.downlink_chosen_bits().unwrap(),
                ps_thr.downlink_chosen_bits().unwrap(),
                "{label}: downlink levels diverged at round {t}"
            );
        }
        assert_eq!(ps_seq.stats, ps_thr.stats, "{label}: CommStats diverged");
    }
    // The per-layer rules bind as spelled: topk@0.05 = 500/10000 kept
    // on b0, kb=2 on b1, dense level 2 on b2.
    let w = mk_worker(0, Some(mixed_sparse_spec()));
    assert_eq!(w.chosen_bits().unwrap(), [500, 2, 2]);
}

/// The TCP engine replays a fixed-seed **sparse-policy** trajectory
/// bit-for-bit against the in-process reference — mixed per-layer
/// topk/sblock/dense rules on both directions.
#[test]
fn sparse_policy_run_bit_identical_over_tcp() {
    let rounds = 10u64;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener);

    let spawn_worker = |addr: String, id: u32| {
        std::thread::spawn(move || {
            let mut w = mk_worker(id, Some(mixed_sparse_spec()));
            for _ in 0..100 {
                match tcp_worker_loop(&addr, &mut w) {
                    Ok(r) => return r,
                    Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
                }
            }
            panic!("worker {id} never connected");
        })
    };
    let h0 = spawn_worker(addr.clone(), 0);
    let h1 = spawn_worker(addr.clone(), 1);

    let mut srv = TcpServer::bind_and_accept(&addr, 2).unwrap();
    let mut ps_tcp = mk_ps_with(mixed_sparse_spec());
    let mut ps_ref = mk_ps_with(mixed_sparse_spec());
    let mut ws_ref: Vec<Worker> =
        (0..2).map(|i| mk_worker(i, Some(mixed_sparse_spec()))).collect();
    let bus = LocalBus::default();
    for t in 1..=rounds {
        let replies = {
            let (b, _) = ps_tcp.broadcast(2);
            srv.round(&b).unwrap()
        };
        let r_ref = {
            let (b, _) = ps_ref.broadcast(2);
            bus.round(&b, &mut ws_ref).unwrap()
        };
        assert_eq!(
            reply_bytes(&replies),
            reply_bytes(&r_ref),
            "tcp sparse uplink frames diverged at round {t}"
        );
        ps_tcp.apply(&replies).unwrap();
        ps_ref.apply(&r_ref).unwrap();
        assert_eq!(ps_tcp.master(), ps_ref.master(), "tcp sparse master diverged at round {t}");
        assert_eq!(
            ps_tcp.downlink_state().unwrap().0,
            ps_ref.downlink_state().unwrap().0,
            "tcp sparse replica diverged at round {t}"
        );
    }
    assert_eq!(ps_tcp.stats, ps_ref.stats, "CommStats diverged over TCP");
    srv.shutdown().unwrap();
    assert_eq!(h0.join().unwrap(), rounds);
    assert_eq!(h1.join().unwrap(), rounds);
}

/// Chaos crash/rejoin under the adaptive-topk density controller: the
/// forced rejoin resync re-anchors the returning worker, the
/// controller's per-tensor densities stay inside their band and agree
/// across engines, and the whole chaotic run is bit-reproducible.
#[test]
fn sparse_chaos_crash_rejoin_parity() {
    let nw = 3usize;
    let plan = ChaosPlan::parse("seed=5,crash=1@4..8").unwrap();
    let mk_stack = |inner: Box<dyn Transport>| -> (ParameterServer, Vec<Worker>, ChaosTransport) {
        let ps = mk_ps_with(adaptive_topk_spec());
        let ws: Vec<Worker> =
            (0..nw as u32).map(|i| mk_worker(i, Some(adaptive_topk_spec()))).collect();
        let bus = ChaosTransport::new(inner, plan.clone()).with_policy(StragglerPolicy::Drop, 1);
        (ps, ws, bus)
    };
    let (mut ps_a, mut ws_a, mut bus_a) = mk_stack(Box::new(LocalBus::default()));
    let (mut ps_b, mut ws_b, mut bus_b) = mk_stack(Box::new(ThreadedBus::new()));
    for t in 1u64..=12 {
        let m_a = bus_a.membership(t, nw);
        let m_b = bus_b.membership(t, nw);
        assert_eq!(m_a, m_b, "membership diverged at round {t}");
        if m_a.rejoined {
            ps_a.force_resync();
            ps_b.force_resync();
        }
        let r_a = {
            let (b, _) = ps_a.broadcast(m_a.present);
            if t == 8 {
                assert!(matches!(b, ToWorker::Weights { .. }), "rejoin round must resync");
            }
            bus_a.round(&b, &mut ws_a).unwrap()
        };
        let r_b = {
            let (b, _) = ps_b.broadcast(m_b.present);
            bus_b.round(&b, &mut ws_b).unwrap()
        };
        assert_eq!(reply_bytes(&r_a), reply_bytes(&r_b), "gather diverged at round {t}");
        let p_a = ps_a.apply(&r_a).unwrap();
        let p_b = ps_b.apply(&r_b).unwrap();
        assert_eq!(p_a, p_b, "participation diverged at round {t}");
        assert_eq!(ps_a.master(), ps_b.master(), "masters diverged at round {t}");
        let (replica, _) = ps_a.downlink_state().unwrap();
        assert_eq!(replica, ps_b.downlink_state().unwrap().0, "replicas diverged at round {t}");
        // chosen densities agree and never leave the 0.01..0.25 band
        for (a, b) in ws_a.iter().zip(&ws_b) {
            let d_a = a.chosen_bits().expect("adaptive-topk reports densities");
            assert_eq!(d_a, b.chosen_bits().unwrap(), "worker {} densities, round {t}", a.id);
            assert!(
                d_a.iter().all(|&d| (100..=2500).contains(&d)),
                "worker {} densities left the band at round {t}: {d_a:?}",
                a.id
            );
        }
        for w in &ws_a {
            if w.id == 1 && (4..8).contains(&t) {
                continue;
            }
            assert_eq!(w.weights(), replica, "worker {} != replica at round {t}", w.id);
        }
    }
    assert_eq!(bus_a.stats, bus_b.stats, "fault patterns diverged");
    assert_eq!(ps_a.stats, ps_b.stats);
    assert!(ps_a.stats.resyncs >= 2, "round 1 + the forced rejoin resync");
}

/// Acceptance: a fixed-seed adaptive run survives a chaos crash/rejoin
/// cycle — bit-reproducible across the sequential and threaded engines,
/// with the forced resync re-anchoring the returning worker's replica.
#[test]
fn adaptive_chaos_crash_rejoin_parity() {
    let nw = 3usize;
    let plan = ChaosPlan::parse("seed=5,crash=1@4..8").unwrap();
    let mk_stack = |inner: Box<dyn Transport>| -> (ParameterServer, Vec<Worker>, ChaosTransport) {
        let mut ps = mk_ps_with_policy();
        ps.force_resync(); // no-op guard: fresh server, round 1 resyncs anyway
        let ws: Vec<Worker> = (0..nw as u32).map(|i| mk_worker(i, Some(adaptive_spec()))).collect();
        let bus = ChaosTransport::new(inner, plan.clone()).with_policy(StragglerPolicy::Drop, 1);
        (ps, ws, bus)
    };
    let (mut ps_a, mut ws_a, mut bus_a) = mk_stack(Box::new(LocalBus::default()));
    let (mut ps_b, mut ws_b, mut bus_b) = mk_stack(Box::new(ThreadedBus::new()));
    for t in 1u64..=12 {
        let m_a = bus_a.membership(t, nw);
        let m_b = bus_b.membership(t, nw);
        assert_eq!(m_a, m_b, "membership diverged at round {t}");
        assert_eq!(m_a.rejoined, t == 8, "t={t}");
        if m_a.rejoined {
            ps_a.force_resync();
            ps_b.force_resync();
        }
        let r_a = {
            let (b, _) = ps_a.broadcast(m_a.present);
            if t == 8 {
                assert!(matches!(b, ToWorker::Weights { .. }), "rejoin round must resync");
            }
            bus_a.round(&b, &mut ws_a).unwrap()
        };
        let r_b = {
            let (b, _) = ps_b.broadcast(m_b.present);
            bus_b.round(&b, &mut ws_b).unwrap()
        };
        assert_eq!(reply_bytes(&r_a), reply_bytes(&r_b), "gather diverged at round {t}");
        let p_a = ps_a.apply(&r_a).unwrap();
        let p_b = ps_b.apply(&r_b).unwrap();
        assert_eq!(p_a, p_b, "participation diverged at round {t}");
        assert_eq!(ps_a.master(), ps_b.master(), "masters diverged at round {t}");
        let (replica, _) = ps_a.downlink_state().unwrap();
        assert_eq!(replica, ps_b.downlink_state().unwrap().0, "replicas diverged at round {t}");
        // live workers track the replica bit-exactly; the crashed one is
        // stale by design until its rejoin resync
        for w in &ws_a {
            if w.id == 1 && (4..8).contains(&t) {
                continue;
            }
            assert_eq!(w.weights(), replica, "worker {} != replica at round {t}", w.id);
        }
    }
    assert_eq!(bus_a.stats, bus_b.stats, "fault patterns diverged");
    assert_eq!(ps_a.stats, ps_b.stats);
    assert!(ps_a.stats.resyncs >= 2, "round 1 + the forced rejoin resync");
}

/// Acceptance: the default `static` policy leaves the pre-policy path
/// untouched — same single-message reply frames byte for byte, same
/// masters, same accounting — whether the policy object is absent or
/// bound with a static spec.
#[test]
fn static_policy_is_bit_identical_to_policy_free_path() {
    let nw = 3usize;
    let x0: Vec<f32> = (0..DIM).map(|i| 0.3 + 0.01 * (i as f32).sin()).collect();
    let run = |spec: Option<PolicySpec>| -> (Vec<Vec<Vec<u8>>>, Vec<f32>, u64, u64) {
        let mut ps = ParameterServer::new(x0.clone(), Some(4));
        let mut ws: Vec<Worker> = (0..nw as u32).map(|i| mk_worker(i, spec.clone())).collect();
        let bus = LocalBus::default();
        let mut frames = Vec::new();
        for _ in 1u64..=15 {
            let replies = {
                let (b, _) = ps.broadcast(nw);
                bus.round(&b, &mut ws).unwrap()
            };
            for r in &replies {
                assert!(
                    matches!(r, ToServer::Delta { .. }),
                    "static path must stay single-message"
                );
            }
            frames.push(reply_bytes(&replies));
            ps.apply(&replies).unwrap();
        }
        (frames, ps.master().to_vec(), ps.stats.up_bytes, ps.stats.down_bytes)
    };
    assert_eq!(
        run(None),
        run(Some(PolicySpec::Static)),
        "a static codec policy must not change a single byte"
    );
}
