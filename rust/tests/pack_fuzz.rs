//! Round-trip and hostile-input fuzzing for the bit-pack layer and the
//! wire frame parser.
//!
//! * arbitrary code sequences round-trip `pack` → `unpack` bit-exactly
//!   at every supported width (1..=32), every ragged length;
//! * truncated frames are rejected by [`WireMsg::from_bytes`] with an
//!   error — never a panic — at **every** prefix length;
//! * extended frames (trailing garbage) are rejected (exact-length
//!   contract);
//! * single-byte header corruptions either fail to parse or parse into
//!   a frame whose decode stays in bounds (the structural-consistency
//!   checks guarantee `decode_msg` cannot index out of range on
//!   anything `from_bytes` accepts — hostile `Packed` shapes are
//!   rejected at the wire boundary).

use qadam::quant::pack::{pack, unpack, unpack_range_into};
use qadam::quant::{
    decode_msg, seeded_rng, Blockwise, Compressor, Identity, LogQuant, Qsgd, SparseBlock,
    TernGrad, TopK, WQuant, WireMsg,
};

#[test]
fn pack_roundtrips_arbitrary_codes_at_every_width() {
    for bits in 1u8..=32 {
        let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
        for &n in &[0usize, 1, 2, 5, 21, 63, 64, 65, 127, 128, 129, 509, 2048] {
            for seed in 0..3u64 {
                let mut rng = seeded_rng(seed, ((bits as u64) << 32) | n as u64);
                let codes: Vec<u32> = (0..n).map(|_| rng.gen_u32() & mask).collect();
                let p = pack(&codes, bits);
                assert_eq!(unpack(&p), codes, "bits={bits} n={n} seed={seed}");
                // ragged range views round-trip too
                if n > 2 {
                    let (start, len) = (n / 3, n / 2);
                    let mut out = vec![0u32; len];
                    unpack_range_into(&p, start, &mut out);
                    assert_eq!(out, &codes[start..start + len], "bits={bits} n={n}");
                }
            }
        }
    }
}

/// One representative valid frame per codec (plus a multi-scale
/// LogQuant layout via Blockwise's many-scales shape).
fn sample_frames() -> Vec<(String, Vec<u8>)> {
    let n = 150;
    let mut rng = seeded_rng(13, 13);
    let u: Vec<f32> = (0..n).map(|_| 0.2 * (rng.gen_f32() - 0.5)).collect();
    let mut q = vec![0.0f32; n];
    let comps: Vec<(&str, Box<dyn Compressor>)> = vec![
        ("logquant", Box::new(LogQuant::new(2))),
        ("terngrad", Box::new(TernGrad)),
        ("blockwise", Box::new(Blockwise::new(16))),
        ("wquant", Box::new(WQuant::new(6))),
        ("qsgd", Box::new(Qsgd::new(4))),
        ("identity", Box::new(Identity)),
        // both TopK encodings: low density packs an index list, high
        // density a bitmap (n = 150 puts the crossover near d = 1/8)
        ("topk-index", Box::new(TopK::new(400))),
        ("topk-bitmap", Box::new(TopK::new(5000))),
        ("sparse-block", Box::new(SparseBlock::new(16, 3))),
    ];
    comps
        .iter()
        .map(|(name, c)| {
            let msg = c.compress_into(&u, &mut q, &mut seeded_rng(1, 1));
            (name.to_string(), msg.to_bytes())
        })
        .collect()
}

#[test]
fn truncated_frames_error_at_every_prefix_length() {
    for (name, frame) in sample_frames() {
        // round-trip sanity first
        let msg = WireMsg::from_bytes(&frame).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(msg.to_bytes(), frame, "{name}: canonical round-trip");
        for cut in 0..frame.len() {
            assert!(
                WireMsg::from_bytes(&frame[..cut]).is_err(),
                "{name}: prefix of {cut}/{} bytes must be rejected",
                frame.len()
            );
        }
    }
}

#[test]
fn extended_frames_are_rejected() {
    for (name, frame) in sample_frames() {
        for extra in [1usize, 4, 64] {
            let mut long = frame.clone();
            let want = long.len() + extra;
            long.resize(want, 0xAB);
            assert!(
                WireMsg::from_bytes(&long).is_err(),
                "{name}: {extra} trailing bytes must be rejected"
            );
        }
    }
}

/// Flip bytes across the whole header of every sample frame: the
/// parser must never panic, and anything it *accepts* must decode
/// without panicking (in-bounds words/scales by construction).
#[test]
fn corrupted_headers_never_panic_and_accepted_frames_stay_decodable() {
    for (_name, frame) in sample_frames() {
        for i in 0..22.min(frame.len()) {
            for flip in [0x01u8, 0x80, 0xFF] {
                let mut b = frame.clone();
                b[i] ^= flip;
                // parse may accept (payload-equivalent headers exist);
                // the property is: no panic here, and no panic decoding
                // whatever was accepted.
                if let Ok(msg) = WireMsg::from_bytes(&b) {
                    let mut out = vec![0.0f32; msg.n];
                    decode_msg(&msg, &mut out);
                    std::hint::black_box(&out);
                }
            }
        }
    }
}

/// Hostile `Packed` shapes — inflated or deflated word counts and
/// element counts that disagree with the codec layout — are rejected
/// at the wire boundary (this is what lets the decode kernels trust
/// `Packed::words` unconditionally).
#[test]
fn inconsistent_layout_counts_are_rejected() {
    let n = 100usize;
    let mut q = vec![0.0f32; n];
    let u: Vec<f32> = (0..n).map(|i| 0.01 * (i as f32).sin()).collect();
    let msg = LogQuant::new(2).compress_into(&u, &mut q, &mut seeded_rng(0, 0));
    let good = msg.to_bytes();
    let set_u32 = |b: &mut Vec<u8>, off: usize, v: u32| {
        b[off..off + 4].copy_from_slice(&v.to_le_bytes());
    };
    // nwords inflated: self-consistent length, wrong for the codec
    let mut b = good.clone();
    set_u32(&mut b, 14, 20);
    b.resize(22 + 4 + 20 * 8, 0);
    assert!(WireMsg::from_bytes(&b).is_err(), "inflated nwords must be rejected");
    // nwords deflated
    let mut b = good.clone();
    set_u32(&mut b, 14, 1);
    b.truncate(22 + 4 + 8);
    assert!(WireMsg::from_bytes(&b).is_err(), "deflated nwords must be rejected");
    // n inflated without matching words
    let mut b = good.clone();
    set_u32(&mut b, 6, 100_000);
    assert!(WireMsg::from_bytes(&b).is_err(), "inflated n must be rejected");
    // out-of-domain codec params
    let mut b = good.clone();
    set_u32(&mut b, 2, 10_000); // kg way past MAX_KG
    assert!(WireMsg::from_bytes(&b).is_err(), "out-of-range kg must be rejected");
    let mut b = good.clone();
    b[0] = 99; // unknown codec id
    assert!(WireMsg::from_bytes(&b).is_err(), "unknown codec must be rejected");
}

/// Hostile *sparse* content: frames whose layout counts are fine but
/// whose payload lies — duplicate/unsorted/out-of-range indices,
/// bitmap popcount disagreeing with the header `k`, per-block
/// positions out of the block — must be rejected at the wire boundary
/// (the range-decode kernels binary-search sorted indices and trust
/// the rank arithmetic; unsorted content would make them scatter out
/// of the accepted window).
#[test]
fn hostile_sparse_frames_are_rejected_without_panic() {
    let n = 150usize;
    let mut rng = seeded_rng(13, 13);
    let u: Vec<f32> = (0..n).map(|_| 0.2 * (rng.gen_f32() - 0.5)).collect();
    let mut q = vec![0.0f32; n];
    let set_u32 = |b: &mut Vec<u8>, off: usize, v: u32| {
        b[off..off + 4].copy_from_slice(&v.to_le_bytes());
    };

    // ---- TopK, index mode (k=6 sorted 8-bit indices at offset 22) ----
    let good = TopK::new(400).compress_into(&u, &mut q, &mut seeded_rng(1, 1)).to_bytes();
    assert!(WireMsg::from_bytes(&good).is_ok(), "baseline index frame parses");
    let mut b = good.clone();
    b[22] = b[23]; // duplicate index
    assert!(WireMsg::from_bytes(&b).is_err(), "duplicate topk index must be rejected");
    let mut b = good.clone();
    b[22] = 0xFF; // 255 >= n, and >= the next index: unsorted AND out of range
    assert!(WireMsg::from_bytes(&b).is_err(), "out-of-range topk index must be rejected");
    let mut b = good.clone();
    b.swap(22, 23); // still unique, no longer ascending
    assert!(WireMsg::from_bytes(&b).is_err(), "unsorted topk indices must be rejected");
    // header k disagreeing with the shipped value/position counts: the
    // parser re-derives both payload sizes from (codec, param, n), so
    // the frame's actual length no longer fits
    let mut b = good.clone();
    set_u32(&mut b, 2, 7);
    assert!(WireMsg::from_bytes(&b).is_err(), "k != payload count must be rejected");
    let mut b = good.clone();
    set_u32(&mut b, 2, n as u32 + 1);
    assert!(WireMsg::from_bytes(&b).is_err(), "k > n must be rejected");

    // ---- TopK, bitmap mode (k=75 over 3 bitmap words) ----
    let good = TopK::new(5000).compress_into(&u, &mut q, &mut seeded_rng(1, 1)).to_bytes();
    assert!(WireMsg::from_bytes(&good).is_ok(), "baseline bitmap frame parses");
    let mut b = good.clone();
    for byte in b.iter_mut().skip(22).take(24) {
        *byte = 0xFF; // popcount != k, and the tail bits past n are set
    }
    assert!(WireMsg::from_bytes(&b).is_err(), "lying bitmap must be rejected");

    // ---- SparseBlock 3-of-16 (10 scales, then 30 5-bit codes) ----
    let good = SparseBlock::new(16, 3).compress_into(&u, &mut q, &mut seeded_rng(1, 1)).to_bytes();
    assert!(WireMsg::from_bytes(&good).is_ok(), "baseline sparse-block frame parses");
    let words_off = 22 + 10 * 4;
    let mut b = good.clone();
    for byte in b.iter_mut().skip(words_off).take(24) {
        *byte = 0xFF; // every position = 15: never strictly increasing
    }
    assert!(
        WireMsg::from_bytes(&b).is_err(),
        "repeated in-block positions must be rejected"
    );
    let mut b = good.clone();
    set_u32(&mut b, 2, 16 | (17 << 16)); // kb > block
    assert!(WireMsg::from_bytes(&b).is_err(), "kb > block must be rejected");
    let mut b = good.clone();
    set_u32(&mut b, 2, 17 << 16); // block = 0
    assert!(WireMsg::from_bytes(&b).is_err(), "block = 0 must be rejected");

    // And the generic sweeps cover these codecs too (sample_frames now
    // includes them) — this test is the targeted content layer.
}
