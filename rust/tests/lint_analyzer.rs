//! Integration tests for the `qadam lint` invariant analyzer: the live
//! tree must be clean, every known-bad fixture in `lint_fixtures/` must
//! fail exactly its rule, and every known-good twin must pass. This is
//! the suite that keeps the analyzer honest — a rules change that stops
//! a bad fixture from failing (or starts flagging a good one) lands
//! here before it can silently weaken the ci.sh gate.

use std::path::Path;

use qadam::analysis::{self, check_file, check_wire};

fn repo_root() -> std::path::PathBuf {
    analysis::repo_root_from(Path::new(env!("CARGO_MANIFEST_DIR")))
        .expect("no rust/src/lib.rs at or above CARGO_MANIFEST_DIR")
}

/// The committed tree passes its own analyzer — same assertion
/// `scripts/ci.sh` makes by running `qadam lint` as a hard gate.
#[test]
fn full_tree_is_clean() {
    let rep = analysis::run(&repo_root()).expect("lint walk failed");
    assert!(rep.findings.is_empty(), "live tree has lint findings:\n{:#?}", rep.findings);
    assert!(rep.files >= 20, "walked only {} files — wrong root?", rep.files);
    assert_eq!(
        rep.unsafe_count,
        analysis::UNSAFE_BUDGET,
        "unsafe inventory drifted from the committed budget"
    );
    assert!(
        rep.waivers.iter().any(|w| w.path.ends_with("ps/transport.rs") && w.rule == "INV-DET"),
        "the transport straggler-deadline waiver should be honored and reported: {:?}",
        rep.waivers
    );
}

#[test]
fn registry_shape_is_pinned() {
    assert_eq!(analysis::REGISTRY_VERSION, 1, "registry version moved — update this pin and ci");
    let ids: Vec<&str> = analysis::RULES.iter().map(|r| r.id).collect();
    assert_eq!(ids, ["INV-ALLOC", "INV-DET", "INV-PANIC", "INV-SAFETY", "INV-WIRE"]);
    assert!(analysis::RULES.iter().all(|r| !r.summary.is_empty()));
}

/// Every known-bad fixture produces at least one finding of exactly the
/// rule named in its header, under a virtual in-scope path.
#[test]
fn known_bad_fixtures_fail_their_rule() {
    let cases = [
        (include_str!("lint_fixtures/bad_alloc.rs"), "rust/src/quant/fixture.rs", "INV-ALLOC"),
        (include_str!("lint_fixtures/bad_det.rs"), "rust/src/ps/fixture.rs", "INV-DET"),
        (include_str!("lint_fixtures/bad_panic.rs"), "rust/src/ps/fixture.rs", "INV-PANIC"),
        (include_str!("lint_fixtures/bad_safety.rs"), "rust/src/runtime/fixture.rs", "INV-SAFETY"),
        (include_str!("lint_fixtures/bad_allow.rs"), "rust/src/ps/fixture.rs", "INV-DET"),
    ];
    for (src, vpath, rule) in cases {
        let rep = check_file(vpath, src);
        assert!(
            rep.findings.iter().any(|f| f.rule == rule),
            "{vpath} fixture produced no {rule} finding: {:?}",
            rep.findings
        );
    }
    // the reasonless waiver in bad_allow.rs is not honored, and the
    // finding says why
    let rep = check_file("rust/src/ps/fixture.rs", include_str!("lint_fixtures/bad_allow.rs"));
    assert!(rep.waivers.is_empty(), "a reasonless allow must not become a waiver");
    assert!(
        rep.findings.iter().any(|f| f.msg.contains("no justification")),
        "{:?}",
        rep.findings
    );
}

/// Every known-good twin is clean under the same virtual paths.
#[test]
fn known_good_fixtures_pass() {
    let cases = [
        (include_str!("lint_fixtures/good_alloc.rs"), "rust/src/quant/fixture.rs"),
        (include_str!("lint_fixtures/good_det.rs"), "rust/src/ps/fixture.rs"),
        (include_str!("lint_fixtures/good_panic.rs"), "rust/src/ps/fixture.rs"),
        (include_str!("lint_fixtures/good_safety.rs"), "rust/src/runtime/fixture.rs"),
    ];
    for (src, vpath) in cases {
        let rep = check_file(vpath, src);
        assert!(rep.findings.is_empty(), "{vpath}: {:?}", rep.findings);
    }
    // good_det's justified waiver is honored AND surfaced
    let rep = check_file("rust/src/ps/fixture.rs", include_str!("lint_fixtures/good_det.rs"));
    assert_eq!(rep.waivers.len(), 1, "{:?}", rep.waivers);
    assert!(rep.waivers[0].reason.contains("logging"), "{:?}", rep.waivers);
}

/// INV-WIRE fails when a tag constant loses its golden fixture — the
/// cross-file direction the per-file fixtures cannot cover.
#[test]
fn inv_wire_catches_a_dropped_tag() {
    let protocol = "\
pub mod tag {
    pub const TO_WORKER_SHUTDOWN: u8 = 0;
    pub const TO_SERVER_DELTA: u8 = 0;
}
";
    let complete = "TO_WORKER_SHUTDOWN TO_SERVER_DELTA";
    assert!(check_wire(protocol, complete, complete).is_empty());
    let missing = check_wire(protocol, "TO_WORKER_SHUTDOWN", complete);
    assert_eq!(missing.len(), 1, "{missing:?}");
    assert!(missing[0].msg.contains("TO_SERVER_DELTA"), "{missing:?}");
    assert!(missing[0].msg.contains("wire_golden"), "{missing:?}");
}
