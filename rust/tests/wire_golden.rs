//! Golden wire-format fixtures: byte-exact encode/decode vectors for
//! every `CodecId` and every frame tag, pinned against
//! `ps::protocol::WIRE_VERSION`.
//!
//! These tests exist to fail LOUDLY on any wire change. If one fails,
//! either (a) you changed the wire format by accident — revert — or
//! (b) you changed it on purpose: bump `WIRE_VERSION`, regenerate the
//! hex below (each assertion prints the actual bytes on mismatch), and
//! say so in DESIGN.md §Wire format. `scripts/ci.sh` runs this suite in
//! both debug and `--release`, so an optimization-dependent divergence
//! (fast-math, UB) in any codec's float path also lands here.
//!
//! Inputs are chosen so every codec is deterministic: TernGrad sees
//! only `|u| ∈ {0, s}` (Bernoulli p ∈ {0, 1}) and QSGD only exact grid
//! points (zero stochastic-rounding mass), so the fixtures hold for any
//! rng stream.

use qadam::ps::protocol::{tag, ToServer, ToWorker, WIRE_VERSION};
use qadam::quant::{
    decode_msg, seeded_rng, Blockwise, Compressor, Identity, LogQuant, Qsgd, SparseBlock,
    TernGrad, TopK, WQuant, WireMsg,
};

fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

fn compress(comp: &dyn Compressor, u: &[f32]) -> (Vec<f32>, WireMsg) {
    let mut q = vec![0.0; u.len()];
    // Any stream works: the fixture inputs leave no decision to the rng.
    let msg = comp.compress_into(u, &mut q, &mut seeded_rng(0xfeed, 7));
    (q, msg)
}

/// One fixture: codec, input, expected dequantized values, expected
/// serialized bytes (hex), expected analytic wire_bytes.
struct Fixture {
    name: &'static str,
    comp: Box<dyn Compressor>,
    u: Vec<f32>,
    q: Vec<f32>,
    hex: String,
    wire_bytes: usize,
}

/// `WireMsg::to_bytes` layout (version 2, unchanged since v1):
/// `codec:u8 | bits:u8 | param:u32 | n:u32 | nscales:u32 | nwords:u32 |
///  nraw:u32 | scales:f32* | words:u64* | raw:f32*`, all LE.
fn fixtures() -> Vec<Fixture> {
    vec![
        Fixture {
            name: "identity",
            comp: Box::new(Identity),
            u: vec![1.0, -2.0],
            q: vec![1.0, -2.0],
            hex: concat!(
                "0000",             // codec=0 bits=0
                "00000000",         // param
                "02000000",         // n=2
                "00000000",         // nscales=0
                "00000000",         // nwords=0
                "02000000",         // nraw=2
                "0000803f",         // 1.0
                "000000c0",         // -2.0
            )
            .into(),
            wire_bytes: 14 + 8,
        },
        Fixture {
            name: "logquant kg=0 (ternary rows)",
            comp: Box::new(LogQuant::new(0)),
            u: vec![1.0, -1.0, 0.0, 0.5],
            // 0.5 is the zero/level midpoint: ties round up, to level 1
            q: vec![1.0, -1.0, 0.0, 1.0],
            hex: concat!(
                "0102",             // codec=1 bits=2
                "00000000",         // param = kg = 0
                "04000000",         // n=4
                "01000000",         // nscales=1
                "01000000",         // nwords=1
                "00000000",         // nraw=0
                "0000803f",         // scale = 1.0
                "9200000000000000", // codes [2,0,1,2] @2b LSB-first = 0x92
            )
            .into(),
            wire_bytes: 14 + 4 + 1, // header + scale + ceil(4*2/8)
        },
        Fixture {
            name: "wquant kx=1",
            comp: Box::new(WQuant::new(1)),
            u: vec![0.5, -0.25, 0.0, 0.25],
            q: vec![0.5, -0.25, 0.0, 0.25],
            hex: concat!(
                "0203",             // codec=2 bits=3
                "01000000",         // param = kx = 1
                "04000000",         // n=4
                "00000000",         // nscales=0 (absolute grid)
                "01000000",         // nwords=1
                "00000000",         // nraw=0
                "8c06000000000000", // codes [4,1,2,3] @3b = 0x68c
            )
            .into(),
            wire_bytes: 14 + 2, // header + ceil(4*3/8)
        },
        Fixture {
            name: "terngrad",
            comp: Box::new(TernGrad),
            u: vec![2.0, -2.0, 0.0, 2.0],
            q: vec![2.0, -2.0, 0.0, 2.0],
            hex: concat!(
                "0302",             // codec=3 bits=2
                "00000000",         // param
                "04000000",         // n=4
                "01000000",         // nscales=1
                "01000000",         // nwords=1
                "00000000",         // nraw=0
                "00000040",         // scale = 2.0
                "9200000000000000", // codes [2,0,1,2]
            )
            .into(),
            wire_bytes: 14 + 4 + 1,
        },
        Fixture {
            name: "blockwise block=2",
            comp: Box::new(Blockwise::new(2)),
            u: vec![1.0, -3.0, 0.5, 0.5],
            q: vec![2.0, -2.0, 0.5, 0.5],
            hex: concat!(
                "0401",             // codec=4 bits=1
                "02000000",         // param = block = 2
                "04000000",         // n=4
                "02000000",         // nscales=2
                "01000000",         // nwords=1
                "00000000",         // nraw=0
                "00000040",         // block scale 2.0
                "0000003f",         // block scale 0.5
                "0d00000000000000", // sign codes [1,0,1,1] @1b = 0x0d
            )
            .into(),
            wire_bytes: 14 + 8 + 1,
        },
        // The sparse family is rng-free by construction (magnitude
        // selection + verbatim values), so any input pins it.
        Fixture {
            // density 0.5 on n=4 keeps k=2; 2 indices at 2 bits would
            // not undercut a 4-bit bitmap, so the size rule picks the
            // bitmap encoding (bits=1, one lane per coordinate).
            name: "topk d=0.5 (bitmap mode)",
            comp: Box::new(TopK::new(5000)),
            u: vec![1.0, -3.0, 0.5, 2.0],
            q: vec![0.0, -3.0, 0.0, 2.0],
            hex: concat!(
                "0601",             // codec=6 bits=1 (bitmap)
                "02000000",         // param = k = 2
                "04000000",         // n=4
                "00000000",         // nscales=0 (values ship verbatim)
                "01000000",         // nwords=1
                "02000000",         // nraw = k = 2
                "0a00000000000000", // bitmap 0b1010: coords {1, 3} kept
                "000040c0",         // kept value -3.0 (ascending index)
                "00000040",         // kept value 2.0
            )
            .into(),
            wire_bytes: 14 + 1 + 8, // header + bitmap byte + 2 raw f32
        },
        Fixture {
            // density 0.125 on n=8 keeps k=1; one 3-bit index beats an
            // 8-bit bitmap, so the size rule picks the index list.
            name: "topk d=0.125 (index mode)",
            comp: Box::new(TopK::new(1250)),
            u: vec![0.0, 0.0, 0.0, 0.0, 0.0, -4.0, 0.0, 0.0],
            q: vec![0.0, 0.0, 0.0, 0.0, 0.0, -4.0, 0.0, 0.0],
            hex: concat!(
                "0603",             // codec=6 bits=3 (index width for n=8)
                "01000000",         // param = k = 1
                "08000000",         // n=8
                "00000000",         // nscales=0
                "01000000",         // nwords=1
                "01000000",         // nraw = k = 1
                "0500000000000000", // sorted indices [5] @3b
                "000080c0",         // kept value -4.0
            )
            .into(),
            wire_bytes: 14 + 1 + 4, // header + ceil(1*3/8) + 1 raw f32
        },
        Fixture {
            // 1-of-2 blockwise top-k: per block, the kept position and
            // sign pack into (pos<<1)|sign codes, the magnitude is the
            // per-block scale (mean |kept|).
            name: "sparse-block 1-of-2",
            comp: Box::new(SparseBlock::new(2, 1)),
            u: vec![1.0, -3.0, 0.5, 0.5],
            q: vec![0.0, -3.0, 0.5, 0.0],
            hex: concat!(
                "0702",             // codec=7 bits=2 (1 pos bit + 1 sign bit)
                "02000100",         // param = block=2 | kb=1 << 16
                "04000000",         // n=4
                "02000000",         // nscales = 2 blocks
                "01000000",         // nwords=1
                "00000000",         // nraw=0
                "00004040",         // block 0 scale 3.0
                "0000003f",         // block 1 scale 0.5
                "0600000000000000", // codes [pos1|neg, pos0|pos] @2b
            )
            .into(),
            wire_bytes: 14 + 8 + 1, // header + 2 scales + ceil(2*2/8)
        },
        Fixture {
            name: "qsgd L=4",
            comp: Box::new(Qsgd::new(4)),
            u: vec![1.0, 0.5, -0.25, 0.0],
            q: vec![1.0, 0.5, -0.25, 0.0],
            hex: concat!(
                "0504",             // codec=5 bits=4
                "04000000",         // param = levels = 4
                "04000000",         // n=4
                "01000000",         // nscales=1
                "01000000",         // nwords=1
                "00000000",         // nraw=0
                "0000803f",         // scale = 1.0
                "6843000000000000", // codes [8,6,3,4] @4b = 0x4368
            )
            .into(),
            wire_bytes: 14 + 4 + 2,
        },
    ]
}

const BUMP: &str = "wire format changed — bump ps::protocol::WIRE_VERSION, regenerate this \
                    fixture from the printed actual bytes, and document the change in DESIGN.md";

#[test]
fn fixtures_are_for_wire_version_2() {
    assert_eq!(
        WIRE_VERSION, 2,
        "WIRE_VERSION moved without regenerating the golden fixtures in this file"
    );
}

/// Encode direction: every codec's serialized bytes match the golden
/// vector bit-for-bit, and the analytic `wire_bytes` accounting matches
/// the fixture.
#[test]
fn codec_encode_matches_golden_bytes() {
    for f in fixtures() {
        let (q, msg) = compress(f.comp.as_ref(), &f.u);
        assert_eq!(q, f.q, "[{}] dequantized values drifted", f.name);
        assert_eq!(
            hex(&msg.to_bytes()),
            f.hex,
            "[{}] serialized bytes drifted — {BUMP}",
            f.name
        );
        assert_eq!(msg.wire_bytes(), f.wire_bytes, "[{}] wire_bytes accounting", f.name);
    }
}

/// Decode direction: the golden bytes parse and decode back to the
/// fixture's dequantized values — so old captures stay readable until a
/// deliberate, versioned break.
#[test]
fn codec_decode_matches_golden_values() {
    for f in fixtures() {
        let bytes: Vec<u8> = (0..f.hex.len())
            .step_by(2)
            .map(|i| u8::from_str_radix(&f.hex[i..i + 2], 16).unwrap())
            .collect();
        let msg = WireMsg::from_bytes(&bytes)
            .unwrap_or_else(|e| panic!("[{}] golden bytes no longer parse: {e} — {BUMP}", f.name));
        let mut out = vec![0.0f32; msg.n];
        decode_msg(&msg, &mut out);
        assert_eq!(out, f.q, "[{}] golden bytes decode drifted", f.name);
    }
}

fn logquant_fixture_msg() -> WireMsg {
    compress(&LogQuant::new(0), &[1.0, -1.0, 0.0, 0.5]).1
}

fn terngrad_fixture_msg() -> WireMsg {
    compress(&TernGrad, &[2.0, -2.0, 0.0, 2.0]).1
}

const T_EPOCH_HEX: &str = concat!(
    "0700000000000000", // t = 7
    "0100000000000000", // epoch = 1
);
const LOGQUANT_HEX: &str = concat!(
    "0102", "00000000", "04000000", "01000000", "01000000", "00000000",
    "0000803f", "9200000000000000",
);
const TERNGRAD_HEX: &str = concat!(
    "0302", "00000000", "04000000", "01000000", "01000000", "00000000",
    "00000040", "9200000000000000",
);

/// Every `ToWorker` frame tag, byte-for-byte.
#[test]
fn toworker_frames_match_golden_bytes() {
    let weights = ToWorker::Weights { t: 7, epoch: 1, msg: logquant_fixture_msg() };
    assert_eq!(
        hex(&weights.to_bytes()),
        format!("01{T_EPOCH_HEX}{LOGQUANT_HEX}"),
        "Weights (tag 1) drifted — {BUMP}"
    );
    let delta = ToWorker::WeightsDelta { t: 7, epoch: 1, msg: logquant_fixture_msg() };
    assert_eq!(
        hex(&delta.to_bytes()),
        format!("02{T_EPOCH_HEX}{LOGQUANT_HEX}"),
        "WeightsDelta (tag 2) drifted — {BUMP}"
    );
    // parts payload: nparts=2, then (len | bytes) per part; both
    // fixture messages serialize to 34 = 0x22 bytes
    let parts =
        ToWorker::WeightsDeltaParts { t: 7, epoch: 1, parts: vec![logquant_fixture_msg(), terngrad_fixture_msg()] };
    assert_eq!(
        hex(&parts.to_bytes()),
        format!("03{T_EPOCH_HEX}02000000{}{LOGQUANT_HEX}{}{TERNGRAD_HEX}", "22000000", "22000000"),
        "WeightsDeltaParts (tag 3) drifted — {BUMP}"
    );
    assert_eq!(hex(&ToWorker::Shutdown.to_bytes()), "00", "Shutdown (tag 0) drifted — {BUMP}");
    // and they all parse back
    for frame in [weights, delta, parts, ToWorker::Shutdown] {
        let b = frame.to_bytes();
        ToWorker::from_bytes(&b).expect("golden frame must parse");
    }
}

/// The frame-tag registry itself: every constant in `protocol::tag` is
/// pinned here by value, and the first byte of a sample frame of each
/// kind equals its registry constant. `qadam lint` (INV-WIRE) checks
/// that every `tag` constant appears in this file, so adding a tag
/// without extending this test fails the analyzer.
#[test]
fn frame_tag_registry_is_pinned() {
    assert_eq!(tag::TO_WORKER_SHUTDOWN, 0, "Shutdown tag moved — {BUMP}");
    assert_eq!(tag::TO_WORKER_WEIGHTS, 1, "Weights tag moved — {BUMP}");
    assert_eq!(tag::TO_WORKER_WEIGHTS_DELTA, 2, "WeightsDelta tag moved — {BUMP}");
    assert_eq!(tag::TO_WORKER_WEIGHTS_DELTA_PARTS, 3, "WeightsDeltaParts tag moved — {BUMP}");
    assert_eq!(tag::TO_SERVER_DELTA, 0, "Delta tag moved — {BUMP}");
    assert_eq!(tag::TO_SERVER_DELTA_PARTS, 1, "DeltaParts tag moved — {BUMP}");
    // The sparse codec ids ride the existing frame kinds as WireMsg
    // byte 0 — pinned like the frame tags, with the registry constant
    // checked against a real encode.
    assert_eq!(tag::CODEC_TOPK, 6, "TopK codec id moved — {BUMP}");
    assert_eq!(tag::CODEC_SPARSE_BLOCK, 7, "SparseBlock codec id moved — {BUMP}");
    assert_eq!(
        compress(&TopK::new(5000), &[1.0, -3.0, 0.5, 2.0]).1.to_bytes()[0],
        tag::CODEC_TOPK
    );
    assert_eq!(
        compress(&SparseBlock::new(2, 1), &[1.0, -3.0, 0.5, 0.5]).1.to_bytes()[0],
        tag::CODEC_SPARSE_BLOCK
    );

    let msg = logquant_fixture_msg;
    assert_eq!(ToWorker::Shutdown.to_bytes()[0], tag::TO_WORKER_SHUTDOWN);
    assert_eq!(
        ToWorker::Weights { t: 7, epoch: 1, msg: msg() }.to_bytes()[0],
        tag::TO_WORKER_WEIGHTS
    );
    assert_eq!(
        ToWorker::WeightsDelta { t: 7, epoch: 1, msg: msg() }.to_bytes()[0],
        tag::TO_WORKER_WEIGHTS_DELTA
    );
    assert_eq!(
        ToWorker::WeightsDeltaParts { t: 7, epoch: 1, parts: vec![msg()] }.to_bytes()[0],
        tag::TO_WORKER_WEIGHTS_DELTA_PARTS
    );
    assert_eq!(
        ToServer::Delta { t: 7, worker: 3, loss: 1.5, msg: msg() }.to_bytes()[0],
        tag::TO_SERVER_DELTA
    );
    assert_eq!(
        ToServer::DeltaParts { t: 7, worker: 3, loss: 1.5, parts: vec![msg()] }.to_bytes()[0],
        tag::TO_SERVER_DELTA_PARTS
    );
}

/// Both `ToServer` frame tags, byte-for-byte.
#[test]
fn toserver_frames_match_golden_bytes() {
    const WORKER_LOSS_HEX: &str = concat!(
        "03000000", // worker = 3
        "0000c03f", // loss = 1.5
    );
    let single = ToServer::Delta { t: 7, worker: 3, loss: 1.5, msg: logquant_fixture_msg() };
    assert_eq!(
        hex(&single.to_bytes()),
        format!("000700000000000000{WORKER_LOSS_HEX}{LOGQUANT_HEX}"),
        "Delta (tag 0) drifted — {BUMP}"
    );
    let parts = ToServer::DeltaParts {
        t: 7,
        worker: 3,
        loss: 1.5,
        parts: vec![logquant_fixture_msg(), terngrad_fixture_msg()],
    };
    assert_eq!(
        hex(&parts.to_bytes()),
        format!(
            "010700000000000000{WORKER_LOSS_HEX}02000000{}{LOGQUANT_HEX}{}{TERNGRAD_HEX}",
            "22000000", "22000000"
        ),
        "DeltaParts (tag 1) drifted — {BUMP}"
    );
    // roundtrip through the payload accessors
    let back = ToServer::from_bytes(&parts.to_bytes()).unwrap();
    assert_eq!((back.round(), back.worker(), back.loss()), (7, 3, 1.5));
    assert_eq!(back.payload_n(), 8);
    let mut out = vec![0.0f32; 8];
    back.decode_range(0, &mut out);
    assert_eq!(out, vec![1.0, -1.0, 0.0, 1.0, 2.0, -2.0, 0.0, 2.0]);
}
