//! Empirical checks of Theorems 3.1–3.3 on the synthetic stochastic
//! nonconvex problem (Assumption-1-compliant by construction).
//!
//! These are *qualitative* checks of the theorems' predictions:
//!   Thm 3.1 — with Q_g + EF, min_t E||∇f||² decays toward 0;
//!   Thm 3.2 — with Q_x only, E||∇f(Q_x(x))||² plateaus at a floor that
//!             shrinks as k_x grows (C_7 ∝ δ_x);
//!   Thm 3.3 — multi-worker: same as 3.1/3.2 with both quantizers, and
//!             more workers do not hurt.

use qadam::elastic::{ChaosPlan, ChaosTransport};
use qadam::optim::{LrSchedule, QAdamEf, ThetaSchedule, WorkerOpt};
use qadam::ps::transport::{LocalBus, Transport};
use qadam::ps::worker::{SimGradSource, Worker};
use qadam::ps::ParameterServer;
use qadam::quant::LogQuant;
use qadam::sim::StochasticProblem;

const DIM: usize = 64;

/// Run Algorithms 2–3 on the sim problem; returns mean ||∇f(x_t)||²
/// over the tail window [T/2, T] (a proxy for E||∇f(x_τ)||²).
fn run(
    workers: usize,
    kg: Option<u32>,
    ef: bool,
    kx: Option<u32>,
    steps: u64,
    alpha: f32,
) -> f32 {
    // Off-grid minimizer so the Thm 3.2 weight-quantization floor is
    // observable (a grid-aligned minimizer has no floor).
    let problem = StochasticProblem::with_offgrid_minimum(DIM, 0.3, 7);
    let mut ps = ParameterServer::new(problem.x0(), kx);
    let mut ws: Vec<Worker> = (0..workers)
        .map(|i| {
            let src = SimGradSource { problem: problem.clone() };
            let opt: Box<dyn WorkerOpt> = match kg {
                Some(k) => Box::new(QAdamEf::new(
                    DIM,
                    Box::new(LogQuant::new(k)),
                    ef,
                    LrSchedule::InvSqrt { alpha },
                    ThetaSchedule::Anneal { theta: 0.9 },
                    0.9,
                    1e-8,
                )),
                None => Box::new(QAdamEf::full_precision(DIM, LrSchedule::InvSqrt { alpha })),
            };
            Worker::new(i as u32, opt, Box::new(src), 11)
        })
        .collect();
    let bus = LocalBus::default();
    let mut tail = 0.0f64;
    let mut count = 0usize;
    for t in 1..=steps {
        let replies = {
            let (b, _) = ps.broadcast(workers);
            bus.round(&b, &mut ws).unwrap()
        };
        ps.apply(&replies).unwrap();
        if t >= steps / 2 {
            // Thm 3.2/3.3 measure the gradient at the quantized weights.
            let gsq = problem.grad_norm_sq(ps.output_weights());
            tail += gsq as f64;
            count += 1;
        }
    }
    (tail / count as f64) as f32
}

#[test]
fn thm_3_1_gradient_quant_with_ef_reaches_stationarity() {
    // grad-quant + EF: tail gradient tiny, and comparable to fp32.
    let g_q = run(1, Some(2), true, None, 600, 0.5);
    let g_fp = run(1, None, false, None, 600, 0.5);
    assert!(g_q < 5e-4, "quantized tail grad^2 {g_q}");
    assert!(g_q < 10.0 * g_fp.max(1e-6), "q={g_q} fp={g_fp}");
}

#[test]
fn thm_3_1_convergence_improves_with_horizon() {
    // The bound is ~ (C + C' log T)/sqrt(T): tail grad at T=800 must be
    // well below the tail at T=100.
    let short = run(1, Some(2), true, None, 100, 0.5);
    let long = run(1, Some(2), true, None, 800, 0.5);
    assert!(long < short, "short={short} long={long}");
}

#[test]
fn thm_3_2_weight_quant_floor_scales_with_delta_x() {
    // With weight quantization only, the floor C_7 ∝ δ_x: coarser grids
    // (smaller k_x) must plateau strictly higher.
    let coarse = run(1, None, false, Some(1), 1000, 0.5); // δ_x ~ 2^-3
    let fine = run(1, None, false, Some(8), 1000, 0.5); // δ_x ~ 2^-10
    let none = run(1, None, false, None, 1000, 0.5);
    assert!(
        coarse > 4.0 * fine.max(1e-7),
        "floor should shrink with k_x: coarse={coarse} fine={fine} none={none}"
    );
    // and the coarse floor is a real floor (way above the unquantized tail)
    assert!(coarse > 10.0 * none.max(1e-7), "coarse={coarse} none={none}");
}

#[test]
fn thm_3_3_multi_worker_converges_with_both_quantizers() {
    let g = run(8, Some(2), true, Some(8), 600, 0.5);
    assert!(g < 5e-3, "8-worker tail grad^2 {g}");
    // variance reduction: 8 workers no worse than 2x a single worker
    let g1 = run(1, Some(2), true, Some(8), 600, 0.5);
    assert!(g < 2.0 * g1.max(1e-6), "multi={g} single={g1}");
}

/// Run 4 workers under a chaos plan and return the per-round EF
/// residual norm of worker 0 (Alg. 3's `‖e_t‖`).
fn residual_track(plan: ChaosPlan, steps: u64) -> Vec<f32> {
    let problem = StochasticProblem::with_offgrid_minimum(DIM, 0.3, 7);
    let mut ps = ParameterServer::new(problem.x0(), None);
    let mut ws: Vec<Worker> = (0..4)
        .map(|i| {
            let src = SimGradSource { problem: problem.clone() };
            let opt = QAdamEf::new(
                DIM,
                Box::new(LogQuant::new(2)),
                true,
                LrSchedule::InvSqrt { alpha: 0.5 },
                ThetaSchedule::Anneal { theta: 0.9 },
                0.9,
                1e-8,
            );
            Worker::new(i as u32, Box::new(opt), Box::new(src), 11)
        })
        .collect();
    let mut bus = ChaosTransport::new(Box::new(LocalBus::default()), plan);
    let mut track = Vec::with_capacity(steps as usize);
    for _ in 1..=steps {
        let replies = {
            let (b, _) = ps.broadcast(4);
            bus.round(&b, &mut ws).unwrap()
        };
        ps.apply(&replies).unwrap();
        track.push(ws[0].residual_norm());
    }
    track
}

/// Partial participation does not break the Assumption-2 contraction:
/// when a chaos plan drops worker 0's reply for K consecutive rounds,
/// its EF residual norm stays finite and bounded by (a small multiple
/// of) the clean run's ceiling. This is the Theorem 3.1 residual
/// argument under elastic rounds — the residual `e_t` obeys
/// `‖e_{t+1}‖ ≤ δ_g ‖u_t + e_t‖` *locally*, whatever the server did
/// with the reply, so losing K replies shifts the trajectory but
/// cannot make the residual drift: the missed mass is bounded by the
/// same geometric contraction.
#[test]
fn ef_residual_bounded_under_k_round_reply_loss() {
    let clean = residual_track(ChaosPlan::default(), 120);
    let clean_max = clean.iter().cloned().fold(0.0f32, f32::max);
    assert!(clean_max > 0.0, "kg=2 must leave a nonzero residual");
    for k in [5u64, 30] {
        let drops: Vec<(u64, u32)> = (40..40 + k).map(|t| (t, 0)).collect();
        let track = residual_track(ChaosPlan::dropping(&drops), 120);
        assert!(track.iter().all(|r| r.is_finite()));
        let chaos_max = track.iter().cloned().fold(0.0f32, f32::max);
        assert!(
            chaos_max <= 3.0 * clean_max,
            "K={k}: residual ceiling {chaos_max} vs clean {clean_max} — \
             partial participation must not break the contraction"
        );
        // and during the outage itself the residual stays in the same
        // regime (no monotone blow-up while the server ignores worker 0)
        let outage_max =
            track[39..(39 + k) as usize].iter().cloned().fold(0.0f32, f32::max);
        assert!(outage_max <= 3.0 * clean_max, "K={k}: outage ceiling {outage_max}");
    }
}
