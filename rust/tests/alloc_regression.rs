//! Allocation-regression harness for the round hot path.
//!
//! A counting `#[global_allocator]` (thread-local counters, so
//! parallel `#[test]` threads don't bleed into each other) pins the
//! memory discipline the kernel rewrite bought:
//!
//! * decode paths (`decompress`, `decompress_range`,
//!   `decode_msg_range_add`) perform **zero** heap allocations;
//! * `compress_into` allocates exactly its wire payload (the returned
//!   `WireMsg`'s words/scales/raw Vecs — the product, not scratch);
//! * `ParameterServer::apply` allocates only the O(workers) reporter
//!   id list — never an O(dim) scratch buffer;
//! * a steady-state LocalBus round (after warmup) has a *flat*
//!   allocation profile: identical count and bytes every round.
//!
//! Everything here runs single-threaded (LocalBus, `threads = 1`
//! server) so all allocations land on the measuring thread.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use qadam::optim::{LrSchedule, QAdamEf};
use qadam::ps::{LocalBus, ParameterServer, SimGradSource, ToServer, Worker};
use qadam::quant::{
    decode_msg_range_add, seeded_rng, Blockwise, CodecPolicy, Compressor, Identity, LogQuant,
    PolicySpec, Qsgd, SparseBlock, StochasticLogQuant, TensorLayout, TernGrad, TopK, WQuant,
    WireMsg,
};
use qadam::sim::StochasticProblem;

struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
    static BYTES: Cell<u64> = const { Cell::new(0) };
}

// `try_with` so allocations during thread teardown (after TLS
// destruction) fall through uncounted instead of aborting.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        let _ = BYTES.try_with(|c| c.set(c.get() + layout.size() as u64));
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        let _ = BYTES.try_with(|c| c.set(c.get() + layout.size() as u64));
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        let _ = BYTES.try_with(|c| c.set(c.get() + new_size as u64));
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Run `f`, returning (allocation count, allocated bytes, result).
fn measure<R>(f: impl FnOnce() -> R) -> (u64, u64, R) {
    let a0 = ALLOCS.with(|c| c.get());
    let b0 = BYTES.with(|c| c.get());
    let r = f();
    let a1 = ALLOCS.with(|c| c.get());
    let b1 = BYTES.with(|c| c.get());
    (a1 - a0, b1 - b0, r)
}

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = seeded_rng(seed, 77);
    (0..n).map(|_| 0.1 * (rng.gen_f32() - 0.5)).collect()
}

/// Every codec's `compress_into` allocates exactly its wire payload:
/// the `Packed` words plus the scales Vec (2 allocations), except
/// WQuant (scale-free grid: 1) and Identity (raw payload: 1). No
/// intermediate code buffers, no scratch.
#[test]
fn compress_allocates_exactly_the_wire_payload() {
    let n = 4096;
    let u = randv(n, 1);
    let mut q = vec![0.0f32; n];
    let cases: Vec<(&str, Box<dyn Compressor>, u64)> = vec![
        ("logquant", Box::new(LogQuant::new(2)), 2),
        ("slq", Box::new(StochasticLogQuant::new(2)), 2),
        ("terngrad", Box::new(TernGrad), 2),
        ("qsgd", Box::new(Qsgd::new(4)), 2),
        ("blockwise", Box::new(Blockwise::new(512)), 2),
        ("wquant", Box::new(WQuant::new(6)), 1),
        ("identity", Box::new(Identity), 1),
    ];
    for (name, comp, want) in &cases {
        let mut rng = seeded_rng(3, 3);
        let _warm = comp.compress_into(&u, &mut q, &mut rng);
        let (allocs, bytes, msg) = measure(|| comp.compress_into(&u, &mut q, &mut rng));
        assert_eq!(
            allocs, *want,
            "{name}: compress must allocate exactly its payload Vecs"
        );
        if msg.codes.is_some() {
            // packed payload, not an O(4n) float scratch
            assert!(bytes < (n * 4) as u64, "{name}: allocated {bytes} bytes for n={n}");
        }
    }
}

/// Every decode path is allocation-free: plain, ranged, and the fused
/// accumulate used by the server's apply loop.
#[test]
fn decode_paths_allocate_nothing() {
    let n = 4096;
    let u = randv(n, 2);
    let mut q = vec![0.0f32; n];
    let cases: Vec<(&str, Box<dyn Compressor>)> = vec![
        ("logquant", Box::new(LogQuant::new(2))),
        ("slq", Box::new(StochasticLogQuant::new(2))),
        ("terngrad", Box::new(TernGrad)),
        ("qsgd", Box::new(Qsgd::new(4))),
        ("blockwise", Box::new(Blockwise::new(512))),
        ("wquant", Box::new(WQuant::new(6))),
        ("identity", Box::new(Identity)),
    ];
    let mut out = vec![0.0f32; n];
    for (name, comp) in &cases {
        let mut rng = seeded_rng(5, 5);
        let msg: WireMsg = comp.compress_into(&u, &mut q, &mut rng);
        let (a, _, ()) = measure(|| comp.decompress(&msg, &mut out));
        assert_eq!(a, 0, "{name}: decompress must not allocate");
        let (a, _, ()) = measure(|| comp.decompress_range(&msg, 100, &mut out[..1000]));
        assert_eq!(a, 0, "{name}: decompress_range must not allocate");
        let (a, _, ()) = measure(|| decode_msg_range_add(&msg, 100, &mut out[..1000]));
        assert_eq!(a, 0, "{name}: decode_msg_range_add must not allocate");
    }
}

/// The sparse decode hot paths are allocation-free too — both TopK
/// encodings (the bitmap rank walk and the index binary search) and the
/// SparseBlock block walk, on the plain, ranged and fused-accumulate
/// entries. The range decodes deliberately slice mid-payload so the
/// rank/binary-search seeding runs, not just the trivial prefix.
#[test]
fn sparse_decode_paths_allocate_nothing() {
    let n = 4096;
    let u = randv(n, 6);
    let mut q = vec![0.0f32; n];
    let cases: Vec<(&str, Box<dyn Compressor>)> = vec![
        ("topk-index", Box::new(TopK::new(400))),
        ("topk-bitmap", Box::new(TopK::new(5000))),
        ("sparse-block", Box::new(SparseBlock::new(512, 16))),
    ];
    let mut out = vec![0.0f32; n];
    for (name, comp) in &cases {
        let mut rng = seeded_rng(5, 5);
        let msg: WireMsg = comp.compress_into(&u, &mut q, &mut rng);
        let (a, _, ()) = measure(|| comp.decompress(&msg, &mut out));
        assert_eq!(a, 0, "{name}: decompress must not allocate");
        let (a, _, ()) = measure(|| comp.decompress_range(&msg, 100, &mut out[..1000]));
        assert_eq!(a, 0, "{name}: decompress_range must not allocate");
        let (a, _, ()) = measure(|| decode_msg_range_add(&msg, 100, &mut out[..1000]));
        assert_eq!(a, 0, "{name}: decode_msg_range_add must not allocate");
    }
}

/// Sparse compression allocates exactly its wire payload plus the one
/// selection scratch — TopK: the index scratch, the raw value Vec and
/// the packed positions (3); an empty keep set allocates nothing;
/// SparseBlock: the scales Vec, the packed codes and the per-block
/// order scratch (3). Never an O(4n) float copy.
#[test]
fn sparse_compress_allocation_is_pinned() {
    let n = 4096;
    let u = randv(n, 4);
    let mut q = vec![0.0f32; n];
    let cases: Vec<(&str, Box<dyn Compressor>, u64)> = vec![
        ("topk-index", Box::new(TopK::new(400)), 3),
        ("topk-bitmap", Box::new(TopK::new(5000)), 3),
        ("topk-empty", Box::new(TopK::new(0)), 0),
        ("sparse-block", Box::new(SparseBlock::new(512, 16)), 3),
    ];
    for (name, comp, want) in &cases {
        let mut rng = seeded_rng(3, 3);
        let _warm = comp.compress_into(&u, &mut q, &mut rng);
        let (allocs, bytes, _msg) = measure(|| comp.compress_into(&u, &mut q, &mut rng));
        assert_eq!(allocs, *want, "{name}: selection scratch + payload Vecs only");
        // the u32 selection scratch is the biggest piece; everything
        // stays well under two dense float copies of the input
        assert!(bytes < (8 * n) as u64, "{name}: allocated {bytes} bytes for n={n}");
    }
}

/// Steady-state rounds under a mixed **sparse** per-layer policy (topk
/// + sblock + dense tensors, on both directions) have a flat allocation
/// profile: fixed densities mean fixed payload shapes, so after warmup
/// every round performs the identical allocation count and byte total —
/// the parts-frame uplink and the sparse decode paths introduce nothing
/// that grows per round.
#[test]
fn steady_state_sparse_policy_round_allocation_is_flat() {
    let dim = 4096;
    let nw = 2usize;
    let spec = PolicySpec::parse("per-layer:b0=topk@0.05,b1=sblock@64x4,*=2").unwrap();
    let layout = TensorLayout::uniform(dim, 4);
    let mut ps = ParameterServer::new(randv(dim, 22), None);
    ps.enable_delta_downlink(Box::new(LogQuant::new(2)), 50);
    ps.set_downlink_policy(CodecPolicy::new(spec.clone(), layout.clone(), 2).unwrap());
    let mut workers: Vec<Worker> = (0..nw)
        .map(|i| {
            let src = SimGradSource { problem: StochasticProblem::new(dim, 0.1, 7) };
            let opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.01 })
                .with_policy(CodecPolicy::new(spec.clone(), layout.clone(), 2).unwrap());
            Worker::new(i as u32, Box::new(opt), Box::new(src), 42)
        })
        .collect();
    let bus = LocalBus;
    let mut run_round = |ps: &mut ParameterServer, workers: &mut [Worker]| -> (u64, u64, u64, u64) {
        let (ba, bb, tw) = measure(|| ps.broadcast(nw).0);
        let (ha, hb, replies) = measure(|| bus.round(&tw, workers).unwrap());
        let (aa, ab, res) = measure(|| ps.apply(&replies));
        res.unwrap();
        (ba + aa, bb + ab, ha, hb)
    };
    for _ in 0..3 {
        run_round(&mut ps, &mut workers);
    }
    let profile: Vec<(u64, u64, u64, u64)> =
        (0..4).map(|_| run_round(&mut ps, &mut workers)).collect();
    for (i, p) in profile.iter().enumerate().skip(1) {
        assert_eq!(
            p, &profile[0],
            "sparse-policy round {} changed the allocation profile",
            i + 1
        );
    }
}

/// The observability hot path is store-only: recording spans into the
/// preallocated ring and feeding every registry series (counters,
/// gauges, both histograms, per-shard comm, faults) performs zero
/// allocations. Together with the round tests below — which run the
/// exact code an obs-off round runs — this pins the tentpole's
/// overhead contract from both sides: off is unchanged, on is
/// alloc-free stores.
#[test]
fn obs_record_and_registry_feed_allocate_nothing() {
    use qadam::elastic::FaultStats;
    use qadam::obs::{MetricsRegistry, RoundTrace, Span, SpanKind};
    use qadam::ps::protocol::CommStats;
    let mut ring = RoundTrace::new(256);
    let reg = MetricsRegistry::new(2);
    let span = Span {
        round: 1,
        shard: 0,
        lane: 2,
        kind: SpanKind::Gather,
        start_ns: 5,
        dur_ns: 7,
        bytes: 640,
    };
    let stats = CommStats { down_bytes: 10, up_bytes: 4, rounds: 1, resyncs: 0 };
    let faults = FaultStats { dropped: 1, delayed: 0, duplicated: 0, corrupted: 0, crashed: 0 };
    let (allocs, bytes, ()) = measure(|| {
        for i in 0..64 {
            ring.record(span);
            reg.frame_bytes.observe(64 + i);
            reg.round_latency_ns.observe(1_000_000 + i);
        }
        reg.observe_comm(&stats, &[]);
        reg.observe_shard(0, &stats);
        reg.observe_shard(1, &stats);
        reg.observe_round(2_000_000, 4, 0.5, 3.0, 1.25);
        reg.straggler_evictions.set_cumulative(2);
        reg.observe_faults(&faults);
    });
    assert_eq!(allocs, 0, "obs recording must never allocate");
    assert_eq!(bytes, 0);
}

fn delta_replies(t: u64, dim: usize, workers: u32) -> Vec<ToServer> {
    let mut rng = seeded_rng(11, t);
    let mut q = vec![0.0f32; dim];
    (0..workers)
        .map(|w| {
            let u = randv(dim, t * 100 + w as u64);
            let msg = LogQuant::new(2).compress_into(&u, &mut q, &mut rng);
            ToServer::Delta { t, worker: w, loss: 1.0, msg }
        })
        .collect()
}

/// `ParameterServer::apply` on the sequential (threads = 1) path
/// allocates exactly one Vec — the O(workers) reporter id list. The
/// decode→sum→apply traversal runs entirely in the persistent arena.
#[test]
fn apply_allocates_only_the_reporter_id_list() {
    let dim = 8192;
    let workers = 4u32;
    let mut ps = ParameterServer::new(randv(dim, 9), None);
    // warmup round: first-touch effects out of the way
    ps.broadcast(workers as usize);
    ps.apply(&delta_replies(1, dim, workers)).unwrap();
    ps.broadcast(workers as usize);
    let deltas = delta_replies(2, dim, workers);
    let (allocs, bytes, res) = measure(|| ps.apply(&deltas));
    res.unwrap();
    assert_eq!(allocs, 1, "apply must allocate only the reporter id list");
    assert_eq!(bytes, workers as u64 * 4, "the id list is O(workers), never O(dim)");
}

/// Steady-state LocalBus rounds have a flat allocation profile: after
/// warmup, every round performs the identical allocation count and
/// byte total (wire payloads + the gradient-source Vec + the two
/// O(workers) lists — nothing that grows, nothing transient in the
/// codec path). The delta-downlink broadcast and the apply are also
/// pinned individually.
#[test]
fn steady_state_round_allocation_is_flat() {
    let dim = 4096;
    let nw = 3usize;
    let mut ps = ParameterServer::new(randv(dim, 21), None);
    ps.enable_delta_downlink(Box::new(LogQuant::new(2)), 50);
    let mut workers: Vec<Worker> = (0..nw)
        .map(|i| {
            let src = SimGradSource { problem: StochasticProblem::new(dim, 0.1, 7) };
            let opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.01 });
            Worker::new(i as u32, Box::new(opt), Box::new(src), 42)
        })
        .collect();
    let bus = LocalBus;
    let mut run_round = |ps: &mut ParameterServer, workers: &mut [Worker]| -> (u64, u64) {
        let (bcast_allocs, _, tw) = measure(|| ps.broadcast(nw).0);
        let (ha, hb, replies) = measure(|| bus.round(&tw, workers).unwrap());
        let (aa, ab, res) = measure(|| ps.apply(&replies));
        res.unwrap();
        if ps.step() > 2 {
            // steady state: the delta-frame broadcast allocates exactly
            // its payload (words + scales), apply exactly the id list
            assert_eq!(bcast_allocs, 2, "t={}: broadcast payload only", ps.step());
            assert_eq!(aa, 1, "t={}: apply id list only", ps.step());
            assert_eq!(ab, nw as u64 * 4, "t={}", ps.step());
        }
        (ha, hb)
    };
    // warmup: resync frame + first-touch
    for _ in 0..3 {
        run_round(&mut ps, &mut workers);
    }
    let profile: Vec<(u64, u64)> =
        (0..4).map(|_| run_round(&mut ps, &mut workers)).collect();
    for (i, p) in profile.iter().enumerate().skip(1) {
        assert_eq!(
            p, &profile[0],
            "round {} of the steady state changed the allocation profile",
            i + 1
        );
    }
    // the whole worker side of a round stays O(payload + gradient):
    // bounded count, and no hidden O(dim) scratch beyond the one
    // gradient Vec per worker the GradSource API returns by value.
    let (count, bytes) = profile[0];
    assert!(count <= 8 * nw as u64, "worker-side allocs per round: {count}");
    assert!(
        bytes <= (nw * (5 * dim)) as u64,
        "worker-side bytes per round: {bytes} (dim={dim})"
    );
}
