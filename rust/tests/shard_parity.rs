//! Shard-layer acceptance suite (no artifacts needed — sim workers
//! over the real engines):
//!
//! * `--shards 1` drives the very same code path as the unsharded seed
//!   engine: broadcast frames, replies, masters, stats and
//!   participation are **byte-identical** round by round.
//! * An N-shard fixed-seed run is **bit-reproducible** across all
//!   three transports — sequential, threaded, TCP — including the
//!   per-shard byte accounting and the adaptive policy's chosen bits.
//! * Chaos crash/rejoin composes with sharding: the rejoin forces a
//!   resync on every shard, replicas re-anchor, and the run stays
//!   bit-identical across the in-process engines.
//! * A single-shard forced resync re-anchors exactly that shard while
//!   the other lanes keep their delta streams.
//! * Checkpoints round-trip across shard counts: a 2-shard run resumes
//!   bit-identically from its v3 file, and v2 ↔ v3 files restore under
//!   either shard count through the stitched blobs.

use qadam::coordinator::checkpoint::{Checkpoint, ShardServerState, WorkerState};
use qadam::elastic::{ChaosPlan, ChaosTransport};
use qadam::optim::{LrSchedule, QAdamEf};
use qadam::ps::transport::{tcp_sharded_worker_loop, TcpServer, TcpShardGroup};
use qadam::ps::worker::{SimGradSource, Worker};
use qadam::ps::{
    LocalBus, ParameterServer, ShardPlan, ShardedServer, ThreadedBus, ToWorker, Transport,
};
use qadam::quant::{CodecPolicy, PolicySpec, TensorLayout};

const BLOCK: usize = 1 << 16;

fn mk_worker(id: u32, dim: usize, policy: Option<(PolicySpec, TensorLayout)>) -> Worker {
    let src = SimGradSource { problem: qadam::sim::StochasticProblem::new(dim, 0.05, 9) };
    let mut opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.02 });
    if let Some((spec, layout)) = policy {
        opt = opt.with_policy(CodecPolicy::new(spec, layout, 2).unwrap());
    }
    Worker::new(id, Box::new(opt), Box::new(src), 1)
}

fn x0(dim: usize) -> Vec<f32> {
    (0..dim).map(|i| 0.3 + 0.01 * (i as f32).sin()).collect()
}

/// Acceptance: `--shards 1` is byte-identical to the pre-shard engine.
/// The seed path (bare `ParameterServer` + `LocalBus::round` +
/// `Worker::handle`) and the shard path (`ShardedServer` over a
/// single-range plan + `round_sharded` + `handle_sharded`) are run
/// side by side; every frame, reply, master, stat and participation
/// must match bit for bit — with weight quantization and the delta
/// downlink in play.
#[test]
fn shards_1_is_byte_identical_to_the_seed_engine() {
    let dim = 64;
    let nw = 3usize;
    let kx = Some(4u32);
    // seed path
    let mut ps_seed = ParameterServer::new(x0(dim), kx);
    ps_seed.enable_delta_downlink(qadam::quant::gradient_codec(Some(2)), 5);
    let mut ws_seed: Vec<Worker> = (0..nw as u32).map(|i| mk_worker(i, dim, None)).collect();
    let seed_bus = LocalBus::default();
    // shard path, shards = 1
    let plan =
        ShardPlan::build(dim, 1, &PolicySpec::Static, &TensorLayout::uniform(dim, 4)).unwrap();
    let mut srv = ShardedServer::new(x0(dim), kx, plan.clone(), BLOCK, 1);
    srv.enable_delta_downlink(Some(2), 5);
    let mut ws: Vec<Worker> = (0..nw as u32)
        .map(|i| {
            let mut w = mk_worker(i, dim, None);
            w.set_shards(plan.clone());
            w
        })
        .collect();
    let mut bus: Box<dyn Transport> = Box::new(LocalBus::default());
    for t in 1u64..=12 {
        let (b, _) = ps_seed.broadcast(nw);
        let r = seed_bus.round(&b, &mut ws_seed).unwrap();
        let part_seed = ps_seed.apply(&r).unwrap();

        let frames = srv.broadcast(nw);
        assert_eq!(frames.len(), 1);
        assert_eq!(frames[0].to_bytes(), b.to_bytes(), "t={t}: broadcast frame diverged");
        let lanes = bus.round_sharded(&frames, &mut ws).unwrap();
        assert_eq!(lanes.len(), 1);
        for (x, y) in lanes[0].iter().zip(&r) {
            assert_eq!(x.to_bytes(), y.to_bytes(), "t={t}: reply diverged");
        }
        let part = srv.apply(&lanes).unwrap();
        assert_eq!(part, part_seed, "t={t}");
        assert_eq!(srv.master(), ps_seed.master(), "t={t}");
        assert_eq!(srv.stats(), ps_seed.stats, "t={t}");
        let (replica, residual) = ps_seed.downlink_state().unwrap();
        let states = srv.downlink_states().unwrap();
        assert_eq!(states[0].0, replica, "t={t}");
        assert_eq!(states[0].1, residual, "t={t}");
    }
}

/// Drive one in-process sharded round: membershipless full fleet.
fn drive_round(
    srv: &mut ShardedServer,
    bus: &mut dyn Transport,
    workers: &mut [Worker],
) -> (Vec<ToWorker>, qadam::elastic::Participation) {
    let frames = srv.broadcast(workers.len());
    let lanes = bus.round_sharded(&frames, workers).unwrap();
    let part = srv.apply(&lanes).unwrap();
    (frames, part)
}

/// Acceptance: a 2-shard fixed-seed run — delta downlink + adaptive
/// per-tensor policy on both directions — is bit-reproducible across
/// LocalBus, ThreadedBus and the TCP shard group: masters, per-shard
/// CommStats, downlink replicas, chosen policy bits and participation
/// all match round by round.
#[test]
fn n_shard_fixed_seed_bit_parity_across_all_three_transports() {
    let dim = 96;
    let nw = 2usize;
    let rounds = 12u64;
    let spec = PolicySpec::Adaptive { lo: 0, hi: 4 };
    let layout = TensorLayout::uniform(dim, 4);
    let plan = ShardPlan::build(dim, 2, &spec, &layout).unwrap();
    assert_eq!(plan.count(), 2);
    let mk_srv = || {
        let mut srv = ShardedServer::new(x0(dim), None, plan.clone(), BLOCK, 1);
        srv.enable_delta_downlink(Some(2), 5);
        srv.set_downlink_policy(&spec, &layout, 2).unwrap();
        srv
    };
    let mk_ws = |plan: &ShardPlan| -> Vec<Worker> {
        (0..nw as u32)
            .map(|i| {
                let mut w = mk_worker(i, dim, Some((spec.clone(), layout.clone())));
                w.set_shards(plan.clone());
                w
            })
            .collect()
    };

    // TCP lanes: two listeners, workers as real sharded TCP clients.
    let ephemeral = || {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        drop(l);
        addr
    };
    let addr0 = ephemeral();
    let addr1 = ephemeral();
    let handles: Vec<_> = (0..nw as u32)
        .map(|id| {
            let addrs = vec![addr0.clone(), addr1.clone()];
            let plan = plan.clone();
            let spec = spec.clone();
            let layout = layout.clone();
            std::thread::spawn(move || {
                let mut w = mk_worker(id, dim, Some((spec, layout)));
                w.set_shards(plan);
                // per-lane connect retries live inside the loop, so a
                // worker may start before the listeners are up
                tcp_sharded_worker_loop(&addrs, &mut w).unwrap()
            })
        })
        .collect();
    let srv0 = TcpServer::bind_and_accept(&addr0, nw).unwrap();
    let srv1 = TcpServer::bind_and_accept(&addr1, nw).unwrap();
    let mut group = TcpShardGroup::new(vec![srv0, srv1]);

    let mut ps_local = mk_srv();
    let mut ws_local = mk_ws(&plan);
    let mut local: Box<dyn Transport> = Box::new(LocalBus::default());
    let mut ps_thr = mk_srv();
    let mut ws_thr = mk_ws(&plan);
    let mut thr: Box<dyn Transport> = Box::new(ThreadedBus::new());
    let mut ps_tcp = mk_srv();

    for t in 1..=rounds {
        let (frames_l, part_l) = drive_round(&mut ps_local, local.as_mut(), &mut ws_local);
        let (frames_t, part_t) = drive_round(&mut ps_thr, thr.as_mut(), &mut ws_thr);
        let frames_tcp = ps_tcp.broadcast(nw);
        let lanes_tcp = group.round_sharded(&frames_tcp).unwrap();
        let part_tcp = ps_tcp.apply(&lanes_tcp).unwrap();

        let bytes = |fs: &[ToWorker]| fs.iter().map(|f| f.to_bytes()).collect::<Vec<_>>();
        assert_eq!(bytes(&frames_l), bytes(&frames_t), "t={t}: frames local vs threaded");
        assert_eq!(bytes(&frames_l), bytes(&frames_tcp), "t={t}: frames local vs tcp");
        assert_eq!(part_l, part_t, "t={t}");
        assert_eq!(part_l, part_tcp, "t={t}");
        assert_eq!(ps_local.master(), ps_thr.master(), "t={t}");
        assert_eq!(ps_local.master(), ps_tcp.master(), "t={t}");
        for s in 0..2 {
            assert_eq!(ps_local.shard_stats(s), ps_thr.shard_stats(s), "t={t} shard {s}");
            assert_eq!(ps_local.shard_stats(s), ps_tcp.shard_stats(s), "t={t} shard {s}");
        }
        assert_eq!(
            ps_local.downlink_chosen_bits(),
            ps_tcp.downlink_chosen_bits(),
            "t={t}: downlink policy bits"
        );
        assert!(ps_local.downlink_bits().is_some());
        let rl = ps_local.downlink_states().unwrap();
        let rt = ps_tcp.downlink_states().unwrap();
        for s in 0..2 {
            assert_eq!(rl[s].0, rt[s].0, "t={t} shard {s}: replica");
        }
        // worker-side chosen bits agree across the in-process engines
        assert_eq!(ws_local[0].chosen_bits(), ws_thr[0].chosen_bits(), "t={t}");
    }
    group.shutdown().unwrap();
    for h in handles {
        assert_eq!(h.join().unwrap(), rounds);
    }
}

/// A 2-shard fixed-seed run under a **mixed sparse** per-layer policy —
/// `topk` on one tensor, `sblock` on another, dense LogQuant on the
/// rest, on both directions — is bit-reproducible across LocalBus,
/// ThreadedBus and the TCP shard group: masters, per-shard CommStats,
/// downlink replicas and the chosen per-tensor densities all match
/// round by round. (The plan snaps to tensor boundaries exactly as the
/// dense adaptive policy's does, so every shard sees whole tensors.)
#[test]
fn sparse_policy_2_shard_bit_parity_across_all_three_transports() {
    let dim = 96;
    let nw = 2usize;
    let rounds = 10u64;
    let spec = PolicySpec::parse("per-layer:b0=topk@0.05,b2=sblock@8x2,*=2").unwrap();
    let layout = TensorLayout::uniform(dim, 4);
    let plan = ShardPlan::build(dim, 2, &spec, &layout).unwrap();
    assert_eq!(plan.count(), 2);
    let mk_srv = || {
        let mut srv = ShardedServer::new(x0(dim), None, plan.clone(), BLOCK, 1);
        srv.enable_delta_downlink(Some(2), 5);
        srv.set_downlink_policy(&spec, &layout, 2).unwrap();
        srv
    };
    let mk_ws = |plan: &ShardPlan| -> Vec<Worker> {
        (0..nw as u32)
            .map(|i| {
                let mut w = mk_worker(i, dim, Some((spec.clone(), layout.clone())));
                w.set_shards(plan.clone());
                w
            })
            .collect()
    };

    let ephemeral = || {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        drop(l);
        addr
    };
    let addr0 = ephemeral();
    let addr1 = ephemeral();
    let handles: Vec<_> = (0..nw as u32)
        .map(|id| {
            let addrs = vec![addr0.clone(), addr1.clone()];
            let plan = plan.clone();
            let spec = spec.clone();
            let layout = layout.clone();
            std::thread::spawn(move || {
                let mut w = mk_worker(id, dim, Some((spec, layout)));
                w.set_shards(plan);
                tcp_sharded_worker_loop(&addrs, &mut w).unwrap()
            })
        })
        .collect();
    let srv0 = TcpServer::bind_and_accept(&addr0, nw).unwrap();
    let srv1 = TcpServer::bind_and_accept(&addr1, nw).unwrap();
    let mut group = TcpShardGroup::new(vec![srv0, srv1]);

    let mut ps_local = mk_srv();
    let mut ws_local = mk_ws(&plan);
    let mut local: Box<dyn Transport> = Box::new(LocalBus::default());
    let mut ps_thr = mk_srv();
    let mut ws_thr = mk_ws(&plan);
    let mut thr: Box<dyn Transport> = Box::new(ThreadedBus::new());
    let mut ps_tcp = mk_srv();

    // the rules bind as spelled: 500/10000 kept on b0, kb=2 on b2,
    // dense level 2 elsewhere
    assert_eq!(ws_local[0].chosen_bits().unwrap(), [500, 2, 2, 2]);

    for t in 1..=rounds {
        let (frames_l, part_l) = drive_round(&mut ps_local, local.as_mut(), &mut ws_local);
        let (frames_t, part_t) = drive_round(&mut ps_thr, thr.as_mut(), &mut ws_thr);
        let frames_tcp = ps_tcp.broadcast(nw);
        let lanes_tcp = group.round_sharded(&frames_tcp).unwrap();
        let part_tcp = ps_tcp.apply(&lanes_tcp).unwrap();

        let bytes = |fs: &[ToWorker]| fs.iter().map(|f| f.to_bytes()).collect::<Vec<_>>();
        assert_eq!(bytes(&frames_l), bytes(&frames_t), "t={t}: frames local vs threaded");
        assert_eq!(bytes(&frames_l), bytes(&frames_tcp), "t={t}: frames local vs tcp");
        assert_eq!(part_l, part_t, "t={t}");
        assert_eq!(part_l, part_tcp, "t={t}");
        assert_eq!(ps_local.master(), ps_thr.master(), "t={t}");
        assert_eq!(ps_local.master(), ps_tcp.master(), "t={t}");
        for s in 0..2 {
            assert_eq!(ps_local.shard_stats(s), ps_thr.shard_stats(s), "t={t} shard {s}");
            assert_eq!(ps_local.shard_stats(s), ps_tcp.shard_stats(s), "t={t} shard {s}");
        }
        assert_eq!(
            ps_local.downlink_chosen_bits(),
            ps_tcp.downlink_chosen_bits(),
            "t={t}: downlink policy bits"
        );
        let rl = ps_local.downlink_states().unwrap();
        let rt = ps_tcp.downlink_states().unwrap();
        for s in 0..2 {
            assert_eq!(rl[s].0, rt[s].0, "t={t} shard {s}: replica");
        }
        assert_eq!(ws_local[0].chosen_bits(), ws_thr[0].chosen_bits(), "t={t}");
    }
    group.shutdown().unwrap();
    for h in handles {
        assert_eq!(h.join().unwrap(), rounds);
    }
}

/// Acceptance: chaos crash/rejoin on a 2-shard fleet — the rejoin
/// forces a full-weights resync on *every* shard (the worker missed
/// frames on every lane), replicas re-anchor, and the whole chaotic
/// run is bit-identical across the sequential and threaded engines.
#[test]
fn chaos_crash_rejoin_forces_resync_on_every_shard_bit_reproducibly() {
    let dim = 64;
    let nw = 3usize;
    let plan = ShardPlan::uniform(dim, 2);
    let chaos_plan = ChaosPlan::default().with_crash(1, 4, 8);
    let mk_srv = || {
        let mut srv = ShardedServer::new(x0(dim), None, plan.clone(), BLOCK, 1);
        srv.enable_delta_downlink(Some(2), 0); // resync only round 1 / forced
        srv
    };
    let mk_ws = || -> Vec<Worker> {
        (0..nw as u32)
            .map(|i| {
                let mut w = mk_worker(i, dim, None);
                w.set_shards(plan.clone());
                w
            })
            .collect()
    };
    let mut ps_a = mk_srv();
    let mut ws_a = mk_ws();
    let mut bus_a: Box<dyn Transport> =
        Box::new(ChaosTransport::new(Box::new(LocalBus::default()), chaos_plan.clone()));
    let mut ps_b = mk_srv();
    let mut ws_b = mk_ws();
    let mut bus_b: Box<dyn Transport> =
        Box::new(ChaosTransport::new(Box::new(ThreadedBus::new()), chaos_plan));
    for t in 1u64..=10 {
        let m_a = bus_a.membership(t, nw);
        let m_b = bus_b.membership(t, nw);
        assert_eq!(m_a, m_b, "t={t}");
        assert_eq!(m_a.rejoined, t == 8, "t={t}");
        if m_a.rejoined {
            ps_a.force_resync_all();
            ps_b.force_resync_all();
        }
        let frames_a = ps_a.broadcast(m_a.present);
        let frames_b = ps_b.broadcast(m_b.present);
        if t == 1 || t == 8 {
            assert!(
                frames_a.iter().all(|f| matches!(f, ToWorker::Weights { .. })),
                "t={t}: every shard must resync"
            );
        } else {
            assert!(
                frames_a.iter().all(|f| matches!(f, ToWorker::WeightsDelta { .. })),
                "t={t}: steady state is delta frames on every shard"
            );
        }
        let lanes_a = bus_a.round_sharded(&frames_a, &mut ws_a).unwrap();
        let lanes_b = bus_b.round_sharded(&frames_b, &mut ws_b).unwrap();
        let part_a = ps_a.apply(&lanes_a).unwrap();
        let part_b = ps_b.apply(&lanes_b).unwrap();
        assert_eq!(part_a, part_b, "t={t}");
        let expected: Vec<u32> =
            if (4..8).contains(&t) { vec![0, 2] } else { vec![0, 1, 2] };
        assert_eq!(part_a.reporters, expected, "t={t}");
        assert_eq!(ps_a.master(), ps_b.master(), "t={t}");
        // every present worker's view equals the concatenated replicas
        let states = ps_a.downlink_states().unwrap();
        let mut replica = Vec::with_capacity(dim);
        for (r, _) in &states {
            replica.extend_from_slice(r);
        }
        for w in &ws_a {
            if w.id == 1 && (4..8).contains(&t) {
                continue; // crashed: stale by design until the rejoin resync
            }
            assert_eq!(w.weights(), &replica[..], "t={t} worker {}", w.id);
        }
    }
    // round 1 + the forced rejoin resync, on each of the two shards
    assert_eq!(ps_a.stats().resyncs, 4);
}

/// A forced single-shard resync (shard-local restore / lane rejoin)
/// re-anchors exactly that shard: the other lane keeps its delta
/// stream, and the run continues bit-consistently.
#[test]
fn single_shard_forced_resync_keeps_other_lanes_on_delta() {
    let dim = 48;
    let nw = 2usize;
    let plan = ShardPlan::uniform(dim, 2);
    let mut srv = ShardedServer::new(x0(dim), None, plan.clone(), BLOCK, 1);
    srv.enable_delta_downlink(Some(2), 0);
    let mut ws: Vec<Worker> = (0..nw as u32)
        .map(|i| {
            let mut w = mk_worker(i, dim, None);
            w.set_shards(plan.clone());
            w
        })
        .collect();
    let mut bus: Box<dyn Transport> = Box::new(LocalBus::default());
    for _ in 1..=3 {
        drive_round(&mut srv, bus.as_mut(), &mut ws);
    }
    srv.force_resync_shard(1);
    let frames = srv.broadcast(nw);
    assert!(matches!(frames[0], ToWorker::WeightsDelta { .. }), "shard 0 stays on delta");
    assert!(matches!(frames[1], ToWorker::Weights { .. }), "shard 1 resyncs alone");
    let lanes = bus.round_sharded(&frames, &mut ws).unwrap();
    srv.apply(&lanes).unwrap();
    for _ in 5..=6 {
        let (frames, _) = drive_round(&mut srv, bus.as_mut(), &mut ws);
        assert!(frames.iter().all(|f| matches!(f, ToWorker::WeightsDelta { .. })));
    }
    assert_eq!(srv.shard_stats(0).resyncs, 1, "shard 0: only round 1");
    assert_eq!(srv.shard_stats(1).resyncs, 2, "shard 1: round 1 + the forced one");
    // replicas still mirror every worker bit-exactly
    let states = srv.downlink_states().unwrap();
    let mut replica = Vec::with_capacity(dim);
    for (r, _) in &states {
        replica.extend_from_slice(r);
    }
    for w in &ws {
        assert_eq!(w.weights(), &replica[..], "worker {}", w.id);
    }
}

/// Snapshot a running sharded fleet into a Checkpoint (the trainer's
/// layout: per-shard blobs + per-worker opt state).
fn snapshot(srv: &ShardedServer, ws: &[Worker]) -> Checkpoint {
    let mut server = Vec::new();
    for (i, &(start, _len)) in srv.plan().ranges().iter().enumerate() {
        let (replica, residual) = srv.shard(i).downlink_state().unwrap();
        server.push(ShardServerState {
            start,
            replica: replica.to_vec(),
            residual: residual.to_vec(),
        });
    }
    Checkpoint {
        model: "sim".into(),
        step: srv.step(),
        x: srv.master(),
        server,
        workers: ws
            .iter()
            .map(|w| {
                w.opt_state().map(|(m, v, e)| WorkerState {
                    m: m.to_vec(),
                    v: v.to_vec(),
                    e: e.to_vec(),
                })
            })
            .collect(),
    }
}

/// Acceptance: checkpoint v2 ↔ v3 round-trip. A 2-shard run writes a
/// version-3 file and resumes from it bit-identically; the same file
/// restores into a 1-shard server (stitched blobs re-sliced), and a
/// v2-style single-blob file restores into a 2-shard server — the
/// per-shard states come back as exact slices of the full vectors.
#[test]
fn checkpoint_v2_v3_round_trip_across_shard_counts() {
    let dim = 32;
    let nw = 2usize;
    let plan2 = ShardPlan::uniform(dim, 2);
    let mk_srv = |plan: &ShardPlan| {
        let mut srv = ShardedServer::new(x0(dim), None, plan.clone(), BLOCK, 1);
        srv.enable_delta_downlink(Some(2), 4);
        srv
    };
    let mk_ws = |plan: &ShardPlan| -> Vec<Worker> {
        (0..nw as u32)
            .map(|i| {
                let mut w = mk_worker(i, dim, None);
                w.set_shards(plan.clone());
                w
            })
            .collect()
    };
    // Reference: 10 uninterrupted rounds.
    let mut ps_ref = mk_srv(&plan2);
    let mut ws_ref = mk_ws(&plan2);
    let mut bus: Box<dyn Transport> = Box::new(LocalBus::default());
    let mut ckpt_bytes = Vec::new();
    for t in 1u64..=10 {
        drive_round(&mut ps_ref, bus.as_mut(), &mut ws_ref);
        if t == 6 {
            ckpt_bytes = snapshot(&ps_ref, &ws_ref).to_bytes();
        }
    }
    // The 2-shard snapshot is a version-3 file.
    assert_eq!(u32::from_le_bytes(ckpt_bytes[8..12].try_into().unwrap()), 3);
    let ckpt = Checkpoint::from_bytes(&ckpt_bytes).unwrap();
    assert_eq!(ckpt.server.len(), 2);
    assert_eq!(ckpt.step, 6);

    // Resume a fresh 2-shard fleet from it: rounds 7..=10 must be
    // bit-identical to the uninterrupted reference.
    let mut ps = mk_srv(&plan2);
    let mut ws = mk_ws(&plan2);
    ps.restore(&ckpt.x, ckpt.step);
    let (replica, residual) = ckpt.stitched_server(dim).unwrap().unwrap();
    ps.restore_downlink_full(&replica, &residual).unwrap();
    for (w, s) in ws.iter_mut().zip(&ckpt.workers) {
        w.restore_weights(&replica);
        let s = s.as_ref().unwrap();
        w.opt_restore(&s.m, &s.v, &s.e);
    }
    for _ in 7..=10 {
        drive_round(&mut ps, bus.as_mut(), &mut ws);
    }
    assert_eq!(ps.master(), ps_ref.master(), "resumed 2-shard run diverged");
    let (a, b) = (ps.downlink_states().unwrap(), ps_ref.downlink_states().unwrap());
    for s in 0..2 {
        assert_eq!(a[s].0, b[s].0, "shard {s} replica diverged after resume");
        assert_eq!(a[s].1, b[s].1, "shard {s} residual diverged after resume");
    }

    // The v3 file loads into a 1-shard server: its single downlink
    // state is exactly the stitched full-range vectors.
    let plan1 = ShardPlan::single(dim);
    let mut ps1 = mk_srv(&plan1);
    ps1.restore(&ckpt.x, ckpt.step);
    ps1.restore_downlink_full(&replica, &residual).unwrap();
    let s1 = ps1.downlink_states().unwrap();
    assert_eq!(s1[0].0, &replica[..]);
    assert_eq!(s1[0].1, &residual[..]);
    assert_eq!(ps1.master(), ckpt.x);

    // And a v2-style file (one full-range blob) restores into the
    // 2-shard fleet as exact slices.
    let v2 = Checkpoint {
        model: "sim".into(),
        step: 6,
        x: ckpt.x.clone(),
        server: vec![ShardServerState {
            start: 0,
            replica: replica.clone(),
            residual: residual.clone(),
        }],
        workers: vec![None, None],
    };
    let v2_bytes = v2.to_bytes();
    assert_eq!(u32::from_le_bytes(v2_bytes[8..12].try_into().unwrap()), 2);
    let v2 = Checkpoint::from_bytes(&v2_bytes).unwrap();
    let (r2, e2) = v2.stitched_server(dim).unwrap().unwrap();
    let mut ps2 = mk_srv(&plan2);
    ps2.restore(&v2.x, v2.step);
    ps2.restore_downlink_full(&r2, &e2).unwrap();
    let states = ps2.downlink_states().unwrap();
    let (s0, s1) = (plan2.range(0), plan2.range(1));
    assert_eq!(states[0].0, &replica[s0.0..s0.0 + s0.1]);
    assert_eq!(states[1].0, &replica[s1.0..s1.0 + s1.1]);
    assert_eq!(states[1].1, &residual[s1.0..s1.0 + s1.1]);
}
