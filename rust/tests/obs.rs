//! Integration: the observability layer end-to-end through the
//! Trainer — and, above all, the zero-overhead-off guarantee: a traced
//! run takes the *bit-identical* trajectory of an untraced one, on
//! every bus engine. Spans and metrics are derived from values the
//! round already produces; if enabling them ever perturbed a loss,
//! a byte count or an RNG draw, these tests pin it.

use qadam::coordinator::config::{BusKind, Downlink, Engine, ExperimentConfig, Method};
use qadam::coordinator::Trainer;
use qadam::elastic::StragglerPolicy;
use qadam::models::artifacts_dir;
use qadam::obs::{read_trace, RoundObs, SpanKind, TickClock};
use qadam::optim::LrSchedule;

fn have_artifacts() -> bool {
    let ok = artifacts_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
    }
    ok
}

fn base_cfg() -> ExperimentConfig {
    ExperimentConfig {
        model: "mlp".into(),
        dataset: "vector".into(),
        method: Method::QAdam { kg: Some(2), error_feedback: true },
        kx: None,
        workers: 4,
        batch: 16,
        steps: 20,
        steps_per_epoch: 10,
        lr: LrSchedule::Const { alpha: 2e-3 },
        engine: Engine::Native,
        bus: BusKind::Sequential,
        downlink: Downlink::Full,
        resync_every: 64,
        chaos: None,
        codec_policy: qadam::quant::PolicySpec::Static,
        shards: 1,
        straggler: StragglerPolicy::Wait,
        min_participation: 1,
        async_rounds: false,
        staleness: 0,
        staleness_down_weight: false,
        cohort: None,
        registry: 100_000,
        seed: 0,
        eval_every: 10,
        eval_batches: 2,
    }
}

/// The deterministic slice of a metrics row — everything except
/// `round_ms`, which is wall-clock telemetry and *supposed* to differ
/// between a traced and an untraced run.
fn row_key(r: &qadam::coordinator::Row) -> (u64, u64, f32, f32, f64, f64, f32, usize, u64, f64, i64)
{
    (
        r.t,
        r.epoch,
        r.train_loss,
        r.test_acc,
        r.up_mb_per_round,
        r.down_mb_per_round,
        r.residual_norm,
        r.participation,
        r.resyncs,
        r.policy_bits,
        r.shard,
    )
}

fn run_traced(cfg: ExperimentConfig, trace: Option<&std::path::Path>) -> Trainer {
    let nshards = cfg.shards;
    let mut tr = Trainer::new(cfg).unwrap();
    let mut obs = RoundObs::new(Box::new(TickClock::millis()), nshards);
    if let Some(p) = trace {
        obs = obs.with_trace_out(p).unwrap();
    }
    tr.enable_obs(obs);
    tr.run().unwrap();
    tr
}

/// Tracing on vs off: bit-identical losses, accuracies, byte
/// accounting and metrics rows, across the sequential and threaded
/// engines and across shard counts.
#[test]
fn tracing_on_is_bit_identical_to_tracing_off() {
    if !have_artifacts() {
        return;
    }
    for bus in [BusKind::Sequential, BusKind::Threaded] {
        for shards in [1usize, 2] {
            let mut cfg = base_cfg();
            cfg.bus = bus;
            cfg.shards = shards;
            let mut plain = Trainer::new(cfg.clone()).unwrap();
            let off = plain.run().unwrap();
            let traced = run_traced(cfg, None);
            let sum = traced.log.rows.last().unwrap();
            let plain_sum = plain.log.rows.last().unwrap();
            assert_eq!(
                plain_sum.train_loss, sum.train_loss,
                "bus={bus:?} shards={shards}: tracing changed the trajectory"
            );
            assert_eq!(off.final_acc, traced.log.last_acc().unwrap());
            let a: Vec<_> = plain.log.rows.iter().map(row_key).collect();
            let b: Vec<_> = traced.log.rows.iter().map(row_key).collect();
            assert_eq!(a, b, "bus={bus:?} shards={shards}: metrics rows diverged");
            // ...and the traced run's merged rows actually carry time
            // (TickClock advances every read), while the untraced run's
            // round_ms column stays 0 — the "0 when tracing off" contract.
            assert!(traced.log.rows.iter().filter(|r| r.shard == -1).all(|r| r.round_ms > 0.0));
            assert!(plain.log.rows.iter().all(|r| r.round_ms == 0.0));
        }
    }
}

/// A traced multi-shard run writes a schema-versioned JSONL trace that
/// covers the full round lifecycle, with the shard/lane attribution
/// conventions the readers rely on.
#[test]
fn traced_run_writes_lifecycle_covering_jsonl() {
    if !have_artifacts() {
        return;
    }
    let dir = std::env::temp_dir().join("qadam_obs_itest_trace");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.jsonl");
    let mut cfg = base_cfg();
    cfg.shards = 2;
    let tr = run_traced(cfg, Some(&path));
    let tf = read_trace(&path).unwrap();
    assert_eq!(tf.clock, "tick");
    assert!(
        tf.covers_lifecycle(),
        "expected broadcast/gather/decode_apply/requantize, got {:?}",
        tf.covered_kinds()
    );
    // Merged spans carry real (tick) durations; per-shard spans carry
    // byte attribution for both shards; gather spans name worker lanes.
    assert!(tf.spans.iter().any(|s| s.shard == -1 && s.dur_ns > 0));
    for shard in 0..2i64 {
        assert!(
            tf.spans
                .iter()
                .any(|s| s.shard == shard && s.kind == SpanKind::Broadcast && s.bytes > 0),
            "no frame bytes attributed to shard {shard}"
        );
    }
    assert!(tf.spans.iter().any(|s| s.kind == SpanKind::Gather && s.lane >= 0 && s.bytes > 0));
    // The registry rode along with the trace.
    assert!(tr.obs_registry().is_some());
    let table = qadam::obs::render_table(&tf);
    assert!(table.contains("-1"), "merged row missing from the top table:\n{table}");
    std::fs::remove_dir_all(&dir).ok();
}

/// The registry exposed over `/metrics` reflects the run (rounds,
/// bytes, loss) and its counters are monotonic: re-feeding a stale
/// cumulative snapshot can never move the exposition backwards.
#[test]
fn registry_reflects_the_run_and_counters_stay_monotonic() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.shards = 2;
    let tr = run_traced(cfg, None);
    let reg = tr.obs_registry().unwrap();
    assert_eq!(reg.rounds.get(), 20);
    assert!(reg.merged.up_bytes.get() > 0);
    assert!(reg.merged.down_bytes.get() > 0);
    // per-shard series: present for both shards, summing below merged
    // (headers are per-lane; shard streams split one fleet's bytes)
    let per_shard_up: u64 = (0..2).map(|s| reg.shard(s).up_bytes.get()).sum();
    assert!(per_shard_up > 0 && per_shard_up <= reg.merged.up_bytes.get());
    assert!(reg.train_loss.get().is_finite());
    assert!(reg.test_acc.get() > 0.0, "eval ran at t=10,20: the gauge must be fed");
    assert!(reg.round_latency_ns.count() == 20);
    assert!(reg.frame_bytes.count() > 0);
    let before = reg.merged.up_bytes.get();
    // A stale snapshot (e.g. a lagging scrape racing a resync) is a
    // no-op, not a decrease.
    reg.merged.up_bytes.set_cumulative(1);
    assert_eq!(reg.merged.up_bytes.get(), before);
    let text = qadam::obs::render(&reg);
    assert!(text.contains("qadam_rounds_total 20"));
    assert!(text.contains("qadam_up_bytes_total{shard=\"-1\"}"));
    assert!(text.contains("qadam_up_bytes_total{shard=\"0\"}"));
}

/// End-to-end scrape: a `MetricsServer` mounted on a live trainer's
/// registry serves the exposition over a real socket with the
/// Prometheus content type.
#[test]
fn metrics_endpoint_scrapes_a_trained_registry() {
    if !have_artifacts() {
        return;
    }
    let tr = run_traced(base_cfg(), None);
    let reg = tr.obs_registry().unwrap();
    let srv = qadam::obs::MetricsServer::spawn("127.0.0.1:0", reg).unwrap();
    use std::io::{Read as _, Write as _};
    let mut conn = std::net::TcpStream::connect(srv.addr()).unwrap();
    conn.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    let mut resp = String::new();
    conn.read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"), "{resp}");
    assert!(resp.contains(&format!("Content-Type: {}", qadam::obs::CONTENT_TYPE)), "{resp}");
    assert!(resp.contains("qadam_rounds_total 20"), "{resp}");
}
