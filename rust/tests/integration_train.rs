//! Integration: the full training stack (Trainer = PS + workers + PJRT
//! graphs + datasets + accounting) on small budgets.

use qadam::coordinator::config::{BusKind, Downlink, Engine, ExperimentConfig, Method};
use qadam::coordinator::Trainer;
use qadam::elastic::{ChaosPlan, FaultKind, ScheduledFault, StragglerPolicy};
use qadam::models::artifacts_dir;
use qadam::optim::LrSchedule;

fn have_artifacts() -> bool {
    let ok = artifacts_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
    }
    ok
}

fn base_cfg() -> ExperimentConfig {
    ExperimentConfig {
        model: "mlp".into(),
        dataset: "vector".into(),
        method: Method::QAdam { kg: Some(2), error_feedback: true },
        kx: None,
        workers: 4,
        batch: 16,
        steps: 60,
        steps_per_epoch: 20,
        lr: LrSchedule::Const { alpha: 2e-3 },
        engine: Engine::Native,
        bus: BusKind::Sequential,
        downlink: Downlink::Full,
        resync_every: 64,
        chaos: None,
        codec_policy: qadam::quant::PolicySpec::Static,
        shards: 1,
        straggler: StragglerPolicy::Wait,
        min_participation: 1,
        async_rounds: false,
        staleness: 0,
        staleness_down_weight: false,
        cohort: None,
        registry: 100_000,
        seed: 0,
        eval_every: 0,
        eval_batches: 2,
    }
}

#[test]
fn qadam_trains_mlp_to_high_accuracy() {
    if !have_artifacts() {
        return;
    }
    let mut tr = Trainer::new(base_cfg()).unwrap();
    let s = tr.run().unwrap();
    assert!(s.final_acc > 0.90, "acc={}", s.final_acc);
    // Comm column: measured ≈ analytic 3 bits/elem (+ scale/header slack)
    let analytic_mb = 85002.0 * 3.0 / 8.0 / 1e6;
    assert!(
        (s.comm_mb_per_iter - analytic_mb).abs() < 0.1 * analytic_mb,
        "measured {} vs analytic {}",
        s.comm_mb_per_iter,
        analytic_mb
    );
}

#[test]
fn weight_quantization_during_training_works() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.kx = Some(6); // 8-bit weights
    let mut tr = Trainer::new(cfg).unwrap();
    let s = tr.run().unwrap();
    assert!(s.final_acc > 0.85, "acc={}", s.final_acc);
    assert!((s.model_size_mb / s.model_size_fp32_mb - 0.25).abs() < 1e-6);
    // WQuan (post-training quantization) path also runs:
    let post = tr.eval_post_quantized(6).unwrap();
    assert!(post > 0.5, "post-quantized acc {post}");
}

#[test]
fn terngrad_and_blockwise_baselines_run() {
    if !have_artifacts() {
        return;
    }
    for method in [Method::TernGrad, Method::Blockwise { block: 4096, momentum: 0.9 }] {
        let mut cfg = base_cfg();
        cfg.method = method;
        cfg.lr = LrSchedule::Const { alpha: 0.05 };
        let mut tr = Trainer::new(cfg).unwrap();
        let s = tr.run().unwrap();
        assert!(s.final_acc > 0.5, "{:?}: acc={}", method, s.final_acc);
        assert!(s.comm_mb_per_iter < 0.05, "{:?} comm {}", method, s.comm_mb_per_iter);
    }
}

#[test]
fn full_precision_baseline_and_comm_ratio() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.method = Method::QAdam { kg: None, error_feedback: false };
    let mut tr = Trainer::new(cfg).unwrap();
    let s = tr.run().unwrap();
    assert!(s.final_acc > 0.9, "acc={}", s.final_acc);
    // fp32 uplink ≈ 4 bytes/param
    let fp32_mb = 85002.0 * 4.0 / 1e6;
    assert!((s.comm_mb_per_iter - fp32_mb).abs() < 0.02 * fp32_mb);
}

#[test]
fn deterministic_given_seed() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.steps = 20;
    let s1 = Trainer::new(cfg.clone()).unwrap().run().unwrap();
    let s2 = Trainer::new(cfg).unwrap().run().unwrap();
    assert_eq!(s1.final_loss, s2.final_loss);
    assert_eq!(s1.final_acc, s2.final_acc);
}

#[test]
fn threaded_bus_matches_sequential_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.steps = 20;
    let seq = Trainer::new(cfg.clone()).unwrap().run().unwrap();
    cfg.bus = BusKind::Threaded;
    let thr = Trainer::new(cfg).unwrap().run().unwrap();
    // The parallel engine is a pure wall-clock optimization: losses,
    // accuracies and byte accounting are bit-identical.
    assert_eq!(seq.final_loss, thr.final_loss);
    assert_eq!(seq.final_acc, thr.final_acc);
    assert_eq!(seq.comm_mb_per_iter, thr.comm_mb_per_iter);
}

#[test]
fn lm_model_trains_and_loss_drops() {
    if !have_artifacts() {
        return;
    }
    let cfg = ExperimentConfig {
        model: "transformer_small".into(),
        dataset: "text".into(),
        method: Method::QAdam { kg: Some(2), error_feedback: true },
        kx: None,
        workers: 2,
        batch: 8,
        steps: 100,
        steps_per_epoch: 100,
        lr: LrSchedule::Const { alpha: 5e-3 },
        engine: Engine::Native,
        bus: BusKind::Sequential,
        downlink: Downlink::Full,
        resync_every: 64,
        chaos: None,
        codec_policy: qadam::quant::PolicySpec::Static,
        shards: 1,
        straggler: StragglerPolicy::Wait,
        min_participation: 1,
        async_rounds: false,
        staleness: 0,
        staleness_down_weight: false,
        cohort: None,
        registry: 100_000,
        seed: 0,
        eval_every: 0,
        eval_batches: 1,
    };
    let mut tr = Trainer::new(cfg).unwrap();
    let s = tr.run().unwrap();
    // This is a composition test, not a convergence benchmark: a
    // d=64 LM needs thousands of steps to digest the 64x64 bigram
    // table (the e2e example runs that); after 100 steps we require
    // finite loss near/below chance (ln 64 = 4.16) and next-token
    // accuracy clearly above the 1/64 = 1.6% chance level.
    assert!(s.final_loss.is_finite() && s.final_loss < 4.3, "loss={}", s.final_loss);
    assert!(s.final_acc > 0.025, "acc={}", s.final_acc);
}

#[test]
fn checkpoint_resume_is_bitwise_identical() {
    if !have_artifacts() {
        return;
    }
    // continuous 40-step run
    let mut cfg = base_cfg();
    cfg.steps = 40;
    let sa = Trainer::new(cfg.clone()).unwrap().run().unwrap();
    // 20 steps -> checkpoint -> restore into a fresh trainer -> 20 more
    let mut cfg_half = cfg.clone();
    cfg_half.steps = 20;
    let mut tr1 = Trainer::new(cfg_half).unwrap();
    tr1.run().unwrap();
    let ckpt = tr1.checkpoint();
    // serialize through bytes like the CLI does
    let ckpt = qadam::coordinator::Checkpoint::from_bytes(&ckpt.to_bytes()).unwrap();
    assert_eq!(ckpt.step, 20);
    let mut tr2 = Trainer::new(cfg).unwrap();
    tr2.restore(&ckpt).unwrap();
    let sb = tr2.run().unwrap();
    assert_eq!(sa.final_loss, sb.final_loss, "resume must match continuous run exactly");
    assert_eq!(sa.final_acc, sb.final_acc);
}

#[test]
fn delta_downlink_threaded_matches_sequential_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.downlink = Downlink::Delta;
    cfg.resync_every = 7;
    cfg.steps = 20;
    let seq = Trainer::new(cfg.clone()).unwrap().run().unwrap();
    cfg.bus = BusKind::Threaded;
    let thr = Trainer::new(cfg).unwrap().run().unwrap();
    assert_eq!(seq.final_loss, thr.final_loss);
    assert_eq!(seq.final_acc, thr.final_acc);
    assert_eq!(seq.comm_mb_per_iter, thr.comm_mb_per_iter);
    assert_eq!(seq.down_mb_per_iter, thr.down_mb_per_iter);
}

#[test]
fn delta_downlink_trains_and_cuts_down_bytes() {
    if !have_artifacts() {
        return;
    }
    let full = Trainer::new(base_cfg()).unwrap().run().unwrap();
    let mut cfg = base_cfg();
    cfg.downlink = Downlink::Delta;
    cfg.resync_every = 50;
    let mut tr = Trainer::new(cfg).unwrap();
    let delta = tr.run().unwrap();
    // Still trains: same budget, slightly noisier worker views.
    assert!(delta.final_acc > 0.85, "acc={}", delta.final_acc);
    // Acceptance: ≥4x smaller downlink at kg=2 vs full fp32 broadcasts.
    let ratio = full.down_mb_per_iter / delta.down_mb_per_iter;
    assert!(ratio >= 4.0, "down-bytes reduction only {ratio:.2}x");
    // The uplink accounting is untouched by the downlink mode.
    assert_eq!(full.comm_mb_per_iter, delta.comm_mb_per_iter);
}

#[test]
fn delta_downlink_checkpoint_resume_is_bitwise_identical() {
    if !have_artifacts() {
        return;
    }
    // resync_every=7 so the resumed half crosses both frame kinds
    let mut cfg = base_cfg();
    cfg.downlink = Downlink::Delta;
    cfg.resync_every = 7;
    cfg.steps = 40;
    let sa = Trainer::new(cfg.clone()).unwrap().run().unwrap();
    let mut cfg_half = cfg.clone();
    cfg_half.steps = 20;
    let mut tr1 = Trainer::new(cfg_half).unwrap();
    tr1.run().unwrap();
    let ckpt = tr1.checkpoint();
    // v2 checkpoints carry the server replica + residual
    let ckpt = qadam::coordinator::Checkpoint::from_bytes(&ckpt.to_bytes()).unwrap();
    assert!(!ckpt.server.is_empty(), "delta-mode checkpoints must carry server state");
    let mut tr2 = Trainer::new(cfg).unwrap();
    tr2.restore(&ckpt).unwrap();
    let sb = tr2.run().unwrap();
    assert_eq!(sa.final_loss, sb.final_loss, "delta-mode resume must match continuous run");
    assert_eq!(sa.final_acc, sb.final_acc);
}

#[test]
fn resume_at_horizon_yields_final_eval_not_nan() {
    if !have_artifacts() {
        return;
    }
    // Satellite: restoring at/past cfg.steps used to return NaN loss
    // and log nothing (the round loop never ran).
    let mut cfg = base_cfg();
    cfg.steps = 20;
    let mut tr1 = Trainer::new(cfg.clone()).unwrap();
    tr1.run().unwrap();
    let ckpt = tr1.checkpoint();
    let mut tr2 = Trainer::new(cfg).unwrap();
    tr2.restore(&ckpt).unwrap();
    let s = tr2.run().unwrap();
    assert!(s.final_loss.is_finite(), "restored-at-horizon loss must be finite");
    assert!(s.final_acc > 0.0, "restored-at-horizon summary must carry the eval");
    assert!(!tr2.log.rows.is_empty(), "a final eval row must be logged");
    assert_eq!(tr2.log.rows.last().unwrap().t, 20);
}

/// A deterministic chaos plan (scheduled drops + a crash window) run
/// end-to-end through the Trainer is bit-reproducible across the
/// sequential and threaded engines — losses, accuracies, byte
/// accounting, participation and resync counts all match.
#[test]
fn chaos_run_reproducible_across_engines_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.steps = 20;
    cfg.downlink = Downlink::Delta;
    cfg.resync_every = 7;
    cfg.straggler = StragglerPolicy::Drop;
    cfg.min_participation = 1;
    let mut plan = ChaosPlan::parse("crash=1@5..9").unwrap();
    plan.scheduled = (6u64..=8)
        .map(|t| ScheduledFault { kind: FaultKind::Drop, t, worker: 2 })
        .collect();
    cfg.chaos = Some(plan);
    let mut tr_seq = Trainer::new(cfg.clone()).unwrap();
    let seq = tr_seq.run().unwrap();
    cfg.bus = BusKind::Threaded;
    let mut tr_thr = Trainer::new(cfg).unwrap();
    let thr = tr_thr.run().unwrap();
    assert_eq!(seq.final_loss, thr.final_loss);
    assert_eq!(seq.final_acc, thr.final_acc);
    assert_eq!(seq.comm_mb_per_iter, thr.comm_mb_per_iter);
    assert_eq!(seq.down_mb_per_iter, thr.down_mb_per_iter);
    let rows_seq: Vec<(u64, usize, u64)> =
        tr_seq.log.rows.iter().map(|r| (r.t, r.participation, r.resyncs)).collect();
    let rows_thr: Vec<(u64, usize, u64)> =
        tr_thr.log.rows.iter().map(|r| (r.t, r.participation, r.resyncs)).collect();
    assert_eq!(rows_seq, rows_thr);
    // The final round (t=20) has everyone back: 4 reporters.
    assert_eq!(tr_seq.log.rows.last().unwrap().participation, 4);
    // Resyncs: t=1, the cadence (t=8, 15), and the forced rejoin at
    // t=9 (which coincides with no cadence round).
    assert_eq!(tr_seq.log.rows.last().unwrap().resyncs, 4);
}

/// A run with a crash window still trains to high accuracy: error
/// feedback and the mean-over-received semantics absorb the missing
/// worker (the elastic-rounds motivation).
#[test]
fn chaos_crash_window_still_trains() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.straggler = StragglerPolicy::Drop;
    cfg.chaos = Some(ChaosPlan::parse("crash=3@10..30").unwrap());
    let mut tr = Trainer::new(cfg).unwrap();
    let s = tr.run().unwrap();
    assert!(s.final_acc > 0.85, "acc={}", s.final_acc);
}

#[test]
fn checkpoint_rejects_wrong_model() {
    if !have_artifacts() {
        return;
    }
    let mut tr = Trainer::new(base_cfg()).unwrap();
    let mut ckpt = tr.checkpoint();
    ckpt.model = "vgg_sim".into();
    assert!(tr.restore(&ckpt).is_err());
}

/// An adaptive codec-policy run through the full Trainer stack (named
/// model tensors, delta downlink, both engines): still trains, is
/// bit-identical between sequential and threaded, and logs the chosen
/// bits in the metrics rows.
#[test]
fn adaptive_policy_trains_and_matches_across_engines() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.codec_policy = qadam::quant::PolicySpec::Adaptive { lo: 0, hi: 4 };
    cfg.downlink = Downlink::Delta;
    cfg.resync_every = 7;
    cfg.steps = 30;
    cfg.eval_every = 10;
    let mut tr_seq = Trainer::new(cfg.clone()).unwrap();
    let seq = tr_seq.run().unwrap();
    cfg.bus = BusKind::Threaded;
    let mut tr_thr = Trainer::new(cfg).unwrap();
    let thr = tr_thr.run().unwrap();
    assert_eq!(seq.final_loss, thr.final_loss, "adaptive run diverged across engines");
    assert_eq!(seq.final_acc, thr.final_acc);
    assert_eq!(seq.comm_mb_per_iter, thr.comm_mb_per_iter);
    assert_eq!(seq.down_mb_per_iter, thr.down_mb_per_iter);
    assert!(seq.final_loss.is_finite());
    // the chosen bits land in the metrics rows, within the band's code
    // widths (kg in 0..=4 -> 2..=4 code bits)
    let bits: Vec<f64> = tr_seq.log.rows.iter().map(|r| r.policy_bits).collect();
    assert_eq!(
        bits,
        tr_thr.log.rows.iter().map(|r| r.policy_bits).collect::<Vec<f64>>()
    );
    for b in bits {
        assert!((2.0..=4.0).contains(&b), "policy_bits={b} outside the band's code widths");
    }
    assert!(seq.label.contains("adaptive0..4"), "label={}", seq.label);
}

/// The satellite fix end-to-end: an out-of-range k_g is rejected with a
/// clear error at Trainer construction, not a panic mid-run.
#[test]
fn out_of_range_kg_is_a_clean_config_error() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.method = Method::QAdam { kg: Some(99), error_feedback: true };
    let err = match Trainer::new(cfg) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("kg=99 must not construct a trainer"),
    };
    assert!(err.contains("out of range"), "{err}");
}
