// Known-bad fixture for INV-SAFETY: an `unsafe impl` with no
// `// SAFETY:` justification anywhere above it.

pub struct Handle(*mut u8);

unsafe impl Send for Handle {}
