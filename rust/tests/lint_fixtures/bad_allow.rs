// Known-bad fixture for waiver hygiene: a `lint: allow` with no reason
// does not excuse the finding — it upgrades it to one that also
// complains about the empty justification (INV-DET here, under the
// virtual path rust/src/ps/fixture.rs).

use std::time::Instant;

pub fn stamp() -> Instant {
    // lint: allow(INV-DET)
    Instant::now()
}
