// Known-good twin of bad_det.rs: an ordered container, and the one
// wall-clock read carries a justified waiver (it feeds logging only,
// never a round's arithmetic).

use std::collections::BTreeMap;
use std::time::Instant;

pub fn pick(order: &BTreeMap<u32, f32>) -> f32 {
    // lint: allow(INV-DET) progress logging only; no round arithmetic
    let _t = Instant::now();
    order.values().sum()
}
