// Known-good twin of bad_safety.rs: the impl carries its argument, in
// the same stacked-comment shape runtime/mod.rs uses.

pub struct Handle(*mut u8);

// SAFETY: the pointer is only ever dereferenced behind a global lock,
// and construction/drop stay on the owning thread.
unsafe impl Send for Handle {}
