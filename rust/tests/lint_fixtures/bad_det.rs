// Known-bad fixture for INV-DET: wall-clock and hash-order reads in a
// bit-parity decision path (the analyzer test lints this under the
// virtual path rust/src/ps/fixture.rs).

use std::collections::HashMap;
use std::time::Instant;

pub fn pick(order: &HashMap<u32, f32>) -> f32 {
    let _t = Instant::now();
    order.values().sum()
}
