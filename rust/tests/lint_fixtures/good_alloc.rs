// Known-good twin of bad_alloc.rs: the hot function writes into a
// caller-provided buffer; allocation happens once, in cold setup code
// outside the annotated span.

// qadam: hotpath
pub fn unpack_hot(src: &[f32], out: &mut [f32]) {
    out.copy_from_slice(src);
}

pub fn setup(n: usize) -> Vec<f32> {
    vec![0.0; n]
}
