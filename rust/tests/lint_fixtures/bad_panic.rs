// Known-bad fixture for INV-PANIC: a decode function (in scope by its
// `*_from_bytes` name alone) that indexes directly and unwraps, so a
// short frame panics instead of returning an error.

pub fn header_from_bytes(b: &[u8]) -> (u8, u32) {
    let kind = b[0];
    let len = u32::from_le_bytes(b[1..5].try_into().unwrap());
    (kind, len)
}
