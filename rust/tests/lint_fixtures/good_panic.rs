// Known-good twin of bad_panic.rs: Option-returning reads all the way
// down — a short frame yields `None`, never a panic.

// qadam: decode
pub fn header_from_bytes(b: &[u8]) -> Option<(u8, u32)> {
    let kind = *b.first()?;
    let len = b.get(1..5).and_then(|s| s.try_into().ok()).map(u32::from_le_bytes)?;
    Some((kind, len))
}
