// Known-bad fixture for INV-ALLOC: a `// qadam: hotpath` function that
// allocates on every call. `lint_analyzer.rs` feeds this file through
// `analysis::check_file` and asserts the rule fires.

// qadam: hotpath
pub fn unpack_hot(src: &[f32], out: &mut Vec<f32>) {
    *out = src.to_vec();
}
