//! Blockwise sign compression (Zheng et al. [44]) — the *biased*
//! baseline of Tables 2–3 ("communication-efficient distributed
//! blockwise momentum SGD with error-feedback").
//!
//! The update vector is split into fixed-size blocks; each block is
//! transmitted as `sign(u_i) * mean(|u_block|)`:
//!
//! ```text
//!   Q(u)_i = s_b * sign(u_i),   s_b = mean_{j in block(i)} |u_j|
//! ```
//!
//! Bias is compensated by worker-side error feedback (composed via
//! [`crate::quant::ErrorFeedback`], exactly as in the source paper).
//!
//! Wire format: one f32 scale per block + 1-bit sign codes. With the
//! default block of 4096 the overhead is 1.008 bits/element — the
//! paper's Comm columns for [44] round this to the same MB as 1-bit.

use super::pack::{for_each_chunk, BitWriter, Packed};
use super::{CodecId, Compressor, WireMsg};
use crate::util::DetRng;

#[derive(Clone, Copy, Debug)]
pub struct Blockwise {
    pub block: usize,
}

impl Default for Blockwise {
    fn default() -> Self {
        Self { block: 4096 }
    }
}

impl Blockwise {
    pub fn new(block: usize) -> Self {
        assert!(block > 0);
        Self { block }
    }

    /// Fused unpack+decode; `ADD` accumulates into `out` (the server's
    /// decode→sum fusion). The per-element scale lookup keeps the old
    /// global-position indexing, so ragged tails and ranges that start
    /// mid-block decode identically.
    // qadam: hotpath
    fn decode_range_impl<const ADD: bool>(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        let p = msg.codes.as_ref().expect("blockwise msg has codes");
        for_each_chunk(p, start, out.len(), |o, chunk| {
            for (j, &c) in chunk.iter().enumerate() {
                let s = msg.scales[(start + o + j) / self.block];
                let v = if c == 0 { -s } else { s };
                if ADD {
                    out[o + j] += v;
                } else {
                    out[o + j] = v;
                }
            }
        });
    }

    /// `decompress_range` that accumulates (`out[i] += decoded`).
    pub fn decompress_range_add(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        self.decode_range_impl::<true>(msg, start, out);
    }
}

impl Compressor for Blockwise {
    fn name(&self) -> &'static str {
        "blockwise-ef"
    }
    fn codec(&self) -> CodecId {
        CodecId::Blockwise
    }

    fn compress_into(&self, u: &[f32], q: &mut [f32], _rng: &mut DetRng) -> WireMsg {
        // Fused scale + sign + bit-pack: one streaming writer runs
        // across all blocks (no intermediate Vec<u32>); the per-block
        // scale keeps its order-sensitive serial sum.
        let n = u.len();
        let nblocks = n.div_ceil(self.block);
        let mut scales = Vec::with_capacity(nblocks);
        let mut words = vec![0u64; n.div_ceil(64)];
        let mut wtr = BitWriter::new(&mut words, 1);
        for (bi, chunk) in u.chunks(self.block).enumerate() {
            let s = chunk.iter().map(|x| x.abs()).sum::<f32>() / chunk.len() as f32;
            scales.push(s);
            let base = bi * self.block;
            for (j, &ui) in chunk.iter().enumerate() {
                // sign convention: >= 0 -> +s (code 1), < 0 -> -s (code 0)
                if ui < 0.0 {
                    q[base + j] = -s;
                    wtr.push(0);
                } else {
                    q[base + j] = s;
                    wtr.push(1);
                }
            }
        }
        wtr.finish();
        WireMsg {
            codec: CodecId::Blockwise,
            param: self.block as u32,
            n,
            scales,
            codes: Some(Packed { bits: 1, n, words }),
            raw: vec![],
        }
    }

    fn decompress(&self, msg: &WireMsg, out: &mut [f32]) {
        let p = msg.codes.as_ref().expect("blockwise msg has codes");
        assert_eq!(out.len(), p.n);
        self.decompress_range(msg, 0, out);
    }

    fn decompress_range(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        self.decode_range_impl::<false>(msg, start, out);
    }

    fn bits_per_element(&self) -> f64 {
        1.0 + 32.0 / self.block as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::seeded_rng;

    #[test]
    fn block_scale_is_mean_abs() {
        let u = vec![1.0f32, -1.0, 3.0, -3.0, /* block 2 */ 0.5, 0.5];
        let bw = Blockwise::new(4);
        let mut q = vec![0.0; 6];
        let mut rng = seeded_rng(0, 0);
        let msg = bw.compress_into(&u, &mut q, &mut rng);
        assert_eq!(msg.scales, vec![2.0, 0.5]);
        assert_eq!(q, vec![2.0, -2.0, 2.0, -2.0, 0.5, 0.5]);
    }

    #[test]
    fn bits_accounting() {
        let bw = Blockwise::new(4096);
        assert!((bw.bits_per_element() - 1.0078).abs() < 1e-3);
    }

    /// Property: worker-local q == server-decoded values across block
    /// sizes and ragged lengths.
    #[test]
    fn decode_identity_prop() {
        for block in [1usize, 2, 3, 7, 16, 63] {
            for seed in 0..6u64 {
                let n = 1 + ((seed as usize * 53 + block * 11) % 300);
                let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1);
                let u: Vec<f32> = (0..n)
                    .map(|_| {
                        s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                        ((s >> 33) as i32 as f32) / (1u32 << 31) as f32
                    })
                    .collect();
                let bw = Blockwise::new(block);
                let mut q = vec![0.0; n];
                let mut rng = seeded_rng(0, 0);
                let msg = bw.compress_into(&u, &mut q, &mut rng);
                let mut out = vec![0.0; n];
                bw.decompress(&msg, &mut out);
                assert_eq!(q, out, "block={block} seed={seed}");
            }
        }
    }
}
