//! TernGrad (Wen et al. [39]) — the *unbiased* ternary baseline of
//! Tables 2–3.
//!
//! ```text
//!   Q(g)_i = s * sign(g_i) * b_i,   s = ||g||_inf,
//!   b_i ~ Bernoulli(|g_i| / s)
//! ```
//!
//! `E[Q(g)] = g` (unbiasedness is what lets TernGrad converge without
//! error feedback, at the price of extra variance — the effect the
//! paper's experiments show as lower accuracy than QAdam+EF).
//!
//! Wire format: one f32 scale + 2-bit codes over `{-1, 0, +1}`.

use super::pack::{for_each_chunk, BitWriter, Packed};
use super::{CodecId, Compressor, WireMsg};
use crate::util::DetRng;

#[derive(Clone, Copy, Debug, Default)]
pub struct TernGrad;

impl TernGrad {
    /// Fused unpack+decode; `ADD` accumulates into `out` (the server's
    /// decode→sum fusion). Codes map through a 4-entry table
    /// `[-s, 0, s, s]` — the (never emitted) code 3 decodes to `s`
    /// exactly as the old `match` fallthrough did.
    // qadam: hotpath
    fn decode_range_impl<const ADD: bool>(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        let p = msg.codes.as_ref().expect("terngrad msg has codes");
        let s = msg.scales[0];
        if p.bits == 2 {
            let table = [-s, 0.0, s, s];
            for_each_chunk(p, start, out.len(), |o, chunk| {
                let dst = &mut out[o..o + chunk.len()];
                if ADD {
                    for (d, &c) in dst.iter_mut().zip(chunk) {
                        *d += table[c as usize];
                    }
                } else {
                    for (d, &c) in dst.iter_mut().zip(chunk) {
                        *d = table[c as usize];
                    }
                }
            });
        } else {
            // Never off the wire (width is validated); in-process odd
            // messages keep the old code→value map.
            for_each_chunk(p, start, out.len(), |o, chunk| {
                for (j, &c) in chunk.iter().enumerate() {
                    let v = match c {
                        0 => -s,
                        1 => 0.0,
                        _ => s,
                    };
                    if ADD {
                        out[o + j] += v;
                    } else {
                        out[o + j] = v;
                    }
                }
            });
        }
    }

    /// `decompress_range` that accumulates (`out[i] += decoded`).
    pub fn decompress_range_add(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        self.decode_range_impl::<true>(msg, start, out);
    }
}

impl Compressor for TernGrad {
    fn name(&self) -> &'static str {
        "terngrad"
    }
    fn codec(&self) -> CodecId {
        CodecId::TernGrad
    }

    fn compress_into(&self, u: &[f32], q: &mut [f32], rng: &mut DetRng) -> WireMsg {
        // Fused quantize + bit-pack: one pass over `u`, codes streamed
        // straight into the packed words (no intermediate Vec<u32>).
        let n = u.len();
        let s = u.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let mut words = vec![0u64; (n * 2).div_ceil(64)];
        let mut wtr = BitWriter::new(&mut words, 2);
        if s == 0.0 {
            q.fill(0.0);
            for _ in 0..n {
                wtr.push(1);
            }
        } else {
            let inv_s = 1.0 / s;
            for (qi, &ui) in q.iter_mut().zip(u) {
                let p = ui.abs() * inv_s;
                let hit = rng.gen_f32() < p;
                let code = if hit {
                    if ui < 0.0 {
                        *qi = -s;
                        0
                    } else {
                        *qi = s;
                        2
                    }
                } else {
                    *qi = 0.0;
                    1
                };
                wtr.push(code);
            }
        }
        wtr.finish();
        WireMsg {
            codec: CodecId::TernGrad,
            param: 0,
            n,
            scales: vec![s],
            codes: Some(Packed { bits: 2, n, words }),
            raw: vec![],
        }
    }

    fn decompress(&self, msg: &WireMsg, out: &mut [f32]) {
        let p = msg.codes.as_ref().expect("terngrad msg has codes");
        assert_eq!(out.len(), p.n);
        self.decompress_range(msg, 0, out);
    }

    fn decompress_range(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        self.decode_range_impl::<false>(msg, start, out);
    }

    fn bits_per_element(&self) -> f64 {
        2.0
    }

    fn is_unbiased(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::seeded_rng;

    #[test]
    fn outputs_are_ternary_and_decode_identity() {
        let u: Vec<f32> = (0..500).map(|i| ((i * 31 % 101) as f32 - 50.0) / 17.0).collect();
        let mut q = vec![0.0; u.len()];
        let mut rng = seeded_rng(7, 0);
        let msg = TernGrad.compress_into(&u, &mut q, &mut rng);
        let s = msg.scales[0];
        for &qi in &q {
            assert!(qi == 0.0 || qi == s || qi == -s);
        }
        let mut out = vec![0.0; u.len()];
        TernGrad.decompress(&msg, &mut out);
        assert_eq!(q, out);
    }

    #[test]
    fn unbiased_in_expectation() {
        // Average many independent quantizations; should approach u.
        let u = vec![0.8f32, -0.3, 0.05, 0.0, 1.0, -1.0];
        let mut acc = vec![0.0f64; u.len()];
        let trials = 20_000;
        for t in 0..trials {
            let mut q = vec![0.0; u.len()];
            let mut rng = seeded_rng(42, t);
            TernGrad.compress_into(&u, &mut q, &mut rng);
            for (a, &qi) in acc.iter_mut().zip(&q) {
                *a += qi as f64;
            }
        }
        for (a, &ui) in acc.iter().zip(&u) {
            let mean = a / trials as f64;
            assert!((mean - ui as f64).abs() < 0.02, "mean={mean} u={ui}");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let u = vec![0.5f32, -0.25, 0.9];
        let run = |seed| {
            let mut q = vec![0.0; 3];
            let mut rng = seeded_rng(seed, 3);
            TernGrad.compress_into(&u, &mut q, &mut rng);
            q
        };
        assert_eq!(run(5), run(5));
    }

    #[test]
    fn zero_vector() {
        let mut q = vec![1.0f32; 8];
        let mut rng = seeded_rng(0, 0);
        let msg = TernGrad.compress_into(&[0.0; 8], &mut q, &mut rng);
        assert!(q.iter().all(|&x| x == 0.0));
        assert_eq!(msg.scales[0], 0.0);
    }
}
