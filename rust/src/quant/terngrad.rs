//! TernGrad (Wen et al. [39]) — the *unbiased* ternary baseline of
//! Tables 2–3.
//!
//! ```text
//!   Q(g)_i = s * sign(g_i) * b_i,   s = ||g||_inf,
//!   b_i ~ Bernoulli(|g_i| / s)
//! ```
//!
//! `E[Q(g)] = g` (unbiasedness is what lets TernGrad converge without
//! error feedback, at the price of extra variance — the effect the
//! paper's experiments show as lower accuracy than QAdam+EF).
//!
//! Wire format: one f32 scale + 2-bit codes over `{-1, 0, +1}`.

use super::pack::{pack, unpack_range_into};
use super::{CodecId, Compressor, WireMsg};
use crate::util::DetRng;

#[derive(Clone, Copy, Debug, Default)]
pub struct TernGrad;

impl Compressor for TernGrad {
    fn name(&self) -> &'static str {
        "terngrad"
    }
    fn codec(&self) -> CodecId {
        CodecId::TernGrad
    }

    fn compress_into(&self, u: &[f32], q: &mut [f32], rng: &mut DetRng) -> WireMsg {
        let s = u.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let mut codes = Vec::with_capacity(u.len());
        if s == 0.0 {
            q.fill(0.0);
            codes.resize(u.len(), 1u32);
        } else {
            let inv_s = 1.0 / s;
            for (qi, &ui) in q.iter_mut().zip(u) {
                let p = ui.abs() * inv_s;
                let hit = rng.gen_f32() < p;
                if hit {
                    if ui < 0.0 {
                        *qi = -s;
                        codes.push(0);
                    } else {
                        *qi = s;
                        codes.push(2);
                    }
                } else {
                    *qi = 0.0;
                    codes.push(1);
                }
            }
        }
        WireMsg {
            codec: CodecId::TernGrad,
            param: 0,
            n: u.len(),
            scales: vec![s],
            codes: Some(pack(&codes, 2)),
            raw: vec![],
        }
    }

    fn decompress(&self, msg: &WireMsg, out: &mut [f32]) {
        let p = msg.codes.as_ref().expect("terngrad msg has codes");
        assert_eq!(out.len(), p.n);
        self.decompress_range(msg, 0, out);
    }

    fn decompress_range(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        let p = msg.codes.as_ref().expect("terngrad msg has codes");
        let s = msg.scales[0];
        let mut codes = vec![0u32; out.len()];
        unpack_range_into(p, start, &mut codes);
        for (o, c) in out.iter_mut().zip(codes) {
            *o = match c {
                0 => -s,
                1 => 0.0,
                _ => s,
            };
        }
    }

    fn bits_per_element(&self) -> f64 {
        2.0
    }

    fn is_unbiased(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::seeded_rng;

    #[test]
    fn outputs_are_ternary_and_decode_identity() {
        let u: Vec<f32> = (0..500).map(|i| ((i * 31 % 101) as f32 - 50.0) / 17.0).collect();
        let mut q = vec![0.0; u.len()];
        let mut rng = seeded_rng(7, 0);
        let msg = TernGrad.compress_into(&u, &mut q, &mut rng);
        let s = msg.scales[0];
        for &qi in &q {
            assert!(qi == 0.0 || qi == s || qi == -s);
        }
        let mut out = vec![0.0; u.len()];
        TernGrad.decompress(&msg, &mut out);
        assert_eq!(q, out);
    }

    #[test]
    fn unbiased_in_expectation() {
        // Average many independent quantizations; should approach u.
        let u = vec![0.8f32, -0.3, 0.05, 0.0, 1.0, -1.0];
        let mut acc = vec![0.0f64; u.len()];
        let trials = 20_000;
        for t in 0..trials {
            let mut q = vec![0.0; u.len()];
            let mut rng = seeded_rng(42, t);
            TernGrad.compress_into(&u, &mut q, &mut rng);
            for (a, &qi) in acc.iter_mut().zip(&q) {
                *a += qi as f64;
            }
        }
        for (a, &ui) in acc.iter().zip(&u) {
            let mean = a / trials as f64;
            assert!((mean - ui as f64).abs() < 0.02, "mean={mean} u={ui}");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let u = vec![0.5f32, -0.25, 0.9];
        let run = |seed| {
            let mut q = vec![0.0; 3];
            let mut rng = seeded_rng(seed, 3);
            TernGrad.compress_into(&u, &mut q, &mut rng);
            q
        };
        assert_eq!(run(5), run(5));
    }

    #[test]
    fn zero_vector() {
        let mut q = vec![1.0f32; 8];
        let mut rng = seeded_rng(0, 0);
        let msg = TernGrad.compress_into(&[0.0; 8], &mut q, &mut rng);
        assert!(q.iter().all(|&x| x == 0.0));
        assert_eq!(msg.scales[0], 0.0);
    }
}
