//! Retained scalar reference kernels.
//!
//! These are byte-for-byte copies of the codec kernels as they existed
//! *before* the fused/streaming rewrite of the hot path (see DESIGN.md
//! §Hot path & memory discipline): the read-modify-write bit packer,
//! the two-load-per-code range unpacker, and each codec's
//! allocate-then-pack compress / unpack-then-decode decompress.
//!
//! They exist for two reasons and sit on no production path:
//!
//! * `rust/tests/kernel_equiv.rs` asserts the production kernels are
//!   bit-identical to these references across lengths (including
//!   non-lane-multiple tails), extreme values, and every supported bit
//!   level — the stochastic codecs consume the *same* rng sequence by
//!   construction, so equality is exact, not statistical.
//! * `benches/quant_micro.rs` times them as the `(ref)` baselines the
//!   committed `BENCH_quant_micro.json` speedups are measured against.
//!
//! Do not "improve" this module: its value is that it does not change.

use super::pack::{bits_for_symbols, Packed};
use super::{CodecId, WireMsg};
use crate::util::DetRng;

/// Pre-rewrite packer: read-modify-write into the word array, up to two
/// word updates per code.
pub fn pack_ref(codes: &[u32], bits: u8) -> Packed {
    debug_assert!((1..=32).contains(&bits));
    let b = bits as usize;
    let nwords = (codes.len() * b).div_ceil(64);
    let mut words = vec![0u64; nwords];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!(bits == 32 || c < (1u32 << bits));
        let w = bitpos >> 6;
        let off = bitpos & 63;
        words[w] |= (c as u64) << off;
        if off + b > 64 {
            words[w + 1] |= (c as u64) >> (64 - off);
        }
        bitpos += b;
    }
    Packed { bits, n: codes.len(), words }
}

/// Pre-rewrite range unpacker: recomputes the word index and reads up
/// to two words for every code.
pub fn unpack_range_ref(p: &Packed, start: usize, out: &mut [u32]) {
    assert!(start + out.len() <= p.n, "range {}+{} out of {} codes", start, out.len(), p.n);
    let b = p.bits as usize;
    let mask = if p.bits == 32 { u32::MAX } else { (1u32 << p.bits) - 1 };
    let mut bitpos = start * b;
    for o in out.iter_mut() {
        let w = bitpos >> 6;
        let off = bitpos & 63;
        let mut v = (p.words[w] >> off) as u32;
        if off + b > 64 {
            v |= (p.words[w + 1] << (64 - off)) as u32;
        }
        *o = v & mask;
        bitpos += b;
    }
}

/// Pre-rewrite `LogQuant::decode_symbol`.
#[inline]
pub fn logquant_decode_symbol_ref(kg: u32, code: u32, s: f32) -> f32 {
    let bias = (kg + 1) as i32;
    let sym = code as i32 - bias; // in [-(kg+1), kg+1]
    if sym == 0 {
        0.0
    } else {
        let m = sym.abs() - bias; // in [-kg, 0]
        let level = f32::exp2(m as f32) * s;
        if sym < 0 {
            -level
        } else {
            level
        }
    }
}

/// Pre-rewrite `LogQuant::compress_into` (the inline read-modify-write
/// bit writer it carried before the shared streaming writer existed).
pub fn logquant_compress_ref(kg: u32, u: &[f32], q: &mut [f32]) -> WireMsg {
    assert_eq!(u.len(), q.len());
    let n = u.len();
    let bits = bits_for_symbols(2 * (kg + 1) + 1) as usize;
    let mut words = vec![0u64; (n * bits).div_ceil(64)];
    let bias = (kg + 1) as i32;
    let s = u.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    if s == 0.0 || !s.is_finite() {
        q.fill(0.0);
        // all-zero symbols: code = bias everywhere
        let mut bitpos = 0usize;
        for _ in 0..n {
            let w = bitpos >> 6;
            let off = bitpos & 63;
            words[w] |= (bias as u64) << off;
            if off + bits > 64 {
                words[w + 1] |= (bias as u64) >> (64 - off);
            }
            bitpos += bits;
        }
        return WireMsg {
            codec: CodecId::LogQuant,
            param: kg,
            n,
            scales: vec![if s.is_finite() { s } else { f32::NAN }],
            codes: Some(Packed { bits: bits as u8, n, words }),
            raw: vec![],
        };
    }
    let inv_s = 1.0 / s;
    let kg = kg as i32;
    let zero_thresh = f32::exp2(-(kg + 1) as f32);
    let mut bitpos = 0usize;
    for (qi, &ui) in q.iter_mut().zip(u.iter()) {
        let a = (ui.abs() * inv_s).min(1.0);
        let (qv, code): (f32, u32) = if a < zero_thresh {
            (0.0, bias as u32)
        } else {
            let b = a.to_bits();
            let mut m = ((b >> 23) & 0xff) as i32 - 127;
            if m < -kg {
                m = -kg;
            } else if (b & 0x7f_ffff) >= 0x40_0000 && m < 0 {
                m += 1;
            }
            let m = m.min(0);
            let level = f32::from_bits(((m + 127) as u32) << 23); // 2^m exactly
            if ui < 0.0 {
                (-level * s, (bias - (m + bias)) as u32)
            } else {
                (level * s, (bias + (m + bias)) as u32)
            }
        };
        *qi = qv;
        let w = bitpos >> 6;
        let off = bitpos & 63;
        words[w] |= (code as u64) << off;
        if off + bits > 64 {
            words[w + 1] |= (code as u64) >> (64 - off);
        }
        bitpos += bits;
    }
    WireMsg {
        codec: CodecId::LogQuant,
        param: kg as u32,
        n,
        scales: vec![s],
        codes: Some(Packed { bits: bits as u8, n, words }),
        raw: vec![],
    }
}

/// Pre-rewrite `LogQuant::decompress_range`: allocate a codes buffer,
/// unpack, then decode symbol by symbol (`k_g` from the wire param).
pub fn logquant_decompress_range_ref(msg: &WireMsg, start: usize, out: &mut [f32]) {
    let kg = msg.param & 0xff;
    let p: &Packed = msg.codes.as_ref().expect("logquant msg has codes");
    let mut codes = vec![0u32; out.len()];
    unpack_range_ref(p, start, &mut codes);
    if msg.scales.len() == 1 {
        let s = msg.scales[0];
        for (o, c) in out.iter_mut().zip(codes) {
            *o = logquant_decode_symbol_ref(kg, c, s);
        }
    } else {
        // Multi-scale (per-chunk) message from the PJRT kernel path:
        // block size is 2^(param >> 8); scales are indexed by the
        // element's *global* position.
        let block = 1usize << (msg.param >> 8);
        for (j, (o, c)) in out.iter_mut().zip(codes).enumerate() {
            *o = logquant_decode_symbol_ref(kg, c, msg.scales[(start + j) / block]);
        }
    }
}

/// Pre-rewrite `StochasticLogQuant::compress_into`: codes `Vec` then a
/// separate pack pass. Consumes the rng in exactly the same order as
/// the production kernel.
pub fn stochastic_log_compress_ref(kg: u32, u: &[f32], q: &mut [f32], rng: &mut DetRng) -> WireMsg {
    let kgi = kg as i32;
    let bias = (kg + 1) as i32;
    let s = u.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let mut codes = Vec::with_capacity(u.len());
    if s == 0.0 {
        q.fill(0.0);
        codes.resize(u.len(), bias as u32);
    } else {
        let inv_s = 1.0 / s;
        let lo = f32::exp2(-kgi as f32);
        for (qi, &ui) in q.iter_mut().zip(u) {
            let a = (ui.abs() * inv_s).min(1.0);
            let (level, m): (f32, i32) = if a < lo {
                // randomize between 0 and the smallest level with
                // p = a/lo so the expectation is a
                if rng.gen_f32() < a / lo {
                    (lo, -kgi)
                } else {
                    (0.0, i32::MIN)
                }
            } else {
                // bracket [2^m, 2^(m+1)); round up w.p. (a-low)/(low)
                let b = a.to_bits();
                let mm = (((b >> 23) & 0xff) as i32 - 127).clamp(-kgi, 0);
                let low = f32::from_bits(((mm + 127) as u32) << 23);
                let hi_m = (mm + 1).min(0);
                let high = f32::from_bits(((hi_m + 127) as u32) << 23);
                if high > low && rng.gen_f32() < (a - low) / (high - low) {
                    (high, hi_m)
                } else {
                    (low, mm)
                }
            };
            if level == 0.0 {
                *qi = 0.0;
                codes.push(bias as u32);
            } else {
                let sym = (m + bias) * if ui < 0.0 { -1 } else { 1 };
                *qi = level * s * if ui < 0.0 { -1.0 } else { 1.0 };
                codes.push((sym + bias) as u32);
            }
        }
    }
    WireMsg {
        codec: CodecId::LogQuant,
        param: kg,
        n: u.len(),
        scales: vec![s],
        codes: Some(pack_ref(&codes, bits_for_symbols(2 * (kg + 1) + 1))),
        raw: vec![],
    }
}

/// Pre-rewrite `Qsgd::compress_into`: codes `Vec` then pack.
pub fn qsgd_compress_ref(levels: u32, u: &[f32], q: &mut [f32], rng: &mut DetRng) -> WireMsg {
    let l = levels as f32;
    let bias = levels as i32;
    let s = u.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let mut codes = Vec::with_capacity(u.len());
    if s == 0.0 {
        q.fill(0.0);
        codes.resize(u.len(), bias as u32);
    } else {
        let inv_s = 1.0 / s;
        for (qi, &ui) in q.iter_mut().zip(u) {
            let a = (ui.abs() * inv_s).min(1.0) * l; // in [0, L]
            let fl = a.floor();
            let idx = fl as i32 + i32::from(rng.gen_f32() < a - fl);
            let idx = idx.min(bias);
            let val = idx as f32 / l * s;
            if ui < 0.0 {
                *qi = -val;
                codes.push((bias - idx) as u32);
            } else {
                *qi = val;
                codes.push((bias + idx) as u32);
            }
        }
    }
    WireMsg {
        codec: CodecId::Qsgd,
        param: levels,
        n: u.len(),
        scales: vec![s],
        codes: Some(pack_ref(&codes, bits_for_symbols(2 * levels + 1))),
        raw: vec![],
    }
}

/// Pre-rewrite `Qsgd::decompress_range`.
pub fn qsgd_decompress_range_ref(msg: &WireMsg, start: usize, out: &mut [f32]) {
    let p = msg.codes.as_ref().expect("qsgd msg has codes");
    let s = msg.scales[0];
    let bias = msg.param as i32;
    let l = msg.param as f32;
    let mut codes = vec![0u32; out.len()];
    unpack_range_ref(p, start, &mut codes);
    for (o, c) in out.iter_mut().zip(codes) {
        *o = (c as i32 - bias) as f32 / l * s;
    }
}

/// Pre-rewrite `TernGrad::compress_into`.
pub fn terngrad_compress_ref(u: &[f32], q: &mut [f32], rng: &mut DetRng) -> WireMsg {
    let s = u.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let mut codes = Vec::with_capacity(u.len());
    if s == 0.0 {
        q.fill(0.0);
        codes.resize(u.len(), 1u32);
    } else {
        let inv_s = 1.0 / s;
        for (qi, &ui) in q.iter_mut().zip(u) {
            let p = ui.abs() * inv_s;
            let hit = rng.gen_f32() < p;
            if hit {
                if ui < 0.0 {
                    *qi = -s;
                    codes.push(0);
                } else {
                    *qi = s;
                    codes.push(2);
                }
            } else {
                *qi = 0.0;
                codes.push(1);
            }
        }
    }
    WireMsg {
        codec: CodecId::TernGrad,
        param: 0,
        n: u.len(),
        scales: vec![s],
        codes: Some(pack_ref(&codes, 2)),
        raw: vec![],
    }
}

/// Pre-rewrite `TernGrad::decompress_range`.
pub fn terngrad_decompress_range_ref(msg: &WireMsg, start: usize, out: &mut [f32]) {
    let p = msg.codes.as_ref().expect("terngrad msg has codes");
    let s = msg.scales[0];
    let mut codes = vec![0u32; out.len()];
    unpack_range_ref(p, start, &mut codes);
    for (o, c) in out.iter_mut().zip(codes) {
        *o = match c {
            0 => -s,
            1 => 0.0,
            _ => s,
        };
    }
}

/// Pre-rewrite `WQuant::compress_into`: codes `Vec` through
/// `encode_into` then pack.
pub fn wquant_compress_ref(kx: u32, u: &[f32], q: &mut [f32]) -> WireMsg {
    let scale = (1u32 << kx) as f32;
    let bias = 1i32 << kx;
    let mut codes = vec![0u32; u.len()];
    for ((&xi, qi), ci) in u.iter().zip(q.iter_mut()).zip(codes.iter_mut()) {
        let idx = ((2.0 * xi).clamp(-1.0, 1.0) * scale).round() as i32;
        *qi = 0.5 * idx as f32 / bias as f32;
        *ci = (idx + bias) as u32;
    }
    WireMsg {
        codec: CodecId::WQuant,
        param: kx,
        n: u.len(),
        scales: vec![],
        codes: Some(pack_ref(&codes, bits_for_symbols(2 * (1 << kx) + 1))),
        raw: vec![],
    }
}

/// Pre-rewrite `WQuant::decompress_range`.
pub fn wquant_decompress_range_ref(kx: u32, msg: &WireMsg, start: usize, out: &mut [f32]) {
    let p = msg.codes.as_ref().expect("wquant msg has codes");
    let bias = 1i32 << kx;
    let mut codes = vec![0u32; out.len()];
    unpack_range_ref(p, start, &mut codes);
    for (o, c) in out.iter_mut().zip(codes) {
        *o = 0.5 * (c as i32 - bias) as f32 / bias as f32;
    }
}

/// Pre-rewrite `Blockwise::compress_into`.
pub fn blockwise_compress_ref(block: usize, u: &[f32], q: &mut [f32]) -> WireMsg {
    let nblocks = u.len().div_ceil(block);
    let mut scales = Vec::with_capacity(nblocks);
    let mut codes = Vec::with_capacity(u.len());
    for (bi, chunk) in u.chunks(block).enumerate() {
        let s = chunk.iter().map(|x| x.abs()).sum::<f32>() / chunk.len() as f32;
        scales.push(s);
        let base = bi * block;
        for (j, &ui) in chunk.iter().enumerate() {
            // sign convention: >= 0 -> +s (code 1), < 0 -> -s (code 0)
            if ui < 0.0 {
                q[base + j] = -s;
                codes.push(0);
            } else {
                q[base + j] = s;
                codes.push(1);
            }
        }
    }
    WireMsg {
        codec: CodecId::Blockwise,
        param: block as u32,
        n: u.len(),
        scales,
        codes: Some(pack_ref(&codes, 1)),
        raw: vec![],
    }
}

/// Pre-rewrite `Blockwise::decompress_range`.
pub fn blockwise_decompress_range_ref(block: usize, msg: &WireMsg, start: usize, out: &mut [f32]) {
    let p = msg.codes.as_ref().expect("blockwise msg has codes");
    let mut codes = vec![0u32; out.len()];
    unpack_range_ref(p, start, &mut codes);
    for (j, (o, c)) in out.iter_mut().zip(codes).enumerate() {
        // scales are indexed by the element's global position
        let s = msg.scales[(start + j) / block];
        *o = if c == 0 { -s } else { s };
    }
}
