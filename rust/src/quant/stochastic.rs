//! Unbiased stochastic variants — the other side of the paper's
//! biased-vs-unbiased design axis (§2.1, Table 1).
//!
//! * [`StochasticLogQuant`] — the same power-of-two codebook as the
//!   paper's `Q_g`, but with *stochastic rounding* between adjacent
//!   levels so that `E[Q(u)] = u` elementwise (for `|y| ≥ 2^-k_g`;
//!   below the smallest level it randomizes between 0 and `2^-k_g`).
//!   Used by the ablation bench to isolate what the paper's
//!   deterministic-nearest + error-feedback choice buys over an
//!   unbiased codec of the *same* bit-width.
//! * [`Qsgd`] — QSGD-style uniform-level stochastic quantizer
//!   (Alistarh et al.), the standard unbiased linear-grid comparator:
//!   levels `{0, 1/L, …, 1}·‖u‖_inf` with stochastic rounding.
//!
//! Both are unbiased, so the baselines using them run without error
//! feedback (mirroring TernGrad).

use super::pack::{bits_for_symbols, for_each_chunk, BitWriter, Packed};
use super::{CodecId, Compressor, WireMsg};
use crate::util::DetRng;

/// Stochastic-rounding log quantizer (unbiased; same wire format as
/// [`super::LogQuant`], reusing its codec id and symbol map).
#[derive(Clone, Copy, Debug)]
pub struct StochasticLogQuant {
    pub kg: u32,
}

impl StochasticLogQuant {
    pub fn new(kg: u32) -> Self {
        assert!(kg <= super::MAX_KG, "kg={kg} out of range");
        Self { kg }
    }

    fn inner(&self) -> super::LogQuant {
        super::LogQuant::new(self.kg)
    }
}

impl Compressor for StochasticLogQuant {
    fn name(&self) -> &'static str {
        "logquant-stochastic"
    }
    fn codec(&self) -> CodecId {
        CodecId::LogQuant // same decode map as LogQuant
    }

    fn compress_into(&self, u: &[f32], q: &mut [f32], rng: &mut DetRng) -> WireMsg {
        // Fused quantize + bit-pack: one pass, codes streamed straight
        // into the packed words (no intermediate Vec<u32>). The rng is
        // consumed in exactly the pre-fusion order (see
        // `reference::stochastic_log_compress_ref`).
        let n = u.len();
        let kg = self.kg as i32;
        let bias = (self.kg + 1) as i32;
        let bits = self.inner().code_bits();
        let s = u.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let mut words = vec![0u64; (n * bits as usize).div_ceil(64)];
        let mut wtr = BitWriter::new(&mut words, bits);
        if s == 0.0 {
            q.fill(0.0);
            for _ in 0..n {
                wtr.push(bias as u32);
            }
        } else {
            let inv_s = 1.0 / s;
            let lo = f32::exp2(-kg as f32);
            for (qi, &ui) in q.iter_mut().zip(u) {
                let a = (ui.abs() * inv_s).min(1.0);
                let (level, m): (f32, i32) = if a < lo {
                    // randomize between 0 and the smallest level with
                    // p = a/lo so the expectation is a
                    if rng.gen_f32() < a / lo {
                        (lo, -kg)
                    } else {
                        (0.0, i32::MIN)
                    }
                } else {
                    // bracket [2^m, 2^(m+1)); round up w.p. (a-low)/(low)
                    let b = a.to_bits();
                    let mm = (((b >> 23) & 0xff) as i32 - 127).clamp(-kg, 0);
                    let low = f32::from_bits(((mm + 127) as u32) << 23);
                    let hi_m = (mm + 1).min(0);
                    let high = f32::from_bits(((hi_m + 127) as u32) << 23);
                    if high > low && rng.gen_f32() < (a - low) / (high - low) {
                        (high, hi_m)
                    } else {
                        (low, mm)
                    }
                };
                if level == 0.0 {
                    *qi = 0.0;
                    wtr.push(bias as u32);
                } else {
                    let sym = (m + bias) * if ui < 0.0 { -1 } else { 1 };
                    *qi = level * s * if ui < 0.0 { -1.0 } else { 1.0 };
                    wtr.push((sym + bias) as u32);
                }
            }
        }
        wtr.finish();
        WireMsg {
            codec: CodecId::LogQuant,
            param: self.kg,
            n,
            scales: vec![s],
            codes: Some(Packed { bits, n, words }),
            raw: vec![],
        }
    }

    fn decompress(&self, msg: &WireMsg, out: &mut [f32]) {
        self.inner().decompress(msg, out)
    }

    fn decompress_range(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        self.inner().decompress_range(msg, start, out)
    }

    fn bits_per_element(&self) -> f64 {
        self.inner().code_bits() as f64
    }

    fn is_unbiased(&self) -> bool {
        true
    }
}

/// QSGD: uniform levels `{0, 1/levels, ..., 1}·‖u‖_inf`, stochastic
/// rounding, sign carried separately in the symbol.
#[derive(Clone, Copy, Debug)]
pub struct Qsgd {
    /// number of positive levels L (codebook size 2L+1).
    pub levels: u32,
}

impl Qsgd {
    pub fn new(levels: u32) -> Self {
        assert!(levels >= 1 && levels <= 1 << 15);
        Self { levels }
    }

    pub fn code_bits(&self) -> u8 {
        bits_for_symbols(2 * self.levels + 1)
    }

    /// Fused unpack+decode; `ADD` accumulates into `out` (the server's
    /// decode→sum fusion). The per-code arithmetic is byte-identical to
    /// the pre-fusion loop (`(c - bias) / L * s`, division kept).
    // qadam: hotpath
    fn decode_range_impl<const ADD: bool>(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        let p = msg.codes.as_ref().expect("qsgd msg has codes");
        let s = msg.scales[0];
        let bias = msg.param as i32;
        let l = msg.param as f32;
        for_each_chunk(p, start, out.len(), |o, chunk| {
            let dst = &mut out[o..o + chunk.len()];
            if ADD {
                for (d, &c) in dst.iter_mut().zip(chunk) {
                    *d += (c as i32 - bias) as f32 / l * s;
                }
            } else {
                for (d, &c) in dst.iter_mut().zip(chunk) {
                    *d = (c as i32 - bias) as f32 / l * s;
                }
            }
        });
    }

    /// `decompress_range` that accumulates (`out[i] += decoded`).
    pub fn decompress_range_add(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        self.decode_range_impl::<true>(msg, start, out);
    }
}

impl Compressor for Qsgd {
    fn name(&self) -> &'static str {
        "qsgd"
    }
    fn codec(&self) -> CodecId {
        CodecId::Qsgd
    }

    fn compress_into(&self, u: &[f32], q: &mut [f32], rng: &mut DetRng) -> WireMsg {
        // Fused quantize + bit-pack; rng consumption order unchanged
        // (see `reference::qsgd_compress_ref`).
        let n = u.len();
        let l = self.levels as f32;
        let bias = self.levels as i32;
        let bits = self.code_bits();
        let s = u.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let mut words = vec![0u64; (n * bits as usize).div_ceil(64)];
        let mut wtr = BitWriter::new(&mut words, bits);
        if s == 0.0 {
            q.fill(0.0);
            for _ in 0..n {
                wtr.push(bias as u32);
            }
        } else {
            let inv_s = 1.0 / s;
            for (qi, &ui) in q.iter_mut().zip(u) {
                let a = (ui.abs() * inv_s).min(1.0) * l; // in [0, L]
                let fl = a.floor();
                let idx = fl as i32 + i32::from(rng.gen_f32() < a - fl);
                let idx = idx.min(bias);
                let val = idx as f32 / l * s;
                if ui < 0.0 {
                    *qi = -val;
                    wtr.push((bias - idx) as u32);
                } else {
                    *qi = val;
                    wtr.push((bias + idx) as u32);
                }
            }
        }
        wtr.finish();
        WireMsg {
            codec: CodecId::Qsgd,
            param: self.levels,
            n,
            scales: vec![s],
            codes: Some(Packed { bits, n, words }),
            raw: vec![],
        }
    }

    fn decompress(&self, msg: &WireMsg, out: &mut [f32]) {
        let p = msg.codes.as_ref().expect("qsgd msg has codes");
        assert_eq!(out.len(), p.n);
        self.decompress_range(msg, 0, out);
    }

    fn decompress_range(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        self.decode_range_impl::<false>(msg, start, out);
    }

    fn bits_per_element(&self) -> f64 {
        self.code_bits() as f64
    }

    fn is_unbiased(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::seeded_rng;

    fn mean_of_trials(comp: &dyn Compressor, u: &[f32], trials: u64) -> Vec<f64> {
        let mut acc = vec![0.0f64; u.len()];
        for t in 0..trials {
            let mut q = vec![0.0; u.len()];
            let mut rng = seeded_rng(99, t);
            comp.compress_into(u, &mut q, &mut rng);
            for (a, &qi) in acc.iter_mut().zip(&q) {
                *a += qi as f64 / trials as f64;
            }
        }
        acc
    }

    #[test]
    fn stochastic_log_is_unbiased() {
        let u = vec![0.9f32, 0.5, 0.3, 0.11, 0.04, -0.6, -0.02, 1.0, 0.0];
        let mean = mean_of_trials(&StochasticLogQuant::new(2), &u, 30_000);
        for (m, &ui) in mean.iter().zip(&u) {
            assert!((m - ui as f64).abs() < 0.015, "mean={m} u={ui}");
        }
    }

    #[test]
    fn qsgd_is_unbiased() {
        let u = vec![0.9f32, 0.5, 0.3, 0.11, -0.6, -0.02, 1.0, 0.0];
        let mean = mean_of_trials(&Qsgd::new(4), &u, 30_000);
        for (m, &ui) in mean.iter().zip(&u) {
            assert!((m - ui as f64).abs() < 0.015, "mean={m} u={ui}");
        }
    }

    #[test]
    fn stochastic_log_decode_identity_and_same_wire_format() {
        let u: Vec<f32> = (0..200).map(|i| ((i * 37) % 101) as f32 / 50.0 - 1.0).collect();
        let c = StochasticLogQuant::new(3);
        let mut q = vec![0.0; u.len()];
        let mut rng = seeded_rng(1, 1);
        let msg = c.compress_into(&u, &mut q, &mut rng);
        assert_eq!(msg.codec, CodecId::LogQuant);
        let mut out = vec![0.0; u.len()];
        crate::quant::decode_msg(&msg, &mut out);
        assert_eq!(q, out);
        // every value lies on the deterministic LogQuant codebook too
        let s = msg.scales[0];
        for &qi in &q {
            if qi != 0.0 {
                let e = (qi.abs() / s).log2();
                assert!((e - e.round()).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn qsgd_decode_identity_and_bits() {
        let u: Vec<f32> = (0..333).map(|i| (i as f32 * 0.7).sin()).collect();
        let c = Qsgd::new(4); // 9 symbols -> 4 bits
        assert_eq!(c.code_bits(), 4);
        let mut q = vec![0.0; u.len()];
        let mut rng = seeded_rng(2, 2);
        let msg = c.compress_into(&u, &mut q, &mut rng);
        let mut out = vec![0.0; u.len()];
        crate::quant::decode_msg(&msg, &mut out);
        assert_eq!(q, out);
    }

    #[test]
    fn qsgd_levels_are_uniform_grid() {
        let u: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) / 37.0).collect();
        let c = Qsgd::new(8);
        let mut q = vec![0.0; u.len()];
        let mut rng = seeded_rng(3, 3);
        let msg = c.compress_into(&u, &mut q, &mut rng);
        let s = msg.scales[0];
        for &qi in &q {
            let g = qi / s * 8.0;
            assert!((g - g.round()).abs() < 1e-5, "g={g}");
        }
    }
}
