//! `Q_g` — the paper's gradient quantizer (§5.1).
//!
//! Levels are the signed powers of two scaled by the message ∞-norm:
//!
//! ```text
//!   Q_g(g) = ||g||_inf * argmin_{ghat in G^d} || g/||g||_inf - ghat ||
//!   G = {-1, ..., -2^{-k_g}, 0, 2^{-k_g}, 2^{-k_g+1}, ..., 1}
//! ```
//!
//! Nearest level in *linear* distance; ties round up (to the larger
//! magnitude); the zero region is `|y| < 2^{-(k_g+1)}` (midpoint between
//! 0 and the smallest level). This is a **biased, deterministic**
//! compressor: Assumption 2 holds with
//! `||u - Q_g(u)|| <= (1 - delta_g) ||u||`, `delta_g > 0` (tested).
//!
//! `k_g = 0` degenerates to deterministic ternary `{-1, 0, 1}` — the
//! 2-bit rows of Tables 2–3; `k_g = 2` gives 7 symbols — the 3-bit rows.
//!
//! Wire format: one f32 scale + `ceil(log2(2 k_g + 3))`-bit codes.
//! Code map: `0 ⇒ 0`; `c in 1..=k_g+1 ⇒ level 2^(c - 1 - k_g)`; the sign
//! is folded in by storing `signed_symbol + (k_g + 1)`.
//!
//! The hot path avoids `log2` entirely: for normal f32, the IEEE
//! exponent field *is* `floor(log2(|y|))` and the mantissa-half test
//! *is* the `|y| < 1.5·2^m` tie rule, so quantization is a few integer
//! ops per element (exactly matching the Pallas kernel's
//! `floor(log2())` form; see `python/compile/kernels/qadam.py`).

use super::pack::{bits_for_symbols, for_each_chunk, BitWriter, Packed};
use super::{CodecId, Compressor, WireMsg};
use crate::util::DetRng;

#[derive(Clone, Copy, Debug)]
pub struct LogQuant {
    /// Number of fractional levels: smallest positive level is 2^-kg.
    pub kg: u32,
}

impl LogQuant {
    pub fn new(kg: u32) -> Self {
        assert!(kg <= super::MAX_KG, "kg={kg} out of range");
        Self { kg }
    }

    /// Distinct symbols: 2*(kg+1) signed levels + zero.
    pub fn symbols(&self) -> u32 {
        2 * (self.kg + 1) + 1
    }

    pub fn code_bits(&self) -> u8 {
        bits_for_symbols(self.symbols())
    }

    /// Quantize a single normalized magnitude `a = |u|/s` (0 <= a <= 1)
    /// to its level exponent: returns `None` for the zero level, else
    /// `m in [-kg, 0]` meaning level `2^m`.
    #[inline]
    pub fn level_exponent(&self, a: f32) -> Option<i32> {
        let kg = self.kg as i32;
        // zero region: a < 2^-(kg+1)
        if a < f32::exp2(-(kg + 1) as f32) {
            return None;
        }
        let bits = a.to_bits();
        // floor(log2 a) for normals straight from the exponent field.
        let mut m = ((bits >> 23) & 0xff) as i32 - 127;
        // tie rule: upper level when mantissa >= 1.5 (a >= 1.5 * 2^m)
        let frac_high = (bits & 0x7f_ffff) >= 0x40_0000;
        if m < -kg {
            // below the smallest level but above the zero midpoint:
            // 2^-(kg+1) <= a < 2^-kg. Nearest is 2^-kg iff a >= 1.5*2^-(kg+1),
            // i.e. frac_high at exponent -(kg+1); anything lower rounds to
            // the smallest level only if >= midpoint, which the zero test
            // already ensured... but the zero midpoint is 0.5*2^-kg =
            // 2^-(kg+1), so everything here is closer to 2^-kg than to 0?
            // Distance to 0 is a >= 2^-(kg+1); distance to 2^-kg is
            // 2^-kg - a <= 2^-(kg+1). Ties at exactly 2^-(kg+1) go up.
            m = -kg;
            return Some(m);
        }
        if frac_high && m < 0 {
            m += 1;
        }
        // a == 1.0 has m == 0 already; clamp for safety.
        Some(m.min(0))
    }

    /// Quantize `u` into `q` and return (scale, codes).
    /// `codes[i] = signed_symbol + (kg+1)` with signed_symbol in
    /// [-(kg+1), kg+1]; 0-symbol encodes the zero level.
    pub fn quantize(&self, u: &[f32], q: &mut [f32], codes: &mut Vec<u32>) -> f32 {
        assert_eq!(u.len(), q.len());
        codes.clear();
        codes.reserve(u.len());
        let s = u.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let bias = (self.kg + 1) as i32;
        if s == 0.0 || !s.is_finite() {
            q.fill(0.0);
            codes.resize(u.len(), bias as u32);
            return if s.is_finite() { s } else { f32::NAN };
        }
        let inv_s = 1.0 / s;
        for (qi, &ui) in q.iter_mut().zip(u.iter()) {
            let a = (ui.abs() * inv_s).min(1.0);
            match self.level_exponent(a) {
                None => {
                    *qi = 0.0;
                    codes.push(bias as u32);
                }
                Some(m) => {
                    let level = f32::exp2(m as f32);
                    let sym = (m + bias) * if ui < 0.0 { -1 } else { 1 };
                    *qi = level * s * if ui < 0.0 { -1.0 } else { 1.0 };
                    codes.push((sym + bias) as u32);
                }
            }
        }
        s
    }

    /// Decode one symbol given the scale.
    #[inline]
    fn decode_symbol(&self, code: u32, s: f32) -> f32 {
        let bias = (self.kg + 1) as i32;
        let sym = code as i32 - bias; // in [-(kg+1), kg+1]
        if sym == 0 {
            0.0
        } else {
            let m = sym.abs() - bias; // in [-kg, 0]
            let level = f32::exp2(m as f32) * s;
            if sym < 0 {
                -level
            } else {
                level
            }
        }
    }

    /// Wire `param` for a multi-chunk (per-chunk-scale) LogQuant message:
    /// low byte = k_g, high byte = log2(block). `block` must be a power
    /// of two (the AOT kernel chunk is).
    pub fn pjrt_param(&self, block: usize) -> u32 {
        debug_assert!(block.is_power_of_two());
        self.kg | ((block.trailing_zeros()) << 8)
    }

    /// Fused unpack+decode over codes `[start, start + out.len())`.
    /// `ADD` accumulates into `out` instead of overwriting — the
    /// server's decode→sum fusion (see `decode_msg_range_add`).
    // qadam: hotpath
    fn decode_range_impl<const ADD: bool>(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        const TABLE_BITS: usize = 6; // kg <= MAX_KG=20 -> 43 symbols -> 6 bits
        let p: &Packed = msg.codes.as_ref().expect("logquant msg has codes");
        let nb = p.bits as usize;
        if msg.scales.len() == 1 {
            let s = msg.scales[0];
            if nb <= TABLE_BITS {
                // Dense symbol table (at most 64 entries on the stack):
                // decode is one lookup per code, identical bit-for-bit
                // to `decode_symbol` by construction.
                let mut table = [0.0f32; 1 << TABLE_BITS];
                for (c, t) in table.iter_mut().take(1 << nb).enumerate() {
                    *t = self.decode_symbol(c as u32, s);
                }
                for_each_chunk(p, start, out.len(), |o, chunk| {
                    let dst = &mut out[o..o + chunk.len()];
                    if ADD {
                        for (d, &c) in dst.iter_mut().zip(chunk) {
                            *d += table[c as usize];
                        }
                    } else {
                        for (d, &c) in dst.iter_mut().zip(chunk) {
                            *d = table[c as usize];
                        }
                    }
                });
            } else {
                // Oversized widths never come off the wire (validated);
                // decode symbol by symbol for in-process odd messages.
                for_each_chunk(p, start, out.len(), |o, chunk| {
                    for (j, &c) in chunk.iter().enumerate() {
                        let v = self.decode_symbol(c, s);
                        if ADD {
                            out[o + j] += v;
                        } else {
                            out[o + j] = v;
                        }
                    }
                });
            }
        } else {
            // Multi-scale (per-chunk) message from the PJRT kernel path:
            // block size is 2^(param >> 8) (see `pjrt_param`). Scales are
            // indexed by the element's *global* position. The table holds
            // the *signed levels* (scale factored out): `(-2^m) * s` and
            // `-(2^m * s)` agree bit-for-bit, and the zero symbol is
            // special-cased so it stays exactly +0.0 under any scale.
            let block = 1usize << (msg.param >> 8);
            if nb <= TABLE_BITS {
                let mut lvl = [0.0f32; 1 << TABLE_BITS];
                for (c, t) in lvl.iter_mut().take(1 << nb).enumerate() {
                    *t = self.decode_symbol(c as u32, 1.0);
                }
                for_each_chunk(p, start, out.len(), |o, chunk| {
                    for (j, &c) in chunk.iter().enumerate() {
                        let l = lvl[c as usize];
                        let s = msg.scales[(start + o + j) / block];
                        let v = if l == 0.0 { 0.0 } else { l * s };
                        if ADD {
                            out[o + j] += v;
                        } else {
                            out[o + j] = v;
                        }
                    }
                });
            } else {
                for_each_chunk(p, start, out.len(), |o, chunk| {
                    for (j, &c) in chunk.iter().enumerate() {
                        let v = self.decode_symbol(c, msg.scales[(start + o + j) / block]);
                        if ADD {
                            out[o + j] += v;
                        } else {
                            out[o + j] = v;
                        }
                    }
                });
            }
        }
    }

    /// `decompress_range` that *accumulates* (`out[i] += decoded`) —
    /// what `ParameterServer::apply` uses to sum worker deltas in a
    /// single traversal without a scratch buffer.
    pub fn decompress_range_add(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        self.decode_range_impl::<true>(msg, start, out);
    }

    /// Re-derive the wire codes from an *already quantized* vector (used
    /// by the PJRT path, where the Pallas kernel produced `qdelta`).
    /// `s` must be the quantization scale (`max|u|` of the pre-quant
    /// vector == `max|qdelta|`, since the max element maps to level 1).
    pub fn encode_quantized(&self, qdelta: &[f32], s: f32) -> Vec<u32> {
        let bias = (self.kg + 1) as i32;
        if s == 0.0 {
            return vec![bias as u32; qdelta.len()];
        }
        let inv_s = 1.0 / s;
        qdelta
            .iter()
            .map(|&qi| {
                if qi == 0.0 {
                    bias as u32
                } else {
                    let a = qi.abs() * inv_s;
                    // a is exactly a power of two in [2^-kg, 1]
                    let m = (((a.to_bits() >> 23) & 0xff) as i32 - 127).clamp(-(self.kg as i32), 0);
                    let sym = (m + bias) * if qi < 0.0 { -1 } else { 1 };
                    (sym + bias) as u32
                }
            })
            .collect()
    }
}

impl Compressor for LogQuant {
    fn name(&self) -> &'static str {
        "qadam-logquant"
    }
    fn codec(&self) -> CodecId {
        CodecId::LogQuant
    }

    fn compress_into(&self, u: &[f32], q: &mut [f32], _rng: &mut DetRng) -> WireMsg {
        // Fused quantize + encode + bit-pack: one pass over `u`, codes
        // written straight into the packed words (no intermediate
        // Vec<u32>; see EXPERIMENTS.md §Perf).
        assert_eq!(u.len(), q.len());
        let n = u.len();
        let bits = self.code_bits() as usize;
        let mut words = vec![0u64; (n * bits).div_ceil(64)];
        let bias = (self.kg + 1) as i32;
        let s = u.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        if s == 0.0 || !s.is_finite() {
            q.fill(0.0);
            // all-zero symbols: code = bias everywhere
            let mut wtr = BitWriter::new(&mut words, bits as u8);
            for _ in 0..n {
                wtr.push(bias as u32);
            }
            wtr.finish();
            return WireMsg {
                codec: CodecId::LogQuant,
                param: self.kg,
                n,
                scales: vec![if s.is_finite() { s } else { f32::NAN }],
                codes: Some(Packed { bits: bits as u8, n, words }),
                raw: vec![],
            };
        }
        let inv_s = 1.0 / s;
        let kg = self.kg as i32;
        let zero_thresh = f32::exp2(-(kg + 1) as f32);
        let mut wtr = BitWriter::new(&mut words, bits as u8);
        for (qi, &ui) in q.iter_mut().zip(u.iter()) {
            let a = (ui.abs() * inv_s).min(1.0);
            let (qv, code): (f32, u32) = if a < zero_thresh {
                (0.0, bias as u32)
            } else {
                let b = a.to_bits();
                let mut m = ((b >> 23) & 0xff) as i32 - 127;
                if m < -kg {
                    m = -kg;
                } else if (b & 0x7f_ffff) >= 0x40_0000 && m < 0 {
                    m += 1;
                }
                let m = m.min(0);
                let level = f32::from_bits(((m + 127) as u32) << 23); // 2^m exactly
                if ui < 0.0 {
                    (-level * s, (bias - (m + bias)) as u32)
                } else {
                    (level * s, (bias + (m + bias)) as u32)
                }
            };
            *qi = qv;
            wtr.push(code);
        }
        wtr.finish();
        WireMsg {
            codec: CodecId::LogQuant,
            param: self.kg,
            n,
            scales: vec![s],
            codes: Some(Packed { bits: bits as u8, n, words }),
            raw: vec![],
        }
    }

    fn decompress(&self, msg: &WireMsg, out: &mut [f32]) {
        let p: &Packed = msg.codes.as_ref().expect("logquant msg has codes");
        assert_eq!(out.len(), p.n);
        self.decompress_range(msg, 0, out);
    }

    fn decompress_range(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        self.decode_range_impl::<false>(msg, start, out);
    }

    fn bits_per_element(&self) -> f64 {
        self.code_bits() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::seeded_rng;

    fn compress_roundtrip(u: &[f32], kg: u32) -> (Vec<f32>, WireMsg) {
        let lq = LogQuant::new(kg);
        let mut q = vec![0.0; u.len()];
        let mut rng = seeded_rng(1, 2);
        let msg = lq.compress_into(u, &mut q, &mut rng);
        (q, msg)
    }

    #[test]
    fn known_values_kg2() {
        // s = 1.0; levels {0.25, 0.5, 1.0}; zero below 0.125.
        let u = [1.0f32, 0.9, 0.6, 0.5, 0.4, 0.3, 0.25, 0.2, 0.126, 0.124, 0.0, -0.7];
        let (q, _) = compress_roundtrip(&u, 2);
        let want = [1.0, 1.0, 0.5, 0.5, 0.5, 0.25, 0.25, 0.25, 0.25, 0.0, 0.0, -0.5];
        for (i, (&got, &w)) in q.iter().zip(want.iter()).enumerate() {
            assert_eq!(got, w, "i={i} u={}", u[i]);
        }
    }

    #[test]
    fn ternary_when_kg0() {
        let lq = LogQuant::new(0);
        assert_eq!(lq.symbols(), 3);
        assert_eq!(lq.code_bits(), 2);
        let u = [2.0f32, 0.9, -1.5, 0.4]; // s=2: |y| = 1, .45, .75, .2
        let (q, _) = compress_roundtrip(&u, 0);
        // zero region is |y| < 0.5 (midpoint between 0 and level 1)
        assert_eq!(q, [2.0, 0.0, -2.0, 0.0]);
    }

    #[test]
    fn paper_comm_bit_widths() {
        // 3-bit rows of Tables 2-3 are kg=2 (7 symbols), 2-bit rows kg=0.
        assert_eq!(LogQuant::new(2).code_bits(), 3);
        assert_eq!(LogQuant::new(0).code_bits(), 2);
        // 162.9 MB * 3/32 = 15.27 MB (paper Table 2 row 2)
        let mb = 162.9 * LogQuant::new(2).bits_per_element() / 32.0;
        assert!((mb - 15.27).abs() < 0.01, "{mb}");
        let mb = 162.9 * LogQuant::new(0).bits_per_element() / 32.0;
        assert!((mb - 10.18).abs() < 0.01, "{mb}");
    }

    #[test]
    fn zero_vector() {
        let (q, msg) = compress_roundtrip(&[0.0; 16], 3);
        assert!(q.iter().all(|&x| x == 0.0));
        let mut out = vec![1.0; 16];
        LogQuant::new(3).decompress(&msg, &mut out);
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn encode_quantized_matches_compress() {
        let u: Vec<f32> = (0..257).map(|i| ((i * 37 % 101) as f32 - 50.0) / 13.0).collect();
        let lq = LogQuant::new(2);
        let mut q = vec![0.0; u.len()];
        let mut codes = Vec::new();
        let s = lq.quantize(&u, &mut q, &mut codes);
        assert_eq!(lq.encode_quantized(&q, s), codes);
    }

    fn rand_vec(seed: u64, n: usize, scale: f32) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1);
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                (((s >> 33) as i32 as f32) / (1u32 << 31) as f32) * scale
            })
            .collect()
    }

    /// Property: worker-local q == server-decoded values, across kg,
    /// seeds and magnitudes.
    #[test]
    fn decode_identity_prop() {
        for kg in 0u32..8 {
            for &scale in &[1e-6f32, 1e-2, 1.0, 1e4] {
                for seed in 0..4u64 {
                    let u = rand_vec(seed, 300, scale);
                    let lq = LogQuant::new(kg);
                    let (q, msg) = compress_roundtrip(&u, kg);
                    let mut out = vec![0.0; u.len()];
                    lq.decompress(&msg, &mut out);
                    assert_eq!(q, out, "kg={kg} scale={scale} seed={seed}");
                }
            }
        }
    }

    /// Property (Assumption 2): ||u - Q(u)|| <= (1 - delta)||u||,
    /// delta = 2^-(kg+2).
    #[test]
    fn contraction_assumption2_prop() {
        for kg in 0u32..8 {
            for seed in 0..8u64 {
                let u = rand_vec(seed, 300, 1.0);
                let (q, _) = compress_roundtrip(&u, kg);
                let err: f32 =
                    u.iter().zip(&q).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
                let norm: f32 = u.iter().map(|a| a * a).sum::<f32>().sqrt();
                let delta = f32::exp2(-((kg + 2) as f32));
                assert!(err <= (1.0 - delta) * norm + 1e-5, "kg={kg} err={err} norm={norm}");
            }
        }
    }

    /// Property: every nonzero quantized magnitude is scale * 2^m with
    /// m in [-kg, 0].
    #[test]
    fn levels_are_powers_of_two_prop() {
        for seed in 0..8u64 {
            let u = rand_vec(seed, 100, 1.0);
            let (q, msg) = compress_roundtrip(&u, 4);
            let scale = msg.scales[0];
            for &qi in &q {
                if qi != 0.0 && scale > 0.0 {
                    let a = qi.abs() / scale;
                    let l = a.log2();
                    assert!((l - l.round()).abs() < 1e-5, "a={a}");
                    assert!((-4.0 - 1e-5..=1e-5).contains(&l.round()));
                }
            }
        }
    }
}
