//! `Q_x` — the paper's weight quantizer (§5.1).
//!
//! ```text
//!   Q_x(x) = 0.5 * argmin_{xhat in X} || 2x - xhat ||
//!   X = { i / 2^{k_x} : i = -2^{k_x}, ..., 2^{k_x} }
//! ```
//!
//! Uniform symmetric grid: clamp `2x` to `[-1, 1]`, round to the nearest
//! multiple of `2^{-k_x}` (half away from zero, = `f32::round`), halve.
//! The effective grid on weights is step `2^{-(k_x+1)}` over
//! `[-0.5, 0.5]` — Assumption 3 holds inside that range with
//! `||x - Q_x(x)||_inf <= 2^{-(k_x+2)}` (tested).
//!
//! Wire format: no scale (the grid is absolute), `k_x + 2`-bit codes
//! `idx + 2^{k_x}` where `idx = round(clamp(2x,-1,1) * 2^{k_x})`.
//! Paper's "Size" column: 162.9 MB fp32 → 81.44 MB at 16 bits
//! (`k_x = 14`) → 40.72 MB at 8 bits (`k_x = 6`).
//!
//! # Role in the convergence theorems
//!
//! `Q_x` is the operator behind the *weight-quantization floor* of the
//! paper's analysis; the per-coordinate bound
//! `‖x − Q_x(x)‖_∞ ≤ δ_x = 2^-(k_x+2)` ([`WQuant::delta_x_per_coord`],
//! property-tested below as `assumption3_bound_prop`) is exactly
//! Assumption 3:
//!
//! * **Theorem 3.2** — single worker, `Q_x` on: `E‖∇f(Q_x(x_t))‖²`
//!   converges to a neighborhood of radius `C₇ ∝ δ_x`, not to 0. The
//!   empirical check (`rust/tests/convergence_theory.rs`, via
//!   [`crate::sim`]) asserts the plateau shrinks as `k_x` grows.
//! * **Theorem 3.3** — the multi-worker version of the same bound;
//!   the checks verify the floor is no worse at 8 workers than at 1.
//!
//! The `decode_identity_prop` test below guards the other contract the
//! parameter server depends on: the worker-side dequantized view equals
//! the server-side decode bit-for-bit, so error feedback compensates
//! exactly the bias the server applies.

use super::pack::{bits_for_symbols, for_each_chunk, pack, BitWriter, Packed};
use super::{CodecId, Compressor, WireMsg};
use crate::util::DetRng;

#[derive(Clone, Copy, Debug)]
pub struct WQuant {
    /// log2 of the number of positive fractional levels of the 2x grid.
    pub kx: u32,
}

impl WQuant {
    pub fn new(kx: u32) -> Self {
        assert!(kx <= super::MAX_KX, "kx={kx} out of range");
        Self { kx }
    }

    pub fn symbols(&self) -> u32 {
        2 * (1 << self.kx) + 1
    }

    pub fn code_bits(&self) -> u8 {
        bits_for_symbols(self.symbols())
    }

    /// The grid index of one weight: `round(clamp(2x,-1,1) * 2^kx)`.
    #[inline]
    pub fn index(&self, x: f32) -> i32 {
        let scale = (1u32 << self.kx) as f32;
        ((2.0 * x).clamp(-1.0, 1.0) * scale).round() as i32
    }

    /// Quantize one weight.
    #[inline]
    pub fn quantize_one(&self, x: f32) -> f32 {
        let scale = (1u32 << self.kx) as f32;
        0.5 * self.index(x) as f32 / scale
    }

    /// In-place quantization of a full weight vector (server hot path).
    pub fn quantize_into(&self, x: &[f32], out: &mut [f32]) {
        for (o, &xi) in out.iter_mut().zip(x) {
            *o = self.quantize_one(xi);
        }
    }

    /// Assumption 3 bound inside the representable range.
    pub fn delta_x_per_coord(&self) -> f32 {
        f32::exp2(-((self.kx + 2) as f32))
    }

    /// Quantize a slice and emit its (unpacked) wire codes — the
    /// per-element kernel of [`Compressor::compress_into`], exposed so
    /// the sharded parameter server can run it one block per thread
    /// before a single serial bit-pack. Bit-identical to the
    /// corresponding range of `compress_into`'s outputs.
    pub fn encode_into(&self, x: &[f32], q: &mut [f32], codes: &mut [u32]) {
        debug_assert!(x.len() == q.len() && x.len() == codes.len());
        let bias = 1i32 << self.kx;
        for ((&xi, qi), ci) in x.iter().zip(q.iter_mut()).zip(codes.iter_mut()) {
            let idx = self.index(xi);
            *qi = 0.5 * idx as f32 / bias as f32;
            *ci = (idx + bias) as u32;
        }
    }

    /// Assemble the wire message for codes produced by
    /// [`Self::encode_into`] — the single owner of the `Q_x` wire
    /// layout, shared by [`Compressor::compress_into`] and the sharded
    /// server's block-parallel broadcast.
    pub fn wire_msg(&self, n: usize, codes: &[u32]) -> WireMsg {
        debug_assert_eq!(n, codes.len());
        WireMsg {
            codec: CodecId::WQuant,
            param: self.kx,
            n,
            scales: vec![],
            codes: Some(pack(codes, self.code_bits())),
            raw: vec![],
        }
    }

    /// Fused unpack+decode; `ADD` accumulates into `out` (the server's
    /// decode→sum fusion). Keeps the exact pre-fusion arithmetic —
    /// `0.5 * (c - bias) / bias`, division not folded into a reciprocal
    /// multiply, so decoded grid points are bit-identical.
    // qadam: hotpath
    fn decode_range_impl<const ADD: bool>(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        let p = msg.codes.as_ref().expect("wquant msg has codes");
        let bias = 1i32 << self.kx;
        for_each_chunk(p, start, out.len(), |o, chunk| {
            let dst = &mut out[o..o + chunk.len()];
            if ADD {
                for (d, &c) in dst.iter_mut().zip(chunk) {
                    *d += 0.5 * (c as i32 - bias) as f32 / bias as f32;
                }
            } else {
                for (d, &c) in dst.iter_mut().zip(chunk) {
                    *d = 0.5 * (c as i32 - bias) as f32 / bias as f32;
                }
            }
        });
    }

    /// `decompress_range` that accumulates (`out[i] += decoded`).
    pub fn decompress_range_add(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        self.decode_range_impl::<true>(msg, start, out);
    }
}

impl Compressor for WQuant {
    fn name(&self) -> &'static str {
        "wquant-uniform"
    }
    fn codec(&self) -> CodecId {
        CodecId::WQuant
    }

    fn compress_into(&self, u: &[f32], q: &mut [f32], _rng: &mut DetRng) -> WireMsg {
        // Fused encode + bit-pack: same per-element kernel as
        // `encode_into`, codes streamed straight into the packed words
        // (no intermediate Vec<u32>).
        let n = u.len();
        let bits = self.code_bits();
        let bias = 1i32 << self.kx;
        let mut words = vec![0u64; (n * bits as usize).div_ceil(64)];
        let mut wtr = BitWriter::new(&mut words, bits);
        for (qi, &xi) in q.iter_mut().zip(u) {
            let idx = self.index(xi);
            *qi = 0.5 * idx as f32 / bias as f32;
            wtr.push((idx + bias) as u32);
        }
        wtr.finish();
        WireMsg {
            codec: CodecId::WQuant,
            param: self.kx,
            n,
            scales: vec![],
            codes: Some(Packed { bits, n, words }),
            raw: vec![],
        }
    }

    fn decompress(&self, msg: &WireMsg, out: &mut [f32]) {
        let p = msg.codes.as_ref().expect("wquant msg has codes");
        assert_eq!(out.len(), p.n);
        self.decompress_range(msg, 0, out);
    }

    fn decompress_range(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        self.decode_range_impl::<false>(msg, start, out);
    }

    fn bits_per_element(&self) -> f64 {
        self.code_bits() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::seeded_rng;

    #[test]
    fn known_values() {
        let wq = WQuant::new(2); // grid on 2x: multiples of 0.25
        assert_eq!(wq.quantize_one(0.0), 0.0);
        assert_eq!(wq.quantize_one(0.13), 0.125); // 2x=.26 -> .25
        assert_eq!(wq.quantize_one(0.19), 0.25); // 2x=.38 -> .5 (grid step .25)
        assert_eq!(wq.quantize_one(-0.13), -0.125);
        assert_eq!(wq.quantize_one(9.0), 0.5); // clamp
        assert_eq!(wq.quantize_one(-9.0), -0.5);
        // round half away from zero: 2x = 0.125 -> idx 0.5 -> 1
        assert_eq!(wq.quantize_one(0.0625), 0.125);
        assert_eq!(wq.quantize_one(-0.0625), -0.125);
    }

    #[test]
    fn paper_size_bit_widths() {
        assert_eq!(WQuant::new(14).code_bits(), 16);
        assert_eq!(WQuant::new(6).code_bits(), 8);
        let mb = 162.9 * WQuant::new(14).bits_per_element() / 32.0;
        assert!((mb - 81.45).abs() < 0.01, "{mb}");
    }

    #[test]
    fn idempotent() {
        let wq = WQuant::new(4);
        for i in -100..100 {
            let x = i as f32 / 97.0;
            let q = wq.quantize_one(x);
            assert_eq!(wq.quantize_one(q), q, "x={x}");
        }
    }

    fn rand_vec(seed: u64, n: usize, scale: f32) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1);
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                scale * ((s >> 33) as i32 as f32) / (1u32 << 31) as f32
            })
            .collect()
    }

    /// Property: worker-local q == server-decoded values.
    #[test]
    fn decode_identity_prop() {
        for kx in 1u32..12 {
            for seed in 0..6u64 {
                let x = rand_vec(seed, 200, 1.0);
                let wq = WQuant::new(kx);
                let mut q = vec![0.0; x.len()];
                let mut rng = seeded_rng(0, 0);
                let msg = wq.compress_into(&x, &mut q, &mut rng);
                let mut out = vec![0.0; x.len()];
                wq.decompress(&msg, &mut out);
                assert_eq!(q, out, "kx={kx} seed={seed}");
            }
        }
    }

    /// Property (Assumption 3): per-coordinate error bounded inside the
    /// representable range.
    #[test]
    fn assumption3_bound_prop() {
        for kx in 1u32..12 {
            for seed in 0..6u64 {
                let x = rand_vec(seed, 200, 0.5);
                let wq = WQuant::new(kx);
                let bound = wq.delta_x_per_coord();
                for &xi in &x {
                    assert!((xi - wq.quantize_one(xi)).abs() <= bound + 1e-7, "kx={kx}");
                }
            }
        }
    }
}
