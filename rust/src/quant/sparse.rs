//! Sparse compressors: ship the few coordinates that matter, feed the
//! rest through error feedback.
//!
//! The paper's EF analysis is codec-agnostic: any contractive
//! compressor whose dropped mass flows into the residual inherits the
//! convergence guarantee (Assumption 2 only asks `‖u − Q(u)‖ ≤
//! (1 − δ)‖u‖`). ECQ-SGD (Wu et al., arXiv:1806.08054) and blockwise
//! momentum SGD with EF (Zheng et al., arXiv:1905.10936) instantiate it
//! with sparsification; this module adds both shapes behind the same
//! [`Compressor`] trait the dense codecs use:
//!
//! * [`TopK`] — global magnitude top-k. The kept values ship as exact
//!   f32 (`WireMsg::raw`), so on kept coordinates the decode identity is
//!   `q_i = u_i` *bitwise* and the EF residual is exactly 0; on dropped
//!   coordinates `q_i = 0` and the residual carries `u_i` exactly. The
//!   per-coordinate conservation `q + e == u` therefore holds in f32
//!   with no rounding at all — the property `rust/tests/sparse_codec.rs`
//!   pins.
//! * [`SparseBlock`] — blockwise top-k with a per-block scale, the
//!   1905.10936 shape composed with sparsification: within each block
//!   of `block` elements keep the `kb` largest magnitudes, ship one
//!   scale `s_b = mean(|kept|)` and a `(position, sign)` code per kept
//!   element; kept coordinates decode to `±s_b`.
//!
//! # Position encoding (TopK)
//!
//! Two encodings, chosen by whichever is smaller for the density —
//! deterministically, from `(n, k)` alone, so the decoder re-derives
//! the mode without a flag byte:
//!
//! * **index mode** when `k·⌈log₂ n⌉ < n` bits: the k kept indices,
//!   sorted ascending, packed at `bits_for_symbols(n)` bits each.
//! * **bitmap mode** otherwise: one bit per element (ties go to the
//!   bitmap).
//!
//! # Wire layout
//!
//! Both codecs reuse the [`WireMsg`] grammar unchanged (wire v2, same
//! 22-byte serialized header): `param` carries `k` (TopK) or
//! `block | kb << 16` (SparseBlock); positions ride in `codes`; TopK's
//! kept values ride in `raw`; SparseBlock's per-block scales ride in
//! `scales`. `WireMsg::from_bytes` re-derives every count from
//! `(codec, param, n)` and additionally validates payload *content*
//! (index monotonicity, bitmap popcount) — see `topk_content_ok` /
//! `sparse_block_content_ok` — so an accepted frame can always be
//! range-decoded without panicking, hostile or not.

use super::{pack, CodecId, Compressor, WireMsg};
use crate::util::DetRng;

/// Density granularity: [`TopK`] densities are expressed in 1/10000ths
/// of kept coordinates (integer, for bit-reproducible policy state).
pub const DENSITY_UNIT: u32 = 10_000;

/// Global magnitude top-k sparsifier at a fixed density.
///
/// `k = ceil(n · density / 10000)` per compressed range, so any
/// positive density keeps at least one coordinate of a non-empty
/// tensor; density 0 ships nothing (the EF residual carries it all).
#[derive(Clone, Copy, Debug)]
pub struct TopK {
    /// Kept density in 1/10000ths (`0..=10000`).
    density_bp: u32,
}

impl TopK {
    pub fn new(density_bp: u32) -> Self {
        assert!(density_bp <= DENSITY_UNIT, "topk density {density_bp} > {DENSITY_UNIT}");
        Self { density_bp }
    }

    /// A decode-only instance: every decode below is driven entirely by
    /// the message header (`param` = k), never by the density.
    pub fn decoder() -> Self {
        Self { density_bp: 0 }
    }

    /// Kept-coordinate count for an `n`-element range.
    pub fn k_for(&self, n: usize) -> usize {
        (n * self.density_bp as usize).div_ceil(DENSITY_UNIT as usize)
    }

    /// The encoding-mode rule, shared verbatim by the encoder and
    /// `WireMsg::from_bytes`: index mode iff the packed sorted indices
    /// are strictly smaller than the n-bit bitmap.
    pub fn index_mode(n: usize, k: usize) -> bool {
        k > 0 && k * pack::bits_for_symbols(n as u32) as usize < n
    }

    /// Fused decode→accumulate (`out[i] += decoded[start + i]`) — the
    /// server's arena traversal calls this via `decode_msg_range_add`.
    pub fn decompress_range_add(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        self.decode_range_impl::<true>(msg, start, out);
    }

    // qadam: hotpath
    fn decode_range_impl<const ADD: bool>(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        let end = start + out.len();
        assert!(end <= msg.n, "range {start}+{} out of {}", out.len(), msg.n);
        if !ADD {
            out.fill(0.0);
        }
        if out.is_empty() || msg.param == 0 {
            return;
        }
        let p = msg.codes.as_ref().expect("topk msg has codes");
        if p.bits == 1 {
            // Bitmap mode: the value of bit i is raw[rank(i)] where
            // rank = ones in [0, i). Seed the rank by popcounting whole
            // words up to `start`, then walk the range.
            let mut rank = rank1(p, start);
            pack::for_each_chunk(p, start, end - start, |o, chunk| {
                for (j, &b) in chunk.iter().enumerate() {
                    if b != 0 {
                        let v = msg.raw[rank];
                        if ADD {
                            out[o + j] += v;
                        } else {
                            out[o + j] = v;
                        }
                        rank += 1;
                    }
                }
            });
        } else {
            // Index mode: indices are sorted, so the ranks touching
            // [start, end) are a contiguous run found by binary search.
            let lo = lower_bound(p, start as u32);
            let hi = lower_bound(p, end as u32);
            if hi > lo {
                pack::for_each_chunk(p, lo, hi - lo, |o, chunk| {
                    for (j, &gi) in chunk.iter().enumerate() {
                        let v = msg.raw[lo + o + j];
                        if ADD {
                            out[gi as usize - start] += v;
                        } else {
                            out[gi as usize - start] = v;
                        }
                    }
                });
            }
        }
    }
}

/// Ones among the first `upto` bits of a 1-bit-per-code payload.
// qadam: hotpath
fn rank1(p: &pack::Packed, upto: usize) -> usize {
    let full = upto >> 6;
    let mut r = 0usize;
    for w in &p.words[..full] {
        r += w.count_ones() as usize;
    }
    let rem = upto & 63;
    if rem > 0 {
        r += (p.words[full] & ((1u64 << rem) - 1)).count_ones() as usize;
    }
    r
}

/// Code `i` of a packed payload (two word reads at most) — the probe
/// the index-mode binary search uses without unpacking the payload.
// qadam: hotpath
#[inline]
fn code_at(p: &pack::Packed, i: usize) -> u32 {
    let b = p.bits as usize;
    let mask = if p.bits == 32 { u32::MAX } else { (1u32 << p.bits) - 1 };
    let bit = i * b;
    let w = bit >> 6;
    let off = bit & 63;
    let lo = p.words[w] >> off;
    let v = if off + b <= 64 { lo } else { lo | (p.words[w + 1] << (64 - off)) };
    (v as u32) & mask
}

/// First rank whose (sorted) code is `>= target`.
// qadam: hotpath
fn lower_bound(p: &pack::Packed, target: u32) -> usize {
    let (mut lo, mut hi) = (0usize, p.n);
    while lo < hi {
        let mid = (lo + hi) / 2;
        if code_at(p, mid) < target {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

impl Compressor for TopK {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn codec(&self) -> CodecId {
        CodecId::TopK
    }

    fn compress_into(&self, u: &[f32], q: &mut [f32], _rng: &mut DetRng) -> WireMsg {
        debug_assert_eq!(u.len(), q.len());
        let n = u.len();
        let k = self.k_for(n);
        q.fill(0.0);
        if k == 0 {
            return WireMsg {
                codec: CodecId::TopK,
                param: 0,
                n,
                scales: vec![],
                codes: None,
                raw: vec![],
            };
        }
        // Select the k largest magnitudes; ties keep the lower index —
        // a total order, so the selection is deterministic.
        let mut idx: Vec<u32> = (0..n as u32).collect();
        if k < n {
            idx.select_nth_unstable_by(k - 1, |&a, &b| {
                let (ma, mb) = (u[a as usize].abs(), u[b as usize].abs());
                mb.total_cmp(&ma).then(a.cmp(&b))
            });
            idx.truncate(k);
        }
        idx.sort_unstable();
        let mut raw = Vec::with_capacity(k);
        for &i in &idx {
            raw.push(u[i as usize]);
            q[i as usize] = u[i as usize];
        }
        let ib = pack::bits_for_symbols(n as u32);
        let codes = if Self::index_mode(n, k) {
            pack::pack(&idx, ib)
        } else {
            let mut words = vec![0u64; n.div_ceil(64)];
            for &i in &idx {
                words[(i as usize) >> 6] |= 1u64 << (i & 63);
            }
            pack::Packed { bits: 1, n, words }
        };
        WireMsg { codec: CodecId::TopK, param: k as u32, n, scales: vec![], codes: Some(codes), raw }
    }

    fn decompress(&self, msg: &WireMsg, out: &mut [f32]) {
        assert_eq!(out.len(), msg.n);
        self.decode_range_impl::<false>(msg, 0, out);
    }

    fn decompress_range(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        self.decode_range_impl::<false>(msg, start, out);
    }

    /// Analytic cost: 32 value bits per kept element plus the position
    /// payload, bounded by the bitmap's 1 bit/element.
    fn bits_per_element(&self) -> f64 {
        let d = self.density_bp as f64 / DENSITY_UNIT as f64;
        d * 32.0 + (d * 32.0).min(1.0)
    }
}

/// Blockwise top-k with a per-block scale (arXiv:1905.10936 composed
/// with sparsification): per `block`-element block, keep the `kb`
/// largest magnitudes, ship `s_b = mean(|kept|)` and one
/// `(position << 1) | sign` code per kept element. Kept coordinates
/// decode to `±s_b`; dropped ones to 0 (their mass rides the EF
/// residual exactly).
#[derive(Clone, Copy, Debug)]
pub struct SparseBlock {
    block: usize,
    kb: usize,
}

impl SparseBlock {
    pub fn new(block: usize, kb: usize) -> Self {
        assert!(
            (1..=0xffff).contains(&block),
            "sparse-block block {block} out of range (1..=65535)"
        );
        assert!((1..=block).contains(&kb), "sparse-block kb {kb} out of range (1..={block})");
        Self { block, kb }
    }

    /// Rebuild from the wire `param` (`block | kb << 16`), the decode
    /// dispatcher's constructor. `WireMsg::from_bytes` vets the domain.
    pub fn from_param(param: u32) -> Self {
        Self::new((param & 0xffff) as usize, (param >> 16) as usize)
    }

    pub fn param(&self) -> u32 {
        self.block as u32 | (self.kb as u32) << 16
    }

    /// Code count of an `n`-element message: every full block carries
    /// `kb` codes, a ragged tail carries `min(kb, tail)`.
    pub fn code_count(&self, n: usize) -> usize {
        let full = n / self.block;
        let tail = n % self.block;
        full * self.kb + if tail > 0 { self.kb.min(tail) } else { 0 }
    }

    /// Bits per packed code: block-local position plus a sign bit.
    pub fn code_bits(&self) -> u8 {
        pack::bits_for_symbols(self.block as u32) + 1
    }

    /// Fused decode→accumulate — the server-side arena traversal entry.
    pub fn decompress_range_add(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        self.decode_range_impl::<true>(msg, start, out);
    }

    // qadam: hotpath
    fn decode_range_impl<const ADD: bool>(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        let end = start + out.len();
        assert!(end <= msg.n, "range {start}+{} out of {}", out.len(), msg.n);
        if !ADD {
            out.fill(0.0);
        }
        if out.is_empty() {
            return;
        }
        let p = msg.codes.as_ref().expect("sparse-block msg has codes");
        let (b0, b1) = (start / self.block, (end - 1) / self.block);
        for bi in b0..=b1 {
            let bs = bi * self.block;
            let len_b = (msg.n - bs).min(self.block);
            let cnt = self.kb.min(len_b);
            // Only the last block can be short, so every earlier block
            // contributes exactly kb codes: rank(bi) = bi · kb.
            let rank = bi * self.kb;
            let scale = msg.scales[bi];
            pack::for_each_chunk(p, rank, cnt, |_, chunk| {
                for &c in chunk {
                    let gi = bs + (c >> 1) as usize;
                    if gi >= start && gi < end {
                        let v = if c & 1 == 1 { scale } else { -scale };
                        if ADD {
                            out[gi - start] += v;
                        } else {
                            out[gi - start] = v;
                        }
                    }
                }
            });
        }
    }
}

impl Compressor for SparseBlock {
    fn name(&self) -> &'static str {
        "sparse_block"
    }

    fn codec(&self) -> CodecId {
        CodecId::SparseBlock
    }

    fn compress_into(&self, u: &[f32], q: &mut [f32], _rng: &mut DetRng) -> WireMsg {
        debug_assert_eq!(u.len(), q.len());
        let n = u.len();
        q.fill(0.0);
        let nblocks = n.div_ceil(self.block);
        let total = self.code_count(n);
        if total == 0 {
            return WireMsg {
                codec: CodecId::SparseBlock,
                param: self.param(),
                n,
                scales: vec![],
                codes: None,
                raw: vec![],
            };
        }
        let cb = self.code_bits();
        let mut scales = Vec::with_capacity(nblocks);
        let mut words = vec![0u64; (total * cb as usize).div_ceil(64)];
        let mut wtr = pack::BitWriter::new(&mut words, cb);
        let mut order: Vec<u32> = Vec::with_capacity(self.block.min(n));
        for bi in 0..nblocks {
            let bs = bi * self.block;
            let len_b = (n - bs).min(self.block);
            let cnt = self.kb.min(len_b);
            order.clear();
            order.extend(0..len_b as u32);
            if cnt < len_b {
                order.select_nth_unstable_by(cnt - 1, |&a, &b| {
                    let (ma, mb) = (u[bs + a as usize].abs(), u[bs + b as usize].abs());
                    mb.total_cmp(&ma).then(a.cmp(&b))
                });
                order.truncate(cnt);
            }
            order.sort_unstable();
            let mut acc = 0.0f32;
            for &pos in &order {
                acc += u[bs + pos as usize].abs();
            }
            let scale = acc / cnt as f32;
            scales.push(scale);
            for &pos in &order {
                let sign = (u[bs + pos as usize] >= 0.0) as u32;
                wtr.push(pos << 1 | sign);
                q[bs + pos as usize] = if sign == 1 { scale } else { -scale };
            }
        }
        wtr.finish();
        WireMsg {
            codec: CodecId::SparseBlock,
            param: self.param(),
            n,
            scales,
            codes: Some(pack::Packed { bits: cb, n: total, words }),
            raw: vec![],
        }
    }

    fn decompress(&self, msg: &WireMsg, out: &mut [f32]) {
        assert_eq!(out.len(), msg.n);
        self.decode_range_impl::<false>(msg, 0, out);
    }

    fn decompress_range(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        self.decode_range_impl::<false>(msg, start, out);
    }

    fn bits_per_element(&self) -> f64 {
        (self.kb as f64 * self.code_bits() as f64 + 32.0) / self.block as f64
    }
}

/// Payload-content check `WireMsg::from_bytes` runs on a structurally
/// consistent TopK frame: the decode scatters `raw[rank]` by position,
/// so an accepted frame must carry exactly `k` set bits with a clean
/// tail word (bitmap mode) or `k` strictly increasing in-bounds indices
/// (index mode) — anything else would index past the value payload.
pub(crate) fn topk_content_ok(msg: &WireMsg) -> bool {
    let k = msg.param as usize;
    let p = match &msg.codes {
        Some(p) => p,
        None => return k == 0,
    };
    if p.bits == 1 {
        let mut ones = 0usize;
        for &w in &p.words {
            ones += w.count_ones() as usize;
        }
        let tail = msg.n & 63;
        if tail > 0 {
            match p.words.last() {
                Some(&last) if last & !((1u64 << tail) - 1) != 0 => return false,
                Some(_) => {}
                None => return false,
            }
        }
        ones == k
    } else {
        let mut ok = p.n == k;
        let mut prev: i64 = -1;
        pack::for_each_chunk(p, 0, p.n, |_, chunk| {
            for &c in chunk {
                if c as i64 <= prev || c as usize >= msg.n {
                    ok = false;
                }
                prev = c as i64;
            }
        });
        ok
    }
}

/// Payload-content check for a structurally consistent SparseBlock
/// frame: per block, positions strictly increasing and inside the
/// block's (possibly ragged) length — the bound that keeps the range
/// decode's scatter in `out`'s bounds on hostile frames.
pub(crate) fn sparse_block_content_ok(msg: &WireMsg) -> bool {
    let blk = (msg.param & 0xffff) as usize;
    let kb = (msg.param >> 16) as usize;
    let p = match &msg.codes {
        Some(p) => p,
        None => return msg.n == 0,
    };
    let blen = |b: usize| (msg.n - (b * blk).min(msg.n)).min(blk);
    let mut ok = true;
    let mut bi = 0usize;
    let mut left = kb.min(blen(0));
    let mut prev: i64 = -1;
    pack::for_each_chunk(p, 0, p.n, |_, chunk| {
        for &c in chunk {
            if !ok {
                return;
            }
            while left == 0 && (bi + 1) * blk < msg.n {
                bi += 1;
                left = kb.min(blen(bi));
                prev = -1;
            }
            if left == 0 {
                ok = false;
                return;
            }
            let pos = (c >> 1) as i64;
            if pos <= prev || pos >= blen(bi) as i64 {
                ok = false;
            }
            prev = pos;
            left -= 1;
        }
    });
    ok
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{decode_msg, decode_msg_range, seeded_rng};

    fn wave(n: usize) -> Vec<f32> {
        (0..n).map(|i| ((i as f32) * 0.61).sin() * (1.0 + (i % 13) as f32)).collect()
    }

    #[test]
    fn topk_keeps_the_largest_and_zeroes_the_rest() {
        let u = [1.0f32, -5.0, 0.25, 3.0, -0.5, 0.0];
        let mut q = [0.0f32; 6];
        let mut rng = seeded_rng(0, 0);
        let msg = TopK::new(DENSITY_UNIT / 3).compress_into(&u, &mut q, &mut rng); // k = 2
        assert_eq!(msg.param, 2);
        assert_eq!(q, [0.0, -5.0, 0.0, 3.0, 0.0, 0.0]);
        assert_eq!(msg.raw, vec![-5.0, 3.0], "raw values in ascending index order");
        let mut out = [9.0f32; 6];
        TopK::decoder().decompress(&msg, &mut out);
        assert_eq!(out, q, "decode identity");
    }

    #[test]
    fn topk_mode_choice_follows_the_size_rule() {
        // n=64 (ib=6): k=2 → 12 bits < 64 → index mode (bits = 6).
        let u = wave(64);
        let mut q = vec![0.0; 64];
        let mut rng = seeded_rng(1, 1);
        let m = TopK::new(313).compress_into(&u, &mut q, &mut rng); // k = ceil(64*313/1e4) = 3
        assert_eq!(m.codes.as_ref().unwrap().bits, 6, "sparse density → packed indices");
        // k large → bitmap: k=32 → 32*6=192 ≥ 64.
        let m2 = TopK::new(DENSITY_UNIT / 2).compress_into(&u, &mut q, &mut rng);
        assert_eq!(m2.param, 32);
        assert_eq!(m2.codes.as_ref().unwrap().bits, 1, "dense density → bitmap");
        assert!(m.wire_bytes() < m2.wire_bytes());
    }

    #[test]
    fn topk_degenerate_densities_are_legal() {
        let u = wave(33);
        let mut q = vec![0.0; 33];
        let mut rng = seeded_rng(2, 2);
        let m0 = TopK::new(0).compress_into(&u, &mut q, &mut rng);
        assert_eq!((m0.param, m0.codes.is_none(), m0.raw.len()), (0, true, 0));
        assert!(q.iter().all(|&x| x == 0.0));
        let mut out = vec![1.0f32; 33];
        TopK::decoder().decompress(&m0, &mut out);
        assert!(out.iter().all(|&x| x == 0.0), "k=0 decodes to all zeros");
        let m1 = TopK::new(DENSITY_UNIT).compress_into(&u, &mut q, &mut rng);
        assert_eq!(m1.param, 33);
        assert_eq!(q, u, "k=len is the identity");
        TopK::decoder().decompress(&m1, &mut out);
        assert_eq!(out, u);
    }

    #[test]
    fn topk_range_decode_matches_full_decode_both_modes() {
        for density in [150u32, 5000] {
            // 150bp on n=301 → k=5 (index mode); 5000bp → k=151 (bitmap)
            let n = 301;
            let u = wave(n);
            let mut q = vec![0.0; n];
            let mut rng = seeded_rng(3, 3);
            let msg = TopK::new(density).compress_into(&u, &mut q, &mut rng);
            let mut full = vec![0.0; n];
            decode_msg(&msg, &mut full);
            assert_eq!(full, q);
            for &(start, len) in &[(0usize, n), (1, 5), (7, 100), (n - 1, 1), (64, 64), (10, 0)] {
                let mut part = vec![7.0; len];
                decode_msg_range(&msg, start, &mut part);
                assert_eq!(part, full[start..start + len], "density={density} start={start}");
            }
        }
    }

    #[test]
    fn topk_wire_roundtrip_and_content_rejection() {
        let n = 90;
        let u = wave(n);
        let mut q = vec![0.0; n];
        let mut rng = seeded_rng(4, 4);
        for density in [0u32, 400, 5000, DENSITY_UNIT] {
            let msg = TopK::new(density).compress_into(&u, &mut q, &mut rng);
            let b = msg.to_bytes();
            let back = WireMsg::from_bytes(&b).unwrap();
            assert_eq!(back.to_bytes(), b, "roundtrip density={density}");
            assert!(topk_content_ok(&back));
        }
        // Hostile content: an index payload with a duplicate index has
        // consistent counts but must still be rejected.
        let msg = TopK::new(400).compress_into(&u, &mut q, &mut rng); // index mode
        let mut dup = msg.clone();
        let p = dup.codes.as_mut().unwrap();
        let first = code_at(p, 0);
        let two = pack::pack(&[first, first, code_at(p, 2), code_at(p, 3)], p.bits);
        p.words = two.words;
        assert!(!topk_content_ok(&dup), "duplicate index must fail content validation");
        assert!(WireMsg::from_bytes(&dup.to_bytes()).is_err());
    }

    #[test]
    fn sparse_block_keeps_per_block_topk_with_scale() {
        // blocks of 4, keep 1: block 0 keeps |−8| → s=8, block 1 (ragged
        // tail of 2) keeps |3| → s=3
        let u = [1.0f32, -8.0, 2.0, 0.5, 3.0, -1.0];
        let mut q = [0.0f32; 6];
        let mut rng = seeded_rng(5, 5);
        let sb = SparseBlock::new(4, 1);
        let msg = sb.compress_into(&u, &mut q, &mut rng);
        assert_eq!(msg.scales, vec![8.0, 3.0]);
        assert_eq!(q, [0.0, -8.0, 0.0, 0.0, 3.0, 0.0]);
        let mut out = [9.0f32; 6];
        SparseBlock::from_param(msg.param).decompress(&msg, &mut out);
        assert_eq!(out, q, "decode identity");
        assert_eq!(sb.code_count(6), 2);
        assert!(sparse_block_content_ok(&msg));
    }

    #[test]
    fn sparse_block_range_decode_matches_full_decode() {
        let n = 301;
        let u = wave(n);
        let mut q = vec![0.0; n];
        let mut rng = seeded_rng(6, 6);
        let sb = SparseBlock::new(7, 2); // ragged tail block
        let msg = sb.compress_into(&u, &mut q, &mut rng);
        let mut full = vec![0.0; n];
        decode_msg(&msg, &mut full);
        assert_eq!(full, q);
        for &(start, len) in &[(0usize, n), (1, 5), (7, 100), (n - 1, 1), (64, 64)] {
            let mut part = vec![7.0; len];
            decode_msg_range(&msg, start, &mut part);
            assert_eq!(part, full[start..start + len], "start={start}");
        }
        let b = msg.to_bytes();
        assert_eq!(WireMsg::from_bytes(&b).unwrap().to_bytes(), b);
    }

    #[test]
    fn sparse_block_full_block_keep_is_blockwise_sign_scale() {
        // kb = block degenerates to the dense blockwise sign·mean shape
        let u = [1.0f32, -2.0, 4.0, -1.0];
        let mut q = [0.0f32; 4];
        let mut rng = seeded_rng(7, 7);
        let msg = SparseBlock::new(4, 4).compress_into(&u, &mut q, &mut rng);
        assert_eq!(msg.scales, vec![2.0]);
        assert_eq!(q, [2.0, -2.0, 2.0, -2.0]);
    }

    #[test]
    fn sparse_block_hostile_positions_rejected() {
        let u = wave(20);
        let mut q = vec![0.0; 20];
        let mut rng = seeded_rng(8, 8);
        let sb = SparseBlock::new(8, 2);
        let msg = sb.compress_into(&u, &mut q, &mut rng);
        // Out-of-block position in the ragged tail (block 2 has len 4):
        // rewrite the last code to position 7.
        let mut bad = msg.clone();
        let p = bad.codes.as_mut().unwrap();
        let mut codes = pack::unpack(p);
        *codes.last_mut().unwrap() = 7 << 1;
        p.words = pack::pack(&codes, p.bits).words;
        assert!(!sparse_block_content_ok(&bad), "tail position past the ragged length");
        assert!(WireMsg::from_bytes(&bad.to_bytes()).is_err());
        // Non-increasing positions within a block are rejected too.
        let mut dup = msg.clone();
        let p = dup.codes.as_mut().unwrap();
        let mut codes = pack::unpack(p);
        codes[1] = codes[0];
        p.words = pack::pack(&codes, p.bits).words;
        assert!(!sparse_block_content_ok(&dup));
    }

    #[test]
    fn rank_and_probe_helpers() {
        let p = pack::pack(&[1, 0, 1, 1, 0, 0, 1, 0], 1);
        assert_eq!(rank1(&p, 0), 0);
        assert_eq!(rank1(&p, 4), 3);
        assert_eq!(rank1(&p, 8), 4);
        let idx = pack::pack(&[2, 5, 9, 40], 6);
        assert_eq!(code_at(&idx, 2), 9);
        assert_eq!(lower_bound(&idx, 0), 0);
        assert_eq!(lower_bound(&idx, 6), 2);
        assert_eq!(lower_bound(&idx, 41), 4);
    }
}
