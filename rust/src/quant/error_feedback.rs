//! Error feedback (Alg. 1 line 6 / Alg. 3 line 7).
//!
//! The residual of the biased compressor is kept locally and added to
//! the *next* update before quantization:
//!
//! ```text
//!   u_t      = direction_t + e_t
//!   delta_t  = Q(u_t)
//!   e_{t+1}  = u_t - delta_t
//! ```
//!
//! [`ErrorFeedback::compress`] wraps any [`Compressor`] with this state
//! machine. For unbiased codecs (TernGrad) the paper's baselines do not
//! use EF; constructing with `enabled = false` reduces to plain
//! compression with `e ≡ 0` (also used by the no-EF ablation).
//!
//! The same state machine runs on both ends of the wire: each worker
//! compensates its gradient-delta uplink, and in the delta-downlink
//! mode (Efficient-Adam-style two-way compression, see
//! `ps::server`) the parameter server keeps a mirror instance that
//! compensates the compressed weight-delta broadcasts.

use super::{Compressor, WireMsg};
use crate::util::DetRng;

#[derive(Clone, Debug)]
pub struct ErrorFeedback {
    e: Vec<f32>,
    enabled: bool,
    /// Scratch for u = direction + e (avoids per-step allocation).
    u: Vec<f32>,
    q: Vec<f32>,
}

impl ErrorFeedback {
    pub fn new(dim: usize, enabled: bool) -> Self {
        Self { e: vec![0.0; dim], enabled, u: vec![0.0; dim], q: vec![0.0; dim] }
    }

    pub fn dim(&self) -> usize {
        self.e.len()
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Current residual (for tests / diagnostics).
    pub fn residual(&self) -> &[f32] {
        &self.e
    }

    pub fn residual_norm(&self) -> f32 {
        self.e.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// ∞-norm of the residual — the scale the ∞-norm-scaled codecs
    /// actually quantize against, exported as the
    /// `qadam_ef_residual_inf_norm` metric.
    pub fn residual_inf_norm(&self) -> f32 {
        self.e.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// One EF-compressed step: returns the wire message for
    /// `Q(direction + e)` and updates `e`.
    pub fn compress(
        &mut self,
        direction: &[f32],
        comp: &dyn Compressor,
        rng: &mut DetRng,
    ) -> WireMsg {
        self.compress_q(direction, comp, rng).0
    }

    /// [`Self::compress`], additionally exposing the dequantized values
    /// `Q(direction + e)` the message decodes to (the decode identity).
    /// The parameter server's delta downlink uses this to advance its
    /// worker-replica estimate without a second decode pass.
    pub fn compress_q(
        &mut self,
        direction: &[f32],
        comp: &dyn Compressor,
        rng: &mut DetRng,
    ) -> (WireMsg, &[f32]) {
        self.compress_range_q(direction, 0, direction.len(), comp, rng)
    }

    /// [`Self::compress`] restricted to `[start, start + len)`: the EF
    /// state machine runs over that range only (the rest of the
    /// residual is untouched), with `comp`'s scale taken over the range
    /// — the per-tensor step the codec-policy layer composes one part
    /// at a time. `compress_range_q(d, 0, d.len(), …)` is bit-identical
    /// to the whole-vector [`Self::compress_q`].
    pub fn compress_range(
        &mut self,
        direction: &[f32],
        start: usize,
        len: usize,
        comp: &dyn Compressor,
        rng: &mut DetRng,
    ) -> WireMsg {
        self.compress_range_q(direction, start, len, comp, rng).0
    }

    /// [`Self::compress_range`], additionally exposing the dequantized
    /// values of the range (the decode identity) — what the server's
    /// delta downlink adds to its worker-replica estimate.
    pub fn compress_range_q(
        &mut self,
        direction: &[f32],
        start: usize,
        len: usize,
        comp: &dyn Compressor,
        rng: &mut DetRng,
    ) -> (WireMsg, &[f32]) {
        assert_eq!(direction.len(), self.e.len());
        assert!(start + len <= self.e.len(), "range {start}+{len} out of {}", self.e.len());
        let end = start + len;
        if self.enabled {
            for i in start..end {
                self.u[i] = direction[i] + self.e[i];
            }
        } else {
            self.u[start..end].copy_from_slice(&direction[start..end]);
        }
        let msg = comp.compress_into(&self.u[start..end], &mut self.q[start..end], rng);
        if self.enabled {
            for i in start..end {
                self.e[i] = self.u[i] - self.q[i];
            }
        }
        (msg, &self.q[start..end])
    }

    /// Fold external mass back into the residual over
    /// `[start, start + len)`: `e[start + i] += scale * vals[i]`.
    ///
    /// The async-round refund path: when the server rejects a delta as
    /// too stale (or applies it down-weighted, leaving a `(1 − w)`
    /// fraction un-applied), the un-applied decoded values are absorbed
    /// here so the next compressed step re-ships them — the same
    /// mechanism that carries quantization error carries rejection
    /// (ECQ-SGD, Wu et al. 2018). A no-op when EF is disabled: without
    /// a residual there is nowhere to carry mass, which the async
    /// trainer rejects at config time.
    pub fn absorb_range(&mut self, start: usize, vals: &[f32], scale: f32) {
        assert!(
            start + vals.len() <= self.e.len(),
            "range {start}+{} out of {}",
            vals.len(),
            self.e.len()
        );
        if !self.enabled || scale == 0.0 {
            return;
        }
        for (ei, &v) in self.e[start..start + vals.len()].iter_mut().zip(vals) {
            *ei += scale * v;
        }
    }

    /// Zero the residual. Used when a resync frame just transmitted the
    /// full state: there is no compression error left to compensate.
    pub fn reset(&mut self) {
        self.e.fill(0.0);
    }

    /// Inject externally computed (u, q) — used by the PJRT path where
    /// the Pallas kernel already produced the quantized delta and new
    /// residual.
    pub fn set_residual(&mut self, e: &[f32]) {
        assert_eq!(e.len(), self.e.len());
        self.e.copy_from_slice(e);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{seeded_rng, LogQuant};

    #[test]
    fn residual_identity() {
        // qdelta + e' == direction + e (exactly, by construction)
        let lq = LogQuant::new(2);
        let dim = 64;
        let mut ef = ErrorFeedback::new(dim, true);
        let mut rng = seeded_rng(0, 0);
        let mut e_prev = vec![0.0f32; dim];
        for t in 0..10 {
            let d: Vec<f32> = (0..dim).map(|i| ((i * 7 + t * 13) % 23) as f32 / 23.0 - 0.5).collect();
            let msg = ef.compress(&d, &lq, &mut rng);
            let mut q = vec![0.0; dim];
            lq.decompress(&msg, &mut q);
            for i in 0..dim {
                let u = d[i] + e_prev[i];
                assert!((q[i] + ef.residual()[i] - u).abs() < 1e-6);
            }
            e_prev = ef.residual().to_vec();
        }
    }

    #[test]
    fn compress_q_exposes_decoded_values_and_reset_clears() {
        let lq = LogQuant::new(2);
        let dim = 32;
        let mut ef = ErrorFeedback::new(dim, true);
        let mut rng = seeded_rng(1, 1);
        let d: Vec<f32> = (0..dim).map(|i| 0.1 * (i as f32 * 0.7).sin()).collect();
        let (msg, q) = ef.compress_q(&d, &lq, &mut rng);
        let q = q.to_vec();
        let mut dec = vec![0.0; dim];
        lq.decompress(&msg, &mut dec);
        assert_eq!(q, dec, "compress_q values must equal the wire decode");
        assert!(ef.residual_norm() > 0.0);
        let inf = ef.residual_inf_norm();
        assert!(inf > 0.0 && inf <= ef.residual_norm(), "∞-norm bounded by L2");
        assert_eq!(inf, ef.residual().iter().fold(0.0f32, |m, x| m.max(x.abs())));
        ef.reset();
        assert!(ef.residual().iter().all(|&x| x == 0.0));
        assert_eq!(ef.residual_norm(), 0.0);
        assert_eq!(ef.residual_inf_norm(), 0.0);
    }

    /// Per-range compression composes to the per-tensor semantics: each
    /// range gets its own scale, the residual outside the range is
    /// untouched, and compressing every range of a partition is
    /// equivalent to independent per-tensor EF state machines.
    #[test]
    fn compress_range_is_per_tensor_ef() {
        let lq = LogQuant::new(2);
        let dim = 24;
        let split = 10usize;
        let mut whole = ErrorFeedback::new(dim, true);
        let mut lo = ErrorFeedback::new(split, true);
        let mut hi = ErrorFeedback::new(dim - split, true);
        let mut rng = seeded_rng(2, 2);
        for t in 0..8 {
            let d: Vec<f32> =
                (0..dim).map(|i| ((i * 5 + t * 11) % 17) as f32 / 17.0 - 0.4).collect();
            let m0 = whole.compress_range(&d, 0, split, &lq, &mut rng);
            let m1 = whole.compress_range(&d, split, dim - split, &lq, &mut rng);
            let r0 = lo.compress(&d[..split], &lq, &mut rng);
            let r1 = hi.compress(&d[split..], &lq, &mut rng);
            assert_eq!(m0.to_bytes(), r0.to_bytes(), "t={t}");
            assert_eq!(m1.to_bytes(), r1.to_bytes(), "t={t}");
            assert_eq!(&whole.residual()[..split], lo.residual(), "t={t}");
            assert_eq!(&whole.residual()[split..], hi.residual(), "t={t}");
        }
    }

    /// The refund identity behind async rounds: rejecting a compressed
    /// delta and absorbing its decoded values restores `u = d + e`
    /// exactly — as if the step had never been quantized away.
    #[test]
    fn absorb_range_refunds_rejected_mass_exactly() {
        let lq = LogQuant::new(2);
        let dim = 16;
        let mut ef = ErrorFeedback::new(dim, true);
        let mut rng = seeded_rng(4, 0);
        let d: Vec<f32> = (0..dim).map(|i| 0.2 * (i as f32 * 0.9).cos()).collect();
        let (msg, q) = ef.compress_q(&d, &lq, &mut rng);
        let q = q.to_vec();
        let mut dec = vec![0.0; dim];
        lq.decompress(&msg, &mut dec);
        // full rejection: e' = (u − q) + q = u = d (e started at 0)
        ef.absorb_range(0, &dec, 1.0);
        for (ei, di) in ef.residual().iter().zip(&d) {
            assert!((ei - di).abs() < 1e-6, "{ei} vs {di}");
        }
        // partial refund (down-weighted apply at w): e gains (1−w)·q
        let before = ef.residual().to_vec();
        ef.absorb_range(0, &dec, 0.5);
        for ((ei, bi), qi) in ef.residual().iter().zip(&before).zip(&q) {
            assert!((ei - (bi + 0.5 * qi)).abs() < 1e-6);
        }
        // scale 0 and disabled EF are exact no-ops
        let snap = ef.residual().to_vec();
        ef.absorb_range(0, &dec, 0.0);
        assert_eq!(ef.residual(), snap.as_slice());
        let mut off = ErrorFeedback::new(dim, false);
        off.absorb_range(0, &dec, 1.0);
        assert!(off.residual().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn disabled_keeps_zero_residual() {
        let lq = LogQuant::new(1);
        let mut ef = ErrorFeedback::new(8, false);
        let mut rng = seeded_rng(0, 0);
        let d = vec![0.3f32; 8];
        ef.compress(&d, &lq, &mut rng);
        assert!(ef.residual().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn ef_bounds_accumulated_bias() {
        // With a very coarse quantizer, EF keeps the running sum of
        // applied deltas close to the running sum of directions; without
        // EF it drifts. This is the mechanism behind Theorem 3.1.
        let lq = LogQuant::new(0); // ternary: very coarse
        let dim = 32;
        let steps = 200;
        let run = |enabled: bool| -> f32 {
            let mut ef = ErrorFeedback::new(dim, enabled);
            let mut rng = seeded_rng(3, 0);
            let mut sum_d = vec![0.0f32; dim];
            let mut sum_q = vec![0.0f32; dim];
            for t in 0..steps {
                // fixed small direction with coordinate-dependent size —
                // coarse ternary without EF zeroes the small coordinates
                // forever.
                let d: Vec<f32> =
                    (0..dim).map(|i| 0.01 * (1.0 + i as f32) / dim as f32 * ((t % 3) as f32 + 1.0)).collect();
                let msg = ef.compress(&d, &lq, &mut rng);
                let mut q = vec![0.0; dim];
                lq.decompress(&msg, &mut q);
                for i in 0..dim {
                    sum_d[i] += d[i];
                    sum_q[i] += q[i];
                }
            }
            sum_d.iter().zip(&sum_q).map(|(a, b)| (a - b).abs()).sum::<f32>()
        };
        let drift_ef = run(true);
        let drift_noef = run(false);
        assert!(
            drift_ef < 0.5 * drift_noef,
            "ef drift {drift_ef} should be well below no-ef drift {drift_noef}"
        );
    }
}
