//! The codec policy layer: which compressor, at which bit-width, for
//! which tensor, on which round.
//!
//! The paper runs one static `k_g` for the whole model and the whole
//! run. Theorem 3.1 ties the error-feedback residual contraction
//! directly to the quantization level (`δ_g = 2^-(k_g+2)`), and the
//! adaptive-quantization line of work (Faghri et al., *Adaptive
//! Gradient Quantization for Data-Parallel SGD*; Chen et al.,
//! *Efficient-Adam*, which makes the two-way bit budget a first-class
//! tunable) shows that spending bits where the signal statistics need
//! them recovers most of the accuracy gap at the same byte budget. This
//! module makes that decision explicit and testable:
//!
//! * [`TensorLayout`] — the named parameter blocks of the flat model
//!   vector (from `artifacts/manifest.json` for real models, uniform
//!   blocks for sim workloads). The policy decides per tensor.
//! * [`PolicySpec`] — the parsed `--codec-policy` flag: `static` (the
//!   seed behavior, byte-identical to it), `per-layer:<name=k,…>`
//!   (fixed per-tensor levels), `adaptive:<lo>..<hi>` (the controller
//!   below).
//! * [`CodecPolicy`] — a bound policy instance: one per endpoint
//!   (each worker's uplink, the server's delta downlink), deciding the
//!   per-tensor `k_g` each round.
//!
//! # The adaptive rule
//!
//! Error feedback hands the controller its signal for free: after the
//! round-`t` compression the residual `e` holds exactly the mass the
//! codec failed to ship, so `‖e‖ / ‖g‖` over a tensor is the measured
//! relative quantization debt of that tensor (Assumption 2 bounds it by
//! `1 − δ_g`; the residual-contraction argument of Theorem 3.1 keeps it
//! near the per-step contraction in steady state). Per tensor, before
//! compressing round `t` the controller compares the debt left by round
//! `t−1` against a band:
//!
//! ```text
//!   r_i = ‖e‖₂(tensor i) / ‖g‖₂(tensor i)
//!   r_i > RATIO_GROW   and k < hi  ⇒  k ← k + 1
//!   r_i < RATIO_SHRINK and k > lo  ⇒  k ← k − 1
//! ```
//!
//! with `RATIO_GROW / RATIO_SHRINK = 4` and a [`HOLD_ROUNDS`]-round
//! freeze after every move — the two hysteresis mechanisms that stop
//! the controller from flapping on a noisy boundary.
//!
//! # Reproducibility
//!
//! A decision consumes no randomness and no wall clock: it is a pure
//! function of the observation stream `(dir, residual)` of its own
//! endpoint, which is itself deterministic in `(seed, t, tensor)` —
//! every gradient source and codec in this tree is. Hence a fixed-seed
//! adaptive run is bit-reproducible across the sequential, threaded and
//! TCP engines (asserted in `rust/tests/policy_parity.rs`), and two
//! controllers fed the same stream choose the same bits (property test
//! below).

use super::logquant::LogQuant;
use super::sparse::{SparseBlock, TopK, DENSITY_UNIT};
use super::{pack, Compressor, MAX_KG};
use anyhow::{anyhow, bail, Result};

/// One named parameter block of the flat model vector.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    /// Offset into the flat vector.
    pub start: usize,
    /// Element count.
    pub len: usize,
}

/// The named blocks of the flat vector, in ascending offset order and
/// covering it exactly — the granularity every [`CodecPolicy`] decision
/// (and every per-tensor wire part) works at.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorLayout {
    tensors: Vec<TensorSpec>,
    dim: usize,
}

impl TensorLayout {
    /// One tensor covering the whole vector (the degenerate layout sim
    /// CLIs fall back to).
    pub fn single(dim: usize) -> Self {
        Self::from_named(&[("flat".to_string(), dim)])
    }

    /// Build from `(name, len)` pairs laid out back to back — the shape
    /// `models::ParamLayout` provides.
    pub fn from_named(parts: &[(String, usize)]) -> Self {
        assert!(!parts.is_empty(), "layout needs at least one tensor");
        let mut tensors = Vec::with_capacity(parts.len());
        let mut off = 0usize;
        for (name, len) in parts {
            assert!(*len > 0, "tensor '{name}' is empty");
            tensors.push(TensorSpec { name: name.clone(), start: off, len: *len });
            off += len;
        }
        Self { tensors, dim: off }
    }

    /// Split `dim` into `parts` near-uniform blocks `b0..bN` (ragged
    /// tail on the last) — the layout sim workloads use, where the flat
    /// vector has no named parameters.
    pub fn uniform(dim: usize, parts: usize) -> Self {
        assert!(dim > 0, "layout needs a non-empty vector");
        let parts = parts.clamp(1, dim);
        let block = dim.div_ceil(parts);
        let named: Vec<(String, usize)> = (0..dim)
            .step_by(block)
            .enumerate()
            .map(|(i, start)| (format!("b{i}"), block.min(dim - start)))
            .collect();
        Self::from_named(&named)
    }

    pub fn tensors(&self) -> &[TensorSpec] {
        &self.tensors
    }

    /// Total element count (must equal the model dim).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The sub-layout covering `[start, start + len)`, with tensor
    /// offsets rebased to the range — what a parameter-server *shard*
    /// hands its own downlink [`CodecPolicy`] so per-tensor decisions
    /// compose across shards. Errors if either range edge splits a
    /// tensor: shard boundaries must snap to tensor boundaries
    /// (`crate::ps::shard::ShardPlan::snapped` guarantees it).
    pub fn crop(&self, start: usize, len: usize) -> Result<TensorLayout> {
        let end = start
            .checked_add(len)
            .filter(|&e| e <= self.dim)
            .ok_or_else(|| anyhow!("crop {start}+{len} outside layout dim {}", self.dim))?;
        let inside: Vec<(String, usize)> = self
            .tensors
            .iter()
            .filter(|ts| ts.start >= start && ts.start + ts.len <= end)
            .map(|ts| (ts.name.clone(), ts.len))
            .collect();
        let covered: usize = inside.iter().map(|(_, l)| l).sum();
        if inside.is_empty() || covered != len {
            bail!(
                "range {start}..{end} does not snap to tensor boundaries \
                 ({covered} of {len} elements covered by whole tensors)"
            );
        }
        Ok(Self::from_named(&inside))
    }
}

/// Controller thresholds: grow above, shrink below. The 4x gap between
/// them is the hysteresis band (a tensor sitting at the boundary cannot
/// alternate: after a grow its ratio must *quadruple back* before the
/// controller shrinks again).
pub const RATIO_GROW: f32 = 0.4;
pub const RATIO_SHRINK: f32 = 0.1;
/// Rounds a tensor's level is frozen after a change (flap damping: the
/// EF residual needs a round or two to reflect the new codec).
pub const HOLD_ROUNDS: u32 = 2;

/// The parsed `--codec-policy` flag.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub enum PolicySpec {
    /// One global `k_g` for every tensor and round — the seed behavior.
    /// The trainer installs no policy at all, keeping the single-message
    /// uplink byte-identical to pre-policy builds.
    #[default]
    Static,
    /// Fixed per-tensor levels: `(pattern, k_g)` pairs, first match
    /// wins. A pattern is an exact tensor name, a `prefix*` glob, or
    /// the catch-all `*`; unmatched tensors keep the method's base
    /// `k_g`.
    PerLayer(Vec<(String, u32)>),
    /// The error-feedback-driven controller, confined to `lo..=hi`.
    Adaptive { lo: u32, hi: u32 },
    /// [`Self::PerLayer`] generalized to mixed codec families — what a
    /// `per-layer:` spelling with at least one sparse value (`topk@d`,
    /// `sblock@BxK`) parses to. All-dense spellings keep parsing to
    /// `PerLayer`, so existing configs bind byte-identically.
    PerLayerCodec(Vec<(String, RuleCodec)>),
    /// The adaptive controller steering a [`TopK`] *density* instead of
    /// a LogQuant level: same residual-ratio band rule, multiplicative
    /// steps (densities span decades, where ±1 never would), band in
    /// 1/10000ths kept.
    AdaptiveTopK { lo: u32, hi: u32 },
}

/// One per-tensor codec rule of a [`PolicySpec::PerLayerCodec`] spec,
/// as a `per-layer:` value spells it: a dense LogQuant level (`=4`), a
/// TopK density (`=topk@0.05`), or a blockwise top-k shape
/// (`=sblock@64x4`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RuleCodec {
    Log(u32),
    /// Kept density in 1/10000ths.
    TopK(u32),
    SparseBlock { block: u32, kb: u32 },
}

/// Parse a `per-layer:` rule value into its codec family.
fn parse_rule_value(v: &str) -> Result<RuleCodec> {
    if let Some(d) = v.strip_prefix("topk@") {
        Ok(RuleCodec::TopK(parse_density(d)?))
    } else if let Some(shape) = v.strip_prefix("sblock@") {
        let (b, kb) = shape
            .split_once('x')
            .ok_or_else(|| anyhow!("sparse-block shape '{shape}' is not BLOCKxK"))?;
        let b: u32 = b.parse().map_err(|e| anyhow!("bad sparse-block size '{b}': {e}"))?;
        let kb: u32 = kb.parse().map_err(|e| anyhow!("bad sparse-block keep '{kb}': {e}"))?;
        if b == 0 || b > 0xffff || kb == 0 || kb > b {
            bail!("sparse-block shape {b}x{kb} invalid (need 1 <= K <= BLOCK <= 65535)");
        }
        Ok(RuleCodec::SparseBlock { block: b, kb })
    } else {
        let k: u32 = v.parse().map_err(|e| anyhow!("bad per-layer level '{v}': {e}"))?;
        Ok(RuleCodec::Log(k))
    }
}

/// A kept-density fraction (`0 < d <= 1`) to integer 1/10000ths,
/// rounded, floored at 1 so any accepted density ships something.
fn parse_density(d: &str) -> Result<u32> {
    let x: f64 = d.parse().map_err(|e| anyhow!("bad topk density '{d}': {e}"))?;
    if !(x > 0.0 && x <= 1.0) {
        bail!("topk density {d} out of range (0 < d <= 1)");
    }
    Ok((x * DENSITY_UNIT as f64).round().clamp(1.0, DENSITY_UNIT as f64) as u32)
}

impl PolicySpec {
    /// Parse a CLI flag value:
    ///
    /// ```text
    ///   static
    ///   per-layer:dense1=4,conv*=3,*=2
    ///   per-layer:expert*=topk@0.05,router=sblock@64x4,*=2
    ///   adaptive:0..4
    ///   adaptive-topk:0.01..0.25
    /// ```
    pub fn parse(s: &str) -> Result<Self> {
        let spec = if s == "static" {
            Self::Static
        } else if let Some(body) = s.strip_prefix("per-layer:") {
            let mut rules = Vec::new();
            for tok in body.split(',').map(str::trim).filter(|t| !t.is_empty()) {
                let (pat, v) = tok
                    .split_once('=')
                    .ok_or_else(|| anyhow!("per-layer rule '{tok}' is not name=k"))?;
                rules.push((pat.to_string(), parse_rule_value(v)?));
            }
            if rules.iter().all(|(_, c)| matches!(c, RuleCodec::Log(_))) {
                // All-dense spellings keep the original variant so
                // existing configs compare (and bind) exactly as before.
                Self::PerLayer(
                    rules
                        .into_iter()
                        .map(|(pat, c)| match c {
                            RuleCodec::Log(k) => (pat, k),
                            _ => unreachable!("checked all-dense above"),
                        })
                        .collect(),
                )
            } else {
                Self::PerLayerCodec(rules)
            }
        } else if let Some(band) = s.strip_prefix("adaptive-topk:") {
            let (lo, hi) = band
                .split_once("..")
                .ok_or_else(|| anyhow!("adaptive-topk band '{band}' is not LO..HI"))?;
            Self::AdaptiveTopK { lo: parse_density(lo)?, hi: parse_density(hi)? }
        } else if let Some(band) = s.strip_prefix("adaptive:") {
            let (lo, hi) = band
                .split_once("..")
                .ok_or_else(|| anyhow!("adaptive band '{band}' is not LO..HI"))?;
            let lo: u32 = lo.parse().map_err(|e| anyhow!("bad band low '{lo}': {e}"))?;
            let hi: u32 = hi.parse().map_err(|e| anyhow!("bad band high '{hi}': {e}"))?;
            Self::Adaptive { lo, hi }
        } else {
            return Err(anyhow!(
                "unknown codec policy '{s}' (static | per-layer:<name=k|topk@d|sblock@BxK,…> \
                 | adaptive:<lo>..<hi> | adaptive-topk:<lo>..<hi>)"
            ));
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Validate the spec's levels against the codec domain — the one
    /// owner of the band/level rule, shared by [`Self::parse`],
    /// [`CodecPolicy::new`] and `ExperimentConfig::validate`.
    pub fn validate(&self) -> Result<()> {
        match self {
            Self::Static => {}
            Self::PerLayer(rules) => {
                if rules.is_empty() {
                    bail!("per-layer policy has no rules");
                }
                for (_, k) in rules {
                    if *k > MAX_KG {
                        bail!("per-layer level {k} out of range (k_g <= {MAX_KG})");
                    }
                }
            }
            Self::Adaptive { lo, hi } => {
                if lo > hi || *hi > MAX_KG {
                    bail!("adaptive band {lo}..{hi} invalid (need lo <= hi <= {MAX_KG})");
                }
            }
            Self::PerLayerCodec(rules) => {
                if rules.is_empty() {
                    bail!("per-layer policy has no rules");
                }
                for (_, c) in rules {
                    match c {
                        RuleCodec::Log(k) => {
                            if *k > MAX_KG {
                                bail!("per-layer level {k} out of range (k_g <= {MAX_KG})");
                            }
                        }
                        RuleCodec::TopK(d) => {
                            if *d == 0 || *d > DENSITY_UNIT {
                                bail!("topk density {d} out of range (1..={DENSITY_UNIT} of {DENSITY_UNIT})");
                            }
                        }
                        RuleCodec::SparseBlock { block, kb } => {
                            if *block == 0 || *block > 0xffff || *kb == 0 || kb > block {
                                bail!("sparse-block shape {block}x{kb} invalid (need 1 <= K <= BLOCK <= 65535)");
                            }
                        }
                    }
                }
            }
            Self::AdaptiveTopK { lo, hi } => {
                if *lo == 0 || lo > hi || *hi > DENSITY_UNIT {
                    bail!(
                        "adaptive-topk band {lo}..{hi} invalid \
                         (need 1 <= lo <= hi <= {DENSITY_UNIT}, in 1/{DENSITY_UNIT}ths kept)"
                    );
                }
            }
        }
        Ok(())
    }

    pub fn is_static(&self) -> bool {
        matches!(self, Self::Static)
    }

    /// True when the spec binds any tensor to a sparse codec — sparse
    /// shipping drops mass by design, so these specs require error
    /// feedback (the CLI rejects them under `--no-ef`, like `adaptive`).
    pub fn is_sparse(&self) -> bool {
        match self {
            Self::AdaptiveTopK { .. } => true,
            Self::PerLayerCodec(rules) => {
                rules.iter().any(|(_, c)| !matches!(c, RuleCodec::Log(_)))
            }
            _ => false,
        }
    }

    pub fn label(&self) -> String {
        match self {
            Self::Static => "static".into(),
            Self::PerLayer(_) => "per-layer".into(),
            Self::Adaptive { lo, hi } => format!("adaptive{lo}..{hi}"),
            Self::PerLayerCodec(_) => "per-layer+sparse".into(),
            Self::AdaptiveTopK { lo, hi } => format!("adaptive-topk{lo}..{hi}bp"),
        }
    }
}

/// First matching rule wins; `prefix*` globs and the `*` catch-all are
/// supported; `None` when nothing matches.
fn match_rule<T: Copy>(rules: &[(String, T)], name: &str) -> Option<T> {
    rules
        .iter()
        .find(|(pat, _)| {
            pat == "*"
                || pat == name
                || pat.strip_suffix('*').is_some_and(|prefix| name.starts_with(prefix))
        })
        .map(|(_, k)| *k)
}

/// A bound policy: the per-tensor `k_g` decision state of one endpoint
/// (a worker's uplink or the server's delta downlink). Construct one
/// per endpoint — state never crosses the wire; only the chosen codecs
/// do, inside each part's `WireMsg` header.
#[derive(Clone, Debug)]
pub struct CodecPolicy {
    spec: PolicySpec,
    layout: TensorLayout,
    /// The codec family bound to each tensor; fixes the *meaning* of
    /// the paired [`Self::bits`] level (`k_g` for Log, kept density in
    /// 1/10000ths for TopK; SparseBlock carries its shape in the kind
    /// and its level is informational).
    kinds: Vec<CodecKind>,
    /// Current level per tensor (see [`Self::kinds`]).
    bits: Vec<u32>,
    /// Per-tensor freeze countdown after a level change.
    hold: Vec<u32>,
}

/// The codec family bound to one tensor of a [`CodecPolicy`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecKind {
    Log,
    TopK,
    SparseBlock { block: u32, kb: u32 },
}

/// A stack-constructed compressor bound to one tensor — what
/// [`CodecPolicy::codec_at`] hands the per-round compression sites, so
/// the hot path keeps the zero-alloc shape of the `LogQuant::new` call
/// it generalizes.
#[derive(Clone, Copy, Debug)]
pub enum BoundCodec {
    Log(LogQuant),
    TopK(TopK),
    Block(SparseBlock),
}

impl BoundCodec {
    pub fn as_dyn(&self) -> &dyn Compressor {
        match self {
            Self::Log(c) => c,
            Self::TopK(c) => c,
            Self::Block(c) => c,
        }
    }
}

impl CodecPolicy {
    /// Bind `spec` to `layout`. `base_kg` is the method's configured
    /// `k_g`: the static/per-layer fallback level, and the adaptive
    /// controller's start point (clamped into the band).
    pub fn new(spec: PolicySpec, layout: TensorLayout, base_kg: u32) -> Result<Self> {
        if base_kg > MAX_KG {
            bail!("k_g = {base_kg} out of range (k_g <= {MAX_KG})");
        }
        spec.validate()?;
        let n = layout.tensors().len();
        let bits = match &spec {
            PolicySpec::Static => vec![base_kg; n],
            PolicySpec::PerLayer(rules) => layout
                .tensors()
                .iter()
                .map(|ts| match_rule(rules, &ts.name).unwrap_or(base_kg))
                .collect(),
            PolicySpec::Adaptive { lo, hi } => vec![base_kg.clamp(*lo, *hi); n],
            PolicySpec::PerLayerCodec(rules) => layout
                .tensors()
                .iter()
                .map(|ts| match match_rule(rules, &ts.name) {
                    Some(RuleCodec::Log(k)) => k,
                    Some(RuleCodec::TopK(d)) => d,
                    Some(RuleCodec::SparseBlock { kb, .. }) => kb,
                    None => base_kg,
                })
                .collect(),
            // The controller starts at the band's dense edge and works
            // down: overshipping early rounds costs bytes, undershipping
            // costs convergence, and only one of those self-corrects
            // before the residual signal arrives.
            PolicySpec::AdaptiveTopK { hi, .. } => vec![*hi; n],
        };
        let kinds = match &spec {
            PolicySpec::PerLayerCodec(rules) => layout
                .tensors()
                .iter()
                .map(|ts| match match_rule(rules, &ts.name) {
                    Some(RuleCodec::TopK(_)) => CodecKind::TopK,
                    Some(RuleCodec::SparseBlock { block, kb }) => {
                        CodecKind::SparseBlock { block, kb }
                    }
                    Some(RuleCodec::Log(_)) | None => CodecKind::Log,
                })
                .collect(),
            PolicySpec::AdaptiveTopK { .. } => vec![CodecKind::TopK; n],
            _ => vec![CodecKind::Log; n],
        };
        Ok(Self { spec, layout, kinds, bits, hold: vec![0; n] })
    }

    pub fn spec(&self) -> &PolicySpec {
        &self.spec
    }

    pub fn layout(&self) -> &TensorLayout {
        &self.layout
    }

    /// The per-tensor levels the next compression must use (updated by
    /// [`Self::decide`]; constant for static/per-layer specs).
    pub fn bits(&self) -> &[u32] {
        &self.bits
    }

    /// The codec family bound to each tensor.
    pub fn kinds(&self) -> &[CodecKind] {
        &self.kinds
    }

    /// The compressor tensor `i`'s next compression must use at the
    /// current level — `LogQuant::new(policy.bits()[i])` generalized to
    /// the bound codec family, still constructed on the stack.
    pub fn codec_at(&self, i: usize) -> BoundCodec {
        match self.kinds[i] {
            CodecKind::Log => BoundCodec::Log(LogQuant::new(self.bits[i])),
            CodecKind::TopK => BoundCodec::TopK(TopK::new(self.bits[i])),
            CodecKind::SparseBlock { block, kb } => {
                BoundCodec::Block(SparseBlock::new(block as usize, kb as usize))
            }
        }
    }

    /// Mean *code* bits per element at the current levels, weighted by
    /// tensor size — the analytic uplink cost the Comm column and the
    /// metrics CSV report. Log tensors keep the exact
    /// `LogQuant::code_bits` accounting; sparse tensors charge 32 value
    /// bits per kept element plus their position payload.
    pub fn mean_code_bits(&self) -> f64 {
        let total = self.layout.dim() as f64;
        self.layout
            .tensors()
            .iter()
            .zip(self.bits.iter().zip(&self.kinds))
            .map(|(ts, (&level, &kind))| per_element_bits(kind, level, ts.len) * ts.len as f64)
            .sum::<f64>()
            / total
    }

    /// One controller step, run *before* compressing round `t`: `dir`
    /// is the direction about to be compressed, `residual` the
    /// error-feedback state left by round `t − 1`. Pure in its inputs:
    /// no rng, no clock — the reproducibility contract of the module
    /// docs. No-op for static/per-layer specs.
    pub fn decide(&mut self, _t: u64, dir: &[f32], residual: &[f32]) {
        // The sparse controller moves the TopK density multiplicatively
        // (densities span decades; ±1/10000th steps never would), the
        // dense one moves k_g by ±1 — same band, same hysteresis.
        let (lo, hi, sparse) = match &self.spec {
            PolicySpec::Adaptive { lo, hi } => (*lo, *hi, false),
            PolicySpec::AdaptiveTopK { lo, hi } => (*lo, *hi, true),
            _ => return,
        };
        debug_assert_eq!(dir.len(), self.layout.dim());
        debug_assert_eq!(residual.len(), self.layout.dim());
        for (i, ts) in self.layout.tensors().iter().enumerate() {
            if self.hold[i] > 0 {
                self.hold[i] -= 1;
                continue;
            }
            let g = l2(&dir[ts.start..ts.start + ts.len]);
            if g == 0.0 {
                continue; // nothing to ship: any level is exact
            }
            let r = l2(&residual[ts.start..ts.start + ts.len]) / g;
            if r > RATIO_GROW && self.bits[i] < hi {
                self.bits[i] = if sparse { (self.bits[i] * 2).min(hi) } else { self.bits[i] + 1 };
                self.hold[i] = HOLD_ROUNDS;
            } else if r < RATIO_SHRINK && self.bits[i] > lo {
                self.bits[i] = if sparse { (self.bits[i] / 2).max(lo) } else { self.bits[i] - 1 };
                self.hold[i] = HOLD_ROUNDS;
            }
        }
    }
}

/// Analytic code bits per element for one bound tensor (the sparse
/// terms mirror `Compressor::bits_per_element`, with TopK's position
/// term sharpened by the tensor length the policy knows).
fn per_element_bits(kind: CodecKind, level: u32, len: usize) -> f64 {
    match kind {
        CodecKind::Log => LogQuant::new(level).code_bits() as f64,
        CodecKind::TopK => {
            let d = level as f64 / DENSITY_UNIT as f64;
            d * 32.0 + (d * pack::bits_for_symbols(len.max(1) as u32) as f64).min(1.0)
        }
        CodecKind::SparseBlock { block, kb } => {
            let cb = pack::bits_for_symbols(block) as f64 + 1.0;
            (kb as f64 * cb + 32.0) / block as f64
        }
    }
}

fn l2(xs: &[f32]) -> f32 {
    xs.iter().map(|x| x * x).sum::<f32>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout3() -> TensorLayout {
        TensorLayout::from_named(&[
            ("dense1".to_string(), 8),
            ("dense2".to_string(), 16),
            ("head".to_string(), 4),
        ])
    }

    #[test]
    fn layout_offsets_and_dim() {
        let l = layout3();
        assert_eq!(l.dim(), 28);
        assert_eq!(l.tensors()[0], TensorSpec { name: "dense1".into(), start: 0, len: 8 });
        assert_eq!(l.tensors()[2], TensorSpec { name: "head".into(), start: 24, len: 4 });
        let u = TensorLayout::uniform(10, 4);
        assert_eq!(u.dim(), 10);
        let lens: Vec<usize> = u.tensors().iter().map(|t| t.len).collect();
        assert_eq!(lens, vec![3, 3, 3, 1], "ragged tail on the last block");
        assert_eq!(TensorLayout::single(5).tensors().len(), 1);
        // more parts than elements clamps
        assert_eq!(TensorLayout::uniform(3, 100).tensors().len(), 3);
    }

    #[test]
    fn crop_rebases_whole_tensors_and_rejects_splits() {
        let l = layout3(); // dense1[0..8) dense2[8..24) head[24..28)
        let sub = l.crop(8, 20).unwrap();
        assert_eq!(sub.dim(), 20);
        assert_eq!(sub.tensors()[0], TensorSpec { name: "dense2".into(), start: 0, len: 16 });
        assert_eq!(sub.tensors()[1], TensorSpec { name: "head".into(), start: 16, len: 4 });
        // whole-layout crop is the identity
        assert_eq!(l.crop(0, 28).unwrap(), l);
        // a range edge inside dense2 must be rejected
        assert!(l.crop(0, 12).is_err());
        assert!(l.crop(10, 18).is_err());
        // out of bounds
        assert!(l.crop(8, 28).is_err());
    }

    #[test]
    fn spec_parse_roundtrip_and_errors() {
        assert_eq!(PolicySpec::parse("static").unwrap(), PolicySpec::Static);
        assert_eq!(
            PolicySpec::parse("adaptive:0..4").unwrap(),
            PolicySpec::Adaptive { lo: 0, hi: 4 }
        );
        assert_eq!(
            PolicySpec::parse("per-layer:dense1=4,conv*=3,*=2").unwrap(),
            PolicySpec::PerLayer(vec![
                ("dense1".into(), 4),
                ("conv*".into(), 3),
                ("*".into(), 2)
            ])
        );
        assert!(PolicySpec::parse("adaptive:4..2").is_err(), "inverted band");
        assert!(PolicySpec::parse("adaptive:0..99").is_err(), "band above MAX_KG");
        assert!(PolicySpec::parse("adaptive:0-4").is_err(), "bad separator");
        assert!(PolicySpec::parse("per-layer:").is_err(), "no rules");
        assert!(PolicySpec::parse("per-layer:dense1=99").is_err(), "level above MAX_KG");
        assert!(PolicySpec::parse("frobnicate").is_err());
        assert_eq!(PolicySpec::default(), PolicySpec::Static);
        assert_eq!(PolicySpec::Adaptive { lo: 0, hi: 4 }.label(), "adaptive0..4");
    }

    #[test]
    fn per_layer_binding_first_match_wins_and_falls_back() {
        let spec = PolicySpec::parse("per-layer:dense1=4,dense*=3").unwrap();
        let p = CodecPolicy::new(spec, layout3(), 2).unwrap();
        // dense1 hits the exact rule before the glob; head falls back to
        // the base k_g.
        assert_eq!(p.bits(), &[4, 3, 2]);
        let all = CodecPolicy::new(PolicySpec::parse("per-layer:*=1").unwrap(), layout3(), 2)
            .unwrap();
        assert_eq!(all.bits(), &[1, 1, 1]);
    }

    #[test]
    fn adaptive_grows_on_debt_and_shrinks_when_idle() {
        let mut p =
            CodecPolicy::new(PolicySpec::Adaptive { lo: 0, hi: 6 }, layout3(), 2).unwrap();
        let dim = p.layout().dim();
        let ones = vec![1.0f32; dim];
        // Residual as large as the direction on tensor 0 only: tensor 0
        // grows, the idle tensors shrink.
        let mut e = vec![0.0f32; dim];
        for v in e.iter_mut().take(8) {
            *v = 1.0;
        }
        p.decide(1, &ones, &e);
        assert_eq!(p.bits(), &[3, 1, 1]);
        // Frozen for HOLD_ROUNDS rounds: the same observation moves
        // nothing.
        p.decide(2, &ones, &e);
        p.decide(3, &ones, &e);
        assert_eq!(p.bits(), &[3, 1, 1], "hold must damp flapping");
        // After the hold expires the pressure is still there: grow again.
        p.decide(4, &ones, &e);
        assert_eq!(p.bits(), &[4, 0, 0]);
    }

    #[test]
    fn adaptive_respects_the_band_edges() {
        let mut p =
            CodecPolicy::new(PolicySpec::Adaptive { lo: 1, hi: 3 }, layout3(), 0).unwrap();
        assert_eq!(p.bits(), &[1, 1, 1], "start clamps into the band");
        let dim = p.layout().dim();
        let ones = vec![1.0f32; dim];
        let zeros = vec![0.0f32; dim];
        // Decades of shrink pressure never go below lo…
        for t in 1..=40 {
            p.decide(t, &ones, &zeros);
            assert!(p.bits().iter().all(|&b| (1..=3).contains(&b)), "t={t}: {:?}", p.bits());
        }
        assert_eq!(p.bits(), &[1, 1, 1]);
        // …and saturated grow pressure never exceeds hi.
        for t in 41..=80 {
            p.decide(t, &ones, &ones);
            assert!(p.bits().iter().all(|&b| (1..=3).contains(&b)), "t={t}: {:?}", p.bits());
        }
        assert_eq!(p.bits(), &[3, 3, 3]);
    }

    #[test]
    fn zero_direction_holds_the_level() {
        let mut p =
            CodecPolicy::new(PolicySpec::Adaptive { lo: 0, hi: 4 }, layout3(), 2).unwrap();
        let dim = p.layout().dim();
        let zeros = vec![0.0f32; dim];
        p.decide(1, &zeros, &zeros);
        assert_eq!(p.bits(), &[2, 2, 2]);
    }

    /// Reproducibility: two controllers fed the same deterministic
    /// observation stream choose identical levels at every round, and
    /// never leave the band.
    #[test]
    fn controller_is_pure_in_its_observation_stream() {
        let run = |debt: f32, seed: u64| -> Vec<Vec<u32>> {
            let mut p = CodecPolicy::new(PolicySpec::Adaptive { lo: 0, hi: 5 }, layout3(), 2)
                .unwrap();
            let dim = p.layout().dim();
            let mut trace = Vec::new();
            let mut rng = crate::quant::seeded_rng(seed, 0);
            for t in 1u64..=20 {
                let dir: Vec<f32> = (0..dim).map(|_| rng.gen_normal() * 0.1).collect();
                // residual = debt × direction: the observed ratio is
                // exactly `debt`, whatever the rng drew
                let e: Vec<f32> = dir.iter().map(|d| d * debt).collect();
                p.decide(t, &dir, &e);
                assert!(p.bits().iter().all(|&b| b <= 5), "band violated at t={t}");
                trace.push(p.bits().to_vec());
            }
            trace
        };
        assert_eq!(run(1.0, 7), run(1.0, 7), "same stream must give the same decisions");
        assert_ne!(
            run(1.0, 7),
            run(0.01, 7),
            "the observed debt must actually steer the controller"
        );
    }

    #[test]
    fn mean_code_bits_weights_by_tensor_size() {
        let p = CodecPolicy::new(
            PolicySpec::parse("per-layer:dense1=2,dense2=0,head=2").unwrap(),
            layout3(),
            2,
        )
        .unwrap();
        // code bits: kg=2 -> 3 bits, kg=0 -> 2 bits
        let want = (3.0 * 8.0 + 2.0 * 16.0 + 3.0 * 4.0) / 28.0;
        assert!((p.mean_code_bits() - want).abs() < 1e-12);
    }

    #[test]
    fn new_rejects_out_of_range_levels() {
        assert!(CodecPolicy::new(PolicySpec::Static, layout3(), 99).is_err());
        assert!(
            CodecPolicy::new(PolicySpec::Adaptive { lo: 0, hi: 99 }, layout3(), 2).is_err()
        );
        assert!(CodecPolicy::new(
            PolicySpec::PerLayer(vec![("*".into(), 77)]),
            layout3(),
            2
        )
        .is_err());
    }

    #[test]
    fn sparse_spec_parse_and_errors() {
        let spec = PolicySpec::parse("per-layer:expert*=topk@0.05,router=sblock@64x4,*=2")
            .unwrap();
        assert_eq!(
            spec,
            PolicySpec::PerLayerCodec(vec![
                ("expert*".into(), RuleCodec::TopK(500)),
                ("router".into(), RuleCodec::SparseBlock { block: 64, kb: 4 }),
                ("*".into(), RuleCodec::Log(2)),
            ])
        );
        assert!(spec.is_sparse());
        assert!(!spec.is_static());
        assert_eq!(spec.label(), "per-layer+sparse");
        // All-dense spellings keep parsing to the original variant.
        assert_eq!(
            PolicySpec::parse("per-layer:dense1=4,*=2").unwrap(),
            PolicySpec::PerLayer(vec![("dense1".into(), 4), ("*".into(), 2)])
        );
        assert!(!PolicySpec::parse("per-layer:dense1=4").unwrap().is_sparse());
        assert_eq!(
            PolicySpec::parse("adaptive-topk:0.01..0.25").unwrap(),
            PolicySpec::AdaptiveTopK { lo: 100, hi: 2500 }
        );
        assert!(PolicySpec::AdaptiveTopK { lo: 100, hi: 2500 }.is_sparse());
        assert!(PolicySpec::parse("per-layer:a=topk@0").is_err(), "zero density");
        assert!(PolicySpec::parse("per-layer:a=topk@1.5").is_err(), "density above 1");
        assert!(PolicySpec::parse("per-layer:a=topk@x").is_err(), "non-numeric density");
        assert!(PolicySpec::parse("per-layer:a=sblock@4x5").is_err(), "keep above block");
        assert!(PolicySpec::parse("per-layer:a=sblock@0x1").is_err(), "zero block");
        assert!(PolicySpec::parse("per-layer:a=sblock@8").is_err(), "missing keep");
        assert!(PolicySpec::parse("adaptive-topk:0.25..0.01").is_err(), "inverted band");
        assert!(PolicySpec::parse("adaptive-topk:0..0.25").is_err(), "zero band low");
    }

    #[test]
    fn sparse_binding_sets_kinds_levels_and_codecs() {
        let spec =
            PolicySpec::parse("per-layer:dense1=topk@0.05,dense2=sblock@8x2,*=3").unwrap();
        let p = CodecPolicy::new(spec, layout3(), 2).unwrap();
        assert_eq!(p.bits(), &[500, 2, 3]);
        assert_eq!(
            p.kinds(),
            &[CodecKind::TopK, CodecKind::SparseBlock { block: 8, kb: 2 }, CodecKind::Log]
        );
        assert_eq!(p.codec_at(0).as_dyn().codec(), crate::quant::CodecId::TopK);
        assert_eq!(p.codec_at(1).as_dyn().codec(), crate::quant::CodecId::SparseBlock);
        assert_eq!(p.codec_at(2).as_dyn().codec(), crate::quant::CodecId::LogQuant);
        // dense specs bind every tensor to Log — the pre-sparse shape
        let dense = CodecPolicy::new(PolicySpec::Static, layout3(), 2).unwrap();
        assert!(dense.kinds().iter().all(|k| *k == CodecKind::Log));
    }

    #[test]
    fn adaptive_topk_moves_density_multiplicatively_in_band() {
        let spec = PolicySpec::AdaptiveTopK { lo: 100, hi: 2500 };
        let mut p = CodecPolicy::new(spec, layout3(), 2).unwrap();
        assert_eq!(p.bits(), &[2500, 2500, 2500], "starts at the dense edge");
        assert!(p.kinds().iter().all(|k| *k == CodecKind::TopK));
        let dim = p.layout().dim();
        let ones = vec![1.0f32; dim];
        let zeros = vec![0.0f32; dim];
        // No residual debt: halve (then hold) toward the band floor.
        p.decide(1, &ones, &zeros);
        assert_eq!(p.bits(), &[1250, 1250, 1250]);
        p.decide(2, &ones, &zeros);
        assert_eq!(p.bits(), &[1250, 1250, 1250], "hold must damp flapping");
        for t in 3..=40 {
            p.decide(t, &ones, &zeros);
            assert!(p.bits().iter().all(|&b| (100..=2500).contains(&b)), "t={t}");
        }
        assert_eq!(p.bits(), &[100, 100, 100], "clamps at the band floor");
        // Saturated debt: double back up to the band ceiling.
        for t in 41..=80 {
            p.decide(t, &ones, &ones);
            assert!(p.bits().iter().all(|&b| (100..=2500).contains(&b)), "t={t}");
        }
        assert_eq!(p.bits(), &[2500, 2500, 2500]);
    }

    #[test]
    fn sparse_mean_code_bits_charges_positions_and_values() {
        let spec =
            PolicySpec::parse("per-layer:dense1=topk@0.25,dense2=sblock@8x2,head=2").unwrap();
        let p = CodecPolicy::new(spec, layout3(), 2).unwrap();
        // dense1 (len 8, d=0.25): 0.25·32 + min(0.25·3, 1) = 8.75
        // dense2 (8x2): (2·4 + 32) / 8 = 5.0
        // head (kg=2): 3 code bits
        let want = (8.75 * 8.0 + 5.0 * 16.0 + 3.0 * 4.0) / 28.0;
        assert!((p.mean_code_bits() - want).abs() < 1e-12, "{}", p.mean_code_bits());
    }
}
