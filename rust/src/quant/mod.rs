//! Quantizers / compressors and their wire format.
//!
//! The paper's two quantization operators plus the two experimental
//! baselines, all behind one [`Compressor`] trait:
//!
//! * [`logquant::LogQuant`] — the paper's gradient quantizer `Q_g`
//!   (∞-norm-scaled power-of-two levels, biased, deterministic).
//! * [`wquant::WQuant`] — the paper's weight quantizer `Q_x`
//!   (uniform grid, scale 0.5).
//! * [`terngrad::TernGrad`] — Wen et al. [39]: unbiased stochastic
//!   ternary (the unbiased baseline in Tables 2–3).
//! * [`blockwise::Blockwise`] — Zheng et al. [44]: per-block
//!   sign·mean(|block|) (the biased baseline in Tables 2–3).
//! * [`Identity`] — full precision (the fp32 rows).
//! * [`sparse::TopK`] / [`sparse::SparseBlock`] — sparsifiers (global
//!   magnitude top-k, blockwise top-k with per-block scale) whose
//!   dropped mass rides the error-feedback residual.
//!
//! [`WireMsg`] is the byte-accurate message each worker sends to the
//! parameter server; `wire_bytes()` is what the Comm columns of
//! Tables 2–3 measure.

pub mod blockwise;
pub mod error_feedback;
pub mod logquant;
pub mod pack;
pub mod policy;
#[doc(hidden)]
pub mod reference;
pub mod sparse;
pub mod stochastic;
pub mod terngrad;
pub mod wquant;

pub use blockwise::Blockwise;
pub use error_feedback::ErrorFeedback;
pub use logquant::LogQuant;
pub use policy::{CodecPolicy, PolicySpec, TensorLayout};
pub use sparse::{SparseBlock, TopK};
pub use stochastic::{Qsgd, StochasticLogQuant};
pub use terngrad::TernGrad;
pub use wquant::WQuant;

use crate::util::DetRng;

/// Largest accepted gradient-quantization level `k_g` (`LogQuant` /
/// `StochasticLogQuant`). Enforced at config parse time
/// (`coordinator::config::ExperimentConfig::validate`), at policy
/// binding, and on the wire ([`WireMsg::from_bytes`] rejects frames
/// claiming more) — so an out-of-range level is a clean error
/// everywhere, never a mid-run panic.
pub const MAX_KG: u32 = 20;

/// Largest accepted weight-quantization level `k_x` ([`WQuant`]).
pub const MAX_KX: u32 = 22;

/// Validate optional quantization levels against the codec domains —
/// the one implementation behind the CLI flags (`--kg`/`--kx`) and
/// `ExperimentConfig::validate`, so an out-of-range level is a clear
/// parse-time error instead of a panic inside a codec constructor
/// mid-run.
pub fn validate_levels(kg: Option<u32>, kx: Option<u32>) -> anyhow::Result<()> {
    if let Some(k) = kg {
        if k > MAX_KG {
            anyhow::bail!("--kg {k} out of range (k_g <= {MAX_KG})");
        }
    }
    if let Some(k) = kx {
        if k > MAX_KX {
            anyhow::bail!("--kx {k} out of range (k_x <= {MAX_KX})");
        }
    }
    Ok(())
}

/// Compressor family id — first wire byte, also used in configs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum CodecId {
    Identity = 0,
    LogQuant = 1,
    WQuant = 2,
    TernGrad = 3,
    Blockwise = 4,
    Qsgd = 5,
    TopK = 6,
    SparseBlock = 7,
}

impl CodecId {
    pub fn from_u8(v: u8) -> Option<Self> {
        match v {
            0 => Some(Self::Identity),
            1 => Some(Self::LogQuant),
            2 => Some(Self::WQuant),
            3 => Some(Self::TernGrad),
            4 => Some(Self::Blockwise),
            5 => Some(Self::Qsgd),
            6 => Some(Self::TopK),
            7 => Some(Self::SparseBlock),
            _ => None,
        }
    }
}

/// A compressed tensor as it crosses the network.
///
/// Dense codecs populate exactly one payload representation: packed
/// `codes` + `scales` for real quantizers, `raw` for [`Identity`].
/// [`sparse::TopK`] is the one codec carrying both — packed positions
/// in `codes`, kept values in `raw`. `wire_bytes()` charges the
/// header, the scales and both payloads — nothing else.
#[derive(Clone, Debug)]
pub struct WireMsg {
    pub codec: CodecId,
    /// Codec parameter needed to decode: `k_g` for LogQuant, `k_x` for
    /// WQuant, block size for Blockwise, kept count `k` for TopK,
    /// `block | kb << 16` for SparseBlock, 0 otherwise.
    pub param: u32,
    /// Element count of the original tensor.
    pub n: usize,
    /// Per-message (len 1) or per-block (len = nblocks) scales.
    pub scales: Vec<f32>,
    /// Packed codes (empty for Identity).
    pub codes: Option<pack::Packed>,
    /// Raw f32 payload (Identity, and TopK's kept values).
    pub raw: Vec<f32>,
}

/// Fixed per-message header: codec(1) + bits(1) + param(4) + n(4) + nscales(4).
pub const WIRE_HEADER_BYTES: usize = 14;

impl WireMsg {
    /// Bytes this message occupies on the wire — the quantity the
    /// paper's Comm column measures (we also charge the tiny header).
    pub fn wire_bytes(&self) -> usize {
        // Charging `codes` and `raw` independently keeps every dense
        // codec's count identical (they populate exactly one of the
        // two) while charging TopK's positions + kept values honestly.
        let codes = self.codes.as_ref().map_or(0, |p| p.payload_bytes());
        WIRE_HEADER_BYTES + self.scales.len() * 4 + codes + self.raw.len() * 4
    }

    /// Serialize for the TCP transport (length-prefix added by caller).
    pub fn to_bytes(&self) -> Vec<u8> {
        let (bits, nwords) = match &self.codes {
            Some(p) => (p.bits, p.words.len()),
            None => (0u8, 0),
        };
        let mut out = Vec::with_capacity(
            22 + self.scales.len() * 4 + nwords * 8 + self.raw.len() * 4,
        );
        out.push(self.codec as u8);
        out.push(bits);
        out.extend_from_slice(&self.param.to_le_bytes());
        out.extend_from_slice(&(self.n as u32).to_le_bytes());
        out.extend_from_slice(&(self.scales.len() as u32).to_le_bytes());
        out.extend_from_slice(&(nwords as u32).to_le_bytes());
        out.extend_from_slice(&(self.raw.len() as u32).to_le_bytes());
        for s in &self.scales {
            out.extend_from_slice(&s.to_le_bytes());
        }
        if let Some(p) = &self.codes {
            for w in &p.words {
                out.extend_from_slice(&w.to_le_bytes());
            }
        }
        for r in &self.raw {
            out.extend_from_slice(&r.to_le_bytes());
        }
        out
    }

    /// Inverse of [`WireMsg::to_bytes`].
    // qadam: decode
    pub fn from_bytes(b: &[u8]) -> anyhow::Result<Self> {
        use crate::util::bytes::Rd;
        use anyhow::anyhow;
        if b.len() < 22 {
            return Err(anyhow!("wire msg too short: {}", b.len()));
        }
        let mut rd = Rd::new(b);
        let header = (rd.u8(), rd.u8(), rd.u32(), rd.u32(), rd.u32(), rd.u32(), rd.u32());
        let (codec_byte, bits, param, n, nscales, nwords, nraw) = match header {
            (Some(c), Some(bt), Some(p), Some(n), Some(s), Some(w), Some(r)) => {
                (c, bt, p, n as usize, s as usize, w as usize, r as usize)
            }
            // unreachable given the length check above, but decode
            // functions never assume — they return Err
            _ => return Err(anyhow!("wire msg too short: {}", b.len())),
        };
        let codec = CodecId::from_u8(codec_byte).ok_or_else(|| anyhow!("bad codec {codec_byte}"))?;
        // Codec-parameter sanity: a frame claiming a level outside the
        // codec's domain would panic deep inside the decode (level
        // constructors assert their range) — reject it here instead,
        // like any other malformed frame off the socket.
        match codec {
            CodecId::LogQuant => {
                if (param & 0xff) > MAX_KG || (param >> 8) > 32 {
                    return Err(anyhow!("logquant param {param} out of range"));
                }
            }
            CodecId::WQuant => {
                if param > MAX_KX {
                    return Err(anyhow!("wquant param {param} out of range"));
                }
            }
            CodecId::Qsgd => {
                if param == 0 || param > 1 << 15 {
                    return Err(anyhow!("qsgd param {param} out of range"));
                }
            }
            CodecId::Blockwise => {
                if param == 0 {
                    return Err(anyhow!("blockwise block size must be positive"));
                }
            }
            CodecId::SparseBlock => {
                let (blk, kb) = (param & 0xffff, param >> 16);
                if blk == 0 || kb == 0 || kb > blk {
                    return Err(anyhow!("sparse-block param {param:#x} out of range"));
                }
            }
            // TopK's param is the kept count, bounded by n in the
            // layout check below.
            CodecId::Identity | CodecId::TernGrad | CodecId::TopK => {}
        }
        let need = 22 + nscales * 4 + nwords * 8 + nraw * 4;
        if b.len() != need {
            return Err(anyhow!("wire msg len {} != expected {}", b.len(), need));
        }
        // Structural consistency: every codec's decode indexes scales
        // and packed words by position, so a frame whose counts don't
        // match its codec's layout would panic there (missing scale,
        // short word array, absurd bit width). The length prefix above
        // only proves the frame is self-consistent — this proves it is
        // decodable. Each check mirrors exactly what `compress_into`
        // emits (the golden fixtures pin both directions).
        let expect = |ok: bool, what: &str| -> anyhow::Result<()> {
            if ok {
                Ok(())
            } else {
                Err(anyhow!("inconsistent {what} for codec {codec:?} (n={n}, bits={bits}, param={param}, nscales={nscales}, nwords={nwords}, nraw={nraw})"))
            }
        };
        let code_words = (n * bits as usize).div_ceil(64);
        // `Packed::n` counts *codes*, which the sparse codecs decouple
        // from the element count: a TopK index payload carries k codes
        // and a SparseBlock payload carries Σ_b min(kb, len_b). Every
        // dense codec keeps code count == element count, so each arm
        // yields the code count the reconstructed payload must claim.
        let packed_n = match codec {
            CodecId::Identity => {
                expect(bits == 0 && nwords == 0 && nscales == 0 && nraw == n, "identity layout")?;
                n
            }
            CodecId::LogQuant => {
                let want_bits = pack::bits_for_symbols(2 * ((param & 0xff) + 1) + 1);
                expect(bits == want_bits && nraw == 0 && nwords == code_words, "logquant layout")?;
                // one global scale, or the PJRT per-chunk layout with
                // the chunk size in the param's high byte
                if nscales != 1 {
                    let chunk_log2 = param >> 8;
                    expect(
                        chunk_log2 > 0 && nscales == n.div_ceil(1usize << chunk_log2),
                        "logquant scale count",
                    )?;
                }
                n
            }
            CodecId::WQuant => {
                let want_bits = pack::bits_for_symbols(2 * (1u32 << param) + 1);
                expect(
                    bits == want_bits && nscales == 0 && nraw == 0 && nwords == code_words,
                    "wquant layout",
                )?;
                n
            }
            CodecId::TernGrad => {
                expect(
                    bits == 2 && nscales == 1 && nraw == 0 && nwords == code_words,
                    "terngrad layout",
                )?;
                n
            }
            CodecId::Blockwise => {
                expect(
                    bits == 1
                        && nscales == n.div_ceil(param as usize)
                        && nraw == 0
                        && nwords == code_words,
                    "blockwise layout",
                )?;
                n
            }
            CodecId::Qsgd => {
                let want_bits = pack::bits_for_symbols(2 * param + 1);
                expect(
                    bits == want_bits && nscales == 1 && nraw == 0 && nwords == code_words,
                    "qsgd layout",
                )?;
                n
            }
            CodecId::TopK => {
                let k = param as usize;
                expect(k <= n && nscales == 0 && nraw == k, "topk layout")?;
                if k == 0 {
                    expect(bits == 0 && nwords == 0, "topk empty layout")?;
                    0
                } else if sparse::TopK::index_mode(n, k) {
                    let ib = pack::bits_for_symbols(n as u32);
                    expect(
                        bits == ib && nwords == (k * ib as usize).div_ceil(64),
                        "topk index layout",
                    )?;
                    k
                } else {
                    expect(bits == 1 && nwords == n.div_ceil(64), "topk bitmap layout")?;
                    n
                }
            }
            CodecId::SparseBlock => {
                let sb = sparse::SparseBlock::from_param(param); // domain vetted above
                let total = sb.code_count(n);
                expect(
                    nscales == n.div_ceil((param & 0xffff) as usize) && nraw == 0,
                    "sparse-block layout",
                )?;
                if total == 0 {
                    expect(bits == 0 && nwords == 0, "sparse-block empty layout")?;
                } else {
                    let cb = sb.code_bits();
                    expect(
                        bits == cb && nwords == (total * cb as usize).div_ceil(64),
                        "sparse-block layout",
                    )?;
                }
                total
            }
        };
        // `need == b.len()` makes these reads infallible, but the
        // bounds-checked readers keep that a local fact, not a
        // load-bearing assumption
        let short = || anyhow!("wire msg len {} != expected {}", b.len(), need);
        let scales = rd.f32s(nscales).ok_or_else(short)?;
        let codes = if nwords > 0 || (bits > 0 && packed_n > 0) {
            Some(pack::Packed { bits, n: packed_n, words: rd.u64s(nwords).ok_or_else(short)? })
        } else {
            None
        };
        let raw = rd.f32s(nraw).ok_or_else(short)?;
        let msg = WireMsg { codec, param, n, scales, codes, raw };
        // Sparse payload *content* can lie even when every count checks
        // out (duplicate indices, bitmap popcount ≠ k, tail-block
        // positions past the ragged length) and the range decodes
        // scatter by position — validate here so an accepted frame is
        // always decodable without a panic.
        match codec {
            CodecId::TopK => {
                if !sparse::topk_content_ok(&msg) {
                    return Err(anyhow!("inconsistent topk payload (n={n}, k={param})"));
                }
            }
            CodecId::SparseBlock => {
                if !sparse::sparse_block_content_ok(&msg) {
                    return Err(anyhow!("inconsistent sparse-block payload (n={n}, param={param:#x})"));
                }
            }
            _ => {}
        }
        Ok(msg)
    }
}

/// A (possibly stochastic) tensor compressor.
///
/// `compress_into` must satisfy the *decode identity*: the `q` it fills
/// equals what `decompress` recovers from the returned message — this is
/// what makes worker-side error feedback (`e' = u - q`) consistent with
/// what the server applies.
pub trait Compressor: Send {
    fn name(&self) -> &'static str;
    fn codec(&self) -> CodecId;
    /// Quantize `u`; fill `q` with the dequantized values; return the
    /// wire message. `rng` is only used by stochastic codecs.
    fn compress_into(&self, u: &[f32], q: &mut [f32], rng: &mut DetRng) -> WireMsg;
    /// Recover the dequantized tensor from a wire message.
    fn decompress(&self, msg: &WireMsg, out: &mut [f32]);
    /// Decode only elements `[start, start + out.len())` of the message.
    /// Every codec is fixed-width with positionally-indexed scales, so
    /// any range decodes independently of the rest — the property the
    /// sharded parameter server uses to decode block-parallel. Must be
    /// bit-identical to the matching slice of [`Compressor::decompress`].
    fn decompress_range(&self, msg: &WireMsg, start: usize, out: &mut [f32]);
    /// Analytic bits per element (paper's Comm formula).
    fn bits_per_element(&self) -> f64;
    /// True for unbiased codecs (E[Q(u)] = u) — error feedback is not
    /// needed (and not used by the corresponding baselines).
    fn is_unbiased(&self) -> bool {
        false
    }
}

/// Full-precision pass-through (the fp32 rows of Tables 2–3).
#[derive(Clone, Copy, Debug, Default)]
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> &'static str {
        "fp32"
    }
    fn codec(&self) -> CodecId {
        CodecId::Identity
    }
    fn compress_into(&self, u: &[f32], q: &mut [f32], _rng: &mut DetRng) -> WireMsg {
        q.copy_from_slice(u);
        WireMsg { codec: CodecId::Identity, param: 0, n: u.len(), scales: vec![], codes: None, raw: u.to_vec() }
    }
    fn decompress(&self, msg: &WireMsg, out: &mut [f32]) {
        out.copy_from_slice(&msg.raw);
    }
    fn decompress_range(&self, msg: &WireMsg, start: usize, out: &mut [f32]) {
        out.copy_from_slice(&msg.raw[start..start + out.len()]);
    }
    fn bits_per_element(&self) -> f64 {
        32.0
    }
    fn is_unbiased(&self) -> bool {
        true
    }
}

/// Decode any wire message without out-of-band codec state — the
/// parameter server's side of the contract. Dispatches on the embedded
/// codec id + parameter.
pub fn decode_msg(msg: &WireMsg, out: &mut [f32]) {
    match msg.codec {
        CodecId::Identity => Identity.decompress(msg, out),
        CodecId::LogQuant => LogQuant::new(msg.param & 0xff).decompress(msg, out),
        CodecId::WQuant => WQuant::new(msg.param).decompress(msg, out),
        CodecId::TernGrad => TernGrad.decompress(msg, out),
        CodecId::Blockwise => Blockwise::new(msg.param as usize).decompress(msg, out),
        CodecId::Qsgd => Qsgd::new(msg.param).decompress(msg, out),
        CodecId::TopK => TopK::decoder().decompress(msg, out),
        CodecId::SparseBlock => SparseBlock::from_param(msg.param).decompress(msg, out),
    }
}

/// [`decode_msg`] restricted to elements `[start, start + out.len())` —
/// the block-parallel decode entry point of the sharded parameter
/// server. Bit-identical to slicing a full [`decode_msg`] result.
// qadam: hotpath
pub fn decode_msg_range(msg: &WireMsg, start: usize, out: &mut [f32]) {
    match msg.codec {
        CodecId::Identity => Identity.decompress_range(msg, start, out),
        CodecId::LogQuant => LogQuant::new(msg.param & 0xff).decompress_range(msg, start, out),
        CodecId::WQuant => WQuant::new(msg.param).decompress_range(msg, start, out),
        CodecId::TernGrad => TernGrad.decompress_range(msg, start, out),
        CodecId::Blockwise => Blockwise::new(msg.param as usize).decompress_range(msg, start, out),
        CodecId::Qsgd => Qsgd::new(msg.param).decompress_range(msg, start, out),
        CodecId::TopK => TopK::decoder().decompress_range(msg, start, out),
        CodecId::SparseBlock => SparseBlock::from_param(msg.param).decompress_range(msg, start, out),
    }
}

/// [`decode_msg_range`] that *accumulates* — `out[i] += decoded[i]` —
/// in the same fused traversal. This is the server's decode→sum fusion:
/// `ParameterServer::apply` sums every worker's delta into one
/// accumulator without a per-delta scratch buffer. The additions are
/// the exact f32 ops (same order) as decoding into scratch and adding,
/// so the summed result is bit-identical to the unfused form.
// qadam: hotpath
pub fn decode_msg_range_add(msg: &WireMsg, start: usize, out: &mut [f32]) {
    match msg.codec {
        CodecId::Identity => {
            for (o, &r) in out.iter_mut().zip(&msg.raw[start..start + out.len()]) {
                *o += r;
            }
        }
        CodecId::LogQuant => LogQuant::new(msg.param & 0xff).decompress_range_add(msg, start, out),
        CodecId::WQuant => WQuant::new(msg.param).decompress_range_add(msg, start, out),
        CodecId::TernGrad => TernGrad.decompress_range_add(msg, start, out),
        CodecId::Blockwise => {
            Blockwise::new(msg.param as usize).decompress_range_add(msg, start, out)
        }
        CodecId::Qsgd => Qsgd::new(msg.param).decompress_range_add(msg, start, out),
        CodecId::TopK => TopK::decoder().decompress_range_add(msg, start, out),
        CodecId::SparseBlock => {
            SparseBlock::from_param(msg.param).decompress_range_add(msg, start, out)
        }
    }
}

/// Decode a per-tensor ("parts") message sequence laid out back to
/// back: part `i` covers elements `[Σ_{j<i} n_j, Σ_{j<=i} n_j)` of the
/// flat vector. The codec-policy layer produces these (one part per
/// [`policy::TensorLayout`] tensor, each with its own codec id and
/// bit-width in its own header); `out.len()` must equal the total.
pub fn decode_parts(parts: &[WireMsg], out: &mut [f32]) {
    let mut off = 0usize;
    for p in parts {
        decode_msg(p, &mut out[off..off + p.n]);
        off += p.n;
    }
    assert_eq!(off, out.len(), "parts cover {off} of {} elements", out.len());
}

/// [`decode_parts`] restricted to elements `[start, start + out.len())`
/// — the block-parallel entry point the sharded parameter server uses
/// on mixed-codec rounds. Bit-identical to slicing a full
/// [`decode_parts`] result (each sub-range decode is, per codec).
// qadam: hotpath
pub fn decode_parts_range(parts: &[WireMsg], start: usize, out: &mut [f32]) {
    let end = start + out.len();
    let mut off = 0usize;
    for p in parts {
        let p_end = off + p.n;
        if p_end > start && off < end {
            let lo = start.max(off);
            let hi = end.min(p_end);
            decode_msg_range(p, lo - off, &mut out[lo - start..hi - start]);
        }
        off = p_end;
    }
    assert!(end <= off, "range {start}..{end} out of {off} part elements");
}

/// [`decode_parts_range`] that accumulates (`out[i] += decoded[i]`) —
/// the mixed-codec side of the server's decode→sum fusion.
// qadam: hotpath
pub fn decode_parts_range_add(parts: &[WireMsg], start: usize, out: &mut [f32]) {
    let end = start + out.len();
    let mut off = 0usize;
    for p in parts {
        let p_end = off + p.n;
        if p_end > start && off < end {
            let lo = start.max(off);
            let hi = end.min(p_end);
            decode_msg_range_add(p, lo - off, &mut out[lo - start..hi - start]);
        }
        off = p_end;
    }
    assert!(end <= off, "range {start}..{end} out of {off} part elements");
}

/// A worker-side compressed update as handed to the transport: one
/// message for the whole vector (the static path — byte-identical to
/// pre-policy builds) or one per layout tensor (codec-policy rounds,
/// each part carrying its own codec header).
#[derive(Clone, Debug)]
pub enum DeltaMsg {
    Single(WireMsg),
    Parts(Vec<WireMsg>),
}

impl DeltaMsg {
    /// Total element count across the payload.
    pub fn n(&self) -> usize {
        match self {
            DeltaMsg::Single(m) => m.n,
            DeltaMsg::Parts(ps) => ps.iter().map(|m| m.n).sum(),
        }
    }

    /// Bytes on the wire (per-part headers included — the per-tensor
    /// codec headers are real traffic and are charged).
    pub fn wire_bytes(&self) -> usize {
        match self {
            DeltaMsg::Single(m) => m.wire_bytes(),
            DeltaMsg::Parts(ps) => ps.iter().map(|m| m.wire_bytes()).sum(),
        }
    }

    /// Decode the full payload (`out.len()` must equal [`Self::n`]).
    pub fn decode(&self, out: &mut [f32]) {
        match self {
            DeltaMsg::Single(m) => decode_msg(m, out),
            DeltaMsg::Parts(ps) => decode_parts(ps, out),
        }
    }
}

/// The gradient-family codec parameterized by `k_g` (`None` = fp32
/// [`Identity`]). The single owner of the "which compressor does a
/// `kg` level mean" decision, shared by the worker uplink
/// (`optim::QAdamEf`) and the parameter server's compressed delta
/// downlink (`ps::server`).
pub fn gradient_codec(kg: Option<u32>) -> Box<dyn Compressor> {
    match kg {
        Some(k) => Box::new(LogQuant::new(k)),
        None => Box::new(Identity),
    }
}

/// Deterministic per-(seed, worker, t) rng used across the system.
pub fn seeded_rng(seed: u64, stream: u64) -> DetRng {
    DetRng::seed_stream(seed, stream)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_roundtrip_and_bytes() {
        let u = vec![1.0f32, -2.5, 0.0, 3.25];
        let mut q = vec![0.0; 4];
        let mut rng = seeded_rng(0, 0);
        let msg = Identity.compress_into(&u, &mut q, &mut rng);
        assert_eq!(q, u);
        assert_eq!(msg.wire_bytes(), WIRE_HEADER_BYTES + 16);
        let mut out = vec![0.0; 4];
        Identity.decompress(&msg, &mut out);
        assert_eq!(out, u);
    }

    #[test]
    fn wire_serialization_roundtrip() {
        // PJRT-style multi-scale LogQuant message: kg=2 in the low
        // byte, log2(chunk)=2 in the high byte, one scale per chunk of
        // 4 elements (ragged tail).
        let msg = WireMsg {
            codec: CodecId::LogQuant,
            param: 2 | (2 << 8),
            n: 5,
            scales: vec![0.5, 1.5],
            codes: Some(pack::pack(&[1, 2, 3, 4, 5], 3)),
            raw: vec![],
        };
        let b = msg.to_bytes();
        let back = WireMsg::from_bytes(&b).unwrap();
        assert_eq!(back.codec, msg.codec);
        assert_eq!(back.param, msg.param);
        assert_eq!(back.n, msg.n);
        assert_eq!(back.scales, msg.scales);
        assert_eq!(back.codes, msg.codes);
    }

    /// Property: for every codec, any [start, end) range decode is
    /// bit-identical to the matching slice of the full decode — the
    /// contract the sharded server's block-parallel apply relies on.
    #[test]
    fn range_decode_matches_full_decode_all_codecs() {
        let n = 300;
        let u: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.37).sin() / (1.0 + i as f32 * 0.01)).collect();
        let comps: Vec<Box<dyn Compressor>> = vec![
            Box::new(Identity),
            Box::new(LogQuant::new(2)),
            Box::new(WQuant::new(4)),
            Box::new(TernGrad),
            Box::new(Blockwise::new(7)), // non-dividing block: ragged scales
            Box::new(Qsgd::new(4)),
            Box::new(StochasticLogQuant::new(3)),
            Box::new(TopK::new(400)),        // index mode at n=300
            Box::new(TopK::new(5000)),       // bitmap mode
            Box::new(SparseBlock::new(7, 2)), // ragged tail block
        ];
        for comp in &comps {
            let mut q = vec![0.0; n];
            let mut rng = seeded_rng(9, 9);
            let msg = comp.compress_into(&u, &mut q, &mut rng);
            let mut full = vec![0.0; n];
            decode_msg(&msg, &mut full);
            assert_eq!(full, q, "{}: decode identity", comp.name());
            for &(start, len) in &[(0usize, n), (1, 5), (7, 100), (n - 1, 1), (64, 64)] {
                let mut part = vec![0.0; len];
                decode_msg_range(&msg, start, &mut part);
                assert_eq!(part, full[start..start + len], "{} start={start}", comp.name());
            }
        }
    }

    /// Property: for every codec, the fused decode→accumulate is
    /// bit-identical to decoding into a scratch buffer and adding — the
    /// equivalence `ParameterServer::apply`'s single-traversal sum
    /// rests on.
    #[test]
    fn range_decode_add_matches_scratch_then_add_all_codecs() {
        let n = 300;
        let u: Vec<f32> =
            (0..n).map(|i| ((i as f32) * 0.37).sin() / (1.0 + i as f32 * 0.01)).collect();
        let comps: Vec<Box<dyn Compressor>> = vec![
            Box::new(Identity),
            Box::new(LogQuant::new(2)),
            Box::new(WQuant::new(4)),
            Box::new(TernGrad),
            Box::new(Blockwise::new(7)),
            Box::new(Qsgd::new(4)),
            Box::new(StochasticLogQuant::new(3)),
            Box::new(TopK::new(400)),
            Box::new(TopK::new(5000)),
            Box::new(SparseBlock::new(7, 2)),
        ];
        for comp in &comps {
            let mut q = vec![0.0; n];
            let mut rng = seeded_rng(9, 9);
            let msg = comp.compress_into(&u, &mut q, &mut rng);
            for &(start, len) in &[(0usize, n), (1, 5), (7, 100), (n - 1, 1), (64, 64)] {
                let acc0: Vec<f32> = (0..len).map(|i| ((start + i) as f32 * 0.11).cos()).collect();
                let mut fused = acc0.clone();
                decode_msg_range_add(&msg, start, &mut fused);
                let mut scratch = vec![0.0; len];
                decode_msg_range(&msg, start, &mut scratch);
                let mut unfused = acc0;
                for (a, &s) in unfused.iter_mut().zip(&scratch) {
                    *a += s;
                }
                assert_eq!(fused, unfused, "{} start={start} len={len}", comp.name());
            }
        }
    }

    #[test]
    fn gradient_codec_dispatch() {
        assert_eq!(gradient_codec(None).codec(), CodecId::Identity);
        let c = gradient_codec(Some(2));
        assert_eq!(c.codec(), CodecId::LogQuant);
        assert_eq!(c.bits_per_element(), 3.0); // 7 symbols at kg=2
    }

    /// Parts decode (full and any range) is bit-identical to decoding
    /// each mixed-codec part into its own slice — the contract the
    /// sharded server relies on for codec-policy rounds.
    #[test]
    fn parts_decode_matches_per_part_decode() {
        let mut rng = seeded_rng(4, 4);
        let lens = [37usize, 64, 5];
        let comps: Vec<Box<dyn Compressor>> =
            vec![Box::new(LogQuant::new(2)), Box::new(LogQuant::new(0)), Box::new(Identity)];
        let mut parts = Vec::new();
        let mut want = Vec::new();
        for (len, comp) in lens.iter().zip(&comps) {
            let u: Vec<f32> =
                (0..*len).map(|i| ((i as f32 + want.len() as f32) * 0.7).sin()).collect();
            let mut q = vec![0.0; *len];
            parts.push(comp.compress_into(&u, &mut q, &mut rng));
            want.extend_from_slice(&q);
        }
        let n: usize = lens.iter().sum();
        let mut full = vec![0.0; n];
        decode_parts(&parts, &mut full);
        assert_eq!(full, want);
        for &(start, len) in &[(0usize, n), (0, 10), (30, 40), (37, 64), (100, 6), (n - 1, 1)] {
            let mut part = vec![0.0; len];
            decode_parts_range(&parts, start, &mut part);
            assert_eq!(part, full[start..start + len], "start={start} len={len}");
        }
        let dm = DeltaMsg::Parts(parts.clone());
        assert_eq!(dm.n(), n);
        assert_eq!(dm.wire_bytes(), parts.iter().map(|m| m.wire_bytes()).sum::<usize>());
        let mut out = vec![0.0; n];
        dm.decode(&mut out);
        assert_eq!(out, full);
        // fused accumulate over mixed-codec parts == scratch-then-add
        for &(start, len) in &[(0usize, n), (30, 40), (37, 64), (100, 6)] {
            let acc0: Vec<f32> = (0..len).map(|i| (start + i) as f32 * 0.5).collect();
            let mut fused = acc0.clone();
            decode_parts_range_add(&parts, start, &mut fused);
            let mut scratch = vec![0.0; len];
            decode_parts_range(&parts, start, &mut scratch);
            let mut unfused = acc0;
            for (a, &s) in unfused.iter_mut().zip(&scratch) {
                *a += s;
            }
            assert_eq!(fused, unfused, "start={start} len={len}");
        }
    }

    /// Frames claiming codec parameters outside the codec's domain, or
    /// whose counts don't match the codec's layout, are clean errors —
    /// not decode-time panics. (Starts from genuinely valid frames and
    /// patches single fields, the shape a bit-flip or hostile peer
    /// produces.)
    #[test]
    fn wire_rejects_out_of_range_or_inconsistent_frames() {
        let u: Vec<f32> = (0..20).map(|i| (i as f32 * 0.7).sin()).collect();
        let encode = |comp: &dyn Compressor| -> Vec<u8> {
            let mut q = vec![0.0; u.len()];
            comp.compress_into(&u, &mut q, &mut seeded_rng(1, 1)).to_bytes()
        };
        // param is bytes 2..6 LE
        let patch_param = |mut b: Vec<u8>, param: u32| -> Vec<u8> {
            b[2..6].copy_from_slice(&param.to_le_bytes());
            b
        };
        let lq = encode(&LogQuant::new(MAX_KG));
        assert!(WireMsg::from_bytes(&lq).is_ok());
        assert!(WireMsg::from_bytes(&patch_param(lq.clone(), MAX_KG + 1)).is_err());
        assert!(
            WireMsg::from_bytes(&patch_param(lq.clone(), MAX_KG | (40 << 8))).is_err(),
            "absurd pjrt chunk log2"
        );
        let wq = encode(&WQuant::new(MAX_KX));
        assert!(WireMsg::from_bytes(&wq).is_ok());
        assert!(WireMsg::from_bytes(&patch_param(wq, MAX_KX + 1)).is_err());
        let qs = encode(&Qsgd::new(4));
        assert!(WireMsg::from_bytes(&qs).is_ok());
        assert!(WireMsg::from_bytes(&patch_param(qs, 0)).is_err());
        let bw = encode(&Blockwise::new(7));
        assert!(WireMsg::from_bytes(&bw).is_ok());
        assert!(WireMsg::from_bytes(&patch_param(bw.clone(), 0)).is_err());
        // sparse codecs: a kept count past n, a kb past the block
        let tk = encode(&TopK::new(2000));
        assert!(WireMsg::from_bytes(&tk).is_ok());
        assert!(WireMsg::from_bytes(&patch_param(tk.clone(), 21)).is_err(), "topk k > n");
        assert!(
            WireMsg::from_bytes(&patch_param(tk, 3)).is_err(),
            "topk k disagreeing with the raw count"
        );
        let sb = encode(&SparseBlock::new(8, 2));
        assert!(WireMsg::from_bytes(&sb).is_ok());
        assert!(
            WireMsg::from_bytes(&patch_param(sb, 8 | (9 << 16))).is_err(),
            "sparse-block kb > block"
        );
        // structural inconsistencies a panic used to hide behind:
        // a bits byte (offset 1) the codec never emits…
        let mut wild_bits = lq.clone();
        wild_bits[1] = 66;
        assert!(WireMsg::from_bytes(&wild_bits).is_err(), "absurd bit width");
        // …a Blockwise block size that disagrees with the scale count…
        assert!(
            WireMsg::from_bytes(&patch_param(bw, 19)).is_err(),
            "scale count must match the claimed block size"
        );
        // …and a TernGrad frame whose scale was amputated (nscales
        // patched to 0 with the frame re-lengthened accordingly).
        let tg = encode(&TernGrad);
        let mut no_scale = tg.clone();
        no_scale[10..14].copy_from_slice(&0u32.to_le_bytes());
        no_scale.drain(22..26); // drop the 4 scale bytes so lengths match
        assert!(
            WireMsg::from_bytes(&no_scale).is_err(),
            "decode would index scales[0] — must be rejected at parse"
        );
    }

    #[test]
    fn wire_rejects_garbage() {
        assert!(WireMsg::from_bytes(&[1, 2, 3]).is_err());
        let msg = WireMsg { codec: CodecId::Identity, param: 0, n: 1, scales: vec![], codes: None, raw: vec![1.0] };
        let mut b = msg.to_bytes();
        b.push(0); // length mismatch
        assert!(WireMsg::from_bytes(&b).is_err());
        b[0] = 99; // bad codec
        assert!(WireMsg::from_bytes(&b[..b.len() - 1]).is_err());
    }
}

#[cfg(test)]
mod fuzz_tests {
    use super::*;

    /// from_bytes must never panic on arbitrary bytes — it feeds straight
    /// off the TCP socket.
    #[test]
    fn wiremsg_from_bytes_never_panics() {
        let mut rng = seeded_rng(1234, 0);
        for trial in 0..2000u32 {
            let len = (rng.gen_u32() % 200) as usize;
            let bytes: Vec<u8> = (0..len).map(|_| (rng.gen_u32() & 0xff) as u8).collect();
            let _ = WireMsg::from_bytes(&bytes); // Err is fine; panic is not
            // also try structurally-plausible prefixes
            if trial % 4 == 0 {
                let mut b = bytes.clone();
                if !b.is_empty() {
                    b[0] %= 8; // valid codec ids
                }
                let _ = WireMsg::from_bytes(&b);
            }
        }
    }

    /// Mutated valid messages either fail cleanly or decode within the
    /// advertised length (no OOB).
    #[test]
    fn wiremsg_mutation_safe() {
        let u: Vec<f32> = (0..64).map(|i| (i as f32).sin()).collect();
        let mut q = vec![0.0; 64];
        let mut rng = seeded_rng(5, 5);
        let msg = LogQuant::new(2).compress_into(&u, &mut q, &mut rng);
        let base = msg.to_bytes();
        let mut mrng = seeded_rng(6, 6);
        for _ in 0..500 {
            let mut b = base.clone();
            let i = (mrng.gen_u32() as usize) % b.len();
            b[i] ^= 1 << (mrng.gen_u32() % 8);
            if let Ok(m) = WireMsg::from_bytes(&b) {
                if m.codec == CodecId::LogQuant
                    && m.codes.as_ref().map(|p| p.n == 64 && p.bits >= 1).unwrap_or(false)
                    && !m.scales.is_empty()
                    && (m.param & 0xff) <= 20
                    && m.codes.as_ref().unwrap().words.len() * 64
                        >= 64 * m.codes.as_ref().unwrap().bits as usize
                {
                    let mut out = vec![0.0; 64];
                    decode_msg(&m, &mut out); // must not panic
                }
            }
        }
    }
}
