//! Bit-packing codec: fixed-width unsigned codes ⇄ `u64` words.
//!
//! This is the byte-exact wire representation behind the paper's
//! "Comm (MB/iteration)" columns: `n` codes of `bits` bits each are
//! packed LSB-first into little-endian `u64` words with no per-element
//! padding. A code may straddle a word boundary.
//!
//! The packer is on the hot path (every worker packs its whole update
//! vector every iteration), so the inner loops are branch-light and the
//! unpacker reads at most two words per code.

/// Packed fixed-width codes.
#[derive(Clone, Debug, PartialEq)]
pub struct Packed {
    /// Bits per code, 1..=32.
    pub bits: u8,
    /// Number of codes.
    pub n: usize,
    /// LSB-first packed payload.
    pub words: Vec<u64>,
}

impl Packed {
    /// Payload size in bytes (ceil(n*bits/8)) — the number that goes on
    /// the wire; whole trailing words are not charged.
    pub fn payload_bytes(&self) -> usize {
        (self.n * self.bits as usize).div_ceil(8)
    }
}

/// Smallest width that can hold `nsymbols` distinct codes.
pub fn bits_for_symbols(nsymbols: u32) -> u8 {
    debug_assert!(nsymbols >= 1);
    (32 - (nsymbols - 1).leading_zeros()).max(1) as u8
}

/// Pack `codes` (each `< 2^bits`) into words.
pub fn pack(codes: &[u32], bits: u8) -> Packed {
    debug_assert!((1..=32).contains(&bits));
    let b = bits as usize;
    let nwords = (codes.len() * b).div_ceil(64);
    let mut words = vec![0u64; nwords];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!(bits == 32 || c < (1u32 << bits));
        let w = bitpos >> 6;
        let off = bitpos & 63;
        words[w] |= (c as u64) << off;
        if off + b > 64 {
            words[w + 1] |= (c as u64) >> (64 - off);
        }
        bitpos += b;
    }
    Packed { bits, n: codes.len(), words }
}

/// Unpack into a caller-provided buffer (len must equal `p.n`).
pub fn unpack_into(p: &Packed, out: &mut [u32]) {
    assert_eq!(out.len(), p.n);
    unpack_range_into(p, 0, out);
}

/// Unpack codes `[start, start + out.len())` without touching the rest
/// of the payload. Because codes are fixed-width, any range decodes
/// independently — this is what lets the sharded parameter server
/// decode one block per thread.
pub fn unpack_range_into(p: &Packed, start: usize, out: &mut [u32]) {
    assert!(start + out.len() <= p.n, "range {}+{} out of {} codes", start, out.len(), p.n);
    let b = p.bits as usize;
    let mask = if p.bits == 32 { u32::MAX } else { (1u32 << p.bits) - 1 };
    let mut bitpos = start * b;
    for o in out.iter_mut() {
        let w = bitpos >> 6;
        let off = bitpos & 63;
        let mut v = (p.words[w] >> off) as u32;
        if off + b > 64 {
            v |= (p.words[w + 1] << (64 - off)) as u32;
        }
        *o = v & mask;
        bitpos += b;
    }
}

/// Convenience allocating unpack.
pub fn unpack(p: &Packed) -> Vec<u32> {
    let mut out = vec![0u32; p.n];
    unpack_into(p, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_for_symbols_table() {
        assert_eq!(bits_for_symbols(1), 1);
        assert_eq!(bits_for_symbols(2), 1);
        assert_eq!(bits_for_symbols(3), 2); // TernGrad {-1,0,1}
        assert_eq!(bits_for_symbols(7), 3); // k_g=2 log levels
        assert_eq!(bits_for_symbols(9), 4);
        assert_eq!(bits_for_symbols(257), 9);
        assert_eq!(bits_for_symbols(1 << 16), 16);
    }

    #[test]
    fn roundtrip_simple() {
        let codes: Vec<u32> = (0..100).map(|i| i % 7).collect();
        let p = pack(&codes, 3);
        assert_eq!(unpack(&p), codes);
        assert_eq!(p.payload_bytes(), (100 * 3usize).div_ceil(8));
    }

    #[test]
    fn straddles_word_boundary() {
        // 13-bit codes guarantee straddles.
        let codes: Vec<u32> = (0..64).map(|i| (i * 641) & 0x1fff).collect();
        let p = pack(&codes, 13);
        assert_eq!(unpack(&p), codes);
    }

    #[test]
    fn empty() {
        let p = pack(&[], 5);
        assert_eq!(p.payload_bytes(), 0);
        assert!(unpack(&p).is_empty());
    }

    /// Property: any [start, end) range unpacks to the matching slice of
    /// the full unpack, across widths (incl. word-straddling ones).
    #[test]
    fn range_unpack_matches_full_unpack() {
        for bits in [1u8, 2, 3, 7, 13, 17, 32] {
            let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
            let n = 301;
            let mut s = 0x1234_5678_9abc_def0u64 ^ bits as u64;
            let codes: Vec<u32> = (0..n)
                .map(|_| {
                    s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                    ((s >> 33) as u32) & mask
                })
                .collect();
            let p = pack(&codes, bits);
            for &(start, len) in &[(0usize, n), (1, 10), (63, 66), (n - 1, 1), (150, 0)] {
                let mut out = vec![0u32; len];
                unpack_range_into(&p, start, &mut out);
                assert_eq!(out, codes[start..start + len], "bits={bits} start={start}");
            }
        }
    }

    #[test]
    #[should_panic]
    fn range_unpack_rejects_out_of_bounds() {
        let p = pack(&[1, 2, 3], 4);
        let mut out = vec![0u32; 2];
        unpack_range_into(&p, 2, &mut out);
    }

    /// Property: roundtrip for every width x many seeds/lengths.
    #[test]
    fn roundtrip_prop() {
        for bits in 1u8..=32 {
            let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
            for seed in 0u64..8 {
                let n = 1 + ((seed as usize * 97 + bits as usize * 13) % 600);
                let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1);
                let codes: Vec<u32> = (0..n)
                    .map(|_| {
                        s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                        ((s >> 33) as u32) & mask
                    })
                    .collect();
                let p = pack(&codes, bits);
                assert_eq!(unpack(&p), codes, "bits={bits} seed={seed}");
                assert!(p.payload_bytes() <= p.words.len() * 8);
            }
        }
    }
}
