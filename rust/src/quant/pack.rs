//! Bit-packing codec: fixed-width unsigned codes ⇄ `u64` words.
//!
//! This is the byte-exact wire representation behind the paper's
//! "Comm (MB/iteration)" columns: `n` codes of `bits` bits each are
//! packed LSB-first into little-endian `u64` words with no per-element
//! padding. A code may straddle a word boundary.
//!
//! The packer is on the hot path (every worker packs its whole update
//! vector every iteration), so both directions are streaming: the
//! packer carries an accumulator word and writes each output word
//! exactly once ([`BitWriter`]), and the unpacker carries a cursor over
//! the current word and hands the caller decoded codes in stack-resident
//! chunks ([`for_each_chunk`]) so decode loops run over plain `&[u32]`
//! slices the compiler can vectorize. Neither direction allocates.
//! `reference::pack_ref` / `reference::unpack_range_ref` keep the old
//! two-loads-per-code forms for the kernel-equivalence suite.

/// Packed fixed-width codes.
#[derive(Clone, Debug, PartialEq)]
pub struct Packed {
    /// Bits per code, 1..=32.
    pub bits: u8,
    /// Number of codes.
    pub n: usize,
    /// LSB-first packed payload.
    pub words: Vec<u64>,
}

impl Packed {
    /// Payload size in bytes (ceil(n*bits/8)) — the number that goes on
    /// the wire; whole trailing words are not charged.
    pub fn payload_bytes(&self) -> usize {
        (self.n * self.bits as usize).div_ceil(8)
    }
}

/// Smallest width that can hold `nsymbols` distinct codes.
pub fn bits_for_symbols(nsymbols: u32) -> u8 {
    debug_assert!(nsymbols >= 1);
    (32 - (nsymbols - 1).leading_zeros()).max(1) as u8
}

/// Streaming fixed-width bit writer over a caller-provided word buffer.
///
/// The fused-compress counterpart of [`for_each_chunk`]: codes are
/// shifted into a 64-bit accumulator and each destination word is
/// stored exactly once when it fills (the old packer read-modified two
/// words per straddling code). The buffer must be zeroed and sized
/// `ceil(n * bits / 64)`; call [`BitWriter::finish`] to flush the
/// partial tail word.
pub struct BitWriter<'a> {
    words: &'a mut [u64],
    b: usize,
    acc: u64,
    fill: usize,
    out: usize,
}

impl<'a> BitWriter<'a> {
    pub fn new(words: &'a mut [u64], bits: u8) -> Self {
        debug_assert!((1..=32).contains(&bits));
        Self { words, b: bits as usize, acc: 0, fill: 0, out: 0 }
    }

    /// Append one code (`< 2^bits`).
    // qadam: hotpath
    #[inline]
    pub fn push(&mut self, c: u32) {
        debug_assert!(self.b == 32 || c < (1u32 << self.b));
        self.acc |= (c as u64) << self.fill;
        self.fill += self.b;
        if self.fill >= 64 {
            self.words[self.out] = self.acc;
            self.out += 1;
            self.fill -= 64;
            // Bits of `c` that did not fit the stored word (b - fill of
            // them were consumed; fill < b <= 32, so the shift is safe).
            self.acc = if self.fill > 0 { (c as u64) >> (self.b - self.fill) } else { 0 };
        }
    }

    /// Flush the partial tail word, if any.
    // qadam: hotpath
    pub fn finish(self) {
        if self.fill > 0 {
            self.words[self.out] = self.acc;
        }
    }
}

/// Pack `codes` (each `< 2^bits`) into words.
pub fn pack(codes: &[u32], bits: u8) -> Packed {
    debug_assert!((1..=32).contains(&bits));
    let nwords = (codes.len() * bits as usize).div_ceil(64);
    let mut words = vec![0u64; nwords];
    let mut w = BitWriter::new(&mut words, bits);
    for &c in codes {
        w.push(c);
    }
    w.finish();
    Packed { bits, n: codes.len(), words }
}

/// Stack-chunk size of [`for_each_chunk`] (codes per callback).
pub const UNPACK_CHUNK: usize = 128;

/// Visit codes `[start, start + len)` as stack-resident chunks: `f` is
/// called with `(offset_within_range, codes)` where `codes` holds at
/// most [`UNPACK_CHUNK`] decoded values. Because codes are fixed-width,
/// any range decodes independently — this is what lets the sharded
/// parameter server decode one block per thread. The cursor reads each
/// payload word once; no heap allocation.
// qadam: hotpath
pub fn for_each_chunk<F: FnMut(usize, &[u32])>(p: &Packed, start: usize, len: usize, mut f: F) {
    assert!(start + len <= p.n, "range {start}+{len} out of {} codes", p.n);
    if len == 0 {
        return;
    }
    let b = p.bits as usize;
    let mask = if p.bits == 32 { u32::MAX } else { (1u32 << p.bits) - 1 };
    let bitpos = start * b;
    let mut w = bitpos >> 6;
    let off = bitpos & 63;
    // `cur` holds the unread (low-aligned) bits of the current word;
    // `avail` counts them, so `cur`'s bits above `avail` are always 0.
    let mut cur = p.words[w] >> off;
    let mut avail = 64 - off;
    let mut buf = [0u32; UNPACK_CHUNK];
    let mut done = 0usize;
    while done < len {
        let k = (len - done).min(UNPACK_CHUNK);
        for slot in buf[..k].iter_mut() {
            if avail >= b {
                *slot = (cur as u32) & mask;
                cur >>= b;
                avail -= b;
            } else {
                // Code straddles into the next word (avail < b <= 32).
                w += 1;
                let next = p.words[w];
                *slot = ((cur | (next << avail)) as u32) & mask;
                cur = next >> (b - avail);
                avail = 64 + avail - b;
            }
        }
        f(done, &buf[..k]);
        done += k;
    }
}

/// Unpack into a caller-provided buffer (len must equal `p.n`).
pub fn unpack_into(p: &Packed, out: &mut [u32]) {
    assert_eq!(out.len(), p.n);
    unpack_range_into(p, 0, out);
}

/// Unpack codes `[start, start + out.len())` without touching the rest
/// of the payload.
// qadam: hotpath
pub fn unpack_range_into(p: &Packed, start: usize, out: &mut [u32]) {
    for_each_chunk(p, start, out.len(), |o, chunk| {
        out[o..o + chunk.len()].copy_from_slice(chunk);
    });
}

/// Convenience allocating unpack.
pub fn unpack(p: &Packed) -> Vec<u32> {
    let mut out = vec![0u32; p.n];
    unpack_into(p, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::reference::{pack_ref, unpack_range_ref};

    #[test]
    fn bits_for_symbols_table() {
        assert_eq!(bits_for_symbols(1), 1);
        assert_eq!(bits_for_symbols(2), 1);
        assert_eq!(bits_for_symbols(3), 2); // TernGrad {-1,0,1}
        assert_eq!(bits_for_symbols(7), 3); // k_g=2 log levels
        assert_eq!(bits_for_symbols(9), 4);
        assert_eq!(bits_for_symbols(257), 9);
        assert_eq!(bits_for_symbols(1 << 16), 16);
    }

    #[test]
    fn roundtrip_simple() {
        let codes: Vec<u32> = (0..100).map(|i| i % 7).collect();
        let p = pack(&codes, 3);
        assert_eq!(unpack(&p), codes);
        assert_eq!(p.payload_bytes(), (100 * 3usize).div_ceil(8));
    }

    #[test]
    fn straddles_word_boundary() {
        // 13-bit codes guarantee straddles.
        let codes: Vec<u32> = (0..64).map(|i| (i * 641) & 0x1fff).collect();
        let p = pack(&codes, 13);
        assert_eq!(unpack(&p), codes);
    }

    #[test]
    fn empty() {
        let p = pack(&[], 5);
        assert_eq!(p.payload_bytes(), 0);
        assert!(unpack(&p).is_empty());
    }

    /// Property: any [start, end) range unpacks to the matching slice of
    /// the full unpack, across widths (incl. word-straddling ones).
    #[test]
    fn range_unpack_matches_full_unpack() {
        for bits in [1u8, 2, 3, 7, 13, 17, 32] {
            let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
            let n = 301;
            let mut s = 0x1234_5678_9abc_def0u64 ^ bits as u64;
            let codes: Vec<u32> = (0..n)
                .map(|_| {
                    s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                    ((s >> 33) as u32) & mask
                })
                .collect();
            let p = pack(&codes, bits);
            for &(start, len) in &[(0usize, n), (1, 10), (63, 66), (n - 1, 1), (150, 0)] {
                let mut out = vec![0u32; len];
                unpack_range_into(&p, start, &mut out);
                assert_eq!(out, codes[start..start + len], "bits={bits} start={start}");
            }
        }
    }

    #[test]
    #[should_panic]
    fn range_unpack_rejects_out_of_bounds() {
        let p = pack(&[1, 2, 3], 4);
        let mut out = vec![0u32; 2];
        unpack_range_into(&p, 2, &mut out);
    }

    /// Property: roundtrip for every width x many seeds/lengths.
    #[test]
    fn roundtrip_prop() {
        for bits in 1u8..=32 {
            let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
            for seed in 0u64..8 {
                let n = 1 + ((seed as usize * 97 + bits as usize * 13) % 600);
                let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1);
                let codes: Vec<u32> = (0..n)
                    .map(|_| {
                        s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                        ((s >> 33) as u32) & mask
                    })
                    .collect();
                let p = pack(&codes, bits);
                assert_eq!(unpack(&p), codes, "bits={bits} seed={seed}");
                assert!(p.payload_bytes() <= p.words.len() * 8);
            }
        }
    }

    /// Property: the streaming packer emits the exact words of the
    /// retained read-modify-write reference, and the chunked cursor
    /// unpack agrees with the reference range unpack, for every width
    /// and ragged lengths around the chunk and word boundaries.
    #[test]
    fn streaming_matches_reference_prop() {
        for bits in 1u8..=32 {
            let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
            for seed in 0u64..4 {
                for n in [0usize, 1, 63, 64, 65, 127, 128, 129, 397] {
                    let mut s = seed
                        .wrapping_mul(0x9e3779b97f4a7c15)
                        .wrapping_add(bits as u64)
                        .wrapping_add(n as u64);
                    let codes: Vec<u32> = (0..n)
                        .map(|_| {
                            s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                            ((s >> 33) as u32) & mask
                        })
                        .collect();
                    let p = pack(&codes, bits);
                    let pr = pack_ref(&codes, bits);
                    assert_eq!(p, pr, "bits={bits} n={n} seed={seed}");
                    if n > 0 {
                        let (start, len) = (n / 3, n - n / 3 - n / 7);
                        let mut a = vec![0u32; len];
                        let mut b = vec![0u32; len];
                        unpack_range_into(&p, start, &mut a);
                        unpack_range_ref(&p, start, &mut b);
                        assert_eq!(a, b, "bits={bits} n={n} seed={seed}");
                    }
                }
            }
        }
    }

    /// The chunk visitor hands back contiguous, correctly-offset chunks
    /// covering exactly the requested range.
    #[test]
    fn for_each_chunk_offsets_cover_the_range() {
        let codes: Vec<u32> = (0..UNPACK_CHUNK as u32 * 3 + 17).map(|i| i % 32).collect();
        let p = pack(&codes, 5);
        let (start, len) = (3usize, codes.len() - 5);
        let mut got = vec![u32::MAX; len];
        let mut calls = 0usize;
        for_each_chunk(&p, start, len, |o, chunk| {
            assert!(chunk.len() <= UNPACK_CHUNK && !chunk.is_empty());
            got[o..o + chunk.len()].copy_from_slice(chunk);
            calls += 1;
        });
        assert_eq!(got, codes[start..start + len]);
        assert_eq!(calls, len.div_ceil(UNPACK_CHUNK));
    }
}
