//! Minimal scoped-thread parallelism (this crate builds offline, so no
//! rayon): a static, deterministic work partitioner used by the sharded
//! parameter server and anything else that can pre-split its work into
//! `Send` tasks over disjoint `&mut` slices.
//!
//! Determinism contract: `par_tasks` only decides *which thread* runs a
//! task, never what the task computes — every task owns its output
//! slice exclusively, so results are bit-identical to running the tasks
//! sequentially in order. This is the property the `Transport`
//! determinism guarantee (DESIGN.md §Round protocol) builds on.

/// Number of worker threads to use by default: the machine's available
/// parallelism, or 1 when it cannot be queried.
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f` over `tasks`, fanned out across at most `threads` scoped
/// threads (round-robin static partition). With `threads <= 1` or a
/// single task, runs inline with no thread spawn at all.
///
/// Tasks must be independent: `f` is shared (`Fn + Sync`) and each task
/// carries its own exclusive data (typically `(offset, &mut [..])`
/// pairs produced by `chunks_mut`).
pub fn par_tasks<T, F>(threads: usize, tasks: Vec<T>, f: F)
where
    T: Send,
    F: Fn(T) + Sync,
{
    let threads = threads.max(1).min(tasks.len());
    if threads <= 1 {
        for t in tasks {
            f(t);
        }
        return;
    }
    let mut buckets: Vec<Vec<T>> = (0..threads).map(|_| Vec::new()).collect();
    for (i, t) in tasks.into_iter().enumerate() {
        buckets[i % threads].push(t);
    }
    let f = &f;
    std::thread::scope(|s| {
        for bucket in buckets {
            s.spawn(move || {
                for t in bucket {
                    f(t);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_task_exactly_once() {
        for threads in [0usize, 1, 2, 5, 64] {
            let n = 37;
            let mut data = vec![0u32; n];
            let tasks: Vec<(usize, &mut u32)> = data.iter_mut().enumerate().collect();
            let count = AtomicUsize::new(0);
            par_tasks(threads, tasks, |(i, slot)| {
                *slot = i as u32 + 1;
                count.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(count.load(Ordering::Relaxed), n, "threads={threads}");
            for (i, &v) in data.iter().enumerate() {
                assert_eq!(v, i as u32 + 1, "threads={threads}");
            }
        }
    }

    #[test]
    fn empty_task_list_is_a_noop() {
        par_tasks::<usize, _>(8, Vec::new(), |_| panic!("no tasks to run"));
    }

    #[test]
    fn chunked_mut_slices_partition_deterministically() {
        // The sharded-server usage pattern: disjoint chunks + offsets.
        let n = 1000;
        let mut seq = vec![0f32; n];
        let mut par = vec![0f32; n];
        for (start, c) in seq.chunks_mut(64).enumerate().map(|(i, c)| (i * 64, c)) {
            for (j, v) in c.iter_mut().enumerate() {
                *v = ((start + j) as f32).sin();
            }
        }
        let tasks: Vec<(usize, &mut [f32])> =
            par.chunks_mut(64).enumerate().map(|(i, c)| (i * 64, c)).collect();
        par_tasks(4, tasks, |(start, c)| {
            for (j, v) in c.iter_mut().enumerate() {
                *v = ((start + j) as f32).sin();
            }
        });
        assert_eq!(seq, par);
    }

    #[test]
    fn available_threads_is_positive() {
        assert!(available_threads() >= 1);
    }
}
