//! Deterministic RNG: xoshiro256++ seeded via SplitMix64.
//!
//! Every stochastic choice in the system (TernGrad rounding, synthetic
//! data, inits) flows through [`DetRng`], keyed by `(seed, stream)` so
//! runs are exactly reproducible and workers/steps get independent
//! streams.

#[derive(Clone, Debug)]
pub struct DetRng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl DetRng {
    /// Independent stream per (seed, stream) pair.
    pub fn seed_stream(seed: u64, stream: u64) -> Self {
        let mut x = seed ^ stream.rotate_left(32) ^ 0x51_7c_c1_b7_27_22_0a_95;
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = splitmix64(&mut x);
        }
        // xoshiro must not start at all-zero (splitmix makes this
        // effectively impossible, but belt and braces):
        if s == [0; 4] {
            s[0] = 1;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    #[inline]
    pub fn gen_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1) with 24 bits of mantissa.
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / 16_777_216.0)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn gen_range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.gen_f32()
    }

    /// Approximately standard normal (Irwin–Hall of 12 uniforms).
    #[inline]
    pub fn gen_normal(&mut self) -> f32 {
        let mut acc = 0.0f32;
        for _ in 0..12 {
            acc += self.gen_f32();
        }
        acc - 6.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_key() {
        let mut a = DetRng::seed_stream(1, 2);
        let mut b = DetRng::seed_stream(1, 2);
        for _ in 0..10 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = DetRng::seed_stream(1, 2);
        let mut b = DetRng::seed_stream(1, 3);
        let mut c = DetRng::seed_stream(2, 2);
        assert_ne!(a.next_u64(), b.next_u64());
        let mut a2 = DetRng::seed_stream(1, 2);
        assert_ne!(a2.next_u64(), c.next_u64());
    }

    #[test]
    fn f32_in_unit_interval_and_spread() {
        let mut r = DetRng::seed_stream(7, 0);
        let mut mean = 0.0f64;
        let n = 10_000;
        for _ in 0..n {
            let x = r.gen_f32();
            assert!((0.0..1.0).contains(&x));
            mean += x as f64;
        }
        mean /= n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_has_unit_variance_roughly() {
        let mut r = DetRng::seed_stream(7, 1);
        let n = 20_000;
        let (mut m, mut v) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.gen_normal() as f64;
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.03, "mean={m}");
        assert!((v - 1.0).abs() < 0.05, "var={v}");
    }
}
