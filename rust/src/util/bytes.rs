//! Bounds-checked little-endian byte readers — the only way wire and
//! checkpoint decoders read untrusted bytes.
//!
//! Every reader returns `Option` instead of panicking: a truncated,
//! hostile or corrupt buffer can only ever surface as `None` (which the
//! decode functions map to their own `Err`), never as an out-of-bounds
//! panic. This is the mechanism behind the INV-PANIC invariant that
//! `qadam lint` enforces over every `from_bytes`/`// qadam: decode`
//! function: no `unwrap()`, no `expect()`, no direct indexing.
//!
//! Two shapes are provided: free functions over an explicit `(buf,
//! &mut offset)` pair (what the checkpoint reader's version-branching
//! layout wants) and the [`Rd`] cursor that owns its offset (what the
//! strictly sequential wire decoders want). Both are zero-copy except
//! for the bulk `f32s`/`u64s` readers, which allocate exactly the
//! validated run.

/// Copy an exactly-`N`-byte slice into an array, without indexing.
fn arr<const N: usize>(s: &[u8]) -> Option<[u8; N]> {
    if s.len() != N {
        return None;
    }
    let mut a = [0u8; N];
    a.copy_from_slice(s);
    Some(a)
}

/// Take `n` bytes at `*off`, advancing it. `None` if the run (or the
/// offset arithmetic itself) overruns `b`.
pub fn take_at<'a>(b: &'a [u8], off: &mut usize, n: usize) -> Option<&'a [u8]> {
    let end = off.checked_add(n)?;
    let s = b.get(*off..end)?;
    *off = end;
    Some(s)
}

pub fn u8_at(b: &[u8], off: &mut usize) -> Option<u8> {
    let v = *b.get(*off)?;
    *off = off.checked_add(1)?;
    Some(v)
}

pub fn u32_at(b: &[u8], off: &mut usize) -> Option<u32> {
    Some(u32::from_le_bytes(arr(take_at(b, off, 4)?)?))
}

pub fn u64_at(b: &[u8], off: &mut usize) -> Option<u64> {
    Some(u64::from_le_bytes(arr(take_at(b, off, 8)?)?))
}

pub fn f32_at(b: &[u8], off: &mut usize) -> Option<f32> {
    Some(f32::from_le_bytes(arr(take_at(b, off, 4)?)?))
}

/// Read a run of `n` little-endian f32s. The length check happens
/// *before* the allocation, so a hostile count cannot trigger an
/// attacker-sized reserve.
pub fn f32s_at(b: &[u8], off: &mut usize, n: usize) -> Option<Vec<f32>> {
    let s = take_at(b, off, n.checked_mul(4)?)?;
    Some(
        s.chunks_exact(4)
            .map(|c| {
                let mut a = [0u8; 4];
                a.copy_from_slice(c);
                f32::from_le_bytes(a)
            })
            .collect(),
    )
}

/// Read a run of `n` little-endian u64s (same allocation discipline as
/// [`f32s_at`]).
pub fn u64s_at(b: &[u8], off: &mut usize, n: usize) -> Option<Vec<u64>> {
    let s = take_at(b, off, n.checked_mul(8)?)?;
    Some(
        s.chunks_exact(8)
            .map(|c| {
                let mut a = [0u8; 8];
                a.copy_from_slice(c);
                u64::from_le_bytes(a)
            })
            .collect(),
    )
}

/// Sequential cursor over an untrusted byte buffer.
pub struct Rd<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Rd<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, off: 0 }
    }

    /// Take the next `n` bytes; `None` past the end.
    pub fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        take_at(self.buf, &mut self.off, n)
    }

    pub fn u8(&mut self) -> Option<u8> {
        u8_at(self.buf, &mut self.off)
    }

    pub fn u32(&mut self) -> Option<u32> {
        u32_at(self.buf, &mut self.off)
    }

    pub fn u64(&mut self) -> Option<u64> {
        u64_at(self.buf, &mut self.off)
    }

    pub fn f32(&mut self) -> Option<f32> {
        f32_at(self.buf, &mut self.off)
    }

    pub fn f32s(&mut self, n: usize) -> Option<Vec<f32>> {
        f32s_at(self.buf, &mut self.off, n)
    }

    pub fn u64s(&mut self, n: usize) -> Option<Vec<u64>> {
        u64s_at(self.buf, &mut self.off, n)
    }

    /// Everything not yet consumed (possibly empty); the cursor moves
    /// to the end.
    pub fn rest(&mut self) -> &'a [u8] {
        let s = self.buf.get(self.off..).unwrap_or(&[]);
        self.off = self.buf.len();
        s
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len().saturating_sub(self.off)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_reads_and_eof() {
        let mut b = Vec::new();
        b.push(7u8);
        b.extend_from_slice(&0xdead_beefu32.to_le_bytes());
        b.extend_from_slice(&42u64.to_le_bytes());
        b.extend_from_slice(&1.5f32.to_le_bytes());
        let mut rd = Rd::new(&b);
        assert_eq!(rd.u8(), Some(7));
        assert_eq!(rd.u32(), Some(0xdead_beef));
        assert_eq!(rd.u64(), Some(42));
        assert_eq!(rd.f32(), Some(1.5));
        assert_eq!(rd.remaining(), 0);
        assert_eq!(rd.u8(), None, "reading past the end is None, not a panic");
        assert_eq!(rd.rest(), &[] as &[u8]);
    }

    #[test]
    fn every_truncation_of_a_run_is_none() {
        let b: Vec<u8> = (0..32).collect();
        for cut in 0..b.len() {
            let mut rd = Rd::new(&b[..cut]);
            // whichever read fails first, none may panic
            let _ = rd.u8();
            let _ = rd.u32();
            let _ = rd.u64();
            let _ = rd.f32s(4);
        }
    }

    #[test]
    fn bulk_reads_reject_overflowing_counts() {
        let b = [0u8; 8];
        let mut off = 0;
        assert!(f32s_at(&b, &mut off, usize::MAX / 2).is_none());
        assert_eq!(off, 0, "a failed read must not move the cursor");
        assert!(u64s_at(&b, &mut off, usize::MAX).is_none());
        let got = f32s_at(&b, &mut off, 2).expect("exact fit");
        assert_eq!(got, vec![0.0, 0.0]);
        assert_eq!(off, 8);
    }

    #[test]
    fn take_and_rest_split_the_buffer() {
        let b = [1u8, 2, 3, 4, 5];
        let mut rd = Rd::new(&b);
        assert_eq!(rd.take(2), Some(&b[..2]));
        assert_eq!(rd.take(9), None);
        assert_eq!(rd.rest(), &b[2..]);
        assert_eq!(rd.remaining(), 0);
    }
}
