//! Minimal JSON reader — just enough for `artifacts/manifest.json`
//! (objects, arrays, strings, numbers, bools, null; no \u escapes
//! beyond pass-through). Zero dependencies by design: the crate builds
//! offline against only `xla` + `anyhow`.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking for '{key}')"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            Value::Num(n) => Ok(*n as usize),
            _ => bail!("not a number"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            Value::Num(n) => Ok(*n as i64),
            _ => bail!("not a number"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn usize_arr(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

pub fn parse(s: &str) -> Result<Value> {
    let b = s.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(b, &mut pos)?;
    skip_ws(b, &mut pos);
    if pos != b.len() {
        bail!("trailing garbage at byte {pos}");
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<()> {
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        bail!("expected '{}' at byte {}", c as char, pos)
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Value> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => {
            *pos += 1;
            let mut m = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Value::Obj(m));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                expect(b, pos, b':')?;
                let v = parse_value(b, pos)?;
                m.insert(key, v);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Value::Obj(m));
                    }
                    _ => bail!("expected ',' or '}}' at byte {pos}"),
                }
            }
        }
        b'[' => {
            *pos += 1;
            let mut a = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Value::Arr(a));
            }
            loop {
                a.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Value::Arr(a));
                    }
                    _ => bail!("expected ',' or ']' at byte {pos}"),
                }
            }
        }
        b'"' => Ok(Value::Str(parse_string(b, pos)?)),
        b't' => {
            if b[*pos..].starts_with(b"true") {
                *pos += 4;
                Ok(Value::Bool(true))
            } else {
                bail!("bad literal at {pos}")
            }
        }
        b'f' => {
            if b[*pos..].starts_with(b"false") {
                *pos += 5;
                Ok(Value::Bool(false))
            } else {
                bail!("bad literal at {pos}")
            }
        }
        b'n' => {
            if b[*pos..].starts_with(b"null") {
                *pos += 4;
                Ok(Value::Null)
            } else {
                bail!("bad literal at {pos}")
            }
        }
        _ => {
            let start = *pos;
            while *pos < b.len()
                && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                *pos += 1;
            }
            let s = std::str::from_utf8(&b[start..*pos])?;
            Ok(Value::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
        }
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    if b.get(*pos) != Some(&b'"') {
        bail!("expected string at byte {pos}");
    }
    *pos += 1;
    let mut out = Vec::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(String::from_utf8(out)?);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'n') => out.push(b'\n'),
                    Some(b't') => out.push(b'\t'),
                    Some(b'r') => out.push(b'\r'),
                    Some(&c @ (b'"' | b'\\' | b'/')) => out.push(c),
                    Some(b'u') => {
                        // pass through \uXXXX as '?' — manifest never uses it
                        *pos += 4;
                        out.push(b'?');
                    }
                    _ => bail!("bad escape at byte {pos}"),
                }
                *pos += 1;
            }
            c => {
                out.push(c);
                *pos += 1;
            }
        }
    }
    bail!("unterminated string")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let s = r#"{
          "models": {"mlp": {"params": [{"name": "w", "shape": [2, 3]}],
                             "total_params": 6, "kind": "classifier"}},
          "optimizer": {"chunk": 65536, "scalars": ["alpha", "beta"]}
        }"#;
        let v = parse(s).unwrap();
        let mlp = v.get("models").unwrap().get("mlp").unwrap();
        assert_eq!(mlp.get("total_params").unwrap().as_usize().unwrap(), 6);
        let p0 = &mlp.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p0.get("name").unwrap().as_str().unwrap(), "w");
        assert_eq!(p0.get("shape").unwrap().usize_arr().unwrap(), vec![2, 3]);
        assert_eq!(
            v.get("optimizer").unwrap().get("chunk").unwrap().as_usize().unwrap(),
            65536
        );
    }

    #[test]
    fn scalars_and_literals() {
        assert_eq!(parse("3.5").unwrap(), Value::Num(3.5));
        assert_eq!(parse("-2e3").unwrap(), Value::Num(-2000.0));
        assert_eq!(parse("-1").unwrap().as_i64().unwrap(), -1);
        assert_eq!(parse("2.75").unwrap().as_f64().unwrap(), 2.75);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(Default::default()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("nul").is_err());
    }
}
