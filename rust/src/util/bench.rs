//! Micro-benchmark harness for `benches/` (criterion is unavailable in
//! the offline build, so `cargo bench` runs these `harness = false`
//! binaries). Reports median / p10 / p90 wall time per iteration and a
//! derived throughput.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
}

impl BenchResult {
    pub fn print(&self, bytes_per_iter: Option<usize>) {
        let thr = bytes_per_iter
            .map(|b| format!("  {:>8.2} MB/s", b as f64 / self.median_ns * 1e3))
            .unwrap_or_default();
        println!(
            "{:<44} {:>10.1} ns/iter  (p10 {:>9.1}, p90 {:>9.1}, n={}){}",
            self.name, self.median_ns, self.p10_ns, self.p90_ns, self.iters, thr
        );
    }
}

/// Time `f` adaptively: warm up, then run enough iterations to fill
/// ~`target_ms` of wall time, collecting per-iteration samples.
pub fn bench<F: FnMut()>(name: &str, target_ms: u64, mut f: F) -> BenchResult {
    // warmup
    let t0 = Instant::now();
    let mut warm_iters = 0usize;
    while t0.elapsed().as_millis() < (target_ms / 4).max(5) as u128 && warm_iters < 1_000_000 {
        f();
        warm_iters += 1;
    }
    let per_iter_est = t0.elapsed().as_nanos() as f64 / warm_iters.max(1) as f64;
    let samples_wanted = ((target_ms as f64 * 1e6) / per_iter_est.max(1.0)).clamp(10.0, 100_000.0) as usize;
    let mut samples = Vec::with_capacity(samples_wanted);
    for _ in 0..samples_wanted {
        let s = Instant::now();
        f();
        samples.push(s.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        median_ns: q(0.5),
        p10_ns: q(0.1),
        p90_ns: q(0.9),
    }
}

/// Convenience: bench and print with optional throughput bytes.
pub fn run(name: &str, bytes_per_iter: Option<usize>, f: impl FnMut()) -> BenchResult {
    let r = bench(name, 300, f);
    r.print(bytes_per_iter);
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut x = 0u64;
        let r = bench("noop-ish", 10, || {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            std::hint::black_box(x);
        });
        assert!(r.median_ns >= 0.0);
        assert!(r.iters >= 10);
        assert!(r.p10_ns <= r.p90_ns);
    }
}
