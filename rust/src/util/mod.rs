//! Self-contained utilities (this crate builds offline against only
//! `xla` + `anyhow`): deterministic RNG, a minimal JSON reader for the
//! artifact manifest, a tiny CLI-flag parser, the micro-bench harness
//! used by `benches/`, and the scoped-thread work partitioner behind
//! the sharded parameter server.

pub mod args;
pub mod bench;
pub mod json;
pub mod par;
pub mod rng;

pub use args::Args;
pub use rng::DetRng;
