//! Self-contained utilities (this crate builds offline against only
//! `xla` + `anyhow`): deterministic RNG, a minimal JSON reader for the
//! artifact manifest, a tiny CLI-flag parser, and the micro-bench
//! harness used by `benches/`.

pub mod args;
pub mod bench;
pub mod json;
pub mod rng;

pub use args::Args;
pub use rng::DetRng;
