//! Self-contained utilities (this crate builds offline against only
//! `xla` + `anyhow`): deterministic RNG, a minimal JSON reader for the
//! artifact manifest, a tiny CLI-flag parser, the micro-bench harness
//! used by `benches/`, the scoped-thread work partitioner behind the
//! sharded parameter server, and the bounds-checked byte readers every
//! wire/checkpoint decoder goes through ([`bytes`]).

pub mod args;
pub mod bench;
pub mod bytes;
pub mod json;
pub mod par;
pub mod rng;

pub use args::Args;
pub use rng::DetRng;
