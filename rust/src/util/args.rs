//! Tiny CLI flag parser: `prog subcommand --key value --flag`.
//! No external dependencies (the crate builds offline).

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::str::FromStr;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    /// keys consumed so far (for unknown-flag detection)
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got '{a}'"))?
                .replace('-', "_");
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    let v = it.next().unwrap();
                    out.kv.insert(key, v);
                }
                _ => out.flags.push(key),
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.seen.borrow_mut().push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt<T: FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        self.seen.borrow_mut().push(name.to_string());
        match self.kv.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow!("bad value for --{name}: '{v}' ({e})")),
        }
    }

    pub fn get<T: FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.opt(name)?.unwrap_or(default))
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.seen.borrow_mut().push(name.to_string());
        self.kv.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Error on flags that no `get`/`opt`/`flag` call ever asked about.
    pub fn reject_unknown(&self) -> Result<()> {
        let seen = self.seen.borrow();
        for k in self.kv.keys().chain(self.flags.iter()) {
            if !seen.iter().any(|s| s == k) {
                bail!("unknown flag --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_kv_and_flags() {
        let a = args("train --model mlp --steps 50 --no-ef");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get_str("model", "x"), "mlp");
        assert_eq!(a.get::<u64>("steps", 0).unwrap(), 50);
        assert!(a.flag("no_ef"));
        assert!(!a.flag("other"));
        a.reject_unknown().unwrap();
    }

    #[test]
    fn defaults_and_errors() {
        let a = args("run --kg abc");
        assert!(a.get::<u32>("kg", 1).is_err());
        assert_eq!(a.get::<u32>("missing", 7).unwrap(), 7);
    }

    #[test]
    fn unknown_flags_rejected() {
        let a = args("run --typo 3");
        assert!(a.reject_unknown().is_err());
        let _ = a.get::<u32>("typo", 0);
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn dashes_normalize() {
        let a = args("x --eval-every 10");
        assert_eq!(a.get::<u64>("eval_every", 0).unwrap(), 10);
    }
}
