//! The shard layer: scale-out across N independent parameter servers.
//!
//! A [`ShardPlan`] partitions the flat parameter vector into N
//! contiguous ranges; a [`ShardedServer`] owns one full
//! [`ParameterServer`] per range. Everything the single server keeps —
//! master weights, the delta-downlink worker-replica `x̂`, the
//! server-side [`crate::quant::ErrorFeedback`] residual, the resync
//! schedule, the downlink [`crate::quant::CodecPolicy`] controller and
//! the [`CommStats`] accounting — becomes **per-shard state**; nothing
//! is shared across shards, which is what lets each shard run as its
//! own process (`qadam serve --shard-id i/N`) on its own host.
//!
//! # Why coordinate-wise error feedback composes across shards
//!
//! The paper's parameter-server protocol (Alg. 2) and its error
//! feedback are coordinate-wise: the residual update
//! `e ← u − Q(u)` and the apply `x ← x − mean δ` never mix
//! coordinates. Restricting the whole state machine to a contiguous
//! range therefore yields *exactly* the per-coordinate trajectory the
//! full-vector machine would produce over that range — the only thing
//! that changes when a vector is split is each codec's *scale* (taken
//! per message, hence per shard), which is a choice the analysis
//! already allows per compression call (Assumption 2 is per-call).
//! Efficient-Adam (Chen et al. 2022) runs the same two-way-compression
//! scheme with per-partition state. Concretely:
//!
//! * `--shards 1` is **byte-identical** to the unsharded engine: the
//!   single shard is the very same [`ParameterServer`] code path, fed
//!   the very same inputs (asserted in `rust/tests/shard_parity.rs`).
//! * An N-shard fixed-seed run is **bit-reproducible** across the
//!   sequential, threaded and TCP transports: every per-shard decision
//!   (codec scale, policy controller, EF residual) is a pure function
//!   of that shard's deterministic input stream.
//!
//! # What is per-shard vs global
//!
//! | state | owner |
//! |---|---|
//! | master weights `x`, broadcast view `Q_x(x)` | per shard (its range) |
//! | delta-downlink replica `x̂`, server EF residual, resync schedule | per shard |
//! | downlink [`crate::quant::CodecPolicy`] controller | per shard (cropped layout) |
//! | [`CommStats`] byte accounting | per shard, summed for the merged row |
//! | worker gradient, Adam moments `m, v`, worker EF residual | global (the worker splits only the *wire message* per shard) |
//! | round counter `t`, epoch | lockstep across shards (one logical round) |
//!
//! Shard boundaries **snap to tensor boundaries** whenever a non-static
//! codec policy is active ([`ShardPlan::snapped`]), so a per-tensor
//! wire part never straddles two shards; without a policy the split is
//! near-uniform ([`ShardPlan::uniform`]). Both ends of the wire compute
//! the plan independently with [`ShardPlan::build`] — the plan itself
//! never crosses the wire.

use super::protocol::{CommStats, ToServer, ToWorker};
use super::server::{AsyncApply, ParameterServer};
use crate::elastic::{Participation, StalenessPolicy};
use crate::quant::{CodecPolicy, PolicySpec, TensorLayout};
use anyhow::{anyhow, bail, Result};

/// A partition of the flat parameter vector into contiguous shard
/// ranges, in ascending offset order and covering it exactly.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    /// `(start, len)` per shard.
    ranges: Vec<(usize, usize)>,
    dim: usize,
}

impl ShardPlan {
    /// One shard covering the whole vector — the unsharded (seed) plan.
    pub fn single(dim: usize) -> Self {
        assert!(dim > 0, "shard plan needs a non-empty vector");
        Self { ranges: vec![(0, dim)], dim }
    }

    /// Balanced contiguous split into **exactly** `shards` non-empty
    /// ranges (widths differ by at most one element; the first
    /// `dim % shards` shards carry the extra) — the plan used when no
    /// per-tensor codec policy is active. Producing exactly the
    /// requested count matters: `serve --shard-id i/N` indexes range
    /// `i` and every worker opens one lane per shard. More shards than
    /// elements clamps to one element per shard.
    pub fn uniform(dim: usize, shards: usize) -> Self {
        assert!(dim > 0, "shard plan needs a non-empty vector");
        let shards = shards.clamp(1, dim);
        let base = dim / shards;
        let rem = dim % shards;
        let mut ranges = Vec::with_capacity(shards);
        let mut start = 0usize;
        for s in 0..shards {
            let len = base + usize::from(s < rem);
            ranges.push((start, len));
            start += len;
        }
        Self { ranges, dim }
    }

    /// Split snapping every shard boundary to a tensor boundary of
    /// `layout`, balancing element counts greedily — required whenever
    /// per-tensor wire parts are in play (a part must live entirely
    /// inside one shard). Errors when there are fewer tensors than
    /// shards.
    pub fn snapped(layout: &TensorLayout, shards: usize) -> Result<Self> {
        let tensors = layout.tensors();
        let n = tensors.len();
        if shards == 0 {
            bail!("shard plan needs at least one shard");
        }
        if shards > n {
            bail!(
                "--shards {shards} exceeds the {n} layout tensors \
                 (per-tensor parts cannot straddle shard boundaries)"
            );
        }
        let dim = layout.dim();
        let mut ranges = Vec::with_capacity(shards);
        let mut ti = 0usize;
        let mut off = 0usize;
        for s in 0..shards {
            let remaining_shards = shards - s;
            // leave at least one tensor for every later shard
            let max_take = (n - ti) - (remaining_shards - 1);
            let target = (dim - off).div_ceil(remaining_shards);
            let start = off;
            let mut len = 0usize;
            let mut took = 0usize;
            while took < max_take {
                len += tensors[ti].len;
                ti += 1;
                took += 1;
                if len >= target {
                    break;
                }
            }
            ranges.push((start, len));
            off += len;
        }
        debug_assert_eq!(off, dim);
        debug_assert_eq!(ti, n);
        Ok(Self { ranges, dim })
    }

    /// The one plan rule both ends of the wire compute independently
    /// (the plan never crosses the wire): snap to `layout` when a
    /// non-static codec policy is active, near-uniform otherwise.
    pub fn build(
        dim: usize,
        shards: usize,
        spec: &PolicySpec,
        layout: &TensorLayout,
    ) -> Result<Self> {
        if shards == 0 {
            bail!("--shards must be at least 1");
        }
        if shards > dim {
            bail!("--shards {shards} exceeds the model dimension {dim}");
        }
        if layout.dim() != dim {
            bail!("layout dim {} != model dim {dim}", layout.dim());
        }
        if spec.is_static() {
            Ok(Self::uniform(dim, shards))
        } else {
            Self::snapped(layout, shards)
        }
    }

    pub fn count(&self) -> usize {
        self.ranges.len()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// `(start, len)` per shard, ascending and tiling `[0, dim)`.
    pub fn ranges(&self) -> &[(usize, usize)] {
        &self.ranges
    }

    /// `(start, len)` of shard `i`.
    pub fn range(&self, i: usize) -> (usize, usize) {
        self.ranges[i]
    }
}

/// N independent [`ParameterServer`]s over the disjoint ranges of a
/// [`ShardPlan`], advancing in lockstep (one logical round drives every
/// shard once). The merged accessors ([`Self::stats`],
/// [`Self::master`], [`Self::apply`]'s [`Participation`]) present the
/// fleet as one logical server to the coordinator; the per-shard
/// accessors ([`Self::shard`], [`Self::shard_stats`]) feed the
/// per-shard metrics rows and the checkpoint-v3 blobs.
pub struct ShardedServer {
    shards: Vec<ParameterServer>,
    plan: ShardPlan,
}

impl ShardedServer {
    /// Split `x0` by `plan`; every shard gets its own block-parallel
    /// [`ParameterServer`] (`block`/`threads` as in
    /// [`ParameterServer::with_shards`]). A single-shard plan builds
    /// exactly the unsharded server, fed exactly the same inputs.
    pub fn new(
        x0: Vec<f32>,
        kx: Option<u32>,
        plan: ShardPlan,
        block: usize,
        threads: usize,
    ) -> Self {
        assert_eq!(x0.len(), plan.dim(), "x0 len != plan dim");
        let shards = plan
            .ranges()
            .iter()
            .map(|&(start, len)| {
                ParameterServer::with_shards(x0[start..start + len].to_vec(), kx, block, threads)
            })
            .collect();
        Self { shards, plan }
    }

    pub fn nshards(&self) -> usize {
        self.shards.len()
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Shard `i`'s server (tests, per-shard metrics, checkpointing).
    pub fn shard(&self, i: usize) -> &ParameterServer {
        &self.shards[i]
    }

    /// Shard `i`'s byte accounting.
    pub fn shard_stats(&self, i: usize) -> &CommStats {
        &self.shards[i].stats
    }

    /// Merged accounting: bytes and resyncs summed across shards;
    /// `rounds` is the lockstep round count (shard 0's — all shards
    /// advance together).
    pub fn stats(&self) -> CommStats {
        let mut s = self.shards[0].stats;
        for sh in &self.shards[1..] {
            s.down_bytes += sh.stats.down_bytes;
            s.up_bytes += sh.stats.up_bytes;
            s.resyncs += sh.stats.resyncs;
        }
        s
    }

    pub fn dim(&self) -> usize {
        self.plan.dim()
    }

    /// Lockstep round counter (shard 0's).
    pub fn step(&self) -> u64 {
        self.shards[0].step()
    }

    /// Concatenated full-precision master weights (allocates; the eval
    /// and checkpoint path, not the round hot path).
    pub fn master(&self) -> Vec<f32> {
        let mut x = Vec::with_capacity(self.dim());
        for sh in &self.shards {
            x.extend_from_slice(sh.master());
        }
        x
    }

    /// Concatenated output weights (`Q_x(x)` when quantizing, else `x`).
    pub fn output_weights(&mut self) -> Vec<f32> {
        let mut x = Vec::with_capacity(self.dim());
        for sh in &mut self.shards {
            x.extend_from_slice(sh.output_weights());
        }
        x
    }

    /// Enable the compressed weight-delta downlink on every shard: each
    /// gets its own replica `x̂`, EF residual and resync schedule, with
    /// the gradient-family codec at level `kg` (fp32 [`crate::quant::Identity`]
    /// when `None`). Must be called before round 1.
    pub fn enable_delta_downlink(&mut self, kg: Option<u32>, resync_every: u64) {
        for sh in &mut self.shards {
            sh.enable_delta_downlink(crate::quant::gradient_codec(kg), resync_every);
        }
    }

    /// Install a per-tensor downlink codec policy: every shard gets its
    /// own controller over the layout **cropped to its range** (shard
    /// boundaries must snap to tensor boundaries —
    /// [`ShardPlan::snapped`]). A static spec installs nothing.
    pub fn set_downlink_policy(
        &mut self,
        spec: &PolicySpec,
        layout: &TensorLayout,
        base_kg: u32,
    ) -> Result<()> {
        if spec.is_static() {
            return Ok(());
        }
        if layout.dim() != self.dim() {
            bail!("policy layout dim {} != model dim {}", layout.dim(), self.dim());
        }
        for (i, &(start, len)) in self.plan.ranges().iter().enumerate() {
            let sub = layout.crop(start, len)?;
            self.shards[i].set_downlink_policy(CodecPolicy::new(spec.clone(), sub, base_kg)?);
        }
        Ok(())
    }

    /// Mean downlink code bits/element across shards, weighted by shard
    /// width (`None` unless every shard runs a non-static policy).
    pub fn downlink_bits(&self) -> Option<f64> {
        let mut num = 0.0;
        for (sh, &(_, len)) in self.shards.iter().zip(self.plan.ranges()) {
            num += sh.downlink_bits()? * len as f64;
        }
        Some(num / self.dim() as f64)
    }

    /// Per-tensor downlink levels concatenated in global tensor order
    /// (`None` unless every shard runs a non-static policy).
    pub fn downlink_chosen_bits(&self) -> Option<Vec<u32>> {
        let mut bits = Vec::new();
        for sh in &self.shards {
            bits.extend(sh.downlink_chosen_bits()?);
        }
        Some(bits)
    }

    /// Is the delta downlink enabled (it is all-shards-or-none)?
    pub fn delta_downlink(&self) -> bool {
        self.shards[0].downlink_state().is_some()
    }

    /// Per-shard `(replica x̂, EF residual)` when the delta downlink is
    /// on, in shard order.
    pub fn downlink_states(&self) -> Option<Vec<(&[f32], &[f32])>> {
        self.shards.iter().map(|sh| sh.downlink_state()).collect()
    }

    /// Restore every shard's downlink state from **full-dim** vectors
    /// (sliced by the plan) — the checkpoint path, which stitches the
    /// per-shard blobs back to full vectors first so a file written
    /// under any shard count restores under any other.
    pub fn restore_downlink_full(&mut self, replica: &[f32], residual: &[f32]) -> Result<()> {
        if replica.len() != self.dim() || residual.len() != self.dim() {
            return Err(anyhow!(
                "downlink state dim {}/{} != model dim {}",
                replica.len(),
                residual.len(),
                self.dim()
            ));
        }
        for (sh, &(start, len)) in self.shards.iter_mut().zip(self.plan.ranges()) {
            sh.restore_downlink(&replica[start..start + len], &residual[start..start + len])?;
        }
        Ok(())
    }

    /// Force a full-weights resync frame on **every** shard (a worker
    /// rejoined: it missed frames on every lane).
    pub fn force_resync_all(&mut self) {
        for sh in &mut self.shards {
            sh.force_resync();
        }
    }

    /// Force a full-weights resync frame on shard `i` only (a
    /// single-shard restore or lane rejoin); the other shards keep
    /// their delta streams.
    pub fn force_resync_shard(&mut self, i: usize) {
        self.shards[i].force_resync();
    }

    /// Restore `(weights, step)` on every shard (slices `x` by the
    /// plan). Like [`ParameterServer::restore`], this schedules a full
    /// resync on each shard until its downlink state is also restored.
    pub fn restore(&mut self, x: &[f32], t: u64) {
        assert_eq!(x.len(), self.dim());
        for (sh, &(start, len)) in self.shards.iter_mut().zip(self.plan.ranges()) {
            sh.restore(&x[start..start + len], t);
        }
    }

    /// Begin the next round on every shard: one broadcast frame per
    /// shard, in shard order. `nworkers` is this round's downlink
    /// membership (each shard charges its frame to that many workers).
    pub fn broadcast(&mut self, nworkers: usize) -> Vec<ToWorker> {
        self.broadcast_at_epoch(nworkers, 0)
    }

    /// [`Self::broadcast`] with an explicit epoch tag.
    pub fn broadcast_at_epoch(&mut self, nworkers: usize, epoch: u64) -> Vec<ToWorker> {
        self.shards
            .iter_mut()
            .map(|sh| {
                let (frame, _view) = sh.broadcast_at_epoch(nworkers, epoch);
                frame
            })
            .collect()
    }

    /// Apply one lockstep round: `replies[s]` are shard `s`'s gathered
    /// replies. The merged [`Participation`] reports the union of the
    /// per-shard reporter sets and the mean of the per-shard mean
    /// losses (with full participation every shard sees the same
    /// reporters and the same per-worker losses, so the merge is
    /// exactly each shard's own view). A failing shard fails the whole
    /// round.
    pub fn apply(&mut self, replies: &[Vec<ToServer>]) -> Result<Participation> {
        if replies.len() != self.shards.len() {
            return Err(anyhow!(
                "reply lanes {} != shards {}",
                replies.len(),
                self.shards.len()
            ));
        }
        let mut parts = Vec::with_capacity(self.shards.len());
        for (sh, r) in self.shards.iter_mut().zip(replies) {
            parts.push(sh.apply(r)?);
        }
        let round = parts[0].round;
        let mean_loss =
            parts.iter().map(|p| p.mean_loss).sum::<f32>() / parts.len() as f32;
        let mut reporters: Vec<u32> =
            parts.iter().flat_map(|p| p.reporters.iter().copied()).collect();
        reporters.sort_unstable();
        reporters.dedup();
        Ok(Participation { round, mean_loss, reporters })
    }

    /// Apply one **asynchronous** lockstep round under bounded staleness:
    /// `replies[s]` are shard `s`'s gathered replies, each admitted or
    /// rejected by `policy` independently per lane
    /// ([`ParameterServer::apply_async`]). Because the admit/reject rule
    /// is a pure function of `(delta round, server round, policy)` and
    /// every shard sits at the same lockstep `t`, the *same logical
    /// delta* gets the same verdict on every lane — but the lanes'
    /// reply sets themselves may differ (over TCP each lane's stream
    /// drains independently), so rejections are reported per
    /// `(lane, index)`.
    ///
    /// The merged `mean_loss` averages only the shards that admitted at
    /// least one reply: an all-rejected lane contributes no loss signal,
    /// and an all-rejected *round* yields 0.0, never NaN (the
    /// zero-reporters guard — a sync drop-all round errors at quorum
    /// before reaching here, but an async quiet tick is routine).
    pub fn apply_async(
        &mut self,
        replies: &[Vec<ToServer>],
        policy: &StalenessPolicy,
    ) -> Result<AsyncRound> {
        if replies.len() != self.shards.len() {
            return Err(anyhow!(
                "reply lanes {} != shards {}",
                replies.len(),
                self.shards.len()
            ));
        }
        let mut lanes: Vec<AsyncApply> = Vec::with_capacity(self.shards.len());
        for (sh, r) in self.shards.iter_mut().zip(replies) {
            lanes.push(sh.apply_async(r, policy)?);
        }
        let round = lanes[0].part.round;
        let reporting: Vec<&AsyncApply> =
            lanes.iter().filter(|l| !l.part.reporters.is_empty()).collect();
        let mean_loss = if reporting.is_empty() {
            0.0
        } else {
            reporting.iter().map(|l| l.part.mean_loss).sum::<f32>() / reporting.len() as f32
        };
        let mut reporters: Vec<u32> =
            lanes.iter().flat_map(|l| l.part.reporters.iter().copied()).collect();
        reporters.sort_unstable();
        reporters.dedup();
        let ages = lanes.iter().map(|l| l.ages.clone()).collect();
        let rejected = lanes
            .iter()
            .enumerate()
            .flat_map(|(lane, l)| l.rejected.iter().map(move |&i| (lane, i)))
            .collect();
        Ok(AsyncRound {
            part: Participation { round, mean_loss, reporters },
            ages,
            rejected,
        })
    }
}

/// Outcome of one [`ShardedServer::apply_async`] round.
///
/// `ages[lane]` is aligned with the input `replies[lane]` (one entry
/// per reply, admitted or rejected); `rejected` lists `(lane, index)`
/// pairs whose full mass the driver must refund into the sending
/// worker's error-feedback residual.
#[derive(Debug, Clone)]
pub struct AsyncRound {
    /// Merged participation: union of per-lane admitted reporters, mean
    /// of the reporting lanes' mean losses (0.0 when none reported).
    pub part: Participation,
    /// Per-lane staleness, aligned with the input reply vectors.
    pub ages: Vec<Vec<u64>>,
    /// `(lane, index into that lane's replies)` of rejected deltas.
    pub rejected: Vec<(usize, usize)>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{seeded_rng, Compressor, LogQuant};

    fn delta_msg(u: &[f32], kg: u32) -> crate::quant::WireMsg {
        let mut q = vec![0.0; u.len()];
        LogQuant::new(kg).compress_into(u, &mut q, &mut seeded_rng(0, 0))
    }

    #[test]
    fn uniform_and_single_plans_tile_the_vector() {
        let p = ShardPlan::uniform(10, 4);
        assert_eq!(p.ranges(), &[(0, 3), (3, 3), (6, 2), (8, 2)]);
        assert_eq!(p.dim(), 10);
        assert_eq!(p.count(), 4);
        assert_eq!(ShardPlan::single(7), ShardPlan::uniform(7, 1));
        // the count is exact even when div_ceil blocks would under-fill
        // (9/4 → blocks of 3 would yield only 3 ranges)
        assert_eq!(ShardPlan::uniform(9, 4).ranges(), &[(0, 3), (3, 2), (5, 2), (7, 2)]);
        // more shards than elements clamps
        assert_eq!(ShardPlan::uniform(3, 100).count(), 3);
    }

    #[test]
    fn snapped_plan_respects_tensor_boundaries_and_balances() {
        let layout = TensorLayout::from_named(&[
            ("a".into(), 10),
            ("b".into(), 30),
            ("c".into(), 10),
            ("d".into(), 10),
        ]);
        let p = ShardPlan::snapped(&layout, 2).unwrap();
        // greedy target 30: shard 0 takes a+b (40), shard 1 the rest
        assert_eq!(p.ranges(), &[(0, 40), (40, 20)]);
        // every boundary is a tensor boundary
        for &(start, len) in p.ranges() {
            assert!(layout.crop(start, len).is_ok());
        }
        // one shard per tensor is the finest legal split
        let p4 = ShardPlan::snapped(&layout, 4).unwrap();
        assert_eq!(p4.count(), 4);
        assert_eq!(p4.ranges()[3], (50, 10));
        // more shards than tensors is a clear error
        assert!(ShardPlan::snapped(&layout, 5).is_err());
    }

    #[test]
    fn build_rule_matches_policy_mode() {
        let layout = TensorLayout::uniform(64, 4);
        let uni = ShardPlan::build(64, 2, &PolicySpec::Static, &layout).unwrap();
        assert_eq!(uni, ShardPlan::uniform(64, 2));
        let snap =
            ShardPlan::build(64, 2, &PolicySpec::Adaptive { lo: 0, hi: 4 }, &layout).unwrap();
        assert_eq!(snap, ShardPlan::snapped(&layout, 2).unwrap());
        assert!(ShardPlan::build(64, 0, &PolicySpec::Static, &layout).is_err());
        assert!(ShardPlan::build(63, 2, &PolicySpec::Static, &layout).is_err());
    }

    /// A 2-shard server applies each lane to its own range; merged
    /// Participation and stats present one logical server.
    #[test]
    fn sharded_apply_is_rangewise_and_merges_participation() {
        let dim = 8;
        let plan = ShardPlan::uniform(dim, 2);
        let mut srv = ShardedServer::new(vec![1.0; dim], None, plan, 4, 1);
        let frames = srv.broadcast(2);
        assert_eq!(frames.len(), 2);
        assert_eq!(srv.step(), 1);
        // worker w ships 0.5 on shard 0 and 1.0 on shard 1
        let lane = |d: f32, w: u32| ToServer::Delta {
            t: 1,
            worker: w,
            loss: 2.0 + w as f32,
            msg: delta_msg(&[d; 4], 2),
        };
        let part = srv
            .apply(&[vec![lane(0.5, 0), lane(0.5, 1)], vec![lane(1.0, 0), lane(1.0, 1)]])
            .unwrap();
        assert_eq!(part.round, 1);
        assert_eq!(part.reporters, vec![0, 1]);
        assert!((part.mean_loss - 2.5).abs() < 1e-6);
        let x = srv.master();
        for (i, v) in x.iter().enumerate() {
            let want = if i < 4 { 0.5 } else { 0.0 };
            assert!((v - want).abs() < 1e-6, "x[{i}] = {v}");
        }
        let s = srv.stats();
        assert_eq!(s.rounds, 1);
        assert_eq!(
            s.up_bytes,
            srv.shard_stats(0).up_bytes + srv.shard_stats(1).up_bytes
        );
        // a missing lane fails the round
        assert_eq!(srv.broadcast(2).len(), 2);
        assert!(srv.apply(&[vec![lane(0.5, 0)]]).is_err());
    }

    /// Per-shard delta downlink: each shard keeps its own replica and
    /// resync schedule; a single-shard forced resync leaves the other
    /// shard's delta stream untouched.
    #[test]
    fn per_shard_downlink_and_single_shard_resync() {
        let dim = 8;
        let plan = ShardPlan::uniform(dim, 2);
        let mut srv = ShardedServer::new(vec![0.5; dim], None, plan, 4, 1);
        srv.enable_delta_downlink(Some(2), 0); // resync only round 1 / forced
        assert!(srv.delta_downlink());
        let lane = |t: u64, w: u32| ToServer::Delta {
            t,
            worker: w,
            loss: 0.0,
            msg: delta_msg(&[0.25; 4], 2),
        };
        let frames = srv.broadcast(1);
        assert!(frames.iter().all(|f| matches!(f, ToWorker::Weights { .. })));
        srv.apply(&[vec![lane(1, 0)], vec![lane(1, 0)]]).unwrap();
        let frames = srv.broadcast(1);
        assert!(frames.iter().all(|f| matches!(f, ToWorker::WeightsDelta { .. })));
        srv.apply(&[vec![lane(2, 0)], vec![lane(2, 0)]]).unwrap();
        // shard 1 resyncs alone
        srv.force_resync_shard(1);
        let frames = srv.broadcast(1);
        assert!(matches!(frames[0], ToWorker::WeightsDelta { .. }));
        assert!(matches!(frames[1], ToWorker::Weights { .. }));
        assert_eq!(srv.shard_stats(0).resyncs, 1);
        assert_eq!(srv.shard_stats(1).resyncs, 2);
        assert_eq!(srv.stats().resyncs, 3);
        let states = srv.downlink_states().unwrap();
        assert_eq!(states.len(), 2);
        assert!(states[1].1.iter().all(|&e| e == 0.0), "resync clears shard 1's residual");
    }

    #[test]
    fn restore_downlink_full_slices_by_plan() {
        let dim = 6;
        let plan = ShardPlan::uniform(dim, 3);
        let mut srv = ShardedServer::new(vec![0.0; dim], None, plan, 4, 1);
        srv.enable_delta_downlink(Some(2), 0);
        let replica: Vec<f32> = (0..dim).map(|i| i as f32).collect();
        let residual = vec![0.125f32; dim];
        srv.restore_downlink_full(&replica, &residual).unwrap();
        let states = srv.downlink_states().unwrap();
        assert_eq!(states[1].0, &[2.0, 3.0]);
        assert_eq!(states[2].0, &[4.0, 5.0]);
        assert!(states.iter().all(|(_, e)| e == &[0.125, 0.125]));
        assert!(srv.restore_downlink_full(&replica[..4], &residual).is_err());
    }

    /// Async sharded round: lanes may hold different reply sets; the
    /// admission verdict for a given (worker, round) is identical on
    /// every lane; rejects come back as (lane, index) and a fully quiet
    /// round reports loss 0.0, not NaN.
    #[test]
    fn sharded_async_apply_merges_lanes_and_guards_empty_rounds() {
        let dim = 8;
        let plan = ShardPlan::uniform(dim, 2);
        let mut srv = ShardedServer::new(vec![1.0; dim], None, plan, 4, 1);
        srv.broadcast(2);
        srv.broadcast(2); // t = 2
        let lane = |t: u64, w: u32, d: f32| ToServer::Delta {
            t,
            worker: w,
            loss: 4.0,
            msg: delta_msg(&[d; 4], 2),
        };
        // lane 0: worker 0 fresh + worker 1 too stale; lane 1: only
        // worker 1's stale delta arrived this tick.
        let rep = srv
            .apply_async(
                &[vec![lane(2, 0, 0.5), lane(0, 1, 8.0)], vec![lane(0, 1, 8.0)]],
                &StalenessPolicy::new(1, false),
            )
            .unwrap();
        assert_eq!(rep.part.round, 2);
        assert_eq!(rep.part.reporters, vec![0]);
        assert_eq!(rep.part.mean_loss, 4.0, "only the reporting lane contributes loss");
        assert_eq!(rep.ages, vec![vec![0, 2], vec![2]]);
        assert_eq!(rep.rejected, vec![(0, 1), (1, 0)]);
        let x = srv.master();
        for (i, v) in x.iter().enumerate() {
            let want = if i < 4 { 0.5 } else { 1.0 };
            assert!((v - want).abs() < 1e-6, "x[{i}] = {v}");
        }
        // a fully quiet tick: no lane admitted anything, loss stays finite
        let rep = srv
            .apply_async(&[vec![], vec![]], &StalenessPolicy::new(1, false))
            .unwrap();
        assert!(rep.part.reporters.is_empty());
        assert_eq!(rep.part.mean_loss, 0.0);
        assert!(rep.part.mean_loss.is_finite());
        assert_eq!(srv.master(), x, "quiet round must not move the weights");
    }
}
