//! PS ⇄ worker message types, wire framing and byte accounting.

use crate::quant::WireMsg;
use anyhow::{anyhow, Result};

/// Server → worker.
#[derive(Clone, Debug)]
pub enum ToWorker {
    /// Broadcast of the (possibly Q_x-quantized) weights for step `t` —
    /// the full frame, also the delta-downlink's resync frame. Workers
    /// **overwrite** their replica with the decode.
    Weights { t: u64, epoch: u64, msg: WireMsg },
    /// Compressed weight-delta broadcast for step `t` (delta-downlink
    /// mode): `msg = Q_g(x_t − x̂_{t−1} + e_server)`. Workers **add**
    /// the decode to their replica.
    WeightsDelta { t: u64, epoch: u64, msg: WireMsg },
    Shutdown,
}

/// Worker → server.
#[derive(Clone, Debug)]
pub enum ToServer {
    Delta { t: u64, worker: u32, loss: f32, msg: WireMsg },
}

impl ToWorker {
    pub fn wire_bytes(&self) -> usize {
        match self {
            // t(8) + epoch(8) + payload
            ToWorker::Weights { msg, .. } | ToWorker::WeightsDelta { msg, .. } => {
                16 + msg.wire_bytes()
            }
            ToWorker::Shutdown => 1,
        }
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        match self {
            ToWorker::Weights { t, epoch, msg } => frame_bytes(1, *t, *epoch, msg),
            ToWorker::WeightsDelta { t, epoch, msg } => frame_bytes(2, *t, *epoch, msg),
            ToWorker::Shutdown => vec![0u8],
        }
    }

    pub fn from_bytes(b: &[u8]) -> Result<Self> {
        match b.first() {
            Some(0) => Ok(ToWorker::Shutdown),
            Some(&(tag @ (1 | 2))) => {
                if b.len() < 17 {
                    return Err(anyhow!("short weights frame"));
                }
                let t = u64::from_le_bytes(b[1..9].try_into().unwrap());
                let epoch = u64::from_le_bytes(b[9..17].try_into().unwrap());
                let msg = WireMsg::from_bytes(&b[17..])?;
                Ok(if tag == 1 {
                    ToWorker::Weights { t, epoch, msg }
                } else {
                    ToWorker::WeightsDelta { t, epoch, msg }
                })
            }
            _ => Err(anyhow!("bad ToWorker tag")),
        }
    }
}

/// `tag | t | epoch | WireMsg` — shared by both weights-frame kinds.
fn frame_bytes(tag: u8, t: u64, epoch: u64, msg: &WireMsg) -> Vec<u8> {
    let body = msg.to_bytes();
    let mut out = Vec::with_capacity(17 + body.len());
    out.push(tag);
    out.extend_from_slice(&t.to_le_bytes());
    out.extend_from_slice(&epoch.to_le_bytes());
    out.extend_from_slice(&body);
    out
}

impl ToServer {
    pub fn wire_bytes(&self) -> usize {
        match self {
            // t(8) + worker(4) + loss(4) + payload
            ToServer::Delta { msg, .. } => 16 + msg.wire_bytes(),
        }
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        match self {
            ToServer::Delta { t, worker, loss, msg } => {
                let body = msg.to_bytes();
                let mut out = Vec::with_capacity(16 + body.len());
                out.extend_from_slice(&t.to_le_bytes());
                out.extend_from_slice(&worker.to_le_bytes());
                out.extend_from_slice(&loss.to_le_bytes());
                out.extend_from_slice(&body);
                out
            }
        }
    }

    pub fn from_bytes(b: &[u8]) -> Result<Self> {
        if b.len() < 16 {
            return Err(anyhow!("short Delta frame"));
        }
        let t = u64::from_le_bytes(b[0..8].try_into().unwrap());
        let worker = u32::from_le_bytes(b[8..12].try_into().unwrap());
        let loss = f32::from_le_bytes(b[12..16].try_into().unwrap());
        let msg = WireMsg::from_bytes(&b[16..])?;
        Ok(ToServer::Delta { t, worker, loss, msg })
    }
}

/// Cumulative traffic accounting, split by direction.
#[derive(Clone, Copy, Debug, Default)]
pub struct CommStats {
    /// Server → workers (weight broadcasts), summed over the workers
    /// actually in each round's membership (crashed/evicted workers are
    /// not shipped — or charged — bytes).
    pub down_bytes: u64,
    /// Workers → server (deltas), all received replies summed.
    pub up_bytes: u64,
    pub rounds: u64,
    /// Full-weights resync frames broadcast in delta-downlink mode
    /// (round 1, the `resync_every` cadence, and forced rejoins). Stays
    /// 0 in full mode, where every frame is full by definition.
    pub resyncs: u64,
}

impl CommStats {
    pub fn up_mb_per_round_per_worker(&self, workers: usize) -> f64 {
        if self.rounds == 0 {
            return 0.0;
        }
        self.up_bytes as f64 / self.rounds as f64 / workers as f64 / 1e6
    }

    pub fn down_mb_per_round_per_worker(&self, workers: usize) -> f64 {
        if self.rounds == 0 {
            return 0.0;
        }
        self.down_bytes as f64 / self.rounds as f64 / workers as f64 / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{seeded_rng, Compressor, LogQuant};

    fn sample_msg() -> WireMsg {
        let u: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) / 7.0).collect();
        let mut q = vec![0.0; 100];
        LogQuant::new(2).compress_into(&u, &mut q, &mut seeded_rng(0, 0))
    }

    #[test]
    fn toworker_roundtrip() {
        let m = ToWorker::Weights { t: 42, epoch: 3, msg: sample_msg() };
        let b = m.to_bytes();
        match ToWorker::from_bytes(&b).unwrap() {
            ToWorker::Weights { t, epoch, msg } => {
                assert_eq!((t, epoch), (42, 3));
                assert_eq!(msg.n, 100);
            }
            _ => panic!(),
        }
        assert!(matches!(ToWorker::from_bytes(&[0]).unwrap(), ToWorker::Shutdown));
        assert!(ToWorker::from_bytes(&[9, 9]).is_err());
    }

    #[test]
    fn weights_delta_roundtrip_and_accounting() {
        let m = ToWorker::WeightsDelta { t: 9, epoch: 1, msg: sample_msg() };
        // same framing cost as a full frame of the same payload
        let full = ToWorker::Weights { t: 9, epoch: 1, msg: sample_msg() };
        assert_eq!(m.wire_bytes(), full.wire_bytes());
        let b = m.to_bytes();
        assert_eq!(b[0], 2, "delta frames carry tag 2");
        match ToWorker::from_bytes(&b).unwrap() {
            ToWorker::WeightsDelta { t, epoch, msg } => {
                assert_eq!((t, epoch), (9, 1));
                assert_eq!(msg.n, 100);
            }
            other => panic!("decoded {other:?}"),
        }
        // truncated delta frames fail cleanly
        assert!(ToWorker::from_bytes(&b[..10]).is_err());
    }

    #[test]
    fn toserver_roundtrip() {
        let m = ToServer::Delta { t: 7, worker: 5, loss: 1.25, msg: sample_msg() };
        let b = m.to_bytes();
        let ToServer::Delta { t, worker, loss, msg } = ToServer::from_bytes(&b).unwrap();
        assert_eq!((t, worker, loss), (7, 5, 1.25));
        assert_eq!(msg.n, 100);
    }

    #[test]
    fn comm_stats_rates() {
        let s = CommStats { down_bytes: 16_000_000, up_bytes: 8_000_000, rounds: 10, resyncs: 0 };
        assert!((s.up_mb_per_round_per_worker(8) - 0.1).abs() < 1e-9);
        assert!((s.down_mb_per_round_per_worker(8) - 0.2).abs() < 1e-9);
    }
}
