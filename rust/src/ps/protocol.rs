//! PS ⇄ worker message types, wire framing and byte accounting.
//!
//! **Wire version.** [`WIRE_VERSION`] names the frame layout; the
//! golden-fixture suite (`rust/tests/wire_golden.rs`) pins every frame
//! byte-for-byte against it, so any layout change fails loudly there
//! until the version is bumped and the fixtures regenerated. Version 2
//! (the codec-policy release) added a tag byte to `ToServer` frames and
//! the parts frame kinds (`ToServer::DeltaParts`,
//! [`ToWorker::WeightsDeltaParts`]) that carry one `WireMsg` — and
//! hence one codec header — per layout tensor.

use crate::quant::{
    decode_msg_range, decode_msg_range_add, decode_parts_range, decode_parts_range_add, WireMsg,
};
use crate::util::bytes::Rd;
use anyhow::{anyhow, Result};

/// Frame-layout version, asserted by the golden-fixture suite. Bump it
/// in lockstep with any byte-layout change to the messages below (or to
/// `WireMsg::to_bytes`), and regenerate the fixtures.
pub const WIRE_VERSION: u32 = 2;

/// Frame-tag registry: the first byte of every frame on the wire, one
/// constant per frame kind (the two directions are separate tag
/// spaces). INV-WIRE (`qadam lint`) requires every constant here to
/// appear in both `rust/tests/wire_golden.rs` and the `qadam info`
/// capability JSON, so a new frame kind cannot ship without a
/// byte-pinned fixture and operator visibility.
pub mod tag {
    /// [`super::ToWorker::Shutdown`].
    pub const TO_WORKER_SHUTDOWN: u8 = 0;
    /// [`super::ToWorker::Weights`] — full broadcast / resync frame.
    pub const TO_WORKER_WEIGHTS: u8 = 1;
    /// [`super::ToWorker::WeightsDelta`] — compressed delta broadcast.
    pub const TO_WORKER_WEIGHTS_DELTA: u8 = 2;
    /// [`super::ToWorker::WeightsDeltaParts`] — per-tensor broadcast.
    pub const TO_WORKER_WEIGHTS_DELTA_PARTS: u8 = 3;
    /// [`super::ToServer::Delta`] — single-message worker reply.
    pub const TO_SERVER_DELTA: u8 = 0;
    /// [`super::ToServer::DeltaParts`] — per-tensor worker reply.
    pub const TO_SERVER_DELTA_PARTS: u8 = 1;
    /// `CodecId::TopK`'s wire id — sparse payloads ride the existing
    /// delta/parts frame kinds (no new frame layout, no version bump),
    /// but a new codec id is still a wire-surface change, so it is
    /// registered and fixture-pinned like a frame tag.
    pub const CODEC_TOPK: u8 = 6;
    /// `CodecId::SparseBlock`'s wire id — see [`CODEC_TOPK`].
    pub const CODEC_SPARSE_BLOCK: u8 = 7;
}

/// Accounting charge for a parts frame's own structure: its tag byte +
/// the `nparts:u32` list header. (The v1 frame kinds keep the legacy
/// convention — tag uncharged — so static-path accounting stays
/// bit-identical to pre-policy builds; the new kinds charge their full
/// in-frame layout.)
const PARTS_OVERHEAD: usize = 1 + 4;
/// Accounting charge per part (its `len:u32` prefix).
const PART_OVERHEAD: usize = 4;

/// Server → worker.
#[derive(Clone, Debug)]
pub enum ToWorker {
    /// Broadcast of the (possibly Q_x-quantized) weights for step `t` —
    /// the full frame, also the delta-downlink's resync frame. Workers
    /// **overwrite** their replica with the decode.
    Weights { t: u64, epoch: u64, msg: WireMsg },
    /// Compressed weight-delta broadcast for step `t` (delta-downlink
    /// mode): `msg = Q_g(x_t − x̂_{t−1} + e_server)`. Workers **add**
    /// the decode to their replica.
    WeightsDelta { t: u64, epoch: u64, msg: WireMsg },
    /// [`Self::WeightsDelta`] under a non-static codec policy: one part
    /// per layout tensor, laid out back to back, each carrying its own
    /// codec id and bit-width. Workers **add** the decode.
    WeightsDeltaParts { t: u64, epoch: u64, parts: Vec<WireMsg> },
    Shutdown,
}

/// Worker → server.
#[derive(Clone, Debug)]
pub enum ToServer {
    /// One compressed update covering the whole vector (the static
    /// codec path).
    Delta { t: u64, worker: u32, loss: f32, msg: WireMsg },
    /// Per-tensor update of a codec-policy round: part `i` covers the
    /// `i`-th layout tensor, with its own codec header.
    DeltaParts { t: u64, worker: u32, loss: f32, parts: Vec<WireMsg> },
}

impl ToWorker {
    pub fn wire_bytes(&self) -> usize {
        match self {
            // t(8) + epoch(8) + payload
            ToWorker::Weights { msg, .. } | ToWorker::WeightsDelta { msg, .. } => {
                16 + msg.wire_bytes()
            }
            // per-part codec headers AND the parts framing (nparts +
            // per-part length prefixes) are real in-frame traffic —
            // both are charged, so the parts path never under-reports
            // against the single-message path
            ToWorker::WeightsDeltaParts { parts, .. } => {
                16 + PARTS_OVERHEAD + parts.iter().map(|m| PART_OVERHEAD + m.wire_bytes()).sum::<usize>()
            }
            ToWorker::Shutdown => 1,
        }
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        match self {
            ToWorker::Weights { t, epoch, msg } => {
                frame_bytes(tag::TO_WORKER_WEIGHTS, *t, *epoch, msg)
            }
            ToWorker::WeightsDelta { t, epoch, msg } => {
                frame_bytes(tag::TO_WORKER_WEIGHTS_DELTA, *t, *epoch, msg)
            }
            ToWorker::WeightsDeltaParts { t, epoch, parts } => {
                let mut out = Vec::with_capacity(21);
                out.push(tag::TO_WORKER_WEIGHTS_DELTA_PARTS);
                out.extend_from_slice(&t.to_le_bytes());
                out.extend_from_slice(&epoch.to_le_bytes());
                parts_to_bytes(&mut out, parts);
                out
            }
            ToWorker::Shutdown => vec![tag::TO_WORKER_SHUTDOWN],
        }
    }

    // qadam: decode
    pub fn from_bytes(b: &[u8]) -> Result<Self> {
        let mut rd = Rd::new(b);
        match rd.u8() {
            Some(tag::TO_WORKER_SHUTDOWN) => Ok(ToWorker::Shutdown),
            Some(
                kind @ (tag::TO_WORKER_WEIGHTS
                | tag::TO_WORKER_WEIGHTS_DELTA
                | tag::TO_WORKER_WEIGHTS_DELTA_PARTS),
            ) => {
                let (step, epoch) = match rd.u64().zip(rd.u64()) {
                    Some(hdr) => hdr,
                    None => return Err(anyhow!("short weights frame")),
                };
                let body = rd.rest();
                Ok(match kind {
                    tag::TO_WORKER_WEIGHTS => {
                        ToWorker::Weights { t: step, epoch, msg: WireMsg::from_bytes(body)? }
                    }
                    tag::TO_WORKER_WEIGHTS_DELTA => {
                        ToWorker::WeightsDelta { t: step, epoch, msg: WireMsg::from_bytes(body)? }
                    }
                    _ => ToWorker::WeightsDeltaParts {
                        t: step,
                        epoch,
                        parts: parts_from_bytes(body)?,
                    },
                })
            }
            _ => Err(anyhow!("bad ToWorker tag")),
        }
    }
}

/// `tag | t | epoch | WireMsg` — shared by both single-message
/// weights-frame kinds.
fn frame_bytes(tag: u8, t: u64, epoch: u64, msg: &WireMsg) -> Vec<u8> {
    let body = msg.to_bytes();
    let mut out = Vec::with_capacity(17 + body.len());
    out.push(tag);
    out.extend_from_slice(&t.to_le_bytes());
    out.extend_from_slice(&epoch.to_le_bytes());
    out.extend_from_slice(&body);
    out
}

/// `nparts:u32 | (len:u32 | WireMsg)*` — the parts payload shared by
/// the uplink and downlink parts frames.
fn parts_to_bytes(out: &mut Vec<u8>, parts: &[WireMsg]) {
    out.extend_from_slice(&(parts.len() as u32).to_le_bytes());
    for p in parts {
        let body = p.to_bytes();
        out.extend_from_slice(&(body.len() as u32).to_le_bytes());
        out.extend_from_slice(&body);
    }
}

/// Inverse of [`parts_to_bytes`]; consumes `b` exactly (trailing bytes
/// are a framing error) and never trusts a length prefix past the
/// buffer.
// qadam: decode
fn parts_from_bytes(b: &[u8]) -> Result<Vec<WireMsg>> {
    let mut rd = Rd::new(b);
    let nparts = match rd.u32() {
        Some(n) => n as usize,
        None => return Err(anyhow!("short parts frame")),
    };
    if nparts == 0 {
        return Err(anyhow!("parts frame with zero parts"));
    }
    let mut parts = Vec::new();
    for i in 0..nparts {
        let len = match rd.u32() {
            Some(l) => l as usize,
            None => return Err(anyhow!("parts frame truncated at part {i}")),
        };
        let body = match rd.take(len) {
            Some(s) => s,
            None => return Err(anyhow!("part {i} length {len} overruns the frame")),
        };
        parts.push(WireMsg::from_bytes(body)?);
    }
    if rd.remaining() != 0 {
        return Err(anyhow!("parts frame has {} trailing bytes", rd.remaining()));
    }
    Ok(parts)
}

impl ToServer {
    /// The round this reply belongs to.
    pub fn round(&self) -> u64 {
        match self {
            ToServer::Delta { t, .. } | ToServer::DeltaParts { t, .. } => *t,
        }
    }

    /// The worker id this reply claims.
    pub fn worker(&self) -> u32 {
        match self {
            ToServer::Delta { worker, .. } | ToServer::DeltaParts { worker, .. } => *worker,
        }
    }

    pub fn loss(&self) -> f32 {
        match self {
            ToServer::Delta { loss, .. } | ToServer::DeltaParts { loss, .. } => *loss,
        }
    }

    /// Total element count of the compressed payload (what must match
    /// the model dimension).
    pub fn payload_n(&self) -> usize {
        match self {
            ToServer::Delta { msg, .. } => msg.n,
            ToServer::DeltaParts { parts, .. } => parts.iter().map(|m| m.n).sum(),
        }
    }

    /// Decode payload elements `[start, start + out.len())` — the
    /// block-parallel decode entry point of the sharded server, codec-
    /// policy rounds included. Bit-identical to slicing a full decode.
    pub fn decode_range(&self, start: usize, out: &mut [f32]) {
        match self {
            ToServer::Delta { msg, .. } => decode_msg_range(msg, start, out),
            ToServer::DeltaParts { parts, .. } => decode_parts_range(parts, start, out),
        }
    }

    /// [`Self::decode_range`] that *accumulates* (`out[i] += decoded`)
    /// in one fused traversal — what `ParameterServer::apply` uses to
    /// sum the round's worker deltas without a per-delta scratch
    /// buffer. Bit-identical to decode-into-scratch-then-add.
    pub fn decode_range_add(&self, start: usize, out: &mut [f32]) {
        match self {
            ToServer::Delta { msg, .. } => decode_msg_range_add(msg, start, out),
            ToServer::DeltaParts { parts, .. } => decode_parts_range_add(parts, start, out),
        }
    }

    pub fn wire_bytes(&self) -> usize {
        match self {
            // t(8) + worker(4) + loss(4) + payload
            ToServer::Delta { msg, .. } => 16 + msg.wire_bytes(),
            // parts framing charged like the downlink (see ToWorker)
            ToServer::DeltaParts { parts, .. } => {
                16 + PARTS_OVERHEAD + parts.iter().map(|m| PART_OVERHEAD + m.wire_bytes()).sum::<usize>()
            }
        }
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        match self {
            ToServer::Delta { t, worker, loss, msg } => {
                let body = msg.to_bytes();
                let mut out = Vec::with_capacity(17 + body.len());
                out.push(tag::TO_SERVER_DELTA);
                out.extend_from_slice(&t.to_le_bytes());
                out.extend_from_slice(&worker.to_le_bytes());
                out.extend_from_slice(&loss.to_le_bytes());
                out.extend_from_slice(&body);
                out
            }
            ToServer::DeltaParts { t, worker, loss, parts } => {
                let mut out = Vec::with_capacity(21);
                out.push(tag::TO_SERVER_DELTA_PARTS);
                out.extend_from_slice(&t.to_le_bytes());
                out.extend_from_slice(&worker.to_le_bytes());
                out.extend_from_slice(&loss.to_le_bytes());
                parts_to_bytes(&mut out, parts);
                out
            }
        }
    }

    // qadam: decode
    pub fn from_bytes(b: &[u8]) -> Result<Self> {
        let mut rd = Rd::new(b);
        let kind = rd.u8();
        let t = rd.u64();
        let worker = rd.u32();
        let loss = rd.f32();
        let (kind, t, worker, loss) = match (kind, t, worker, loss) {
            (Some(k), Some(t), Some(w), Some(l)) => (k, t, w, l),
            _ => return Err(anyhow!("short Delta frame")),
        };
        let body = rd.rest();
        match kind {
            tag::TO_SERVER_DELTA => {
                Ok(ToServer::Delta { t, worker, loss, msg: WireMsg::from_bytes(body)? })
            }
            tag::TO_SERVER_DELTA_PARTS => {
                Ok(ToServer::DeltaParts { t, worker, loss, parts: parts_from_bytes(body)? })
            }
            other => Err(anyhow!("bad ToServer tag {other}")),
        }
    }
}

/// Cumulative traffic accounting, split by direction.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CommStats {
    /// Server → workers (weight broadcasts), summed over the workers
    /// actually in each round's membership (crashed/evicted workers are
    /// not shipped — or charged — bytes).
    pub down_bytes: u64,
    /// Workers → server (deltas), all received replies summed.
    pub up_bytes: u64,
    pub rounds: u64,
    /// Full-weights resync frames broadcast in delta-downlink mode
    /// (round 1, the `resync_every` cadence, and forced rejoins). Stays
    /// 0 in full mode, where every frame is full by definition.
    pub resyncs: u64,
}

impl CommStats {
    pub fn up_mb_per_round_per_worker(&self, workers: usize) -> f64 {
        if self.rounds == 0 {
            return 0.0;
        }
        self.up_bytes as f64 / self.rounds as f64 / workers as f64 / 1e6
    }

    pub fn down_mb_per_round_per_worker(&self, workers: usize) -> f64 {
        if self.rounds == 0 {
            return 0.0;
        }
        self.down_bytes as f64 / self.rounds as f64 / workers as f64 / 1e6
    }

    /// Per-window accounting tap: the delta accumulated since an
    /// `earlier` snapshot of the same counter set. Saturating, so a
    /// stale/foreign snapshot yields zeros instead of wrap-around
    /// garbage — the obs layer feeds windows, never trusts ordering.
    pub fn since(&self, earlier: &CommStats) -> CommStats {
        CommStats {
            down_bytes: self.down_bytes.saturating_sub(earlier.down_bytes),
            up_bytes: self.up_bytes.saturating_sub(earlier.up_bytes),
            rounds: self.rounds.saturating_sub(earlier.rounds),
            resyncs: self.resyncs.saturating_sub(earlier.resyncs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{decode_msg, seeded_rng, Compressor, LogQuant};

    fn sample_msg() -> WireMsg {
        let u: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) / 7.0).collect();
        let mut q = vec![0.0; 100];
        LogQuant::new(2).compress_into(&u, &mut q, &mut seeded_rng(0, 0))
    }

    fn sample_parts() -> Vec<WireMsg> {
        let mut rng = seeded_rng(0, 0);
        [(40usize, 2u32), (60, 0)]
            .iter()
            .map(|&(n, kg)| {
                let u: Vec<f32> = (0..n).map(|i| (i as f32 - 20.0) / 9.0).collect();
                let mut q = vec![0.0; n];
                LogQuant::new(kg).compress_into(&u, &mut q, &mut rng)
            })
            .collect()
    }

    #[test]
    fn toworker_roundtrip() {
        let m = ToWorker::Weights { t: 42, epoch: 3, msg: sample_msg() };
        let b = m.to_bytes();
        match ToWorker::from_bytes(&b).unwrap() {
            ToWorker::Weights { t, epoch, msg } => {
                assert_eq!((t, epoch), (42, 3));
                assert_eq!(msg.n, 100);
            }
            _ => panic!(),
        }
        assert!(matches!(ToWorker::from_bytes(&[0]).unwrap(), ToWorker::Shutdown));
        assert!(ToWorker::from_bytes(&[9, 9]).is_err());
    }

    #[test]
    fn weights_delta_roundtrip_and_accounting() {
        let m = ToWorker::WeightsDelta { t: 9, epoch: 1, msg: sample_msg() };
        // same framing cost as a full frame of the same payload
        let full = ToWorker::Weights { t: 9, epoch: 1, msg: sample_msg() };
        assert_eq!(m.wire_bytes(), full.wire_bytes());
        let b = m.to_bytes();
        assert_eq!(b[0], 2, "delta frames carry tag 2");
        match ToWorker::from_bytes(&b).unwrap() {
            ToWorker::WeightsDelta { t, epoch, msg } => {
                assert_eq!((t, epoch), (9, 1));
                assert_eq!(msg.n, 100);
            }
            other => panic!("decoded {other:?}"),
        }
        // truncated delta frames fail cleanly
        assert!(ToWorker::from_bytes(&b[..10]).is_err());
    }

    #[test]
    fn weights_delta_parts_roundtrip_and_accounting() {
        let parts = sample_parts();
        let m = ToWorker::WeightsDeltaParts { t: 5, epoch: 2, parts: parts.clone() };
        assert_eq!(
            m.wire_bytes(),
            16 + 5 + parts.iter().map(|p| 4 + p.wire_bytes()).sum::<usize>(),
            "per-part headers and the full parts framing (tag + nparts + len prefixes) are charged"
        );
        let b = m.to_bytes();
        assert_eq!(b[0], 3, "parts frames carry tag 3");
        match ToWorker::from_bytes(&b).unwrap() {
            ToWorker::WeightsDeltaParts { t, epoch, parts: back } => {
                assert_eq!((t, epoch), (5, 2));
                assert_eq!(back.len(), 2);
                assert_eq!(back[0].n, 40);
                assert_eq!(back[1].n, 60);
                // the parts decode to exactly what went in
                for (a, b) in back.iter().zip(&parts) {
                    let mut da = vec![0.0; a.n];
                    let mut db = vec![0.0; b.n];
                    decode_msg(a, &mut da);
                    decode_msg(b, &mut db);
                    assert_eq!(da, db);
                }
            }
            other => panic!("decoded {other:?}"),
        }
        // truncation anywhere fails cleanly, never panics
        for cut in [0, 5, 17, 20, b.len() - 1] {
            assert!(ToWorker::from_bytes(&b[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn toserver_roundtrip() {
        let m = ToServer::Delta { t: 7, worker: 5, loss: 1.25, msg: sample_msg() };
        let b = m.to_bytes();
        assert_eq!(b[0], 0, "single-message replies carry tag 0");
        match ToServer::from_bytes(&b).unwrap() {
            ToServer::Delta { t, worker, loss, msg } => {
                assert_eq!((t, worker, loss), (7, 5, 1.25));
                assert_eq!(msg.n, 100);
            }
            other => panic!("decoded {other:?}"),
        }
        assert!(ToServer::from_bytes(&[7; 16]).is_err(), "short frame");
        let mut bad = b.clone();
        bad[0] = 9;
        assert!(ToServer::from_bytes(&bad).is_err(), "unknown tag");
    }

    #[test]
    fn toserver_parts_roundtrip_and_accessors() {
        let parts = sample_parts();
        let m = ToServer::DeltaParts { t: 3, worker: 1, loss: 0.5, parts: parts.clone() };
        assert_eq!(m.round(), 3);
        assert_eq!(m.worker(), 1);
        assert_eq!(m.loss(), 0.5);
        assert_eq!(m.payload_n(), 100);
        assert_eq!(
            m.wire_bytes(),
            16 + 5 + parts.iter().map(|p| 4 + p.wire_bytes()).sum::<usize>()
        );
        let b = m.to_bytes();
        assert_eq!(b[0], 1, "parts replies carry tag 1");
        let back = ToServer::from_bytes(&b).unwrap();
        assert!(matches!(back, ToServer::DeltaParts { .. }));
        assert_eq!(back.payload_n(), 100);
        // range decode across the part seam equals the full decode
        let mut full = vec![0.0; 100];
        let mut expect = vec![0.0; 100];
        back.decode_range(0, &mut full);
        decode_msg(&parts[0], &mut expect[..40]);
        decode_msg(&parts[1], &mut expect[40..]);
        assert_eq!(full, expect);
        let mut seam = vec![0.0; 20];
        back.decode_range(30, &mut seam);
        assert_eq!(seam, full[30..50]);
    }

    #[test]
    fn parts_frame_rejects_malformed_payloads() {
        let parts = sample_parts();
        let m = ToServer::DeltaParts { t: 1, worker: 0, loss: 0.0, parts };
        let good = m.to_bytes();
        // zero parts
        let mut b = good[..17].to_vec();
        b.extend_from_slice(&0u32.to_le_bytes());
        assert!(ToServer::from_bytes(&b).is_err());
        // lying part length (overruns the frame)
        let mut b = good.clone();
        b[21] = 0xff;
        b[22] = 0xff;
        assert!(ToServer::from_bytes(&b).is_err());
        // trailing garbage after the last part
        let mut b = good.clone();
        b.push(0);
        assert!(ToServer::from_bytes(&b).is_err());
    }

    #[test]
    fn every_prefix_truncation_errors_cleanly() {
        // INV-PANIC regression: every strict prefix of every frame kind
        // must decode to Err, never panic (the decoders only read
        // through util::bytes).
        let down = [
            ToWorker::Weights { t: 1, epoch: 2, msg: sample_msg() }.to_bytes(),
            ToWorker::WeightsDelta { t: 1, epoch: 2, msg: sample_msg() }.to_bytes(),
            ToWorker::WeightsDeltaParts { t: 1, epoch: 2, parts: sample_parts() }.to_bytes(),
        ];
        for b in &down {
            assert!(ToWorker::from_bytes(b).is_ok());
            for cut in 0..b.len() {
                assert!(ToWorker::from_bytes(&b[..cut]).is_err(), "cut={cut}");
            }
        }
        let up = [
            ToServer::Delta { t: 1, worker: 0, loss: 0.5, msg: sample_msg() }.to_bytes(),
            ToServer::DeltaParts { t: 1, worker: 0, loss: 0.5, parts: sample_parts() }.to_bytes(),
        ];
        for b in &up {
            assert!(ToServer::from_bytes(b).is_ok());
            for cut in 0..b.len() {
                assert!(ToServer::from_bytes(&b[..cut]).is_err(), "cut={cut}");
            }
        }
    }

    #[test]
    fn comm_stats_rates() {
        let s = CommStats { down_bytes: 16_000_000, up_bytes: 8_000_000, rounds: 10, resyncs: 0 };
        assert!((s.up_mb_per_round_per_worker(8) - 0.1).abs() < 1e-9);
        assert!((s.down_mb_per_round_per_worker(8) - 0.2).abs() < 1e-9);
    }

    #[test]
    fn comm_stats_since_windows_and_saturates() {
        let early = CommStats { down_bytes: 100, up_bytes: 40, rounds: 2, resyncs: 1 };
        let late = CommStats { down_bytes: 260, up_bytes: 90, rounds: 5, resyncs: 1 };
        assert_eq!(
            late.since(&early),
            CommStats { down_bytes: 160, up_bytes: 50, rounds: 3, resyncs: 0 }
        );
        // a snapshot from the wrong epoch must not wrap
        assert_eq!(early.since(&late), CommStats::default());
    }
}
