//! The worker (Algorithm 3): receive weights → local stochastic
//! gradient → worker optimizer (moments + EF + quantization) → delta.
//!
//! **Sharding contract.** A worker is a *global* endpoint: its weight
//! replica, gradient, Adam moments and EF residual always cover the
//! whole model. Under `--shards N` only the *wire traffic* is split —
//! [`Worker::handle_sharded`] assembles the N per-shard broadcast
//! frames into the one replica, computes one gradient, runs one
//! optimizer step, and routes the resulting per-shard messages back on
//! their lanes. Per-shard `synced` flags track which ranges have seen
//! a full-weights frame, so a single-shard resync re-anchors exactly
//! that range.

use super::protocol::{ToServer, ToWorker};
use super::shard::ShardPlan;
use crate::data::Dataset;
use crate::optim::WorkerOpt;
use crate::quant::{decode_msg, decode_parts, DeltaMsg};
use anyhow::{anyhow, Result};
use crate::util::DetRng;
use std::sync::Arc;

/// Where a worker's gradients come from: a PJRT model graph over a data
/// shard, or a synthetic problem (theory checks).
///
/// `Send` so a whole [`Worker`] can run on its own
/// [`super::transport::ThreadedBus`] thread.
pub trait GradSource: Send {
    /// Stochastic gradient at `weights` for (worker, t). Returns
    /// (loss, flat gradient).
    fn loss_grad(&mut self, weights: &[f32], worker: usize, t: u64) -> Result<(f32, Vec<f32>)>;
    fn dim(&self) -> usize;
}

/// Synthetic-problem gradient source (Theorems 3.1–3.3 checks).
pub struct SimGradSource {
    pub problem: crate::sim::StochasticProblem,
}

impl GradSource for SimGradSource {
    fn loss_grad(&mut self, weights: &[f32], worker: usize, t: u64) -> Result<(f32, Vec<f32>)> {
        let mut g = vec![0.0; weights.len()];
        self.problem.stoch_grad_into(weights, t, worker as u64, &mut g);
        Ok((self.problem.loss(weights), g))
    }

    fn dim(&self) -> usize {
        self.problem.dim
    }
}

/// PJRT model gradient source over a dataset shard.
pub struct ModelGradSource {
    pub model: Arc<crate::runtime::ModelRuntime>,
    pub data: Arc<dyn Dataset>,
    pub batch: usize,
}

impl GradSource for ModelGradSource {
    fn loss_grad(&mut self, weights: &[f32], worker: usize, t: u64) -> Result<(f32, Vec<f32>)> {
        let batch = self.data.train_batch(worker, t, self.batch);
        self.model.loss_grad(weights, &batch)
    }

    fn dim(&self) -> usize {
        self.model.dim()
    }
}

pub struct Worker {
    pub id: u32,
    opt: Box<dyn WorkerOpt>,
    src: Box<dyn GradSource>,
    rng: DetRng,
    /// decoded weight buffer (the worker replica in delta-downlink mode)
    w: Vec<f32>,
    /// scratch for decoding delta frames
    scratch: Vec<f32>,
    /// The shard partition this worker's wire traffic is split by
    /// (single full-vector shard by default — the seed behavior).
    plan: ShardPlan,
    /// Per-shard: has this range seen a full weights frame (or a
    /// checkpoint restore)? Delta frames on an unsynced range are a
    /// protocol error (every shard opens its stream with a resync
    /// frame).
    synced: Vec<bool>,
    pub last_loss: f32,
}

impl Worker {
    pub fn new(id: u32, opt: Box<dyn WorkerOpt>, src: Box<dyn GradSource>, seed: u64) -> Self {
        let dim = src.dim();
        Self {
            id,
            opt,
            src,
            rng: crate::quant::seeded_rng(seed, 0x9e37_79b9 ^ id as u64),
            w: vec![0.0; dim],
            scratch: vec![0.0; dim],
            plan: ShardPlan::single(dim),
            synced: vec![false; 1],
            last_loss: f32::NAN,
        }
    }

    /// Split this worker's wire traffic by `plan`: frame `s` of every
    /// [`Self::handle_sharded`] round covers shard `s`'s range, and the
    /// reply comes back as one message per shard. Resets the per-shard
    /// sync state (the fleet re-syncs via each shard's opening full
    /// frame).
    pub fn set_shards(&mut self, plan: ShardPlan) {
        assert_eq!(plan.dim(), self.w.len(), "plan dim != worker dim");
        self.synced = vec![false; plan.count()];
        self.plan = plan;
    }

    fn all_synced(&self) -> bool {
        self.synced.iter().all(|&s| s)
    }

    /// Current decoded weight view (the replica the next gradient is
    /// evaluated at) — for parity tests and diagnostics.
    pub fn weights(&self) -> &[f32] {
        &self.w
    }

    /// Seed the replica directly (checkpoint restore in delta-downlink
    /// mode: the server's `x̂` is the bit-exact worker view).
    pub fn restore_weights(&mut self, w: &[f32]) {
        assert_eq!(w.len(), self.w.len());
        self.w.copy_from_slice(w);
        self.synced.fill(true);
    }

    pub fn opt_name(&self) -> String {
        self.opt.name()
    }

    pub fn bits_per_element(&self) -> f64 {
        self.opt.bits_per_element()
    }

    pub fn residual_norm(&self) -> f32 {
        self.opt.residual_norm()
    }

    /// Residual ∞-norm (0 when EF is off) — the obs-layer gauge.
    pub fn residual_inf_norm(&self) -> f32 {
        self.opt.residual_inf_norm()
    }

    /// Mean code bits/element the uplink codec policy currently
    /// chooses (None on the static path) — for the metrics CSV.
    pub fn policy_bits(&self) -> Option<f64> {
        self.opt.policy_bits()
    }

    /// Per-tensor levels the uplink policy currently chooses (parity
    /// tests compare these across engines). Borrowed view — copy-free
    /// in the round path.
    pub fn chosen_bits(&self) -> Option<&[u32]> {
        self.opt.chosen_bits()
    }

    /// Checkpointable optimizer state `(m, v, e)` as borrowed views;
    /// the checkpoint writer owns the one copy it makes.
    pub fn opt_state(&self) -> Option<(&[f32], &[f32], &[f32])> {
        self.opt.state()
    }

    pub fn opt_restore(&mut self, m: &[f32], v: &[f32], e: &[f32]) {
        self.opt.restore(m, v, e);
    }

    /// Does this worker's optimizer carry an EF residual (required by
    /// the async-round refund path)?
    pub fn has_error_feedback(&self) -> bool {
        self.opt.has_error_feedback()
    }

    /// Async-round refund: fold `scale ×` the decoded payload of
    /// `reply` — one of this worker's own per-lane replies the server
    /// rejected as too stale (`scale = 1`), or the un-applied fraction
    /// of a down-weighted apply (`scale = 1 − w`) — back into the EF
    /// residual over lane `lane`'s shard range. The residual then
    /// re-ships that mass compressed into the worker's next reply, so
    /// rejection loses no gradient mass (the ECQ-SGD argument; see
    /// [`crate::quant::ErrorFeedback::absorb_range`]).
    pub fn absorb_rejected(&mut self, lane: usize, reply: &ToServer, scale: f32) -> Result<()> {
        if reply.worker() != self.id {
            return Err(anyhow!(
                "refund for worker {} routed to worker {}",
                reply.worker(),
                self.id
            ));
        }
        let (start, len) = self.plan.range(lane);
        if reply.payload_n() != len {
            return Err(anyhow!(
                "refund payload dim {} != lane {lane} width {len}",
                reply.payload_n()
            ));
        }
        reply.decode_range(0, &mut self.scratch[start..start + len]);
        let vals = &self.scratch[start..start + len];
        self.opt.absorb_residual(start, vals, scale);
        Ok(())
    }

    /// Process one broadcast; returns the delta reply.
    pub fn handle(&mut self, msg: &ToWorker) -> Result<Option<ToServer>> {
        match msg {
            ToWorker::Shutdown => Ok(None),
            ToWorker::Weights { t, epoch, msg } => {
                if msg.n != self.w.len() {
                    return Err(anyhow!("weights dim {} != worker dim {}", msg.n, self.w.len()));
                }
                decode_msg(msg, &mut self.w);
                self.synced.fill(true);
                self.reply(*t, *epoch)
            }
            ToWorker::WeightsDelta { t, epoch, msg } => {
                if msg.n != self.w.len() {
                    return Err(anyhow!("delta dim {} != worker dim {}", msg.n, self.w.len()));
                }
                if !self.all_synced() {
                    return Err(anyhow!(
                        "worker {}: delta frame before any full weights frame",
                        self.id
                    ));
                }
                decode_msg(msg, &mut self.scratch);
                for (w, &d) in self.w.iter_mut().zip(&self.scratch) {
                    *w += d;
                }
                self.reply(*t, *epoch)
            }
            ToWorker::WeightsDeltaParts { t, epoch, parts } => {
                let n: usize = parts.iter().map(|m| m.n).sum();
                if n != self.w.len() {
                    return Err(anyhow!("delta parts dim {} != worker dim {}", n, self.w.len()));
                }
                if !self.all_synced() {
                    return Err(anyhow!(
                        "worker {}: delta frame before any full weights frame",
                        self.id
                    ));
                }
                // mixed-codec round: each part decodes with its own
                // header, laid out back to back
                decode_parts(parts, &mut self.scratch);
                for (w, &d) in self.w.iter_mut().zip(&self.scratch) {
                    *w += d;
                }
                self.reply(*t, *epoch)
            }
        }
    }

    /// Process one sharded round: frame `s` covers shard `s`'s range of
    /// the replica (a `Weights` frame overwrites and re-syncs that
    /// range; delta frames add to it), then one gradient is computed at
    /// the fully assembled view and one global optimizer step emits the
    /// per-shard replies, in shard order. A single-shard plan delegates
    /// to [`Self::handle`] — byte-identical to the unsharded path. Any
    /// `Shutdown` frame ends the run (`None`).
    pub fn handle_sharded(&mut self, frames: &[ToWorker]) -> Result<Option<Vec<ToServer>>> {
        if self.plan.count() == 1 && frames.len() == 1 {
            return Ok(self.handle(&frames[0])?.map(|r| vec![r]));
        }
        if frames.len() != self.plan.count() {
            return Err(anyhow!(
                "worker {}: {} shard frames for a {}-shard plan",
                self.id,
                frames.len(),
                self.plan.count()
            ));
        }
        if frames.iter().any(|f| matches!(f, ToWorker::Shutdown)) {
            return Ok(None);
        }
        // All lanes must carry the same logical round.
        let (t, epoch) = match &frames[0] {
            ToWorker::Weights { t, epoch, .. }
            | ToWorker::WeightsDelta { t, epoch, .. }
            | ToWorker::WeightsDeltaParts { t, epoch, .. } => (*t, *epoch),
            ToWorker::Shutdown => unreachable!("checked above"),
        };
        for (s, f) in frames.iter().enumerate() {
            let ft = match f {
                ToWorker::Weights { t, .. }
                | ToWorker::WeightsDelta { t, .. }
                | ToWorker::WeightsDeltaParts { t, .. } => *t,
                ToWorker::Shutdown => unreachable!("checked above"),
            };
            if ft != t {
                return Err(anyhow!(
                    "worker {}: shard {s} at round {ft}, shard 0 at {t} (lanes desynchronized)",
                    self.id
                ));
            }
        }
        for (s, f) in frames.iter().enumerate() {
            let (start, len) = self.plan.range(s);
            match f {
                ToWorker::Weights { msg, .. } => {
                    if msg.n != len {
                        return Err(anyhow!(
                            "shard {s} weights dim {} != shard width {len}",
                            msg.n
                        ));
                    }
                    decode_msg(msg, &mut self.w[start..start + len]);
                    self.synced[s] = true;
                }
                ToWorker::WeightsDelta { msg, .. } => {
                    if msg.n != len {
                        return Err(anyhow!("shard {s} delta dim {} != shard width {len}", msg.n));
                    }
                    if !self.synced[s] {
                        return Err(anyhow!(
                            "worker {}: delta frame on shard {s} before its full weights frame",
                            self.id
                        ));
                    }
                    decode_msg(msg, &mut self.scratch[start..start + len]);
                    for (w, &d) in
                        self.w[start..start + len].iter_mut().zip(&self.scratch[start..start + len])
                    {
                        *w += d;
                    }
                }
                ToWorker::WeightsDeltaParts { parts, .. } => {
                    let n: usize = parts.iter().map(|m| m.n).sum();
                    if n != len {
                        return Err(anyhow!("shard {s} parts dim {n} != shard width {len}"));
                    }
                    if !self.synced[s] {
                        return Err(anyhow!(
                            "worker {}: delta frame on shard {s} before its full weights frame",
                            self.id
                        ));
                    }
                    decode_parts(parts, &mut self.scratch[start..start + len]);
                    for (w, &d) in
                        self.w[start..start + len].iter_mut().zip(&self.scratch[start..start + len])
                    {
                        *w += d;
                    }
                }
                ToWorker::Shutdown => unreachable!("checked above"),
            }
        }
        let (loss, grad) = self.src.loss_grad(&self.w, self.id as usize, t)?;
        self.last_loss = loss;
        let msgs = self.opt.step_sharded(&grad, t, epoch, &mut self.rng, self.plan.ranges())?;
        Ok(Some(
            msgs.into_iter()
                .map(|m| match m {
                    DeltaMsg::Single(msg) => {
                        ToServer::Delta { t, worker: self.id, loss, msg }
                    }
                    DeltaMsg::Parts(parts) => {
                        ToServer::DeltaParts { t, worker: self.id, loss, parts }
                    }
                })
                .collect(),
        ))
    }

    /// Gradient at the current replica → optimizer step → delta reply
    /// (Alg. 3 lines 2–8; shared by every weights-frame kind).
    fn reply(&mut self, t: u64, epoch: u64) -> Result<Option<ToServer>> {
        let (loss, grad) = self.src.loss_grad(&self.w, self.id as usize, t)?;
        self.last_loss = loss;
        Ok(Some(match self.opt.step(&grad, t, epoch, &mut self.rng) {
            DeltaMsg::Single(msg) => ToServer::Delta { t, worker: self.id, loss, msg },
            DeltaMsg::Parts(parts) => ToServer::DeltaParts { t, worker: self.id, loss, parts },
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{LrSchedule, QAdamEf};
    use crate::quant::{CodecId, Compressor, Identity, WireMsg};

    fn weights_msg(w: &[f32], t: u64) -> ToWorker {
        let mut q = vec![0.0; w.len()];
        let msg: WireMsg = Identity.compress_into(w, &mut q, &mut crate::quant::seeded_rng(0, 0));
        ToWorker::Weights { t, epoch: 0, msg }
    }

    #[test]
    fn worker_round_produces_delta() {
        let dim = 8;
        let src = SimGradSource { problem: crate::sim::StochasticProblem::new(dim, 0.1, 1) };
        let opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.01 });
        let mut w = Worker::new(3, Box::new(opt), Box::new(src), 42);
        let x = vec![1.0f32; dim];
        let out = w.handle(&weights_msg(&x, 1)).unwrap().unwrap();
        match out {
            ToServer::Delta { t, worker, loss, msg } => {
                assert_eq!((t, worker), (1, 3));
                assert!(loss.is_finite());
                assert_eq!(msg.codec, CodecId::LogQuant);
                assert_eq!(msg.n, dim);
            }
            other => panic!("static opt must reply single-message, got {other:?}"),
        }
    }

    fn delta_msg(d: &[f32], t: u64) -> ToWorker {
        let mut q = vec![0.0; d.len()];
        let msg: WireMsg = Identity.compress_into(d, &mut q, &mut crate::quant::seeded_rng(0, 0));
        ToWorker::WeightsDelta { t, epoch: 0, msg }
    }

    #[test]
    fn delta_frame_accumulates_into_replica() {
        let dim = 8;
        let src = SimGradSource { problem: crate::sim::StochasticProblem::new(dim, 0.1, 1) };
        let opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.01 });
        let mut w = Worker::new(0, Box::new(opt), Box::new(src), 42);
        let x0 = vec![1.0f32; dim];
        w.handle(&weights_msg(&x0, 1)).unwrap().unwrap();
        assert_eq!(w.weights(), &x0[..]);
        let d = vec![0.25f32; dim];
        let out = w.handle(&delta_msg(&d, 2)).unwrap().unwrap();
        assert_eq!(out.round(), 2);
        assert_eq!(w.weights(), &[1.25f32; 8][..], "delta adds, full frame overwrites");
        // a later full frame overwrites again
        w.handle(&weights_msg(&x0, 3)).unwrap().unwrap();
        assert_eq!(w.weights(), &x0[..]);
    }

    /// Mixed-codec downlink parts accumulate into the replica exactly
    /// like a single delta frame of the concatenated payload.
    #[test]
    fn delta_parts_frame_accumulates_into_replica() {
        use crate::quant::{Compressor, LogQuant};
        let dim = 12;
        let src = SimGradSource { problem: crate::sim::StochasticProblem::new(dim, 0.1, 1) };
        let opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.01 });
        let mut w = Worker::new(0, Box::new(opt), Box::new(src), 42);
        w.handle(&weights_msg(&vec![1.0f32; dim], 1)).unwrap().unwrap();
        // two parts with different codecs; exact powers of two decode
        // exactly
        let mut rng = crate::quant::seeded_rng(0, 0);
        let mut q = vec![0.0; dim];
        let p0 = LogQuant::new(0).compress_into(&[0.5f32; 8], &mut q[..8], &mut rng);
        let p1 = LogQuant::new(2).compress_into(&[0.25f32; 4], &mut q[8..], &mut rng);
        let out = w
            .handle(&ToWorker::WeightsDeltaParts { t: 2, epoch: 0, parts: vec![p0.clone(), p1] })
            .unwrap()
            .unwrap();
        assert_eq!(out.round(), 2);
        let want: Vec<f32> =
            (0..dim).map(|i| if i < 8 { 1.5 } else { 1.25 }).collect();
        assert_eq!(w.weights(), &want[..]);
        // wrong total dimension is rejected
        let err =
            w.handle(&ToWorker::WeightsDeltaParts { t: 3, epoch: 0, parts: vec![p0] }).unwrap_err();
        assert!(err.to_string().contains("parts dim"), "{err}");
    }

    /// Sharded rounds: per-shard frames assemble one replica, one
    /// gradient step answers with one reply per shard, and a
    /// single-shard resync re-anchors exactly its range.
    #[test]
    fn handle_sharded_assembles_ranges_and_replies_per_shard() {
        use crate::ps::shard::ShardPlan;
        use crate::quant::LogQuant;
        let dim = 8;
        let src = SimGradSource { problem: crate::sim::StochasticProblem::new(dim, 0.1, 1) };
        let opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.01 });
        let mut w = Worker::new(0, Box::new(opt), Box::new(src), 42);
        w.set_shards(ShardPlan::uniform(dim, 2));
        let full = |x: f32, t: u64| ToWorker::Weights {
            t,
            epoch: 0,
            msg: Identity.compress_into(
                &[x; 4],
                &mut [0.0; 4],
                &mut crate::quant::seeded_rng(0, 0),
            ),
        };
        let delta = |d: f32, t: u64| ToWorker::WeightsDelta {
            t,
            epoch: 0,
            msg: LogQuant::new(2).compress_into(
                &[d; 4],
                &mut [0.0; 4],
                &mut crate::quant::seeded_rng(1, t),
            ),
        };
        // a delta before the shard's resync frame is rejected
        let err = w.handle_sharded(&[delta(0.5, 1), full(1.0, 1)]).unwrap_err();
        assert!(err.to_string().contains("shard 0"), "{err}");
        // round 1: both lanes resync
        let replies = w.handle_sharded(&[full(1.0, 1), full(2.0, 1)]).unwrap().unwrap();
        assert_eq!(replies.len(), 2);
        assert_eq!(replies[0].worker(), 0);
        assert_eq!(replies[0].payload_n(), 4);
        assert_eq!(replies[1].payload_n(), 4);
        assert_eq!(replies[0].loss(), replies[1].loss(), "one gradient, one loss, every lane");
        assert_eq!(&w.weights()[..4], &[1.0; 4]);
        assert_eq!(&w.weights()[4..], &[2.0; 4]);
        // round 2: shard 0 delta (exact power of two), shard 1 resync
        w.handle_sharded(&[delta(0.5, 2), full(3.0, 2)]).unwrap().unwrap();
        assert_eq!(&w.weights()[..4], &[1.5; 4], "delta adds on its range");
        assert_eq!(&w.weights()[4..], &[3.0; 4], "resync overwrites its range");
        // desynchronized lanes are a clear error
        let err = w.handle_sharded(&[delta(0.5, 3), full(0.0, 4)]).unwrap_err();
        assert!(err.to_string().contains("desynchronized"), "{err}");
        // wrong frame count for the plan
        assert!(w.handle_sharded(&[full(0.0, 3)]).is_err());
        // any Shutdown lane ends the run
        assert!(w.handle_sharded(&[ToWorker::Shutdown, full(0.0, 3)]).unwrap().is_none());
    }

    #[test]
    fn delta_before_sync_rejected() {
        let dim = 4;
        let src = SimGradSource { problem: crate::sim::StochasticProblem::new(dim, 0.0, 1) };
        let opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.01 });
        let mut w = Worker::new(0, Box::new(opt), Box::new(src), 0);
        let err = w.handle(&delta_msg(&[0.1; 4], 1)).unwrap_err();
        assert!(err.to_string().contains("full weights frame"), "{err}");
        // restore_weights counts as a sync
        w.restore_weights(&[0.5; 4]);
        assert!(w.handle(&delta_msg(&[0.1; 4], 1)).unwrap().is_some());
        assert_eq!(w.weights(), &[0.6f32; 4][..]);
    }

    /// The async refund path: absorbing a worker's own rejected reply
    /// raises its EF residual by exactly the decoded payload over the
    /// rejected lane's range, and misrouted refunds are rejected.
    #[test]
    fn absorb_rejected_refunds_the_lane_range() {
        use crate::ps::shard::ShardPlan;
        let dim = 8;
        let src = SimGradSource { problem: crate::sim::StochasticProblem::new(dim, 0.1, 1) };
        let opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.01 });
        let mut w = Worker::new(0, Box::new(opt), Box::new(src), 42);
        assert!(w.has_error_feedback());
        w.set_shards(ShardPlan::uniform(dim, 2));
        let full = |x: f32, t: u64| ToWorker::Weights {
            t,
            epoch: 0,
            msg: Identity.compress_into(
                &[x; 4],
                &mut [0.0; 4],
                &mut crate::quant::seeded_rng(0, 0),
            ),
        };
        let replies = w.handle_sharded(&[full(1.0, 1), full(2.0, 1)]).unwrap().unwrap();
        let (_, _, e_before) = w.opt_state().unwrap();
        let e_before = e_before.to_vec();
        // decode what lane 1's reply carries, then refund it in full
        let mut dec = vec![0.0f32; 4];
        replies[1].decode_range(0, &mut dec);
        w.absorb_rejected(1, &replies[1], 1.0).unwrap();
        let (_, _, e_after) = w.opt_state().unwrap();
        assert_eq!(&e_after[..4], &e_before[..4], "lane 0's residual range is untouched");
        for i in 0..4 {
            let want = e_before[4 + i] + dec[i];
            assert!((e_after[4 + i] - want).abs() < 1e-6, "i={i}");
        }
        // a refund claiming another worker's reply is refused
        let foreign = match &replies[0] {
            ToServer::Delta { t, loss, msg, .. } => {
                ToServer::Delta { t: *t, worker: 9, loss: *loss, msg: msg.clone() }
            }
            other => panic!("{other:?}"),
        };
        assert!(w.absorb_rejected(0, &foreign, 1.0).is_err());
        // a payload that does not match the lane width is refused
        assert!(w.absorb_rejected(0, &replies[1], 1.0).is_ok());
        let err = {
            let bad = match &replies[0] {
                ToServer::Delta { t, loss, msg, .. } => ToServer::Delta {
                    t: *t,
                    worker: 0,
                    loss: *loss,
                    msg: {
                        let mut m = msg.clone();
                        m.n = 3;
                        m
                    },
                },
                other => panic!("{other:?}"),
            };
            w.absorb_rejected(0, &bad, 1.0).unwrap_err()
        };
        assert!(err.to_string().contains("width"), "{err}");
    }

    #[test]
    fn shutdown_yields_none() {
        let dim = 4;
        let src = SimGradSource { problem: crate::sim::StochasticProblem::new(dim, 0.0, 1) };
        let opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.01 });
        let mut w = Worker::new(0, Box::new(opt), Box::new(src), 0);
        assert!(w.handle(&ToWorker::Shutdown).unwrap().is_none());
    }

    #[test]
    fn dim_mismatch_rejected() {
        let src = SimGradSource { problem: crate::sim::StochasticProblem::new(4, 0.0, 1) };
        let opt = QAdamEf::paper_default(4, 2, LrSchedule::Const { alpha: 0.01 });
        let mut w = Worker::new(0, Box::new(opt), Box::new(src), 0);
        assert!(w.handle(&weights_msg(&[0.0; 5], 1)).is_err());
    }
}
