//! Transports: how PS messages move between server and workers.
//!
//! * [`LocalBus`] — in-process, deterministic, zero-copy (messages are
//!   passed by reference through the synchronous round loop). This is
//!   the default engine for experiments and benches: the paper's
//!   protocol is synchronous, so sequential execution is *semantically
//!   exact*, and byte accounting uses the same wire encoding the TCP
//!   path ships.
//! * [`TcpServer`] / [`tcp_worker_loop`] — a real multi-process
//!   deployment: length-prefixed frames over TCP, one blocking stream
//!   per worker (run each worker as its own `qadam worker` process; see
//!   `qadam serve --help`).

use super::protocol::{ToServer, ToWorker};
use anyhow::{anyhow, Context, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};

// ---------------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------------

pub fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> Result<()> {
    let len = (payload.len() as u32).to_le_bytes();
    stream.write_all(&len)?;
    stream.write_all(payload)?;
    Ok(())
}

pub fn read_frame(stream: &mut TcpStream) -> Result<Vec<u8>> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    if n > 1 << 30 {
        return Err(anyhow!("frame too large: {n}"));
    }
    let mut buf = vec![0u8; n];
    stream.read_exact(&mut buf)?;
    Ok(buf)
}

// ---------------------------------------------------------------------------
// in-process bus
// ---------------------------------------------------------------------------

/// Deterministic in-process "network": the trainer broadcasts by calling
/// each worker in worker-id order and gathers the replies. Kept as a
/// type so tests/benches can interpose (e.g. drop or reorder messages).
#[derive(Default)]
pub struct LocalBus {
    /// Optional fault injection: drop the delta of worker `w` at step `t`.
    pub drop_deltas: Vec<(u64, u32)>,
}

impl LocalBus {
    pub fn round(
        &self,
        broadcast: &ToWorker,
        workers: &mut [super::worker::Worker],
    ) -> Result<Vec<ToServer>> {
        let mut replies = Vec::with_capacity(workers.len());
        for w in workers.iter_mut() {
            if let Some(reply) = w.handle(broadcast)? {
                let drop = match (&reply, broadcast) {
                    (ToServer::Delta { t, worker, .. }, _) => {
                        self.drop_deltas.iter().any(|&(dt, dw)| dt == *t && dw == *worker)
                    }
                };
                if !drop {
                    replies.push(reply);
                }
            }
        }
        Ok(replies)
    }
}

// ---------------------------------------------------------------------------
// TCP deployment
// ---------------------------------------------------------------------------

/// Server side of the TCP deployment: accepts `n` workers, then drives
/// synchronous rounds (broadcast → gather).
pub struct TcpServer {
    streams: Vec<TcpStream>,
}

impl TcpServer {
    pub fn bind_and_accept(addr: &str, nworkers: usize) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        eprintln!("[server] listening on {addr}, waiting for {nworkers} workers");
        let mut streams = Vec::with_capacity(nworkers);
        for i in 0..nworkers {
            let (s, peer) = listener.accept()?;
            s.set_nodelay(true)?;
            eprintln!("[server] worker {i} connected from {peer}");
            streams.push(s);
        }
        Ok(Self { streams })
    }

    pub fn nworkers(&self) -> usize {
        self.streams.len()
    }

    /// One synchronous round over TCP.
    pub fn round(&mut self, broadcast: &ToWorker) -> Result<Vec<ToServer>> {
        let payload = broadcast.to_bytes();
        for s in &mut self.streams {
            write_frame(s, &payload)?;
        }
        let mut replies = Vec::with_capacity(self.streams.len());
        for s in &mut self.streams {
            let buf = read_frame(s)?;
            replies.push(ToServer::from_bytes(&buf)?);
        }
        Ok(replies)
    }

    pub fn shutdown(&mut self) -> Result<()> {
        let payload = ToWorker::Shutdown.to_bytes();
        for s in &mut self.streams {
            write_frame(s, &payload)?;
        }
        Ok(())
    }
}

/// Worker side of the TCP deployment: connect and serve rounds until
/// Shutdown. The closure maps each weight broadcast to a delta reply.
pub fn tcp_worker_loop(
    addr: &str,
    worker: &mut super::worker::Worker,
) -> Result<u64> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    stream.set_nodelay(true)?;
    let mut rounds = 0u64;
    loop {
        let buf = read_frame(&mut stream)?;
        let msg = ToWorker::from_bytes(&buf)?;
        match worker.handle(&msg)? {
            None => return Ok(rounds),
            Some(reply) => {
                write_frame(&mut stream, &reply.to_bytes())?;
                rounds += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{LrSchedule, QAdamEf};
    use crate::ps::worker::{SimGradSource, Worker};
    use crate::ps::ParameterServer;

    fn mk_worker(id: u32, dim: usize) -> Worker {
        let src = SimGradSource { problem: crate::sim::StochasticProblem::new(dim, 0.05, 9) };
        let opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.02 });
        Worker::new(id, Box::new(opt), Box::new(src), 1)
    }

    #[test]
    fn local_bus_synchronous_round() {
        let dim = 16;
        let mut ps = ParameterServer::new(vec![1.0; dim], None);
        let mut workers: Vec<Worker> = (0..4).map(|i| mk_worker(i, dim)).collect();
        let bus = LocalBus::default();
        for _ in 0..5 {
            let replies = {
                let (b, _w) = ps.broadcast(workers.len());
                bus.round(&b, &mut workers).unwrap()
            };
            assert_eq!(replies.len(), 4);
            ps.apply(&replies).unwrap();
        }
        assert_eq!(ps.stats.rounds, 5);
        assert!(ps.stats.up_bytes > 0 && ps.stats.down_bytes > 0);
    }

    #[test]
    fn local_bus_fault_injection_drops_delta() {
        let dim = 8;
        let mut ps = ParameterServer::new(vec![1.0; dim], None);
        let mut workers: Vec<Worker> = (0..3).map(|i| mk_worker(i, dim)).collect();
        let bus = LocalBus { drop_deltas: vec![(1, 1)] };
        let replies = {
            let (b, _) = ps.broadcast(3);
            bus.round(&b, &mut workers).unwrap()
        };
        assert_eq!(replies.len(), 2); // worker 1's delta dropped
        ps.apply(&replies).unwrap(); // PS still makes progress on the rest
    }

    #[test]
    fn tcp_roundtrip_two_workers() {
        let dim = 16;
        let addr = "127.0.0.1:0";
        let listener = std::net::TcpListener::bind(addr).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener); // free the port for bind_and_accept (tiny race, test-only)

        let addr2 = addr.clone();
        let h1 = std::thread::spawn(move || {
            let mut w = mk_worker(0, dim);
            // retry until server is up
            for _ in 0..100 {
                match tcp_worker_loop(&addr2, &mut w) {
                    Ok(r) => return r,
                    Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
                }
            }
            panic!("worker 0 never connected");
        });
        let addr3 = addr.clone();
        let h2 = std::thread::spawn(move || {
            let mut w = mk_worker(1, dim);
            for _ in 0..100 {
                match tcp_worker_loop(&addr3, &mut w) {
                    Ok(r) => return r,
                    Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
                }
            }
            panic!("worker 1 never connected");
        });

        let mut srv = TcpServer::bind_and_accept(&addr, 2).unwrap();
        let mut ps = ParameterServer::new(vec![1.0; dim], None);
        for _ in 0..3 {
            let (b, _) = ps.broadcast(2);
            let replies = srv.round(&b).unwrap();
            assert_eq!(replies.len(), 2);
            ps.apply(&replies).unwrap();
        }
        srv.shutdown().unwrap();
        assert_eq!(h1.join().unwrap(), 3);
        assert_eq!(h2.join().unwrap(), 3);
    }
}
