//! Transports: how PS messages move between server and workers, behind
//! the one [`Transport`] contract the trainer drives.
//!
//! * [`LocalBus`] — in-process, sequential, deterministic: workers are
//!   stepped one after another in worker-id order. The paper's protocol
//!   is synchronous, so sequential execution is *semantically exact*,
//!   and byte accounting uses the same wire encoding the TCP path
//!   ships. This is the reference engine every other transport must
//!   match bit-for-bit.
//! * [`ThreadedBus`] — in-process, parallel: each worker's local step
//!   (gradient + optimizer + encode) runs on its own scoped thread, and
//!   replies are merged in worker-id order. Because workers share no
//!   mutable state and every per-worker computation is deterministic in
//!   `(worker, t)`, the result is **bit-identical** to [`LocalBus`]
//!   (asserted by the parity tests below); only wall-clock changes.
//! * [`TcpServer`] / [`tcp_worker_loop`] — a real multi-process
//!   deployment: length-prefixed frames over TCP, one blocking stream
//!   per worker (run each worker as its own `qadam worker` process; see
//!   `qadam serve --help`).
//!
//! **Sharding contract.** A sharded round is N independent *lanes* —
//! one per parameter-server shard — driven in lockstep by
//! [`Transport::round_sharded`]: lane `s` carries shard `s`'s broadcast
//! frame out and its replies back, and the gather contract (worker-id
//! order, no duplicates, drops allowed) holds **per lane**. The frame
//! format itself is shard-agnostic: a lane's connection (or in-process
//! slot) *is* its routing. In-process buses run the lanes through
//! [`crate::ps::Worker::handle_sharded`]; over TCP every shard is its
//! own listener ([`TcpShardGroup`] in one driver process,
//! `qadam serve --shard-id i/N` as separate processes) and the worker
//! fans its per-lane frames out concurrently
//! ([`tcp_sharded_worker_loop`]). A transport's single-shard
//! `round_sharded` is byte-identical to its classic [`Transport::round`].

use super::protocol::{ToServer, ToWorker};
use crate::elastic::{Membership, StragglerPolicy};
use anyhow::{anyhow, Context, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------------

/// Hard cap on a single frame (1 GiB): anything larger is a corrupt or
/// hostile length prefix, not a real message.
pub const MAX_FRAME_BYTES: usize = 1 << 30;

pub fn write_frame<W: Write>(stream: &mut W, payload: &[u8]) -> Result<()> {
    let len = (payload.len() as u32).to_le_bytes();
    stream.write_all(&len)?;
    stream.write_all(payload)?;
    Ok(())
}

// qadam: decode
pub fn read_frame<R: Read>(stream: &mut R) -> Result<Vec<u8>> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME_BYTES {
        return Err(anyhow!("frame too large: {n}"));
    }
    // Grow while reading instead of trusting the prefix with one huge
    // upfront allocation — a lying peer costs us at most what it sends.
    let mut buf = Vec::with_capacity(n.min(1 << 20));
    let read = stream.take(n as u64).read_to_end(&mut buf)?;
    if read != n {
        return Err(anyhow!("short frame: {read} of {n} bytes"));
    }
    Ok(buf)
}

// ---------------------------------------------------------------------------
// the round contract
// ---------------------------------------------------------------------------

/// One synchronous PS round (Alg. 2 line 2 + Alg. 3): broadcast the
/// weights message to every worker, gather their delta replies.
///
/// Contract:
/// * replies come back ordered by worker id (gather order never depends
///   on scheduling), so the server's mean is summed in a fixed order
///   and trajectories are reproducible bit-for-bit across transports;
/// * a transport may drop replies (chaos injection via
///   [`crate::elastic::ChaosTransport`], lost frames, evicted
///   stragglers) but must never reorder or duplicate them —
///   [`TcpServer`] rejects duplicate ids at the gather, and
///   `ParameterServer::apply` enforces the same invariant server-side;
/// * `workers` is the in-process worker set; transports whose workers
///   live elsewhere (TCP) ignore it.
pub trait Transport {
    fn round(&mut self, broadcast: &ToWorker, workers: &mut [super::worker::Worker])
        -> Result<Vec<ToServer>>;
    /// One sharded round: `broadcasts[s]` goes out on lane `s`, and the
    /// result's lane `s` holds shard `s`'s gathered replies (the round
    /// contract above applies per lane). The default handles the
    /// single-lane case by delegating to [`Transport::round`] —
    /// byte-identical to the unsharded path — and rejects multi-lane
    /// plans; engines that can route shards override it.
    fn round_sharded(
        &mut self,
        broadcasts: &[ToWorker],
        workers: &mut [super::worker::Worker],
    ) -> Result<Vec<Vec<ToServer>>> {
        match broadcasts {
            [single] => Ok(vec![self.round(single, workers)?]),
            _ => Err(anyhow!(
                "transport '{}' does not route multi-shard rounds",
                self.name()
            )),
        }
    }
    /// Short engine name for logs/benches.
    fn name(&self) -> &'static str;
    /// Downlink membership of round `next_t`: who will receive the
    /// broadcast (and is therefore charged `down_bytes`), plus the
    /// rejoin signal that tells the driver to force a full-weights
    /// resync. Static in-process fleets are always fully present;
    /// elastic transports ([`TcpServer`] under rejoin,
    /// [`crate::elastic::ChaosTransport`] under crash windows)
    /// override this.
    fn membership(&mut self, _next_t: u64, total: usize) -> Membership {
        Membership::full(total)
    }
    /// Tell remote workers the run is over. In-process engines have
    /// nothing to do (the driver owns the workers).
    fn shutdown(&mut self) -> Result<()> {
        Ok(())
    }
    /// Cumulative injected-fault counters, for the obs layer. `None`
    /// for engines without a fault injector; the chaos wrapper
    /// overrides. Read-only: calling this never perturbs the round.
    fn fault_stats(&self) -> Option<crate::elastic::FaultStats> {
        None
    }
    /// Cumulative count of lanes evicted by a straggler deadline, for
    /// the obs layer. Engines without deadlines report 0.
    fn straggler_evictions(&self) -> u64 {
        0
    }
}

/// The worker id a reply claims (sort key of the deterministic gather).
fn worker_id(reply: &ToServer) -> u32 {
    reply.worker()
}

/// Merge one worker's per-lane replies into the per-lane gathers (the
/// in-process sharded round merge, shared by both buses).
fn push_lanes(lanes: &mut [Vec<ToServer>], replies: Vec<ToServer>) -> Result<()> {
    if replies.len() != lanes.len() {
        return Err(anyhow!("worker replied on {} of {} lanes", replies.len(), lanes.len()));
    }
    for (lane, r) in lanes.iter_mut().zip(replies) {
        lane.push(r);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// in-process buses
// ---------------------------------------------------------------------------

/// Deterministic in-process "network": the trainer broadcasts by calling
/// each worker in worker-id order and gathers the replies. Fault
/// injection lives in [`crate::elastic::ChaosTransport`], which wraps
/// this bus (or any other) — the bus itself is a faithful wire.
#[derive(Default)]
pub struct LocalBus;

impl LocalBus {
    pub fn round(
        &self,
        broadcast: &ToWorker,
        workers: &mut [super::worker::Worker],
    ) -> Result<Vec<ToServer>> {
        let mut replies = Vec::with_capacity(workers.len());
        for w in workers.iter_mut() {
            if let Some(reply) = w.handle(broadcast)? {
                replies.push(reply);
            }
        }
        Ok(replies)
    }
}

impl Transport for LocalBus {
    fn round(
        &mut self,
        broadcast: &ToWorker,
        workers: &mut [super::worker::Worker],
    ) -> Result<Vec<ToServer>> {
        LocalBus::round(self, broadcast, workers)
    }

    /// Sharded lanes, sequentially: workers are stepped in worker-id
    /// order, each handling all lanes of the round at once
    /// ([`super::worker::Worker::handle_sharded`]); a single-lane call
    /// is byte-identical to [`Transport::round`].
    fn round_sharded(
        &mut self,
        broadcasts: &[ToWorker],
        workers: &mut [super::worker::Worker],
    ) -> Result<Vec<Vec<ToServer>>> {
        let mut lanes: Vec<Vec<ToServer>> =
            (0..broadcasts.len()).map(|_| Vec::with_capacity(workers.len())).collect();
        for w in workers.iter_mut() {
            if let Some(replies) = w.handle_sharded(broadcasts)? {
                push_lanes(&mut lanes, replies)?;
            }
        }
        Ok(lanes)
    }

    fn name(&self) -> &'static str {
        "local-sequential"
    }
}

/// Parallel in-process bus: one scoped thread per worker, deterministic
/// merge in worker-id order.
///
/// Each [`super::worker::Worker`] owns all of its mutable state (opt
/// moments, EF residual, rng, decode buffer), gradient sources are
/// deterministic in `(worker, t)`, and the merge order is fixed — so a
/// `ThreadedBus` round is bit-identical to a [`LocalBus`] round over
/// the same workers, just `min(nworkers, cores)` times faster on the
/// worker-compute half of the round.
#[derive(Default)]
pub struct ThreadedBus;

impl ThreadedBus {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn round(
        &self,
        broadcast: &ToWorker,
        workers: &mut [super::worker::Worker],
    ) -> Result<Vec<ToServer>> {
        // Spawn in worker order, join in worker order: the gather is
        // deterministic no matter how the OS schedules the threads.
        let results: Vec<Result<Option<ToServer>>> = std::thread::scope(|s| {
            let handles: Vec<_> =
                workers.iter_mut().map(|w| s.spawn(move || w.handle(broadcast))).collect();
            handles
                .into_iter()
                .enumerate()
                .map(|(i, h)| {
                    h.join().unwrap_or_else(|payload| {
                        // keep the diagnostic the sequential engine would
                        // have printed
                        let msg = payload
                            .downcast_ref::<&str>()
                            .map(|s| s.to_string())
                            .or_else(|| payload.downcast_ref::<String>().cloned())
                            .unwrap_or_else(|| "non-string panic payload".into());
                        Err(anyhow!("worker thread {i} panicked: {msg}"))
                    })
                })
                .collect()
        });
        let mut replies = Vec::with_capacity(results.len());
        for r in results {
            if let Some(reply) = r? {
                replies.push(reply);
            }
        }
        Ok(replies)
    }
}

impl Transport for ThreadedBus {
    fn round(
        &mut self,
        broadcast: &ToWorker,
        workers: &mut [super::worker::Worker],
    ) -> Result<Vec<ToServer>> {
        ThreadedBus::round(self, broadcast, workers)
    }

    /// Sharded lanes, one scoped thread per worker (the worker handles
    /// all its lanes on its own thread), merged in worker-id order —
    /// bit-identical to the sequential lanes.
    fn round_sharded(
        &mut self,
        broadcasts: &[ToWorker],
        workers: &mut [super::worker::Worker],
    ) -> Result<Vec<Vec<ToServer>>> {
        let results: Vec<Result<Option<Vec<ToServer>>>> = std::thread::scope(|s| {
            let handles: Vec<_> = workers
                .iter_mut()
                .map(|w| s.spawn(move || w.handle_sharded(broadcasts)))
                .collect();
            handles
                .into_iter()
                .enumerate()
                .map(|(i, h)| {
                    h.join().unwrap_or_else(|payload| {
                        let msg = payload
                            .downcast_ref::<&str>()
                            .map(|s| s.to_string())
                            .or_else(|| payload.downcast_ref::<String>().cloned())
                            .unwrap_or_else(|| "non-string panic payload".into());
                        Err(anyhow!("worker thread {i} panicked: {msg}"))
                    })
                })
                .collect()
        });
        let mut lanes: Vec<Vec<ToServer>> =
            (0..broadcasts.len()).map(|_| Vec::with_capacity(results.len())).collect();
        for r in results {
            if let Some(replies) = r? {
                push_lanes(&mut lanes, replies)?;
            }
        }
        Ok(lanes)
    }

    fn name(&self) -> &'static str {
        "local-threaded"
    }
}

// ---------------------------------------------------------------------------
// TCP deployment
// ---------------------------------------------------------------------------

/// Server side of the TCP deployment: accepts `n` workers, then drives
/// synchronous rounds (broadcast → gather).
///
/// **Elastic rounds** ([`TcpServer::set_elastic`]). Under the default
/// [`StragglerPolicy::Wait`] the round blocks until every connection
/// replies and any I/O error fails the round — exactly the seed
/// behavior, bit-identical to it. Under [`StragglerPolicy::Drop`] the
/// gather runs against the per-round deadline: a worker that misses it
/// — or whose connection dies mid-round — counts as a dropped reply and
/// is **evicted** (its socket is closed, so a late reply can never
/// desynchronize the frame stream), and the round fails only below the
/// `min_participation` quorum. The listener stays open: an evicted or
/// freshly started worker reconnects, [`TcpServer::membership`] accepts
/// it between rounds and reports `rejoined = true`, and the driver
/// forces a full-weights resync so a delta-downlink replica can never
/// diverge across the drop/rejoin cycle.
pub struct TcpServer {
    listener: TcpListener,
    streams: Vec<TcpStream>,
    /// The worker id each connection last claimed, aligned with
    /// `streams` (`None` until the connection's first reply — a
    /// connection identifies itself by replying, not by connecting).
    /// This is what lets a shard group intersect per-lane worker sets
    /// instead of guessing from connection counts.
    ids: Vec<Option<u32>>,
    /// Worker slots the deployment was sized for (the rejoin cap).
    capacity: usize,
    deadline: Option<Duration>,
    policy: StragglerPolicy,
    min_participation: usize,
    /// Async (bounded-staleness) rounds: the gather harvests only the
    /// replies already on the wire and leaves quiet connections
    /// untouched — their replies surface in later rounds as stale
    /// deltas for `ParameterServer::apply_async`.
    async_gather: bool,
    /// Cumulative connections evicted (dead at broadcast, or past the
    /// straggler deadline at gather) — the obs accounting tap.
    evicted: u64,
}

impl TcpServer {
    pub fn bind_and_accept(addr: &str, nworkers: usize) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        eprintln!("[server] listening on {addr}, waiting for {nworkers} workers");
        let mut streams = Vec::with_capacity(nworkers);
        for i in 0..nworkers {
            let (s, peer) = listener.accept()?;
            s.set_nodelay(true)?;
            eprintln!("[server] worker {i} connected from {peer}");
            streams.push(s);
        }
        // Rejoin polling must never block the round loop.
        listener.set_nonblocking(true)?;
        let ids = vec![None; streams.len()];
        Ok(Self {
            listener,
            streams,
            ids,
            capacity: nworkers,
            deadline: None,
            policy: StragglerPolicy::Wait,
            min_participation: 1,
            async_gather: false,
            evicted: 0,
        })
    }

    /// Configure the elastic round: under [`StragglerPolicy::Drop`] the
    /// gather stops at `deadline_ms` (`None` = wait for live peers, but
    /// still drop dead connections) and fails below the
    /// `min_participation` quorum. [`StragglerPolicy::Wait`] ignores
    /// both and keeps the seed behavior.
    pub fn set_elastic(
        &mut self,
        deadline_ms: Option<u64>,
        policy: StragglerPolicy,
        min_participation: usize,
    ) {
        self.deadline = deadline_ms.map(Duration::from_millis);
        self.policy = policy;
        self.min_participation = min_participation.max(1);
    }

    /// Switch the gather to **async (bounded-staleness) rounds**: it
    /// harvests one reply from every connection that already has bytes
    /// queued (or produces them within the poll window) and leaves
    /// quiet connections alone — no eviction, no quorum; an empty
    /// harvest is a legal round. A slow worker's reply stays in its
    /// stream and surfaces on a later tick carrying its original round
    /// tag, for `ParameterServer::apply_async` to admit (within `τ`) or
    /// reject. Only a genuinely dead connection (EOF / hard error) is
    /// evicted. The straggler deadline, when set, doubles as the poll
    /// window.
    pub fn set_async(&mut self, on: bool) {
        self.async_gather = on;
    }

    /// The worker id each live connection last claimed, aligned with
    /// the connection order (`None` = no reply seen yet).
    pub fn lane_ids(&self) -> &[Option<u32>] {
        &self.ids
    }

    pub fn nworkers(&self) -> usize {
        self.streams.len()
    }

    /// Accept any workers waiting to (re)join, up to capacity. Call
    /// between rounds; when it reports `rejoined`, force a full-weights
    /// resync before the next broadcast (`ParameterServer::force_resync`)
    /// — the joiner has no (or a stale) replica.
    pub fn membership(&mut self) -> Membership {
        let mut rejoined = false;
        while self.streams.len() < self.capacity {
            match self.listener.accept() {
                Ok((s, peer)) => {
                    let _ = s.set_nodelay(true);
                    eprintln!("[server] worker rejoined from {peer}");
                    self.streams.push(s);
                    self.ids.push(None); // identifies itself at its first reply
                    rejoined = true;
                }
                Err(_) => break, // WouldBlock: nobody waiting
            }
        }
        Membership { expected: self.capacity, present: self.streams.len(), rejoined }
    }

    /// One synchronous round over TCP. Replies are sorted by worker id
    /// after the gather: connection-accept order races the workers'
    /// startup, and the [`Transport`] contract requires the merge order
    /// (and hence the server's float summation order) to be independent
    /// of scheduling. Two connections claiming the same worker id are a
    /// deployment error (the mean would double-weight that worker) and
    /// fail the round under either policy.
    pub fn round(&mut self, broadcast: &ToWorker) -> Result<Vec<ToServer>> {
        self.send_broadcast(broadcast)?;
        self.gather()
    }

    /// The broadcast half of a round: ship the frame to every live
    /// connection. Split from [`Self::gather`] so a multi-shard driver
    /// ([`TcpShardGroup`]) can put every lane's frame on the wire
    /// before any lane blocks in its gather — a sharded worker replies
    /// only after it has read *all* of its lanes' frames, so gathering
    /// lane 0 before sending lane 1 would deadlock. Under
    /// [`StragglerPolicy::Drop`] a connection that cannot be written to
    /// is dead and is evicted here.
    pub fn send_broadcast(&mut self, broadcast: &ToWorker) -> Result<()> {
        let payload = broadcast.to_bytes();
        match self.policy {
            StragglerPolicy::Wait => {
                for s in &mut self.streams {
                    write_frame(s, &payload)?;
                }
            }
            StragglerPolicy::Drop => {
                let mut live = Vec::with_capacity(self.streams.len());
                let mut live_ids = Vec::with_capacity(self.ids.len());
                let taken = std::mem::take(&mut self.streams);
                let taken_ids = std::mem::take(&mut self.ids);
                for (mut s, id) in taken.into_iter().zip(taken_ids) {
                    // A connection we cannot even send to is dead: evict
                    // it and treat its reply as dropped.
                    if write_frame(&mut s, &payload).is_ok() {
                        live.push(s);
                        live_ids.push(id);
                    } else {
                        self.evicted += 1;
                        eprintln!("[server] dropping dead connection at broadcast");
                    }
                }
                self.streams = live;
                self.ids = live_ids;
            }
        }
        Ok(())
    }

    /// Arm this round's straggler budget: `(gather start, deadline)`,
    /// or `None` when no deadline is configured. This is the **only**
    /// clock read in the transport — [`Self::gather`] arms its own
    /// budget, and [`TcpShardGroup::round_sharded`] arms **one** budget
    /// and shares it across every lane's gather, so a sharded round's
    /// worst case is one deadline total, not one per lane.
    // lint: allow(INV-DET) the straggler deadline is wall-clock by design; what
    // a round computes from the replies it keeps stays deterministic
    fn arm_deadline(&self) -> Option<(Instant, Duration)> {
        self.deadline.map(|d| (Instant::now(), d))
    }

    /// The gather half of a round (sorted, duplicate-checked, quorum-
    /// checked). Under [`StragglerPolicy::Drop`] the round deadline is
    /// armed when the gather starts; a straggler past it — or a dead
    /// connection — is evicted (its socket closes with the drop, so a
    /// late reply can never desync the frame stream; the worker
    /// reconnects and rejoins through the resync path). In async mode
    /// ([`Self::set_async`]) the gather is non-evicting: see
    /// [`Self::gather_available`].
    pub fn gather(&mut self) -> Result<Vec<ToServer>> {
        let budget = self.arm_deadline();
        self.gather_with(budget)
    }

    /// [`Self::gather`] against a caller-supplied straggler budget —
    /// the shard-group entry point, so N lanes can draw down one shared
    /// `(start, deadline)` pair instead of arming N consecutive ones.
    fn gather_with(&mut self, budget: Option<(Instant, Duration)>) -> Result<Vec<ToServer>> {
        let mut replies = if self.async_gather {
            self.gather_available(budget)?
        } else {
            match self.policy {
                StragglerPolicy::Wait => {
                    let mut replies = Vec::with_capacity(self.streams.len());
                    for (i, s) in self.streams.iter_mut().enumerate() {
                        let buf = read_frame(s)?;
                        let r = ToServer::from_bytes(&buf)?;
                        self.ids[i] = Some(r.worker());
                        replies.push(r);
                    }
                    replies
                }
                StragglerPolicy::Drop => {
                    let mut replies = Vec::with_capacity(self.streams.len());
                    let taken = std::mem::take(&mut self.streams);
                    let taken_ids = std::mem::take(&mut self.ids);
                    for (mut s, _id) in taken.into_iter().zip(taken_ids) {
                        match read_reply(&mut s, budget) {
                            Ok(r) => {
                                self.ids.push(Some(r.worker()));
                                replies.push(r);
                                self.streams.push(s);
                            }
                            Err(e) => {
                                self.evicted += 1;
                                eprintln!("[server] dropping straggler/dead connection: {e}");
                            }
                        }
                    }
                    replies
                }
            }
        };
        replies.sort_by_key(worker_id);
        if let Some(pair) = replies.windows(2).find(|p| worker_id(&p[0]) == worker_id(&p[1])) {
            return Err(anyhow!(
                "duplicate reply from worker {} (two connections share one id)",
                worker_id(&pair[0])
            ));
        }
        if !self.async_gather
            && self.policy == StragglerPolicy::Drop
            && replies.len() < self.min_participation
        {
            return Err(anyhow!(
                "round below quorum: {} of {} replies, need {}",
                replies.len(),
                self.capacity,
                self.min_participation
            ));
        }
        Ok(replies)
    }

    /// The async harvest: one reply from every connection with bytes
    /// already queued (or arriving within the poll window); quiet
    /// connections keep their socket and their in-flight reply — it
    /// surfaces on a later tick as a stale delta. Eviction is reserved
    /// for genuinely dead connections (EOF / hard error), never for
    /// slowness: the bounded-staleness admission rule, not the
    /// transport, decides what a late reply is worth.
    fn gather_available(
        &mut self,
        budget: Option<(Instant, Duration)>,
    ) -> Result<Vec<ToServer>> {
        // The deadline (remaining budget, for shard groups) doubles as
        // the poll window; without one, a short fixed window keeps the
        // driver loop from spinning hot on a quiet fleet.
        let window = match budget {
            Some((start, d)) => {
                let left = d.saturating_sub(start.elapsed());
                if left.is_zero() { Duration::from_millis(1) } else { left }
            }
            None => Duration::from_millis(5),
        };
        let mut replies = Vec::with_capacity(self.streams.len());
        let taken = std::mem::take(&mut self.streams);
        let taken_ids = std::mem::take(&mut self.ids);
        for (mut s, id) in taken.into_iter().zip(taken_ids) {
            s.set_read_timeout(Some(window))?;
            let mut first = [0u8; 1];
            match s.peek(&mut first) {
                Ok(0) => {
                    self.evicted += 1;
                    eprintln!("[server] dropping dead connection (EOF) in async gather");
                }
                Ok(_) => {
                    // Bytes are queued: commit to the whole frame.
                    match read_reply(&mut s, budget) {
                        Ok(r) => {
                            self.ids.push(Some(r.worker()));
                            replies.push(r);
                            self.streams.push(s);
                        }
                        Err(e) => {
                            self.evicted += 1;
                            eprintln!("[server] dropping connection mid-frame in async gather: {e}");
                        }
                    }
                }
                Err(ref e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    // Quiet this tick: keep the connection and whatever
                    // it knows about its id.
                    self.streams.push(s);
                    self.ids.push(id);
                }
                Err(e) => {
                    self.evicted += 1;
                    eprintln!("[server] dropping dead connection in async gather: {e}");
                }
            }
        }
        Ok(replies)
    }

    pub fn shutdown(&mut self) -> Result<()> {
        let payload = ToWorker::Shutdown.to_bytes();
        for s in &mut self.streams {
            write_frame(s, &payload)?;
        }
        Ok(())
    }

    /// Cumulative evicted-connection count (see the `evicted` field).
    pub fn evictions(&self) -> u64 {
        self.evicted
    }
}

/// Read one reply frame within the round budget (`None` = block until
/// the peer replies or dies).
///
/// The budget is `(round start, deadline)` and is re-checked before
/// **every** recv: each syscall's timeout is the *remaining* wall-clock
/// budget, so a peer trickling one byte per timeout window cannot hold
/// the round open past the deadline — the total wait is bounded by the
/// deadline (plus a 1 ms drain grace per recv once exhausted, which
/// only ever extends the wait while bytes are actually arriving), not
/// by `deadline × reads`.
fn read_reply(s: &mut TcpStream, budget: Option<(Instant, Duration)>) -> Result<ToServer> {
    let (start, d) = match budget {
        Some(b) => b,
        None => {
            s.set_read_timeout(None)?;
            let buf = read_frame(s)?;
            return ToServer::from_bytes(&buf);
        }
    };
    let arm = |s: &mut TcpStream| -> Result<()> {
        let remaining = d.saturating_sub(start.elapsed());
        // An exhausted budget still grants a minimal drain window: a
        // reply already sitting in the socket buffer (e.g. on a later
        // lane of a shared-budget sharded gather) is harvested instead
        // of thrown away, while a peer with nothing queued times out
        // within the grace tick — the total stays bounded by the
        // deadline plus epsilon per connection, not deadline × lanes.
        let window =
            if remaining.is_zero() { Duration::from_millis(1) } else { remaining };
        s.set_read_timeout(Some(window))?;
        Ok(())
    };
    let mut len = [0u8; 4];
    let mut filled = 0usize;
    while filled < len.len() {
        arm(s)?;
        match s.read(&mut len[filled..]) {
            Ok(0) => return Err(anyhow!("connection closed mid-frame")),
            Ok(k) => filled += k,
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME_BYTES {
        return Err(anyhow!("frame too large: {n}"));
    }
    // Grow while reading (same rule as `read_frame`): a lying length
    // prefix costs us at most what the peer actually sends.
    let mut buf = Vec::with_capacity(n.min(1 << 20));
    let mut chunk = [0u8; 64 * 1024];
    while buf.len() < n {
        arm(s)?;
        let want = (n - buf.len()).min(chunk.len());
        match s.read(&mut chunk[..want]) {
            Ok(0) => return Err(anyhow!("short frame: {} of {n} bytes", buf.len())),
            Ok(k) => buf.extend_from_slice(&chunk[..k]),
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    ToServer::from_bytes(&buf)
}

impl Transport for TcpServer {
    /// The in-process `workers` slice is ignored: this transport's
    /// workers are remote processes.
    fn round(
        &mut self,
        broadcast: &ToWorker,
        _workers: &mut [super::worker::Worker],
    ) -> Result<Vec<ToServer>> {
        TcpServer::round(self, broadcast)
    }

    fn name(&self) -> &'static str {
        "tcp"
    }

    fn membership(&mut self, _next_t: u64, _total: usize) -> Membership {
        TcpServer::membership(self)
    }

    fn shutdown(&mut self) -> Result<()> {
        TcpServer::shutdown(self)
    }

    fn straggler_evictions(&self) -> u64 {
        self.evicted
    }
}

/// N shard lanes over TCP in one driver process: one [`TcpServer`]
/// (its own listener, its own worker connections) per parameter-server
/// shard, driven in lockstep. The sharded round puts **every** lane's
/// broadcast on the wire before any lane blocks in its gather — a
/// sharded worker replies only once it has read all of its lanes'
/// frames, so a send-then-gather-per-lane driver would deadlock.
///
/// Cross-host deployments run each lane as its own
/// `qadam serve --shard-id i/N` process instead (same wire bytes, no
/// shared driver); this type exists for single-driver deployments and
/// for the cross-engine parity suite.
pub struct TcpShardGroup {
    servers: Vec<TcpServer>,
}

impl TcpShardGroup {
    /// `servers[s]` carries shard `s`'s lane. Every server must have
    /// been accepted with the same worker capacity.
    pub fn new(servers: Vec<TcpServer>) -> Self {
        assert!(!servers.is_empty(), "shard group needs at least one lane");
        Self { servers }
    }

    pub fn nshards(&self) -> usize {
        self.servers.len()
    }

    /// Per-lane membership, in shard order — lanes rejoin
    /// independently, and a driver that sees `rejoined` on lane `s`
    /// only needs to force a resync on shard `s`
    /// (`ShardedServer::force_resync_shard`).
    pub fn shard_memberships(&mut self) -> Vec<Membership> {
        self.servers.iter_mut().map(|s| s.membership()).collect()
    }

    /// Switch every lane to async (bounded-staleness) gathers — see
    /// [`TcpServer::set_async`].
    pub fn set_async(&mut self, on: bool) {
        for srv in &mut self.servers {
            srv.set_async(on);
        }
    }

    /// One lockstep sharded round: broadcast on every lane, then gather
    /// every lane — against **one** shared straggler budget. The budget
    /// `(start, deadline)` is armed once, before the first gather, and
    /// every lane's reads draw down the same remaining wall-clock: a
    /// straggler that exhausts it on lane 0 has nothing left to stall
    /// lanes 1..N with, so the whole sharded round is bounded by one
    /// deadline, not by `nshards × deadline` (each lane arming its own
    /// budget was exactly that worst case).
    pub fn round_sharded(&mut self, broadcasts: &[ToWorker]) -> Result<Vec<Vec<ToServer>>> {
        if broadcasts.len() != self.servers.len() {
            return Err(anyhow!(
                "{} broadcast frames for {} shard lanes",
                broadcasts.len(),
                self.servers.len()
            ));
        }
        for (srv, b) in self.servers.iter_mut().zip(broadcasts) {
            srv.send_broadcast(b)?;
        }
        let budget = self.servers[0].arm_deadline();
        let mut lanes = Vec::with_capacity(self.servers.len());
        for srv in &mut self.servers {
            lanes.push(srv.gather_with(budget)?);
        }
        Ok(lanes)
    }

    pub fn shutdown(&mut self) -> Result<()> {
        for srv in &mut self.servers {
            srv.shutdown()?;
        }
        Ok(())
    }
}

impl Transport for TcpShardGroup {
    /// Single-lane rounds only make sense for a 1-shard group.
    fn round(
        &mut self,
        broadcast: &ToWorker,
        _workers: &mut [super::worker::Worker],
    ) -> Result<Vec<ToServer>> {
        if self.servers.len() != 1 {
            return Err(anyhow!("single-frame round on a {}-shard group", self.servers.len()));
        }
        self.servers[0].round(broadcast)
    }

    fn round_sharded(
        &mut self,
        broadcasts: &[ToWorker],
        _workers: &mut [super::worker::Worker],
    ) -> Result<Vec<Vec<ToServer>>> {
        TcpShardGroup::round_sharded(self, broadcasts)
    }

    fn name(&self) -> &'static str {
        "tcp-sharded"
    }

    /// Merged membership: a worker must be present on *every* lane to
    /// serve the round, so `present` is the size of the **intersection
    /// of the per-lane worker-id sets** — not the minimum of the lane
    /// counts, which silently miscounts when evictions are asymmetric
    /// (lane 0 keeping worker {0} and lane 1 keeping worker {1} has
    /// min-count 1 but zero workers able to serve a full round).
    /// Connections that have not identified themselves yet (no reply
    /// seen — a fresh accept or a pre-round fleet) cannot be
    /// attributed, so they fall back to the count rule: the minimum
    /// across lanes of each lane's unidentified-connection count is
    /// added on top. Any lane's rejoin raises the resync signal.
    /// Drivers wanting per-shard resyncs use
    /// [`TcpShardGroup::shard_memberships`] directly.
    fn membership(&mut self, _next_t: u64, _total: usize) -> Membership {
        let per_lane = self.shard_memberships();
        let mut known: Option<Vec<u32>> = None;
        let mut min_unknown = usize::MAX;
        for srv in &self.servers {
            let mut ids: Vec<u32> = srv.lane_ids().iter().filter_map(|&id| id).collect();
            ids.sort_unstable();
            min_unknown = min_unknown.min(srv.lane_ids().len() - ids.len());
            known = Some(match known {
                None => ids,
                Some(prev) => {
                    prev.into_iter().filter(|id| ids.binary_search(id).is_ok()).collect()
                }
            });
        }
        let present = known.map_or(0, |k| k.len())
            + if min_unknown == usize::MAX { 0 } else { min_unknown };
        Membership {
            expected: per_lane.iter().map(|m| m.expected).min().unwrap_or(0),
            present,
            rejoined: per_lane.iter().any(|m| m.rejoined),
        }
    }

    fn shutdown(&mut self) -> Result<()> {
        TcpShardGroup::shutdown(self)
    }

    fn straggler_evictions(&self) -> u64 {
        self.servers.iter().map(|s| s.evicted).sum()
    }
}

/// Worker side of the TCP deployment: connect and serve rounds until
/// Shutdown. The closure maps each weight broadcast to a delta reply.
pub fn tcp_worker_loop(
    addr: &str,
    worker: &mut super::worker::Worker,
) -> Result<u64> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    stream.set_nodelay(true)?;
    let mut rounds = 0u64;
    loop {
        let buf = read_frame(&mut stream)?;
        let msg = ToWorker::from_bytes(&buf)?;
        match worker.handle(&msg)? {
            None => return Ok(rounds),
            Some(reply) => {
                write_frame(&mut stream, &reply.to_bytes())?;
                rounds += 1;
            }
        }
    }
}

/// Connect to one shard lane, retrying for up to ~10 s. In a rolling
/// multi-shard deployment the worker routinely starts before some
/// shard's listener is up; giving up on lane `s` after lane `s−1`
/// already connected would strand a half-open worker slot on the
/// earlier shard's accept queue, so the retry must happen *per lane*,
/// inside the loop — not by restarting the whole connect sequence.
fn connect_lane(addr: &str) -> Result<TcpStream> {
    let mut last: Option<std::io::Error> = None;
    for _ in 0..500 {
        match TcpStream::connect(addr) {
            Ok(s) => {
                s.set_nodelay(true)?;
                return Ok(s);
            }
            Err(e) => {
                last = Some(e);
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
    Err(anyhow!(
        "connecting shard lane {addr}: {}",
        last.map(|e| e.to_string()).unwrap_or_else(|| "no attempt".into())
    ))
}

/// Worker side of a sharded TCP deployment: one connection per shard
/// listener (`addrs[s]` = shard `s`'s server), serving lockstep rounds
/// until any lane sends Shutdown. Each round fans the per-lane frame
/// reads out concurrently (every lane is its own FIFO stream, so
/// concurrent reads stay deterministic), assembles them through
/// [`super::worker::Worker::handle_sharded`] — the worker must carry
/// the matching `ShardPlan` ([`super::worker::Worker::set_shards`]) —
/// and routes each per-shard reply back on its lane. A single address
/// delegates to [`tcp_worker_loop`] (whose caller owns the retry, as
/// before — the seed behavior).
pub fn tcp_sharded_worker_loop(
    addrs: &[String],
    worker: &mut super::worker::Worker,
) -> Result<u64> {
    match addrs {
        [] => Err(anyhow!("no shard addresses")),
        [single] => tcp_worker_loop(single, worker),
        _ => {
            let mut streams = Vec::with_capacity(addrs.len());
            for addr in addrs {
                streams.push(connect_lane(addr)?);
            }
            let mut rounds = 0u64;
            loop {
                let results: Vec<Result<ToWorker>> = std::thread::scope(|scope| {
                    let handles: Vec<_> = streams
                        .iter_mut()
                        .map(|s| {
                            scope.spawn(move || -> Result<ToWorker> {
                                let buf = read_frame(s)?;
                                ToWorker::from_bytes(&buf)
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        .enumerate()
                        .map(|(i, h)| {
                            h.join().unwrap_or_else(|_| {
                                Err(anyhow!("shard lane {i} reader panicked"))
                            })
                        })
                        .collect()
                });
                let frames = results.into_iter().collect::<Result<Vec<ToWorker>>>()?;
                match worker.handle_sharded(&frames)? {
                    None => return Ok(rounds),
                    Some(replies) => {
                        for (s, reply) in streams.iter_mut().zip(&replies) {
                            write_frame(s, &reply.to_bytes())?;
                        }
                        rounds += 1;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{LrSchedule, QAdamEf};
    use crate::ps::worker::{SimGradSource, Worker};
    use crate::ps::ParameterServer;

    fn mk_worker(id: u32, dim: usize) -> Worker {
        let src = SimGradSource { problem: crate::sim::StochasticProblem::new(dim, 0.05, 9) };
        let opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.02 });
        Worker::new(id, Box::new(opt), Box::new(src), 1)
    }

    #[test]
    fn local_bus_synchronous_round() {
        let dim = 16;
        let mut ps = ParameterServer::new(vec![1.0; dim], None);
        let mut workers: Vec<Worker> = (0..4).map(|i| mk_worker(i, dim)).collect();
        let bus = LocalBus::default();
        for _ in 0..5 {
            let replies = {
                let (b, _w) = ps.broadcast(workers.len());
                bus.round(&b, &mut workers).unwrap()
            };
            assert_eq!(replies.len(), 4);
            ps.apply(&replies).unwrap();
        }
        assert_eq!(ps.stats.rounds, 5);
        assert!(ps.stats.up_bytes > 0 && ps.stats.down_bytes > 0);
    }

    // The fault-injection tests that used to live here (scheduled
    // reply drops on LocalBus/ThreadedBus) moved to
    // `crate::elastic::chaos`, onto the one `ChaosTransport` mechanism.

    /// Acceptance: ThreadedBus (+ sharded server) produces trajectories
    /// bit-identical to LocalBus (+ sequential server) over ≥50 rounds,
    /// checked at every round, with both gradient and weight
    /// quantization in play.
    #[test]
    fn threaded_bus_bit_identical_to_local_bus() {
        for &kx in &[None, Some(4u32)] {
            let dim = 96;
            let rounds = 60u64;
            let x0: Vec<f32> = (0..dim).map(|i| 0.3 + 0.01 * (i as f32).sin()).collect();
            // reference: sequential bus, unsharded server
            let mut ps_seq = ParameterServer::new(x0.clone(), kx);
            let mut ws_seq: Vec<Worker> = (0..4).map(|i| mk_worker(i, dim)).collect();
            let seq = LocalBus::default();
            // candidate: threaded bus, sharded server (ragged block on purpose)
            let mut ps_thr = ParameterServer::with_shards(x0, kx, 13, 4);
            let mut ws_thr: Vec<Worker> = (0..4).map(|i| mk_worker(i, dim)).collect();
            let thr = ThreadedBus::new();
            for t in 1..=rounds {
                let r_seq = {
                    let (b, _) = ps_seq.broadcast(4);
                    seq.round(&b, &mut ws_seq).unwrap()
                };
                ps_seq.apply(&r_seq).unwrap();
                let r_thr = {
                    let (b, _) = ps_thr.broadcast(4);
                    thr.round(&b, &mut ws_thr).unwrap()
                };
                ps_thr.apply(&r_thr).unwrap();
                assert_eq!(
                    ps_seq.master(),
                    ps_thr.master(),
                    "kx={kx:?} diverged at round {t}"
                );
            }
            assert_eq!(ps_seq.stats.up_bytes, ps_thr.stats.up_bytes);
            assert_eq!(ps_seq.stats.down_bytes, ps_thr.stats.down_bytes);
        }
    }

    #[test]
    fn transport_trait_is_object_safe_across_engines() {
        let dim = 8;
        let mut ps = ParameterServer::new(vec![0.5; dim], None);
        let mut workers: Vec<Worker> = (0..2).map(|i| mk_worker(i, dim)).collect();
        let mut buses: Vec<Box<dyn Transport>> =
            vec![Box::new(LocalBus::default()), Box::new(ThreadedBus::new())];
        for bus in buses.iter_mut() {
            let replies = {
                let (b, _) = ps.broadcast(2);
                bus.round(&b, &mut workers).unwrap()
            };
            assert_eq!(replies.len(), 2, "{}", bus.name());
            ps.apply(&replies).unwrap();
        }
    }

    #[test]
    fn read_frame_rejects_oversized_length_prefix() {
        // A length prefix just past the cap must be rejected before any
        // allocation of that size is attempted.
        let n = (MAX_FRAME_BYTES as u32) + 1;
        let mut bytes = n.to_le_bytes().to_vec();
        bytes.extend_from_slice(&[0u8; 16]);
        let mut cur = std::io::Cursor::new(bytes);
        let err = read_frame(&mut cur).unwrap_err();
        assert!(err.to_string().contains("frame too large"), "{err}");

        // exactly at the cap the length is accepted (then EOF errors out,
        // which is fine — we only care the cap itself is inclusive)
        let mut at_cap = std::io::Cursor::new((MAX_FRAME_BYTES as u32).to_le_bytes().to_vec());
        let err = read_frame(&mut at_cap).unwrap_err();
        assert!(!err.to_string().contains("frame too large"), "{err}");
    }

    #[test]
    fn frame_roundtrip_over_any_io() {
        let payload: Vec<u8> = (0..=255u8).collect();
        let mut wire = Vec::new();
        write_frame(&mut wire, &payload).unwrap();
        assert_eq!(wire.len(), 4 + payload.len());
        let mut cur = std::io::Cursor::new(wire);
        assert_eq!(read_frame(&mut cur).unwrap(), payload);
    }

    /// Acceptance (delta downlink): LocalBus and ThreadedBus produce
    /// bit-identical trajectories with compressed weight-delta
    /// broadcasts, and every worker's decoded view equals the server
    /// replica on every round.
    #[test]
    fn delta_downlink_parity_local_vs_threaded() {
        use crate::quant::LogQuant;
        let dim = 96;
        let x0: Vec<f32> = (0..dim).map(|i| 0.3 + 0.01 * (i as f32).sin()).collect();
        let mk_ps = |x0: Vec<f32>, block: usize, threads: usize| -> ParameterServer {
            let mut ps = ParameterServer::with_shards(x0, Some(4), block, threads);
            ps.enable_delta_downlink(Box::new(LogQuant::new(2)), 7);
            ps
        };
        let mut ps_seq = mk_ps(x0.clone(), crate::ps::server::DEFAULT_BLOCK, 1);
        let mut ws_seq: Vec<Worker> = (0..4).map(|i| mk_worker(i, dim)).collect();
        let seq = LocalBus::default();
        let mut ps_thr = mk_ps(x0, 13, 4);
        let mut ws_thr: Vec<Worker> = (0..4).map(|i| mk_worker(i, dim)).collect();
        let thr = ThreadedBus::new();
        for t in 1u64..=40 {
            let r_seq = {
                let (b, _) = ps_seq.broadcast(4);
                seq.round(&b, &mut ws_seq).unwrap()
            };
            ps_seq.apply(&r_seq).unwrap();
            let r_thr = {
                let (b, _) = ps_thr.broadcast(4);
                thr.round(&b, &mut ws_thr).unwrap()
            };
            ps_thr.apply(&r_thr).unwrap();
            assert_eq!(ps_seq.master(), ps_thr.master(), "diverged at round {t}");
            let (replica, _) = ps_seq.downlink_state().unwrap();
            for w in &ws_seq {
                assert_eq!(w.weights(), replica, "worker {} != replica at round {t}", w.id);
            }
            let (replica_thr, _) = ps_thr.downlink_state().unwrap();
            assert_eq!(replica, replica_thr, "round {t}");
        }
        assert_eq!(ps_seq.stats.down_bytes, ps_thr.stats.down_bytes);
        assert_eq!(ps_seq.stats.up_bytes, ps_thr.stats.up_bytes);
    }

    /// Acceptance (delta downlink over TCP): the TCP engine matches the
    /// LocalBus reference bit-for-bit — same masters, same replica,
    /// same byte accounting — across resync and delta frames.
    #[test]
    fn tcp_delta_downlink_matches_local_bus() {
        use crate::quant::LogQuant;
        let dim = 16;
        let rounds = 9u64; // crosses the resync at t=1 and t=5
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);

        let spawn_worker = |addr: String, id: u32| {
            std::thread::spawn(move || {
                let mut w = mk_worker(id, dim);
                for _ in 0..100 {
                    match tcp_worker_loop(&addr, &mut w) {
                        Ok(r) => return r,
                        Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
                    }
                }
                panic!("worker {id} never connected");
            })
        };
        let h1 = spawn_worker(addr.clone(), 0);
        let h2 = spawn_worker(addr.clone(), 1);

        let mk_ps = || -> ParameterServer {
            let mut ps = ParameterServer::new(vec![1.0; dim], None);
            ps.enable_delta_downlink(Box::new(LogQuant::new(2)), 4);
            ps
        };
        let mut srv = TcpServer::bind_and_accept(&addr, 2).unwrap();
        let mut ps_tcp = mk_ps();
        let mut ps_ref = mk_ps();
        let mut ws_ref: Vec<Worker> = (0..2).map(|i| mk_worker(i, dim)).collect();
        let bus = LocalBus::default();
        for t in 1..=rounds {
            let replies = {
                let (b, _) = ps_tcp.broadcast(2);
                srv.round(&b).unwrap()
            };
            ps_tcp.apply(&replies).unwrap();
            let r_ref = {
                let (b, _) = ps_ref.broadcast(2);
                bus.round(&b, &mut ws_ref).unwrap()
            };
            ps_ref.apply(&r_ref).unwrap();
            assert_eq!(ps_tcp.master(), ps_ref.master(), "tcp diverged at round {t}");
            assert_eq!(
                ps_tcp.downlink_state().unwrap().0,
                ps_ref.downlink_state().unwrap().0,
                "replica diverged at round {t}"
            );
        }
        assert_eq!(ps_tcp.stats.down_bytes, ps_ref.stats.down_bytes);
        assert_eq!(ps_tcp.stats.up_bytes, ps_ref.stats.up_bytes);
        srv.shutdown().unwrap();
        assert_eq!(h1.join().unwrap(), rounds);
        assert_eq!(h2.join().unwrap(), rounds);
    }

    /// Two connections claiming the same worker id must fail the round
    /// (satellite: the contract forbade duplicates but nothing checked).
    #[test]
    fn tcp_round_rejects_duplicate_worker_ids() {
        use crate::quant::{seeded_rng, Compressor, LogQuant};
        let dim = 4;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);

        // Two hand-rolled clients that both claim worker id 0.
        let mk_client = |addr: String| {
            std::thread::spawn(move || {
                for _ in 0..100 {
                    if let Ok(mut s) = std::net::TcpStream::connect(&addr) {
                        let _ = read_frame(&mut s); // the broadcast
                        let zeros = vec![0.0f32; dim];
                        let mut q = vec![0.0; dim];
                        let msg =
                            LogQuant::new(2).compress_into(&zeros, &mut q, &mut seeded_rng(0, 0));
                        let reply = ToServer::Delta { t: 1, worker: 0, loss: 0.0, msg };
                        let _ = write_frame(&mut s, &reply.to_bytes());
                        let _ = read_frame(&mut s); // hold until server exits
                        return;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                panic!("client never connected");
            })
        };
        let h1 = mk_client(addr.clone());
        let h2 = mk_client(addr.clone());
        let mut srv = TcpServer::bind_and_accept(&addr, 2).unwrap();
        let mut ps = ParameterServer::new(vec![0.0; dim], None);
        let err = {
            let (b, _) = ps.broadcast(2);
            srv.round(&b).unwrap_err()
        };
        assert!(err.to_string().contains("duplicate"), "{err}");
        drop(srv); // closes the streams, releasing the clients
        h1.join().unwrap();
        h2.join().unwrap();
    }

    #[test]
    fn tcp_roundtrip_two_workers() {
        let dim = 16;
        let addr = "127.0.0.1:0";
        let listener = std::net::TcpListener::bind(addr).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener); // free the port for bind_and_accept (tiny race, test-only)

        let addr2 = addr.clone();
        let h1 = std::thread::spawn(move || {
            let mut w = mk_worker(0, dim);
            // retry until server is up
            for _ in 0..100 {
                match tcp_worker_loop(&addr2, &mut w) {
                    Ok(r) => return r,
                    Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
                }
            }
            panic!("worker 0 never connected");
        });
        let addr3 = addr.clone();
        let h2 = std::thread::spawn(move || {
            let mut w = mk_worker(1, dim);
            for _ in 0..100 {
                match tcp_worker_loop(&addr3, &mut w) {
                    Ok(r) => return r,
                    Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
                }
            }
            panic!("worker 1 never connected");
        });

        let mut srv = TcpServer::bind_and_accept(&addr, 2).unwrap();
        let mut ps = ParameterServer::new(vec![1.0; dim], None);
        for _ in 0..3 {
            let (b, _) = ps.broadcast(2);
            let replies = srv.round(&b).unwrap();
            assert_eq!(replies.len(), 2);
            ps.apply(&replies).unwrap();
        }
        srv.shutdown().unwrap();
        assert_eq!(h1.join().unwrap(), 3);
        assert_eq!(h2.join().unwrap(), 3);
    }

    /// A hand-rolled TCP client driving a real [`Worker`]: serves
    /// `rounds` rounds, then drops the connection (a mid-run death).
    fn short_lived_client(
        addr: String,
        id: u32,
        dim: usize,
        rounds: u64,
    ) -> std::thread::JoinHandle<u64> {
        std::thread::spawn(move || {
            let mut stream = loop {
                match TcpStream::connect(&addr) {
                    Ok(s) => break s,
                    Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
                }
            };
            stream.set_nodelay(true).unwrap();
            let mut w = mk_worker(id, dim);
            let mut served = 0u64;
            while served < rounds {
                let buf = read_frame(&mut stream).unwrap();
                let msg = ToWorker::from_bytes(&buf).unwrap();
                match w.handle(&msg).unwrap() {
                    None => return served,
                    Some(reply) => {
                        write_frame(&mut stream, &reply.to_bytes()).unwrap();
                        served += 1;
                    }
                }
            }
            served // the stream drops here: connection dies mid-run
        })
    }

    /// Satellite: under `--straggler drop` a worker dying mid-round is a
    /// dropped reply, not a failed round — the run continues at quorum,
    /// and `down_bytes` is charged only for the workers actually in each
    /// round's membership.
    #[test]
    fn tcp_drop_policy_survives_mid_round_disconnect() {
        let dim = 16;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);

        // Worker 0 serves the whole run; worker 1 dies after two rounds.
        let addr0 = addr.clone();
        let h0 = std::thread::spawn(move || {
            let mut w = mk_worker(0, dim);
            for _ in 0..100 {
                match tcp_worker_loop(&addr0, &mut w) {
                    Ok(r) => return r,
                    Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
                }
            }
            panic!("worker 0 never connected");
        });
        let h1 = short_lived_client(addr.clone(), 1, dim, 2);

        let mut srv = TcpServer::bind_and_accept(&addr, 2).unwrap();
        srv.set_elastic(Some(3000), StragglerPolicy::Drop, 1);
        let mut ps = ParameterServer::new(vec![1.0; dim], None);
        let mut expected_down = 0u64;
        for t in 1u64..=6 {
            let m = srv.membership();
            let replies = {
                let (b, _) = ps.broadcast(m.present);
                expected_down += (b.wire_bytes() * m.present) as u64;
                srv.round(&b).unwrap()
            };
            let part = ps.apply(&replies).unwrap();
            if t <= 2 {
                assert_eq!(part.reporters, vec![0, 1], "t={t}");
            } else {
                assert_eq!(part.reporters, vec![0], "t={t}: dead worker 1 must be dropped");
            }
        }
        // After the eviction, broadcasts go (and are charged) to one
        // worker only.
        assert_eq!(ps.stats.down_bytes, expected_down);
        assert_eq!(srv.nworkers(), 1);
        srv.shutdown().unwrap();
        assert_eq!(h0.join().unwrap(), 6);
        assert_eq!(h1.join().unwrap(), 2);
    }

    /// A worker that died and comes back rejoins through
    /// [`TcpServer::membership`] and is re-anchored by a forced full-
    /// weights resync — so delta-downlink replicas survive a
    /// drop/rejoin cycle (the joiner would otherwise fail on its first
    /// delta frame).
    #[test]
    fn tcp_rejoin_after_eviction_gets_resync() {
        use crate::quant::LogQuant;
        let dim = 16;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);

        let addr0 = addr.clone();
        let h0 = std::thread::spawn(move || {
            let mut w = mk_worker(0, dim);
            for _ in 0..100 {
                match tcp_worker_loop(&addr0, &mut w) {
                    Ok(r) => return r,
                    Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
                }
            }
            panic!("worker 0 never connected");
        });
        // First incarnation of worker 1: two rounds, then death.
        let h1 = short_lived_client(addr.clone(), 1, dim, 2);

        let mut srv = TcpServer::bind_and_accept(&addr, 2).unwrap();
        srv.set_elastic(Some(3000), StragglerPolicy::Drop, 1);
        let mut ps = ParameterServer::new(vec![1.0; dim], None);
        ps.enable_delta_downlink(Box::new(LogQuant::new(2)), 0); // resync: round 1 / forced only

        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let mut h2 = None;
        for t in 1u64..=8 {
            if t == 5 {
                // Second incarnation of worker 1: a fresh process with
                // no replica. Wait until its connect has landed so the
                // rejoin is deterministic.
                let addr2 = addr.clone();
                let tx = tx.clone();
                h2 = Some(std::thread::spawn(move || {
                    let mut stream = TcpStream::connect(&addr2).unwrap();
                    stream.set_nodelay(true).unwrap();
                    tx.send(()).unwrap();
                    let mut w = mk_worker(1, dim);
                    let mut served = 0u64;
                    loop {
                        let buf = read_frame(&mut stream).unwrap();
                        let msg = ToWorker::from_bytes(&buf).unwrap();
                        match w.handle(&msg).unwrap() {
                            None => return served,
                            Some(reply) => {
                                write_frame(&mut stream, &reply.to_bytes()).unwrap();
                                served += 1;
                            }
                        }
                    }
                }));
                rx.recv().unwrap();
            }
            let m = srv.membership();
            if m.rejoined {
                ps.force_resync();
            }
            assert_eq!(m.rejoined, t == 5, "t={t}");
            let replies = {
                let (b, _) = ps.broadcast(m.present);
                match t {
                    1 | 5 => assert!(matches!(b, ToWorker::Weights { .. }), "t={t}"),
                    _ => assert!(matches!(b, ToWorker::WeightsDelta { .. }), "t={t}"),
                }
                srv.round(&b).unwrap()
            };
            let part = ps.apply(&replies).unwrap();
            match t {
                1 | 2 => assert_eq!(part.reporters, vec![0, 1], "t={t}"),
                3 | 4 => assert_eq!(part.reporters, vec![0], "t={t}"),
                _ => assert_eq!(part.reporters, vec![0, 1], "t={t}: rejoined worker must serve"),
            }
        }
        srv.shutdown().unwrap();
        assert_eq!(h0.join().unwrap(), 8);
        assert_eq!(h1.join().unwrap(), 2);
        assert_eq!(h2.unwrap().join().unwrap(), 4, "rejoined worker serves rounds 5..=8");
    }

    /// Build a lane whose connections claim the given worker ids
    /// (`None` = not yet identified), plus the client-side sockets that
    /// keep the connections alive.
    fn lane_with_ids(ids: Vec<Option<u32>>, capacity: usize) -> (TcpServer, Vec<TcpStream>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut clients = Vec::new();
        let mut streams = Vec::new();
        for _ in &ids {
            clients.push(TcpStream::connect(addr).unwrap());
            let (s, _) = listener.accept().unwrap();
            streams.push(s);
        }
        listener.set_nonblocking(true).unwrap();
        let srv = TcpServer {
            listener,
            streams,
            ids,
            capacity,
            deadline: None,
            policy: StragglerPolicy::Drop,
            min_participation: 1,
            async_gather: false,
            evicted: 0,
        };
        (srv, clients)
    }

    /// Regression (satellite): merged shard-group membership must
    /// intersect the per-lane worker-id sets. With asymmetric eviction
    /// — lane 0 keeps only worker 0, lane 1 keeps only worker 1 — the
    /// old min-over-counts rule reported `present = 1`, but **zero**
    /// workers can serve a full sharded round.
    #[test]
    fn tcp_sharded_membership_intersects_per_lane_worker_sets() {
        let (s0, _c0) = lane_with_ids(vec![Some(0)], 2);
        let (s1, _c1) = lane_with_ids(vec![Some(1)], 2);
        let mut group = TcpShardGroup::new(vec![s0, s1]);
        let m = Transport::membership(&mut group, 1, 2);
        assert_eq!(m.expected, 2);
        assert_eq!(m.present, 0, "disjoint per-lane survivor sets share no worker");
        assert!(!m.rejoined);

        // Overlapping sets count exactly the common workers.
        let (s0, _c0) = lane_with_ids(vec![Some(0), Some(1)], 2);
        let (s1, _c1) = lane_with_ids(vec![Some(1)], 2);
        let mut group = TcpShardGroup::new(vec![s0, s1]);
        assert_eq!(Transport::membership(&mut group, 1, 2).present, 1);

        // Unidentified connections (no reply seen yet) fall back to the
        // min-count rule — a pre-round fleet is still fully present.
        let (s0, _c0) = lane_with_ids(vec![None, None], 2);
        let (s1, _c1) = lane_with_ids(vec![None, None], 2);
        let mut group = TcpShardGroup::new(vec![s0, s1]);
        assert_eq!(Transport::membership(&mut group, 1, 2).present, 2);

        // Mixed: one known shared worker plus one unidentified slot on
        // each lane.
        let (s0, _c0) = lane_with_ids(vec![Some(0), None], 2);
        let (s1, _c1) = lane_with_ids(vec![Some(0), None], 2);
        let mut group = TcpShardGroup::new(vec![s0, s1]);
        assert_eq!(Transport::membership(&mut group, 1, 2).present, 2);
    }

    /// A scripted client for deadline/async tests: serves canned Delta
    /// replies (worker `id`, round tags from `ts`) after reading each
    /// broadcast; a `None` entry reads the frame but never replies that
    /// round.
    fn scripted_client(
        addr: String,
        id: u32,
        dim: usize,
        script: Vec<Option<u64>>,
        hold_ms: u64,
    ) -> std::thread::JoinHandle<()> {
        use crate::quant::{seeded_rng, Compressor, LogQuant};
        std::thread::spawn(move || {
            let mut s = loop {
                match TcpStream::connect(&addr) {
                    Ok(s) => break s,
                    Err(_) => std::thread::sleep(Duration::from_millis(10)),
                }
            };
            s.set_nodelay(true).unwrap();
            for step in script {
                let _ = read_frame(&mut s).expect("broadcast frame");
                if let Some(t) = step {
                    let zeros = vec![0.0f32; dim];
                    let mut q = vec![0.0; dim];
                    let msg =
                        LogQuant::new(2).compress_into(&zeros, &mut q, &mut seeded_rng(0, 0));
                    let reply = ToServer::Delta { t, worker: id, loss: 0.0, msg };
                    write_frame(&mut s, &reply.to_bytes()).unwrap();
                }
            }
            std::thread::sleep(Duration::from_millis(hold_ms));
        })
    }

    /// Regression (satellite): a sharded round shares **one** straggler
    /// budget across its lanes. With a silent worker on both lanes the
    /// round must finish in ~one deadline — the per-lane arming it
    /// replaces took `nshards × deadline`.
    #[test]
    fn tcp_sharded_round_shares_one_deadline_across_lanes() {
        let dim = 4;
        let mut addrs = Vec::new();
        for _ in 0..2 {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            addrs.push(l.local_addr().unwrap().to_string());
            drop(l);
        }
        // Worker 0 answers instantly on both lanes; worker 1 reads the
        // frames and stays silent past the deadline.
        let mut handles = Vec::new();
        for a in &addrs {
            handles.push(scripted_client(a.clone(), 0, dim, vec![Some(1)], 1500));
            handles.push(scripted_client(a.clone(), 1, dim, vec![None], 1500));
        }
        let mut lanes = Vec::new();
        for a in &addrs {
            let mut srv = TcpServer::bind_and_accept(a, 2).unwrap();
            srv.set_elastic(Some(400), StragglerPolicy::Drop, 1);
            lanes.push(srv);
        }
        let mut group = TcpShardGroup::new(lanes);
        let frames: Vec<ToWorker> = (0..2)
            .map(|_| {
                let mut ps = ParameterServer::new(vec![1.0; dim], None);
                let (b, _) = ps.broadcast(2);
                b
            })
            .collect();
        let t0 = std::time::Instant::now();
        let lanes = group.round_sharded(&frames).unwrap();
        let elapsed = t0.elapsed();
        for lane in &lanes {
            assert_eq!(lane.len(), 1, "only the live worker replies");
            assert_eq!(lane[0].worker(), 0);
        }
        assert_eq!(group.straggler_evictions(), 2, "the silent worker is evicted per lane");
        assert!(
            elapsed < Duration::from_millis(700),
            "2 lanes must share one 400ms deadline, took {elapsed:?}"
        );
        for h in handles {
            h.join().unwrap();
        }
    }

    /// Async gathers harvest what is on the wire and never evict a
    /// quiet connection: a worker replying one round late stays
    /// connected and its reply surfaces on the next tick still carrying
    /// its original round tag — the input `apply_async` admits within
    /// `τ` or refunds into error feedback.
    #[test]
    fn tcp_async_gather_leaves_quiet_streams_connected() {
        let dim = 4;
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        drop(l);
        // Worker 0 answers every round on time. Worker 1 reads rounds 1
        // and 2, then sends its round-1 and round-2 replies back to
        // back — so its round-1 reply arrives during the round-2 gather
        // and its round-2 reply during the round-3 gather.
        let h0 = scripted_client(addr.clone(), 0, dim, vec![Some(1), Some(2), Some(3)], 500);
        let a1 = addr.clone();
        let h1 = std::thread::spawn(move || {
            use crate::quant::{seeded_rng, Compressor, LogQuant};
            let mut s = loop {
                match TcpStream::connect(&a1) {
                    Ok(s) => break s,
                    Err(_) => std::thread::sleep(Duration::from_millis(10)),
                }
            };
            s.set_nodelay(true).unwrap();
            let reply = |t: u64| {
                let zeros = vec![0.0f32; dim];
                let mut q = vec![0.0; dim];
                let msg = LogQuant::new(2).compress_into(&zeros, &mut q, &mut seeded_rng(0, 0));
                ToServer::Delta { t, worker: 1, loss: 0.0, msg }
            };
            let _ = read_frame(&mut s).unwrap(); // round 1 frame, no reply yet
            let _ = read_frame(&mut s).unwrap(); // round 2 frame
            write_frame(&mut s, &reply(1).to_bytes()).unwrap();
            write_frame(&mut s, &reply(2).to_bytes()).unwrap();
            let _ = read_frame(&mut s).unwrap(); // round 3 frame
            std::thread::sleep(Duration::from_millis(500));
        });
        let mut srv = TcpServer::bind_and_accept(&addr, 2).unwrap();
        srv.set_elastic(Some(300), StragglerPolicy::Drop, 1);
        srv.set_async(true);
        let mut ps = ParameterServer::new(vec![1.0; dim], None);
        let mut per_round = Vec::new();
        for _ in 1..=3u64 {
            let (b, _) = ps.broadcast(2);
            let replies = srv.round(&b).unwrap();
            per_round.push(
                replies.iter().map(|r| (r.worker(), r.round())).collect::<Vec<_>>(),
            );
            assert_eq!(srv.nworkers(), 2, "a quiet stream must stay connected");
        }
        assert_eq!(per_round[0], vec![(0, 1)], "round 1: only the prompt worker");
        assert_eq!(per_round[1], vec![(0, 2), (1, 1)], "round 2: late round-1 reply surfaces");
        assert_eq!(per_round[2], vec![(0, 3), (1, 2)], "round 3: the next late reply");
        assert_eq!(srv.evictions(), 0, "async gathers never evict for slowness");
        h0.join().unwrap();
        h1.join().unwrap();
    }
}
