//! The parameter server (Algorithm 2), sharded.
//!
//! Keeps the master weights `x_t` in full precision; broadcasts
//! `Q_x(x_t)` (or raw fp32 when weight quantization is off); gathers
//! the workers' compressed deltas, decodes and averages them, and
//! applies `x_{t+1} = x_t − mean_i δ_t^{(i)}`.
//!
//! **Sharding.** The server state is processed in fixed-size blocks
//! (`block` coordinates each): delta decode, averaging, the apply, and
//! the `Q_x` broadcast re-quantization all run one block per task,
//! fanned out over `threads` scoped threads
//! ([`crate::util::par::par_tasks`]). Every per-coordinate operation is
//! independent and scales are indexed by global position
//! ([`crate::quant::decode_msg_range`]), so the blocked result is
//! **bit-identical** to the sequential one for any `(block, threads)` —
//! asserted by the tests below. `threads = 1` (the [`Self::new`]
//! default) keeps the seed behavior exactly.

use super::protocol::{CommStats, ToServer, ToWorker};
use crate::quant::{decode_msg_range, Compressor, Identity, WQuant, WireMsg};
use crate::util::par::par_tasks;
use anyhow::{anyhow, Result};

/// Default shard width: matches the AOT kernel chunk (64Ki f32 = 256 KB
/// per block buffer, comfortably L2-resident).
pub const DEFAULT_BLOCK: usize = 1 << 16;

pub struct ParameterServer {
    /// Full-precision master weights.
    x: Vec<f32>,
    /// Weight quantizer for broadcast / final output (None = fp32).
    wq: Option<WQuant>,
    /// Scratch: quantized broadcast weights.
    qx: Vec<f32>,
    /// Scratch: unpacked broadcast codes (WQuant path only).
    codes: Vec<u32>,
    /// Shard width in coordinates.
    block: usize,
    /// Worker threads for block-parallel passes (1 = sequential).
    threads: usize,
    pub stats: CommStats,
    t: u64,
}

impl ParameterServer {
    /// Sequential server (one thread, default block width) — the seed
    /// behavior, still the default for single-process tools.
    pub fn new(x0: Vec<f32>, kx: Option<u32>) -> Self {
        Self::with_shards(x0, kx, DEFAULT_BLOCK, 1)
    }

    /// Sharded server: state is processed `block` coordinates at a time
    /// across up to `threads` threads. Bit-identical to [`Self::new`]
    /// for every `(block, threads)` choice.
    pub fn with_shards(x0: Vec<f32>, kx: Option<u32>, block: usize, threads: usize) -> Self {
        assert!(block > 0, "shard block must be positive");
        let dim = x0.len();
        let wq = kx.map(WQuant::new);
        Self {
            qx: vec![0.0; dim],
            codes: if wq.is_some() { vec![0; dim] } else { Vec::new() },
            x: x0,
            wq,
            block,
            threads: threads.max(1),
            stats: CommStats::default(),
            t: 0,
        }
    }

    pub fn dim(&self) -> usize {
        self.x.len()
    }

    pub fn step(&self) -> u64 {
        self.t
    }

    /// Master (full-precision) weights.
    pub fn master(&self) -> &[f32] {
        &self.x
    }

    /// Restore (weights, step) from a checkpoint.
    pub fn restore(&mut self, x: &[f32], t: u64) {
        assert_eq!(x.len(), self.x.len());
        self.x.copy_from_slice(x);
        self.t = t;
    }

    /// What an edge device stores/serves: Q_x(x) when quantizing,
    /// else x (paper Alg. 2 "Output Q_x(x_t)").
    pub fn output_weights(&mut self) -> &[f32] {
        match self.wq {
            Some(wq) => {
                let x = &self.x;
                let tasks: Vec<(usize, &mut [f32])> = blocks(&mut self.qx, self.block);
                par_tasks(self.threads, tasks, |(start, qc)| {
                    wq.quantize_into(&x[start..start + qc.len()], qc);
                });
                &self.qx
            }
            None => &self.x,
        }
    }

    /// Begin round `t+1`: produce the broadcast message and the weight
    /// view workers must evaluate gradients at (Assumption 3: gradients
    /// are taken at `Q_x(x_t)`).
    pub fn broadcast(&mut self, nworkers: usize) -> (ToWorker, &[f32]) {
        self.broadcast_at_epoch(nworkers, 0)
    }

    /// [`Self::broadcast`] with an explicit epoch tag (drives the
    /// workers' ExpDecay schedules).
    pub fn broadcast_at_epoch(&mut self, nworkers: usize, epoch: u64) -> (ToWorker, &[f32]) {
        self.t += 1;
        let n = self.x.len();
        let msg: WireMsg = match self.wq {
            Some(wq) => {
                // Block-parallel re-quantization: each task fills its
                // slice of (qx, codes); the bit-pack stays serial (it is
                // a cheap, memory-bound tail next to the float math).
                let x = &self.x;
                let block = self.block;
                let tasks: Vec<(usize, &mut [f32], &mut [u32])> = self
                    .qx
                    .chunks_mut(block)
                    .zip(self.codes.chunks_mut(block))
                    .enumerate()
                    .map(|(i, (qc, cc))| (i * block, qc, cc))
                    .collect();
                par_tasks(self.threads, tasks, |(start, qc, cc)| {
                    wq.encode_into(&x[start..start + qc.len()], qc, cc);
                });
                wq.wire_msg(n, &self.codes)
            }
            None => {
                let mut rng = crate::quant::seeded_rng(0, self.t); // unused (Identity)
                Identity.compress_into(&self.x, &mut self.qx, &mut rng)
            }
        };
        let tw = ToWorker::Weights { t: self.t, epoch, msg };
        self.stats.down_bytes += (tw.wire_bytes() * nworkers) as u64;
        (tw, &self.qx)
    }

    /// Gather + apply one synchronous round of deltas (Alg. 2 lines 3–4).
    /// Returns the mean training loss reported by the workers.
    pub fn apply(&mut self, deltas: &[ToServer]) -> Result<f32> {
        if deltas.is_empty() {
            return Err(anyhow!("no deltas to apply"));
        }
        // Validate everything first, so a rejected round is fully
        // side-effect-free: no weight movement, no accounting drift.
        for d in deltas {
            let ToServer::Delta { t, msg, .. } = d;
            if *t != self.t {
                return Err(anyhow!("stale delta for t={t}, server at {}", self.t));
            }
            if msg.n != self.x.len() {
                return Err(anyhow!("delta dim {} != model dim {}", msg.n, self.x.len()));
            }
        }
        let n = deltas.len() as f32;
        let mut mean_loss = 0.0f32;
        for d in deltas {
            let ToServer::Delta { loss, .. } = d;
            mean_loss += loss / n;
            self.stats.up_bytes += d.wire_bytes() as u64;
        }
        // Block-parallel decode + average + apply. Per coordinate the
        // worker summation order is fixed (delta order == worker order),
        // so this is bit-identical to the sequential pass.
        let inv = 1.0 / n;
        let tasks: Vec<(usize, &mut [f32])> = blocks(&mut self.x, self.block);
        par_tasks(self.threads, tasks, |(start, xc)| {
            let len = xc.len();
            let mut scratch = vec![0.0f32; len];
            let mut acc = vec![0.0f32; len];
            for d in deltas {
                let ToServer::Delta { msg, .. } = d;
                decode_msg_range(msg, start, &mut scratch);
                for (a, &s) in acc.iter_mut().zip(&scratch) {
                    *a += s;
                }
            }
            for (xi, &a) in xc.iter_mut().zip(&acc) {
                *xi -= inv * a;
            }
        });
        self.stats.rounds += 1;
        Ok(mean_loss)
    }
}

/// Split a buffer into `(global offset, block)` tasks.
fn blocks(buf: &mut [f32], block: usize) -> Vec<(usize, &mut [f32])> {
    buf.chunks_mut(block).enumerate().map(|(i, c)| (i * block, c)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{seeded_rng, CodecId, Compressor, LogQuant};

    fn delta_msg(u: &[f32], kg: u32) -> WireMsg {
        let mut q = vec![0.0; u.len()];
        LogQuant::new(kg).compress_into(u, &mut q, &mut seeded_rng(0, 0))
    }

    #[test]
    fn applies_mean_of_decoded_deltas() {
        let mut ps = ParameterServer::new(vec![1.0; 4], None);
        let (_msg, w) = ps.broadcast(2);
        assert_eq!(w, &[1.0; 4]);
        // two workers send exact powers of two so quantization is exact
        let d1 = delta_msg(&[0.5, 0.5, 1.0, 0.0], 2);
        let d2 = delta_msg(&[1.0, 0.0, 1.0, 0.5], 2);
        let loss = ps
            .apply(&[
                ToServer::Delta { t: 1, worker: 0, loss: 2.0, msg: d1 },
                ToServer::Delta { t: 1, worker: 1, loss: 4.0, msg: d2 },
            ])
            .unwrap();
        assert_eq!(loss, 3.0);
        let want = [1.0 - 0.75, 1.0 - 0.25, 0.0, 1.0 - 0.25];
        for (a, b) in ps.master().iter().zip(want) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn broadcast_quantizes_weights() {
        let mut ps = ParameterServer::new(vec![0.13, -0.13, 0.0, 0.26], Some(2));
        let (tw, w) = ps.broadcast(1);
        assert_eq!(w, &[0.125, -0.125, 0.0, 0.25]);
        match tw {
            ToWorker::Weights { msg, .. } => assert_eq!(msg.codec, CodecId::WQuant),
            _ => panic!(),
        }
        // master stays full precision
        assert_eq!(ps.master(), &[0.13, -0.13, 0.0, 0.26]);
        // output is quantized
        assert_eq!(ps.output_weights(), &[0.125, -0.125, 0.0, 0.25]);
    }

    #[test]
    fn rejects_stale_or_misshapen() {
        let mut ps = ParameterServer::new(vec![0.0; 4], None);
        ps.broadcast(1);
        let bad_t = ToServer::Delta { t: 9, worker: 0, loss: 0.0, msg: delta_msg(&[0.0; 4], 1) };
        assert!(ps.apply(&[bad_t]).is_err());
        let bad_dim = ToServer::Delta { t: 1, worker: 0, loss: 0.0, msg: delta_msg(&[0.0; 3], 1) };
        assert!(ps.apply(&[bad_dim]).is_err());
        assert!(ps.apply(&[]).is_err());
    }

    #[test]
    fn accounting_accumulates() {
        let mut ps = ParameterServer::new(vec![0.0; 64], Some(6));
        let (tw, _) = ps.broadcast(8);
        assert_eq!(ps.stats.down_bytes, (tw.wire_bytes() * 8) as u64);
        let d = ToServer::Delta { t: 1, worker: 0, loss: 0.0, msg: delta_msg(&[0.0; 64], 2) };
        let up = d.wire_bytes() as u64;
        ps.apply(&[d]).unwrap();
        assert_eq!(ps.stats.up_bytes, up);
        assert_eq!(ps.stats.rounds, 1);
    }

    /// Acceptance: the sharded server (any block/thread split, including
    /// ragged tails) is bit-identical to the sequential one — weights,
    /// broadcast messages and byte accounting — over many rounds and
    /// mixed codecs.
    #[test]
    fn sharded_server_bit_identical_to_sequential() {
        use crate::quant::{Blockwise, TernGrad};
        let dim = 233; // prime-ish: every block width leaves a ragged tail
        let mk_x0 = || (0..dim).map(|i| 0.2 * ((i as f32) * 0.31).sin()).collect::<Vec<f32>>();
        let deltas_for = |t: u64| -> Vec<ToServer> {
            let mut rng = seeded_rng(7, t);
            let mk = |w: u32| -> Vec<f32> {
                (0..dim).map(|i| 0.01 * ((i as f32 + w as f32 * 3.7 + t as f32).cos())).collect()
            };
            let mut q = vec![0.0; dim];
            let m0 = LogQuant::new(2).compress_into(&mk(0), &mut q, &mut rng);
            let m1 = TernGrad.compress_into(&mk(1), &mut q, &mut rng);
            let m2 = Blockwise::new(13).compress_into(&mk(2), &mut q, &mut rng);
            vec![
                ToServer::Delta { t, worker: 0, loss: 1.0, msg: m0 },
                ToServer::Delta { t, worker: 1, loss: 2.0, msg: m1 },
                ToServer::Delta { t, worker: 2, loss: 3.0, msg: m2 },
            ]
        };
        for &kx in &[None, Some(6u32)] {
            let mut seq = ParameterServer::new(mk_x0(), kx);
            let mut configs = vec![
                ParameterServer::with_shards(mk_x0(), kx, 7, 4),
                ParameterServer::with_shards(mk_x0(), kx, 64, 3),
                ParameterServer::with_shards(mk_x0(), kx, 1024, 8),
            ];
            for t in 1u64..=20 {
                let (b_seq, _) = seq.broadcast(3);
                seq.apply(&deltas_for(t)).unwrap();
                for ps in configs.iter_mut() {
                    let (b, _) = ps.broadcast(3);
                    assert_eq!(b.to_bytes(), b_seq.to_bytes(), "kx={kx:?} t={t}");
                    ps.apply(&deltas_for(t)).unwrap();
                    assert_eq!(ps.master(), seq.master(), "kx={kx:?} t={t}");
                    assert_eq!(ps.stats.up_bytes, seq.stats.up_bytes);
                    assert_eq!(ps.stats.down_bytes, seq.stats.down_bytes);
                }
            }
        }
    }

    /// A failed apply must not move the weights, even with sharding.
    #[test]
    fn failed_apply_leaves_weights_untouched() {
        let mut ps = ParameterServer::with_shards(vec![1.0; 32], None, 8, 4);
        ps.broadcast(2);
        let good = ToServer::Delta { t: 1, worker: 0, loss: 0.0, msg: delta_msg(&[0.5; 32], 2) };
        let stale = ToServer::Delta { t: 7, worker: 1, loss: 0.0, msg: delta_msg(&[0.5; 32], 2) };
        assert!(ps.apply(&[good, stale]).is_err());
        assert_eq!(ps.master(), &[1.0; 32][..]);
    }
}
