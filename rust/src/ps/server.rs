//! The parameter server (Algorithm 2).
//!
//! Keeps the master weights `x_t` in full precision; broadcasts
//! `Q_x(x_t)` (or raw fp32 when weight quantization is off); gathers
//! the workers' compressed deltas, decodes and averages them, and
//! applies `x_{t+1} = x_t − mean_i δ_t^{(i)}`.

use super::protocol::{CommStats, ToServer, ToWorker};
use crate::quant::{decode_msg, Compressor, Identity, WQuant, WireMsg};
use anyhow::{anyhow, Result};

pub struct ParameterServer {
    /// Full-precision master weights.
    x: Vec<f32>,
    /// Weight quantizer for broadcast / final output (None = fp32).
    wq: Option<WQuant>,
    /// Scratch: quantized broadcast weights.
    qx: Vec<f32>,
    /// Scratch: decoded delta.
    scratch: Vec<f32>,
    pub stats: CommStats,
    t: u64,
}

impl ParameterServer {
    pub fn new(x0: Vec<f32>, kx: Option<u32>) -> Self {
        let dim = x0.len();
        Self {
            qx: vec![0.0; dim],
            scratch: vec![0.0; dim],
            x: x0,
            wq: kx.map(WQuant::new),
            stats: CommStats::default(),
            t: 0,
        }
    }

    pub fn dim(&self) -> usize {
        self.x.len()
    }

    pub fn step(&self) -> u64 {
        self.t
    }

    /// Master (full-precision) weights.
    pub fn master(&self) -> &[f32] {
        &self.x
    }

    /// Restore (weights, step) from a checkpoint.
    pub fn restore(&mut self, x: &[f32], t: u64) {
        assert_eq!(x.len(), self.x.len());
        self.x.copy_from_slice(x);
        self.t = t;
    }

    /// What an edge device stores/serves: Q_x(x) when quantizing,
    /// else x (paper Alg. 2 "Output Q_x(x_t)").
    pub fn output_weights(&mut self) -> &[f32] {
        match self.wq {
            Some(wq) => {
                wq.quantize_into(&self.x, &mut self.qx);
                &self.qx
            }
            None => &self.x,
        }
    }

    /// Begin round `t+1`: produce the broadcast message and the weight
    /// view workers must evaluate gradients at (Assumption 3: gradients
    /// are taken at `Q_x(x_t)`).
    pub fn broadcast(&mut self, nworkers: usize) -> (ToWorker, &[f32]) {
        self.broadcast_at_epoch(nworkers, 0)
    }

    /// [`Self::broadcast`] with an explicit epoch tag (drives the
    /// workers' ExpDecay schedules).
    pub fn broadcast_at_epoch(&mut self, nworkers: usize, epoch: u64) -> (ToWorker, &[f32]) {
        self.t += 1;
        let msg: WireMsg = match self.wq {
            Some(wq) => {
                let mut rng = crate::quant::seeded_rng(0, self.t); // unused (deterministic codec)
                let x = std::mem::take(&mut self.x);
                let m = wq.compress_into(&x, &mut self.qx, &mut rng);
                self.x = x;
                m
            }
            None => {
                let mut rng = crate::quant::seeded_rng(0, self.t);
                let x = std::mem::take(&mut self.x);
                let m = Identity.compress_into(&x, &mut self.qx, &mut rng);
                self.x = x;
                m
            }
        };
        let tw = ToWorker::Weights { t: self.t, epoch, msg };
        self.stats.down_bytes += (tw.wire_bytes() * nworkers) as u64;
        (tw, &self.qx)
    }

    /// Gather + apply one synchronous round of deltas (Alg. 2 lines 3–4).
    /// Returns the mean training loss reported by the workers.
    pub fn apply(&mut self, deltas: &[ToServer]) -> Result<f32> {
        if deltas.is_empty() {
            return Err(anyhow!("no deltas to apply"));
        }
        let n = deltas.len() as f32;
        let mut mean_loss = 0.0f32;
        // accumulate mean decoded delta into scratch
        let mut acc = vec![0.0f32; self.x.len()];
        for d in deltas {
            let ToServer::Delta { t, loss, msg, .. } = d;
            if *t != self.t {
                return Err(anyhow!("stale delta for t={t}, server at {}", self.t));
            }
            if msg.n != self.x.len() {
                return Err(anyhow!("delta dim {} != model dim {}", msg.n, self.x.len()));
            }
            decode_msg(msg, &mut self.scratch);
            for (a, &s) in acc.iter_mut().zip(&self.scratch) {
                *a += s;
            }
            mean_loss += loss / n;
            self.stats.up_bytes += d.wire_bytes() as u64;
        }
        let inv = 1.0 / n;
        for (xi, &a) in self.x.iter_mut().zip(&acc) {
            *xi -= inv * a;
        }
        self.stats.rounds += 1;
        Ok(mean_loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{seeded_rng, CodecId, LogQuant};

    fn delta_msg(u: &[f32], kg: u32) -> WireMsg {
        let mut q = vec![0.0; u.len()];
        LogQuant::new(kg).compress_into(u, &mut q, &mut seeded_rng(0, 0))
    }

    #[test]
    fn applies_mean_of_decoded_deltas() {
        let mut ps = ParameterServer::new(vec![1.0; 4], None);
        let (_msg, w) = ps.broadcast(2);
        assert_eq!(w, &[1.0; 4]);
        // two workers send exact powers of two so quantization is exact
        let d1 = delta_msg(&[0.5, 0.5, 1.0, 0.0], 2);
        let d2 = delta_msg(&[1.0, 0.0, 1.0, 0.5], 2);
        let loss = ps
            .apply(&[
                ToServer::Delta { t: 1, worker: 0, loss: 2.0, msg: d1 },
                ToServer::Delta { t: 1, worker: 1, loss: 4.0, msg: d2 },
            ])
            .unwrap();
        assert_eq!(loss, 3.0);
        let want = [1.0 - 0.75, 1.0 - 0.25, 0.0, 1.0 - 0.25];
        for (a, b) in ps.master().iter().zip(want) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn broadcast_quantizes_weights() {
        let mut ps = ParameterServer::new(vec![0.13, -0.13, 0.0, 0.26], Some(2));
        let (tw, w) = ps.broadcast(1);
        assert_eq!(w, &[0.125, -0.125, 0.0, 0.25]);
        match tw {
            ToWorker::Weights { msg, .. } => assert_eq!(msg.codec, CodecId::WQuant),
            _ => panic!(),
        }
        // master stays full precision
        assert_eq!(ps.master(), &[0.13, -0.13, 0.0, 0.26]);
        // output is quantized
        assert_eq!(ps.output_weights(), &[0.125, -0.125, 0.0, 0.25]);
    }

    #[test]
    fn rejects_stale_or_misshapen() {
        let mut ps = ParameterServer::new(vec![0.0; 4], None);
        ps.broadcast(1);
        let bad_t = ToServer::Delta { t: 9, worker: 0, loss: 0.0, msg: delta_msg(&[0.0; 4], 1) };
        assert!(ps.apply(&[bad_t]).is_err());
        let bad_dim = ToServer::Delta { t: 1, worker: 0, loss: 0.0, msg: delta_msg(&[0.0; 3], 1) };
        assert!(ps.apply(&[bad_dim]).is_err());
        assert!(ps.apply(&[]).is_err());
    }

    #[test]
    fn accounting_accumulates() {
        let mut ps = ParameterServer::new(vec![0.0; 64], Some(6));
        let (tw, _) = ps.broadcast(8);
        assert_eq!(ps.stats.down_bytes, (tw.wire_bytes() * 8) as u64);
        let d = ToServer::Delta { t: 1, worker: 0, loss: 0.0, msg: delta_msg(&[0.0; 64], 2) };
        let up = d.wire_bytes() as u64;
        ps.apply(&[d]).unwrap();
        assert_eq!(ps.stats.up_bytes, up);
        assert_eq!(ps.stats.rounds, 1);
    }
}
