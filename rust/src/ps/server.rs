//! The parameter server (Algorithm 2), block-parallel.
//!
//! Keeps the master weights `x_t` in full precision; broadcasts
//! `Q_x(x_t)` (or raw fp32 when weight quantization is off); gathers
//! the workers' compressed deltas, decodes and averages them, and
//! applies `x_{t+1} = x_t − mean_i δ_t^{(i)}`.
//!
//! **Sharding contract.** One [`ParameterServer`] owns one contiguous
//! range of the model — the *whole* vector in the unsharded (seed)
//! deployment, or one shard's range under the scale-out layer
//! ([`crate::ps::shard::ShardedServer`]), which composes N fully
//! independent instances. Everything in this file is per-instance
//! state: master weights, broadcast view, the delta-downlink replica
//! `x̂` + EF residual + resync schedule, the downlink policy
//! controller, and the [`CommStats`] accounting. Nothing here knows
//! about other shards.
//!
//! **Block-parallelism** (orthogonal to sharding): the instance's state
//! is processed in fixed-size blocks (`block` coordinates each): delta
//! decode, averaging, the apply, and the `Q_x` broadcast
//! re-quantization all run one block per task, fanned out over
//! `threads` scoped threads ([`crate::util::par::par_tasks`]). Every
//! per-coordinate operation is independent and scales are indexed by
//! global position ([`crate::quant::decode_msg_range`]), so the
//! blocked result is **bit-identical** to the sequential one for any
//! `(block, threads)` — asserted by the tests below. `threads = 1`
//! (the [`Self::new`] default) keeps the seed behavior exactly.
//!
//! **Delta downlink** ([`ParameterServer::enable_delta_downlink`]). The uplink has
//! always been compressed; by default the downlink still ships the full
//! `Q_x(x_t)` codes (or raw fp32) every round. In delta mode the server
//! mirrors the worker-side error feedback (Efficient-Adam, Chen et al.
//! 2022): it keeps a worker-replica estimate `x̂` plus its own
//! [`ErrorFeedback`] residual `e`, and broadcasts
//! `ToWorker::WeightsDelta { msg = Q_g(view_t − x̂_{t−1} + e) }`
//! (where `view_t` is `Q_x(x_t)` or `x_t`), advancing
//! `x̂ ← x̂ + decode(msg)` — the bit-exact mirror of what every worker
//! applies, by the codec decode identity. A full [`ToWorker::Weights`]
//! resync frame goes out on round 1, every `resync_every` rounds after
//! it, and after a restore without downlink state, resetting `x̂` to
//! the broadcast view and `e` to zero. `downlink=full` is untouched
//! code and stays bit-identical to the seed behavior.

use super::protocol::{CommStats, ToServer, ToWorker};
use crate::elastic::{Participation, StalenessPolicy};
use crate::quant::{CodecPolicy, Compressor, ErrorFeedback, Identity, WQuant, WireMsg};
use crate::util::par::par_tasks;
use anyhow::{anyhow, Result};

/// Default shard width: matches the AOT kernel chunk (64Ki f32 = 256 KB
/// per block buffer, comfortably L2-resident).
pub const DEFAULT_BLOCK: usize = 1 << 16;

pub struct ParameterServer {
    /// Full-precision master weights.
    x: Vec<f32>,
    /// Weight quantizer for broadcast / final output (None = fp32).
    wq: Option<WQuant>,
    /// Scratch: quantized broadcast weights.
    qx: Vec<f32>,
    /// Scratch: unpacked broadcast codes (WQuant path only).
    codes: Vec<u32>,
    /// Round-scoped accumulator arena for [`Self::apply`]: the fused
    /// decode→sum pass lands in here block by block, so steady-state
    /// rounds allocate nothing in the codec path. Persistent like
    /// `qx`/`codes`; contents are only meaningful inside one `apply`.
    acc: Vec<f32>,
    /// Shard width in coordinates.
    block: usize,
    /// Worker threads for block-parallel passes (1 = sequential).
    threads: usize,
    /// Compressed-downlink state (None = full broadcasts, the default).
    down: Option<DeltaDownlink>,
    pub stats: CommStats,
    t: u64,
}

/// Server-side state of the compressed (weight-delta) downlink.
struct DeltaDownlink {
    /// Gradient-family codec compressing the broadcast delta (the
    /// static path; unused while a non-static `policy` is installed).
    comp: Box<dyn Compressor>,
    /// Full-resync cadence in rounds (0 = only round 1 / forced).
    resync_every: u64,
    /// Worker-replica estimate `x̂`: bit-exact mirror of every worker's
    /// decoded weight state.
    replica: Vec<f32>,
    /// Server-side error-feedback residual over broadcast deltas.
    ef: ErrorFeedback,
    /// Scratch: broadcast direction `view − x̂`.
    dir: Vec<f32>,
    /// Next broadcast must be a full resync frame (set after restores
    /// that carry no downlink state).
    pending_resync: bool,
    /// Per-tensor codec policy for the delta frames (None = the static
    /// single-message path, byte-identical to pre-policy builds). The
    /// server runs its own controller instance over *its* EF state —
    /// policy state never crosses the wire, only the per-part codec
    /// headers do.
    policy: Option<CodecPolicy>,
}

impl ParameterServer {
    /// Sequential server (one thread, default block width) — the seed
    /// behavior, still the default for single-process tools.
    pub fn new(x0: Vec<f32>, kx: Option<u32>) -> Self {
        Self::with_shards(x0, kx, DEFAULT_BLOCK, 1)
    }

    /// Sharded server: state is processed `block` coordinates at a time
    /// across up to `threads` threads. Bit-identical to [`Self::new`]
    /// for every `(block, threads)` choice.
    pub fn with_shards(x0: Vec<f32>, kx: Option<u32>, block: usize, threads: usize) -> Self {
        assert!(block > 0, "shard block must be positive");
        let dim = x0.len();
        let wq = kx.map(WQuant::new);
        Self {
            qx: vec![0.0; dim],
            codes: if wq.is_some() { vec![0; dim] } else { Vec::new() },
            acc: vec![0.0; dim],
            x: x0,
            wq,
            block,
            threads: threads.max(1),
            down: None,
            stats: CommStats::default(),
            t: 0,
        }
    }

    /// Switch the downlink to compressed weight-delta broadcasts. Must
    /// be called before the first round (the protocol needs round 1 to
    /// be the initial full resync frame). `comp` is the gradient-family
    /// codec for the delta payload ([`crate::quant::gradient_codec`]);
    /// a full resync frame goes out every `resync_every` rounds (0 =
    /// only round 1 and forced resyncs).
    pub fn enable_delta_downlink(&mut self, comp: Box<dyn Compressor>, resync_every: u64) {
        assert_eq!(self.t, 0, "downlink mode must be chosen before round 1");
        let dim = self.x.len();
        self.down = Some(DeltaDownlink {
            comp,
            resync_every,
            replica: vec![0.0; dim],
            ef: ErrorFeedback::new(dim, true),
            dir: vec![0.0; dim],
            pending_resync: false,
            policy: None,
        });
    }

    /// Install a per-tensor codec policy on the delta downlink: delta
    /// frames become [`ToWorker::WeightsDeltaParts`] (one codec header
    /// per layout tensor), with the adaptive controller — when the spec
    /// is adaptive — driven by the *server's* EF residual against the
    /// broadcast direction. A static spec is a no-op: the single-message
    /// path stays byte-identical. Must be called before round 1, after
    /// [`Self::enable_delta_downlink`].
    pub fn set_downlink_policy(&mut self, policy: CodecPolicy) {
        assert_eq!(self.t, 0, "downlink policy must be chosen before round 1");
        let d = self.down.as_mut().expect("downlink policy requires delta mode");
        assert_eq!(
            policy.layout().dim(),
            d.replica.len(),
            "policy layout dim != model dim"
        );
        if !policy.spec().is_static() {
            d.policy = Some(policy);
        }
    }

    /// Mean code bits/element the downlink policy currently chooses
    /// (None without a non-static policy) — what the metrics CSV logs.
    pub fn downlink_bits(&self) -> Option<f64> {
        self.down.as_ref().and_then(|d| d.policy.as_ref()).map(|p| p.mean_code_bits())
    }

    /// Per-tensor levels the downlink policy currently chooses (parity
    /// tests compare these across engines).
    pub fn downlink_chosen_bits(&self) -> Option<Vec<u32>> {
        self.down.as_ref().and_then(|d| d.policy.as_ref()).map(|p| p.bits().to_vec())
    }

    /// `(replica x̂, server EF residual)` when the delta downlink is on.
    pub fn downlink_state(&self) -> Option<(&[f32], &[f32])> {
        self.down.as_ref().map(|d| (d.replica.as_slice(), d.ef.residual()))
    }

    /// Restore delta-downlink state saved from [`Self::downlink_state`]
    /// (version-2 checkpoints).
    pub fn restore_downlink(&mut self, replica: &[f32], residual: &[f32]) -> Result<()> {
        let d = self.down.as_mut().ok_or_else(|| anyhow!("delta downlink is not enabled"))?;
        if replica.len() != d.replica.len() || residual.len() != d.replica.len() {
            return Err(anyhow!(
                "downlink state dim {}/{} != model dim {}",
                replica.len(),
                residual.len(),
                d.replica.len()
            ));
        }
        d.replica.copy_from_slice(replica);
        d.ef.set_residual(residual);
        d.pending_resync = false;
        Ok(())
    }

    /// Force the next broadcast to be a full `Weights` resync frame —
    /// used after a restore that carries no downlink state, so workers
    /// (and the replica) re-synchronize before any delta frame.
    pub fn force_resync(&mut self) {
        if let Some(d) = self.down.as_mut() {
            d.pending_resync = true;
        }
    }

    pub fn dim(&self) -> usize {
        self.x.len()
    }

    pub fn step(&self) -> u64 {
        self.t
    }

    /// Master (full-precision) weights.
    pub fn master(&self) -> &[f32] {
        &self.x
    }

    /// Restore (weights, step) from a checkpoint. In delta-downlink
    /// mode this schedules a full resync frame for the next round — the
    /// in-memory replica no longer matches any worker; callers that
    /// also restore the saved downlink state
    /// ([`Self::restore_downlink`]) clear the pending resync again.
    pub fn restore(&mut self, x: &[f32], t: u64) {
        assert_eq!(x.len(), self.x.len());
        self.x.copy_from_slice(x);
        self.t = t;
        self.force_resync();
    }

    /// What an edge device stores/serves: Q_x(x) when quantizing,
    /// else x (paper Alg. 2 "Output Q_x(x_t)").
    pub fn output_weights(&mut self) -> &[f32] {
        match self.wq {
            Some(_) => {
                self.refresh_view();
                &self.qx
            }
            None => &self.x,
        }
    }

    /// Fill `qx` with the broadcast view: `Q_x(x)` block-parallel, or a
    /// copy of `x` when weight quantization is off. Shared by
    /// [`Self::output_weights`] and the delta-frame path; bit-identical
    /// to the view [`Self::encode_full_msg`] leaves behind.
    fn refresh_view(&mut self) {
        match self.wq {
            Some(wq) => {
                let x = &self.x;
                for_blocks(self.threads, self.block, &mut self.qx, |(start, qc)| {
                    wq.quantize_into(&x[start..start + qc.len()], qc);
                });
            }
            None => self.qx.copy_from_slice(&self.x),
        }
    }

    /// Begin round `t+1`: produce the broadcast message and the weight
    /// view workers must evaluate gradients at (Assumption 3: gradients
    /// are taken at `Q_x(x_t)`).
    pub fn broadcast(&mut self, nworkers: usize) -> (ToWorker, &[f32]) {
        self.broadcast_at_epoch(nworkers, 0)
    }

    /// [`Self::broadcast`] with an explicit epoch tag (drives the
    /// workers' ExpDecay schedules).
    pub fn broadcast_at_epoch(&mut self, nworkers: usize, epoch: u64) -> (ToWorker, &[f32]) {
        self.t += 1;
        let resync = match &self.down {
            // full downlink: every frame is a full frame
            None => true,
            Some(d) => {
                d.pending_resync
                    || self.t == 1
                    || (d.resync_every > 0 && (self.t - 1) % d.resync_every == 0)
            }
        };
        if resync {
            let msg = self.encode_full_msg();
            if let Some(d) = self.down.as_mut() {
                // A full frame re-anchors every worker replica at the
                // broadcast view exactly; the old residual is obsolete.
                d.replica.copy_from_slice(&self.qx);
                d.ef.reset();
                d.pending_resync = false;
                // Only delta mode counts resyncs: in full mode every
                // frame is full and the counter would just echo rounds.
                self.stats.resyncs += 1;
            }
            let tw = ToWorker::Weights { t: self.t, epoch, msg };
            self.stats.down_bytes += (tw.wire_bytes() * nworkers) as u64;
            return (tw, &self.qx);
        }

        // Delta frame: target view Q_x(x_t) (or x_t) into qx.
        self.refresh_view();
        let down = self.down.as_mut().expect("delta frame requires delta mode");
        // direction = view − x̂ (the EF residual is added inside compress)
        {
            let qx = &self.qx;
            let replica = &down.replica;
            for_blocks(self.threads, self.block, &mut down.dir, |(start, dc)| {
                for (j, d) in dc.iter_mut().enumerate() {
                    *d = qx[start + j] - replica[start + j];
                }
            });
        }
        // The codec quantize + pack stays serial, like the full path's
        // bit-pack; rng is only consumed by stochastic codecs and is
        // deterministic in the round.
        let mut rng = crate::quant::seeded_rng(0x00d0_0b17, self.t);
        let tw = if down.policy.is_some() {
            // Codec-policy frame: decide the per-tensor levels from the
            // server's own EF state, then run the range-EF step one
            // tensor at a time — each part gets its own scale and codec
            // header — advancing x̂ per range (decode identity per
            // range, so x̂ still mirrors every worker bit-exactly).
            let policy = down.policy.as_mut().expect("checked above");
            policy.decide(self.t, &down.dir, down.ef.residual());
            let mut parts = Vec::with_capacity(policy.layout().tensors().len());
            for (i, ts) in policy.layout().tensors().iter().enumerate() {
                let comp = policy.codec_at(i);
                let (msg, q) =
                    down.ef.compress_range_q(&down.dir, ts.start, ts.len, comp.as_dyn(), &mut rng);
                // x̂ ← x̂ + decode(msg) over this tensor's range,
                // block-parallel like the static path (per-coordinate
                // adds: identical bytes for any (block, threads)).
                let repl = &mut down.replica[ts.start..ts.start + ts.len];
                for_blocks(self.threads, self.block, repl, |(start, rc)| {
                    for (j, r) in rc.iter_mut().enumerate() {
                        *r += q[start + j];
                    }
                });
                parts.push(msg);
            }
            ToWorker::WeightsDeltaParts { t: self.t, epoch, parts }
        } else {
            let (msg, q) = down.ef.compress_q(&down.dir, down.comp.as_ref(), &mut rng);
            // x̂ ← x̂ + decode(msg): the bit-exact mirror of what every
            // worker applies (codec decode identity).
            for_blocks(self.threads, self.block, &mut down.replica, |(start, rc)| {
                for (j, r) in rc.iter_mut().enumerate() {
                    *r += q[start + j];
                }
            });
            ToWorker::WeightsDelta { t: self.t, epoch, msg }
        };
        self.stats.down_bytes += (tw.wire_bytes() * nworkers) as u64;
        let down = self.down.as_ref().expect("delta frame requires delta mode");
        (tw, &down.replica)
    }

    /// Encode the full weight broadcast payload (`Q_x(x_t)` codes or
    /// raw fp32), leaving the dequantized broadcast view in `self.qx`.
    /// The one owner of the full-frame encoding, shared by the full
    /// downlink and the delta mode's resync frames.
    fn encode_full_msg(&mut self) -> WireMsg {
        let n = self.x.len();
        match self.wq {
            Some(wq) => {
                // Block-parallel re-quantization: each task fills its
                // slice of (qx, codes); the bit-pack stays serial (it is
                // a cheap, memory-bound tail next to the float math).
                let x = &self.x;
                let block = self.block;
                let tasks: Vec<(usize, &mut [f32], &mut [u32])> = self
                    .qx
                    .chunks_mut(block)
                    .zip(self.codes.chunks_mut(block))
                    .enumerate()
                    .map(|(i, (qc, cc))| (i * block, qc, cc))
                    .collect();
                par_tasks(self.threads, tasks, |(start, qc, cc)| {
                    wq.encode_into(&x[start..start + qc.len()], qc, cc);
                });
                wq.wire_msg(n, &self.codes)
            }
            None => {
                let mut rng = crate::quant::seeded_rng(0, self.t); // unused (Identity)
                Identity.compress_into(&self.x, &mut self.qx, &mut rng)
            }
        }
    }

    /// Gather + apply one synchronous round of deltas (Alg. 2 lines 3–4).
    ///
    /// **Participation semantics** (the elastic-round contract): the
    /// mean is taken over the *received* replies — `x ← x − mean_i δ`
    /// averages over `deltas.len()`, not over the deployment size. A
    /// worker whose reply was dropped (straggler, chaos, dead
    /// connection) simply does not pull the mean that round; its
    /// error-feedback residual carries the un-applied mass into its
    /// next reply (the Theorem 3.1 argument under partial
    /// participation). The returned [`Participation`] names exactly the
    /// workers the mean ran over.
    pub fn apply(&mut self, deltas: &[ToServer]) -> Result<Participation> {
        if deltas.is_empty() {
            return Err(anyhow!("no deltas to apply"));
        }
        // Validate everything first, so a rejected round is fully
        // side-effect-free: no weight movement, no accounting drift.
        // Replies may mix the single-message and per-tensor frame kinds
        // (and, within parts, any codec per tensor): validation and
        // decode go through the `ToServer` payload accessors.
        for d in deltas {
            if d.round() != self.t {
                return Err(anyhow!("stale delta for t={}, server at {}", d.round(), self.t));
            }
            if d.payload_n() != self.x.len() {
                return Err(anyhow!(
                    "delta dim {} != model dim {}",
                    d.payload_n(),
                    self.x.len()
                ));
            }
        }
        // The Transport contract forbids duplicate replies, but a buggy
        // transport (or a misconfigured worker id) would otherwise
        // silently double-weight that worker in the mean — enforce it.
        let mut ids: Vec<u32> = deltas.iter().map(|d| d.worker()).collect();
        ids.sort_unstable();
        if let Some(dup) = ids.windows(2).find(|p| p[0] == p[1]) {
            return Err(anyhow!("duplicate delta from worker {} in round {}", dup[0], self.t));
        }
        let n = deltas.len() as f32;
        let mut mean_loss = 0.0f32;
        for d in deltas {
            mean_loss += d.loss() / n;
            self.stats.up_bytes += d.wire_bytes() as u64;
        }
        // Block-parallel fused decode→sum→apply: each block zeroes its
        // slice of the persistent `acc` arena, accumulates every
        // worker's decoded range straight into it
        // (`ToServer::decode_range_add` — no per-delta scratch buffer),
        // then applies the mean. Per coordinate the summation order is
        // fixed (delta order == worker order) and `acc[j] += decode`
        // performs the identical f32 adds the old scratch-then-add pass
        // did, so this is bit-identical to the sequential seed pass.
        let inv = 1.0 / n;
        let block = self.block;
        let work = |(start, xc, ac): (usize, &mut [f32], &mut [f32])| {
            apply_block(deltas, inv, start, xc, ac)
        };
        let chunks = self
            .x
            .chunks_mut(block)
            .zip(self.acc.chunks_mut(block))
            .enumerate()
            .map(|(i, (xc, ac))| (i * block, xc, ac));
        if self.threads <= 1 {
            // Sequential fast path: no task Vec either — a steady-state
            // round allocates nothing in the decode/apply path.
            chunks.for_each(work);
        } else {
            par_tasks(self.threads, chunks.collect(), work);
        }
        self.stats.rounds += 1;
        Ok(Participation { round: self.t, mean_loss, reporters: ids })
    }

    /// Gather + apply one **asynchronous** round under a bounded-staleness
    /// admission rule.
    ///
    /// Unlike [`ParameterServer::apply`] — which demands every delta carry
    /// the current round tag — this path accepts any delta whose age
    /// `self.t − d.round()` the [`StalenessPolicy`] admits (`age ≤ τ`),
    /// optionally down-weighting it by age, and *rejects* the rest instead
    /// of failing the round. The caller is responsible for folding each
    /// rejected delta (and the `1 − w(age)` remainder of each
    /// down-weighted one) back into the sender's error-feedback residual
    /// (`Worker::absorb_rejected`) so no gradient mass is silently lost —
    /// the same residual-composition argument that makes straggler drops
    /// safe (ECQ-SGD, Wu et al. 2018; two-way compression in
    /// Efficient-Adam, Chen et al. 2022) covers bounded staleness: a
    /// rejected delta re-ships through the residual within τ rounds of
    /// retries or is carried indefinitely, but never vanishes.
    ///
    /// Invariants:
    /// * The admit/reject decision is a pure function of
    ///   `(d.round(), self.t, policy)` — no clock, no rng — so every
    ///   shard of a [`super::ShardedServer`] makes the identical call for
    ///   the same logical delta.
    /// * A delta tagged *ahead* of the server (`d.round() > self.t`) is
    ///   treated as maximally stale and rejected, never applied.
    /// * An all-rejected (or empty) round is legal: the weights do not
    ///   move, `mean_loss` is 0.0 (never NaN — the mean runs over the
    ///   *admitted* set, which may be empty), and the round still counts
    ///   in [`CommStats::rounds`].
    /// * With every age 0 and no down-weighting this computes the
    ///   identical per-block f32 operations as [`ParameterServer::apply`]
    ///   (asserted in tests), so turning async mode on does not perturb a
    ///   worker set that happens to stay fresh.
    ///
    /// The weighted decode path allocates a block-sized scratch: this is
    /// the async round path, not the sync hot loop, and clarity wins.
    pub fn apply_async(
        &mut self,
        deltas: &[ToServer],
        policy: &StalenessPolicy,
    ) -> Result<AsyncApply> {
        // Validate first: a rejected *round* (malformed input) is fully
        // side-effect-free. Staleness is not an error — it is the point.
        for d in deltas {
            if d.payload_n() != self.x.len() {
                return Err(anyhow!(
                    "delta dim {} != model dim {}",
                    d.payload_n(),
                    self.x.len()
                ));
            }
        }
        // Duplicates are per (worker, origin round): one worker may
        // legitimately have two in-flight deltas from different rounds,
        // but the same (worker, round) pair twice is a transport bug.
        let mut keys: Vec<(u32, u64)> =
            deltas.iter().map(|d| (d.worker(), d.round())).collect();
        keys.sort_unstable();
        if let Some(dup) = keys.windows(2).find(|p| p[0] == p[1]) {
            return Err(anyhow!(
                "duplicate delta from worker {} for round {}",
                dup[0].0,
                dup[0].1
            ));
        }
        let ages: Vec<u64> =
            deltas.iter().map(|d| StalenessPolicy::age(self.t, d.round())).collect();
        let admitted: Vec<usize> =
            (0..deltas.len()).filter(|&i| policy.admits(ages[i])).collect();
        let rejected: Vec<usize> =
            (0..deltas.len()).filter(|&i| !policy.admits(ages[i])).collect();
        for d in deltas {
            self.stats.up_bytes += d.wire_bytes() as u64;
        }
        let mut mean_loss = 0.0f32;
        let mut reporters: Vec<u32> = Vec::with_capacity(admitted.len());
        if !admitted.is_empty() {
            let n = admitted.len() as f32;
            for &i in &admitted {
                mean_loss += deltas[i].loss() / n;
                reporters.push(deltas[i].worker());
            }
            reporters.sort_unstable();
            reporters.dedup();
            let inv = 1.0 / n;
            let block = self.block;
            let mut tmp = vec![0.0f32; block.min(self.x.len())];
            for (bi, (xc, ac)) in
                self.x.chunks_mut(block).zip(self.acc.chunks_mut(block)).enumerate()
            {
                let start = bi * block;
                ac.fill(0.0);
                for &i in &admitted {
                    let w = policy.weight(ages[i]);
                    if w == 1.0 {
                        // Same accumulation the sync fused kernel performs.
                        deltas[i].decode_range_add(start, ac);
                    } else {
                        let t = &mut tmp[..ac.len()];
                        deltas[i].decode_range(start, t);
                        for (a, &v) in ac.iter_mut().zip(t.iter()) {
                            *a += w * v;
                        }
                    }
                }
                for (xi, &a) in xc.iter_mut().zip(ac.iter()) {
                    *xi -= inv * a;
                }
            }
        }
        self.stats.rounds += 1;
        Ok(AsyncApply {
            part: Participation { round: self.t, mean_loss, reporters },
            ages,
            rejected,
        })
    }
}

/// Outcome of one [`ParameterServer::apply_async`] call.
///
/// `ages` is aligned with the input slice (one entry per delta, admitted
/// or not) so the caller can compute the `1 − w(age)` refund share for
/// down-weighted deltas; `rejected` indexes the deltas whose full mass
/// must flow back into the sender's error-feedback residual.
#[derive(Debug, Clone)]
pub struct AsyncApply {
    /// Who the (possibly empty) admitted mean ran over, and its loss.
    pub part: Participation,
    /// Staleness `server_t − delta_t` per input delta, in input order.
    pub ages: Vec<u64>,
    /// Indices (into the input slice) rejected as beyond `τ`.
    pub rejected: Vec<usize>,
}

/// One block of the fused decode→sum→apply traversal behind
/// [`ParameterServer::apply`]: zero the block's slice of the persistent
/// accumulator arena, sum every worker's decoded range into it, apply
/// the mean. Runs once per block per round on every thread — the
/// steady-state server hot loop, so it must not allocate.
// qadam: hotpath
fn apply_block(deltas: &[ToServer], inv: f32, start: usize, xc: &mut [f32], ac: &mut [f32]) {
    ac.fill(0.0);
    for d in deltas {
        d.decode_range_add(start, ac);
    }
    for (xi, &a) in xc.iter_mut().zip(ac.iter()) {
        *xi -= inv * a;
    }
}

/// Split a buffer into `(global offset, block)` tasks.
fn blocks(buf: &mut [f32], block: usize) -> Vec<(usize, &mut [f32])> {
    buf.chunks_mut(block).enumerate().map(|(i, c)| (i * block, c)).collect()
}

/// Run `f` over the `(global offset, block)` chunks of `buf`: inline
/// with no task-list allocation when `threads <= 1` (the seed/LocalBus
/// configuration), else fanned out via [`par_tasks`]. Identical results
/// either way — `par_tasks` never changes what a task computes.
fn for_blocks<F>(threads: usize, block: usize, buf: &mut [f32], f: F)
where
    F: Fn((usize, &mut [f32])) + Sync,
{
    if threads <= 1 {
        for (i, c) in buf.chunks_mut(block).enumerate() {
            f((i * block, c));
        }
    } else {
        par_tasks(threads, blocks(buf, block), f);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{seeded_rng, CodecId, Compressor, LogQuant};

    fn delta_msg(u: &[f32], kg: u32) -> WireMsg {
        let mut q = vec![0.0; u.len()];
        LogQuant::new(kg).compress_into(u, &mut q, &mut seeded_rng(0, 0))
    }

    #[test]
    fn applies_mean_of_decoded_deltas() {
        let mut ps = ParameterServer::new(vec![1.0; 4], None);
        let (_msg, w) = ps.broadcast(2);
        assert_eq!(w, &[1.0; 4]);
        // two workers send exact powers of two so quantization is exact
        let d1 = delta_msg(&[0.5, 0.5, 1.0, 0.0], 2);
        let d2 = delta_msg(&[1.0, 0.0, 1.0, 0.5], 2);
        let part = ps
            .apply(&[
                ToServer::Delta { t: 1, worker: 0, loss: 2.0, msg: d1 },
                ToServer::Delta { t: 1, worker: 1, loss: 4.0, msg: d2 },
            ])
            .unwrap();
        assert_eq!(part.mean_loss, 3.0);
        assert_eq!(part.reporters, vec![0, 1]);
        assert_eq!(part.round, 1);
        let want = [1.0 - 0.75, 1.0 - 0.25, 0.0, 1.0 - 0.25];
        for (a, b) in ps.master().iter().zip(want) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn broadcast_quantizes_weights() {
        let mut ps = ParameterServer::new(vec![0.13, -0.13, 0.0, 0.26], Some(2));
        let (tw, w) = ps.broadcast(1);
        assert_eq!(w, &[0.125, -0.125, 0.0, 0.25]);
        match tw {
            ToWorker::Weights { msg, .. } => assert_eq!(msg.codec, CodecId::WQuant),
            _ => panic!(),
        }
        // master stays full precision
        assert_eq!(ps.master(), &[0.13, -0.13, 0.0, 0.26]);
        // output is quantized
        assert_eq!(ps.output_weights(), &[0.125, -0.125, 0.0, 0.25]);
    }

    /// The elastic participation semantics: the mean runs over the
    /// *received* replies, and [`Participation`] reports exactly who
    /// they came from.
    #[test]
    fn participation_is_the_received_set_and_mean_is_over_received() {
        let mut ps = ParameterServer::new(vec![1.0; 4], None);
        ps.broadcast(4); // 4 workers expected, 2 report
        let part = ps
            .apply(&[
                ToServer::Delta { t: 1, worker: 3, loss: 1.0, msg: delta_msg(&[1.0, 0.0, 0.0, 0.0], 2) },
                ToServer::Delta { t: 1, worker: 0, loss: 3.0, msg: delta_msg(&[0.0, 1.0, 0.0, 0.0], 2) },
            ])
            .unwrap();
        // mean over the 2 received replies, not over the 4 expected
        assert_eq!(part.mean_loss, 2.0);
        assert_eq!(part.reporters, vec![0, 3], "sorted by worker id");
        assert_eq!(part.count(), 2);
        // the applied step divides by the received count too
        let want = [1.0 - 0.5, 1.0 - 0.5, 1.0, 1.0];
        for (a, b) in ps.master().iter().zip(want) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    /// In delta mode the resync counter tracks full frames: round 1,
    /// the cadence, and forced resyncs — full mode leaves it at 0.
    #[test]
    fn resync_counter_counts_delta_mode_full_frames() {
        let mut full = ParameterServer::new(vec![0.5; 8], None);
        for _ in 0..5 {
            full.broadcast(1);
        }
        assert_eq!(full.stats.resyncs, 0, "full mode does not count resyncs");
        let mut ps = ParameterServer::new(vec![0.5; 8], None);
        ps.enable_delta_downlink(Box::new(LogQuant::new(2)), 4);
        for _ in 0..6 {
            ps.broadcast(1); // resync frames at t=1 and t=5
        }
        assert_eq!(ps.stats.resyncs, 2);
        ps.force_resync();
        ps.broadcast(1); // t=7, forced
        assert_eq!(ps.stats.resyncs, 3);
    }

    #[test]
    fn rejects_stale_or_misshapen() {
        let mut ps = ParameterServer::new(vec![0.0; 4], None);
        ps.broadcast(1);
        let bad_t = ToServer::Delta { t: 9, worker: 0, loss: 0.0, msg: delta_msg(&[0.0; 4], 1) };
        assert!(ps.apply(&[bad_t]).is_err());
        let bad_dim = ToServer::Delta { t: 1, worker: 0, loss: 0.0, msg: delta_msg(&[0.0; 3], 1) };
        assert!(ps.apply(&[bad_dim]).is_err());
        assert!(ps.apply(&[]).is_err());
    }

    #[test]
    fn accounting_accumulates() {
        let mut ps = ParameterServer::new(vec![0.0; 64], Some(6));
        let (tw, _) = ps.broadcast(8);
        assert_eq!(ps.stats.down_bytes, (tw.wire_bytes() * 8) as u64);
        let d = ToServer::Delta { t: 1, worker: 0, loss: 0.0, msg: delta_msg(&[0.0; 64], 2) };
        let up = d.wire_bytes() as u64;
        ps.apply(&[d]).unwrap();
        assert_eq!(ps.stats.up_bytes, up);
        assert_eq!(ps.stats.rounds, 1);
    }

    /// Acceptance: the sharded server (any block/thread split, including
    /// ragged tails) is bit-identical to the sequential one — weights,
    /// broadcast messages and byte accounting — over many rounds and
    /// mixed codecs.
    #[test]
    fn sharded_server_bit_identical_to_sequential() {
        use crate::quant::{Blockwise, TernGrad};
        let dim = 233; // prime-ish: every block width leaves a ragged tail
        let mk_x0 = || (0..dim).map(|i| 0.2 * ((i as f32) * 0.31).sin()).collect::<Vec<f32>>();
        let deltas_for = |t: u64| -> Vec<ToServer> {
            let mut rng = seeded_rng(7, t);
            let mk = |w: u32| -> Vec<f32> {
                (0..dim).map(|i| 0.01 * ((i as f32 + w as f32 * 3.7 + t as f32).cos())).collect()
            };
            let mut q = vec![0.0; dim];
            let m0 = LogQuant::new(2).compress_into(&mk(0), &mut q, &mut rng);
            let m1 = TernGrad.compress_into(&mk(1), &mut q, &mut rng);
            let m2 = Blockwise::new(13).compress_into(&mk(2), &mut q, &mut rng);
            vec![
                ToServer::Delta { t, worker: 0, loss: 1.0, msg: m0 },
                ToServer::Delta { t, worker: 1, loss: 2.0, msg: m1 },
                ToServer::Delta { t, worker: 2, loss: 3.0, msg: m2 },
            ]
        };
        for &kx in &[None, Some(6u32)] {
            let mut seq = ParameterServer::new(mk_x0(), kx);
            let mut configs = vec![
                ParameterServer::with_shards(mk_x0(), kx, 7, 4),
                ParameterServer::with_shards(mk_x0(), kx, 64, 3),
                ParameterServer::with_shards(mk_x0(), kx, 1024, 8),
            ];
            for t in 1u64..=20 {
                let (b_seq, _) = seq.broadcast(3);
                seq.apply(&deltas_for(t)).unwrap();
                for ps in configs.iter_mut() {
                    let (b, _) = ps.broadcast(3);
                    assert_eq!(b.to_bytes(), b_seq.to_bytes(), "kx={kx:?} t={t}");
                    ps.apply(&deltas_for(t)).unwrap();
                    assert_eq!(ps.master(), seq.master(), "kx={kx:?} t={t}");
                    assert_eq!(ps.stats.up_bytes, seq.stats.up_bytes);
                    assert_eq!(ps.stats.down_bytes, seq.stats.down_bytes);
                }
            }
        }
    }

    /// Duplicate worker ids in a round must be rejected before any
    /// state is touched: averaging a duplicated reply would silently
    /// double-weight that worker.
    #[test]
    fn rejects_duplicate_worker_ids() {
        let mut ps = ParameterServer::new(vec![1.0; 4], None);
        ps.broadcast(2);
        let d = |w: u32| ToServer::Delta {
            t: 1,
            worker: w,
            loss: 0.0,
            msg: delta_msg(&[0.5, 0.0, 0.0, 0.0], 2),
        };
        let err = ps.apply(&[d(0), d(0)]).unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
        assert_eq!(ps.master(), &[1.0; 4][..], "rejected round must be side-effect-free");
        assert_eq!(ps.stats.up_bytes, 0);
        ps.apply(&[d(0), d(1)]).unwrap();
    }

    /// Acceptance (delta downlink): on every round the server replica
    /// `x̂` equals what a worker holds after decoding the broadcast
    /// stream, the sharded delta server is bit-identical to the
    /// sequential one (frames, master, replica, accounting), and resync
    /// frames appear exactly on the configured cadence.
    #[test]
    fn delta_downlink_replica_tracks_decode_and_shards_agree() {
        use crate::quant::decode_msg;
        let dim = 233;
        let resync_every = 4u64;
        let mk_x0 = || (0..dim).map(|i| 0.2 * ((i as f32) * 0.31).sin()).collect::<Vec<f32>>();
        let deltas_for = |t: u64| -> Vec<ToServer> {
            let mut rng = seeded_rng(7, t);
            let mut q = vec![0.0; dim];
            (0..3u32)
                .map(|w| {
                    let u: Vec<f32> = (0..dim)
                        .map(|i| 0.01 * ((i as f32 + w as f32 * 3.7 + t as f32).cos()))
                        .collect();
                    let msg = LogQuant::new(2).compress_into(&u, &mut q, &mut rng);
                    ToServer::Delta { t, worker: w, loss: 1.0, msg }
                })
                .collect()
        };
        let mk_ps = |block: usize, threads: usize, kx: Option<u32>| -> ParameterServer {
            let mut ps = ParameterServer::with_shards(mk_x0(), kx, block, threads);
            ps.enable_delta_downlink(Box::new(LogQuant::new(2)), resync_every);
            ps
        };
        for &kx in &[None, Some(6u32)] {
            let mut seq = mk_ps(DEFAULT_BLOCK, 1, kx);
            let mut configs = vec![mk_ps(7, 4, kx), mk_ps(64, 3, kx)];
            // independent worker-side replica, driven only by the frames
            let mut w = vec![0.0f32; dim];
            let mut scratch = vec![0.0f32; dim];
            for t in 1u64..=13 {
                let (b_seq, _) = seq.broadcast(3);
                match &b_seq {
                    ToWorker::Weights { msg, .. } => {
                        assert!(
                            t == 1 || (t - 1) % resync_every == 0,
                            "unexpected resync frame at t={t}"
                        );
                        decode_msg(msg, &mut w);
                    }
                    ToWorker::WeightsDelta { msg, .. } => {
                        assert!(
                            t != 1 && (t - 1) % resync_every != 0,
                            "expected resync frame at t={t}"
                        );
                        decode_msg(msg, &mut scratch);
                        for (wi, &d) in w.iter_mut().zip(&scratch) {
                            *wi += d;
                        }
                    }
                    other => panic!("unexpected frame {other:?}"),
                }
                let (replica, _res) = seq.downlink_state().unwrap();
                assert_eq!(w.as_slice(), replica, "kx={kx:?} t={t}: replica != worker decode");
                seq.apply(&deltas_for(t)).unwrap();
                for ps in configs.iter_mut() {
                    let (b, _) = ps.broadcast(3);
                    assert_eq!(b.to_bytes(), b_seq.to_bytes(), "kx={kx:?} t={t}");
                    ps.apply(&deltas_for(t)).unwrap();
                    assert_eq!(ps.master(), seq.master(), "kx={kx:?} t={t}");
                    let (r_seq, e_seq) = seq.downlink_state().unwrap();
                    let (r, e) = ps.downlink_state().unwrap();
                    assert_eq!(r, r_seq, "kx={kx:?} t={t}");
                    assert_eq!(e, e_seq, "kx={kx:?} t={t}");
                    assert_eq!(ps.stats.down_bytes, seq.stats.down_bytes);
                    assert_eq!(ps.stats.up_bytes, seq.stats.up_bytes);
                }
            }
        }
    }

    /// Acceptance: at kg=2 the compressed downlink is ≥4x smaller than
    /// full fp32 broadcasts on an 8-worker round sequence.
    #[test]
    fn delta_downlink_cuts_down_bytes_4x() {
        let dim = 4096;
        let rounds = 20u64;
        let x0: Vec<f32> = (0..dim).map(|i| 0.1 * (i as f32 * 0.013).sin()).collect();
        let deltas_for = |t: u64| -> Vec<ToServer> {
            let mut rng = seeded_rng(3, t);
            let mut q = vec![0.0; dim];
            (0..8u32)
                .map(|w| {
                    let u: Vec<f32> =
                        (0..dim).map(|i| 0.001 * ((i + w as usize) as f32 + t as f32).sin()).collect();
                    let msg = LogQuant::new(2).compress_into(&u, &mut q, &mut rng);
                    ToServer::Delta { t, worker: w, loss: 0.0, msg }
                })
                .collect()
        };
        let mut full = ParameterServer::new(x0.clone(), None);
        let mut delta = ParameterServer::new(x0, None);
        delta.enable_delta_downlink(Box::new(LogQuant::new(2)), 50);
        for t in 1..=rounds {
            full.broadcast(8);
            full.apply(&deltas_for(t)).unwrap();
            delta.broadcast(8);
            delta.apply(&deltas_for(t)).unwrap();
        }
        let ratio = full.stats.down_bytes as f64 / delta.stats.down_bytes as f64;
        assert!(ratio >= 4.0, "down-bytes reduction only {ratio:.2}x");
        // the uplink is untouched by the downlink mode
        assert_eq!(full.stats.up_bytes, delta.stats.up_bytes);
    }

    /// After a restore without downlink state, the next frame must be a
    /// full resync (and the replica re-anchors on it).
    #[test]
    fn forced_resync_after_restore_emits_full_frame() {
        let dim = 16;
        let mut ps = ParameterServer::new(vec![0.5; dim], None);
        ps.enable_delta_downlink(Box::new(LogQuant::new(2)), 100);
        let deltas = |t: u64| {
            vec![ToServer::Delta { t, worker: 0, loss: 0.0, msg: delta_msg(&[0.25; 16], 2) }]
        };
        for t in 1..=3 {
            let (b, _) = ps.broadcast(1);
            if t > 1 {
                assert!(matches!(b, ToWorker::WeightsDelta { .. }), "t={t}");
            }
            ps.apply(&deltas(t)).unwrap();
        }
        let x: Vec<f32> = ps.master().to_vec();
        ps.restore(&x, 3);
        ps.force_resync();
        let (b, _) = ps.broadcast(1);
        match &b {
            ToWorker::Weights { msg, .. } => {
                let mut dec = vec![0.0; dim];
                crate::quant::decode_msg(msg, &mut dec);
                let (replica, residual) = ps.downlink_state().unwrap();
                assert_eq!(replica, dec.as_slice());
                assert!(residual.iter().all(|&e| e == 0.0), "resync must clear the residual");
            }
            other => panic!("expected a resync frame, got {other:?}"),
        }
    }

    /// Mixed-frame rounds: single-message and per-tensor replies (with
    /// different codecs per tensor) average together, block-parallel,
    /// bit-identical to the sequential pass.
    #[test]
    fn apply_mixes_single_and_parts_replies_bit_identically() {
        use crate::quant::TernGrad;
        let dim = 233;
        let mk_x0 = || (0..dim).map(|i| 0.2 * ((i as f32) * 0.31).sin()).collect::<Vec<f32>>();
        let deltas_for = |t: u64| -> Vec<ToServer> {
            let mut rng = seeded_rng(7, t);
            let mut q = vec![0.0; dim];
            let u = |w: u32| -> Vec<f32> {
                (0..dim).map(|i| 0.01 * ((i as f32 + w as f32 * 3.7 + t as f32).cos())).collect()
            };
            // worker 0: classic single-message reply
            let m0 = LogQuant::new(2).compress_into(&u(0), &mut q, &mut rng);
            // worker 1: per-tensor reply, mixed codecs and a ragged split
            let u1 = u(1);
            let p0 = LogQuant::new(0).compress_into(&u1[..100], &mut q[..100], &mut rng);
            let p1 = LogQuant::new(4).compress_into(&u1[100..170], &mut q[100..170], &mut rng);
            let p2 = TernGrad.compress_into(&u1[170..], &mut q[170..], &mut rng);
            vec![
                ToServer::Delta { t, worker: 0, loss: 1.0, msg: m0 },
                ToServer::DeltaParts { t, worker: 1, loss: 2.0, parts: vec![p0, p1, p2] },
            ]
        };
        let mut seq = ParameterServer::new(mk_x0(), None);
        let mut shard = ParameterServer::with_shards(mk_x0(), None, 13, 4);
        for t in 1u64..=10 {
            seq.broadcast(2);
            seq.apply(&deltas_for(t)).unwrap();
            shard.broadcast(2);
            shard.apply(&deltas_for(t)).unwrap();
            assert_eq!(seq.master(), shard.master(), "t={t}");
        }
        assert_eq!(seq.stats.up_bytes, shard.stats.up_bytes);
        // a parts reply with the wrong total dim is rejected cleanly
        let mut rng = seeded_rng(0, 0);
        let mut q = vec![0.0; 10];
        let short = LogQuant::new(2).compress_into(&[0.1; 10], &mut q, &mut rng);
        seq.broadcast(1);
        let bad = ToServer::DeltaParts { t: seq.step(), worker: 0, loss: 0.0, parts: vec![short] };
        let err = seq.apply(&[bad]).unwrap_err();
        assert!(err.to_string().contains("delta dim"), "{err}");
    }

    /// Codec-policy delta downlink: parts frames carry one codec header
    /// per tensor, the replica still mirrors a frame-driven worker
    /// decode bit-exactly across resyncs, and a static-spec policy
    /// leaves the single-message frames byte-identical.
    #[test]
    fn policy_downlink_parts_frames_track_replica() {
        use crate::quant::{decode_parts, PolicySpec, TensorLayout};
        let dim = 96;
        let layout = TensorLayout::uniform(dim, 3);
        let x0: Vec<f32> = (0..dim).map(|i| 0.3 + 0.01 * (i as f32).sin()).collect();
        let deltas_for = |t: u64| -> Vec<ToServer> {
            let mut rng = seeded_rng(3, t);
            let mut q = vec![0.0; dim];
            (0..2u32)
                .map(|w| {
                    let u: Vec<f32> = (0..dim)
                        .map(|i| 0.05 * ((i as f32 + w as f32 * 3.7 + t as f32).cos()))
                        .collect();
                    let msg = LogQuant::new(2).compress_into(&u, &mut q, &mut rng);
                    ToServer::Delta { t, worker: w, loss: 1.0, msg }
                })
                .collect()
        };
        let mut ps = ParameterServer::new(x0.clone(), Some(6));
        ps.enable_delta_downlink(Box::new(LogQuant::new(2)), 5);
        let policy =
            CodecPolicy::new(PolicySpec::Adaptive { lo: 0, hi: 4 }, layout.clone(), 2).unwrap();
        ps.set_downlink_policy(policy);
        assert!(ps.downlink_bits().is_some());
        // frame-driven worker replica
        let mut w = vec![0.0f32; dim];
        let mut scratch = vec![0.0f32; dim];
        for t in 1u64..=12 {
            let (b, _) = ps.broadcast(2);
            match &b {
                ToWorker::Weights { msg, .. } => {
                    assert!(t == 1 || (t - 1) % 5 == 0, "unexpected resync at t={t}");
                    crate::quant::decode_msg(msg, &mut w);
                }
                ToWorker::WeightsDeltaParts { parts, .. } => {
                    assert_eq!(parts.len(), layout.tensors().len());
                    let chosen = ps.downlink_chosen_bits().unwrap();
                    for (p, &k) in parts.iter().zip(&chosen) {
                        assert_eq!(p.param, k, "part header must carry the chosen level");
                    }
                    decode_parts(parts, &mut scratch);
                    for (wi, &d) in w.iter_mut().zip(&scratch) {
                        *wi += d;
                    }
                }
                other => panic!("unexpected frame {other:?} at t={t}"),
            }
            let (replica, _) = ps.downlink_state().unwrap();
            assert_eq!(w.as_slice(), replica, "t={t}: replica != worker decode");
            ps.apply(&deltas_for(t)).unwrap();
        }
        // a static-spec policy is a no-op: frames stay byte-identical
        // to the policy-free delta downlink
        let mk = |with_static_policy: bool| -> Vec<Vec<u8>> {
            let mut ps = ParameterServer::new(x0.clone(), None);
            ps.enable_delta_downlink(Box::new(LogQuant::new(2)), 0);
            if with_static_policy {
                let p = CodecPolicy::new(PolicySpec::Static, layout.clone(), 2).unwrap();
                ps.set_downlink_policy(p);
                assert!(ps.downlink_bits().is_none(), "static installs no controller");
            }
            (1u64..=6)
                .map(|t| {
                    let (b, _) = ps.broadcast(1);
                    ps.apply(&deltas_for(t)).unwrap();
                    b.to_bytes()
                })
                .collect()
        };
        assert_eq!(mk(false), mk(true), "static policy must not change a single byte");
    }

    /// A failed apply must not move the weights, even with sharding.
    #[test]
    fn failed_apply_leaves_weights_untouched() {
        let mut ps = ParameterServer::with_shards(vec![1.0; 32], None, 8, 4);
        ps.broadcast(2);
        let good = ToServer::Delta { t: 1, worker: 0, loss: 0.0, msg: delta_msg(&[0.5; 32], 2) };
        let stale = ToServer::Delta { t: 7, worker: 1, loss: 0.0, msg: delta_msg(&[0.5; 32], 2) };
        assert!(ps.apply(&[good, stale]).is_err());
        assert_eq!(ps.master(), &[1.0; 32][..]);
    }

    /// With every delta fresh (age 0) and no down-weighting, the async
    /// path performs the identical per-block f32 operations as the sync
    /// fused kernel — byte-for-byte equal weights.
    #[test]
    fn async_apply_with_fresh_deltas_matches_sync_apply_bitwise() {
        let x0: Vec<f32> = (0..64).map(|i| 0.3 + 0.01 * (i as f32).sin()).collect();
        let deltas: Vec<ToServer> = (0..3)
            .map(|w| {
                let u: Vec<f32> = (0..64).map(|i| 0.01 * ((i + w) as f32).cos()).collect();
                ToServer::Delta { t: 1, worker: w as u32, loss: 1.0, msg: delta_msg(&u, 4) }
            })
            .collect();
        let mut sync = ParameterServer::with_shards(x0.clone(), None, 16, 1);
        sync.broadcast(3);
        let part = sync.apply(&deltas).unwrap();
        let mut asyn = ParameterServer::with_shards(x0, None, 16, 1);
        asyn.broadcast(3);
        let rep = asyn.apply_async(&deltas, &StalenessPolicy::new(2, false)).unwrap();
        assert_eq!(sync.master(), asyn.master(), "fresh async round must equal sync apply");
        assert_eq!(rep.part.mean_loss, part.mean_loss);
        assert_eq!(rep.part.reporters, part.reporters);
        assert_eq!(rep.ages, vec![0, 0, 0]);
        assert!(rep.rejected.is_empty());
    }

    /// Bounded staleness: an in-window delta is applied, an over-window
    /// one is rejected (reported, weights unmoved by it), and a delta
    /// tagged ahead of the server counts as maximally stale.
    #[test]
    fn async_apply_admits_within_tau_and_rejects_beyond() {
        let mut ps = ParameterServer::new(vec![1.0; 4], None);
        for _ in 0..3 {
            ps.broadcast(2);
        } // server now at t = 3
        assert_eq!(ps.step(), 3);
        let fresh = ToServer::Delta { t: 3, worker: 0, loss: 1.0, msg: delta_msg(&[0.5; 4], 2) };
        let stale_ok =
            ToServer::Delta { t: 2, worker: 1, loss: 3.0, msg: delta_msg(&[1.0; 4], 2) };
        let too_old =
            ToServer::Delta { t: 0, worker: 2, loss: 9.0, msg: delta_msg(&[8.0; 4], 2) };
        let future =
            ToServer::Delta { t: 9, worker: 3, loss: 9.0, msg: delta_msg(&[8.0; 4], 2) };
        let rep = ps
            .apply_async(&[fresh, stale_ok, too_old, future], &StalenessPolicy::new(1, false))
            .unwrap();
        assert_eq!(rep.ages, vec![0, 1, 3, u64::MAX]);
        assert_eq!(rep.rejected, vec![2, 3]);
        assert_eq!(rep.part.reporters, vec![0, 1]);
        assert_eq!(rep.part.mean_loss, 2.0, "mean over the admitted set only");
        // mean of the two admitted deltas: (0.5 + 1.0) / 2 = 0.75 off each coord
        for v in ps.master() {
            assert!((v - 0.25).abs() < 1e-6, "{v}");
        }
    }

    /// An all-rejected round is legal: weights hold still, the loss is
    /// 0.0 (not NaN), and the same (worker, round) pair twice errors
    /// while the same worker at two different rounds does not.
    #[test]
    fn async_apply_survives_empty_admission_and_checks_dup_pairs() {
        let mut ps = ParameterServer::new(vec![1.0; 4], None);
        for _ in 0..4 {
            ps.broadcast(1);
        }
        let old = |t, worker| ToServer::Delta {
            t,
            worker,
            loss: 5.0,
            msg: delta_msg(&[1.0; 4], 2),
        };
        let rep = ps.apply_async(&[old(0, 0), old(1, 0)], &StalenessPolicy::new(0, false)).unwrap();
        assert!(rep.part.reporters.is_empty());
        assert_eq!(rep.rejected, vec![0, 1]);
        assert_eq!(rep.part.mean_loss, 0.0, "empty admission must not produce NaN");
        assert!(rep.part.mean_loss.is_finite());
        assert_eq!(ps.master(), &[1.0; 4][..]);
        // Same worker, same round, twice: transport bug, hard error.
        assert!(ps.apply_async(&[old(1, 0), old(1, 0)], &StalenessPolicy::new(0, false)).is_err());
        // Empty gather (no replies arrived this tick) is fine too.
        let rep = ps.apply_async(&[], &StalenessPolicy::new(0, false)).unwrap();
        assert!(rep.part.reporters.is_empty() && rep.ages.is_empty());
    }

    /// Age-down-weighting scales a stale delta by `1/(1+age)`; the
    /// remainder is reported via `ages` so the trainer can refund
    /// `(1 − w)` of the mass into the sender's residual.
    #[test]
    fn async_apply_down_weights_by_age() {
        let mut ps = ParameterServer::new(vec![1.0; 4], None);
        ps.broadcast(1);
        ps.broadcast(1); // t = 2
        let fresh = ToServer::Delta { t: 2, worker: 0, loss: 0.0, msg: delta_msg(&[1.0; 4], 2) };
        let old = ToServer::Delta { t: 1, worker: 1, loss: 0.0, msg: delta_msg(&[1.0; 4], 2) };
        let rep = ps.apply_async(&[fresh, old], &StalenessPolicy::new(2, true)).unwrap();
        assert!(rep.rejected.is_empty());
        // mean of [1.0·1.0, 0.5·1.0] = 0.75 pulled off each coordinate
        for v in ps.master() {
            assert!((v - 0.25).abs() < 1e-6, "{v}");
        }
    }
}
