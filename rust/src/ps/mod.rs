//! The parameter-server system (paper Fig. 1, Algorithms 2–3).
//!
//! * [`server::ParameterServer`] — holds the full-precision master
//!   weights, quantizes them for broadcast (`Q_x`), averages the
//!   decoded worker deltas and applies `x ← x − mean δ` (Alg. 2; the
//!   paper writes `+δ̂` with the descent sign folded into δ — we keep
//!   the explicit minus).
//! * [`worker::Worker`] — receives (quantized) weights, draws its data
//!   shard, computes the local stochastic gradient (PJRT model graph or
//!   a synthetic problem), runs its [`crate::optim::WorkerOpt`]
//!   (Alg. 3) and replies with the compressed delta.
//! * [`transport`] — how messages move, behind the [`Transport`] round
//!   contract: `LocalBus` (in-process, sequential, deterministic),
//!   `ThreadedBus` (in-process, one scoped thread per worker,
//!   bit-identical to `LocalBus`) and a TCP transport (length-prefixed
//!   frames) for the real multi-process deployment demo. The contract
//!   also carries the elastic-round hooks (`membership`, `shutdown`);
//!   straggler policies and deterministic fault injection live in
//!   [`crate::elastic`].
//! * [`protocol`] — the message types + byte accounting.

pub mod protocol;
pub mod server;
pub mod transport;
pub mod worker;

pub use protocol::{CommStats, ToServer, ToWorker};
pub use server::ParameterServer;
pub use transport::{LocalBus, ThreadedBus, Transport};
pub use worker::{GradSource, SimGradSource, Worker};
