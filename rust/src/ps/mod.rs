//! The parameter-server system (paper Fig. 1, Algorithms 2–3).
//!
//! * [`server::ParameterServer`] — holds the full-precision master
//!   weights, quantizes them for broadcast (`Q_x`), averages the
//!   decoded worker deltas and applies `x ← x − mean δ` (Alg. 2; the
//!   paper writes `+δ̂` with the descent sign folded into δ — we keep
//!   the explicit minus). One instance owns one contiguous range of
//!   the model — the whole vector in the unsharded (seed) deployment.
//! * [`shard`] — the scale-out layer: a [`shard::ShardPlan`] splits
//!   the flat vector into N contiguous ranges and a
//!   [`shard::ShardedServer`] runs one independent `ParameterServer`
//!   per range (its own EF residual, replica `x̂`, resync schedule,
//!   codec-policy controller and byte accounting). `--shards 1` is
//!   byte-identical to the unsharded engine.
//! * [`worker::Worker`] — receives (quantized) weights, draws its data
//!   shard, computes the local stochastic gradient (PJRT model graph or
//!   a synthetic problem), runs its [`crate::optim::WorkerOpt`]
//!   (Alg. 3) and replies with the compressed delta. The worker's
//!   optimizer state (Adam moments, EF residual) is **global** — only
//!   the wire messages are split per shard
//!   ([`worker::Worker::handle_sharded`]).
//! * [`transport`] — how messages move, behind the [`Transport`] round
//!   contract: `LocalBus` (in-process, sequential, deterministic),
//!   `ThreadedBus` (in-process, one scoped thread per worker,
//!   bit-identical to `LocalBus`) and a TCP transport (length-prefixed
//!   frames) for the real multi-process deployment. Sharded rounds run
//!   the same contract over N independent lanes
//!   ([`Transport::round_sharded`]); over TCP each shard is its own
//!   listener ([`transport::TcpShardGroup`], `qadam serve --shard-id`).
//!   The contract also carries the elastic-round hooks (`membership`,
//!   `shutdown`); straggler policies and deterministic fault injection
//!   live in [`crate::elastic`].
//! * [`protocol`] — the message types + byte accounting. Frames are
//!   shard-agnostic: a lane's connection (or in-process slot) *is* its
//!   shard routing, so the wire format is unchanged by sharding.

pub mod protocol;
pub mod server;
pub mod shard;
pub mod transport;
pub mod worker;

pub use protocol::{CommStats, ToServer, ToWorker};
pub use server::{AsyncApply, ParameterServer};
pub use shard::{AsyncRound, ShardPlan, ShardedServer};
pub use transport::{LocalBus, ThreadedBus, Transport};
pub use worker::{GradSource, SimGradSource, Worker};
