//! Client sampling and bounded staleness: the policy types behind
//! `--cohort` / `--registry` and `--async-rounds --staleness τ`.
//!
//! **Why sampling.** The ROADMAP's federated target is a registry of
//! 100k+ *logical* workers, of which only a small cohort contributes
//! each round — the regime every cross-device federated system runs in.
//! The paper's convergence argument is per-received-delta (the mean in
//! Alg. 2 runs over whoever reported), so a sampled cohort is already
//! inside the analysis: it only changes *which* workers' stochastic
//! gradients the round averages, exactly like partial participation.
//!
//! **Determinism contract.** The cohort of round `t` is a pure function
//! of `(registry seed, t)` drawn on its **own** rng stream
//! ([`COHORT_SALT`]) — it never consumes from the worker/chaos/server
//! streams, so enabling sampling cannot perturb a fixed-seed sync run,
//! and both ends of any wire (or a restarted run resuming at round `t`)
//! recompute the identical cohort independently. Per-round cost is
//! `O(K log K)` in the cohort size `K` and **independent of the
//! registry size** (Floyd's sampling draws exactly `K` variates).
//!
//! **Why bounded staleness composes with error feedback.** In async
//! mode a delta computed against round `t` may arrive when the server
//! is already at `now > t`. [`StalenessPolicy`] admits it while
//! `now − t ≤ τ` (optionally down-weighted by age); anything staler is
//! rejected, and the *rejected mass is folded back into that worker's
//! EF residual* — the same mechanism that absorbs quantization error
//! absorbs rejection (ECQ-SGD, Wu et al. 2018): the residual carries
//! the un-applied update into the worker's next reply, so no gradient
//! mass is silently lost. Efficient-Adam (Chen et al. 2022) analyzes
//! the two-way-compressed regime this extends.

use crate::quant::seeded_rng;

/// The dedicated rng stream salt for cohort draws. Sampling must never
/// consume from any other stream (worker, chaos, server downlink): a
/// fixed-seed sync run with sampling off is byte-identical to one where
/// sampling code merely exists.
pub const COHORT_SALT: u64 = 0xc047_5eed;

/// A registry of `size` logical workers (ids `0..size`), from which a
/// deterministic cohort is drawn per round. Purely virtual: the
/// registry stores no per-worker state — `O(1)` memory at any size.
#[derive(Clone, Debug)]
pub struct WorkerRegistry {
    size: u32,
    seed: u64,
}

impl WorkerRegistry {
    /// A registry of `size` logical workers. Ids travel the wire as
    /// `u32` (the `ToServer` worker field), which caps the registry at
    /// `u32::MAX` — comfortably past the 100k+ target.
    pub fn new(size: u64, seed: u64) -> Self {
        assert!(size > 0, "registry needs at least one logical worker");
        assert!(size <= u32::MAX as u64, "registry size exceeds the u32 wire id space");
        Self { size: size as u32, seed }
    }

    pub fn size(&self) -> u64 {
        self.size as u64
    }

    /// Round `t`'s cohort: `k` distinct logical worker ids, sorted
    /// ascending, drawn by Floyd's algorithm on the dedicated
    /// [`COHORT_SALT`] stream. Pure in `(seed, t, k)`: any process can
    /// recompute any round's cohort at any time (the trainer uses this
    /// to route a stale delta's refund to the slot that sent it).
    /// `k >= size` returns everyone.
    pub fn cohort(&self, t: u64, k: usize) -> Vec<u32> {
        let n = self.size as u64;
        if k as u64 >= n {
            return (0..self.size).collect();
        }
        let k = k as u64;
        let mut rng = seeded_rng(self.seed ^ COHORT_SALT, t);
        // Floyd's distinct sampling: k draws total, membership kept in
        // a sorted vec (INV-DET bans hash collections here; k is small).
        let mut chosen: Vec<u32> = Vec::with_capacity(k as usize);
        for j in (n - k)..n {
            let r = (rng.next_u64() % (j + 1)) as u32;
            let candidate = match chosen.binary_search(&r) {
                Ok(_) => j as u32, // r already chosen → take j (j > all prior draws)
                Err(_) => r,
            };
            match chosen.binary_search(&candidate) {
                Ok(_) => unreachable!("Floyd's invariant: j is never chosen twice"),
                Err(pos) => chosen.insert(pos, candidate),
            }
        }
        chosen
    }
}

/// The bounded-staleness admission rule of async rounds: a delta
/// computed against round `t`, arriving with the server at `now`, has
/// age `now − t`; it is applied while `age ≤ tau` and rejected past
/// that (the reject path refunds the decoded mass into the sender's EF
/// residual — see [`crate::quant::ErrorFeedback::absorb_range`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StalenessPolicy {
    /// Maximum admitted age in rounds (0 = only same-round deltas).
    pub tau: u64,
    /// Down-weight admitted deltas by age (`1/(1+age)`) instead of
    /// applying them at full weight. The un-applied fraction
    /// `(1−w)·δ` is refunded into the sender's residual, so mass is
    /// conserved either way.
    pub down_weight: bool,
}

impl StalenessPolicy {
    pub fn new(tau: u64, down_weight: bool) -> Self {
        Self { tau, down_weight }
    }

    /// Age of a delta tagged `t` at server round `now`. `t > now` can
    /// only come from a corrupt or hostile frame; treat it as maximally
    /// stale rather than wrapping.
    pub fn age(now: u64, t: u64) -> u64 {
        now.checked_sub(t).unwrap_or(u64::MAX)
    }

    /// Is a delta of this age applied (true) or rejected into the
    /// sender's EF residual (false)?
    pub fn admits(&self, age: u64) -> bool {
        age <= self.tau
    }

    /// The apply weight for an admitted delta of this age: 1 when
    /// down-weighting is off (age-0 deltas are always weight 1, so sync
    /// rounds are untouched), else `1/(1+age)`.
    pub fn weight(&self, age: u64) -> f32 {
        if self.down_weight {
            1.0 / (1.0 + age as f32)
        } else {
            1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cohort_is_distinct_sorted_and_in_range() {
        let reg = WorkerRegistry::new(1000, 7);
        for t in 1u64..=50 {
            let c = reg.cohort(t, 32);
            assert_eq!(c.len(), 32, "t={t}");
            assert!(c.windows(2).all(|p| p[0] < p[1]), "t={t}: not strictly ascending");
            assert!(c.iter().all(|&id| (id as u64) < reg.size()), "t={t}");
        }
    }

    #[test]
    fn cohort_is_deterministic_and_varies_by_round() {
        let reg = WorkerRegistry::new(100_000, 42);
        let a = reg.cohort(3, 32);
        let b = WorkerRegistry::new(100_000, 42).cohort(3, 32);
        assert_eq!(a, b, "same (seed, t, k) must redraw identically");
        let c = reg.cohort(4, 32);
        assert_ne!(a, c, "different rounds should draw different cohorts");
        let d = WorkerRegistry::new(100_000, 43).cohort(3, 32);
        assert_ne!(a, d, "different seeds should draw different cohorts");
    }

    #[test]
    fn cohort_covers_the_registry_over_time() {
        // With 8 logical workers and cohorts of 2, every id should be
        // drawn within a modest number of rounds — the draw is not
        // stuck on a subset.
        let reg = WorkerRegistry::new(8, 1);
        let mut seen = vec![false; 8];
        for t in 1u64..=200 {
            for id in reg.cohort(t, 2) {
                seen[id as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "some logical worker never sampled: {seen:?}");
    }

    #[test]
    fn oversized_cohort_returns_everyone() {
        let reg = WorkerRegistry::new(5, 9);
        assert_eq!(reg.cohort(1, 5), vec![0, 1, 2, 3, 4]);
        assert_eq!(reg.cohort(1, 50), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn cohort_cost_is_independent_of_registry_size() {
        // Structural proxy for the acceptance criterion (the example
        // measures wall-clock): the draw consumes exactly k rng
        // variates regardless of registry size, so two registries that
        // disagree only in size do identical work per draw.
        let small = WorkerRegistry::new(1_000, 5).cohort(7, 32);
        let large = WorkerRegistry::new(1_000_000_000, 5).cohort(7, 32);
        assert_eq!(small.len(), large.len());
    }

    #[test]
    fn staleness_policy_admits_and_weights_by_age() {
        let p = StalenessPolicy::new(2, false);
        assert!(p.admits(0) && p.admits(2));
        assert!(!p.admits(3));
        assert_eq!(p.weight(2), 1.0, "no down-weighting by default");
        let dw = StalenessPolicy::new(4, true);
        assert_eq!(dw.weight(0), 1.0, "age-0 deltas are never down-weighted");
        assert_eq!(dw.weight(1), 0.5);
        assert_eq!(dw.weight(3), 0.25);
        // a from-the-future tag is maximally stale, never admitted
        assert_eq!(StalenessPolicy::age(3, 9), u64::MAX);
        assert!(!p.admits(StalenessPolicy::age(3, 9)));
    }
}
