//! Elastic rounds: membership, straggler policies, and deterministic
//! chaos injection.
//!
//! The paper's multi-worker analysis (Theorems 3.2–3.3) assumes every
//! worker reports every round. A production parameter server does not
//! get that luxury: workers straggle, crash, and rejoin. Error feedback
//! is exactly the mechanism that absorbs a missed contribution — the
//! worker's residual carries the un-applied mass into its next reply
//! (Error-Compensated QSGD, Wu et al. 2018; server-side in
//! Efficient-Adam, Chen et al. 2022) — so the protocol can afford to
//! *drop* a straggler instead of waiting on it. This module makes that
//! policy explicit and testable:
//!
//! * [`membership`] — the participation layer of the round protocol:
//!   [`Participation`] (which workers a round's mean actually averaged
//!   over — `ParameterServer::apply` has always averaged over the
//!   *received* replies; this formalizes it), [`StragglerPolicy`]
//!   (`wait` = the seed behavior, `drop` = proceed at quorum), and
//!   [`Membership`] (who receives the next broadcast, the set
//!   `down_bytes` is charged for, plus the rejoin signal that forces a
//!   full-weights resync so delta-downlink replicas never diverge).
//! * [`sampling`] — client sampling and bounded staleness:
//!   [`WorkerRegistry`] (a 100k+-scale registry of logical workers
//!   with a per-round deterministic cohort draw on its own rng stream)
//!   and [`StalenessPolicy`] (the async-round admission rule: apply a
//!   delta while `now − t ≤ τ`, refund rejected mass into the sender's
//!   EF residual).
//! * [`chaos`] — a deterministic fault injector: [`ChaosPlan`] decides
//!   drop / delay / duplicate / corrupt-frame and crash/restart faults
//!   purely from `(seed, t, worker)` — no wall clock in the in-process
//!   engines — and [`ChaosTransport`] applies the plan behind the
//!   ordinary [`crate::ps::Transport`] round contract, wrapping any
//!   engine (sequential, threaded, TCP).
//!
//! Determinism contract: with an empty plan and [`StragglerPolicy::Wait`]
//! every engine is bit-identical to the unwrapped transport; with a
//! fixed plan seed a chaotic run is reproducible bit-for-bit across the
//! sequential and threaded engines (asserted in [`chaos`] tests).
//!
//! **Sharding contract.** Membership and fault decisions are
//! **worker-level, not lane-level**: a worker is present (or crashed,
//! or dropped) as a unit across every parameter-server shard it talks
//! to, so the per-shard reporter sets of a sharded round stay
//! consistent and one [`Membership`] covers all lanes. The exceptions
//! are deliberate: over TCP each shard listener tracks its own
//! connections (`ps::transport::TcpShardGroup::shard_memberships`
//! exposes the per-lane view so a driver can resync a single shard),
//! and a corrupt fault's *outcome* is per-lane (the same decision
//! bit-flips each lane's different frame). A worker's rejoin forces a
//! resync on every shard — it missed frames on every lane.

pub mod chaos;
pub mod membership;
pub mod sampling;

pub use chaos::{ChaosPlan, ChaosTransport, CrashWindow, FaultKind, FaultStats, ScheduledFault};
pub use membership::{Membership, Participation, StragglerPolicy};
pub use sampling::{StalenessPolicy, WorkerRegistry};
