//! Deterministic chaos injection behind the [`Transport`] contract.
//!
//! [`ChaosPlan`] is a pure function of `(seed, t, worker)`: every fault
//! decision comes from [`crate::quant::seeded_rng`] keyed by the plan
//! seed, the round and the worker id (or from an explicitly scheduled
//! fault list) — never from a wall clock — so a chaotic run on an
//! in-process engine is exactly reproducible, bit-for-bit across the
//! sequential and threaded engines. [`ChaosTransport`] wraps any
//! [`Transport`] and applies the plan:
//!
//! * **crash/restart** — a worker crashed at round `t` is excluded from
//!   the round entirely: it receives no broadcast, computes nothing,
//!   and advances none of its state (the in-process analogue of a dead
//!   process). On restart the membership report flips `rejoined`, which
//!   tells the driver to force a full-weights resync so the worker's
//!   delta-downlink replica is re-anchored before any delta frame.
//! * **drop** — the worker's reply is lost on the wire.
//! * **delay** — the reply arrives after the round deadline: delivered
//!   under [`StragglerPolicy::Wait`] (the round waits it out), dropped
//!   under [`StragglerPolicy::Drop`]. Under **async rounds**
//!   ([`ChaosTransport::with_async`]) a delayed reply is neither: it is
//!   *held* for `1 + lag` rounds and then re-injected verbatim, still
//!   tagged with its original round — genuine staleness for the
//!   bounded-staleness apply path to admit or refund.
//! * **duplicate** — the reply is retransmitted. Under `Wait` the extra
//!   copy is passed through so the server's duplicate rejection fires
//!   (the protocol-violation path); under `Drop` the elastic gather
//!   discards the retransmit and the round proceeds.
//! * **corrupt** — one deterministic bit of the serialized reply frame
//!   is flipped. A frame that no longer parses, or whose round/worker/
//!   dimension metadata changed, is dropped (what a checksum would do);
//!   a frame that still parses with intact metadata is delivered
//!   corrupted (silent payload corruption, the realistic worst case —
//!   still deterministic, because the flip is keyed by `(seed, t,
//!   worker)` over deterministic bytes).
//!
//! This is the *one* fault-injection mechanism in the tree: the ad-hoc
//! `drop_deltas` lists that used to live on `LocalBus`/`ThreadedBus`
//! are gone, and their tests run here against [`ChaosTransport`].

use super::membership::{Membership, StragglerPolicy};
use crate::ps::protocol::{ToServer, ToWorker};
use crate::ps::transport::Transport;
use crate::ps::worker::Worker;
use anyhow::{anyhow, Result};

/// A fault kind a [`ChaosPlan`] can inject on a worker's reply.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    Drop,
    Delay,
    Duplicate,
    Corrupt,
}

/// One explicitly scheduled reply fault (tests and scripted drills).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScheduledFault {
    pub kind: FaultKind,
    pub t: u64,
    pub worker: u32,
}

/// A crash window: worker `worker` is down for every round
/// `t ∈ [from, until)` and rejoins at round `until`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CrashWindow {
    pub worker: u32,
    pub from: u64,
    pub until: u64,
}

// Per-fault-kind salts so the probabilistic decisions are independent
// streams of the same plan seed.
const DROP_SALT: u64 = 0xc4a0_5_d201;
const DELAY_SALT: u64 = 0xc4a0_5_d202;
const DUP_SALT: u64 = 0xc4a0_5_d203;
const CORRUPT_SALT: u64 = 0xc4a0_5_d204;
const CORRUPT_BIT_SALT: u64 = 0xc4a0_5_d205;

/// A deterministic fault plan. Probabilistic rates fire per
/// `(t, worker)` from the plan seed; `scheduled` and `crashes` fire
/// exactly when listed. The empty (default) plan injects nothing.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChaosPlan {
    pub seed: u64,
    /// Per-reply drop probability.
    pub drop_p: f32,
    /// Per-reply past-deadline delay probability.
    pub delay_p: f32,
    /// Per-reply duplicate (retransmit) probability.
    pub dup_p: f32,
    /// Per-reply frame-corruption probability.
    pub corrupt_p: f32,
    /// Extra rounds of lag for a delayed reply under **async** rounds
    /// ([`ChaosTransport::with_async`]): a delay fault holds the reply
    /// until round `t + 1 + lag` instead of dropping it. Ignored in
    /// sync mode, where a delay means "missed the deadline".
    pub lag: u64,
    /// Crash/restart windows.
    pub crashes: Vec<CrashWindow>,
    /// Explicitly scheduled one-off faults.
    pub scheduled: Vec<ScheduledFault>,
}

impl ChaosPlan {
    /// A plan that drops exactly the listed `(t, worker)` replies — the
    /// successor of the old `drop_deltas` lists.
    pub fn dropping(faults: &[(u64, u32)]) -> Self {
        Self {
            scheduled: faults
                .iter()
                .map(|&(t, worker)| ScheduledFault { kind: FaultKind::Drop, t, worker })
                .collect(),
            ..Self::default()
        }
    }

    /// Add a crash window (builder style, for tests and examples).
    pub fn with_crash(mut self, worker: u32, from: u64, until: u64) -> Self {
        self.crashes.push(CrashWindow { worker, from, until });
        self
    }

    /// Parse the CLI spec: comma-separated `key=value` tokens.
    ///
    /// ```text
    ///   seed=7,drop=0.1,delay=0.05,dup=0.01,corrupt=0.02,crash=3@40..80
    /// ```
    ///
    /// `drop`/`delay`/`dup`/`corrupt` are probabilities in `[0, 1]`;
    /// `crash=W@A..B` (repeatable) takes worker `W` down for rounds
    /// `[A, B)`; `lag=N` adds `N` extra rounds to every delayed reply
    /// under async rounds (no effect in sync mode).
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = ChaosPlan::default();
        for tok in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (k, v) = tok
                .split_once('=')
                .ok_or_else(|| anyhow!("chaos token '{tok}' is not key=value"))?;
            match k {
                "seed" => {
                    plan.seed =
                        v.parse().map_err(|e| anyhow!("bad chaos seed '{v}': {e}"))?;
                }
                "drop" => plan.drop_p = parse_prob(k, v)?,
                "delay" => plan.delay_p = parse_prob(k, v)?,
                "dup" => plan.dup_p = parse_prob(k, v)?,
                "corrupt" => plan.corrupt_p = parse_prob(k, v)?,
                "lag" => {
                    plan.lag = v.parse().map_err(|e| anyhow!("bad chaos lag '{v}': {e}"))?;
                }
                "crash" => plan.crashes.push(parse_crash(v)?),
                other => {
                    return Err(anyhow!(
                        "unknown chaos key '{other}' (seed|drop|delay|dup|corrupt|lag|crash)"
                    ))
                }
            }
        }
        Ok(plan)
    }

    /// True when the plan injects nothing (every decision is a no-op).
    pub fn is_empty(&self) -> bool {
        self.drop_p == 0.0
            && self.delay_p == 0.0
            && self.dup_p == 0.0
            && self.corrupt_p == 0.0
            && self.crashes.is_empty()
            && self.scheduled.is_empty()
    }

    fn hit(&self, kind: FaultKind, t: u64, worker: u32) -> bool {
        self.scheduled.iter().any(|f| f.kind == kind && f.t == t && f.worker == worker)
    }

    fn roll(&self, salt: u64, p: f32, t: u64, worker: u32) -> bool {
        p > 0.0
            && crate::quant::seeded_rng(self.seed ^ salt, (t << 20) ^ worker as u64).gen_f32() < p
    }

    pub fn drops(&self, t: u64, worker: u32) -> bool {
        self.hit(FaultKind::Drop, t, worker) || self.roll(DROP_SALT, self.drop_p, t, worker)
    }

    pub fn delays(&self, t: u64, worker: u32) -> bool {
        self.hit(FaultKind::Delay, t, worker) || self.roll(DELAY_SALT, self.delay_p, t, worker)
    }

    pub fn duplicates(&self, t: u64, worker: u32) -> bool {
        self.hit(FaultKind::Duplicate, t, worker) || self.roll(DUP_SALT, self.dup_p, t, worker)
    }

    pub fn corrupts(&self, t: u64, worker: u32) -> bool {
        self.hit(FaultKind::Corrupt, t, worker)
            || self.roll(CORRUPT_SALT, self.corrupt_p, t, worker)
    }

    /// Is `worker` down for round `t`?
    pub fn crashed(&self, t: u64, worker: u32) -> bool {
        self.crashes.iter().any(|c| c.worker == worker && c.from <= t && t < c.until)
    }

    /// Does any of `0..total` worker ids come back at round `t` after
    /// being down at `t − 1`? (In-process worker ids are `0..total`.)
    pub fn any_rejoin(&self, t: u64, total: usize) -> bool {
        t > 1 && (0..total as u32).any(|w| !self.crashed(t, w) && self.crashed(t - 1, w))
    }
}

fn parse_prob(key: &str, v: &str) -> Result<f32> {
    let p: f32 = v.parse().map_err(|e| anyhow!("bad chaos {key} '{v}': {e}"))?;
    if !(0.0..=1.0).contains(&p) {
        return Err(anyhow!("chaos {key}={p} outside [0, 1]"));
    }
    Ok(p)
}

fn parse_crash(v: &str) -> Result<CrashWindow> {
    let (w, range) = v
        .split_once('@')
        .ok_or_else(|| anyhow!("chaos crash '{v}' is not W@A..B"))?;
    let (a, b) = range
        .split_once("..")
        .ok_or_else(|| anyhow!("chaos crash range '{range}' is not A..B"))?;
    let worker: u32 = w.parse().map_err(|e| anyhow!("bad crash worker '{w}': {e}"))?;
    let from: u64 = a.parse().map_err(|e| anyhow!("bad crash start '{a}': {e}"))?;
    let until: u64 = b.parse().map_err(|e| anyhow!("bad crash end '{b}': {e}"))?;
    if from == 0 || until <= from {
        return Err(anyhow!("chaos crash window {from}..{until} is empty (rounds start at 1)"));
    }
    Ok(CrashWindow { worker, from, until })
}

/// Counters of what a [`ChaosTransport`] actually injected.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Replies lost outright (drop faults + corrupt frames that no
    /// longer parsed).
    pub dropped: u64,
    /// Replies that missed the deadline (dropped only under
    /// [`StragglerPolicy::Drop`]).
    pub delayed: u64,
    /// Replies retransmitted.
    pub duplicated: u64,
    /// Reply frames bit-flipped.
    pub corrupted: u64,
    /// Worker-rounds skipped because the worker was crashed.
    pub crashed: u64,
}

/// A [`Transport`] wrapper that injects the plan's faults around any
/// inner engine and enforces the straggler policy's quorum.
///
/// Crash faults act on the in-process worker set (ids are assumed to be
/// `0..n`, as the trainer assigns them); over TCP the worker slice is
/// empty and crashes are modeled by the remote process actually dying —
/// the reply-level faults (drop/delay/duplicate/corrupt) apply to every
/// engine.
pub struct ChaosTransport {
    inner: Box<dyn Transport>,
    plan: ChaosPlan,
    policy: StragglerPolicy,
    min_participation: usize,
    /// Async (bounded-staleness) mode: a delay fault *holds* the reply
    /// in `held` and re-injects it — verbatim, without re-rolling any
    /// fault — once the round counter reaches its release round,
    /// instead of delivering late (Wait) or dropping (Drop). Quorum is
    /// not enforced: an empty async round is legal.
    async_mode: bool,
    /// Held delayed replies: `(release round, lane, reply)`, in
    /// deterministic insertion order.
    held: Vec<(u64, usize, ToServer)>,
    pub stats: FaultStats,
}

impl ChaosTransport {
    pub fn new(inner: Box<dyn Transport>, plan: ChaosPlan) -> Self {
        Self {
            inner,
            plan,
            policy: StragglerPolicy::Wait,
            min_participation: 1,
            async_mode: false,
            held: Vec::new(),
            stats: FaultStats::default(),
        }
    }

    /// Set the straggler policy and the quorum a round must meet.
    pub fn with_policy(mut self, policy: StragglerPolicy, min_participation: usize) -> Self {
        self.policy = policy;
        self.min_participation = min_participation.max(1);
        self
    }

    /// Switch to async (bounded-staleness) rounds: delay faults hold
    /// the reply for `1 + plan.lag` rounds and then re-inject it with
    /// its **original round tag**, modeling a slow worker whose delta
    /// arrives late instead of never — the input
    /// `ShardedServer::apply_async` admits it within `τ` or rejects it
    /// into the sender's error-feedback refund path. Sync mode
    /// (`with_async(false)`, the default) is byte-identical to the
    /// seed behavior.
    pub fn with_async(mut self, on: bool) -> Self {
        self.async_mode = on;
        self
    }

    /// Replies currently held by async delay faults (release round,
    /// lane, reply) — test/driver introspection, never mutating.
    pub fn held_replies(&self) -> &[(u64, usize, ToServer)] {
        &self.held
    }

    pub fn plan(&self) -> &ChaosPlan {
        &self.plan
    }

    /// Apply the plan's reply-level faults to one lane's gathered
    /// replies, in the deterministic gather order — the shared tail of
    /// the unsharded round and of each sharded lane. `lane` routes
    /// async-held delayed replies back to the lane they came from.
    fn apply_reply_faults(&mut self, lane: usize, replies: Vec<ToServer>) -> Vec<ToServer> {
        let mut out = Vec::with_capacity(replies.len());
        for reply in replies {
            let (rt, rw) = (reply.round(), reply.worker());
            if self.plan.drops(rt, rw) {
                self.stats.dropped += 1;
                continue;
            }
            if self.plan.delays(rt, rw) {
                self.stats.delayed += 1;
                if self.async_mode {
                    // Held verbatim (no fault re-roll at release): the
                    // reply arrives `1 + lag` rounds late, still tagged
                    // with the round it was computed against.
                    self.held.push((rt + 1 + self.plan.lag, lane, reply));
                    continue;
                }
                if self.policy == StragglerPolicy::Drop {
                    continue; // missed the deadline
                }
            }
            let duplicated = self.plan.duplicates(rt, rw);
            let delivered = if self.plan.corrupts(rt, rw) {
                self.stats.corrupted += 1;
                self.corrupt_reply(&reply, rt, rw)
            } else {
                Some(reply)
            };
            match delivered {
                None => self.stats.dropped += 1, // corrupt frame failed to parse
                Some(r) => {
                    if duplicated {
                        self.stats.duplicated += 1;
                        if self.policy == StragglerPolicy::Wait {
                            // surface the retransmit so the server's
                            // duplicate rejection fires
                            out.push(r.clone());
                        }
                    }
                    out.push(r);
                }
            }
        }
        out
    }

    /// Release every held reply whose round has come for `lane`,
    /// prepending them (in their deterministic insertion order) ahead
    /// of the round's fresh replies — the oldest mass lands first.
    fn release_held(&mut self, t: u64, lane: usize, fresh: Vec<ToServer>) -> Vec<ToServer> {
        if self.held.is_empty() {
            return fresh;
        }
        let taken = std::mem::take(&mut self.held);
        let mut out = Vec::with_capacity(taken.len() + fresh.len());
        for (release, l, r) in taken {
            if l == lane && release <= t {
                out.push(r);
            } else {
                self.held.push((release, l, r));
            }
        }
        out.extend(fresh);
        out
    }

    /// Flip one deterministic bit of the serialized reply. Returns the
    /// reparsed frame when it still parses with intact `(t, worker, n)`
    /// metadata, `None` (dropped) otherwise.
    fn corrupt_reply(&self, reply: &ToServer, t: u64, worker: u32) -> Option<ToServer> {
        let mut bytes = reply.to_bytes();
        let mut rng =
            crate::quant::seeded_rng(self.plan.seed ^ CORRUPT_BIT_SALT, (t << 20) ^ worker as u64);
        let bit = (rng.next_u64() as usize) % (bytes.len() * 8);
        bytes[bit / 8] ^= 1 << (bit % 8);
        match ToServer::from_bytes(&bytes) {
            Ok(parsed)
                if parsed.round() == t
                    && parsed.worker() == worker
                    && parsed.payload_n() == reply.payload_n() =>
            {
                Some(parsed)
            }
            _ => None,
        }
    }
}

impl Transport for ChaosTransport {
    fn round(
        &mut self,
        broadcast: &ToWorker,
        workers: &mut [Worker],
    ) -> Result<Vec<ToServer>> {
        let t = match broadcast {
            ToWorker::Weights { t, .. }
            | ToWorker::WeightsDelta { t, .. }
            | ToWorker::WeightsDeltaParts { t, .. } => *t,
            ToWorker::Shutdown => return self.inner.round(broadcast, workers),
        };
        if self.plan.is_empty() {
            let replies = self.inner.round(broadcast, workers)?;
            return self.check_quorum(t, replies);
        }

        // Crash faults: a crashed worker receives nothing and computes
        // nothing. Stable-partition the slice so the alive workers form
        // an id-ordered prefix the inner engine can run on, then
        // restore id order (the Transport gather contract).
        let n_crashed = workers.iter().filter(|w| self.plan.crashed(t, w.id)).count();
        let replies = if n_crashed == 0 {
            self.inner.round(broadcast, workers)?
        } else {
            self.stats.crashed += n_crashed as u64;
            let plan = &self.plan;
            workers.sort_by_key(|w| plan.crashed(t, w.id)); // stable: alive prefix stays id-ordered
            let n_alive = workers.len() - n_crashed;
            let r = self.inner.round(broadcast, &mut workers[..n_alive]);
            workers.sort_by_key(|w| w.id);
            r?
        };

        let out = self.apply_reply_faults(0, replies);
        let out = self.release_held(t, 0, out);
        self.check_quorum(t, out)
    }

    /// Sharded rounds: **crash and reply-level fault decisions stay
    /// keyed by `(t, worker)`** — a worker faults as a unit, so a
    /// crashed or dropped worker loses *every* lane of the round and
    /// the per-shard reporter sets stay consistent. Only corruption is
    /// per-lane in its outcome: the same decision flips one bit of each
    /// lane's (different) frame, and each lane independently delivers
    /// or drops the result. [`FaultStats`] consequently count per-lane
    /// events in multi-shard rounds.
    fn round_sharded(
        &mut self,
        broadcasts: &[ToWorker],
        workers: &mut [Worker],
    ) -> Result<Vec<Vec<ToServer>>> {
        if broadcasts.len() == 1 {
            // the unsharded chaos path, byte-identical
            return Ok(vec![self.round(&broadcasts[0], workers)?]);
        }
        let t = match &broadcasts[0] {
            ToWorker::Weights { t, .. }
            | ToWorker::WeightsDelta { t, .. }
            | ToWorker::WeightsDeltaParts { t, .. } => *t,
            ToWorker::Shutdown => return self.inner.round_sharded(broadcasts, workers),
        };
        if self.plan.is_empty() {
            let lanes = self.inner.round_sharded(broadcasts, workers)?;
            return lanes.into_iter().map(|r| self.check_quorum(t, r)).collect();
        }
        let n_crashed = workers.iter().filter(|w| self.plan.crashed(t, w.id)).count();
        let lanes = if n_crashed == 0 {
            self.inner.round_sharded(broadcasts, workers)?
        } else {
            self.stats.crashed += n_crashed as u64;
            let plan = &self.plan;
            workers.sort_by_key(|w| plan.crashed(t, w.id)); // stable: alive prefix stays id-ordered
            let n_alive = workers.len() - n_crashed;
            let r = self.inner.round_sharded(broadcasts, &mut workers[..n_alive]);
            workers.sort_by_key(|w| w.id);
            r?
        };
        lanes
            .into_iter()
            .enumerate()
            .map(|(li, lane)| {
                let out = self.apply_reply_faults(li, lane);
                let out = self.release_held(t, li, out);
                self.check_quorum(t, out)
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "chaos"
    }

    fn membership(&mut self, next_t: u64, total: usize) -> Membership {
        let inner = self.inner.membership(next_t, total);
        if self.plan.crashes.is_empty() {
            return inner;
        }
        let crashed = (0..total as u32).filter(|&w| self.plan.crashed(next_t, w)).count();
        Membership {
            expected: inner.expected,
            present: inner.present.saturating_sub(crashed),
            rejoined: inner.rejoined || self.plan.any_rejoin(next_t, total),
        }
    }

    fn shutdown(&mut self) -> Result<()> {
        self.inner.shutdown()
    }

    fn fault_stats(&self) -> Option<FaultStats> {
        Some(self.stats)
    }

    fn straggler_evictions(&self) -> u64 {
        self.inner.straggler_evictions()
    }
}

impl ChaosTransport {
    fn check_quorum(&self, t: u64, replies: Vec<ToServer>) -> Result<Vec<ToServer>> {
        if self.async_mode {
            // Async rounds have no quorum: an empty harvest is a legal
            // (weight-preserving) round.
            return Ok(replies);
        }
        if self.policy == StragglerPolicy::Drop && replies.len() < self.min_participation {
            return Err(anyhow!(
                "round {t} below quorum: {} replies, need {}",
                replies.len(),
                self.min_participation
            ));
        }
        Ok(replies)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{LrSchedule, QAdamEf};
    use crate::ps::transport::{LocalBus, ThreadedBus};
    use crate::ps::worker::SimGradSource;
    use crate::ps::ParameterServer;
    use crate::quant::LogQuant;

    fn mk_worker(id: u32, dim: usize) -> Worker {
        let src = SimGradSource { problem: crate::sim::StochasticProblem::new(dim, 0.05, 9) };
        let opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.02 });
        Worker::new(id, Box::new(opt), Box::new(src), 1)
    }

    fn reply_ids(replies: &[ToServer]) -> Vec<u32> {
        replies.iter().map(|r| r.worker()).collect()
    }

    #[test]
    fn spec_parse_roundtrip_and_errors() {
        let p = ChaosPlan::parse("seed=7, drop=0.1,delay=0.05,dup=0.01,corrupt=0.02,crash=3@40..80").unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.drop_p, 0.1);
        assert_eq!(p.delay_p, 0.05);
        assert_eq!(p.dup_p, 0.01);
        assert_eq!(p.corrupt_p, 0.02);
        assert_eq!(p.crashes, vec![CrashWindow { worker: 3, from: 40, until: 80 }]);
        assert!(!p.is_empty());
        // repeatable crash windows
        let p = ChaosPlan::parse("crash=0@2..4,crash=1@5..6").unwrap();
        assert_eq!(p.crashes.len(), 2);
        assert!(ChaosPlan::parse("").unwrap().is_empty());
        // lag only shapes async delay release; alone it injects nothing
        let p = ChaosPlan::parse("lag=2,delay=0.1").unwrap();
        assert_eq!(p.lag, 2);
        assert!(ChaosPlan::parse("lag=2").unwrap().is_empty());
        assert!(ChaosPlan::parse("lag=x").is_err());
        assert!(ChaosPlan::parse("drop=1.5").is_err()); // outside [0,1]
        assert!(ChaosPlan::parse("frobnicate=1").is_err());
        assert!(ChaosPlan::parse("drop").is_err()); // not key=value
        assert!(ChaosPlan::parse("crash=0@5..5").is_err()); // empty window
        assert!(ChaosPlan::parse("crash=0@0..5").is_err()); // rounds start at 1
    }

    #[test]
    fn plan_decisions_are_deterministic_in_seed_t_worker() {
        let p = ChaosPlan { seed: 11, drop_p: 0.3, ..Default::default() };
        for t in 1u64..=50 {
            for w in 0u32..8 {
                assert_eq!(p.drops(t, w), p.clone().drops(t, w));
            }
        }
        // a different seed gives a different pattern somewhere
        let q = ChaosPlan { seed: 12, drop_p: 0.3, ..Default::default() };
        let diff = (1u64..=50).any(|t| (0u32..8).any(|w| p.drops(t, w) != q.drops(t, w)));
        assert!(diff, "seed must steer the fault pattern");
    }

    #[test]
    fn crash_windows_and_rejoin_signal() {
        let p = ChaosPlan::default().with_crash(1, 4, 8);
        assert!(!p.crashed(3, 1));
        assert!(p.crashed(4, 1) && p.crashed(7, 1));
        assert!(!p.crashed(8, 1));
        assert!(!p.crashed(5, 0));
        for t in 1u64..=12 {
            assert_eq!(p.any_rejoin(t, 3), t == 8, "t={t}");
        }
    }

    /// Ported from `local_bus_fault_injection_drops_delta`: a scheduled
    /// drop removes exactly that worker's reply, the server still makes
    /// progress on the rest.
    #[test]
    fn chaos_drop_fault_drops_delta() {
        let dim = 8;
        let mut ps = ParameterServer::new(vec![1.0; dim], None);
        let mut workers: Vec<Worker> = (0..3).map(|i| mk_worker(i, dim)).collect();
        let mut bus = ChaosTransport::new(Box::new(LocalBus::default()), ChaosPlan::dropping(&[(1, 1)]));
        let replies = {
            let (b, _) = ps.broadcast(3);
            bus.round(&b, &mut workers).unwrap()
        };
        assert_eq!(replies.len(), 2); // worker 1's delta dropped
        assert_eq!(bus.stats.dropped, 1);
        ps.apply(&replies).unwrap(); // PS still makes progress on the rest
    }

    /// Ported from `local_bus_drop_deltas_is_step_scoped_and_order_preserving`:
    /// scheduled drops are per-(step, worker) — only the scheduled round
    /// loses the delta, later rounds from the same worker go through,
    /// and the surviving replies keep worker-id order.
    #[test]
    fn chaos_drop_is_step_scoped_and_order_preserving() {
        let dim = 8;
        let mut ps = ParameterServer::new(vec![1.0; dim], None);
        let mut workers: Vec<Worker> = (0..4).map(|i| mk_worker(i, dim)).collect();
        let mut bus =
            ChaosTransport::new(Box::new(LocalBus::default()), ChaosPlan::dropping(&[(2, 0), (2, 3)]));
        for t in 1u64..=3 {
            let replies = {
                let (b, _) = ps.broadcast(4);
                bus.round(&b, &mut workers).unwrap()
            };
            if t == 2 {
                assert_eq!(reply_ids(&replies), vec![1, 2]); // 0 and 3 dropped this round only
            } else {
                assert_eq!(reply_ids(&replies), vec![0, 1, 2, 3]);
            }
            ps.apply(&replies).unwrap();
        }
    }

    /// Ported from `threaded_bus_honors_drop_deltas`: the same plan
    /// applies over the threaded engine.
    #[test]
    fn chaos_drop_on_threaded_bus() {
        let dim = 8;
        let mut ps = ParameterServer::new(vec![1.0; dim], None);
        let mut workers: Vec<Worker> = (0..3).map(|i| mk_worker(i, dim)).collect();
        let mut bus = ChaosTransport::new(Box::new(ThreadedBus::new()), ChaosPlan::dropping(&[(1, 2)]));
        let replies = {
            let (b, _) = ps.broadcast(3);
            bus.round(&b, &mut workers).unwrap()
        };
        assert_eq!(reply_ids(&replies), vec![0, 1]);
    }

    /// An empty plan under Wait is a pure pass-through: trajectories are
    /// bit-identical to the unwrapped engine.
    #[test]
    fn empty_plan_is_bit_identical_to_bare_bus() {
        let dim = 64;
        let x0: Vec<f32> = (0..dim).map(|i| 0.3 + 0.01 * (i as f32).sin()).collect();
        let mut ps_bare = ParameterServer::new(x0.clone(), Some(4));
        let mut ws_bare: Vec<Worker> = (0..3).map(|i| mk_worker(i, dim)).collect();
        let bare = LocalBus::default();
        let mut ps_chaos = ParameterServer::new(x0, Some(4));
        let mut ws_chaos: Vec<Worker> = (0..3).map(|i| mk_worker(i, dim)).collect();
        let mut chaos = ChaosTransport::new(Box::new(LocalBus::default()), ChaosPlan::default());
        for t in 1u64..=25 {
            let r_bare = {
                let (b, _) = ps_bare.broadcast(3);
                bare.round(&b, &mut ws_bare).unwrap()
            };
            ps_bare.apply(&r_bare).unwrap();
            let r_chaos = {
                let (b, _) = ps_chaos.broadcast(3);
                chaos.round(&b, &mut ws_chaos).unwrap()
            };
            ps_chaos.apply(&r_chaos).unwrap();
            assert_eq!(ps_bare.master(), ps_chaos.master(), "diverged at round {t}");
        }
        assert_eq!(ps_bare.stats.down_bytes, ps_chaos.stats.down_bytes);
        assert_eq!(ps_bare.stats.up_bytes, ps_chaos.stats.up_bytes);
        assert_eq!(chaos.stats, FaultStats::default());
    }

    /// Acceptance: a fixed-seed chaotic run (probabilistic drops/delays
    /// plus a crash window) is bit-reproducible across the sequential
    /// and threaded engines — same masters, same replicas, same fault
    /// pattern, same byte accounting, round by round.
    #[test]
    fn fixed_seed_chaos_bit_reproducible_across_engines() {
        let dim = 96;
        let x0: Vec<f32> = (0..dim).map(|i| 0.3 + 0.01 * (i as f32).sin()).collect();
        let plan = ChaosPlan::parse("seed=5,drop=0.2,delay=0.15,crash=2@6..11").unwrap();
        let mk_ps = |x0: Vec<f32>, block: usize, threads: usize| -> ParameterServer {
            let mut ps = ParameterServer::with_shards(x0, Some(4), block, threads);
            ps.enable_delta_downlink(Box::new(LogQuant::new(2)), 7);
            ps
        };
        let mut ps_seq = mk_ps(x0.clone(), crate::ps::server::DEFAULT_BLOCK, 1);
        let mut ws_seq: Vec<Worker> = (0..4).map(|i| mk_worker(i, dim)).collect();
        let mut seq = ChaosTransport::new(Box::new(LocalBus::default()), plan.clone())
            .with_policy(StragglerPolicy::Drop, 1);
        let mut ps_thr = mk_ps(x0, 13, 4);
        let mut ws_thr: Vec<Worker> = (0..4).map(|i| mk_worker(i, dim)).collect();
        let mut thr = ChaosTransport::new(Box::new(ThreadedBus::new()), plan)
            .with_policy(StragglerPolicy::Drop, 1);
        let mut applied = 0u32;
        for t in 1u64..=30 {
            let m_seq = seq.membership(t, 4);
            let m_thr = thr.membership(t, 4);
            assert_eq!(m_seq, m_thr, "membership diverged at round {t}");
            if m_seq.rejoined {
                ps_seq.force_resync();
                ps_thr.force_resync();
            }
            let r_seq = {
                let (b, _) = ps_seq.broadcast(m_seq.present);
                seq.round(&b, &mut ws_seq)
            };
            let r_thr = {
                let (b, _) = ps_thr.broadcast(m_thr.present);
                thr.round(&b, &mut ws_thr)
            };
            match (r_seq, r_thr) {
                (Ok(a), Ok(c)) => {
                    assert_eq!(reply_ids(&a), reply_ids(&c), "gather diverged at round {t}");
                    let pa = ps_seq.apply(&a).unwrap();
                    let pc = ps_thr.apply(&c).unwrap();
                    assert_eq!(pa, pc, "participation diverged at round {t}");
                    applied += 1;
                }
                (Err(ea), Err(ec)) => assert_eq!(ea.to_string(), ec.to_string()),
                (a, c) => panic!("engines disagree at round {t}: {a:?} vs {c:?}"),
            }
            assert_eq!(ps_seq.master(), ps_thr.master(), "masters diverged at round {t}");
            assert_eq!(
                ps_seq.downlink_state().unwrap().0,
                ps_thr.downlink_state().unwrap().0,
                "replicas diverged at round {t}"
            );
        }
        assert_eq!(seq.stats, thr.stats, "fault patterns diverged");
        assert_eq!(ps_seq.stats.down_bytes, ps_thr.stats.down_bytes);
        assert_eq!(ps_seq.stats.up_bytes, ps_thr.stats.up_bytes);
        assert!(applied > 0, "the fixed seed must leave some applicable rounds");
        assert!(seq.stats.dropped + seq.stats.delayed > 0, "the plan must actually fire");
        assert!(seq.stats.crashed > 0);
    }

    /// Acceptance: delta-downlink replica parity holds across a
    /// crash/rejoin cycle — the rejoin flips `Membership::rejoined`,
    /// the forced resync re-anchors the returning worker, and every
    /// participating worker equals the server replica on every round.
    #[test]
    fn crash_rejoin_replica_parity_with_forced_resync() {
        let dim = 48;
        let mut ps = ParameterServer::new(vec![0.5; dim], None);
        ps.enable_delta_downlink(Box::new(LogQuant::new(2)), 0); // resync only round 1 / forced
        let mut workers: Vec<Worker> = (0..3).map(|i| mk_worker(i, dim)).collect();
        let plan = ChaosPlan::default().with_crash(1, 4, 8);
        let mut bus = ChaosTransport::new(Box::new(LocalBus::default()), plan);
        for t in 1u64..=12 {
            let m = bus.membership(t, 3);
            assert_eq!(m.present, if (4..8).contains(&t) { 2 } else { 3 }, "t={t}");
            assert_eq!(m.rejoined, t == 8, "t={t}");
            if m.rejoined {
                ps.force_resync();
            }
            let replies = {
                let (b, _) = ps.broadcast(m.present);
                if t == 8 {
                    assert!(matches!(b, ToWorker::Weights { .. }), "rejoin round must resync");
                } else if t > 1 {
                    assert!(matches!(b, ToWorker::WeightsDelta { .. }), "t={t}");
                }
                bus.round(&b, &mut workers).unwrap()
            };
            if (4..8).contains(&t) {
                assert_eq!(reply_ids(&replies), vec![0, 2]);
            } else {
                assert_eq!(reply_ids(&replies), vec![0, 1, 2]);
            }
            let part = ps.apply(&replies).unwrap();
            assert_eq!(part.reporters, reply_ids(&replies));
            // the crash partition must leave the slice back in id order
            let order: Vec<u32> = workers.iter().map(|w| w.id).collect();
            assert_eq!(order, vec![0, 1, 2]);
            let (replica, _) = ps.downlink_state().unwrap();
            for w in &workers {
                if w.id == 1 && (4..8).contains(&t) {
                    continue; // crashed: stale by design until the rejoin resync
                }
                assert_eq!(w.weights(), replica, "worker {} != replica at round {t}", w.id);
            }
        }
        assert_eq!(ps.stats.resyncs, 2, "round 1 + the forced rejoin resync");
        assert_eq!(bus.stats.crashed, 4, "worker 1 skipped rounds 4..8");
    }

    /// Duplicate faults: under Wait the retransmit reaches the server
    /// and its duplicate rejection fires; under Drop the elastic gather
    /// discards the retransmit and the round applies cleanly.
    #[test]
    fn duplicate_fault_rejected_under_wait_dropped_under_drop() {
        let dim = 8;
        let plan = || ChaosPlan {
            scheduled: vec![ScheduledFault { kind: FaultKind::Duplicate, t: 1, worker: 1 }],
            ..Default::default()
        };
        // Wait: the duplicate passes through, apply rejects the round.
        let mut ps = ParameterServer::new(vec![1.0; dim], None);
        let mut workers: Vec<Worker> = (0..3).map(|i| mk_worker(i, dim)).collect();
        let mut bus = ChaosTransport::new(Box::new(LocalBus::default()), plan());
        let replies = {
            let (b, _) = ps.broadcast(3);
            bus.round(&b, &mut workers).unwrap()
        };
        assert_eq!(reply_ids(&replies), vec![0, 1, 1, 2]);
        let err = ps.apply(&replies).unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
        // Drop: the retransmit is discarded at the gather.
        let mut ps = ParameterServer::new(vec![1.0; dim], None);
        let mut workers: Vec<Worker> = (0..3).map(|i| mk_worker(i, dim)).collect();
        let mut bus = ChaosTransport::new(Box::new(LocalBus::default()), plan())
            .with_policy(StragglerPolicy::Drop, 1);
        let replies = {
            let (b, _) = ps.broadcast(3);
            bus.round(&b, &mut workers).unwrap()
        };
        assert_eq!(reply_ids(&replies), vec![0, 1, 2]);
        ps.apply(&replies).unwrap();
        assert_eq!(bus.stats.duplicated, 1);
    }

    /// Delay faults only drop the reply when the policy says the round
    /// stops waiting.
    #[test]
    fn delay_drops_only_under_drop_policy() {
        let dim = 8;
        let plan = || ChaosPlan {
            scheduled: vec![ScheduledFault { kind: FaultKind::Delay, t: 1, worker: 0 }],
            ..Default::default()
        };
        let mut ps = ParameterServer::new(vec![1.0; dim], None);
        let mut workers: Vec<Worker> = (0..2).map(|i| mk_worker(i, dim)).collect();
        let mut wait = ChaosTransport::new(Box::new(LocalBus::default()), plan());
        let replies = {
            let (b, _) = ps.broadcast(2);
            wait.round(&b, &mut workers).unwrap()
        };
        assert_eq!(reply_ids(&replies), vec![0, 1], "wait rides out the delay");
        assert_eq!(wait.stats.delayed, 1);

        let mut ps = ParameterServer::new(vec![1.0; dim], None);
        let mut workers: Vec<Worker> = (0..2).map(|i| mk_worker(i, dim)).collect();
        let mut drop = ChaosTransport::new(Box::new(LocalBus::default()), plan())
            .with_policy(StragglerPolicy::Drop, 1);
        let replies = {
            let (b, _) = ps.broadcast(2);
            drop.round(&b, &mut workers).unwrap()
        };
        assert_eq!(reply_ids(&replies), vec![1], "drop treats the delay as a miss");
    }

    /// Corrupt faults either deliver a deterministically bit-flipped
    /// frame with intact metadata or drop it — never a panic, never a
    /// round-poisoning stale/misshapen reply.
    #[test]
    fn corrupt_fault_is_deterministic_and_metadata_safe() {
        let dim = 16;
        let run = || -> (Vec<Vec<u32>>, FaultStats, Vec<f32>) {
            let plan = ChaosPlan { seed: 3, corrupt_p: 1.0, ..Default::default() };
            let mut ps = ParameterServer::new(vec![1.0; dim], None);
            let mut workers: Vec<Worker> = (0..3).map(|i| mk_worker(i, dim)).collect();
            let mut bus = ChaosTransport::new(Box::new(LocalBus::default()), plan)
                .with_policy(StragglerPolicy::Drop, 1);
            let mut ids = Vec::new();
            for _ in 1u64..=6 {
                let r = {
                    let (b, _) = ps.broadcast(3);
                    bus.round(&b, &mut workers)
                };
                match r {
                    Ok(replies) => {
                        // delivered frames carry intact round/worker/dim
                        // metadata — a flip there drops the frame instead
                        for r in &replies {
                            assert_eq!(r.round(), ps.step());
                            assert_eq!(r.payload_n(), dim);
                        }
                        ids.push(reply_ids(&replies));
                        ps.apply(&replies).unwrap();
                    }
                    // every frame of the round corrupted to death: the
                    // quorum check fires; skip the apply, like a driver
                    // retrying the next round would
                    Err(_) => ids.push(Vec::new()),
                }
            }
            (ids, bus.stats, ps.master().to_vec())
        };
        let (ids_a, stats_a, x_a) = run();
        let (ids_b, stats_b, x_b) = run();
        assert_eq!(ids_a, ids_b, "corruption pattern must be deterministic");
        assert_eq!(stats_a, stats_b);
        assert_eq!(x_a, x_b, "corrupted trajectories must be reproducible");
        assert_eq!(stats_a.corrupted, 18, "every reply of every round is hit");
    }

    /// Async mode: a delay fault holds the reply and re-injects it
    /// verbatim `1 + lag` rounds later, still carrying its original
    /// round tag; nothing is dropped and no quorum fires on the
    /// thinned round.
    #[test]
    fn async_mode_holds_delayed_replies_and_reinjects_with_original_tag() {
        let dim = 8;
        let plan = ChaosPlan {
            lag: 1,
            scheduled: vec![ScheduledFault { kind: FaultKind::Delay, t: 1, worker: 0 }],
            ..Default::default()
        };
        let mut ps = ParameterServer::new(vec![1.0; dim], None);
        let mut workers: Vec<Worker> = (0..2).map(|i| mk_worker(i, dim)).collect();
        let mut bus = ChaosTransport::new(Box::new(LocalBus::default()), plan)
            .with_policy(StragglerPolicy::Drop, 2)
            .with_async(true);
        let mut seen: Vec<Vec<(u32, u64)>> = Vec::new();
        for _ in 1u64..=3 {
            let (b, _) = ps.broadcast(2);
            let replies = bus.round(&b, &mut workers).unwrap();
            seen.push(replies.iter().map(|r| (r.worker(), r.round())).collect());
        }
        // round 1: worker 0's reply is held (not dropped) — and the
        // 2-worker quorum does NOT fail the thinned async round
        assert_eq!(seen[0], vec![(1, 1)]);
        assert_eq!(bus.held_replies().len(), 1);
        assert_eq!(bus.held_replies()[0].0, 3, "release = t + 1 + lag");
        // round 2: fresh replies only, the hold is still pending
        assert_eq!(seen[1], vec![(0, 2), (1, 2)]);
        // round 3: the held reply lands first, original tag intact
        assert_eq!(seen[2], vec![(0, 1), (0, 3), (1, 3)]);
        assert!(bus.held_replies().is_empty());
        assert_eq!(bus.stats.delayed, 1);
        assert_eq!(bus.stats.dropped, 0);
    }

    /// Below the configured quorum the round fails loudly.
    #[test]
    fn below_quorum_fails_the_round() {
        let dim = 8;
        let mut ps = ParameterServer::new(vec![1.0; dim], None);
        let mut workers: Vec<Worker> = (0..3).map(|i| mk_worker(i, dim)).collect();
        let mut bus =
            ChaosTransport::new(Box::new(LocalBus::default()), ChaosPlan::dropping(&[(1, 0), (1, 1)]))
                .with_policy(StragglerPolicy::Drop, 2);
        let err = {
            let (b, _) = ps.broadcast(3);
            bus.round(&b, &mut workers).unwrap_err()
        };
        assert!(err.to_string().contains("quorum"), "{err}");
    }
}
