//! The participation layer of the round protocol: who is in a round,
//! what the server averaged over, and what to do about stragglers.
//!
//! **Sharding contract.** All three types here are worker-level and
//! shard-agnostic: one [`Membership`] describes the round across every
//! shard lane (a worker is present as a unit), a [`StragglerPolicy`]
//! applies identically per lane, and the merged [`Participation`] of a
//! sharded round (`ps::shard::ShardedServer::apply`) is the union of
//! the per-shard reporter sets — identical to each shard's own set
//! under worker-level faults.

/// Outcome of one applied round: which workers' deltas made it into the
/// server's mean. `ParameterServer::apply` averages over the *received*
/// replies (`mean_i` runs over `reporters`, not over the deployment
/// size) — a dropped worker simply does not pull the mean that round,
/// and its error-feedback residual carries the missed mass into its
/// next reply (the Theorem 3.1 argument, round-robin across members).
#[derive(Clone, Debug, PartialEq)]
pub struct Participation {
    /// The round this outcome belongs to (`t` of the applied deltas).
    pub round: u64,
    /// Mean training loss over the received replies.
    pub mean_loss: f32,
    /// Worker ids whose deltas entered the mean, sorted ascending.
    pub reporters: Vec<u32>,
}

impl Participation {
    /// How many workers reported this round.
    pub fn count(&self) -> usize {
        self.reporters.len()
    }
}

/// What a round does about workers that miss the gather.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum StragglerPolicy {
    /// Block until every live worker replies — the seed behavior, and
    /// bit-identical to it. A dead connection or a lost reply fails the
    /// round.
    #[default]
    Wait,
    /// Proceed once the round deadline passes: stragglers and dead
    /// connections count as dropped replies, and the round fails only
    /// below the `min_participation` quorum.
    Drop,
}

impl StragglerPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            StragglerPolicy::Wait => "wait",
            StragglerPolicy::Drop => "drop",
        }
    }

    /// Parse a CLI flag value; `None` for unknown values — callers
    /// should error, not fall back silently.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "wait" => Some(StragglerPolicy::Wait),
            "drop" => Some(StragglerPolicy::Drop),
            _ => None,
        }
    }
}

/// Downlink membership of the next round: who will receive the
/// broadcast. The server charges `down_bytes` for exactly `present`
/// workers — a crashed or evicted worker is not shipped bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Membership {
    /// Worker slots the deployment is sized for.
    pub expected: usize,
    /// Workers that will receive this round's broadcast.
    pub present: usize,
    /// True when at least one worker (re)joined since the previous
    /// round. The caller must then force a full-weights resync
    /// (`ParameterServer::force_resync`) before broadcasting: a
    /// rejoining worker missed frames, and in delta-downlink mode its
    /// replica would silently diverge from `x̂` otherwise.
    pub rejoined: bool,
}

impl Membership {
    /// Everyone present, nobody rejoining — the static-fleet default.
    pub fn full(total: usize) -> Self {
        Self { expected: total, present: total, rejoined: false }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn participation_counts_reporters() {
        let p = Participation { round: 3, mean_loss: 1.5, reporters: vec![0, 2, 5] };
        assert_eq!(p.count(), 3);
    }

    #[test]
    fn straggler_policy_parse_and_label() {
        assert_eq!(StragglerPolicy::default(), StragglerPolicy::Wait);
        assert_eq!(StragglerPolicy::parse("wait"), Some(StragglerPolicy::Wait));
        assert_eq!(StragglerPolicy::parse("drop"), Some(StragglerPolicy::Drop));
        assert_eq!(StragglerPolicy::parse("dropp"), None); // typos error, never fall back
        assert_eq!(StragglerPolicy::Wait.label(), "wait");
        assert_eq!(StragglerPolicy::Drop.label(), "drop");
    }

    #[test]
    fn full_membership() {
        let m = Membership::full(8);
        assert_eq!(m, Membership { expected: 8, present: 8, rejoined: false });
    }
}
