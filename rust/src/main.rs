//! `qadam` — CLI launcher for the QAdam-EF parameter-server system.
//!
//! Subcommands:
//!   train   single-process training (in-proc PS + N workers, PJRT graphs)
//!   eval    evaluate a checkpoint (optionally after weight quantization)
//!   serve   TCP parameter-server shard (pair with `worker` processes)
//!   worker  TCP worker process
//!   info    binary-compatibility capabilities (JSON) + artifacts/manifest.json
//!   lint    static invariant analyzer over rust/src/ (the registry in
//!           `qadam::analysis`; nonzero exit on any finding)
//!   top     tail a `--trace-out` JSONL trace and render the per-shard
//!           round-time/bytes table (refreshing, or --once / --check)
//!
//! Examples:
//!   qadam train --model vgg_sim --dataset cifar10_sim --kg 2 --steps 200
//!   qadam train --model resnet_sim --dataset cifar100_sim --method terngrad
//!   qadam serve --addr 127.0.0.1:7777 --workers 2 &
//!   qadam worker --addr 127.0.0.1:7777 --id 0 & qadam worker --id 1
//!   # 2-shard scale-out: one serve process per shard (ports 7777, 7778)
//!   qadam serve --addr 127.0.0.1:7777 --shard-id 0/2 --workers 2 &
//!   qadam serve --addr 127.0.0.1:7777 --shard-id 1/2 --workers 2 &
//!   qadam worker --addr 127.0.0.1:7777 --shards 2 --id 0 --kg 2

use anyhow::{anyhow, bail, Result};
use qadam::coordinator::config::{BusKind, Downlink, Engine};
use qadam::coordinator::{ExperimentConfig, Method, Trainer};
use qadam::elastic::{ChaosPlan, ChaosTransport, StragglerPolicy};
use qadam::models::{artifacts_dir, Manifest};
use qadam::optim::LrSchedule;
use qadam::quant::{CodecPolicy, PolicySpec, TensorLayout};
use qadam::util::Args;

/// Tensor granularity the sim CLIs (`serve` / `worker`) give the codec
/// policy: the flat sim vector has no named parameters, so it is split
/// into this many uniform blocks on both ends of the wire.
const SIM_POLICY_TENSORS: usize = 4;

const USAGE: &str = "\
qadam — Quantized Adam with Error Feedback (paper reproduction)

USAGE: qadam <train|eval|serve|worker|info|lint|bench-diff|top> [flags]

train flags:
  --model NAME          manifest model (default vgg_sim)
  --dataset NAME        cifar10_sim | cifar100_sim | text (default cifar10_sim)
  --method NAME         qadam | terngrad | blockwise (default qadam)
  --kg K                gradient quantization levels (omit = fp32 gradients)
  --no-ef               disable error feedback (ablation)
  --kx K                weight quantization level (omit = fp32 weights)
  --block N             blockwise baseline block size (default 4096)
  --engine E            native | pjrt_kernel (default native)
  --bus B               sequential | threaded round engine (default
                        sequential; threaded = one thread per worker +
                        block-sharded server, bit-identical results)
  --downlink D          full | delta broadcasts (default full; delta =
                        compressed weight deltas + server-side error
                        feedback, resync every --resync-every rounds)
  --resync-every N      full-weights resync cadence in delta mode
                        (default 64; 0 = only round 1)
  --codec-policy P      per-tensor gradient-codec policy:
                        static (default; the seed single-message path)
                        | per-layer:<name=k,...> (fixed per-tensor k_g;
                          exact names, prefix* globs, * catch-all)
                        | adaptive:<lo>..<hi> (bits tuned per tensor and
                          round from the EF residual / gradient ratio)
                        per-layer values may also be sparse codecs:
                          name=topk@D (keep density D, 0<D<=1, global
                          magnitude top-k) | name=sblock@BxK (keep K of
                          every B coordinates, sign * per-block scale)
                        | adaptive-topk:<lo>..<hi> (kept density tuned
                          per tensor and round, same EF-residual signal)
  --chaos SPEC          deterministic fault injection, e.g.
                        \"seed=7,drop=0.1,delay=0.05,crash=3@40..80\"
                        (keys: seed|drop|delay|dup|corrupt|crash)
  --straggler P         wait | drop (default wait; drop = proceed at
                        quorum, stragglers count as dropped replies)
  --min-participation N quorum under --straggler drop (default 1)
  --async-rounds        bounded-staleness rounds: a delta tagged with
                        the round it was computed against is applied
                        while its age (now − t) is <= --staleness; any
                        staler delta is rejected and its mass refunded
                        into the sender's EF residual. Off (default) =
                        the sync engine, byte-identical to prior builds
  --staleness N         max admitted delta age in rounds under
                        --async-rounds (default 0 = fresh only)
  --stale-down-weight   weight admitted deltas by 1/(1+age) and refund
                        the un-applied fraction into the sender's
                        residual (mass is conserved either way)
  --cohort K            client sampling: each round draws a cohort of K
                        logical workers from a registry of --registry
                        ids on a dedicated seeded rng stream; per-round
                        cost is independent of the registry size
  --registry N          logical-worker registry size for --cohort
                        (default 100000)
  --shards N            parameter-server shards: the flat vector splits
                        into N contiguous ranges, each with its own
                        server state (EF residual, replica, resync,
                        policy controller). 1 (default) = the seed
                        engine, byte-identical
  --workers N           number of workers (default 8)
  --steps N             training steps (default 200)
  --steps-per-epoch N   epoch length for LR decay (default 64)
  --alpha A             base learning rate (default 1e-3)
  --seed S              rng seed (default 0)
  --eval-every N        evaluation cadence (default 50)
  --eval-batches N      eval batches per evaluation (default 4)
  --csv PATH            write the metrics curve CSV
  --save-ckpt PATH      write a checkpoint at the end of training
  --resume PATH         resume from a checkpoint
  --trace-out PATH      write a JSONL round-lifecycle span trace (tail it
                        live with `qadam top --trace PATH`); also fills
                        the CSV round_ms column. Off by default: the
                        disabled path reads no clock and records nothing
  --metrics-addr A      serve GET /metrics (Prometheus text format) from
                        a dedicated listener, e.g. 127.0.0.1:9184

eval flags:
  --ckpt PATH --model NAME --dataset NAME [--post-kx K] [--eval-batches N]

serve flags:  --addr A --workers N --dim D --steps N [--kx K] [--kg K]
              [--downlink D] [--resync-every N] [--round-deadline-ms MS]
              [--straggler P] [--min-participation N] [--chaos SPEC]
              [--async-rounds] [--staleness N]  (non-barrier gather:
              apply whatever replies are queued, admit by age <= N;
              remote workers keep their own EF state, so rejected-delta
              refunds happen worker-side on the next round)
              [--codec-policy P]  (applies to the delta downlink)
              [--shard-id i/N]  (this process serves shard i of N;
              listens on base addr port + i; default 0/1 = unsharded)
              [--trace-out PATH]  (per-shard span trace: a serve process
              owns one shard, so its spans are real per-shard timings)
              [--metrics-addr A]  (GET /metrics listener — separate from
              --addr: the worker listener treats any connection as a
              rejoining worker, so never scrape that port)
worker flags: --addr A --id I --dim D --method M [--kg K] [--alpha A]
              [--downlink D] [--codec-policy P] [--shards N]
              (match the server fleet; --shards N connects to the N
              listeners at base addr port + 0..N)

lint flags:   [--root PATH]  repo root (default: walk up from the cwd to
              the directory containing rust/src/lib.rs). Runs the static
              invariant analyzer over rust/src/: INV-ALLOC (no
              allocation in `// qadam: hotpath` fns), INV-DET (no
              nondeterminism in ps/ quant/ elastic/), INV-PANIC (no
              panics/indexing in decode fns), INV-SAFETY (SAFETY
              comments + pinned unsafe budget), INV-WIRE (frame tags
              pinned in golden tests and `qadam info`). Prints honored
              waivers, then findings; nonzero exit on any finding.

bench-diff flags: --baseline PATH --fresh PATH [--threshold PCT]
              [--require-measured]
              compare two bench JSONs (benches/ emit them; the committed
              BENCH_*.json are the baselines). Entries present in both
              with measured medians are compared; a fresh median more
              than PCT percent slower (default 25) fails the command.
              Baseline entries with null medians count as unmeasured and
              never fail — `scripts/bench_diff.sh --refresh` measures
              them. --require-measured instead fails loudly when the
              baseline carries any unmeasured placeholder, so a \"pass\"
              can never be vacuous.

top flags:    --trace PATH  the JSONL file a run writes via --trace-out
              [--once]         render one table and exit
              [--check]        parse + assert the trace covers the full
                               round lifecycle (CI smoke; nonzero exit
                               when a span kind is missing)
              [--interval-ms N]  refresh cadence (default 1000)
";

fn parse_method(a: &Args) -> Result<(Method, Option<u32>, Engine)> {
    let kg: Option<u32> = a.opt("kg")?;
    let kx: Option<u32> = a.opt("kx")?;
    // Validate the levels where they are parsed (the satellite fix):
    // `LogQuant::new` / `WQuant::new` would only panic deep inside the
    // run otherwise.
    qadam::quant::validate_levels(kg, kx)?;
    let method = match a.get_str("method", "qadam").as_str() {
        "qadam" => Method::QAdam { kg, error_feedback: !a.flag("no_ef") },
        "terngrad" => Method::TernGrad,
        "blockwise" => Method::Blockwise { block: a.get("block", 4096usize)?, momentum: 0.9 },
        other => bail!("unknown method '{other}'"),
    };
    let engine = match a.get_str("engine", "native").as_str() {
        "native" => Engine::Native,
        "pjrt_kernel" | "pjrt" => Engine::PjrtKernel,
        other => bail!("unknown engine '{other}'"),
    };
    Ok((method, kx, engine))
}

fn parse_bus(a: &Args) -> Result<BusKind> {
    let v = a.get_str("bus", "sequential");
    BusKind::parse(&v).ok_or_else(|| anyhow!("unknown bus '{v}' (sequential | threaded)"))
}

fn parse_downlink(a: &Args) -> Result<(Downlink, u64)> {
    let v = a.get_str("downlink", "full");
    let d = Downlink::parse(&v).ok_or_else(|| anyhow!("unknown downlink '{v}' (full | delta)"))?;
    Ok((d, a.get("resync_every", 64u64)?))
}

fn parse_policy(a: &Args) -> Result<PolicySpec> {
    PolicySpec::parse(&a.get_str("codec_policy", "static"))
}

/// The elastic-round flags shared by `train` and `serve`:
/// `(chaos plan, straggler policy, quorum)`.
fn parse_elastic(a: &Args) -> Result<(Option<ChaosPlan>, StragglerPolicy, usize)> {
    let chaos = match a.opt::<String>("chaos")? {
        Some(spec) => Some(ChaosPlan::parse(&spec)?),
        None => None,
    };
    let v = a.get_str("straggler", "wait");
    let straggler =
        StragglerPolicy::parse(&v).ok_or_else(|| anyhow!("unknown straggler '{v}' (wait | drop)"))?;
    Ok((chaos, straggler, a.get("min_participation", 1usize)?))
}

/// Bind a non-static policy spec to `layout` (`None` for static or
/// methods without a `k_g` — callers error/warn as appropriate).
fn sim_policy_over(
    spec: &PolicySpec,
    m: Method,
    layout: TensorLayout,
) -> Result<Option<CodecPolicy>> {
    if spec.is_static() {
        return Ok(None);
    }
    let kg = match m {
        Method::QAdam { kg: Some(k), error_feedback } => {
            // the adaptive controller reads the EF residual; without EF
            // it sees zero debt forever and collapses to the band floor.
            // Sparse codecs are one step stricter: the dropped
            // coordinates ARE the residual, so without EF they are
            // simply lost mass and convergence quietly breaks.
            if !error_feedback
                && (matches!(spec, PolicySpec::Adaptive { .. }) || spec.is_sparse())
            {
                bail!(
                    "--codec-policy {} needs error feedback (drop --no-ef)",
                    spec.label()
                );
            }
            k
        }
        _ => bail!("--codec-policy {} needs a k_g-bearing method (--kg)", spec.label()),
    };
    Ok(Some(CodecPolicy::new(spec.clone(), layout, kg)?))
}

/// [`sim_policy_over`] on the whole sim vector's uniform layout.
fn sim_policy(spec: &PolicySpec, m: Method, dim: usize) -> Result<Option<CodecPolicy>> {
    sim_policy_over(spec, m, TensorLayout::uniform(dim, SIM_POLICY_TENSORS))
}

/// The sim deployment's shard plan. `serve --shard-id i/N` and
/// `worker --shards N` compute it independently and must agree, so it
/// is a pure function of `(dim, shards, policy spec)`: snapped to the
/// uniform sim policy layout when a non-static policy is active,
/// near-uniform otherwise.
fn sim_plan(dim: usize, shards: usize, spec: &PolicySpec) -> Result<qadam::ps::ShardPlan> {
    qadam::ps::ShardPlan::build(dim, shards, spec, &TensorLayout::uniform(dim, SIM_POLICY_TENSORS))
}

/// Parse `--shard-id i/N` (default `0/1`, the unsharded server).
fn parse_shard_id(a: &Args) -> Result<(usize, usize)> {
    let v = a.get_str("shard_id", "0/1");
    let (i, n) = v
        .split_once('/')
        .ok_or_else(|| anyhow!("--shard-id '{v}' is not i/N"))?;
    let i: usize = i.parse().map_err(|e| anyhow!("bad shard index '{i}': {e}"))?;
    let n: usize = n.parse().map_err(|e| anyhow!("bad shard count '{n}': {e}"))?;
    if n == 0 || i >= n {
        bail!("--shard-id {i}/{n} out of range (need i < N, N >= 1)");
    }
    Ok((i, n))
}

/// Shard `i`'s listener address: base port + i — the deployment
/// convention `serve --shard-id` and `worker --shards` share.
fn shard_addr(base: &str, i: usize) -> Result<String> {
    if i == 0 {
        return Ok(base.to_string());
    }
    let (host, port) = base
        .rsplit_once(':')
        .ok_or_else(|| anyhow!("--addr '{base}' is not host:port"))?;
    let port: u16 = port.parse().map_err(|e| anyhow!("bad port in '{base}': {e}"))?;
    let shifted = u16::try_from(i)
        .ok()
        .and_then(|i| port.checked_add(i))
        .ok_or_else(|| anyhow!("shard {i} port overflows past {port}"))?;
    Ok(format!("{host}:{shifted}"))
}

fn build_sim_opt(
    m: Method,
    dim: usize,
    lr: LrSchedule,
    policy: Option<CodecPolicy>,
) -> Box<dyn qadam::optim::WorkerOpt> {
    use qadam::optim::{BlockwiseSgdEf, QAdamEf, TernGradSgd};
    match m {
        Method::QAdam { kg: Some(k), error_feedback } => {
            let mut opt = QAdamEf::new(
                dim,
                qadam::quant::gradient_codec(Some(k)),
                error_feedback,
                lr,
                qadam::optim::ThetaSchedule::Const { theta: qadam::defaults::THETA },
                qadam::defaults::BETA,
                qadam::defaults::EPS,
            );
            if let Some(p) = policy {
                opt = opt.with_policy(p);
            }
            Box::new(opt)
        }
        Method::QAdam { kg: None, .. } => Box::new(QAdamEf::full_precision(dim, lr)),
        Method::TernGrad => Box::new(TernGradSgd::new(dim, lr)),
        Method::Blockwise { block, momentum } => Box::new(BlockwiseSgdEf::new(dim, momentum, block, lr)),
    }
}

fn cmd_train(a: &Args) -> Result<()> {
    let (method, kx, engine) = parse_method(a)?;
    let (downlink, resync_every) = parse_downlink(a)?;
    let (chaos, straggler, min_participation) = parse_elastic(a)?;
    let codec_policy = parse_policy(a)?;
    let cfg = ExperimentConfig {
        model: a.get_str("model", "vgg_sim"),
        dataset: a.get_str("dataset", "cifar10_sim"),
        method,
        kx,
        workers: a.get("workers", qadam::defaults::WORKERS)?,
        batch: qadam::defaults::BATCH,
        steps: a.get("steps", 200u64)?,
        steps_per_epoch: a.get("steps_per_epoch", 64u64)?,
        lr: LrSchedule::ExpDecay { alpha: a.get("alpha", 1e-3f32)?, half_every: 50 },
        engine,
        bus: parse_bus(a)?,
        downlink,
        resync_every,
        chaos,
        codec_policy,
        shards: a.get("shards", 1usize)?,
        straggler,
        min_participation,
        async_rounds: a.flag("async_rounds"),
        staleness: a.get("staleness", 0u64)?,
        staleness_down_weight: a.flag("stale_down_weight"),
        cohort: a.opt("cohort")?,
        registry: a.get("registry", 100_000u64)?,
        seed: a.get("seed", 0u64)?,
        eval_every: a.get("eval_every", 50u64)?,
        eval_batches: a.get("eval_batches", 4usize)?,
    };
    let csv: Option<String> = a.opt("csv")?;
    let save_ckpt: Option<String> = a.opt("save_ckpt")?;
    let resume: Option<String> = a.opt("resume")?;
    let obs_cfg = qadam::coordinator::ObsConfig {
        trace_out: a.opt::<String>("trace_out")?.map(std::path::PathBuf::from),
        metrics_addr: a.opt("metrics_addr")?,
    };
    a.reject_unknown()?;
    let nshards = cfg.shards;
    let mut tr = Trainer::new(cfg)?;
    if obs_cfg.enabled() {
        let mut obs = qadam::obs::RoundObs::new(Box::new(qadam::obs::MonoClock::new()), nshards);
        if let Some(p) = &obs_cfg.trace_out {
            obs = obs.with_trace_out(p)?;
            println!("tracing round lifecycle to {}", p.display());
        }
        tr.enable_obs(obs);
        if let Some(addr) = &obs_cfg.metrics_addr {
            let reg = tr.obs_registry().expect("obs just enabled");
            let srv = qadam::obs::MetricsServer::spawn(addr, reg)?;
            println!("serving /metrics on http://{}/metrics", srv.addr());
        }
    }
    if let Some(p) = resume {
        let ckpt = qadam::coordinator::Checkpoint::load(std::path::Path::new(&p))?;
        tr.restore(&ckpt)?;
        println!("resumed from {p} at step {}", ckpt.step);
    }
    let summary = tr.run()?;
    if let Some(p) = save_ckpt {
        let p = std::path::PathBuf::from(p);
        tr.checkpoint().save(&p)?;
        println!("checkpoint written to {}", p.display());
    }
    println!("{}", summary.table_row());
    if let Some(p) = csv {
        let p = std::path::PathBuf::from(p);
        tr.log.write_csv(&p)?;
        println!("wrote {}", p.display());
    }
    Ok(())
}

fn cmd_serve(a: &Args) -> Result<()> {
    use qadam::ps::transport::{TcpServer, Transport};
    use qadam::ps::ParameterServer;
    let base_addr = a.get_str("addr", "127.0.0.1:7777");
    let workers = a.get("workers", 2usize)?;
    let dim = a.get("dim", 64usize)?;
    let steps = a.get("steps", 200u64)?;
    let kx: Option<u32> = a.opt("kx")?;
    let kg: Option<u32> = a.opt("kg")?;
    qadam::quant::validate_levels(kg, kx)?;
    let (downlink, resync_every) = parse_downlink(a)?;
    let (chaos, straggler, min_participation) = parse_elastic(a)?;
    let codec_policy = parse_policy(a)?;
    let deadline_ms: Option<u64> = a.opt("round_deadline_ms")?;
    let async_rounds = a.flag("async_rounds");
    let staleness = a.get("staleness", 0u64)?;
    if staleness != 0 && !async_rounds {
        bail!("--staleness needs --async-rounds");
    }
    let staleness_policy = qadam::elastic::StalenessPolicy::new(staleness, false);
    let (shard_id, nshards) = parse_shard_id(a)?;
    let addr = shard_addr(&base_addr, shard_id)?;
    // This process owns shard `shard_id`'s contiguous range of the
    // shared sim problem; its workers connect to every shard's
    // listener and split their replies accordingly. The plan is a pure
    // function of (dim, shards, policy), so both ends agree on it.
    let plan = sim_plan(dim, nshards, &codec_policy)?;
    let (start, len) = plan.range(shard_id);
    let trace_out: Option<String> = a.opt("trace_out")?;
    let metrics_addr: Option<String> = a.opt("metrics_addr")?;
    a.reject_unknown()?;
    // One serve process owns exactly one shard, so its spans carry this
    // shard's id with *real* durations — the per-shard timing view the
    // in-process trainer cannot produce. The registry is merged-only
    // (`MetricsRegistry::new(1)`): it describes this process. The
    // metrics listener binds before the worker accept loop below so the
    // endpoint is scrapeable while the fleet is still assembling (and
    // it must be a separate port: the worker listener treats any
    // connection as a rejoining worker).
    let mut obs = if trace_out.is_some() || metrics_addr.is_some() {
        let mut o = qadam::obs::RoundObs::new(Box::new(qadam::obs::MonoClock::new()), 1);
        if let Some(p) = &trace_out {
            o = o.with_trace_out(std::path::Path::new(p))?;
            println!("tracing round lifecycle to {p}");
        }
        if let Some(addr) = &metrics_addr {
            let srv = qadam::obs::MetricsServer::spawn(addr, o.registry.clone())?;
            println!("serving /metrics on http://{}/metrics", srv.addr());
        }
        Some(o)
    } else {
        None
    };
    // Chaos (if any) wraps the TCP transport: reply-level faults apply
    // to the gathered frames. Crash windows act on the in-process
    // worker set, which a TCP server does not have — membership and
    // accounting would silently disagree with the real fleet — so over
    // TCP a crash is a worker process you actually kill.
    if let Some(p) = &chaos {
        if !p.crashes.is_empty() {
            bail!(
                "--chaos crash windows are in-process faults (train); over TCP, kill the \
                 worker process instead — drop/delay/dup/corrupt apply on serve"
            );
        }
    }
    let mut srv = TcpServer::bind_and_accept(&addr, workers)?;
    srv.set_elastic(deadline_ms, straggler, min_participation);
    // Async mode turns the gather into a non-barrier poll: the round
    // applies whatever replies are already queued (however old their
    // round tags) instead of waiting for every lane.
    srv.set_async(async_rounds);
    let mut bus: Box<dyn Transport> = Box::new(srv);
    if let Some(chaos_plan) = chaos {
        bus = Box::new(
            ChaosTransport::new(bus, chaos_plan)
                .with_policy(straggler, min_participation)
                .with_async(async_rounds),
        );
    }
    let problem = qadam::sim::StochasticProblem::new(dim, 0.05, 1);
    // Shard 0/1 is the whole vector — the unsharded seed path, bit for
    // bit. Any other shard serves its slice of the same x0.
    let mut ps = ParameterServer::new(problem.x0()[start..start + len].to_vec(), kx);
    let tag: String =
        if nshards > 1 { format!("server shard {shard_id}/{nshards}") } else { "server".into() };
    if downlink == Downlink::Delta {
        if kg.is_none() {
            eprintln!(
                "[{tag}] --downlink delta without --kg: delta frames ship fp32 \
                 (protocol-correct, but no downlink compression)"
            );
        }
        ps.enable_delta_downlink(qadam::quant::gradient_codec(kg), resync_every);
        let method = Method::QAdam { kg, error_feedback: true };
        // The shard's downlink controller runs over the sim layout
        // cropped to its range — only computed under a non-static
        // policy, where the plan snapped to that layout (a uniform
        // static-policy plan need not align with it).
        if !codec_policy.is_static() {
            let sub_layout = TensorLayout::uniform(dim, SIM_POLICY_TENSORS).crop(start, len)?;
            if let Some(p) = sim_policy_over(&codec_policy, method, sub_layout)? {
                ps.set_downlink_policy(p);
            }
        }
    } else if !codec_policy.is_static() {
        eprintln!(
            "[{tag}] --codec-policy {} affects only worker uplinks and the delta \
             downlink; with --downlink full the broadcast stays full frames",
            codec_policy.label()
        );
    }
    let mut stale_rejected = 0u64;
    for t in 1..=steps {
        let m = bus.membership(t, workers);
        if m.rejoined {
            ps.force_resync();
        }
        let t0 = obs.as_mut().map_or(0, |o| o.now_ns());
        let (b, _) = ps.broadcast(m.present);
        let t1 = obs.as_mut().map_or(0, |o| o.now_ns());
        let replies = bus.round(&b, &mut [])?;
        let t2 = obs.as_mut().map_or(0, |o| o.now_ns());
        let part = if async_rounds {
            // Bounded-staleness apply. A rejected delta's refund is
            // worker-side state this process cannot reach over TCP (the
            // worker folds its own residual on the next round); the
            // server's job is to admit by age and account the rejects.
            let ar = ps.apply_async(&replies, &staleness_policy)?;
            stale_rejected += ar.rejected.len() as u64;
            if let Some(o) = &obs {
                for (i, &age) in ar.ages.iter().enumerate() {
                    if ar.rejected.binary_search(&i).is_err() {
                        o.registry.staleness_rounds.observe(age);
                    }
                }
                o.registry.stale_rejected.set_cumulative(stale_rejected);
            }
            ar.part
        } else {
            ps.apply(&replies)?
        };
        if let Some(o) = &mut obs {
            use qadam::obs::{Span, SpanKind};
            let t3 = o.now_ns();
            let sh = shard_id as i64;
            let span = |kind, start_ns, dur_ns, bytes| Span {
                round: t,
                shard: sh,
                lane: -1,
                kind,
                start_ns,
                dur_ns,
                bytes,
            };
            o.record(span(SpanKind::Broadcast, t0, t1 - t0, b.wire_bytes() as u64));
            o.record(span(SpanKind::Gather, t1, t2 - t1, 0));
            for r in &replies {
                o.record(Span {
                    lane: r.worker() as i64,
                    bytes: r.wire_bytes() as u64,
                    ..span(SpanKind::Gather, t1, 0, 0)
                });
            }
            o.record(span(SpanKind::DecodeApply, t2, t3 - t2, 0));
            o.registry.observe_comm(&ps.stats, &[]);
            // A serve process cannot see worker-side EF residuals or
            // the fleet-level codec policy; those gauges stay 0 here.
            o.registry.observe_round(t3 - t0, part.count(), 0.0, 0.0, part.mean_loss);
            o.registry.straggler_evictions.set_cumulative(bus.straggler_evictions());
            if let Some(f) = bus.fault_stats() {
                o.registry.observe_faults(&f);
            }
            o.end_round();
        }
        if t % 50 == 0 || t == steps {
            if nshards == 1 {
                println!(
                    "[server] t={t} loss={:.5} |grad|^2={:.6} members={}/{} up={}B down={}B",
                    part.mean_loss,
                    problem.grad_norm_sq(ps.master()),
                    part.count(),
                    workers,
                    ps.stats.up_bytes,
                    ps.stats.down_bytes
                );
            } else {
                // a shard sees only its range: no global gradient norm
                println!(
                    "[{tag}] t={t} loss={:.5} members={}/{} up={}B down={}B",
                    part.mean_loss,
                    part.count(),
                    workers,
                    ps.stats.up_bytes,
                    ps.stats.down_bytes
                );
            }
        }
    }
    bus.shutdown()?;
    println!(
        "[{tag}] done: {:.4} MB up, {:.4} MB down over {} rounds ({} resyncs)",
        ps.stats.up_bytes as f64 / 1e6,
        ps.stats.down_bytes as f64 / 1e6,
        ps.stats.rounds,
        ps.stats.resyncs
    );
    Ok(())
}

fn cmd_worker(a: &Args) -> Result<()> {
    use qadam::ps::transport::tcp_sharded_worker_loop;
    use qadam::ps::worker::{SimGradSource, Worker};
    let addr = a.get_str("addr", "127.0.0.1:7777");
    let id = a.get("id", 0u32)?;
    let dim = a.get("dim", 64usize)?;
    let alpha = a.get("alpha", 0.01f32)?;
    let shards = a.get("shards", 1usize)?;
    let (m, _kx, _engine) = parse_method(a)?;
    // `--downlink` mirrors the server flag so a misconfigured fleet is
    // diagnosable from either end: the server already warns when delta
    // frames will ship fp32, and so do we.
    let (downlink, _resync_every) = parse_downlink(a)?;
    let codec_policy = parse_policy(a)?;
    a.reject_unknown()?;
    if downlink == Downlink::Delta {
        let kg = match m {
            Method::QAdam { kg, .. } => kg,
            _ => None,
        };
        if kg.is_none() {
            eprintln!(
                "[worker {id}] --downlink delta without --kg: delta frames ship fp32 \
                 (protocol-correct, but no downlink compression)"
            );
        }
    }
    // One lane per shard listener (base port + shard id), the same plan
    // the serve fleet computes. --shards 1 is the classic single-lane
    // loop, byte-identical.
    let plan = sim_plan(dim, shards, &codec_policy)?;
    let addrs: Vec<String> = (0..shards).map(|i| shard_addr(&addr, i)).collect::<Result<_>>()?;
    let src = SimGradSource { problem: qadam::sim::StochasticProblem::new(dim, 0.05, 1) };
    let opt = build_sim_opt(m, dim, LrSchedule::Const { alpha }, sim_policy(&codec_policy, m, dim)?);
    let mut w = Worker::new(id, opt, Box::new(src), 7);
    w.set_shards(plan);
    let rounds = tcp_sharded_worker_loop(&addrs, &mut w)?;
    println!("[worker {id}] served {rounds} rounds ({})", w.opt_name());
    Ok(())
}

fn cmd_eval(a: &Args) -> Result<()> {
    use qadam::coordinator::config::{Engine, ExperimentConfig, Method};
    let ckpt_path = a.get_str("ckpt", "");
    if ckpt_path.is_empty() {
        bail!("--ckpt PATH required");
    }
    let ckpt = qadam::coordinator::Checkpoint::load(std::path::Path::new(&ckpt_path))?;
    let cfg = ExperimentConfig {
        model: a.get_str("model", &ckpt.model),
        dataset: a.get_str("dataset", "vector"),
        method: Method::QAdam { kg: None, error_feedback: false },
        kx: None,
        workers: 1,
        batch: qadam::defaults::BATCH,
        steps: 0,
        steps_per_epoch: 1,
        lr: LrSchedule::Const { alpha: 0.0 },
        engine: Engine::Native,
        bus: BusKind::Sequential,
        downlink: Downlink::Full,
        resync_every: 0,
        chaos: None,
        codec_policy: PolicySpec::Static,
        shards: 1,
        straggler: StragglerPolicy::Wait,
        min_participation: 1,
        async_rounds: false,
        staleness: 0,
        staleness_down_weight: false,
        cohort: None,
        registry: 100_000,
        seed: a.get("seed", 0u64)?,
        eval_every: 0,
        eval_batches: a.get("eval_batches", 4usize)?,
    };
    let post_kx: Option<u32> = a.opt("post_kx")?;
    a.reject_unknown()?;
    let tr = Trainer::new(cfg)?;
    let acc = match post_kx {
        None => tr.eval_weights(&ckpt.x)?,
        Some(kx) => {
            let wq = qadam::quant::WQuant::new(kx);
            let mut q = vec![0.0f32; ckpt.x.len()];
            wq.quantize_into(&ckpt.x, &mut q);
            tr.eval_weights(&q)?
        }
    };
    println!(
        "checkpoint {} (model {}, step {}): accuracy {:.2}%{}",
        ckpt_path,
        ckpt.model,
        ckpt.step,
        100.0 * acc,
        post_kx.map(|k| format!(" at kx={k} weights")).unwrap_or_default()
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    // Binary-compatibility capabilities, machine-readable: what an
    // operator checks across a fleet before a mixed-version rollout
    // (wire layout, frame tags, codec set, shard conventions). Printed
    // unconditionally — no artifacts needed.
    println!("{{");
    println!("  \"wire_version\": {},", qadam::ps::protocol::WIRE_VERSION);
    println!(
        "  \"checkpoint_versions\": {:?},",
        qadam::coordinator::checkpoint::SUPPORTED_VERSIONS
    );
    // Tag values come from the registry constants, never re-typed here:
    // INV-WIRE (`qadam lint`) checks every `tag::` constant is used by
    // this emitter, so a new frame kind shows up below or fails CI.
    use qadam::ps::protocol::tag;
    println!("  \"frame_tags\": {{");
    println!(
        "    \"to_worker\": {{\"shutdown\": {}, \"weights\": {}, \"weights_delta\": {}, \
         \"weights_delta_parts\": {}}},",
        tag::TO_WORKER_SHUTDOWN,
        tag::TO_WORKER_WEIGHTS,
        tag::TO_WORKER_WEIGHTS_DELTA,
        tag::TO_WORKER_WEIGHTS_DELTA_PARTS
    );
    println!(
        "    \"to_server\": {{\"delta\": {}, \"delta_parts\": {}}},",
        tag::TO_SERVER_DELTA,
        tag::TO_SERVER_DELTA_PARTS
    );
    // Codec ids ride the existing frame kinds (WireMsg byte 0) — pinned
    // here so a fleet can check sparse-codec support before enabling a
    // sparse policy on the wire.
    println!(
        "    \"codec_ids\": {{\"topk\": {}, \"sparse_block\": {}}}",
        tag::CODEC_TOPK,
        tag::CODEC_SPARSE_BLOCK
    );
    println!("  }},");
    println!(
        "  \"codecs\": [\"identity\", \"logquant\", \"wquant\", \"terngrad\", \"blockwise\", \
         \"qsgd\", \"topk\", \"sparse_block\"],"
    );
    println!("  \"max_kg\": {},", qadam::quant::MAX_KG);
    println!("  \"max_kx\": {},", qadam::quant::MAX_KX);
    println!("  \"shards\": {{");
    println!("    \"supported\": true,");
    println!("    \"tcp_port_convention\": \"base_port + shard_id\",");
    println!("    \"snap_to_tensor_boundaries\": \"when a non-static codec policy is active\",");
    println!("    \"sharded_checkpoint_version\": 3");
    println!("  }},");
    // Observability capability set: which exporters this binary ships,
    // the trace schema it writes, and the exact metric series a scrape
    // config can rely on. All sourced from the `qadam::obs` constants —
    // a unit test asserts they match the real exposition.
    println!("  \"obs\": {{");
    let quoted = |xs: &[&str]| {
        xs.iter().map(|x| format!("\"{x}\"")).collect::<Vec<_>>().join(", ")
    };
    println!("    \"exporters\": [{}],", quoted(&qadam::obs::EXPORTERS));
    println!("    \"trace_schema_version\": {},", qadam::obs::TRACE_SCHEMA_VERSION);
    let kinds: Vec<&str> = qadam::obs::SpanKind::ALL.iter().map(|k| k.name()).collect();
    println!("    \"span_kinds\": [{}],", quoted(&kinds));
    println!("    \"metrics_content_type\": \"{}\",", qadam::obs::CONTENT_TYPE);
    println!("    \"metric_names\": [{}]", quoted(&qadam::obs::METRIC_NAMES));
    println!("  }},");
    // Which invariant rule set this binary's `qadam lint` enforces —
    // CI and bench-diff-style probes assert on it.
    println!("  \"invariant_registry\": {{");
    println!("    \"version\": {},", qadam::analysis::REGISTRY_VERSION);
    println!("    \"unsafe_budget\": {},", qadam::analysis::UNSAFE_BUDGET);
    let rules: Vec<String> =
        qadam::analysis::RULES.iter().map(|r| format!("\"{}\"", r.id)).collect();
    println!("    \"rules\": [{}]", rules.join(", "));
    println!("  }}");
    println!("}}");
    // The artifacts listing stays best-effort: a deploy box checking
    // wire compatibility has no reason to carry model artifacts.
    let dir = artifacts_dir();
    match Manifest::load(&dir) {
        Err(_) => eprintln!("(no artifacts at {} — model listing skipped)", dir.display()),
        Ok(m) => {
            println!("artifacts: {}", dir.display());
            println!(
                "optimizer kernel: {} (chunk {})",
                m.optimizer.qadam_artifact, m.optimizer.chunk
            );
            for (name, meta) in &m.models {
                println!(
                    "  {:<20} {:>9} params  {:>2} tensors  train_x={:?} ({})",
                    name,
                    meta.total_params,
                    meta.params.len(),
                    meta.train_x.shape,
                    meta.kind
                );
            }
        }
    }
    Ok(())
}

/// `qadam lint`: run the invariant analyzer over the repo's
/// `rust/src/` tree and fail (nonzero exit) on any finding — the CI
/// hard gate `scripts/ci.sh` runs right after the build.
fn cmd_lint(a: &Args) -> Result<()> {
    use qadam::analysis;
    let root = match a.opt::<String>("root")? {
        Some(r) => std::path::PathBuf::from(r),
        None => {
            let cwd = std::env::current_dir()?;
            analysis::repo_root_from(&cwd).ok_or_else(|| {
                anyhow!("no rust/src/lib.rs at or above {} (use --root)", cwd.display())
            })?
        }
    };
    a.reject_unknown()?;
    let report = analysis::run(&root)?;
    for w in &report.waivers {
        println!("waived  {}:{} [{}] {}", w.path, w.line, w.rule, w.reason);
    }
    for f in &report.findings {
        println!("FAIL    {}:{} [{}] {}", f.path, f.line, f.rule, f.msg);
    }
    println!(
        "qadam lint: {} files, {} unsafe sites (budget {}), {} waivers, {} findings \
         (registry v{})",
        report.files,
        report.unsafe_count,
        analysis::UNSAFE_BUDGET,
        report.waivers.len(),
        report.findings.len(),
        analysis::REGISTRY_VERSION
    );
    if !report.findings.is_empty() {
        bail!("{} invariant violations in {}", report.findings.len(), root.display());
    }
    Ok(())
}

/// Read one bench JSON: its `bench` tag, the measured `(name,
/// median_ns)` pairs from `results`, and how many entries carry a null
/// median (committed placeholder baselines that nobody has measured on
/// this machine yet).
fn load_bench(path: &str) -> Result<(String, Vec<(String, f64)>, usize)> {
    use qadam::util::json::{parse, Value};
    let text = std::fs::read_to_string(path).map_err(|e| anyhow!("reading {path}: {e}"))?;
    let v = parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))?;
    let bench = v.get("bench")?.as_str()?.to_string();
    let mut measured = Vec::new();
    let mut unmeasured = 0usize;
    for e in v.get("results")?.as_arr()? {
        let name = e.get("name")?.as_str()?.to_string();
        match e.get("median_ns")? {
            Value::Num(ns) if ns.is_finite() && *ns > 0.0 => measured.push((name, *ns)),
            _ => unmeasured += 1,
        }
    }
    Ok((bench, measured, unmeasured))
}

fn cmd_bench_diff(a: &Args) -> Result<()> {
    let baseline = a.get_str("baseline", "");
    let fresh = a.get_str("fresh", "");
    let threshold: f64 = a.get("threshold", 25.0)?;
    let require_measured = a.flag("require_measured");
    a.reject_unknown()?;
    if baseline.is_empty() || fresh.is_empty() {
        bail!("bench-diff needs --baseline and --fresh JSON paths\n{USAGE}");
    }
    let (base_tag, base, base_unmeasured) = load_bench(&baseline)?;
    if require_measured && base_unmeasured > 0 {
        // Unmeasured placeholders silently shrink the comparison set; a
        // gate that must mean something (bench_diff.sh --refresh
        // self-check) opts into failing instead.
        bail!(
            "--require-measured: baseline {baseline} carries {base_unmeasured} unmeasured \
             (null-median) entries — run scripts/bench_diff.sh --refresh to record them"
        );
    }
    let (fresh_tag, new, _) = load_bench(&fresh)?;
    if base_tag != fresh_tag {
        bail!("bench mismatch: baseline is '{base_tag}', fresh run is '{fresh_tag}'");
    }
    let base_map: std::collections::BTreeMap<&str, f64> =
        base.iter().map(|(n, v)| (n.as_str(), *v)).collect();
    let (mut compared, mut regressions) = (0usize, 0usize);
    for (name, new_ns) in &new {
        match base_map.get(name.as_str()) {
            Some(base_ns) => {
                compared += 1;
                let pct = (new_ns / base_ns - 1.0) * 100.0;
                let flag = if pct > threshold {
                    regressions += 1;
                    "  << REGRESSION"
                } else {
                    ""
                };
                println!("{name:<52} {base_ns:>12.1} -> {new_ns:>12.1} ns  {pct:+7.1}%{flag}");
            }
            None => println!("{name:<52} (no baseline)"),
        }
    }
    if base_unmeasured > 0 {
        println!(
            "({base_unmeasured} baseline entries are unmeasured placeholders — \
             run scripts/bench_diff.sh --refresh to record this machine)"
        );
    }
    println!(
        "bench-diff [{base_tag}]: compared {compared} entries, threshold {threshold}%"
    );
    if regressions > 0 {
        bail!("{regressions} benchmark entries regressed more than {threshold}%");
    }
    Ok(())
}

/// `qadam top`: tail a `--trace-out` JSONL trace and render the
/// per-shard round-time/bytes table. `--once` renders a single frame;
/// `--check` is the CI smoke gate — parse the trace and fail unless it
/// covers the full round lifecycle.
fn cmd_top(a: &Args) -> Result<()> {
    let trace = a.get_str("trace", "");
    let once = a.flag("once");
    let check = a.flag("check");
    let interval_ms: u64 = a.get("interval_ms", 1000)?;
    a.reject_unknown()?;
    if trace.is_empty() {
        bail!("top needs --trace PATH (the file a run writes via --trace-out)\n{USAGE}");
    }
    let path = std::path::PathBuf::from(&trace);
    if check {
        let tf = qadam::obs::read_trace(&path)?;
        let covered = tf.covered_kinds();
        println!(
            "trace {}: schema v{}, clock {}, {} spans, covers [{}]",
            trace,
            tf.schema_version,
            tf.clock,
            tf.spans.len(),
            covered.join(", ")
        );
        if !tf.covers_lifecycle() {
            bail!(
                "trace covers only [{}] of the round lifecycle — expected all of \
                 broadcast/gather/decode_apply/requantize (did the run eval at least once?)",
                covered.join(", ")
            );
        }
        return Ok(());
    }
    loop {
        let table = match qadam::obs::read_trace(&path) {
            Ok(tf) => qadam::obs::render_table(&tf),
            // A live run may not have written the header yet — keep
            // polling instead of dying under `qadam top` started first.
            Err(e) if !once => format!("waiting for {trace}: {e}\n"),
            Err(e) => return Err(e),
        };
        if once {
            print!("{table}");
            return Ok(());
        }
        // ANSI clear + home, like watch(1); main.rs is outside the
        // INV-DET scope, so sleeping here needs no waiver.
        print!("\x1b[2J\x1b[H{table}");
        use std::io::Write as _;
        std::io::stdout().flush().ok();
        std::thread::sleep(std::time::Duration::from_millis(interval_ms.max(100)));
    }
}

fn main() -> Result<()> {
    let args = Args::parse_env()?;
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("serve") => cmd_serve(&args),
        Some("worker") => cmd_worker(&args),
        Some("eval") => cmd_eval(&args),
        Some("info") => cmd_info(),
        Some("lint") => cmd_lint(&args),
        Some("bench-diff") => cmd_bench_diff(&args),
        Some("top") => cmd_top(&args),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(anyhow!("unknown subcommand '{other}'\n{USAGE}")),
    }
}
