//! Synthetic mixture-of-experts workload — the gradient-sparsity regime
//! the sparse codecs ([`crate::quant::TopK`], [`crate::quant::SparseBlock`])
//! are built for.
//!
//! The flat vector is a small shared **router** block followed by `E`
//! equal-sized **expert** slices (the fastmoe parameter shape). Each
//! (worker, t) microbatch is routed top-1 to a single expert, so the
//! stochastic gradient is dense on the router and on exactly one expert
//! slice and *exactly zero* everywhere else: with `E` experts only a
//! `(router + expert) / dim` fraction of coordinates is live per step.
//! A dense codec spends bits on every coordinate of that mostly-zero
//! vector; a sparse codec spends them only where the mass is — which is
//! the equal-byte-budget comparison `benches/sparse_sweep.rs` runs.
//!
//! Per-coordinate objective is the same smooth bounded-gradient
//! nonconvex `phi` as [`crate::sim::StochasticProblem`] (Assumption 1
//! holds by construction), applied to `x - target` where the targets
//! are deterministic per expert. The expert term is scaled by `E` so
//! the *expected* per-coordinate gradient (each expert trains ~1/E of
//! the steps) matches the router's and the problem doesn't degenerate
//! into router-only training.
//!
//! Routing is deterministic in `(seed, worker, t)` — like everything
//! else in the tree, a fixed-seed run is bit-reproducible across
//! transports and shard counts.

use crate::ps::worker::GradSource;
use crate::quant::TensorLayout;
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct MoeProblem {
    pub experts: usize,
    pub expert_dim: usize,
    pub router_dim: usize,
    /// uniform noise half-width per *live* coordinate (zeros stay zero).
    pub sigma: f32,
    pub cos_weight: f32,
    pub seed: u64,
    /// Per-coordinate minimizer offsets, router first then experts
    /// back-to-back (deterministic, off every dyadic grid).
    pub target: Vec<f32>,
}

impl MoeProblem {
    pub fn new(experts: usize, expert_dim: usize, router_dim: usize, sigma: f32, seed: u64) -> Self {
        assert!(experts >= 1, "need at least one expert");
        assert!(expert_dim >= 1 && router_dim >= 1, "empty parameter block");
        let dim = router_dim + experts * expert_dim;
        let target =
            (0..dim).map(|i| 0.077 + 0.0131 * (i as f32 * 1.7 + seed as f32).sin()).collect();
        Self { experts, expert_dim, router_dim, sigma, cos_weight: 0.5, seed, target }
    }

    pub fn dim(&self) -> usize {
        self.router_dim + self.experts * self.expert_dim
    }

    /// Flat-vector range of expert `e`'s slice.
    pub fn expert_range(&self, e: usize) -> std::ops::Range<usize> {
        let start = self.router_dim + e * self.expert_dim;
        start..start + self.expert_dim
    }

    /// Top-1 routing decision for a (worker, t) microbatch —
    /// deterministic, uniform over experts.
    pub fn route(&self, worker: usize, t: u64) -> usize {
        let mut rng = crate::quant::seeded_rng(self.seed ^ 0x6d6f_6531, (t << 16) ^ worker as u64);
        (rng.gen_u32() as usize) % self.experts
    }

    /// Fraction of coordinates carrying gradient mass on any one step.
    pub fn live_density(&self) -> f64 {
        (self.router_dim + self.expert_dim) as f64 / self.dim() as f64
    }

    /// `(name, len)` parts for [`TensorLayout::from_named`]: `router`,
    /// `expert0`, `expert1`, … — the names `--codec-policy
    /// per-layer:expert*=topk@0.05,router=2` binds against.
    pub fn layout(&self) -> TensorLayout {
        let mut parts = Vec::with_capacity(1 + self.experts);
        parts.push(("router".to_string(), self.router_dim));
        for e in 0..self.experts {
            parts.push((format!("expert{e}"), self.expert_dim));
        }
        TensorLayout::from_named(&parts)
    }

    fn phi(&self, z: f32) -> f32 {
        z * z / (1.0 + z * z) + self.cos_weight * (1.0 - z.cos())
    }

    fn dphi(&self, z: f32) -> f32 {
        let den = 1.0 + z * z;
        2.0 * z / (den * den) + self.cos_weight * z.sin()
    }

    /// Routed objective for a (worker, t) microbatch: router term plus
    /// the active expert's term (scaled by `experts` — see module doc).
    pub fn loss(&self, x: &[f32], worker: usize, t: u64) -> f32 {
        debug_assert_eq!(x.len(), self.dim());
        let inv_d = 1.0 / self.dim() as f32;
        let mut acc = 0.0f32;
        for j in 0..self.router_dim {
            acc += self.phi(x[j] - self.target[j]);
        }
        let scale = self.experts as f32;
        for j in self.expert_range(self.route(worker, t)) {
            acc += scale * self.phi(x[j] - self.target[j]);
        }
        acc * inv_d
    }

    /// Stochastic gradient of the routed objective: dense on the router
    /// and the routed expert slice, exactly zero elsewhere. Noise is
    /// bounded, zero-mean, deterministic in (t, worker), and only
    /// touches live coordinates — the sparsity pattern survives it.
    pub fn stoch_grad_into(&self, x: &[f32], t: u64, worker: usize, out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.dim());
        debug_assert_eq!(out.len(), self.dim());
        out.fill(0.0);
        let inv_d = 1.0 / self.dim() as f32;
        let mut rng = crate::quant::seeded_rng(self.seed, (t << 16) ^ worker as u64);
        for j in 0..self.router_dim {
            let noise = self.sigma * (rng.gen_f32() * 2.0 - 1.0);
            out[j] = (self.dphi(x[j] - self.target[j]) + noise) * inv_d;
        }
        let scale = self.experts as f32;
        for j in self.expert_range(self.route(worker, t)) {
            let noise = self.sigma * (rng.gen_f32() * 2.0 - 1.0);
            out[j] = (scale * self.dphi(x[j] - self.target[j]) + noise) * inv_d;
        }
    }

    /// Exact full (un-routed, expectation-over-routing) gradient norm² —
    /// the stationarity measure the bench reports alongside loss.
    pub fn full_grad_norm_sq(&self, x: &[f32]) -> f32 {
        let inv_d = 1.0 / self.dim() as f32;
        let mut acc = 0.0f32;
        for j in 0..self.router_dim {
            let g = self.dphi(x[j] - self.target[j]) * inv_d;
            acc += g * g;
        }
        // each expert is active with probability 1/E and scaled by E →
        // E[g_j] = dphi.
        for j in self.router_dim..self.dim() {
            let g = self.dphi(x[j] - self.target[j]) * inv_d;
            acc += g * g;
        }
        acc
    }

    /// Mean loss over experts (routing-independent scalar for logs).
    pub fn mean_loss(&self, x: &[f32]) -> f32 {
        let inv_d = 1.0 / self.dim() as f32;
        let mut acc = 0.0f32;
        for j in 0..self.router_dim {
            acc += self.phi(x[j] - self.target[j]);
        }
        for j in self.router_dim..self.dim() {
            acc += self.phi(x[j] - self.target[j]);
        }
        acc * inv_d
    }

    /// Deterministic non-zero starting point.
    pub fn x0(&self) -> Vec<f32> {
        (0..self.dim()).map(|i| 1.5 + (i as f32 * 0.7).sin()).collect()
    }
}

/// [`GradSource`] adapter so the MoE problem drives the full
/// server/worker loop (examples, benches, parity tests).
pub struct MoeGradSource {
    pub problem: MoeProblem,
}

impl GradSource for MoeGradSource {
    fn loss_grad(&mut self, weights: &[f32], worker: usize, t: u64) -> Result<(f32, Vec<f32>)> {
        let mut g = vec![0.0; weights.len()];
        self.problem.stoch_grad_into(weights, t, worker, &mut g);
        Ok((self.problem.loss(weights, worker, t), g))
    }

    fn dim(&self) -> usize {
        self.problem.dim()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradient_is_sparse_outside_router_and_routed_expert() {
        let p = MoeProblem::new(8, 32, 16, 0.05, 7);
        let x = p.x0();
        let mut g = vec![0.0; p.dim()];
        for t in 0..20u64 {
            for w in 0..4usize {
                p.stoch_grad_into(&x, t, w, &mut g);
                let e = p.route(w, t);
                let live = p.expert_range(e);
                for (j, &gj) in g.iter().enumerate() {
                    let is_live = j < p.router_dim || live.contains(&j);
                    if is_live {
                        continue;
                    }
                    assert_eq!(gj, 0.0, "t={t} w={w} coord {j} leaked outside expert {e}");
                }
                // the live part is genuinely non-zero
                assert!(g[..p.router_dim].iter().any(|&v| v != 0.0));
                assert!(g[live].iter().any(|&v| v != 0.0));
            }
        }
    }

    #[test]
    fn routing_is_deterministic_and_covers_experts() {
        let p = MoeProblem::new(4, 8, 4, 0.0, 3);
        let mut seen = [false; 4];
        for t in 0..64u64 {
            for w in 0..4usize {
                let e = p.route(w, t);
                assert!(e < 4);
                assert_eq!(e, p.route(w, t), "routing must be pure in (worker, t)");
                seen[e] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "top-1 routing never picked some expert: {seen:?}");
    }

    #[test]
    fn routed_gradient_matches_finite_difference() {
        let p = MoeProblem::new(3, 4, 2, 0.0, 11);
        let x = p.x0();
        let mut g = vec![0.0; p.dim()];
        let (w, t) = (1usize, 5u64);
        p.stoch_grad_into(&x, t, w, &mut g);
        let h = 1e-3f32;
        for j in 0..p.dim() {
            let mut xp = x.clone();
            let mut xm = x.clone();
            xp[j] += h;
            xm[j] -= h;
            let fd = (p.loss(&xp, w, t) - p.loss(&xm, w, t)) / (2.0 * h);
            assert!((fd - g[j]).abs() < 1e-3, "j={j}: fd={fd} g={}", g[j]);
        }
    }

    #[test]
    fn layout_names_bind_per_layer_rules() {
        let p = MoeProblem::new(2, 8, 4, 0.0, 0);
        let layout = p.layout();
        assert_eq!(layout.dim(), p.dim());
        let names: Vec<&str> = layout.tensors().iter().map(|t| t.name.as_str()).collect();
        assert_eq!(names, ["router", "expert0", "expert1"]);
        assert_eq!(layout.tensors()[1].start, 4);
        assert_eq!(layout.tensors()[2].len, 8);
        // the per-layer sparse spec from the README binds cleanly
        let spec =
            crate::quant::PolicySpec::parse("per-layer:expert*=topk@0.05,router=2").unwrap();
        let policy = crate::quant::CodecPolicy::new(spec, layout, 2).unwrap();
        assert_eq!(policy.bits(), &[2, 500, 500]);
    }

    #[test]
    fn grad_source_adapter_reports_dim_and_loss() {
        let mut src = MoeGradSource { problem: MoeProblem::new(2, 4, 2, 0.0, 1) };
        assert_eq!(src.dim(), 10);
        let x = src.problem.x0();
        let (loss, g) = src.loss_grad(&x, 0, 0).unwrap();
        assert!(loss > 0.0);
        assert_eq!(g.len(), 10);
    }
}
