//! The `artifacts/manifest.json` contract with the JAX layer.
//!
//! `python/compile/aot.py` writes, for every model, the ordered
//! parameter list (names + shapes), the train/eval input specs and the
//! artifact file names; plus the optimizer-kernel metadata (chunk size,
//! scalar order). This module parses it and provides the flat ⇄
//! per-parameter layout used everywhere on the Rust side.

pub mod moe;

use crate::util::json::{parse, Value};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelMeta>,
    pub optimizer: OptimizerMeta,
}

#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub params: Vec<ParamMeta>,
    pub total_params: usize,
    pub train_x: TensorSpec,
    pub train_y: TensorSpec,
    pub eval_x: TensorSpec,
    pub num_classes: usize,
    pub kind: String, // "classifier" | "lm"
    pub grad_artifact: String,
    pub eval_artifact: String,
}

#[derive(Clone, Debug)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamMeta {
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct OptimizerMeta {
    pub chunk: usize,
    pub qadam_artifact: String,
    pub qadam_scalars: Vec<String>,
    pub adam_artifact: String,
    pub adam_scalars: Vec<String>,
    pub wquant_artifact: String,
    pub wquant_scalars: Vec<String>,
}

fn tensor_spec(v: &Value) -> Result<TensorSpec> {
    Ok(TensorSpec {
        shape: v.get("shape")?.usize_arr()?,
        dtype: v.get("dtype")?.as_str()?.to_string(),
    })
}

fn str_arr(v: &Value) -> Result<Vec<String>> {
    v.as_arr()?.iter().map(|s| Ok(s.as_str()?.to_string())).collect()
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let p = artifacts_dir.join("manifest.json");
        let s = std::fs::read_to_string(&p)
            .with_context(|| format!("reading {} — run `make artifacts` first", p.display()))?;
        Self::from_json(&s).context("parsing manifest.json")
    }

    pub fn from_json(s: &str) -> Result<Self> {
        let v = parse(s)?;
        let mut models = BTreeMap::new();
        for (name, mv) in v.get("models")?.as_obj()? {
            let params = mv
                .get("params")?
                .as_arr()?
                .iter()
                .map(|pv| {
                    Ok(ParamMeta {
                        name: pv.get("name")?.as_str()?.to_string(),
                        shape: pv.get("shape")?.usize_arr()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ModelMeta {
                    params,
                    total_params: mv.get("total_params")?.as_usize()?,
                    train_x: tensor_spec(mv.get("train_x")?)?,
                    train_y: tensor_spec(mv.get("train_y")?)?,
                    eval_x: tensor_spec(mv.get("eval_x")?)?,
                    num_classes: mv.get("num_classes")?.as_usize()?,
                    kind: mv.get("kind")?.as_str()?.to_string(),
                    grad_artifact: mv.get("grad_artifact")?.as_str()?.to_string(),
                    eval_artifact: mv.get("eval_artifact")?.as_str()?.to_string(),
                },
            );
        }
        let o = v.get("optimizer")?;
        let optimizer = OptimizerMeta {
            chunk: o.get("chunk")?.as_usize()?,
            qadam_artifact: o.get("qadam_artifact")?.as_str()?.to_string(),
            qadam_scalars: str_arr(o.get("qadam_scalars")?)?,
            adam_artifact: o.get("adam_artifact")?.as_str()?.to_string(),
            adam_scalars: str_arr(o.get("adam_scalars")?)?,
            wquant_artifact: o.get("wquant_artifact")?.as_str()?.to_string(),
            wquant_scalars: str_arr(o.get("wquant_scalars")?)?,
        };
        Ok(Manifest { models, optimizer })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models.get(name).ok_or_else(|| {
            anyhow!("model '{}' not in manifest (have: {:?})", name, self.models.keys().collect::<Vec<_>>())
        })
    }
}

/// Byte/offset layout of the flattened parameter vector: parameters are
/// concatenated in manifest order (the same order as the HLO graph's
/// leading arguments).
#[derive(Clone, Debug)]
pub struct ParamLayout {
    pub names: Vec<String>,
    pub shapes: Vec<Vec<usize>>,
    pub offsets: Vec<usize>, // len = nparams + 1
}

impl ParamLayout {
    pub fn from_meta(meta: &ModelMeta) -> Self {
        let mut offsets = Vec::with_capacity(meta.params.len() + 1);
        let mut off = 0;
        for p in &meta.params {
            offsets.push(off);
            off += p.size();
        }
        offsets.push(off);
        debug_assert_eq!(off, meta.total_params);
        Self {
            names: meta.params.iter().map(|p| p.name.clone()).collect(),
            shapes: meta.params.iter().map(|p| p.shape.clone()).collect(),
            offsets,
        }
    }

    pub fn nparams(&self) -> usize {
        self.names.len()
    }

    pub fn total(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    /// Slice of parameter `i` inside a flat vector.
    pub fn slice<'a>(&self, flat: &'a [f32], i: usize) -> &'a [f32] {
        &flat[self.offsets[i]..self.offsets[i + 1]]
    }

    pub fn slice_mut<'a>(&self, flat: &'a mut [f32], i: usize) -> &'a mut [f32] {
        &mut flat[self.offsets[i]..self.offsets[i + 1]]
    }
}

/// Where the artifacts live; resolves relative to the repo root by
/// default (`QADAM_ARTIFACTS` overrides).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("QADAM_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // crate root = CARGO_MANIFEST_DIR at build time; fall back to cwd.
    let root = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
    Path::new(&root).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_meta() -> ModelMeta {
        ModelMeta {
            params: vec![
                ParamMeta { name: "w0".into(), shape: vec![4, 3] },
                ParamMeta { name: "b0".into(), shape: vec![3] },
                ParamMeta { name: "w1".into(), shape: vec![3, 2] },
            ],
            total_params: 21,
            train_x: TensorSpec { shape: vec![8, 4], dtype: "f32".into() },
            train_y: TensorSpec { shape: vec![8], dtype: "i32".into() },
            eval_x: TensorSpec { shape: vec![16, 4], dtype: "f32".into() },
            num_classes: 2,
            kind: "classifier".into(),
            grad_artifact: "grad_x.hlo.txt".into(),
            eval_artifact: "eval_x.hlo.txt".into(),
        }
    }

    #[test]
    fn layout_offsets() {
        let l = ParamLayout::from_meta(&fake_meta());
        assert_eq!(l.offsets, vec![0, 12, 15, 21]);
        assert_eq!(l.total(), 21);
        let flat: Vec<f32> = (0..21).map(|i| i as f32).collect();
        assert_eq!(l.slice(&flat, 1), &[12.0, 13.0, 14.0]);
    }

    #[test]
    fn manifest_parses_real_artifact() {
        // Uses the real artifacts dir when present (CI runs after
        // `make artifacts`); skips silently otherwise.
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.models.contains_key("mlp"));
        let mlp = m.model("mlp").unwrap();
        let l = ParamLayout::from_meta(mlp);
        assert_eq!(l.total(), mlp.total_params);
        assert_eq!(m.optimizer.chunk % 1024, 0);
        assert_eq!(m.optimizer.qadam_scalars, vec!["alpha", "beta", "theta", "eps", "qlo"]);
    }
}
