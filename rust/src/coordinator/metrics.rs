//! Per-run metrics: training-loss / accuracy curves (the data behind
//! Figures 3–4) and cumulative communication (Tables 2–3 columns).

use anyhow::{Context, Result};
use std::io::Write;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct Row {
    pub t: u64,
    pub epoch: u64,
    pub train_loss: f32,
    pub test_acc: f32,
    /// MB sent worker→server per round per worker, measured.
    pub up_mb_per_round: f64,
    /// MB sent server→worker per round per worker, measured.
    pub down_mb_per_round: f64,
    pub residual_norm: f32,
    /// Workers whose deltas entered this round's mean (0 for rows that
    /// are pure evals, e.g. restored-at-horizon).
    pub participation: usize,
    /// Cumulative full-weights resync frames (delta-downlink mode).
    pub resyncs: u64,
    /// Mean uplink code bits/element the codec policy chose this round
    /// (the static codec's analytic bits when no policy is installed).
    pub policy_bits: f64,
    /// Which parameter-server shard this row describes: `-1` is the
    /// merged (whole-fleet) row every run emits; multi-shard runs add
    /// one row per shard (`0..N`) with that shard's bytes/resyncs.
    pub shard: i64,
    /// Wall-clock round time in milliseconds, measured by the injected
    /// obs clock at the coordinator seam — `0` when tracing is off
    /// (the clock is never read on the disabled path).
    pub round_ms: f64,
    /// Median staleness (rounds of age) across the deltas this round
    /// admitted — `-1` for sync rounds, where every delta is fresh by
    /// construction and the column would read as a misleading 0.
    pub staleness_p50: i64,
    /// Sampled cohort size this round (`--cohort`); `-1` when client
    /// sampling is off and the full worker fleet participates.
    pub cohort: i64,
}

#[derive(Clone, Debug, Default)]
pub struct MetricsLog {
    pub label: String,
    pub rows: Vec<Row>,
}

impl MetricsLog {
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), rows: Vec::new() }
    }

    pub fn push(&mut self, row: Row) {
        self.rows.push(row);
    }

    pub fn last_acc(&self) -> Option<f32> {
        self.rows.last().map(|r| r.test_acc)
    }

    /// Best test accuracy over the run, skipping non-finite evals: a
    /// diverged eval (NaN loss → NaN accuracy) must not become the
    /// "best" — and `reduce(f32::max)` would otherwise report
    /// `Some(NaN)` for a NaN-only run. `None` when no finite eval
    /// exists.
    pub fn best_acc(&self) -> Option<f32> {
        self.rows.iter().map(|r| r.test_acc).filter(|a| a.is_finite()).reduce(f32::max)
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        // New columns are appended at the end (`round_ms`, then the
        // async pair `staleness_p50,cohort`) so positional consumers of
        // the earlier columns keep parsing.
        writeln!(
            f,
            "t,epoch,train_loss,test_acc,up_mb_per_round,down_mb_per_round,residual_norm,participation,resyncs,policy_bits,shard,round_ms,staleness_p50,cohort"
        )?;
        for r in &self.rows {
            writeln!(
                f,
                "{},{},{},{},{:.6},{:.6},{},{},{},{:.3},{},{:.3},{},{}",
                r.t,
                r.epoch,
                r.train_loss,
                r.test_acc,
                r.up_mb_per_round,
                r.down_mb_per_round,
                r.residual_norm,
                r.participation,
                r.resyncs,
                r.policy_bits,
                r.shard,
                r.round_ms,
                r.staleness_p50,
                r.cohort
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(t: u64, acc: f32, shard: i64) -> Row {
        Row {
            t,
            epoch: 0,
            train_loss: 0.0,
            test_acc: acc,
            up_mb_per_round: 0.0,
            down_mb_per_round: 0.0,
            residual_norm: 0.0,
            participation: 1,
            resyncs: 0,
            policy_bits: 3.0,
            shard,
            round_ms: 0.0,
            staleness_p50: -1,
            cohort: -1,
        }
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut log = MetricsLog::new("test");
        log.push(Row {
            t: 1,
            epoch: 0,
            train_loss: 2.5,
            test_acc: 0.1,
            up_mb_per_round: 0.5,
            down_mb_per_round: 1.0,
            residual_norm: 0.01,
            participation: 7,
            resyncs: 2,
            policy_bits: 2.75,
            shard: -1,
            round_ms: 12.5,
            staleness_p50: 1,
            cohort: 32,
        });
        let dir = std::env::temp_dir().join("qadam_metrics_test");
        let p = dir.join("m.csv");
        log.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.starts_with("t,epoch,"));
        let header = s.lines().next().unwrap();
        assert!(header
            .ends_with("participation,resyncs,policy_bits,shard,round_ms,staleness_p50,cohort"));
        assert_eq!(s.lines().count(), 2);
        assert!(s.lines().nth(1).unwrap().ends_with(",7,2,2.750,-1,12.500,1,32"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn best_acc() {
        let mut log = MetricsLog::new("x");
        for (i, a) in [0.1f32, 0.5, 0.3].iter().enumerate() {
            log.push(row(i as u64, *a, -1));
        }
        assert_eq!(log.best_acc(), Some(0.5));
        assert_eq!(log.last_acc(), Some(0.3));
    }

    #[test]
    fn best_acc_skips_non_finite_evals() {
        let mut log = MetricsLog::new("x");
        log.push(row(0, 0.4, -1));
        log.push(row(1, f32::NAN, -1)); // diverged eval mid-run
        log.push(row(2, 0.2, -1));
        assert_eq!(log.best_acc(), Some(0.4), "NaN must not mask a finite best");

        let mut diverged = MetricsLog::new("y");
        diverged.push(row(0, f32::NAN, -1));
        diverged.push(row(1, f32::INFINITY, -1));
        assert_eq!(diverged.best_acc(), None, "no finite eval: no best, not Some(NaN)");
        assert!(diverged.last_acc().unwrap().is_infinite(), "last_acc stays raw");
    }

    /// Multi-shard logs interleave one merged row (`shard = -1`) with
    /// one row per shard at each log point; the CSV must preserve that
    /// ordering and shape so per-shard consumers can group by the
    /// final columns.
    #[test]
    fn multi_shard_csv_ordering_and_shape() {
        let mut log = MetricsLog::new("sharded");
        for t in [1u64, 2] {
            log.push(row(t, 0.5, -1));
            log.push(row(t, 0.5, 0));
            log.push(row(t, 0.5, 1));
        }
        let dir = std::env::temp_dir().join("qadam_metrics_test_sharded");
        let p = dir.join("m.csv");
        log.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        let ncols = s.lines().next().unwrap().split(',').count();
        let rows: Vec<&str> = s.lines().skip(1).collect();
        assert_eq!(rows.len(), 6, "2 log points x (merged + 2 shards)");
        let shard_of = |line: &str| -> i64 {
            let cols: Vec<&str> = line.split(',').collect();
            assert_eq!(cols.len(), ncols, "ragged row: {line}");
            cols[ncols - 4].parse().unwrap() // shard precedes round_ms,staleness_p50,cohort
        };
        let shards: Vec<i64> = rows.iter().map(|l| shard_of(l)).collect();
        assert_eq!(shards, vec![-1, 0, 1, -1, 0, 1], "merged row leads each log point");
        let t_of = |line: &str| -> u64 { line.split(',').next().unwrap().parse().unwrap() };
        assert_eq!(rows.iter().map(|l| t_of(l)).collect::<Vec<_>>(), vec![1, 1, 1, 2, 2, 2]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
