//! The synchronous training driver: server + N workers + dataset +
//! PJRT model graphs, one process, byte-accurate comm accounting.

use super::config::{BusKind, Downlink, Engine, ExperimentConfig, Method};
use super::metrics::{MetricsLog, Row};
use crate::data::{Dataset, SyntheticText, SyntheticVector, SyntheticVision};
use crate::elastic::{ChaosTransport, StalenessPolicy, StragglerPolicy, WorkerRegistry};
use crate::models::{artifacts_dir, Manifest};
use crate::obs::{RoundObs, Span, SpanKind};
use crate::optim::{BlockwiseSgdEf, LrSchedule, QAdamEf, TernGradSgd, WorkerOpt};
use crate::ps::transport::{LocalBus, ThreadedBus, Transport};
use crate::ps::worker::{ModelGradSource, Worker};
use crate::ps::{ShardPlan, ShardedServer};
use crate::quant::{CodecPolicy, TensorLayout};
use crate::runtime::kernel::PjrtQAdam;
use crate::runtime::{KernelQAdam, ModelRuntime, Runtime};
use anyhow::{anyhow, Result};
use std::sync::Arc;

#[derive(Clone, Debug)]
pub struct RunSummary {
    pub label: String,
    pub final_acc: f32,
    pub best_acc: f32,
    pub final_loss: f32,
    /// Measured uplink MB per iteration per worker (Comm column).
    pub comm_mb_per_iter: f64,
    /// Measured downlink MB per iteration per worker (full broadcasts
    /// or compressed weight deltas, resync frames included).
    pub down_mb_per_iter: f64,
    /// Analytic model size in MB at the broadcast precision (Size column).
    pub model_size_mb: f64,
    /// fp32 model size in MB for the ratio.
    pub model_size_fp32_mb: f64,
    pub steps: u64,
}

impl RunSummary {
    /// Paper-style table row.
    pub fn table_row(&self) -> String {
        format!(
            "{:<28} acc={:.2}% comm={:.3}MB/iter size={:.3}MB (fp32 {:.3}MB)",
            self.label,
            100.0 * self.final_acc,
            self.comm_mb_per_iter,
            self.model_size_mb,
            self.model_size_fp32_mb
        )
    }
}

pub struct Trainer {
    pub cfg: ExperimentConfig,
    /// The (possibly 1-shard) server fleet: `--shards 1` builds the
    /// single unsharded `ParameterServer` behind the same merged API,
    /// byte-identical to pre-shard builds.
    ps: ShardedServer,
    workers: Vec<Worker>,
    bus: Box<dyn Transport>,
    model: Arc<ModelRuntime>,
    data: Arc<dyn Dataset>,
    /// Set by [`Trainer::restore`], cleared by [`Trainer::run`]: lets
    /// `run` distinguish "restored at/past the horizon" (log a final
    /// eval) from a fresh `steps = 0` config or a repeated `run` call.
    restored: bool,
    pub log: MetricsLog,
    /// Observability, off (`None`) by default. The round loop never
    /// reads a clock, records a span, or touches a registry unless
    /// [`Trainer::enable_obs`] installed one — that branch-on-None is
    /// the zero-overhead-off guarantee (`rust/tests/obs.rs` pins
    /// bit-identical trajectories, `alloc_regression.rs` pins the
    /// allocation profile).
    obs: Option<RoundObs>,
    /// Duration of the last observed round in ns (0 with obs off) —
    /// the `round_ms` CSV column.
    last_round_ns: u64,
    /// Client-sampling registry (`--cohort`): `Some` makes the worker
    /// slots impersonate a fresh cohort of logical ids each round;
    /// `None` keeps the fixed worker fleet (the seed behavior).
    registry: Option<WorkerRegistry>,
    /// Median admitted-delta age of the last async round (`-1` in sync
    /// mode or when a round admitted nothing) — the `staleness_p50`
    /// CSV column.
    last_staleness_p50: i64,
    /// Cumulative deltas rejected as too stale (async mode), fed to the
    /// obs registry's `qadam_stale_rejected_total` counter.
    stale_rejected: u64,
}

fn make_dataset(cfg: &ExperimentConfig, seq: usize, vocab: usize) -> Result<Arc<dyn Dataset>> {
    Ok(match cfg.dataset.as_str() {
        "cifar10_sim" => Arc::new(SyntheticVision::cifar10_sim(cfg.seed)),
        "cifar100_sim" => Arc::new(SyntheticVision::cifar100_sim(cfg.seed)),
        "vector" => Arc::new(SyntheticVector::new(seq.max(1), vocab.max(2), cfg.seed)),
        "text" => Arc::new(SyntheticText::new(vocab, seq, cfg.seed)),
        other => return Err(anyhow!("unknown dataset '{other}'")),
    })
}

fn make_opt(
    cfg: &ExperimentConfig,
    dim: usize,
    kernel: Option<&Arc<KernelQAdam>>,
    policy: Option<CodecPolicy>,
) -> Result<Box<dyn WorkerOpt>> {
    Ok(match cfg.method {
        Method::QAdam { kg, error_feedback } => match (kg, cfg.engine) {
            (Some(k), Engine::PjrtKernel) => {
                let kernel = kernel.ok_or_else(|| anyhow!("pjrt engine needs the qadam kernel"))?;
                if !error_feedback {
                    return Err(anyhow!("the AOT kernel always applies error feedback; use engine=native for the no-EF ablation"));
                }
                Box::new(PjrtQAdam::new(kernel.clone(), dim, k, cfg.lr))
            }
            (Some(k), Engine::Native) => {
                let mut opt = QAdamEf::new(
                    dim,
                    crate::quant::gradient_codec(Some(k)),
                    error_feedback,
                    cfg.lr,
                    crate::optim::ThetaSchedule::Const { theta: crate::defaults::THETA },
                    crate::defaults::BETA,
                    crate::defaults::EPS,
                );
                if let Some(p) = policy {
                    opt = opt.with_policy(p);
                }
                Box::new(opt)
            }
            (None, _) => Box::new(QAdamEf::full_precision(dim, cfg.lr)),
        },
        Method::TernGrad => Box::new(TernGradSgd::new(dim, terngrad_lr(cfg.lr))),
        Method::Blockwise { block, momentum } => {
            Box::new(BlockwiseSgdEf::new(dim, momentum, block, sgd_lr(cfg.lr)))
        }
    })
}

/// Bind the config's codec-policy spec to the model layout — one fresh
/// instance per endpoint (each worker, plus the delta downlink), since
/// every endpoint runs its own controller over its own EF state.
/// `None` for `static`: the caller then keeps the policy-free path,
/// which stays byte-identical to pre-policy builds.
fn make_policy(cfg: &ExperimentConfig, layout: &TensorLayout) -> Result<Option<CodecPolicy>> {
    if cfg.codec_policy.is_static() {
        return Ok(None);
    }
    let kg = match cfg.method {
        Method::QAdam { kg: Some(k), .. } => k,
        // `ExperimentConfig::validate` rejects this combination before
        // any policy is built.
        _ => return Err(anyhow!("codec policy needs a k_g-bearing method")),
    };
    Ok(Some(CodecPolicy::new(cfg.codec_policy.clone(), layout.clone(), kg)?))
}

/// The paper tunes baseline SGD-family LRs separately (its grid:
/// {0.1, 0.05, 0.01} vs Adam's 1e-3). When the config carries an
/// Adam-scaled LR, rescale to the SGD grid, preserving the decay shape.
/// The x30 factor is our grid-search winner at the CPU step budget
/// (x100 = the paper's 0.1 diverges within 128 steps on the sim
/// workloads; see EXPERIMENTS.md).
fn sgd_lr(lr: LrSchedule) -> LrSchedule {
    match lr {
        LrSchedule::ExpDecay { alpha, half_every } if alpha <= 0.01 => {
            LrSchedule::ExpDecay { alpha: alpha * 30.0, half_every }
        }
        other => other,
    }
}

fn terngrad_lr(lr: LrSchedule) -> LrSchedule {
    sgd_lr(lr)
}

impl Trainer {
    pub fn new(mut cfg: ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let artifacts = artifacts_dir();
        let manifest = Manifest::load(&artifacts)?;
        let rt = Runtime::cpu()?;
        let model = Arc::new(ModelRuntime::load(&rt, &artifacts, &manifest, &cfg.model)?);
        // Per-worker batch is baked into the AOT graph.
        let aot_batch = model.meta.train_x.shape[0];
        if cfg.batch != aot_batch {
            eprintln!("[trainer] batch {} -> {} (AOT graph batch)", cfg.batch, aot_batch);
            cfg.batch = aot_batch;
        }
        // For "lm": (vocab, seq). For "vector": (classes, feature dim).
        let (vocab, seq) = match model.meta.kind.as_str() {
            "lm" => (model.meta.num_classes, model.meta.train_x.shape[1]),
            _ => (model.meta.num_classes, model.meta.train_x.shape[1..].iter().product()),
        };
        let data = make_dataset(&cfg, seq, vocab)?;
        // Per-sample feature count must match the AOT graph input.
        let model_feats: usize = model.meta.train_x.shape[1..].iter().product();
        let data_feats = match data.train_batch(0, 0, 1) {
            crate::data::Batch::Vision { x, .. } => x.len(),
            crate::data::Batch::Text { x, .. } => x.len(),
        };
        if data_feats != model_feats {
            return Err(anyhow!(
                "dataset '{}' produces {} features/sample but model '{}' expects {:?} — pick a matching dataset",
                cfg.dataset, data_feats, cfg.model, &model.meta.train_x.shape[1..]
            ));
        }
        if model.meta.kind == "classifier" && data.num_classes() != model.meta.num_classes {
            return Err(anyhow!(
                "dataset classes {} != model classes {}",
                data.num_classes(),
                model.meta.num_classes
            ));
        }
        let dim = model.dim();
        let kernel = match (cfg.engine, &cfg.method) {
            (Engine::PjrtKernel, Method::QAdam { kg: Some(_), .. }) => {
                Some(Arc::new(KernelQAdam::load(&rt, &artifacts, &manifest)?))
            }
            _ => None,
        };
        // Engine selection: the threaded bus pairs with the sharded
        // server so both halves of the round run parallel; both engines
        // produce bit-identical trajectories (ps::transport parity tests).
        let (mut bus, ps_threads): (Box<dyn Transport>, usize) = match cfg.bus {
            BusKind::Sequential => (Box::new(LocalBus::default()), 1),
            BusKind::Threaded => {
                (Box::new(ThreadedBus::new()), crate::util::par::available_threads())
            }
        };
        // With chaos or a non-wait straggler policy the bus is wrapped
        // in the elastic layer; the default config keeps the bare bus
        // (and hence the seed round path) untouched.
        if cfg.chaos.is_some() || cfg.straggler != StragglerPolicy::Wait {
            bus = Box::new(
                ChaosTransport::new(bus, cfg.chaos.clone().unwrap_or_default())
                    .with_policy(cfg.straggler, cfg.min_participation)
                    .with_async(cfg.async_rounds),
            );
        }
        // The named parameter blocks of the flat vector — the
        // granularity the codec policy decides at, and (under a
        // non-static policy) the boundaries shard ranges snap to.
        let layout = TensorLayout::from_named(
            &model.meta.params.iter().map(|p| (p.name.clone(), p.size())).collect::<Vec<_>>(),
        );
        let plan = ShardPlan::build(dim, cfg.shards, &cfg.codec_policy, &layout)?;
        let mut ps = ShardedServer::new(
            model.init_flat(cfg.seed),
            cfg.kx,
            plan.clone(),
            crate::ps::server::DEFAULT_BLOCK,
            ps_threads,
        );
        if cfg.downlink == Downlink::Delta {
            // The downlink reuses the gradient codec family: the method's
            // kg level when it has one, fp32 Identity otherwise.
            let kg = match cfg.method {
                Method::QAdam { kg, .. } => kg,
                _ => None,
            };
            if kg.is_none() {
                eprintln!(
                    "[trainer] downlink=delta without a k_g-bearing method: delta frames \
                     ship fp32 (protocol-correct, but no downlink compression)"
                );
            }
            ps.enable_delta_downlink(kg, cfg.resync_every);
            // Non-static policy: every shard runs its own controller
            // over the layout cropped to its range, and delta frames
            // carry per-tensor codecs.
            if !cfg.codec_policy.is_static() {
                if let Some(kg) = kg {
                    ps.set_downlink_policy(&cfg.codec_policy, &layout, kg)?;
                }
            }
        }
        // Under client sampling the process holds one worker *slot* per
        // cohort seat, not one per logical worker: a 100k-id registry
        // costs K slots of memory, and each round re-points the slots
        // at that round's sampled ids (`Worker::id` drives both data
        // sampling and the wire identity). Without sampling, slots and
        // logical workers coincide (the seed behavior).
        let nslots = cfg.cohort.unwrap_or(cfg.workers);
        let registry = cfg.cohort.map(|_| WorkerRegistry::new(cfg.registry, cfg.seed));
        let mut workers = Vec::with_capacity(nslots);
        for i in 0..nslots {
            let opt = make_opt(&cfg, dim, kernel.as_ref(), make_policy(&cfg, &layout)?)?;
            let src = ModelGradSource { model: model.clone(), data: data.clone(), batch: cfg.batch };
            let mut w = Worker::new(i as u32, opt, Box::new(src), cfg.seed ^ 0x5a5a);
            w.set_shards(plan.clone());
            workers.push(w);
        }
        let log = MetricsLog::new(cfg.run_label());
        Ok(Self {
            cfg,
            ps,
            workers,
            bus,
            model,
            data,
            restored: false,
            log,
            obs: None,
            last_round_ns: 0,
            registry,
            last_staleness_p50: -1,
            stale_rejected: 0,
        })
    }

    /// Install observability (span tracing + metrics registry). Build
    /// the [`RoundObs`] with this trainer's shard count so the
    /// per-shard metric series line up with the CSV's shard rows.
    pub fn enable_obs(&mut self, obs: RoundObs) {
        self.obs = Some(obs);
    }

    /// The installed obs registry (for mounting a `/metrics` listener
    /// on it); `None` when obs is off.
    pub fn obs_registry(&self) -> Option<std::sync::Arc<crate::obs::MetricsRegistry>> {
        self.obs.as_ref().map(|o| o.registry.clone())
    }

    /// Model size at broadcast precision, MB.
    fn model_size_mb(&self) -> (f64, f64) {
        let fp32 = self.model.dim() as f64 * 4.0 / 1e6;
        let quant = match self.cfg.kx {
            Some(kx) => {
                self.model.dim() as f64 * crate::quant::WQuant::new(kx).code_bits() as f64 / 8.0 / 1e6
            }
            None => fp32,
        };
        (quant, fp32)
    }

    /// Uplink policy bits for a metrics row (the worker controller's
    /// choice, falling back to the static codec's analytic bits).
    fn row_policy_bits(&self) -> f64 {
        self.workers[0].policy_bits().unwrap_or_else(|| self.workers[0].bits_per_element())
    }

    /// Push the merged metrics row plus, in multi-shard runs, one row
    /// per shard carrying that shard's bytes/resyncs (the `shard` CSV
    /// dimension; single-shard runs emit only the merged row).
    fn log_rows(&mut self, t: u64, epoch: u64, loss: f32, acc: f32, participation: usize) {
        let nworkers = self.workers.len();
        let merged = self.ps.stats();
        let policy_bits = self.row_policy_bits();
        let cohort = self.cfg.cohort.map_or(-1, |k| k as i64);
        self.log.push(Row {
            t,
            epoch,
            train_loss: loss,
            test_acc: acc,
            up_mb_per_round: merged.up_mb_per_round_per_worker(nworkers),
            down_mb_per_round: merged.down_mb_per_round_per_worker(nworkers),
            residual_norm: self.workers[0].residual_norm(),
            participation,
            resyncs: merged.resyncs,
            policy_bits,
            shard: -1,
            round_ms: self.last_round_ns as f64 / 1e6,
            staleness_p50: self.last_staleness_p50,
            cohort,
        });
        if self.ps.nshards() > 1 {
            for s in 0..self.ps.nshards() {
                let st = *self.ps.shard_stats(s);
                self.log.push(Row {
                    t,
                    epoch,
                    train_loss: loss,
                    test_acc: acc,
                    up_mb_per_round: st.up_mb_per_round_per_worker(nworkers),
                    down_mb_per_round: st.down_mb_per_round_per_worker(nworkers),
                    residual_norm: self.workers[0].residual_norm(),
                    participation,
                    resyncs: st.resyncs,
                    // the column's semantics are uplink bits on every
                    // row (per-shard *downlink* controller choices are
                    // queryable via `ParameterServer::downlink_bits`)
                    policy_bits,
                    shard: s as i64,
                    // an in-process trainer drives every shard lane
                    // through one round call, so per-shard time is not
                    // observable here — 0, like byte-attribution spans
                    round_ms: 0.0,
                    staleness_p50: self.last_staleness_p50,
                    cohort,
                });
            }
        }
    }

    /// Record one observed round: the merged phase spans (real
    /// durations from the seam timestamps `ts = [t0..t3]`), per-shard
    /// frame and per-lane reply byte-attribution spans (`dur_ns = 0` —
    /// an in-process trainer drives all lanes through one transport
    /// call, so it cannot see inside them; a `serve` process owns one
    /// shard and gets real per-shard times), and the registry feed.
    /// Only called with obs installed; everything it does is stores
    /// into preallocated obs state.
    fn record_round_obs(
        &mut self,
        t: u64,
        frames: &[crate::ps::protocol::ToWorker],
        replies: &[Vec<crate::ps::protocol::ToServer>],
        ts: [u64; 4],
        participation: usize,
        loss: f32,
    ) {
        let [t0, t1, t2, t3] = ts;
        let merged = self.ps.stats();
        let nshards = self.ps.nshards();
        let residual_inf = self.workers[0].residual_inf_norm();
        let policy_bits = self.row_policy_bits();
        let evictions = self.bus.straggler_evictions();
        let faults = self.bus.fault_stats();
        let Some(obs) = &mut self.obs else { return };
        let span = |kind, start_ns, dur_ns, bytes| Span {
            round: t,
            shard: -1,
            lane: -1,
            kind,
            start_ns,
            dur_ns,
            bytes,
        };
        let down: u64 = frames.iter().map(|f| f.wire_bytes() as u64).sum();
        let up: u64 = replies.iter().flatten().map(|r| r.wire_bytes() as u64).sum();
        obs.record(span(SpanKind::Broadcast, t0, t1 - t0, down));
        for (s, f) in frames.iter().enumerate() {
            obs.record(Span {
                shard: s as i64,
                dur_ns: 0,
                bytes: f.wire_bytes() as u64,
                ..span(SpanKind::Broadcast, t0, 0, 0)
            });
        }
        obs.record(span(SpanKind::Gather, t1, t2 - t1, up));
        for (s, lane) in replies.iter().enumerate() {
            for r in lane {
                obs.record(Span {
                    shard: s as i64,
                    lane: r.worker() as i64,
                    bytes: r.wire_bytes() as u64,
                    ..span(SpanKind::Gather, t1, 0, 0)
                });
            }
        }
        obs.record(span(SpanKind::DecodeApply, t2, t3 - t2, 0));
        obs.registry.observe_comm(&merged, &[]);
        for s in 0..nshards {
            obs.registry.observe_shard(s, self.ps.shard_stats(s));
        }
        obs.registry.observe_round(t3 - t0, participation, residual_inf, policy_bits, loss);
        obs.registry.straggler_evictions.set_cumulative(evictions);
        if let Some(f) = faults {
            obs.registry.observe_faults(&f);
        }
    }

    /// Post-apply bookkeeping of one async round: refund every rejected
    /// delta at full scale — and the un-applied `1 − w(age)` fraction
    /// of every down-weighted admitted one — into its sender's EF
    /// residual, then update the staleness summary (the CSV p50 and the
    /// cumulative reject count the obs registry exports).
    fn settle_async(
        &mut self,
        replies: &[Vec<crate::ps::protocol::ToServer>],
        ar: &crate::ps::AsyncRound,
        policy: &StalenessPolicy,
    ) -> Result<()> {
        let mut admitted_ages: Vec<u64> = Vec::new();
        for (lane, lane_replies) in replies.iter().enumerate() {
            for (idx, r) in lane_replies.iter().enumerate() {
                let age = ar.ages[lane][idx];
                // `rejected` is built lane-major in index order, so
                // membership is a binary search
                let scale = if ar.rejected.binary_search(&(lane, idx)).is_ok() {
                    1.0
                } else {
                    admitted_ages.push(age);
                    1.0 - policy.weight(age)
                };
                if scale > 0.0 {
                    self.refund(lane, r, scale)?;
                }
            }
        }
        self.stale_rejected += ar.rejected.len() as u64;
        admitted_ages.sort_unstable();
        self.last_staleness_p50 = match admitted_ages.len() {
            0 => -1, // a quiet/all-rejected tick has no admitted ages
            n => admitted_ages[n / 2] as i64,
        };
        if let Some(obs) = &self.obs {
            for &a in &admitted_ages {
                obs.registry.staleness_rounds.observe(a);
            }
            obs.registry.stale_rejected.set_cumulative(self.stale_rejected);
        }
        Ok(())
    }

    /// Fold `scale ×` a reply's decoded payload back into the EF
    /// residual of the slot that sent it. Under client sampling the
    /// sending slot is recovered by redrawing the cohort of the round
    /// the reply was computed against (the draw is pure in
    /// `(seed, t)`); the slot — possibly already re-pointed at a newer
    /// logical id — briefly re-assumes the reply's id for the absorb.
    fn refund(
        &mut self,
        lane: usize,
        reply: &crate::ps::protocol::ToServer,
        scale: f32,
    ) -> Result<()> {
        let slot = match &self.registry {
            Some(reg) => {
                match reg.cohort(reply.round(), self.workers.len()).binary_search(&reply.worker())
                {
                    Ok(slot) => slot,
                    // not in that round's cohort: a forged or corrupt
                    // id — drop the refund rather than crediting the
                    // wrong slot
                    Err(_) => return Ok(()),
                }
            }
            None => {
                let slot = reply.worker() as usize;
                if slot >= self.workers.len() {
                    return Ok(());
                }
                slot
            }
        };
        let w = &mut self.workers[slot];
        if !w.has_error_feedback() {
            return Ok(()); // no residual to fold into (e.g. TernGrad)
        }
        let cur = w.id;
        w.id = reply.worker();
        let res = w.absorb_rejected(lane, reply, scale);
        w.id = cur;
        res
    }

    pub fn run(&mut self) -> Result<RunSummary> {
        let mut last_loss = f32::NAN;
        let start = self.ps.step() + 1; // continues after a restore
        for t in start..=self.cfg.steps {
            let epoch = self.cfg.epoch_of(t);
            // Client sampling: re-point the worker slots at round t's
            // cohort before anything reads a worker id (the id drives
            // both the data draw and the wire identity). The draw runs
            // on its own rng stream, so with sampling off this branch
            // never executes and the round is byte-identical to seed.
            if let Some(reg) = &self.registry {
                for (slot, lid) in reg.cohort(t, self.workers.len()).into_iter().enumerate() {
                    self.workers[slot].id = lid;
                }
            }
            // Downlink membership first: who receives (and is charged
            // for) this round's broadcast, and whether a rejoin forces
            // a full-weights resync — on every shard: the rejoined
            // worker missed frames on every lane.
            let m = self.bus.membership(t, self.workers.len());
            if m.rejoined {
                self.ps.force_resync_all();
            }
            // Obs timestamps bracket the phases at this seam — the
            // clock is only read when obs is on, and never inside the
            // transport/server calls themselves (INV-DET stays
            // waiver-free: `ps/` code is untouched by timing).
            let t0 = self.obs.as_mut().map_or(0, |o| o.now_ns());
            let frames = self.ps.broadcast_at_epoch(m.present, epoch);
            let t1 = self.obs.as_mut().map_or(0, |o| o.now_ns());
            let replies = self.bus.round_sharded(&frames, &mut self.workers)?;
            let t2 = self.obs.as_mut().map_or(0, |o| o.now_ns());
            let part = if self.cfg.async_rounds {
                // Bounded-staleness apply: admit by age, then refund
                // every rejected delta (and the un-applied fraction of
                // each down-weighted one) into its sender's residual.
                let policy =
                    StalenessPolicy::new(self.cfg.staleness, self.cfg.staleness_down_weight);
                let ar = self.ps.apply_async(&replies, &policy)?;
                self.settle_async(&replies, &ar, &policy)?;
                ar.part
            } else {
                self.ps.apply(&replies)?
            };
            let t3 = self.obs.as_mut().map_or(0, |o| o.now_ns());
            last_loss = part.mean_loss;
            if self.obs.is_some() {
                self.last_round_ns = t3 - t0;
                self.record_round_obs(t, &frames, &replies, [t0, t1, t2, t3], part.count(), last_loss);
            }
            let do_eval = self.cfg.eval_every > 0 && t % self.cfg.eval_every == 0;
            if do_eval || t == self.cfg.steps {
                // Inlined eval so the requantize phase (`Q_x` of the
                // master for the eval/serving view) gets its span.
                let r0 = self.obs.as_mut().map_or(0, |o| o.now_ns());
                let w = self.ps.output_weights();
                if let Some(obs) = &mut self.obs {
                    let r1 = obs.now_ns();
                    obs.record(Span {
                        round: t,
                        shard: -1,
                        lane: -1,
                        kind: SpanKind::Requantize,
                        start_ns: r0,
                        dur_ns: r1 - r0,
                        bytes: 0,
                    });
                }
                let acc = self.model.accuracy(&w, self.data.as_ref(), self.cfg.eval_batches)?;
                if let Some(obs) = &self.obs {
                    obs.registry.test_acc.set(acc as f64);
                }
                self.log_rows(t, epoch, last_loss, acc, part.count());
                eprintln!(
                    "[{}] t={t} epoch={epoch} loss={last_loss:.4} acc={:.2}%",
                    self.log.label,
                    100.0 * acc
                );
            }
            if let Some(obs) = &mut self.obs {
                // per-round flush: a live `qadam top` tails whole lines
                obs.end_round();
            }
        }
        if start > self.cfg.steps && self.restored {
            // Restored at/past the configured horizon: no rounds ran, so
            // the loop above logged nothing and `last_loss` would stay
            // NaN. Evaluate the restored weights instead — the fused
            // fwd/bwd graph on the step's deterministic batch for the
            // training loss (there is no loss-only AOT graph), plus the
            // usual eval on the same view — and log the final row. (A
            // fresh `steps = 0` trainer or a repeated `run` call is not
            // a restore and keeps the seed behavior: no rounds, no rows.)
            let t = self.ps.step();
            let epoch = self.cfg.epoch_of(t.max(1));
            let w = self.ps.output_weights();
            let batch = self.data.train_batch(0, t, self.cfg.batch);
            let (loss, _grad) = self.model.loss_grad(&w, &batch)?;
            last_loss = loss;
            let acc = self.model.accuracy(&w, self.data.as_ref(), self.cfg.eval_batches)?;
            // participation 0: no round ran, this row is a pure eval
            self.log_rows(t, epoch, last_loss, acc, 0);
            eprintln!(
                "[{}] t={t} (restored at horizon) loss={last_loss:.4} acc={:.2}%",
                self.log.label,
                100.0 * acc
            );
        }
        self.restored = false;
        let (size_mb, fp32_mb) = self.model_size_mb();
        let stats = self.ps.stats();
        Ok(RunSummary {
            label: self.log.label.clone(),
            final_acc: self.log.last_acc().unwrap_or(0.0),
            best_acc: self.log.best_acc().unwrap_or(0.0),
            final_loss: last_loss,
            comm_mb_per_iter: stats.up_mb_per_round_per_worker(self.workers.len()),
            down_mb_per_iter: stats.down_mb_per_round_per_worker(self.workers.len()),
            model_size_mb: size_mb,
            model_size_fp32_mb: fp32_mb,
            steps: self.cfg.steps,
        })
    }

    /// Snapshot the current training state (weights + step + the
    /// per-shard delta-downlink server state when that mode is on +
    /// worker optimizer states when available). Single-shard runs write
    /// the version-2 layout byte-identically; multi-shard runs write
    /// one blob per shard (version 3).
    pub fn checkpoint(&self) -> super::checkpoint::Checkpoint {
        let mut server = Vec::new();
        for (i, &(start, _len)) in self.ps.plan().ranges().iter().enumerate() {
            if let Some((replica, residual)) = self.ps.shard(i).downlink_state() {
                server.push(super::checkpoint::ShardServerState {
                    start,
                    replica: replica.to_vec(),
                    residual: residual.to_vec(),
                });
            }
        }
        super::checkpoint::Checkpoint {
            model: self.cfg.model.clone(),
            step: self.ps.step(),
            x: self.ps.master(),
            server,
            workers: self
                .workers
                .iter()
                .map(|w| {
                    w.opt_state().map(|(m, v, e)| super::checkpoint::WorkerState {
                        m: m.to_vec(),
                        v: v.to_vec(),
                        e: e.to_vec(),
                    })
                })
                .collect(),
        }
    }

    /// Resume from a checkpoint written by [`Trainer::checkpoint`].
    ///
    /// In delta-downlink mode the per-shard replica/residual blobs are
    /// stitched back to full vectors, re-sliced by *this* run's shard
    /// plan, and every worker's weight view is seeded from the replica
    /// (the replica is the bit-exact worker state) — so a resumed run
    /// continues the exact trajectory of an uninterrupted one, and a
    /// file written under any shard count restores under any other
    /// (v2 ↔ v3). Restoring a checkpoint without downlink state (a
    /// version-1 file, or one written in full mode) forces full resync
    /// frames on the next round instead.
    pub fn restore(&mut self, ckpt: &super::checkpoint::Checkpoint) -> Result<()> {
        if ckpt.model != self.cfg.model {
            return Err(anyhow!("checkpoint is for model '{}', trainer runs '{}'", ckpt.model, self.cfg.model));
        }
        if ckpt.x.len() != self.model.dim() {
            return Err(anyhow!("checkpoint dim {} != model dim {}", ckpt.x.len(), self.model.dim()));
        }
        self.ps.restore(&ckpt.x, ckpt.step);
        if self.cfg.downlink == Downlink::Delta {
            // Absent state (a v1 file, or one written in full mode):
            // `ps.restore` already scheduled the resync frames that
            // re-sync the workers. Full mode ignores any state blobs.
            if let Some((replica, residual)) = ckpt.stitched_server(self.model.dim())? {
                self.ps.restore_downlink_full(&replica, &residual)?;
                for w in self.workers.iter_mut() {
                    w.restore_weights(&replica);
                }
            }
        }
        for (w, ws) in self.workers.iter_mut().zip(&ckpt.workers) {
            if let Some(ws) = ws {
                w.opt_restore(&ws.m, &ws.v, &ws.e);
            }
        }
        self.restored = true;
        Ok(())
    }

    /// Evaluate arbitrary weights (e.g. from a checkpoint) on the
    /// configured dataset.
    pub fn eval_weights(&self, w: &[f32]) -> Result<f32> {
        self.model.accuracy(w, self.data.as_ref(), self.cfg.eval_batches)
    }

    /// Post-training weight quantization (the paper's **WQuan** rows):
    /// train at full precision, then quantize the final weights and
    /// re-evaluate.
    pub fn eval_post_quantized(&self, kx: u32) -> Result<f32> {
        let wq = crate::quant::WQuant::new(kx);
        let mut q = vec![0.0f32; self.ps.dim()];
        wq.quantize_into(&self.ps.master(), &mut q);
        self.model.accuracy(&q, self.data.as_ref(), self.cfg.eval_batches)
    }
}
