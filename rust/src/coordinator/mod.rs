//! Experiment configuration, the synchronous training driver, and
//! metrics logging — the launcher layer a user actually touches.

pub mod checkpoint;
pub mod config;
pub mod metrics;
pub mod tables;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use config::{ExperimentConfig, Method, ObsConfig};
pub use metrics::{MetricsLog, Row};
pub use trainer::{RunSummary, Trainer};
