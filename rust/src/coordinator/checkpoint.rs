//! Checkpointing: binary snapshots of the parameter-server state
//! (master weights + step) and, when available, per-worker optimizer
//! state (m, v, e) — enough to resume training or to serve/evaluate the
//! model without rerunning.
//!
//! Format (little-endian):
//! ```text
//!   magic "QADMCKPT" (8)  version u32  step u64
//!   model_name: len u32 + utf8
//!   dim u64, x: dim f32
//!   nworkers u32; per worker: flags u8 (1 = has m/v/e), then 3*dim f32
//!   crc32 of everything above (simple polynomial, self-contained)
//! ```

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"QADMCKPT";
const VERSION: u32 = 1;

#[derive(Clone, Debug, Default)]
pub struct WorkerState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub e: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub model: String,
    pub step: u64,
    pub x: Vec<f32>,
    pub workers: Vec<Option<WorkerState>>,
}

/// Tiny self-contained CRC32 (IEEE polynomial, bitwise — checkpoints
/// are written once per eval cadence, not per step).
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xffff_ffffu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let m = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xedb8_8320 & m);
        }
    }
    !crc
}

fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    buf.reserve(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn get_f32s(b: &[u8], off: &mut usize, n: usize) -> Result<Vec<f32>> {
    if b.len() < *off + n * 4 {
        bail!("checkpoint truncated");
    }
    let out = b[*off..*off + n * 4]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    *off += n * 4;
    Ok(out)
}

impl Checkpoint {
    pub fn to_bytes(&self) -> Vec<u8> {
        let dim = self.x.len();
        let mut buf = Vec::with_capacity(64 + dim * 4 * (1 + 3 * self.workers.len()));
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&self.step.to_le_bytes());
        buf.extend_from_slice(&(self.model.len() as u32).to_le_bytes());
        buf.extend_from_slice(self.model.as_bytes());
        buf.extend_from_slice(&(dim as u64).to_le_bytes());
        put_f32s(&mut buf, &self.x);
        buf.extend_from_slice(&(self.workers.len() as u32).to_le_bytes());
        for w in &self.workers {
            match w {
                None => buf.push(0),
                Some(ws) => {
                    buf.push(1);
                    put_f32s(&mut buf, &ws.m);
                    put_f32s(&mut buf, &ws.v);
                    put_f32s(&mut buf, &ws.e);
                }
            }
        }
        let crc = crc32(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        buf
    }

    pub fn from_bytes(b: &[u8]) -> Result<Self> {
        if b.len() < 8 + 4 + 8 + 4 + 8 + 4 + 4 {
            bail!("checkpoint too short");
        }
        let (body, tail) = b.split_at(b.len() - 4);
        let want = u32::from_le_bytes(tail.try_into().unwrap());
        if crc32(body) != want {
            bail!("checkpoint CRC mismatch");
        }
        if &body[..8] != MAGIC {
            bail!("bad checkpoint magic");
        }
        let mut off = 8usize;
        let rd_u32 = |b: &[u8], off: &mut usize| -> u32 {
            let v = u32::from_le_bytes(b[*off..*off + 4].try_into().unwrap());
            *off += 4;
            v
        };
        let rd_u64 = |b: &[u8], off: &mut usize| -> u64 {
            let v = u64::from_le_bytes(b[*off..*off + 8].try_into().unwrap());
            *off += 8;
            v
        };
        let version = rd_u32(body, &mut off);
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let step = rd_u64(body, &mut off);
        let name_len = rd_u32(body, &mut off) as usize;
        if body.len() < off + name_len {
            bail!("checkpoint truncated (name)");
        }
        let model = String::from_utf8(body[off..off + name_len].to_vec())?;
        off += name_len;
        let dim = rd_u64(body, &mut off) as usize;
        let x = get_f32s(body, &mut off, dim)?;
        let nworkers = rd_u32(body, &mut off) as usize;
        let mut workers = Vec::with_capacity(nworkers);
        for _ in 0..nworkers {
            if body.len() <= off {
                bail!("checkpoint truncated (worker flag)");
            }
            let flag = body[off];
            off += 1;
            workers.push(match flag {
                0 => None,
                1 => Some(WorkerState {
                    m: get_f32s(body, &mut off, dim)?,
                    v: get_f32s(body, &mut off, dim)?,
                    e: get_f32s(body, &mut off, dim)?,
                }),
                f => bail!("bad worker flag {f}"),
            });
        }
        Ok(Checkpoint { model, step, x, workers })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(&self.to_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?; // atomic replace
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut buf = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?
            .read_to_end(&mut buf)?;
        Self::from_bytes(&buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            model: "mlp".into(),
            step: 123,
            x: (0..37).map(|i| i as f32 * 0.5).collect(),
            workers: vec![
                None,
                Some(WorkerState {
                    m: vec![1.0; 37],
                    v: vec![2.0; 37],
                    e: vec![-0.5; 37],
                }),
            ],
        }
    }

    #[test]
    fn roundtrip() {
        let c = sample();
        let b = c.to_bytes();
        let back = Checkpoint::from_bytes(&b).unwrap();
        assert_eq!(back.model, "mlp");
        assert_eq!(back.step, 123);
        assert_eq!(back.x, c.x);
        assert!(back.workers[0].is_none());
        assert_eq!(back.workers[1].as_ref().unwrap().e, vec![-0.5; 37]);
    }

    #[test]
    fn corruption_detected() {
        let c = sample();
        let mut b = c.to_bytes();
        let mid = b.len() / 2;
        b[mid] ^= 0x40;
        assert!(Checkpoint::from_bytes(&b).is_err());
        // truncation
        let b2 = c.to_bytes();
        assert!(Checkpoint::from_bytes(&b2[..b2.len() - 9]).is_err());
    }

    #[test]
    fn file_roundtrip_atomic() {
        let dir = std::env::temp_dir().join(format!("qadam_ckpt_{}", std::process::id()));
        let p = dir.join("a.ckpt");
        let c = sample();
        c.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.x, c.x);
        assert!(!p.with_extension("tmp").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn crc32_known_value() {
        // IEEE CRC32 of "123456789" is 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
    }
}
