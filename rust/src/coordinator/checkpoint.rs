//! Checkpointing: binary snapshots of the parameter-server state
//! (master weights + step), the per-shard delta-downlink server state
//! (worker replica `x̂` + server EF residual, one blob per shard) when
//! that mode is on, and, when available, per-worker optimizer state
//! (m, v, e) — enough to resume training or to serve/evaluate the
//! model without rerunning.
//!
//! Format (little-endian). Version 2 — written whenever the downlink
//! state is absent or covers the whole vector in one blob (every
//! `--shards 1` run), byte-identical to pre-shard builds:
//! ```text
//!   magic "QADMCKPT" (8)  version u32  step u64
//!   model_name: len u32 + utf8
//!   dim u64, x: dim f32
//!   server flags u8 (1 = delta-downlink state), then 2*dim f32
//!     (replica x̂, then residual e_server)
//!   nworkers u32; per worker: flags u8 (1 = has m/v/e), then 3*dim f32
//!   crc32 of everything above (simple polynomial, self-contained)
//! ```
//! Version 3 — written by multi-shard runs — replaces the server
//! section with per-shard blobs (everything else unchanged):
//! ```text
//!   nshards u32; per shard: start u64, len u64,
//!     replica: len f32, residual: len f32
//! ```
//! Version-1 checkpoints (no server section) still load with an empty
//! `server` (the trainer forces a resync frame on resume). Restore is
//! **shard-count-agnostic**: [`Checkpoint::stitched_server`] reassembles
//! the blobs into full-dim vectors, which the trainer re-slices by its
//! own plan — so a v2 file loads into an N-shard run and a v3 file
//! loads into a `--shards 1` run.
//!
//! `from_bytes` must never panic: it feeds off files an operator hands
//! us. Every read is bounds-checked (truncated or hostile headers —
//! oversized `name_len`/`dim`/`nshards`/`nworkers` — return
//! `Err("checkpoint truncated …")`), and trailing garbage after a
//! structurally complete body is rejected too.

use crate::util::bytes;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"QADMCKPT";
/// The single-blob (unsharded) format version.
const VERSION: u32 = 2;
/// The per-shard-blob format version.
const VERSION_SHARDED: u32 = 3;
/// Every checkpoint version this build reads (`qadam info` reports it
/// so operators can check compatibility before a rollout).
pub const SUPPORTED_VERSIONS: &[u32] = &[1, 2, 3];

#[derive(Clone, Debug, Default)]
pub struct WorkerState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub e: Vec<f32>,
}

/// One shard's delta-downlink state: the worker-replica estimate `x̂`
/// and the server-side EF residual over
/// `[start, start + replica.len())`. A version-2 file is the single
/// full-range blob (`start == 0`, `replica.len() == dim`).
#[derive(Clone, Debug, Default)]
pub struct ShardServerState {
    pub start: usize,
    pub replica: Vec<f32>,
    pub residual: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub model: String,
    pub step: u64,
    pub x: Vec<f32>,
    /// Per-shard delta-downlink state blobs (empty in full-downlink
    /// runs and in version-1 checkpoints). The blobs of a delta-mode
    /// run tile `[0, dim)`; [`Self::stitched_server`] reassembles them.
    pub server: Vec<ShardServerState>,
    pub workers: Vec<Option<WorkerState>>,
}

/// Tiny self-contained CRC32 (IEEE polynomial, bitwise — checkpoints
/// are written once per eval cadence, not per step).
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xffff_ffffu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let m = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xedb8_8320 & m);
        }
    }
    !crc
}

fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    buf.reserve(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

// --- bounds-checked readers -------------------------------------------------
// Thin error-mapping wrappers over `util::bytes`: a truncated or
// hostile header can only ever produce Err, never an out-of-bounds
// panic or an attacker-sized allocation.

// qadam: decode
fn rd_u8(b: &[u8], off: &mut usize) -> Result<u8> {
    bytes::u8_at(b, off).ok_or_else(|| anyhow!("checkpoint truncated (u8)"))
}

// qadam: decode
fn rd_u32(b: &[u8], off: &mut usize) -> Result<u32> {
    bytes::u32_at(b, off).ok_or_else(|| anyhow!("checkpoint truncated (u32)"))
}

// qadam: decode
fn rd_u64(b: &[u8], off: &mut usize) -> Result<u64> {
    bytes::u64_at(b, off).ok_or_else(|| anyhow!("checkpoint truncated (u64)"))
}

// qadam: decode
fn get_f32s(b: &[u8], off: &mut usize, n: usize) -> Result<Vec<f32>> {
    bytes::f32s_at(b, off, n).ok_or_else(|| anyhow!("checkpoint truncated (f32 run)"))
}

impl Checkpoint {
    /// Is this the single full-range (or absent) downlink state the
    /// version-2 layout encodes? Multi-shard blobs need version 3.
    fn needs_v3(&self) -> bool {
        match self.server.as_slice() {
            [] => false,
            [s] => !(s.start == 0 && s.replica.len() == self.x.len()),
            _ => true,
        }
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let dim = self.x.len();
        let sharded = self.needs_v3();
        let version = if sharded { VERSION_SHARDED } else { VERSION };
        let mut buf = Vec::with_capacity(64 + dim * 4 * (3 + 3 * self.workers.len()));
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&version.to_le_bytes());
        buf.extend_from_slice(&self.step.to_le_bytes());
        buf.extend_from_slice(&(self.model.len() as u32).to_le_bytes());
        buf.extend_from_slice(self.model.as_bytes());
        buf.extend_from_slice(&(dim as u64).to_le_bytes());
        put_f32s(&mut buf, &self.x);
        if sharded {
            buf.extend_from_slice(&(self.server.len() as u32).to_le_bytes());
            for s in &self.server {
                // The reader bounds every blob against `dim`; writing an
                // out-of-range blob would seal a corrupt file under a
                // valid CRC, so this must hold in release builds too.
                assert!(
                    s.replica.len() == s.residual.len()
                        && s.start + s.replica.len() <= dim,
                    "shard state {}+{}/{} out of dim {dim}",
                    s.start,
                    s.replica.len(),
                    s.residual.len()
                );
                buf.extend_from_slice(&(s.start as u64).to_le_bytes());
                buf.extend_from_slice(&(s.replica.len() as u64).to_le_bytes());
                put_f32s(&mut buf, &s.replica);
                put_f32s(&mut buf, &s.residual);
            }
        } else {
            match self.server.first() {
                None => buf.push(0),
                Some(s) => {
                    // The v2 reader infers both run lengths from `dim`.
                    assert!(
                        s.replica.len() == dim && s.residual.len() == dim,
                        "server state dims {}/{} != dim {dim}",
                        s.replica.len(),
                        s.residual.len()
                    );
                    buf.push(1);
                    put_f32s(&mut buf, &s.replica);
                    put_f32s(&mut buf, &s.residual);
                }
            }
        }
        buf.extend_from_slice(&(self.workers.len() as u32).to_le_bytes());
        for w in &self.workers {
            match w {
                None => buf.push(0),
                Some(ws) => {
                    buf.push(1);
                    put_f32s(&mut buf, &ws.m);
                    put_f32s(&mut buf, &ws.v);
                    put_f32s(&mut buf, &ws.e);
                }
            }
        }
        let crc = crc32(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        buf
    }

    pub fn from_bytes(b: &[u8]) -> Result<Self> {
        // magic + version + crc is the absolute minimum
        if b.len() < 8 + 4 + 4 {
            bail!("checkpoint truncated (header)");
        }
        let (body, tail) = b.split_at(b.len() - 4);
        let want = {
            let mut toff = 0usize;
            bytes::u32_at(tail, &mut toff).ok_or_else(|| anyhow!("checkpoint truncated (crc)"))?
        };
        if crc32(body) != want {
            bail!("checkpoint CRC mismatch");
        }
        let mut off = 0usize;
        let magic = bytes::take_at(body, &mut off, 8);
        if magic != Some(MAGIC.as_slice()) {
            bail!("bad checkpoint magic");
        }
        let version = rd_u32(body, &mut off)?;
        if !SUPPORTED_VERSIONS.contains(&version) {
            bail!("unsupported checkpoint version {version}");
        }
        let step = rd_u64(body, &mut off)?;
        let name_len = rd_u32(body, &mut off)? as usize;
        let name = bytes::take_at(body, &mut off, name_len)
            .ok_or_else(|| anyhow!("checkpoint truncated (name)"))?;
        let model = String::from_utf8(name.to_vec())?;
        let dim64 = rd_u64(body, &mut off)?;
        let dim = usize::try_from(dim64).map_err(|_| anyhow!("checkpoint truncated (dim)"))?;
        let x = get_f32s(body, &mut off, dim)?;
        let server = match version {
            1 => Vec::new(),
            2 => match rd_u8(body, &mut off)? {
                0 => Vec::new(),
                1 => vec![ShardServerState {
                    start: 0,
                    replica: get_f32s(body, &mut off, dim)?,
                    residual: get_f32s(body, &mut off, dim)?,
                }],
                f => bail!("bad server-state flag {f}"),
            },
            _ => {
                let nshards = rd_u32(body, &mut off)? as usize;
                // each shard record is at least start + len (16 bytes) —
                // a huge count cannot name more shards than bytes left
                if nshards == 0 || nshards > (body.len() - off) / 16 {
                    bail!("checkpoint truncated (shard count {nshards})");
                }
                let mut blobs = Vec::with_capacity(nshards);
                for i in 0..nshards {
                    let start64 = rd_u64(body, &mut off)?;
                    let len64 = rd_u64(body, &mut off)?;
                    let start = usize::try_from(start64)
                        .map_err(|_| anyhow!("checkpoint truncated (shard {i} start)"))?;
                    let len = usize::try_from(len64)
                        .map_err(|_| anyhow!("checkpoint truncated (shard {i} len)"))?;
                    if start.checked_add(len).filter(|&e| e <= dim).is_none() {
                        bail!("shard {i} range {start}+{len} outside dim {dim}");
                    }
                    blobs.push(ShardServerState {
                        start,
                        replica: get_f32s(body, &mut off, len)?,
                        residual: get_f32s(body, &mut off, len)?,
                    });
                }
                blobs
            }
        };
        let nworkers = rd_u32(body, &mut off)? as usize;
        // each worker record is at least its flag byte — a huge count
        // cannot name more workers than there are bytes left
        if nworkers > body.len() - off {
            bail!("checkpoint truncated (worker count)");
        }
        let mut workers = Vec::with_capacity(nworkers);
        for _ in 0..nworkers {
            workers.push(match rd_u8(body, &mut off)? {
                0 => None,
                1 => Some(WorkerState {
                    m: get_f32s(body, &mut off, dim)?,
                    v: get_f32s(body, &mut off, dim)?,
                    e: get_f32s(body, &mut off, dim)?,
                }),
                f => bail!("bad worker flag {f}"),
            });
        }
        if off != body.len() {
            bail!("checkpoint truncated (trailing bytes)");
        }
        Ok(Checkpoint { model, step, x, server, workers })
    }

    /// Stitch the per-shard downlink blobs back into full-dim
    /// `(replica, residual)` vectors — `None` when the file carries no
    /// downlink state, `Err` when the blobs do not tile `[0, dim)`
    /// exactly. Restoring through the stitched vectors (re-sliced by
    /// the *current* plan) is what makes a checkpoint written under any
    /// shard count load under any other.
    pub fn stitched_server(&self, dim: usize) -> Result<Option<(Vec<f32>, Vec<f32>)>> {
        if self.server.is_empty() {
            return Ok(None);
        }
        let mut blobs: Vec<&ShardServerState> = self.server.iter().collect();
        blobs.sort_by_key(|s| s.start);
        let mut replica = Vec::with_capacity(dim);
        let mut residual = Vec::with_capacity(dim);
        for b in blobs {
            if b.start != replica.len() {
                bail!(
                    "shard state at {} does not tile the vector (expected offset {})",
                    b.start,
                    replica.len()
                );
            }
            if b.replica.len() != b.residual.len() {
                bail!("shard state at {} has mismatched blob lengths", b.start);
            }
            replica.extend_from_slice(&b.replica);
            residual.extend_from_slice(&b.residual);
        }
        if replica.len() != dim {
            bail!("shard states cover {} of dim {dim}", replica.len());
        }
        Ok(Some((replica, residual)))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(&self.to_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?; // atomic replace
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut buf = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?
            .read_to_end(&mut buf)?;
        Self::from_bytes(&buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            model: "mlp".into(),
            step: 123,
            x: (0..37).map(|i| i as f32 * 0.5).collect(),
            server: Vec::new(),
            workers: vec![
                None,
                Some(WorkerState {
                    m: vec![1.0; 37],
                    v: vec![2.0; 37],
                    e: vec![-0.5; 37],
                }),
            ],
        }
    }

    fn sample_with_server() -> Checkpoint {
        let mut c = sample();
        c.server = vec![ShardServerState {
            start: 0,
            replica: (0..37).map(|i| i as f32 * 0.25).collect(),
            residual: vec![0.125; 37],
        }];
        c
    }

    fn sample_sharded() -> Checkpoint {
        let mut c = sample();
        c.server = vec![
            ShardServerState {
                start: 0,
                replica: (0..20).map(|i| i as f32 * 0.25).collect(),
                residual: vec![0.125; 20],
            },
            ShardServerState {
                start: 20,
                replica: (20..37).map(|i| i as f32 * 0.25).collect(),
                residual: vec![0.25; 17],
            },
        ];
        c
    }

    #[test]
    fn roundtrip() {
        let c = sample();
        let b = c.to_bytes();
        let back = Checkpoint::from_bytes(&b).unwrap();
        assert_eq!(back.model, "mlp");
        assert_eq!(back.step, 123);
        assert_eq!(back.x, c.x);
        assert!(back.server.is_empty());
        assert!(back.workers[0].is_none());
        assert_eq!(back.workers[1].as_ref().unwrap().e, vec![-0.5; 37]);
    }

    #[test]
    fn roundtrip_with_server_state() {
        let c = sample_with_server();
        let b = c.to_bytes();
        // a single full-range blob stays on the version-2 layout
        assert_eq!(u32::from_le_bytes(b[8..12].try_into().unwrap()), 2);
        let back = Checkpoint::from_bytes(&b).unwrap();
        assert_eq!(back.server.len(), 1);
        assert_eq!(back.server[0].start, 0);
        assert_eq!(back.server[0].replica, c.server[0].replica);
        assert_eq!(back.server[0].residual, c.server[0].residual);
    }

    /// Multi-shard blobs round-trip on the version-3 layout, and the
    /// stitched view reassembles them — so a v3 file restores under
    /// `--shards 1` and a v2 file restores under any shard count.
    #[test]
    fn sharded_checkpoint_v3_roundtrip_and_stitching() {
        let c = sample_sharded();
        let b = c.to_bytes();
        assert_eq!(u32::from_le_bytes(b[8..12].try_into().unwrap()), 3);
        let back = Checkpoint::from_bytes(&b).unwrap();
        assert_eq!(back.server.len(), 2);
        assert_eq!(back.server[1].start, 20);
        assert_eq!(back.server[1].replica, c.server[1].replica);
        // stitched: v3 blobs == the v2 single blob's full vectors
        let (replica, residual) = back.stitched_server(37).unwrap().unwrap();
        let v2 = sample_with_server();
        assert_eq!(replica, v2.server[0].replica);
        let want: Vec<f32> =
            (0..37).map(|i| if i < 20 { 0.125 } else { 0.25 }).collect();
        assert_eq!(residual, want);
        // and the v2 file stitches identically
        let (r2, _) = v2.stitched_server(37).unwrap().unwrap();
        assert_eq!(r2, replica);
        // no state at all stitches to None
        assert!(sample().stitched_server(37).unwrap().is_none());
        // blobs that overlap (or leave a gap) are a clear error
        let mut gap = sample_sharded();
        gap.server[1].start = 19;
        let b = gap.to_bytes();
        let gap = Checkpoint::from_bytes(&b).unwrap();
        assert!(gap.stitched_server(37).is_err());
        assert!(sample_with_server().stitched_server(36).is_err());
    }

    #[test]
    fn version1_checkpoints_still_load() {
        // A v1 body is the v2 body minus the server flag byte.
        let c = sample();
        let v2 = c.to_bytes();
        let body = &v2[..v2.len() - 4];
        let mut v1 = Vec::with_capacity(body.len());
        v1.extend_from_slice(&body[..8]);
        v1.extend_from_slice(&1u32.to_le_bytes()); // version
        let x_end = 12 + 8 + 4 + c.model.len() + 8 + c.x.len() * 4;
        v1.extend_from_slice(&body[12..x_end]);
        v1.extend_from_slice(&body[x_end + 1..]); // skip the server flag
        let crc = crc32(&v1);
        v1.extend_from_slice(&crc.to_le_bytes());
        let back = Checkpoint::from_bytes(&v1).unwrap();
        assert_eq!(back.step, 123);
        assert_eq!(back.x, c.x);
        assert!(back.server.is_empty());
        assert_eq!(back.workers.len(), 2);
    }

    #[test]
    fn corruption_detected() {
        let c = sample();
        let mut b = c.to_bytes();
        let mid = b.len() / 2;
        b[mid] ^= 0x40;
        assert!(Checkpoint::from_bytes(&b).is_err());
        // truncation
        let b2 = c.to_bytes();
        assert!(Checkpoint::from_bytes(&b2[..b2.len() - 9]).is_err());
    }

    /// Satellite acceptance: `from_bytes` never panics — truncation at
    /// every byte offset and a single-bit flip at every byte offset
    /// must both return Err cleanly.
    #[test]
    fn truncation_and_bitflip_sweep_never_panics() {
        for c in [sample(), sample_with_server(), sample_sharded()] {
            let b = c.to_bytes();
            for len in 0..b.len() {
                assert!(
                    Checkpoint::from_bytes(&b[..len]).is_err(),
                    "truncated to {len} of {} bytes must not parse",
                    b.len()
                );
            }
            for i in 0..b.len() {
                let mut m = b.clone();
                m[i] ^= 0x01;
                // CRC (or the CRC field itself) catches every single-bit
                // flip; the parse must fail without panicking.
                assert!(Checkpoint::from_bytes(&m).is_err(), "bit flip at {i} must not parse");
            }
        }
    }

    /// Hostile headers that *pass* the CRC (an attacker can always
    /// recompute it) must still fail cleanly: oversized name/dim/worker
    /// counts may not panic, wrap offsets, or trigger huge allocations.
    #[test]
    fn hostile_headers_with_valid_crc_fail_cleanly() {
        let base = sample_with_server().to_bytes();
        let body_len = base.len() - 4;
        let reseal = |mut body: Vec<u8>| -> Vec<u8> {
            let crc = crc32(&body);
            body.extend_from_slice(&crc.to_le_bytes());
            body
        };
        let patched = |at: usize, val: &[u8]| -> Vec<u8> {
            let mut body = base[..body_len].to_vec();
            body[at..at + val.len()].copy_from_slice(val);
            reseal(body)
        };
        // name_len at offset 20 (after magic+version+step)
        for huge in [u32::MAX, body_len as u32] {
            let b = patched(20, &huge.to_le_bytes());
            assert!(Checkpoint::from_bytes(&b).is_err());
        }
        // dim at offset 24 + name_len ("mlp" = 3)
        let dim_off = 24 + 3;
        for huge in [u64::MAX, 1u64 << 40, (body_len as u64) + 1] {
            let b = patched(dim_off, &huge.to_le_bytes());
            assert!(Checkpoint::from_bytes(&b).is_err());
        }
        // server flag gets an unknown value
        let flag_off = dim_off + 8 + 37 * 4;
        assert!(Checkpoint::from_bytes(&patched(flag_off, &[7])).is_err());
        // nworkers (after flag + 2*dim f32)
        let nw_off = flag_off + 1 + 2 * 37 * 4;
        for huge in [u32::MAX, (body_len as u32) + 1] {
            let b = patched(nw_off, &huge.to_le_bytes());
            assert!(Checkpoint::from_bytes(&b).is_err());
        }
        // unknown version
        assert!(Checkpoint::from_bytes(&patched(8, &99u32.to_le_bytes())).is_err());
        // hostile v3 headers: oversized shard count / out-of-range blob
        // ranges may not panic, wrap offsets, or allocate wildly
        let v3 = sample_sharded().to_bytes();
        let v3_len = v3.len() - 4;
        let patched3 = |at: usize, val: &[u8]| -> Vec<u8> {
            let mut body = v3[..v3_len].to_vec();
            body[at..at + val.len()].copy_from_slice(val);
            reseal(body)
        };
        // nshards sits right after x (dim_off + 8 + 37*4)
        let nshards_off = 24 + 3 + 8 + 37 * 4;
        for huge in [u32::MAX, (v3_len as u32) + 1, 0] {
            assert!(Checkpoint::from_bytes(&patched3(nshards_off, &huge.to_le_bytes())).is_err());
        }
        // shard 0's start pushed outside dim
        let start_off = nshards_off + 4;
        assert!(Checkpoint::from_bytes(&patched3(start_off, &u64::MAX.to_le_bytes())).is_err());
        // shard 0's len overrunning dim
        assert!(
            Checkpoint::from_bytes(&patched3(start_off + 8, &(1u64 << 40).to_le_bytes())).is_err()
        );
        assert!(Checkpoint::from_bytes(&v3).is_ok(), "the unpatched v3 bytes still parse");
        // trailing garbage after a structurally complete body
        let mut body = base[..body_len].to_vec();
        body.push(0xab);
        assert!(Checkpoint::from_bytes(&reseal(body)).is_err());
        // sanity: the unpatched bytes still parse
        assert!(Checkpoint::from_bytes(&base).is_ok());
    }

    #[test]
    fn file_roundtrip_atomic() {
        let dir = std::env::temp_dir().join(format!("qadam_ckpt_{}", std::process::id()));
        let p = dir.join("a.ckpt");
        let c = sample();
        c.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.x, c.x);
        assert!(!p.with_extension("tmp").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn crc32_known_value() {
        // IEEE CRC32 of "123456789" is 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
    }
}
