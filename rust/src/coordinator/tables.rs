//! The Tables 2–3 / Figures 3–4 experiment grid — shared by
//! `examples/table_sweep.rs` and the `table2`/`table3` benches so
//! `cargo bench` regenerates the paper tables from the same code path.

use super::config::{Engine, ExperimentConfig, Method};
use super::metrics::MetricsLog;
use super::trainer::{RunSummary, Trainer};
use crate::optim::LrSchedule;
use anyhow::{bail, Result};

pub struct RowSpec {
    pub name: &'static str,
    pub method: Method,
    pub kx: Option<u32>,
    /// post-training weight quantization level (the WQuan rows).
    pub post_kx: Option<u32>,
}

/// The row grid of Tables 2–3: gradient-quantization block (Comm column
/// varies), weight-quantization block incl. post-hoc WQuan (Size column
/// varies), and the combined block. The no-EF ablation row is ours (the
/// paper motivates EF but does not table it).
pub fn rows() -> Vec<RowSpec> {
    let q = |kg| Method::QAdam { kg, error_feedback: true };
    vec![
        RowSpec { name: "QADAM fp32", method: q(None), kx: None, post_kx: None },
        RowSpec { name: "QADAM kg=2 (3bit)", method: q(Some(2)), kx: None, post_kx: None },
        RowSpec { name: "QADAM kg=0 (2bit)", method: q(Some(0)), kx: None, post_kx: None },
        RowSpec {
            name: "QADAM kg=2 no-EF",
            method: Method::QAdam { kg: Some(2), error_feedback: false },
            kx: None,
            post_kx: None,
        },
        RowSpec { name: "TernGrad", method: Method::TernGrad, kx: None, post_kx: None },
        RowSpec {
            name: "Zheng et al.[44]",
            method: Method::Blockwise { block: 4096, momentum: 0.9 },
            kx: None,
            post_kx: None,
        },
        RowSpec { name: "QADAM kx=14 (16bit)", method: q(None), kx: Some(14), post_kx: None },
        RowSpec { name: "QADAM kx=6  (8bit)", method: q(None), kx: Some(6), post_kx: None },
        RowSpec { name: "WQuan kx=14", method: q(None), kx: None, post_kx: Some(14) },
        RowSpec { name: "WQuan kx=6", method: q(None), kx: None, post_kx: Some(6) },
        RowSpec { name: "QADAM kg=2 kx=14", method: q(Some(2)), kx: Some(14), post_kx: None },
        RowSpec { name: "QADAM kg=0 kx=14", method: q(Some(0)), kx: Some(14), post_kx: None },
        RowSpec { name: "QADAM kg=2 kx=6", method: q(Some(2)), kx: Some(6), post_kx: None },
        RowSpec { name: "QADAM kg=0 kx=6", method: q(Some(0)), kx: Some(6), post_kx: None },
    ]
}

/// Model/dataset selection for a table/figure id.
pub fn workload(which: &str) -> Result<(&'static str, &'static str, &'static str)> {
    Ok(match which {
        "table2" | "fig3" => ("resnet_sim", "cifar100_sim", "Table 2 (ResNet-101/CIFAR100 stand-in)"),
        "table3" | "fig4" => ("vgg_sim", "cifar10_sim", "Table 3 (VGG16/CIFAR10 stand-in)"),
        other => bail!("unknown target '{other}' (table2|table3|fig3|fig4)"),
    })
}

/// Run the whole grid; prints the paper-style table, writes the summary
/// CSV (plus per-run curve CSVs when `which` is a fig), returns the
/// summaries.
pub fn run_table(which: &str, steps: u64, workers: usize, outdir: &str) -> Result<Vec<(String, RunSummary)>> {
    let (model, dataset, title) = workload(which)?;
    let curves = which.starts_with("fig");
    std::fs::create_dir_all(outdir)?;

    println!("=== {title}: {steps} steps x {workers} workers ===");
    println!("{:<22} {:>9} {:>12} {:>10}", "Method", "Test Acc", "Comm MB/it", "Size MB");
    let mut summary_csv = String::from("method,acc,comm_mb_per_iter,size_mb,fp32_mb\n");
    let mut out = Vec::new();
    for row in rows() {
        let cfg = ExperimentConfig {
            model: model.into(),
            dataset: dataset.into(),
            method: row.method,
            kx: row.kx,
            workers,
            batch: 16,
            steps,
            steps_per_epoch: 64,
            lr: LrSchedule::ExpDecay { alpha: 1e-3, half_every: 50 },
            engine: Engine::Native,
            bus: super::config::BusKind::default(),
            downlink: super::config::Downlink::default(),
            resync_every: 64,
            chaos: None,
            codec_policy: crate::quant::PolicySpec::Static,
            shards: 1,
            straggler: crate::elastic::StragglerPolicy::Wait,
            min_participation: 1,
            async_rounds: false,
            staleness: 0,
            staleness_down_weight: false,
            cohort: None,
            registry: 100_000,
            seed: 0,
            eval_every: if curves { 32 } else { 0 },
            eval_batches: if curves { 2 } else { 4 },
        };
        let mut tr = Trainer::new(cfg)?;
        let mut s = tr.run()?;
        if let Some(pkx) = row.post_kx {
            s.final_acc = tr.eval_post_quantized(pkx)?;
            s.model_size_mb =
                s.model_size_fp32_mb * crate::quant::WQuant::new(pkx).code_bits() as f64 / 32.0;
        }
        println!(
            "{:<22} {:>8.2}% {:>12.4} {:>10.4}",
            row.name,
            100.0 * s.final_acc,
            s.comm_mb_per_iter,
            s.model_size_mb
        );
        summary_csv.push_str(&format!(
            "{},{},{:.6},{:.6},{:.6}\n",
            row.name, s.final_acc, s.comm_mb_per_iter, s.model_size_mb, s.model_size_fp32_mb
        ));
        if curves {
            let mut log = MetricsLog::new(row.name);
            log.rows = tr.log.rows.clone();
            let fname = format!(
                "{outdir}/{which}_{}.csv",
                row.name.replace([' ', '.', '[', ']', '='], "_")
            );
            log.write_csv(std::path::Path::new(&fname))?;
        }
        out.push((row.name.to_string(), s));
    }
    let path = format!("{outdir}/{which}_summary.csv");
    std::fs::write(&path, summary_csv)?;
    println!("\nsummary written to {path}");
    Ok(out)
}
