//! Experiment configuration (JSON-serializable; drives CLI, examples
//! and benches).

use crate::elastic::{ChaosPlan, StragglerPolicy};
use crate::optim::LrSchedule;
use crate::quant::PolicySpec;
use anyhow::{bail, Result};

/// Which training method a run uses (rows of Tables 2–3).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    /// The paper: quantized generic Adam + error feedback. `kg = None`
    /// means no gradient quantization (fp32 uplink).
    QAdam { kg: Option<u32>, error_feedback: bool },
    /// TernGrad baseline (unbiased stochastic ternary, SGD).
    TernGrad,
    /// Zheng et al. [44] baseline (blockwise sign momentum SGD + EF).
    Blockwise { block: usize, momentum: f32 },
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::QAdam { kg: None, .. } => "qadam-fp32".into(),
            Method::QAdam { kg: Some(k), error_feedback: true } => format!("qadam-kg{k}"),
            Method::QAdam { kg: Some(k), error_feedback: false } => format!("qadam-kg{k}-noef"),
            Method::TernGrad => "terngrad".into(),
            Method::Blockwise { .. } => "blockwise".into(),
        }
    }
}

/// Which engine computes the QAdam worker step.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum Engine {
    /// Pure-Rust fused loop (fast on CPU; used by baselines always).
    #[default]
    Native,
    /// The AOT Pallas kernel through PJRT (the paper's L1 hot path).
    PjrtKernel,
}

/// How the synchronous round executes across workers — the transport
/// engine ([`crate::ps::Transport`]). Both produce bit-identical
/// trajectories; only wall-clock differs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BusKind {
    /// `LocalBus`: one thread, workers stepped in worker-id order, and
    /// a single-threaded parameter server. The seed behavior.
    #[default]
    Sequential,
    /// `ThreadedBus`: one scoped thread per worker, plus the
    /// block-sharded parameter server fanned out over all cores.
    Threaded,
}

impl BusKind {
    pub fn label(&self) -> &'static str {
        match self {
            BusKind::Sequential => "sequential",
            BusKind::Threaded => "threaded",
        }
    }

    /// Parse a CLI flag value (the one place the accepted spellings
    /// live); `None` for unknown values — callers should error, not
    /// fall back silently.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "sequential" | "seq" => Some(BusKind::Sequential),
            "threaded" | "thr" => Some(BusKind::Threaded),
            _ => None,
        }
    }
}

/// Downlink (server → worker) broadcast mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Downlink {
    /// Full `Q_x(x_t)` (or fp32) weights every round — the seed
    /// behavior, bit-identical trajectories to pre-delta builds.
    #[default]
    Full,
    /// Compressed weight-delta broadcasts with server-side error
    /// feedback (Efficient-Adam-style two-way compression) and periodic
    /// full resync frames (`resync_every`).
    Delta,
}

impl Downlink {
    pub fn label(&self) -> &'static str {
        match self {
            Downlink::Full => "full",
            Downlink::Delta => "delta",
        }
    }

    /// Parse a CLI flag value; `None` for unknown values — callers
    /// should error, not fall back silently.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "full" => Some(Downlink::Full),
            "delta" => Some(Downlink::Delta),
            _ => None,
        }
    }
}

/// Observability switches (`--trace-out`, `--metrics-addr`). Kept out
/// of [`ExperimentConfig`] on purpose: obs never changes what a run
/// computes (bit-reproducibility is pinned by `rust/tests/obs.rs`), so
/// it is not part of the experiment identity — two runs differing only
/// in `ObsConfig` are the *same* experiment. The default is all-off,
/// which is the zero-overhead path.
#[derive(Clone, Debug, Default)]
pub struct ObsConfig {
    /// JSONL span-trace output path (`--trace-out`). `None` = no trace.
    pub trace_out: Option<std::path::PathBuf>,
    /// `GET /metrics` listener address (`--metrics-addr`, e.g.
    /// `127.0.0.1:9184`). `None` = no exporter.
    pub metrics_addr: Option<String>,
}

impl ObsConfig {
    /// Whether any obs sink is requested — `false` keeps the trainer's
    /// obs slot `None`, i.e. the statically-zero-cost path.
    pub fn enabled(&self) -> bool {
        self.trace_out.is_some() || self.metrics_addr.is_some()
    }
}

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Model name from artifacts/manifest.json (e.g. "vgg_sim").
    pub model: String,
    /// Dataset: "cifar10_sim" | "cifar100_sim" | "text".
    pub dataset: String,
    pub method: Method,
    /// Weight quantization level for broadcast (None = fp32 weights).
    pub kx: Option<u32>,
    pub workers: usize,
    /// Per-worker batch size (must match the AOT-lowered train batch).
    pub batch: usize,
    pub steps: u64,
    /// Steps per "epoch" for LR decay / eval cadence.
    pub steps_per_epoch: u64,
    pub lr: LrSchedule,
    pub engine: Engine,
    /// Round transport: sequential reference engine or the parallel
    /// sharded engine (bit-identical results).
    pub bus: BusKind,
    /// Downlink broadcast mode: full frames every round, or compressed
    /// weight deltas with server-side error feedback.
    pub downlink: Downlink,
    /// Full-weights resync cadence in delta mode, in rounds (0 = only
    /// round 1 and forced resyncs). Ignored with `downlink = Full`.
    pub resync_every: u64,
    /// Deterministic fault-injection plan (`--chaos`). `None` keeps the
    /// round path untouched and bit-identical to pre-chaos builds.
    pub chaos: Option<ChaosPlan>,
    /// Per-tensor codec policy for the uplink (and, in delta mode, the
    /// downlink): `static` keeps the seed single-message path
    /// byte-identical; `per-layer`/`adaptive` switch to per-tensor
    /// frames ([`crate::quant::PolicySpec`], `--codec-policy`).
    pub codec_policy: PolicySpec,
    /// Parameter-server shards: the flat vector is split into this many
    /// contiguous ranges, each owned by an independent server instance
    /// with its own EF residual, replica, resync schedule and policy
    /// controller (`crate::ps::ShardedServer`). `1` (the default) is
    /// byte-identical to the unsharded engine.
    pub shards: usize,
    /// What a round does about stragglers: `Wait` (the seed behavior)
    /// or `Drop` (proceed at quorum).
    pub straggler: StragglerPolicy,
    /// Quorum under `straggler = Drop`: a round with fewer replies
    /// fails the run.
    pub min_participation: usize,
    /// Async bounded-staleness rounds (`--async-rounds`): the server
    /// applies deltas tagged with the round they were computed against,
    /// admitting any with age `now − t ≤ staleness` and refunding the
    /// rest into the sender's EF residual. `false` (the default) keeps
    /// the synchronous path byte-identical to pre-async builds.
    pub async_rounds: bool,
    /// Staleness bound τ in rounds (`--staleness`). Only read with
    /// `async_rounds = true`; `0` admits only fresh deltas.
    pub staleness: u64,
    /// Down-weight admitted deltas by `1/(1+age)` and refund the
    /// remaining `age/(1+age)` mass into the sender's EF residual
    /// (`--stale-down-weight`). Off = every admitted delta at full
    /// weight, matching the sync averaging rule exactly at age 0.
    pub staleness_down_weight: bool,
    /// Client sampling (`--cohort K`): draw K logical workers from a
    /// [`crate::elastic::WorkerRegistry`] of `registry` ids each round
    /// on a seeded per-round rng stream. `None` = every worker slot
    /// participates every round (the seed behavior).
    pub cohort: Option<usize>,
    /// Logical-worker registry size for `--cohort` sampling
    /// (`--registry`, default 100_000). Per-round cost is independent
    /// of this number.
    pub registry: u64,
    pub seed: u64,
    /// Evaluate every this many steps (0 = only at the end).
    pub eval_every: u64,
    /// How many eval batches per evaluation.
    pub eval_batches: usize,
}

impl ExperimentConfig {
    /// Paper-style defaults for the Table-3 stand-in (vgg_sim/CIFAR10-sim).
    pub fn table3_default() -> Self {
        Self {
            model: "vgg_sim".into(),
            dataset: "cifar10_sim".into(),
            method: Method::QAdam { kg: Some(2), error_feedback: true },
            kx: None,
            workers: crate::defaults::WORKERS,
            batch: crate::defaults::BATCH,
            steps: 400,
            steps_per_epoch: 64,
            lr: LrSchedule::ExpDecay { alpha: crate::defaults::ALPHA, half_every: 50 },
            engine: Engine::Native,
            bus: BusKind::default(),
            downlink: Downlink::default(),
            resync_every: 64,
            chaos: None,
            codec_policy: PolicySpec::default(),
            shards: 1,
            straggler: StragglerPolicy::default(),
            min_participation: 1,
            async_rounds: false,
            staleness: 0,
            staleness_down_weight: false,
            cohort: None,
            registry: 100_000,
            seed: 0,
            eval_every: 64,
            eval_batches: 4,
        }
    }

    /// Table-2 stand-in (resnet_sim/CIFAR100-sim).
    pub fn table2_default() -> Self {
        Self {
            model: "resnet_sim".into(),
            dataset: "cifar100_sim".into(),
            ..Self::table3_default()
        }
    }

    pub fn epoch_of(&self, t: u64) -> u64 {
        (t - 1) / self.steps_per_epoch.max(1)
    }

    pub fn run_label(&self) -> String {
        let kx = match self.kx {
            Some(k) => format!("-kx{k}"),
            None => String::new(),
        };
        let down = match self.downlink {
            Downlink::Full => String::new(),
            Downlink::Delta => "-ddelta".to_string(),
        };
        let pol = if self.codec_policy.is_static() {
            String::new()
        } else {
            format!("-{}", self.codec_policy.label())
        };
        let sh = if self.shards > 1 { format!("-s{}", self.shards) } else { String::new() };
        let asy = if self.async_rounds {
            format!("-async{}", self.staleness)
        } else {
            String::new()
        };
        let co = match self.cohort {
            Some(k) => format!("-c{k}"),
            None => String::new(),
        };
        format!("{}-{}{}{}{}{}{}{}", self.model, self.method.label(), kx, down, pol, sh, asy, co)
    }

    /// Cross-field sanity, run by `Trainer::new` before anything is
    /// built — the one place a bad `k_g`/`k_x`/policy combination turns
    /// into a clear error instead of a mid-run panic (satellite fix:
    /// `gradient_codec(kg)` used to accept an out-of-range level at
    /// parse time and blow up inside the codec constructor later).
    pub fn validate(&self) -> Result<()> {
        let kg = match self.method {
            Method::QAdam { kg, .. } => kg,
            _ => None,
        };
        crate::quant::validate_levels(kg, self.kx)?;
        if !self.codec_policy.is_static() {
            match self.method {
                Method::QAdam { kg: Some(_), error_feedback } => {
                    // The adaptive controller's only input is the EF
                    // residual; with EF off it reads zero debt forever
                    // and silently walks every tensor down to `lo`.
                    if !error_feedback
                        && matches!(self.codec_policy, PolicySpec::Adaptive { .. })
                    {
                        bail!(
                            "--codec-policy adaptive needs error feedback (drop --no-ef): \
                             the controller is driven by the EF residual"
                        );
                    }
                }
                _ => bail!(
                    "--codec-policy {} needs a k_g-bearing method (qadam with --kg)",
                    self.codec_policy.label()
                ),
            }
            if self.engine == Engine::PjrtKernel {
                bail!("--codec-policy is native-engine only (the AOT kernel bakes in one k_g)");
            }
            self.codec_policy.validate()?;
        }
        if self.shards == 0 {
            bail!("--shards must be at least 1");
        }
        if self.shards > 1 && self.engine == Engine::PjrtKernel {
            bail!(
                "--shards > 1 is native-engine only (the AOT kernel emits one fused \
                 whole-vector message and cannot split its payload per shard)"
            );
        }
        if !self.async_rounds && (self.staleness != 0 || self.staleness_down_weight) {
            bail!("--staleness / --stale-down-weight need --async-rounds");
        }
        if let Some(k) = self.cohort {
            if k == 0 {
                bail!("--cohort must be at least 1");
            }
            if (k as u64) > self.registry {
                bail!(
                    "--cohort {k} exceeds the registry size {} (raise --registry)",
                    self.registry
                );
            }
        }
        if self.registry == 0 {
            bail!("--registry must be at least 1");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{MAX_KG, MAX_KX};

    #[test]
    fn defaults_are_consistent() {
        let c = ExperimentConfig::table2_default();
        assert_eq!(c.model, "resnet_sim");
        assert_eq!(c.dataset, "cifar100_sim");
        assert_eq!(c.workers, 8);
    }

    #[test]
    fn labels() {
        assert_eq!(Method::QAdam { kg: Some(2), error_feedback: true }.label(), "qadam-kg2");
        assert_eq!(Method::QAdam { kg: None, error_feedback: false }.label(), "qadam-fp32");
        let mut c = ExperimentConfig::table3_default();
        c.kx = Some(6);
        assert_eq!(c.run_label(), "vgg_sim-qadam-kg2-kx6");
    }

    #[test]
    fn downlink_modes() {
        assert_eq!(Downlink::default(), Downlink::Full);
        assert_eq!(Downlink::Full.label(), "full");
        assert_eq!(Downlink::Delta.label(), "delta");
        assert_eq!(Downlink::parse("full"), Some(Downlink::Full));
        assert_eq!(Downlink::parse("delta"), Some(Downlink::Delta));
        assert_eq!(Downlink::parse("deltaa"), None); // typos error, never fall back
        let mut c = ExperimentConfig::table3_default();
        c.downlink = Downlink::Delta;
        assert_eq!(c.run_label(), "vgg_sim-qadam-kg2-ddelta");
    }

    #[test]
    fn bus_kinds() {
        assert_eq!(BusKind::default(), BusKind::Sequential);
        assert_eq!(BusKind::Sequential.label(), "sequential");
        assert_eq!(BusKind::Threaded.label(), "threaded");
        assert_eq!(BusKind::parse("sequential"), Some(BusKind::Sequential));
        assert_eq!(BusKind::parse("thr"), Some(BusKind::Threaded));
        assert_eq!(BusKind::parse("threadd"), None); // typos error, never fall back
    }

    #[test]
    fn codec_policy_defaults_and_validation() {
        let mut c = ExperimentConfig::table3_default();
        assert!(c.codec_policy.is_static());
        c.validate().unwrap();
        // satellite fix: out-of-range kg is a clear parse-time error,
        // not a mid-run panic inside the codec constructor
        c.method = Method::QAdam { kg: Some(MAX_KG + 1), error_feedback: true };
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        c.method = Method::QAdam { kg: Some(2), error_feedback: true };
        c.kx = Some(MAX_KX + 1);
        assert!(c.validate().is_err());
        c.kx = None;
        // non-static policy needs a kg-bearing native method
        c.codec_policy = PolicySpec::Adaptive { lo: 0, hi: 4 };
        c.validate().unwrap();
        assert_eq!(c.run_label(), "vgg_sim-qadam-kg2-adaptive0..4");
        c.method = Method::TernGrad;
        assert!(c.validate().is_err());
        c.method = Method::QAdam { kg: None, error_feedback: true };
        assert!(c.validate().is_err());
        c.method = Method::QAdam { kg: Some(2), error_feedback: true };
        c.engine = Engine::PjrtKernel;
        assert!(c.validate().is_err());
        c.engine = Engine::Native;
        // adaptive without EF has no signal: the controller would read
        // zero debt forever and silently collapse to the band floor
        c.method = Method::QAdam { kg: Some(2), error_feedback: false };
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("error feedback"), "{err}");
        // …but a *fixed* per-layer policy is fine without EF
        c.codec_policy = PolicySpec::parse("per-layer:*=1").unwrap();
        c.validate().unwrap();
        c.method = Method::QAdam { kg: Some(2), error_feedback: true };
        c.codec_policy = PolicySpec::Adaptive { lo: 5, hi: 1 };
        assert!(c.validate().is_err());
    }

    #[test]
    fn elastic_defaults_keep_the_seed_path() {
        let c = ExperimentConfig::table3_default();
        assert!(c.chaos.is_none());
        assert_eq!(c.straggler, StragglerPolicy::Wait);
        assert_eq!(c.min_participation, 1);
        assert_eq!(c.shards, 1, "the default is the unsharded (seed) engine");
        assert!(!c.async_rounds, "sync rounds are the seed behavior");
        assert!(c.cohort.is_none(), "no client sampling by default");
    }

    #[test]
    fn async_and_cohort_validate_and_label() {
        let mut c = ExperimentConfig::table3_default();
        c.async_rounds = true;
        c.staleness = 3;
        c.validate().unwrap();
        assert_eq!(c.run_label(), "vgg_sim-qadam-kg2-async3");
        c.cohort = Some(4);
        c.validate().unwrap();
        assert_eq!(c.run_label(), "vgg_sim-qadam-kg2-async3-c4");
        // staleness knobs without the mode are a config error, not a
        // silent no-op
        c.async_rounds = false;
        assert!(c.validate().is_err());
        c.staleness = 0;
        c.staleness_down_weight = true;
        assert!(c.validate().is_err());
        c.staleness_down_weight = false;
        c.validate().unwrap();
        // cohort must fit inside the registry
        c.registry = 3;
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("registry"), "{err}");
        c.registry = 0;
        assert!(c.validate().is_err());
        c.registry = 100_000;
        c.cohort = Some(0);
        assert!(c.validate().is_err());
    }

    #[test]
    fn shards_validate_and_label() {
        let mut c = ExperimentConfig::table3_default();
        c.shards = 4;
        c.validate().unwrap();
        assert_eq!(c.run_label(), "vgg_sim-qadam-kg2-s4");
        c.shards = 0;
        assert!(c.validate().is_err());
        // the AOT kernel cannot split its fused payload
        c.shards = 2;
        c.engine = Engine::PjrtKernel;
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("native-engine"), "{err}");
        c.engine = Engine::Native;
        c.validate().unwrap();
    }

    #[test]
    fn epoch_boundaries() {
        let mut c = ExperimentConfig::table3_default();
        c.steps_per_epoch = 10;
        assert_eq!(c.epoch_of(1), 0);
        assert_eq!(c.epoch_of(10), 0);
        assert_eq!(c.epoch_of(11), 1);
    }
}
