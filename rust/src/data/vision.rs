//! Synthetic vision dataset: the CIFAR10/CIFAR100 stand-in.
//!
//! Each class `c` has a deterministic structured prototype image built
//! from a few random low-frequency "blobs" plus a class-colored
//! gradient; a sample is `prototype + sigma * noise`, with a small
//! label-noise rate so accuracy saturates below 100% (as in real data).
//! This keeps the task nonconvex and non-trivial for a conv net while
//! exercising exactly the code paths the paper's tables depend on
//! (optimizer/compressor interaction — see DESIGN.md §Substitutions).

use super::{Batch, Dataset};
use crate::util::DetRng;

pub const H: usize = 32;
pub const W: usize = 32;
pub const C: usize = 3;
pub const DIM: usize = H * W * C;

#[derive(Clone, Debug)]
pub struct SyntheticVision {
    pub n_classes: usize,
    pub noise: f32,
    pub label_noise: f32,
    pub train_n: usize,
    pub test_n: usize,
    seed: u64,
    prototypes: Vec<Vec<f32>>, // n_classes x DIM
}

fn rng_for(seed: u64, stream: u64) -> DetRng {
    crate::quant::seeded_rng(seed, stream)
}

impl SyntheticVision {
    pub fn new(n_classes: usize, train_n: usize, test_n: usize, seed: u64) -> Self {
        Self::with_difficulty(n_classes, train_n, test_n, seed, 0.25, 1.3)
    }

    /// `class_amp` scales the class-specific blob amplitude relative to
    /// the shared base image; together with `noise` it sets how hard the
    /// discrimination is (tuned so each stand-in trains into the
    /// mid-accuracy regime within the CPU step budget).
    pub fn with_difficulty(
        n_classes: usize,
        train_n: usize,
        test_n: usize,
        seed: u64,
        class_amp: f32,
        noise: f32,
    ) -> Self {
        let mut prototypes = Vec::with_capacity(n_classes);
        for c in 0..n_classes {
            prototypes.push(Self::make_prototype(seed, c, class_amp));
        }
        Self { n_classes, noise, label_noise: 0.05, train_n, test_n, seed, prototypes }
    }

    /// The Table-2 stand-in (CIFAR100 / resnet_sim): 20 classes. The
    /// residual net pools globally, so it needs a stronger per-class
    /// signal than the FC-headed vgg_sim to learn within budget.
    pub fn cifar100_sim(seed: u64) -> Self {
        Self::with_difficulty(20, 8192, 2048, seed, 0.8, 1.0)
    }

    /// The Table-3 stand-in (CIFAR10 / vgg_sim): 10 classes.
    pub fn cifar10_sim(seed: u64) -> Self {
        Self::with_difficulty(10, 8192, 2048, seed, 0.25, 1.3)
    }

    fn make_prototype(seed: u64, class: usize, class_amp: f32) -> Vec<f32> {
        // A shared base image (same for every class) plus a *small*
        // class-specific perturbation: between-class distances are a
        // fraction of the within-class noise, so the task does not
        // saturate instantly and optimizer differences are visible.
        let mut img = vec![0.0f32; DIM];
        let mut base_rng = rng_for(seed, 999_999);
        Self::add_blobs(&mut base_rng, &mut img, 4, 1.0);
        let mut rng = rng_for(seed, 1_000_000 + class as u64);
        Self::add_blobs(&mut rng, &mut img, 3, class_amp);
        // class-colored gradient so global pooling also carries signal
        let hue = class as f32 / 7.0;
        for y in 0..H {
            for x in 0..W {
                let t = (x as f32 / W as f32 + y as f32 / H as f32) * 0.5;
                img[(y * W + x) * C] += 0.1 * (hue + t).sin();
                img[(y * W + x) * C + 1] += 0.1 * (hue * 2.0 + t).cos();
                img[(y * W + x) * C + 2] += 0.1 * (hue * 3.0 - t).sin();
            }
        }
        img
    }

    fn add_blobs(rng: &mut DetRng, img: &mut [f32], n: usize, amp_scale: f32) {
        for _ in 0..n {
            let cx: f32 = rng.gen_f32() * W as f32;
            let cy: f32 = rng.gen_f32() * H as f32;
            let rad: f32 = 3.0 + rng.gen_f32() * 6.0;
            let amp: [f32; 3] = [
                amp_scale * (rng.gen_f32() * 2.0 - 1.0),
                amp_scale * (rng.gen_f32() * 2.0 - 1.0),
                amp_scale * (rng.gen_f32() * 2.0 - 1.0),
            ];
            for y in 0..H {
                for x in 0..W {
                    let d2 = ((x as f32 - cx).powi(2) + (y as f32 - cy).powi(2)) / (rad * rad);
                    let g = (-d2).exp();
                    for ch in 0..C {
                        img[(y * W + x) * C + ch] += amp[ch] * g;
                    }
                }
            }
        }
    }

    fn sample_into(&self, global_idx: u64, is_test: bool, x: &mut [f32]) -> i32 {
        let stream = if is_test { 2_000_000_000 + global_idx } else { global_idx };
        let mut rng = rng_for(self.seed, stream);
        let true_class = (rng.gen_u32() as usize) % self.n_classes;
        let proto = &self.prototypes[true_class];
        for (xo, &p) in x.iter_mut().zip(proto) {
            // Box-Muller-free: sum of uniforms ~ approx gaussian (Irwin-Hall)
            let n: f32 = (0..4).map(|_| rng.gen_f32()).sum::<f32>() - 2.0;
            *xo = p + self.noise * n * 0.866; // var-normalized
        }
        let label = if rng.gen_f32() < self.label_noise {
            (rng.gen_u32() as usize % self.n_classes) as i32
        } else {
            true_class as i32
        };
        label
    }
}

impl Dataset for SyntheticVision {
    fn train_batch(&self, worker: usize, step: u64, batch: usize) -> Batch {
        let mut x = vec![0.0f32; batch * DIM];
        let mut y = vec![0i32; batch];
        for b in 0..batch {
            // disjoint per-worker shards of the (cyclic) training stream
            let idx = (step * batch as u64 + b as u64) % (self.train_n as u64)
                + (worker as u64) * self.train_n as u64;
            y[b] = self.sample_into(idx, false, &mut x[b * DIM..(b + 1) * DIM]);
        }
        Batch::Vision { x, y }
    }

    fn eval_batch(&self, idx: usize, batch: usize) -> Batch {
        let mut x = vec![0.0f32; batch * DIM];
        let mut y = vec![0i32; batch];
        for b in 0..batch {
            let gi = (idx * batch + b) as u64;
            y[b] = self.sample_into(gi, true, &mut x[b * DIM..(b + 1) * DIM]);
        }
        Batch::Vision { x, y }
    }

    fn eval_batches(&self, batch: usize) -> usize {
        self.test_n / batch
    }

    fn num_classes(&self) -> usize {
        self.n_classes
    }

    fn train_size(&self) -> usize {
        self.train_n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_batches() {
        let d = SyntheticVision::cifar10_sim(7);
        let a = d.train_batch(2, 5, 4);
        let b = d.train_batch(2, 5, 4);
        match (a, b) {
            (Batch::Vision { x: xa, y: ya }, Batch::Vision { x: xb, y: yb }) => {
                assert_eq!(xa, xb);
                assert_eq!(ya, yb);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn workers_get_disjoint_shards() {
        let d = SyntheticVision::cifar10_sim(7);
        let (Batch::Vision { x: x0, .. }, Batch::Vision { x: x1, .. }) =
            (d.train_batch(0, 0, 4), d.train_batch(1, 0, 4))
        else {
            unreachable!()
        };
        assert_ne!(x0, x1);
    }

    #[test]
    fn labels_in_range_and_varied() {
        let d = SyntheticVision::cifar100_sim(1);
        let Batch::Vision { y, .. } = d.eval_batch(0, 256) else { unreachable!() };
        assert!(y.iter().all(|&l| (0..20).contains(&l)));
        let distinct: std::collections::HashSet<_> = y.iter().collect();
        assert!(distinct.len() > 10);
    }

    #[test]
    fn class_signal_exists() {
        // nearest-prototype classification on clean-ish samples should
        // beat chance by a wide margin -> the task is learnable.
        let d = SyntheticVision::cifar10_sim(3);
        let Batch::Vision { x, y } = d.eval_batch(0, 128) else { unreachable!() };
        let mut correct = 0;
        for b in 0..128 {
            let xi = &x[b * DIM..(b + 1) * DIM];
            let best = (0..10)
                .min_by(|&a, &c| {
                    let da: f32 = d.prototypes[a].iter().zip(xi).map(|(p, v)| (p - v).powi(2)).sum();
                    let dc: f32 = d.prototypes[c].iter().zip(xi).map(|(p, v)| (p - v).powi(2)).sum();
                    da.partial_cmp(&dc).unwrap()
                })
                .unwrap();
            if best as i32 == y[b] {
                correct += 1;
            }
        }
        assert!(correct > 40, "nearest-prototype acc {correct}/128");
    }
}
