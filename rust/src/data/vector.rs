//! Synthetic vector dataset (Gaussian clusters in R^d) — the quickstart
//! / MLP workload and the convergence-check classifier task.

use super::{Batch, Dataset};
use crate::util::DetRng;

#[derive(Clone, Debug)]
pub struct SyntheticVector {
    pub dim: usize,
    pub n_classes: usize,
    pub noise: f32,
    pub train_n: usize,
    pub test_n: usize,
    seed: u64,
    prototypes: Vec<Vec<f32>>,
}

impl SyntheticVector {
    pub fn new(dim: usize, n_classes: usize, seed: u64) -> Self {
        let mut prototypes = Vec::with_capacity(n_classes);
        for c in 0..n_classes {
            let mut rng = DetRng::seed_stream(seed, 5_000_000 + c as u64);
            prototypes.push((0..dim).map(|_| rng.gen_normal() * 1.2).collect());
        }
        Self { dim, n_classes, noise: 1.0, train_n: 8192, test_n: 2048, seed, prototypes }
    }

    fn sample_into(&self, global_idx: u64, is_test: bool, x: &mut [f32]) -> i32 {
        let stream = if is_test { 2_000_000_000 + global_idx } else { global_idx };
        let mut rng = DetRng::seed_stream(self.seed, stream);
        let cls = (rng.gen_u32() as usize) % self.n_classes;
        for (xo, &p) in x.iter_mut().zip(&self.prototypes[cls]) {
            *xo = p + self.noise * rng.gen_normal();
        }
        cls as i32
    }
}

impl Dataset for SyntheticVector {
    fn train_batch(&self, worker: usize, step: u64, batch: usize) -> Batch {
        let mut x = vec![0.0f32; batch * self.dim];
        let mut y = vec![0i32; batch];
        for b in 0..batch {
            let idx = (step * batch as u64 + b as u64) % self.train_n as u64
                + worker as u64 * self.train_n as u64;
            y[b] = self.sample_into(idx, false, &mut x[b * self.dim..(b + 1) * self.dim]);
        }
        Batch::Vision { x, y }
    }

    fn eval_batch(&self, idx: usize, batch: usize) -> Batch {
        let mut x = vec![0.0f32; batch * self.dim];
        let mut y = vec![0i32; batch];
        for b in 0..batch {
            y[b] = self.sample_into((idx * batch + b) as u64, true, &mut x[b * self.dim..(b + 1) * self.dim]);
        }
        Batch::Vision { x, y }
    }

    fn eval_batches(&self, batch: usize) -> usize {
        self.test_n / batch
    }

    fn num_classes(&self) -> usize {
        self.n_classes
    }

    fn train_size(&self) -> usize {
        self.train_n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_separable() {
        let d = SyntheticVector::new(64, 10, 3);
        let Batch::Vision { x: a, y: ya } = d.train_batch(0, 0, 8) else { unreachable!() };
        let Batch::Vision { x: b, y: yb } = d.train_batch(0, 0, 8) else { unreachable!() };
        assert_eq!(a, b);
        assert_eq!(ya, yb);
        // nearest-prototype accuracy well above chance
        let Batch::Vision { x, y } = d.eval_batch(0, 128) else { unreachable!() };
        let mut correct = 0;
        for i in 0..128 {
            let xi = &x[i * 64..(i + 1) * 64];
            let best = (0..10)
                .min_by(|&p, &q| {
                    let dp: f32 = d.prototypes[p].iter().zip(xi).map(|(a, b)| (a - b) * (a - b)).sum();
                    let dq: f32 = d.prototypes[q].iter().zip(xi).map(|(a, b)| (a - b) * (a - b)).sum();
                    dp.partial_cmp(&dq).unwrap()
                })
                .unwrap();
            if best as i32 == y[i] {
                correct += 1;
            }
        }
        assert!(correct > 90, "nearest-prototype acc {correct}/128");
    }
}
