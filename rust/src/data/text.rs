//! Synthetic token corpus for the LM end-to-end run: an order-2 Markov
//! chain with sparse, peaked transitions. A transformer that learns the
//! bigram→next table reaches substantially lower cross-entropy than the
//! unigram baseline, so the loss curve is a meaningful training signal.

use super::{Batch, Dataset};
use crate::util::DetRng;

#[derive(Clone, Debug)]
pub struct SyntheticText {
    pub vocab: usize,
    pub seq: usize,
    seed: u64,
    /// transitions[a*vocab + b] = the 4 candidate next tokens (peaked).
    transitions: Vec<[u16; 4]>,
    /// temperature: probability mass of the top candidate.
    top_p: f32,
}

fn rng_for(seed: u64, stream: u64) -> DetRng {
    crate::quant::seeded_rng(seed, stream)
}

impl SyntheticText {
    pub fn new(vocab: usize, seq: usize, seed: u64) -> Self {
        assert!(vocab <= u16::MAX as usize);
        let mut transitions = Vec::with_capacity(vocab * vocab);
        let mut rng = rng_for(seed, 42);
        for _ in 0..vocab * vocab {
            transitions.push([
                (rng.gen_u32() as usize % vocab) as u16,
                (rng.gen_u32() as usize % vocab) as u16,
                (rng.gen_u32() as usize % vocab) as u16,
                (rng.gen_u32() as usize % vocab) as u16,
            ]);
        }
        Self { vocab, seq, seed, transitions, top_p: 0.75 }
    }

    /// Generate a (seq+1)-token stream for stream id `sid`; the batch is
    /// x = tokens[..seq], y = tokens[1..].
    fn stream(&self, sid: u64, is_test: bool) -> Vec<u16> {
        let base = if is_test { 3_000_000_000 } else { 0 };
        let mut rng = rng_for(self.seed, base + sid);
        let mut out = Vec::with_capacity(self.seq + 1);
        let mut a = (rng.gen_u32() as usize % self.vocab) as u16;
        let mut b = (rng.gen_u32() as usize % self.vocab) as u16;
        out.push(a);
        out.push(b);
        while out.len() < self.seq + 1 {
            let cands = &self.transitions[a as usize * self.vocab + b as usize];
            let r: f32 = rng.gen_f32();
            let next = if r < self.top_p {
                cands[0]
            } else if r < self.top_p + (1.0 - self.top_p) * 0.6 {
                cands[1]
            } else if r < self.top_p + (1.0 - self.top_p) * 0.9 {
                cands[2]
            } else {
                cands[3]
            };
            out.push(next);
            a = b;
            b = next;
        }
        out
    }

    fn batch(&self, first_sid: u64, batch: usize, is_test: bool) -> Batch {
        let mut x = Vec::with_capacity(batch * self.seq);
        let mut y = Vec::with_capacity(batch * self.seq);
        for b in 0..batch {
            let s = self.stream(first_sid + b as u64, is_test);
            x.extend(s[..self.seq].iter().map(|&t| t as i32));
            y.extend(s[1..=self.seq].iter().map(|&t| t as i32));
        }
        Batch::Text { x, y }
    }
}

impl Dataset for SyntheticText {
    fn train_batch(&self, worker: usize, step: u64, batch: usize) -> Batch {
        let sid = (worker as u64) << 40 | step * batch as u64;
        self.batch(sid, batch, false)
    }

    fn eval_batch(&self, idx: usize, batch: usize) -> Batch {
        self.batch((idx * batch) as u64, batch, true)
    }

    fn eval_batches(&self, _batch: usize) -> usize {
        8
    }

    fn num_classes(&self) -> usize {
        self.vocab
    }

    fn train_size(&self) -> usize {
        1 << 20
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_range() {
        let d = SyntheticText::new(64, 32, 5);
        let (Batch::Text { x: xa, y: ya }, Batch::Text { x: xb, y: yb }) =
            (d.train_batch(1, 3, 4), d.train_batch(1, 3, 4))
        else {
            unreachable!()
        };
        assert_eq!(xa, xb);
        assert_eq!(ya, yb);
        assert!(xa.iter().all(|&t| (0..64).contains(&t)));
        assert_eq!(xa.len(), 4 * 32);
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let d = SyntheticText::new(64, 32, 5);
        let Batch::Text { x, y } = d.train_batch(0, 0, 1) else { unreachable!() };
        assert_eq!(&x[1..], &y[..31]);
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // The top transition should dominate empirically (~top_p).
        let d = SyntheticText::new(64, 512, 9);
        let Batch::Text { x, y } = d.train_batch(0, 0, 4) else { unreachable!() };
        let mut hits = 0;
        let mut total = 0;
        for b in 0..4 {
            for i in 1..511 {
                let a = x[b * 512 + i - 1] as usize;
                let bb = x[b * 512 + i] as usize;
                let next = y[b * 512 + i];
                if d.transitions[a * 64 + bb][0] as i32 == next {
                    hits += 1;
                }
                total += 1;
            }
        }
        let rate = hits as f32 / total as f32;
        assert!(rate > 0.6, "top-transition rate {rate}");
    }
}
