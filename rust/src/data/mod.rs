//! Synthetic datasets — the CPU-scale stand-ins for CIFAR10/100 and the
//! LM corpus (DESIGN.md §Substitutions).

pub mod text;
pub mod vector;
pub mod vision;

pub use text::SyntheticText;
pub use vector::SyntheticVector;
pub use vision::SyntheticVision;

/// A minibatch as the flat buffers the PJRT graphs consume.
#[derive(Clone, Debug)]
pub enum Batch {
    /// (x f32 [B, ...flattened], y i32 [B])
    Vision { x: Vec<f32>, y: Vec<i32> },
    /// (tokens i32 [B, T], targets i32 [B, T])
    Text { x: Vec<i32>, y: Vec<i32> },
}

impl Batch {
    pub fn labels(&self) -> &[i32] {
        match self {
            Batch::Vision { y, .. } | Batch::Text { y, .. } => y,
        }
    }
}

/// Common dataset interface: deterministic, shardable by worker.
pub trait Dataset: Send + Sync {
    /// Training batch for (worker, step). Deterministic in all args.
    fn train_batch(&self, worker: usize, step: u64, batch: usize) -> Batch;
    /// Fixed held-out evaluation batch `idx` of size `batch`.
    fn eval_batch(&self, idx: usize, batch: usize) -> Batch;
    /// Number of eval batches available at this size.
    fn eval_batches(&self, batch: usize) -> usize;
    fn num_classes(&self) -> usize;
    /// Samples per epoch across all workers (defines epoch boundaries).
    fn train_size(&self) -> usize;
}
