//! PJRT runtime: loads `artifacts/*.hlo.txt` and executes them on the
//! CPU PJRT client. This is the only place the `xla` crate is touched;
//! everything above works on flat `Vec<f32>` tensors.
//!
//! Interchange is HLO *text* (the jax side lowers StableHLO →
//! XlaComputation → `as_hlo_text()`); `HloModuleProto::from_text_file`
//! reassigns instruction ids, which sidesteps the 64-bit-id protos that
//! xla_extension 0.5.1 rejects (see /opt/xla-example/README.md).

pub mod kernel;
pub mod model;

pub use kernel::KernelQAdam;
pub use model::ModelRuntime;

use anyhow::{Context, Result};
use std::path::Path;
use std::rc::Rc;

/// Shared PJRT CPU client. One per process; graphs are compiled against
/// it and share its thread pool.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Rc<Self>> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Rc::new(Self { client }))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<Graph> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Graph { exe })
    }
}

/// One compiled executable. All our graphs are lowered with
/// `return_tuple=True`, so `run` unpacks the single tuple output.
pub struct Graph {
    exe: xla::PjRtLoadedExecutable,
}

impl Graph {
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let res = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = res[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// f32 vector -> rank-N literal with the given dims.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    let v = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(v);
    }
    let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
    Ok(v.reshape(&d)?)
}

/// i32 vector -> rank-N literal.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    let v = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(v);
    }
    let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
    Ok(v.reshape(&d)?)
}

/// f32 scalar literal (shape `f32[]`, matching a jax `()` operand).
pub fn literal_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}
