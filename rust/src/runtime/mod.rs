//! PJRT runtime: loads `artifacts/*.hlo.txt` and executes them on the
//! CPU PJRT client. This is the only place the `xla` crate is touched;
//! everything above works on flat `Vec<f32>` tensors.
//!
//! Interchange is HLO *text* (the jax side lowers StableHLO →
//! XlaComputation → `as_hlo_text()`); `HloModuleProto::from_text_file`
//! reassigns instruction ids, which sidesteps the 64-bit-id protos that
//! xla_extension 0.5.1 rejects (see /opt/xla-example/README.md).

pub mod kernel;
pub mod model;

pub use kernel::KernelQAdam;
pub use model::ModelRuntime;

use anyhow::{Context, Result};
use std::path::Path;
use std::sync::Arc;

/// Shared PJRT CPU client. One per process; graphs are compiled against
/// it and share its thread pool.
pub struct Runtime {
    client: xla::PjRtClient,
}

/// All PJRT object traffic from worker threads funnels through this
/// one lock (see the SAFETY notes below). Coarse on purpose: the CPU
/// PJRT client parallelizes *inside* one execution via its own thread
/// pool, so serializing the execute calls themselves costs little,
/// and it is what lets us share graphs across `ThreadedBus` threads
/// without trusting unverifiable internals of the `xla` wrapper.
static PJRT_EXEC_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

// SAFETY: the underlying PJRT C++ client and loaded executables are
// thread-safe per the PJRT API contract, but the rust `xla` wrapper
// adds bookkeeping we cannot audit from here (it is not vendored), so
// we do not rely on it: every cross-thread use of PJRT state goes
// through [`Graph::run`], which holds the global [`PJRT_EXEC_LOCK`]
// for the whole execute + host-transfer, and construction/drop of
// `Runtime`/`Graph` stay on the owning thread, ordered against worker
// threads by `std::thread::scope`'s spawn/join happens-before edges.
// `Literal` inputs/outputs are created, used and dropped by exactly
// one thread (inside the lock where they touch device buffers).
//
// Audit (INV-SAFETY): derived bounds are not an option — the wrapper
// types hold raw FFI handles the compiler conservatively marks
// `!Send`/`!Sync`, and wrapping them in a `Mutex` would not help
// because `Mutex<T>: Send/Sync` still requires `T: Send`. These four
// impls are the crate's entire unsafe inventory; `qadam lint` pins the
// count to `analysis::UNSAFE_BUDGET` and rejects any site missing a
// SAFETY justification, so a new impl cannot slip in unaudited. The
// opt-in ThreadSanitizer lane in scripts/ci.sh exercises the
// cross-thread path this argument covers (`shard_parity` over
// `ThreadedBus`).
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    pub fn cpu() -> Result<Arc<Self>> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Arc::new(Self { client }))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<Graph> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Graph { exe })
    }
}

/// One compiled executable. All our graphs are lowered with
/// `return_tuple=True`, so `run` unpacks the single tuple output.
pub struct Graph {
    exe: xla::PjRtLoadedExecutable,
}

// SAFETY: see the audit note on [`Runtime`] — all executions serialize
// on [`PJRT_EXEC_LOCK`], so the wrapper's internals are never touched
// by two threads at once, and derived bounds are unavailable for the
// same FFI-handle reason.
unsafe impl Send for Graph {}
unsafe impl Sync for Graph {}

impl Graph {
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let _guard = PJRT_EXEC_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let res = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = res[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// f32 vector -> rank-N literal with the given dims.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    let v = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(v);
    }
    let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
    Ok(v.reshape(&d)?)
}

/// i32 vector -> rank-N literal.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    let v = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(v);
    }
    let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
    Ok(v.reshape(&d)?)
}

/// f32 scalar literal (shape `f32[]`, matching a jax `()` operand).
pub fn literal_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}
